//! API-compatible stand-in for the vendored XLA PjRT bindings.
//!
//! The real `xla` crate wraps a PJRT CPU client (raw C API pointers, a
//! multi-hundred-megabyte native dependency) and is vendored out-of-tree.
//! This stub reproduces the exact API surface `yggdrasil::runtime::actor`
//! drives — client/buffer/executable/literal types, `HloModuleProto`
//! loading — so the crate builds and every unit/property test runs in
//! environments without the native toolchain.
//!
//! Behavioural contract:
//!
//! * Host↔device buffer traffic works for real (buffers hold their host
//!   bytes, `Literal::to_vec` round-trips them), so allocation paths and
//!   cache bookkeeping are exercised.
//! * `compile`/`execute` fail with [`Error::StubBackend`]-style messages:
//!   model execution genuinely needs the native bindings. Every test and
//!   experiment that needs model execution is gated on the presence of the
//!   AOT `artifacts/` bundle, which can only be produced with the real
//!   backend — so nothing silently "passes" against fake numerics.
//!
//! Dropping the real vendored crate into `rust/vendor/xla` restores full
//! execution with no source changes elsewhere.

use std::fmt;

/// Error type mirroring the native wrapper's opaque status errors.
pub struct Error(String);

impl Error {
    pub fn msg(s: impl Into<String>) -> Self {
        Error(s.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla::Error({})", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_exec_error() -> Error {
    Error::msg(
        "the XLA PjRT bindings are stubbed out in this build \
         (rust/vendor/xla is the API stand-in); model execution is \
         unavailable — vendor the real bindings to run against artifacts",
    )
}

/// Element types the in-tree runtime stages (tokens/positions/slots are
/// `i32`, everything else `f32`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElemType {
    F32,
    I32,
}

/// Host-native element trait for typed staging/readback.
pub trait NativeType: Copy {
    const ELEM: ElemType;
    fn to_le_bytes_vec(xs: &[Self]) -> Vec<u8>;
    fn from_le_bytes_vec(bytes: &[u8]) -> Vec<Self>;
}

impl NativeType for f32 {
    const ELEM: ElemType = ElemType::F32;
    fn to_le_bytes_vec(xs: &[Self]) -> Vec<u8> {
        xs.iter().flat_map(|x| x.to_le_bytes()).collect()
    }
    fn from_le_bytes_vec(bytes: &[u8]) -> Vec<Self> {
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }
}

impl NativeType for i32 {
    const ELEM: ElemType = ElemType::I32;
    fn to_le_bytes_vec(xs: &[Self]) -> Vec<u8> {
        xs.iter().flat_map(|x| x.to_le_bytes()).collect()
    }
    fn from_le_bytes_vec(bytes: &[u8]) -> Vec<Self> {
        bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }
}

/// A parsed HLO module (the stub only records where it came from).
pub struct HloModuleProto {
    pub source: String,
}

impl HloModuleProto {
    /// Loads HLO text from `path`. The stub validates the file exists and
    /// is readable but does not parse the HLO grammar.
    pub fn from_text_file(path: &str) -> Result<Self> {
        std::fs::read_to_string(path)
            .map(|_| HloModuleProto { source: path.to_string() })
            .map_err(|e| Error::msg(format!("reading HLO text {path}: {e}")))
    }
}

/// An XLA computation handle built from an HLO module.
pub struct XlaComputation {
    pub source: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        XlaComputation { source: proto.source.clone() }
    }
}

/// Device-resident buffer: in the stub, the host bytes plus shape/dtype.
pub struct PjRtBuffer {
    elem: ElemType,
    shape: Vec<usize>,
    bytes: Vec<u8>,
}

impl PjRtBuffer {
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Synchronous device→host readback.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(Literal { elem: self.elem, bytes: self.bytes.clone() })
    }
}

/// Host-side copy of a buffer.
pub struct Literal {
    elem: ElemType,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.elem != T::ELEM {
            return Err(Error::msg(format!(
                "literal element type {:?} does not match requested {:?}",
                self.elem,
                T::ELEM
            )));
        }
        Ok(T::from_le_bytes_vec(&self.bytes))
    }
}

/// A compiled executable. The stub never constructs one (compilation
/// fails first), but the type and its API exist so callers typecheck.
pub struct PjRtLoadedExecutable {
    _source: String,
}

impl PjRtLoadedExecutable {
    /// Executes with borrowed (non-donated) argument buffers, untupled
    /// replica outputs: `result[replica][output]`.
    pub fn execute_b_untuple(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_exec_error())
    }
}

/// The PJRT client. `cpu()` succeeds so buffer/cache plumbing (weight
/// upload, KV-cache allocation) is exercised; `compile` is the gate that
/// reports the missing native backend.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(PjRtClient)
    }

    /// Stages a host slice as a device buffer. `_device` selects a device
    /// ordinal in the real bindings; the stub is single-device.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            return Err(Error::msg(format!(
                "shape {shape:?} ({numel} elements) does not match host data of {}",
                data.len()
            )));
        }
        Ok(PjRtBuffer {
            elem: T::ELEM,
            shape: shape.to_vec(),
            bytes: T::to_le_bytes_vec(data),
        })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_exec_error())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffer_roundtrip_f32() {
        let c = PjRtClient::cpu().unwrap();
        let data = vec![1.0f32, -2.5, 3.25];
        let b = c.buffer_from_host_buffer(&data, &[3], None).unwrap();
        let back: Vec<f32> = b.to_literal_sync().unwrap().to_vec().unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn buffer_roundtrip_i32_and_type_check() {
        let c = PjRtClient::cpu().unwrap();
        let data = vec![7i32, -9];
        let b = c.buffer_from_host_buffer(&data, &[2], None).unwrap();
        let lit = b.to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data);
        assert!(lit.to_vec::<f32>().is_err(), "dtype mismatch must error");
    }

    #[test]
    fn shape_mismatch_is_rejected() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.buffer_from_host_buffer(&[1.0f32], &[2], None).is_err());
    }

    #[test]
    fn compile_reports_stub_backend() {
        let c = PjRtClient::cpu().unwrap();
        let comp = XlaComputation { source: "x".into() };
        let err = c.compile(&comp).unwrap_err();
        assert!(format!("{err}").contains("stub"));
    }
}
