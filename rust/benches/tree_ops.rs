//! Microbenchmarks of the L3 hot-path CPU primitives: EGT growth, mask
//! building, the pruning DP, Sequoia construction, sampling kernels.
//! These are the components the §5 scheduler must overlap with device
//! work, so their absolute costs matter (EXPERIMENTS.md §Perf).

use yggdrasil::objective::{LatencyCurve, LatencyModel};
use yggdrasil::pruning::{prune_for_objective, SubtreeDp};
use yggdrasil::sampling::{softmax_inplace, top_k, XorShiftRng};
use yggdrasil::tree::{grow_step, Frontier, MaskBuilder, TokenTree, TreeShape};
use yggdrasil::util::benchkit::{black_box, Bench};

fn grown_tree(depth: usize, width: usize, branch: usize) -> TokenTree {
    let mut rng = XorShiftRng::new(7);
    let mut tree = TokenTree::new(0);
    let mut frontier = Frontier::new(depth);
    let cands = |rng: &mut XorShiftRng| {
        let mut v: Vec<(u32, f32)> = (0..branch)
            .map(|_| (rng.next_u64() as u32 % 1024, rng.next_f32()))
            .collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v
    };
    frontier.push_candidates(&tree, 0, cands(&mut rng));
    for _ in 0..depth {
        let ids = grow_step(&mut tree, &mut frontier, width);
        for id in ids {
            let c = cands(&mut rng);
            frontier.push_candidates(&tree, id, c);
        }
    }
    tree
}

fn main() {
    let mut b = Bench::from_env();

    b.run("egt_grow d8 w8 (full tree build)", || grown_tree(8, 8, 8).len());

    let tree = grown_tree(8, 8, 8);
    let values: Vec<f64> = (0..tree.len()).map(|i| tree.path_prob(i) as f64).collect();
    b.run("pruning_dp solve n=65 k=64", || {
        SubtreeDp::solve(black_box(&tree), black_box(&values), 64).kmax()
    });

    let lat = LatencyModel {
        drafter: LatencyCurve::new(&[(1, 1e-3), (8, 1.2e-3), (64, 2e-3)]),
        verifier: LatencyCurve::new(&[(1, 5e-3), (16, 6e-3), (64, 1.5e-2)]),
        cpu_overhead: 2e-4,
    };
    b.run("prune_for_objective (DP + width sweep)", || {
        prune_for_objective(black_box(&tree), &lat, &[8; 8], 64).1
    });

    let mut mb = MaskBuilder::new(320);
    for s in 0..100u32 {
        mb.commit_slot(s);
    }
    let nodes: Vec<usize> = (0..tree.len()).collect();
    let slot_of: Vec<Option<u32>> = (0..tree.len()).map(|i| Some(150 + i as u32)).collect();
    b.run("mask_build 65 rows x 320 slots", || {
        mb.build(black_box(&tree), black_box(&nodes), &slot_of, 65).len()
    });

    b.run("sequoia_construction budget=63", || {
        TreeShape::sequoia(&[0.62, 0.12, 0.05, 0.03, 0.02, 0.01, 0.01, 0.01], 63).len()
    });

    let mut rng = XorShiftRng::new(3);
    let logits: Vec<f32> = (0..1024).map(|_| rng.next_f32() * 10.0).collect();
    b.run("softmax_1024", || {
        let mut l = logits.clone();
        softmax_inplace(&mut l, 1.0);
        l[0]
    });
    b.run("top_k_8_of_1024", || top_k(black_box(&logits), 8).len());

    b.save_csv(std::path::Path::new("results/bench_tree_ops.csv")).unwrap();
}
