//! Microbenchmarks of the L3 hot-path CPU primitives: EGT growth, mask
//! building, the pruning DP, Sequoia construction, sampling kernels.
//! These are the components the §5 scheduler must overlap with device
//! work, so their absolute costs matter (EXPERIMENTS.md §Perf).
//!
//! The maskpath sweep (mask build/pack + acceptance walk, boolean vs
//! bit-packed, 1–8 sessions × depth 2–6) first asserts the bit-packed
//! path is bit-exact against the f32 reference — CI runs this bench in
//! smoke mode (`YGG_BENCH_QUICK=1`) and a parity mismatch panics the
//! run — then emits `results/BENCH_maskpath.json` with the measured
//! speedups.

use yggdrasil::objective::{LatencyCurve, LatencyModel};
use yggdrasil::pruning::{prune_for_objective, SubtreeDp};
use yggdrasil::scheduler::alloc::{allocate_verify_budget, SessionDemand};
use yggdrasil::sampling::{softmax_inplace, top_k, XorShiftRng};
use yggdrasil::tree::{
    grow_step, pack_block_diagonal, pack_block_diagonal_bits, BitMask, Frontier, MaskBuilder,
    RoundArena, TokenTree, TreeShape,
};
use yggdrasil::util::benchkit::{black_box, Bench};
use yggdrasil::util::json::Json;

fn grown_tree_seeded(depth: usize, width: usize, branch: usize, seed: u64) -> TokenTree {
    let mut rng = XorShiftRng::new(seed);
    let mut tree = TokenTree::new(0);
    let mut frontier = Frontier::new(depth);
    let cands = |rng: &mut XorShiftRng| {
        let mut v: Vec<(u32, f32)> = (0..branch)
            .map(|_| (rng.next_u64() as u32 % 1024, rng.next_f32()))
            .collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v
    };
    frontier.push_candidates(&tree, 0, cands(&mut rng));
    for _ in 0..depth {
        let ids = grow_step(&mut tree, &mut frontier, width);
        for id in ids {
            let c = cands(&mut rng);
            frontier.push_candidates(&tree, id, c);
        }
    }
    tree
}

fn grown_tree(depth: usize, width: usize, branch: usize) -> TokenTree {
    grown_tree_seeded(depth, width, branch, 7)
}

/// One batched-round mask workload: `sessions` trees over disjoint slot
/// regions of a shared 640-slot cache, each session with a 16-slot
/// committed prefix (the shapes `step_batch` packs per round).
struct MaskSetup {
    trees: Vec<TokenTree>,
    builders: Vec<MaskBuilder>,
    node_lists: Vec<Vec<usize>>,
    slot_ofs: Vec<Vec<Option<u32>>>,
    keeps: Vec<Vec<usize>>,
    total_rows: usize,
}

const CAPACITY: usize = 640;

fn mask_setup(sessions: usize, depth: usize) -> MaskSetup {
    let mut s = MaskSetup {
        trees: Vec::new(),
        builders: Vec::new(),
        node_lists: Vec::new(),
        slot_ofs: Vec::new(),
        keeps: Vec::new(),
        total_rows: 0,
    };
    for i in 0..sessions {
        let tree = grown_tree_seeded(depth, 4, 4, 7 + i as u64);
        let base = (i * 70) as u32;
        let mut mb = MaskBuilder::new(CAPACITY);
        for p in 0..16u32 {
            mb.commit_slot(base + p);
        }
        let nodes: Vec<usize> = (0..tree.len()).collect();
        let slot_of: Vec<Option<u32>> =
            (0..tree.len()).map(|j| Some(base + 16 + j as u32)).collect();
        // A non-trivial pruned set (root always kept) so the walks filter.
        let keep: Vec<usize> = (0..tree.len()).filter(|&j| j == 0 || j % 3 != 2).collect();
        s.total_rows += tree.len();
        s.trees.push(tree);
        s.builders.push(mb);
        s.node_lists.push(nodes);
        s.slot_ofs.push(slot_of);
        s.keeps.push(keep);
    }
    s
}

/// The pre-arena acceptance-walk shape: a `keep.position` scan per row
/// lookup and fresh `kids`/`kid_tokens` Vecs per visited node. Descends
/// to the largest-token in-keep child (a deterministic surrogate for the
/// acceptance rule) and folds the visited rows into a checksum.
fn walk_linear(tree: &TokenTree, keep: &[usize]) -> u64 {
    let row_of = |node: usize| keep.iter().position(|&k| k == node).unwrap();
    let mut acc = 0u64;
    let mut cur = 0usize;
    loop {
        acc += row_of(cur) as u64;
        let kids: Vec<usize> =
            tree.children(cur).iter().copied().filter(|c| keep.contains(c)).collect();
        let kid_tokens: Vec<u32> = kids.iter().map(|&k| tree.token(k)).collect();
        let Some((i, _)) = kid_tokens.iter().enumerate().max_by_key(|&(_, &t)| t) else {
            break;
        };
        acc += kid_tokens[i] as u64;
        cur = kids[i];
    }
    acc
}

/// The arena walk of `complete_verify`: O(1) row lookups through the
/// node→row table and reused kid/token stacks. Must compute exactly what
/// [`walk_linear`] computes (parity-asserted before the timed runs).
fn walk_arena(tree: &TokenTree, keep: &[usize], arena: &mut RoundArena) -> u64 {
    arena.row_of.clear();
    arena.row_of.resize(tree.len(), -1);
    for (r, &node) in keep.iter().enumerate() {
        arena.row_of[node] = r as i32;
    }
    arena.walk_path.clear();
    arena.walk_path.push(0);
    let mut acc = 0u64;
    let mut cur = 0usize;
    loop {
        acc += arena.row_of[cur] as u64;
        arena.walk_kids.clear();
        arena.walk_tokens.clear();
        for &c in tree.children(cur) {
            if arena.row_of[c] >= 0 {
                arena.walk_kids.push(c);
                arena.walk_tokens.push(tree.token(c));
            }
        }
        let Some((i, _)) = arena.walk_tokens.iter().enumerate().max_by_key(|&(_, &t)| t)
        else {
            break;
        };
        acc += arena.walk_tokens[i] as u64;
        cur = arena.walk_kids[i];
        arena.walk_path.push(cur);
    }
    acc
}

/// Panics unless the bit-packed build/pack/walk agree bit-exactly with
/// the boolean/f32 reference on this workload.
fn assert_parity(s: &mut MaskSetup, label: &str) {
    let mut arena = RoundArena::new();
    let mut bit_blocks: Vec<BitMask> = Vec::new();
    let mut f32_blocks: Vec<Vec<f32>> = Vec::new();
    for i in 0..s.trees.len() {
        let mb = &mut s.builders[i];
        let dense = mb
            .build(&s.trees[i], &s.node_lists[i], &s.slot_ofs[i], s.trees[i].len())
            .to_vec();
        let bits =
            mb.build_bits(&s.trees[i], &s.node_lists[i], &s.slot_ofs[i], s.trees[i].len());
        assert_eq!(bits.to_f32(), dense, "mask build parity broke at {label} session {i}");
        bit_blocks.push(bits.clone());
        f32_blocks.push(dense);
        assert_eq!(
            walk_linear(&s.trees[i], &s.keeps[i]),
            walk_arena(&s.trees[i], &s.keeps[i], &mut arena),
            "acceptance-walk parity broke at {label} session {i}",
        );
    }
    let f32_refs: Vec<&[f32]> = f32_blocks.iter().map(|v| v.as_slice()).collect();
    let dense_packed = pack_block_diagonal(&f32_refs, CAPACITY, s.total_rows);
    let bit_refs: Vec<&BitMask> = bit_blocks.iter().collect();
    let mut packed = BitMask::new(CAPACITY);
    pack_block_diagonal_bits(&bit_refs, CAPACITY, s.total_rows, &mut packed);
    assert_eq!(packed.to_f32(), dense_packed, "block-diagonal pack parity broke at {label}");
}

fn mean_of(b: &Bench, name: &str) -> f64 {
    b.results.iter().find(|r| r.name == name).map(|r| r.mean_s).expect("case ran")
}

fn main() {
    let mut b = Bench::from_env();

    b.run("egt_grow d8 w8 (full tree build)", || grown_tree(8, 8, 8).len());

    let tree = grown_tree(8, 8, 8);
    let values: Vec<f64> = (0..tree.len()).map(|i| tree.path_prob(i) as f64).collect();
    b.run("pruning_dp solve n=65 k=64", || {
        SubtreeDp::solve(black_box(&tree), black_box(&values), 64).kmax()
    });

    let lat = LatencyModel {
        drafter: LatencyCurve::new(&[(1, 1e-3), (8, 1.2e-3), (64, 2e-3)]),
        verifier: LatencyCurve::new(&[(1, 5e-3), (16, 6e-3), (64, 1.5e-2)]),
        cpu_overhead: 2e-4,
    };
    b.run("prune_for_objective (DP + width sweep)", || {
        prune_for_objective(black_box(&tree), &lat, &[8; 8], 64).1
    });

    let mut mb = MaskBuilder::new(320);
    for s in 0..100u32 {
        mb.commit_slot(s);
    }
    let nodes: Vec<usize> = (0..tree.len()).collect();
    let slot_of: Vec<Option<u32>> = (0..tree.len()).map(|i| Some(150 + i as u32)).collect();
    b.run("mask_build 65 rows x 320 slots", || {
        mb.build(black_box(&tree), black_box(&nodes), &slot_of, 65).len()
    });

    b.run("sequoia_construction budget=63", || {
        TreeShape::sequoia(&[0.62, 0.12, 0.05, 0.03, 0.02, 0.01, 0.01, 0.01], 63).len()
    });

    let mut rng = XorShiftRng::new(3);
    let logits: Vec<f32> = (0..1024).map(|_| rng.next_f32() * 10.0).collect();
    b.run("softmax_1024", || {
        let mut l = logits.clone();
        softmax_inplace(&mut l, 1.0);
        l[0]
    });
    b.run("top_k_8_of_1024", || top_k(black_box(&logits), 8).len());

    // ---------------- maskpath sweep (boolean vs bit-packed) ----------------
    for &sessions in &[1usize, 2, 4, 8] {
        for &depth in &[2usize, 4, 6] {
            let mut s = mask_setup(sessions, depth);
            assert_parity(&mut s, &format!("s{sessions} d{depth}"));
            let total_rows = s.total_rows;

            b.run(&format!("mask_build+pack bool s{sessions} d{depth}"), || {
                let blocks: Vec<Vec<f32>> = s
                    .builders
                    .iter_mut()
                    .enumerate()
                    .map(|(i, mb)| {
                        mb.build(
                            &s.trees[i],
                            &s.node_lists[i],
                            &s.slot_ofs[i],
                            s.trees[i].len(),
                        )
                        .to_vec()
                    })
                    .collect();
                let refs: Vec<&[f32]> = blocks.iter().map(|v| v.as_slice()).collect();
                pack_block_diagonal(&refs, CAPACITY, total_rows).len()
            });

            let mut packed = BitMask::new(CAPACITY);
            b.run(&format!("mask_build+pack bits s{sessions} d{depth}"), || {
                let blocks: Vec<&BitMask> = s
                    .builders
                    .iter_mut()
                    .enumerate()
                    .map(|(i, mb)| {
                        &*mb.build_bits(
                            &s.trees[i],
                            &s.node_lists[i],
                            &s.slot_ofs[i],
                            s.trees[i].len(),
                        )
                    })
                    .collect();
                pack_block_diagonal_bits(&blocks, CAPACITY, total_rows, &mut packed);
                packed.words().len()
            });
        }
    }

    // The call-boundary expansion the engine pays once per packed call.
    {
        let mut s = mask_setup(8, 6);
        let total_rows = s.total_rows;
        let mut packed = BitMask::new(CAPACITY);
        {
            let blocks: Vec<&BitMask> = s
                .builders
                .iter_mut()
                .enumerate()
                .map(|(i, mb)| {
                    &*mb.build_bits(
                        &s.trees[i],
                        &s.node_lists[i],
                        &s.slot_ofs[i],
                        s.trees[i].len(),
                    )
                })
                .collect();
            pack_block_diagonal_bits(&blocks, CAPACITY, total_rows, &mut packed);
        }
        let mut arena = RoundArena::new();
        let mut dense = arena.take_f32();
        b.run("bit_expand_to_f32 s8 d6", || {
            packed.expand_into(&mut dense);
            dense.len()
        });
        arena.put_f32(dense);

        b.run("accept_walk linear s8 d6", || {
            let mut acc = 0u64;
            for (t, keep) in s.trees.iter().zip(&s.keeps) {
                acc += walk_linear(t, keep);
            }
            acc
        });
        b.run("accept_walk arena s8 d6", || {
            let mut acc = 0u64;
            for (t, keep) in s.trees.iter().zip(&s.keeps) {
                acc += walk_arena(t, keep, &mut arena);
            }
            acc
        });
    }

    // ---------------- round allocator cost (DESIGN.md §15) ----------------
    // One global allocation per batched round has to stay noise against
    // the ~1 ms round floor of the serving mock: < 5% (50 µs) even at
    // 16 packed sessions with curve pricing on.
    {
        let mut rng = XorShiftRng::new(11);
        let demands: Vec<SessionDemand> = (0..16)
            .map(|_| SessionDemand {
                q: 0.05 + 0.9 * rng.next_f64(),
                envelope: 64,
                headroom: 512,
                latency_class: rng.next_f32() < 0.5,
            })
            .collect();
        let curve = LatencyCurve::new(&[(1, 5e-3), (16, 6e-3), (64, 1.5e-2)]);
        b.run("round_alloc 16 sessions budget=128", || {
            allocate_verify_budget(black_box(&demands), 128, 1024, Some(&curve))
                .iter()
                .sum::<usize>()
        });
        let mean = mean_of(&b, "round_alloc 16 sessions budget=128");
        assert!(
            mean < 50e-6,
            "round allocation took {:.1} us at 16 sessions (> 5% of a 1 ms mock round)",
            mean * 1e6
        );
    }

    let speedup = mean_of(&b, "mask_build+pack bool s8 d6")
        / mean_of(&b, "mask_build+pack bits s8 d6");
    let walk_speedup =
        mean_of(&b, "accept_walk linear s8 d6") / mean_of(&b, "accept_walk arena s8 d6");
    println!("maskpath: bit-packed build+pack speedup s8 d6 = {speedup:.1}x");
    println!("maskpath: arena acceptance-walk speedup s8 d6 = {walk_speedup:.1}x");

    let cases: Vec<Json> = b
        .results
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", Json::Str(r.name.clone())),
                ("iters", Json::Num(r.iters as f64)),
                ("mean_s", Json::Num(r.mean_s)),
                ("median_s", Json::Num(r.median_s)),
                ("p99_s", Json::Num(r.p99_s)),
                ("min_s", Json::Num(r.min_s)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("suite", Json::Str("maskpath".to_string())),
        // Reaching this point means every parity assert above passed.
        ("parity_ok", Json::Bool(true)),
        ("speedup_bits_s8_d6", Json::Num(speedup)),
        ("walk_speedup_s8_d6", Json::Num(walk_speedup)),
        ("cases", Json::Arr(cases)),
    ]);
    doc.save(std::path::Path::new("results/BENCH_maskpath.json")).unwrap();

    b.save_csv(std::path::Path::new("results/bench_tree_ops.csv")).unwrap();
}
