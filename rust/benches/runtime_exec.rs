//! Device-path benchmarks over the real artifacts: forward latency per
//! width/model, host-staging overhead, eager-vs-resident weights, and the
//! submission round-trip cost of the device actor. Skips silently when
//! artifacts are absent.

use yggdrasil::runtime::{ExecMode, Runtime};
use yggdrasil::util::benchkit::Bench;

fn main() {
    let dir = std::path::Path::new("artifacts");
    if !(dir.join("manifest.json").exists() && dir.join("dft-xs.weights.bin").exists() && dir.join("tgt-lg.weights.bin").exists()) {
        eprintln!("artifacts not built; skipping runtime benches");
        return;
    }
    let rt = Runtime::load(dir, &["dft-xs", "tgt-sm"]).unwrap();
    let mut b = Bench::from_env();
    // Warm all used widths first (compile outside the timed region).
    rt.precompile("dft-xs", &[1, 8, 64]).unwrap();
    rt.precompile("tgt-sm", &[1, 8, 64]).unwrap();

    for model in ["dft-xs", "tgt-sm"] {
        for w in [1usize, 8, 64] {
            let spec = rt.spec(model).unwrap().clone();
            let cache = rt.new_cache(model).unwrap();
            let mut mask = vec![0f32; w * spec.cache_capacity];
            for r in 0..w {
                mask[r * spec.cache_capacity + r] = 1.0;
            }
            let req = yggdrasil::runtime::ForwardRequest {
                model: model.into(),
                width: w,
                cache,
                tokens: vec![1; w],
                positions: (0..w as i32).collect(),
                slots: (0..w as i32).collect(),
                mask,
                mode: ExecMode::Resident,
            };
            b.run(&format!("forward {model} w={w} (resident)"), || {
                rt.forward(req.clone()).unwrap().exec_seconds
            });
            let mut req2 = req.clone();
            req2.mode = ExecMode::WeightsByValue;
            b.run(&format!("forward {model} w={w} (eager/by-value)"), || {
                rt.forward(req2.clone()).unwrap().exec_seconds
            });
            rt.drop_cache(cache);
        }
    }
    b.save_csv(std::path::Path::new("results/bench_runtime.csv")).unwrap();
}
