//! End-to-end engine benchmarks (one per paper-table engine): TPOT over a
//! fixed prompt on the real artifacts, plus a multi-client serving sweep
//! (throughput vs per-request latency as concurrency grows) over the
//! continuous-serving scheduler. `YGG_BENCH_QUICK=1` shortens runs.

use yggdrasil::baselines::build_engine;
use yggdrasil::config::EngineConfig;
use yggdrasil::corpus::PromptSet;
use yggdrasil::engine::{profiling, Engine as _, SpecDecoder};
use yggdrasil::runtime::Runtime;
use yggdrasil::server::{client_wave, ServeOpts, Server};
use yggdrasil::util::benchkit::Bench;

fn main() {
    let dir = std::path::Path::new("artifacts");
    if !(dir.join("manifest.json").exists() && dir.join("dft-xs.weights.bin").exists() && dir.join("tgt-lg.weights.bin").exists()) {
        eprintln!("artifacts not built; skipping engine benches");
        return;
    }
    let quick = std::env::var("YGG_BENCH_QUICK").is_ok();
    let max_new = if quick { 16 } else { 32 };
    let rt = Runtime::load(dir, &["dft-xs", "tgt-sm"]).unwrap();
    let lat =
        profiling::load_or_profile(&rt, "dft-xs", "tgt-sm", Some(&dir.join("profile.json")), 3)
            .unwrap();
    let prompts = PromptSet::load(dir, "c4s").unwrap();
    let prompt = prompts.prompts[0].clone();

    let mut b = Bench::from_env();
    // Model-call-bound: one sample per measurement window is enough.
    b.target_time = std::time::Duration::from_millis(if quick { 1 } else { 100 });
    b.warmup = std::time::Duration::from_millis(1);

    for name in ["vanilla", "seqspec", "specinfer", "sequoia", "vllmspec", "yggdrasil"] {
        let mut e = build_engine(&rt, name, ("dft-xs", "tgt-sm"), &lat).unwrap();
        let _ = e.generate(&prompt, 8).unwrap(); // warm compile
        b.run(&format!("generate[{name}] {max_new} tokens"), || {
            e.generate(&prompt, max_new).unwrap().tokens.len()
        });
    }
    b.save_csv(std::path::Path::new("results/bench_engines.csv")).unwrap();

    serving_sweep(&rt, &lat, &prompts, quick);
}

/// Multi-client throughput-vs-latency sweep: one continuous-serving
/// server, waves of 1..=8 concurrent clients, reporting aggregate tok/s
/// and mean end-to-end / first-token latency per wave.
fn serving_sweep(
    rt: &Runtime,
    lat: &yggdrasil::objective::LatencyModel,
    prompts: &PromptSet,
    quick: bool,
) {
    let max_new = if quick { 12 } else { 24 };
    let mut cfg = EngineConfig::default();
    cfg.drafter = "dft-xs".into();
    cfg.target = "tgt-sm".into();
    cfg.use_depth_predictor = false;
    let engine = SpecDecoder::new(rt, cfg, lat.clone(), None);
    let srv = Server::spawn(
        "127.0.0.1:0",
        Box::new(engine),
        ServeOpts { max_queue: 64, max_sessions: 4, ..ServeOpts::default() },
    )
    .unwrap();

    let sweep: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4, 8] };
    let mut csv = String::from("clients,tok_per_s,e2e_ms_mean,ttft_ms_mean,queue_ms_mean\n");
    println!("\nserving sweep (max_sessions=4, {max_new} tokens/request)");
    for &clients in sweep {
        let w = client_wave(srv.addr, clients, &prompts.prompts, max_new).unwrap();
        let row = format!(
            "{clients},{:.1},{:.1},{:.1},{:.1}",
            w.tok_per_s, w.e2e_ms_mean, w.ttft_ms_mean, w.queue_ms_mean
        );
        println!("  {row}");
        csv.push_str(&row);
        csv.push('\n');
    }
    std::fs::create_dir_all("results").unwrap();
    std::fs::write("results/bench_serving.csv", csv).unwrap();
}
