//! End-to-end engine benchmarks (one per paper-table engine): TPOT over a
//! fixed prompt on the real artifacts. `YGG_BENCH_QUICK=1` shortens runs.

use yggdrasil::baselines::build_engine;
use yggdrasil::corpus::PromptSet;
use yggdrasil::engine::profiling;
use yggdrasil::runtime::Runtime;
use yggdrasil::util::benchkit::Bench;

fn main() {
    let dir = std::path::Path::new("artifacts");
    if !(dir.join("manifest.json").exists() && dir.join("dft-xs.weights.bin").exists() && dir.join("tgt-lg.weights.bin").exists()) {
        eprintln!("artifacts not built; skipping engine benches");
        return;
    }
    let quick = std::env::var("YGG_BENCH_QUICK").is_ok();
    let max_new = if quick { 16 } else { 32 };
    let rt = Runtime::load(dir, &["dft-xs", "tgt-sm"]).unwrap();
    let lat =
        profiling::load_or_profile(&rt, "dft-xs", "tgt-sm", Some(&dir.join("profile.json")), 3)
            .unwrap();
    let prompts = PromptSet::load(dir, "c4s").unwrap();
    let prompt = prompts.prompts[0].clone();

    let mut b = Bench::from_env();
    // Model-call-bound: one sample per measurement window is enough.
    b.target_time = std::time::Duration::from_millis(if quick { 1 } else { 100 });
    b.warmup = std::time::Duration::from_millis(1);

    for name in ["vanilla", "seqspec", "specinfer", "sequoia", "vllmspec", "yggdrasil"] {
        let mut e = build_engine(&rt, name, ("dft-xs", "tgt-sm"), &lat).unwrap();
        let _ = e.generate(&prompt, 8).unwrap(); // warm compile
        b.run(&format!("generate[{name}] {max_new} tokens"), || {
            e.generate(&prompt, max_new).unwrap().tokens.len()
        });
    }
    b.save_csv(std::path::Path::new("results/bench_engines.csv")).unwrap();
}
