//! Benchmarks of the stage-plan estimator and the profile-guided search —
//! these run on the per-iteration critical path when the engine re-plans.

use yggdrasil::scheduler::{plan_latency, search_best_plan, Plan, StageDurations};
use yggdrasil::util::benchkit::{black_box, Bench};

fn main() {
    let mut b = Bench::from_env();
    let d = StageDurations {
        head_draft: 1.0e-3,
        tree_draft: 4.0e-3,
        cpu_build: 0.5e-3,
        cpu_mask: 0.1e-3,
        verify: 6.0e-3,
        tail_draft: 1.2e-3,
        cpu_walk: 0.5e-3,
        accept: 0.3e-3,
        bookkeep: 0.7e-3,
        tail_hit_rate: 0.6,
    };
    b.run("plan_latency (one plan)", || plan_latency(black_box(&d), Plan::SEQUENTIAL));
    b.run("search_best_plan (exhaustive)", || search_best_plan(black_box(&d)).1);

    // Sensitivity sweep used by the §5.2 offline search (all grid points).
    b.run("plan_search_grid 16x16", || {
        let mut acc = 0.0;
        for i in 0..16 {
            for j in 0..16 {
                let mut dd = d;
                dd.accept = 1e-4 * (i + 1) as f64;
                dd.tail_hit_rate = j as f64 / 16.0;
                acc += search_best_plan(&dd).1;
            }
        }
        acc
    });
    b.save_csv(std::path::Path::new("results/bench_scheduler.csv")).unwrap();
}
