//! Server integration tests.
//!
//! Scheduler-behaviour tests (interleaving, cancellation, admission
//! control, queueing) run against `MockStepEngine` — a step-driven mock
//! with simulated per-step latency and KV capacity — so they exercise the
//! continuous-serving loop on any machine, no artifacts needed. The
//! real-engine tests at the bottom drive a `SpecDecoder` over the AOT
//! artifacts and skip cleanly when those are absent.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::Path;
use std::time::{Duration, Instant};

use yggdrasil::config::EngineConfig;
use yggdrasil::engine::{profiling, SpecDecoder, StepEngine};
use yggdrasil::runtime::Runtime;
use yggdrasil::server::{Client, MockStepEngine, RoutingPolicy, ServeOpts, Server};
use yggdrasil::util::json::Json;

fn opts(max_sessions: usize, stream: bool) -> ServeOpts {
    ServeOpts { max_queue: 32, max_sessions, stream, ..ServeOpts::default() }
}

/// Sends one request on a raw socket and reads events until `done`,
/// returning (first-stream-event instant, done instant, token count).
fn timed_request(
    addr: std::net::SocketAddr,
    id: u64,
    prompt: &[u32],
    max_new: usize,
) -> (Instant, Instant, usize) {
    let sock = TcpStream::connect(addr).unwrap();
    let mut w = sock.try_clone().unwrap();
    let mut r = BufReader::new(sock);
    let prompt_json: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    writeln!(
        w,
        r#"{{"id": {id}, "prompt": [{}], "max_new": {max_new}}}"#,
        prompt_json.join(",")
    )
    .unwrap();
    let mut first_stream: Option<Instant> = None;
    let mut tokens = 0usize;
    loop {
        let mut line = String::new();
        assert!(r.read_line(&mut line).unwrap() > 0, "server closed connection");
        let j = Json::parse(&line).unwrap();
        match j.str("event").unwrap() {
            "tokens" => {
                first_stream.get_or_insert_with(Instant::now);
                tokens += j.arr("tokens").unwrap().len();
            }
            "done" => {
                let done = Instant::now();
                tokens = j.arr("tokens").unwrap().len();
                return (first_stream.expect("no stream events before done"), done, tokens);
            }
            other => panic!("unexpected event '{other}': {line}"),
        }
    }
}

#[test]
fn two_concurrent_clients_interleave_streams() {
    // 10 ms per step, 2 tokens per step → each request takes ≥ 80 ms of
    // device time; under round-robin stepping both clients must see their
    // first stream event long before either sees `done`.
    let srv =
        Server::spawn("127.0.0.1:0", Box::new(MockStepEngine::new(10, 2, 10_000)), opts(4, true))
            .unwrap();
    let addr = srv.addr;
    let handles: Vec<_> = (0..2)
        .map(|i| {
            std::thread::spawn(move || timed_request(addr, i, &[1, 2, 3], 16))
        })
        .collect();
    let results: Vec<(Instant, Instant, usize)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    for (_, _, tokens) in &results {
        assert_eq!(*tokens, 16);
    }
    // True interleaving, not FCFS: each client's first tokens arrive
    // before the *other* client's completion.
    assert!(
        results[0].0 < results[1].1,
        "client 0 saw no stream output before client 1 finished (FCFS behaviour)"
    );
    assert!(
        results[1].0 < results[0].1,
        "client 1 saw no stream output before client 0 finished (FCFS behaviour)"
    );
    assert_eq!(srv.stats.requests.load(std::sync::atomic::Ordering::Relaxed), 2);
}

#[test]
fn one_connection_multiplexes_interleaved_requests() {
    let srv =
        Server::spawn("127.0.0.1:0", Box::new(MockStepEngine::new(5, 2, 10_000)), opts(4, true))
            .unwrap();
    let sock = TcpStream::connect(srv.addr).unwrap();
    let mut w = sock.try_clone().unwrap();
    let mut r = BufReader::new(sock);
    writeln!(w, r#"{{"id": 1, "prompt": [1], "max_new": 8}}"#).unwrap();
    writeln!(w, r#"{{"id": 2, "prompt": [2], "max_new": 8}}"#).unwrap();
    let mut lines = Vec::new();
    let mut done = 0;
    while done < 2 {
        let mut line = String::new();
        assert!(r.read_line(&mut line).unwrap() > 0);
        if line.contains("\"done\"") {
            done += 1;
        }
        lines.push(line);
    }
    // Both ids must stream tokens before the first done of either.
    let first_done = lines.iter().position(|l| {
        Json::parse(l).unwrap().str("event").unwrap() == "done"
    });
    let first_done = first_done.unwrap();
    for id in [1u64, 2u64] {
        let streamed_before_done = lines[..first_done].iter().any(|l| {
            let j = Json::parse(l).unwrap();
            j.get("id").and_then(|v| v.as_u64()) == Some(id)
                && j.str("event").unwrap() == "tokens"
        });
        assert!(streamed_before_done, "request {id} did not stream before the first done");
    }
}

#[test]
fn disconnect_mid_stream_frees_session_and_kv_slots() {
    let engine = MockStepEngine::new(5, 1, 10_000);
    let slots = engine.slots_in_use.clone();
    let srv = Server::spawn("127.0.0.1:0", Box::new(engine), opts(4, true)).unwrap();
    {
        let sock = TcpStream::connect(srv.addr).unwrap();
        let mut w = sock.try_clone().unwrap();
        let mut r = BufReader::new(sock);
        writeln!(w, r#"{{"id": 9, "prompt": [1, 2, 3, 4], "max_new": 5000}}"#).unwrap();
        // Wait until the session is demonstrably generating…
        let mut line = String::new();
        assert!(r.read_line(&mut line).unwrap() > 0);
        assert!(line.contains("tokens"), "expected a stream event, got: {line}");
        assert!(slots.load(std::sync::atomic::Ordering::Relaxed) > 0);
        // …then vanish mid-generation.
    }
    // The scheduler must notice the disconnect, drop the session, and
    // free every simulated KV slot.
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let freed = slots.load(std::sync::atomic::Ordering::Relaxed) == 0;
        let cancelled = srv.stats.cancelled.load(std::sync::atomic::Ordering::Relaxed) == 1;
        let idle = srv.stats.active_sessions.load(std::sync::atomic::Ordering::Relaxed) == 0;
        let kv_gauge = srv.stats.kv_slots_in_use.load(std::sync::atomic::Ordering::Relaxed) == 0;
        if freed && cancelled && idle && kv_gauge {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "cancellation leak: slots={}, cancelled={}, active={}, kv_gauge={}",
            slots.load(std::sync::atomic::Ordering::Relaxed),
            srv.stats.cancelled.load(std::sync::atomic::Ordering::Relaxed),
            srv.stats.active_sessions.load(std::sync::atomic::Ordering::Relaxed),
            srv.stats.kv_slots_in_use.load(std::sync::atomic::Ordering::Relaxed),
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    // No tokens were ever counted as completed for the cancelled request.
    assert_eq!(srv.stats.tokens.load(std::sync::atomic::Ordering::Relaxed), 0);
}

#[test]
fn admission_control_rejects_prompts_beyond_kv_headroom() {
    // Capacity of 4 simulated KV slots cannot host a 10-token prompt.
    let srv =
        Server::spawn("127.0.0.1:0", Box::new(MockStepEngine::new(1, 1, 4)), opts(4, true))
            .unwrap();
    let mut c = Client::connect(&srv.addr).unwrap();
    let err = c.generate(1, &(0..10).collect::<Vec<u32>>(), 8).unwrap_err();
    assert!(
        format!("{err:#}").contains("insufficient KV headroom"),
        "unexpected error: {err:#}"
    );
    assert_eq!(srv.stats.rejected.load(std::sync::atomic::Ordering::Relaxed), 1);
    // A prompt that fits still works.
    let r = c.generate(2, &[1], 2).unwrap();
    assert_eq!(r.tokens.len(), 2);
}

#[test]
fn saturated_server_queues_and_reports_queueing_delay() {
    // One session slot: the second request must wait for the first.
    let srv =
        Server::spawn("127.0.0.1:0", Box::new(MockStepEngine::new(5, 1, 10_000)), opts(1, true))
            .unwrap();
    let addr = srv.addr;
    let long = std::thread::spawn(move || {
        let mut c = Client::connect(&addr).unwrap();
        c.generate(1, &[1], 40).unwrap() // ≥ 200 ms of device time
    });
    std::thread::sleep(Duration::from_millis(40)); // let request 1 admit
    let mut c = Client::connect(&srv.addr).unwrap();
    let r2 = c.generate(2, &[2], 2).unwrap();
    let r1 = long.join().unwrap();
    assert_eq!(r1.tokens.len(), 40);
    assert_eq!(r2.tokens.len(), 2);
    assert!(
        r2.queue_ms > 10.0,
        "expected a measurable queueing delay behind the saturated slot, got {} ms",
        r2.queue_ms
    );
    assert!(r1.queue_ms < r2.queue_ms, "first request should barely queue");
}

#[test]
fn two_sessions_in_one_batch_both_stream_correct_tokens() {
    // Batched rounds: both sessions ride one simulated device call per
    // round. Seed-offset mock tokens make any cross-session mixing of
    // the split batch outputs visible immediately.
    let srv =
        Server::spawn("127.0.0.1:0", Box::new(MockStepEngine::new(5, 2, 10_000)), opts(4, true))
            .unwrap();
    let addr = srv.addr;
    let handles: Vec<_> = [1000u32, 2000u32]
        .into_iter()
        .enumerate()
        .map(|(i, seed)| {
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                (seed, c.generate(i as u64, &[seed], 9).unwrap())
            })
        })
        .collect();
    for h in handles {
        let (seed, r) = h.join().unwrap();
        let expect: Vec<u32> = (0..9).map(|x| seed + x).collect();
        assert_eq!(r.tokens, expect, "session {seed} streamed foreign/mixed tokens");
        assert!(r.stream_events >= 2, "expected streamed chunks");
    }
    assert_eq!(srv.stats.requests.load(std::sync::atomic::Ordering::Relaxed), 2);
}

#[test]
fn batched_rounds_outscale_round_robin_throughput() {
    // 20 ms of simulated device time per call. Round-robin charges it
    // per session per round; batched charges it once per round. At 4
    // concurrent clients the batched server must clear the ≥1.5× bar
    // (ideal is ~4×, so the margin absorbs scheduler jitter).
    let prompts: Vec<Vec<u32>> = (0..4).map(|i| vec![1000 * (i + 1) as u32]).collect();
    let mut tput = Vec::new();
    for batched in [false, true] {
        let srv = Server::spawn(
            "127.0.0.1:0",
            Box::new(MockStepEngine::new(20, 2, 10_000)),
            ServeOpts { max_queue: 32, max_sessions: 4, batched, ..ServeOpts::default() },
        )
        .unwrap();
        let w = yggdrasil::server::client_wave(srv.addr, 4, &prompts, 16).unwrap();
        assert_eq!(w.tokens, 64, "all four clients complete");
        tput.push(w.tok_per_s);
    }
    let speedup = tput[1] / tput[0];
    assert!(
        speedup >= 1.5,
        "batched serving {:.1} tok/s vs round-robin {:.1} tok/s = {speedup:.2}x (< 1.5x)",
        tput[1],
        tput[0]
    );
}

#[test]
fn batched_draft_rounds_outscale_verify_only_batching() {
    // The acceptance scenario for stage-aligned batched drafting
    // (DESIGN.md §11): drafting-bound sessions — 15 ms of drafter time
    // per session per round against 5 ms of (already shared) verify.
    // Verify-only batching pays the drafter serially, 5 + 4×15 = 65 ms
    // per round at 4 clients; packing the draft stage makes the round
    // 5 + 15 = 20 ms. Ideal speedup 3.25×; the ≥1.3× bar absorbs
    // scheduler jitter.
    let prompts: Vec<Vec<u32>> = (0..4).map(|i| vec![1000 * (i + 1) as u32]).collect();
    let mut tput = Vec::new();
    for batch_draft in [false, true] {
        let engine = MockStepEngine::new(5, 2, 10_000).with_draft_stage(15, batch_draft);
        let srv = Server::spawn(
            "127.0.0.1:0",
            Box::new(engine),
            ServeOpts { max_queue: 32, max_sessions: 4, ..ServeOpts::default() },
        )
        .unwrap();
        let w = yggdrasil::server::client_wave(srv.addr, 4, &prompts, 16).unwrap();
        assert_eq!(w.tokens, 64, "all four clients complete");
        tput.push(w.tok_per_s);
    }
    let speedup = tput[1] / tput[0];
    assert!(
        speedup >= 1.3,
        "batched-draft serving {:.1} tok/s vs verify-only batching {:.1} tok/s \
         = {speedup:.2}x (< 1.3x) at 4 drafting-bound clients",
        tput[1],
        tput[0]
    );
}

// ---------------------------------------------------------------------------
// Paged shared cache: admission, preemption/resume, confinement (mock).
// ---------------------------------------------------------------------------

/// Fires `(prompt, max_new)` jobs as concurrent clients and returns each
/// client's `(prompt, max_new, result)`; panics on any request-level
/// error (the paged scheduler must preempt/resume, never fail a request
/// it admitted).
fn concurrent_wave(
    addr: std::net::SocketAddr,
    jobs: Vec<(Vec<u32>, usize)>,
) -> Vec<(Vec<u32>, usize, yggdrasil::server::ClientResult)> {
    let handles: Vec<_> = jobs
        .into_iter()
        .enumerate()
        .map(|(i, (p, n))| {
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                // A fresh request racing a momentarily-dry pool gets an
                // immediate headroom rejection; back off and retry like a
                // real client (bounded, so genuine failures still fail).
                for attempt in 0..100 {
                    match c.generate(i as u64, &p, n) {
                        Ok(r) => return (p, n, r),
                        Err(e)
                            if attempt < 99
                                && format!("{e:#}").contains("insufficient KV headroom") =>
                        {
                            std::thread::sleep(Duration::from_millis(10));
                        }
                        Err(e) => panic!("client {i} failed: {e:#}"),
                    }
                }
                unreachable!()
            })
        })
        .collect();
    handles.into_iter().map(|h| h.join().unwrap()).collect()
}

/// The mock counter stream a request must produce, regardless of how
/// many times it was preempted and resumed: `seed + (len - 1) + i`.
fn expected_tokens(prompt: &[u32], max_new: usize) -> Vec<u32> {
    (0..max_new)
        .map(|i| prompt[0].wrapping_add((prompt.len() - 1 + i) as u32))
        .collect()
}

#[test]
fn paged_pool_outadmits_equal_partition_on_heterogeneous_prompts() {
    // The acceptance scenario: one 65-slot cache (64 usable + trash), a
    // mix of one long and five short prompts. Equal partition must size
    // regions for the long request (64 / 32 = 2 sessions); the paged
    // pool (8 × 8-slot blocks) lets block counts follow the actual
    // footprint, so it must sustain ≥ 2× the concurrently admitted
    // sessions — with zero mask-confinement violations and every client
    // still receiving its exact token stream.
    let long: Vec<u32> = (0..20).map(|x| 9000 + x as u32).collect();
    let jobs: Vec<(Vec<u32>, usize)> = std::iter::once((long, 8))
        .chain((0..5).map(|i| (vec![1000 * (i + 1) as u32, 7], 6)))
        .collect();

    let mut peaks = Vec::new();
    for paged in [false, true] {
        let engine = if paged {
            MockStepEngine::with_paged_pool(4, 1, 65, 8).unwrap()
        } else {
            // Two regions of 32: the long request (20 prompt + 8 gen +
            // transient draft slots) only fits a 32-slot region.
            MockStepEngine::with_equal_partition(4, 1, 65, 2).unwrap()
        };
        let violations = engine.violations.clone();
        let srv = Server::spawn(
            "127.0.0.1:0",
            Box::new(engine),
            ServeOpts {
                max_queue: 32,
                max_sessions: if paged { 8 } else { 2 },
                max_resumes: 32,
                ..ServeOpts::default()
            },
        )
        .unwrap();
        // Every client completes with its exact stream in both modes —
        // preemption/resume must be invisible in the token sequence.
        for (p, n, r) in concurrent_wave(srv.addr, jobs.clone()) {
            assert_eq!(
                r.tokens,
                expected_tokens(&p, n),
                "paged={paged}: wrong stream for prompt seed {}",
                p[0]
            );
        }
        assert_eq!(
            violations.load(std::sync::atomic::Ordering::Relaxed),
            0,
            "paged={paged}: mask rows escaped their owned slots"
        );
        peaks.push(srv.stats.peak_sessions.load(std::sync::atomic::Ordering::Relaxed));
    }
    let (equal_peak, paged_peak) = (peaks[0], peaks[1]);
    assert!(
        equal_peak <= 2,
        "equal partition cannot admit more sessions than regions, got {equal_peak}"
    );
    assert!(
        paged_peak >= 2 * equal_peak.max(1),
        "paged admitted {paged_peak} concurrent sessions, equal {equal_peak} — \
         expected ≥ 2× at the same total capacity"
    );
}

#[test]
fn pool_exhaustion_preempts_then_resumes_with_exact_streams() {
    // Two 16-token-footprint requests (6 prompt + 8 gen + 2 transient
    // draft slots) over a 2-block (16-slot) pool: both cannot run at
    // once, so one must be preempted — blocks released, job requeued —
    // and later resumed to completion. The client sees nothing but its
    // exact stream; the stats see the preempt/resume counters and the
    // resume-delay series.
    let engine = MockStepEngine::with_paged_pool(5, 1, 17, 8).unwrap();
    let srv = Server::spawn(
        "127.0.0.1:0",
        Box::new(engine),
        ServeOpts { max_queue: 32, max_sessions: 4, max_resumes: 32, ..ServeOpts::default() },
    )
    .unwrap();
    let jobs: Vec<(Vec<u32>, usize)> = vec![
        ((100..106).collect(), 8),
        ((200..206).collect(), 8),
    ];
    for (p, n, r) in concurrent_wave(srv.addr, jobs) {
        assert_eq!(r.tokens, expected_tokens(&p, n), "stream broke across preemption");
    }
    let preempts = srv.stats.preemptions.load(std::sync::atomic::Ordering::Relaxed);
    let resumes = srv.stats.resumes.load(std::sync::atomic::Ordering::Relaxed);
    assert!(preempts >= 1, "pool pressure must preempt at least one session");
    assert!(resumes >= 1 && resumes <= preempts, "every resume follows a preemption");
    // The re-prefill resume path is covered by the stats recorder.
    let rec = srv.stats.recorder.lock().unwrap();
    assert!(
        rec.count("server.resume_delay_s") as u64 == resumes,
        "one resume-delay sample per resume"
    );
    drop(rec);
    // Terminal state: every block returned to the pool.
    let snap = srv.stats.snapshot();
    assert_eq!(snap.preemptions, preempts);
    assert_eq!(snap.resumes, resumes);
}

#[test]
fn lone_oversized_paged_request_fails_cleanly_instead_of_livelocking() {
    // A request whose footprint exceeds the whole pool can never be
    // served: the scheduler must surface a terminal error (exhaustion
    // with nothing to preempt), not spin preempt/resume forever.
    let engine = MockStepEngine::with_paged_pool(1, 1, 17, 8).unwrap();
    let srv = Server::spawn(
        "127.0.0.1:0",
        Box::new(engine),
        ServeOpts { max_queue: 8, max_sessions: 2, max_resumes: 4, ..ServeOpts::default() },
    )
    .unwrap();
    let mut c = Client::connect(&srv.addr).unwrap();
    // Prompt fits (admission sees 16 slots ≥ 11), but 10 + 32 generated
    // can never fit 16 slots, and no other session holds blocks.
    let err = c.generate(1, &(0..10).collect::<Vec<u32>>(), 32).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("exhausted") || msg.contains("resume"),
        "expected a terminal exhaustion error, got: {msg}"
    );
    // A well-sized request on the same server still completes.
    let r = c.generate(2, &[5, 6], 4).unwrap();
    assert_eq!(r.tokens, expected_tokens(&[5, 6], 4));
}

#[test]
fn preempted_request_that_cannot_resume_gets_the_typed_terminal_error() {
    // Two requests whose 6 + 40 footprints each exceed the whole 2-block
    // pool. Under pressure the degradation ladder escalates to its top
    // rung and preempts both; with `max_resumes: 1` neither can ever be
    // re-admitted (an empty server still cannot host prompt + remaining
    // budget). The terminal rejection must reach each client as the
    // typed "preempted request cannot resume" error — not a raw engine
    // failure that hides the preemption history. 10 ms steps leave a wide
    // admission window so both requests are live before the pool drains.
    let engine = MockStepEngine::with_paged_pool(10, 1, 17, 8).unwrap();
    let srv = Server::spawn(
        "127.0.0.1:0",
        Box::new(engine),
        ServeOpts { max_queue: 8, max_sessions: 4, max_resumes: 1, ..ServeOpts::default() },
    )
    .unwrap();
    let addr = srv.addr;
    let handles: Vec<_> = (0..2u64)
        .map(|i| {
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                let prompt: Vec<u32> = (0..6).map(|j| 100 * (i as u32 + 1) + j).collect();
                c.generate(i, &prompt, 40).unwrap_err()
            })
        })
        .collect();
    for h in handles {
        let msg = format!("{:#}", h.join().unwrap());
        assert!(
            msg.contains("preempted request cannot resume"),
            "expected the typed terminal-resume error, got: {msg}"
        );
    }
    let preempts = srv.stats.preemptions.load(std::sync::atomic::Ordering::Relaxed);
    assert!(preempts >= 2, "both oversized sessions must be preempted once, got {preempts}");
    let degraded = srv.stats.degraded_rounds.load(std::sync::atomic::Ordering::Relaxed);
    assert!(degraded >= 4, "the ladder walks every rung before preempting, got {degraded}");
}

// ---------------------------------------------------------------------------
// Cross-request prefix cache (DESIGN.md §12, mock).
// ---------------------------------------------------------------------------

#[test]
fn prefix_cache_halves_prefill_and_improves_warm_ttft() {
    // The acceptance scenario: a shared system prompt 5 blocks long
    // (40 tokens ≥ 4× the 8-slot block size) in front of distinct
    // per-client suffixes, across 1 cold + 4 warm clients. With the
    // prefix cache on, the warm clients attach the cached system blocks
    // and prefill only their suffixes: total prefilled tokens must drop
    // ≥ 2× vs cache-off, warm TTFT must improve (each uncached prefill
    // token costs 1 ms of simulated device time), ownership violations
    // must stay zero, and every stream must stay bit-exact.
    let block = 8usize;
    let sys: Vec<u32> = (0..40u32).map(|i| 5000 + i).collect();
    let mk_prompt = |c: u32| -> Vec<u32> {
        let mut p = sys.clone();
        p.extend([100 * (c + 1), 100 * (c + 1) + 1, 100 * (c + 1) + 2]);
        p
    };
    let mut prefilled = Vec::new();
    let mut warm_ttft = Vec::new();
    for prefix_on in [false, true] {
        let mut engine = MockStepEngine::with_paged_pool(2, 2, 24 * block + 1, block)
            .unwrap()
            .with_prefill_cost(1000);
        if prefix_on {
            engine = engine.with_prefix_cache();
        }
        let counter = engine.prefilled_tokens.clone();
        let violations = engine.violations.clone();
        let srv = Server::spawn(
            "127.0.0.1:0",
            Box::new(engine),
            ServeOpts { max_queue: 32, max_sessions: 8, ..ServeOpts::default() },
        )
        .unwrap();
        // Cold client seeds the trie (its task's teardown donates the
        // fully-committed system-prompt blocks).
        let mut c0 = Client::connect(&srv.addr).unwrap();
        let p0 = mk_prompt(0);
        let r0 = c0.generate(0, &p0, 8).unwrap();
        assert_eq!(r0.tokens, expected_tokens(&p0, 8));
        // Warm wave: four concurrent clients share the system prompt.
        let addr = srv.addr;
        let handles: Vec<_> = (1..5u32)
            .map(|c| {
                let p = mk_prompt(c);
                std::thread::spawn(move || {
                    let mut cl = Client::connect(&addr).unwrap();
                    let r = cl.generate(c as u64, &p, 8).unwrap();
                    (p, r)
                })
            })
            .collect();
        let mut ttft = 0.0f64;
        for h in handles {
            let (p, r) = h.join().unwrap();
            assert_eq!(
                r.tokens,
                expected_tokens(&p, 8),
                "prefix_on={prefix_on}: reused prefix corrupted the stream"
            );
            ttft += r.ttft_ms;
        }
        assert_eq!(
            violations.load(std::sync::atomic::Ordering::Relaxed),
            0,
            "prefix_on={prefix_on}: mask rows escaped their owned/shared blocks"
        );
        if prefix_on {
            let snap = srv.stats.snapshot();
            assert!(
                snap.prefix_hits >= 4,
                "all four warm admissions should hit, got {}",
                snap.prefix_hits
            );
            assert!(snap.prefix_tokens_reused >= 4 * 40, "warm waves reuse the system prompt");
            assert!(snap.prefix_cached_blocks >= 5, "system blocks stay cached");
        }
        prefilled.push(counter.load(std::sync::atomic::Ordering::Relaxed));
        warm_ttft.push(ttft / 4.0);
    }
    let (off, on) = (prefilled[0], prefilled[1]);
    assert!(
        off >= 2 * on,
        "prefix cache must cut total prefilled tokens ≥ 2×: {on} on vs {off} off"
    );
    assert!(
        warm_ttft[1] < warm_ttft[0],
        "warm TTFT must improve with the prefix cache: {:.1} ms on vs {:.1} ms off",
        warm_ttft[1],
        warm_ttft[0]
    );
}

#[test]
fn prefix_cache_on_off_streams_are_bit_identical() {
    // Satellite parity check: the same prompt served twice with the
    // prefix cache on (the second run attaches the first run's blocks —
    // the stats prove it hit) must produce exactly the stream a
    // cache-off server produces.
    let prompt: Vec<u32> = (0..20u32).map(|i| 7000 + i).collect();
    let mut streams: Vec<Vec<u32>> = Vec::new();
    for prefix_on in [false, true] {
        let mut engine = MockStepEngine::with_paged_pool(2, 2, 129, 8).unwrap();
        if prefix_on {
            engine = engine.with_prefix_cache();
        }
        let srv = Server::spawn("127.0.0.1:0", Box::new(engine), opts(4, true)).unwrap();
        let mut c = Client::connect(&srv.addr).unwrap();
        let r1 = c.generate(1, &prompt, 10).unwrap();
        let r2 = c.generate(2, &prompt, 10).unwrap();
        assert_eq!(r1.tokens, r2.tokens, "prefix_on={prefix_on}: repeat run diverged");
        if prefix_on {
            let snap = srv.stats.snapshot();
            assert!(snap.prefix_hits >= 1, "second identical prompt must hit the cache");
        }
        streams.push(r1.tokens);
    }
    assert_eq!(streams[0], streams[1], "cache on vs off streams diverged");
}

#[test]
fn paged_stats_expose_block_occupancy_gauges() {
    let engine = MockStepEngine::with_paged_pool(5, 1, 65, 8).unwrap();
    let srv = Server::spawn("127.0.0.1:0", Box::new(engine), opts(4, true)).unwrap();
    let mut c = Client::connect(&srv.addr).unwrap();
    let r = c.generate(1, &[10, 11, 12], 4).unwrap();
    assert_eq!(r.tokens.len(), 4);
    let s = c.stats().unwrap();
    assert_eq!(s.u64("blocks_total").unwrap(), 8, "8 blocks of 8 over 64 usable slots");
    assert!(s.u64("peak_sessions").unwrap() >= 1);
    assert_eq!(s.u64("preemptions").unwrap(), 0, "no pressure, no preemption");
}

// ---------------------------------------------------------------------------
// Real-artifact tests (skip without `artifacts/`).
// ---------------------------------------------------------------------------

fn spawn_real_server(max_sessions: usize, stream: bool) -> Option<Server> {
    let dir = Path::new("artifacts");
    if !(dir.join("manifest.json").exists() && dir.join("dft-xs.weights.bin").exists() && dir.join("tgt-lg.weights.bin").exists()) {
        return None;
    }
    let rt = Runtime::load(dir, &["dft-xs", "tgt-sm"]).unwrap();
    let lat =
        profiling::load_or_profile(&rt, "dft-xs", "tgt-sm", Some(&dir.join("profile.json")), 2)
            .unwrap();
    let mut cfg = EngineConfig::default();
    cfg.use_depth_predictor = false;
    let engine = SpecDecoder::new(&rt, cfg, lat, None);
    Some(Server::spawn("127.0.0.1:0", Box::new(engine), opts(max_sessions, stream)).unwrap())
}

/// Spawns a batched shared-cache real-engine server (equal or paged
/// layout; verify-only or stage-aligned batched drafting) and asserts
/// that concurrent batched sessions reproduce the solo greedy output
/// bit-exactly: block-diagonal masks mean a rider in the same device
/// batch cannot perturb another session's logits — whether its slots
/// come from a contiguous region or a set of owned blocks, and whether
/// only the verify or also every draft level rides a packed call.
fn assert_batched_matches_solo(paged: bool, batch_draft: bool) {
    let dir = Path::new("artifacts");
    if !(dir.join("manifest.json").exists()
        && dir.join("dft-xs.weights.bin").exists()
        && dir.join("tgt-lg.weights.bin").exists())
    {
        return;
    }
    let rt = Runtime::load(dir, &["dft-xs", "tgt-sm"]).unwrap();
    let lat =
        profiling::load_or_profile(&rt, "dft-xs", "tgt-sm", Some(&dir.join("profile.json")), 2)
            .unwrap();
    // Envelope sized to the per-session quota of the 4-way shared cache.
    let mut cfg = EngineConfig::default();
    cfg.use_depth_predictor = false;
    cfg.max_depth = 3;
    cfg.max_width = 4;
    cfg.max_verify = 16;
    cfg.batch.enabled = true;
    cfg.batch.max_sessions = 4;
    cfg.batch.paged = paged;
    cfg.batch.batch_draft = batch_draft;
    cfg.batch.block_size = 16;
    let engine = SpecDecoder::new(&rt, cfg, lat, None);
    let srv = Server::spawn(
        "127.0.0.1:0",
        Box::new(engine),
        ServeOpts { max_queue: 32, max_sessions: 4, ..ServeOpts::default() },
    )
    .unwrap();
    let prompt: Vec<u32> = (0..12).map(|i| (i * 29 + 11) % 1024).collect();
    // Solo pass fixes the greedy-deterministic expectation…
    let mut c = Client::connect(&srv.addr).unwrap();
    let solo = c.generate(1, &prompt, 12).unwrap();
    assert_eq!(solo.tokens.len(), 12);
    // …then two concurrent sessions batched into shared verifier calls
    // must reproduce it exactly.
    let addr = srv.addr;
    let handles: Vec<_> = (0..2)
        .map(|i| {
            let p = prompt.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                c.generate(10 + i, &p, 12).unwrap()
            })
        })
        .collect();
    for h in handles {
        let r = h.join().unwrap();
        assert_eq!(
            r.tokens, solo.tokens,
            "batched (paged={paged}, batch_draft={batch_draft}) session diverged \
             from solo run"
        );
    }
}

#[test]
fn batched_real_engine_sessions_stay_isolated_and_deterministic() {
    // Equal-partition layout, verify-only batching: the PR 2 invariant,
    // still selectable via --no-batch-draft.
    assert_batched_matches_solo(false, false);
}

#[test]
fn paged_real_engine_sessions_stay_isolated_and_deterministic() {
    // Paged block-granular layout: same bit-exactness over owned blocks.
    assert_batched_matches_solo(true, false);
}

#[test]
fn batched_draft_real_engine_matches_solo_equal_partition() {
    // Stage-aligned batched drafting over equal-partition leases: the
    // packed head + level calls must be bit-exact with the solo run.
    assert_batched_matches_solo(false, true);
}

#[test]
fn batched_draft_real_engine_matches_solo_paged() {
    // Stage-aligned batched drafting over the paged pool — packed draft
    // rows confined to owned blocks, bit-exact greedy output.
    assert_batched_matches_solo(true, true);
}

#[test]
fn prefix_cache_real_engine_parity_with_cache_off() {
    // Artifact-gated twin of the mock parity test: the same prompt served
    // twice on a paged prefix-cache server (second run attaches the first
    // run's donated blocks) must match a cache-off server bit-exactly —
    // reused K/V is the same K/V.
    let dir = Path::new("artifacts");
    if !(dir.join("manifest.json").exists()
        && dir.join("dft-xs.weights.bin").exists()
        && dir.join("tgt-lg.weights.bin").exists())
    {
        return;
    }
    let rt = Runtime::load(dir, &["dft-xs", "tgt-sm"]).unwrap();
    let lat =
        profiling::load_or_profile(&rt, "dft-xs", "tgt-sm", Some(&dir.join("profile.json")), 2)
            .unwrap();
    let prompt: Vec<u32> = (0..24).map(|i| (i * 37 + 5) % 1024).collect();
    let mut streams: Vec<Vec<u32>> = Vec::new();
    for prefix_on in [false, true] {
        let mut cfg = EngineConfig::default();
        cfg.use_depth_predictor = false;
        cfg.max_depth = 3;
        cfg.max_width = 4;
        cfg.max_verify = 16;
        cfg.batch.enabled = true;
        cfg.batch.max_sessions = 4;
        cfg.batch.paged = true;
        cfg.batch.block_size = 8;
        cfg.batch.prefix_cache = prefix_on;
        let engine = SpecDecoder::new(&rt, cfg, lat.clone(), None);
        let srv = Server::spawn(
            "127.0.0.1:0",
            Box::new(engine),
            ServeOpts { max_queue: 32, max_sessions: 4, ..ServeOpts::default() },
        )
        .unwrap();
        let mut c = Client::connect(&srv.addr).unwrap();
        let r1 = c.generate(1, &prompt, 12).unwrap();
        let r2 = c.generate(2, &prompt, 12).unwrap();
        assert_eq!(
            r1.tokens, r2.tokens,
            "prefix_on={prefix_on}: repeat of the same prompt diverged"
        );
        if prefix_on {
            let snap = srv.stats.snapshot();
            assert!(
                snap.prefix_hits >= 1,
                "second identical prompt must hit the prefix cache"
            );
            assert!(snap.prefix_tokens_reused >= 8, "at least one block reused");
        }
        streams.push(r1.tokens);
    }
    assert_eq!(streams[0], streams[1], "prefix cache changed the decoded stream");
}

#[test]
fn real_engine_serves_streaming_requests() {
    let Some(srv) = spawn_real_server(4, true) else { return };
    let prompt: Vec<u32> = (0..12).map(|i| (i * 31 + 3) % 1024).collect();
    let mut c = Client::connect(&srv.addr).unwrap();
    let r1 = c.generate(1, &prompt, 16).unwrap();
    assert_eq!(r1.tokens.len(), 16);
    assert!(r1.stream_events >= 1, "expected streamed chunks");
    assert!(r1.aal >= 1.0);
    // Same prompt again: greedy decoding is deterministic.
    let r2 = c.generate(2, &prompt, 16).unwrap();
    assert_eq!(r1.tokens, r2.tokens);
}

#[test]
fn concurrent_real_clients_all_complete() {
    let Some(srv) = spawn_real_server(4, false) else { return };
    let addr = srv.addr;
    let handles: Vec<_> = (0..3)
        .map(|i| {
            std::thread::spawn(move || {
                let prompt: Vec<u32> = (0..10).map(|j| ((j + i) * 17 + 5) % 1024).collect();
                let mut c = Client::connect(&addr).unwrap();
                c.generate(i as u64, &prompt, 12).unwrap()
            })
        })
        .collect();
    for h in handles {
        let r = h.join().unwrap();
        assert_eq!(r.tokens.len(), 12);
    }
    assert_eq!(srv.stats.requests.load(std::sync::atomic::Ordering::Relaxed), 3);
}

#[test]
fn concurrent_real_clients_interleave_streams() {
    let Some(srv) = spawn_real_server(4, true) else { return };
    let addr = srv.addr;
    let handles: Vec<_> = (0..2)
        .map(|i| {
            std::thread::spawn(move || {
                let prompt: Vec<u32> = (0..10).map(|j| ((j + i) * 13 + 7) % 1024).collect();
                timed_request(addr, i as u64, &prompt, 24)
            })
        })
        .collect();
    let results: Vec<(Instant, Instant, usize)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    for (_, _, tokens) in &results {
        assert_eq!(*tokens, 24);
    }
    assert!(results[0].0 < results[1].1, "no interleaving: client 0 starved");
    assert!(results[1].0 < results[0].1, "no interleaving: client 1 starved");
}

// ---------------------------------------------------------------------------
// Multi-worker fleet (DESIGN.md §16): sharded serving behind one listener.
// ---------------------------------------------------------------------------

fn mock_fleet(workers: usize, step_delay_ms: u64) -> Vec<Box<dyn StepEngine + Send>> {
    (0..workers)
        .map(|_| Box::new(MockStepEngine::new(step_delay_ms, 1, 10_000)) as Box<dyn StepEngine + Send>)
        .collect()
}

#[test]
fn four_worker_fleet_serves_a_wave_with_exact_streams_and_merged_stats() {
    let opts = ServeOpts {
        max_queue: 32,
        max_sessions: 2,
        stream: true,
        routing: RoutingPolicy::RoundRobin,
        ..ServeOpts::default()
    };
    let srv = Server::spawn_fleet("127.0.0.1:0", mock_fleet(4, 2), opts).unwrap();
    let jobs: Vec<(Vec<u32>, usize)> =
        (0..12).map(|i| ((0..8).map(|t| 100 * (i + 1) + t).collect(), 10)).collect();
    for (p, n, r) in concurrent_wave(srv.addr, jobs) {
        assert_eq!(r.tokens, expected_tokens(&p, n), "sharded stream diverged");
    }
    // A stats request over the wire reports the *fleet* merge, not one
    // worker's slice.
    let mut c = Client::connect(&srv.addr).unwrap();
    let j = c.stats().unwrap();
    assert_eq!(j.u64("requests").unwrap(), 12);
    assert_eq!(j.u64("workers").unwrap(), 4);
    assert_eq!(j.arr("worker_stats").unwrap().len(), 4);
}

#[test]
fn one_worker_fleet_streams_bit_exact_with_single_engine_spawn() {
    // `--workers 1` must be indistinguishable from the pre-fleet path:
    // same wave, same streams, on both spawn entry points.
    let wave: Vec<(Vec<u32>, usize)> =
        (0..4).map(|i| ((0..10).map(|t| 7 * i + t + 3).collect(), 12)).collect();
    let legacy = Server::spawn(
        "127.0.0.1:0",
        Box::new(MockStepEngine::new(2, 1, 10_000)),
        opts(4, true),
    )
    .unwrap();
    let fleet = Server::spawn_fleet("127.0.0.1:0", mock_fleet(1, 2), opts(4, true)).unwrap();
    let run = |srv: &Server| -> Vec<Vec<u32>> {
        concurrent_wave(srv.addr, wave.clone()).into_iter().map(|(_, _, r)| r.tokens).collect()
    };
    let a = run(&legacy);
    let b = run(&fleet);
    assert_eq!(a, b, "one-worker fleet diverged from the single-engine server");
    for ((p, n), tokens) in wave.iter().zip(&a) {
        assert_eq!(tokens, &expected_tokens(p, *n));
    }
}

#[test]
fn work_stealing_rebalances_queued_jobs_with_bit_exact_streams() {
    // Every request shares one prompt, so affinity pins the whole wave to
    // whichever worker saw the prefix first; with a single session slot
    // per worker the rest sit *queued* (never prefilled) until the
    // rebalancer steals them across. Stolen streams must be bit-exact —
    // a steal moves only queue entries, never engine state.
    let opts = ServeOpts {
        max_queue: 64,
        max_sessions: 1,
        stream: true,
        batched: false,
        steal_threshold: 1,
        ..ServeOpts::default()
    };
    let srv = Server::spawn_fleet("127.0.0.1:0", mock_fleet(2, 5), opts).unwrap();
    let prompt: Vec<u32> = (0..20).map(|t| 40 + t).collect();
    let jobs: Vec<_> = (0..8).map(|_| (prompt.clone(), 20)).collect();
    for (p, n, r) in concurrent_wave(srv.addr, jobs) {
        assert_eq!(r.tokens, expected_tokens(&p, n), "stolen stream diverged");
    }
    let steals = srv.router.steals.load(std::sync::atomic::Ordering::Relaxed);
    assert!(steals > 0, "backlogged queue was never rebalanced");
    let snap = srv.router.fleet_snapshot();
    assert_eq!(snap.merged.requests, 8);
    assert_eq!(snap.steals, steals);
}
