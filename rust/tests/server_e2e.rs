//! Server integration tests.
//!
//! Scheduler-behaviour tests (interleaving, cancellation, admission
//! control, queueing) run against `MockStepEngine` — a step-driven mock
//! with simulated per-step latency and KV capacity — so they exercise the
//! continuous-serving loop on any machine, no artifacts needed. The
//! real-engine tests at the bottom drive a `SpecDecoder` over the AOT
//! artifacts and skip cleanly when those are absent.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::Path;
use std::time::{Duration, Instant};

use yggdrasil::config::EngineConfig;
use yggdrasil::engine::{profiling, SpecDecoder};
use yggdrasil::runtime::Runtime;
use yggdrasil::server::{Client, MockStepEngine, ServeOpts, Server};
use yggdrasil::util::json::Json;

fn opts(max_sessions: usize, stream: bool) -> ServeOpts {
    ServeOpts { max_queue: 32, max_sessions, stream, batched: true }
}

/// Sends one request on a raw socket and reads events until `done`,
/// returning (first-stream-event instant, done instant, token count).
fn timed_request(
    addr: std::net::SocketAddr,
    id: u64,
    prompt: &[u32],
    max_new: usize,
) -> (Instant, Instant, usize) {
    let sock = TcpStream::connect(addr).unwrap();
    let mut w = sock.try_clone().unwrap();
    let mut r = BufReader::new(sock);
    let prompt_json: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    writeln!(
        w,
        r#"{{"id": {id}, "prompt": [{}], "max_new": {max_new}}}"#,
        prompt_json.join(",")
    )
    .unwrap();
    let mut first_stream: Option<Instant> = None;
    let mut tokens = 0usize;
    loop {
        let mut line = String::new();
        assert!(r.read_line(&mut line).unwrap() > 0, "server closed connection");
        let j = Json::parse(&line).unwrap();
        match j.str("event").unwrap() {
            "tokens" => {
                first_stream.get_or_insert_with(Instant::now);
                tokens += j.arr("tokens").unwrap().len();
            }
            "done" => {
                let done = Instant::now();
                tokens = j.arr("tokens").unwrap().len();
                return (first_stream.expect("no stream events before done"), done, tokens);
            }
            other => panic!("unexpected event '{other}': {line}"),
        }
    }
}

#[test]
fn two_concurrent_clients_interleave_streams() {
    // 10 ms per step, 2 tokens per step → each request takes ≥ 80 ms of
    // device time; under round-robin stepping both clients must see their
    // first stream event long before either sees `done`.
    let srv =
        Server::spawn("127.0.0.1:0", Box::new(MockStepEngine::new(10, 2, 10_000)), opts(4, true))
            .unwrap();
    let addr = srv.addr;
    let handles: Vec<_> = (0..2)
        .map(|i| {
            std::thread::spawn(move || timed_request(addr, i, &[1, 2, 3], 16))
        })
        .collect();
    let results: Vec<(Instant, Instant, usize)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    for (_, _, tokens) in &results {
        assert_eq!(*tokens, 16);
    }
    // True interleaving, not FCFS: each client's first tokens arrive
    // before the *other* client's completion.
    assert!(
        results[0].0 < results[1].1,
        "client 0 saw no stream output before client 1 finished (FCFS behaviour)"
    );
    assert!(
        results[1].0 < results[0].1,
        "client 1 saw no stream output before client 0 finished (FCFS behaviour)"
    );
    assert_eq!(srv.stats.requests.load(std::sync::atomic::Ordering::Relaxed), 2);
}

#[test]
fn one_connection_multiplexes_interleaved_requests() {
    let srv =
        Server::spawn("127.0.0.1:0", Box::new(MockStepEngine::new(5, 2, 10_000)), opts(4, true))
            .unwrap();
    let sock = TcpStream::connect(srv.addr).unwrap();
    let mut w = sock.try_clone().unwrap();
    let mut r = BufReader::new(sock);
    writeln!(w, r#"{{"id": 1, "prompt": [1], "max_new": 8}}"#).unwrap();
    writeln!(w, r#"{{"id": 2, "prompt": [2], "max_new": 8}}"#).unwrap();
    let mut lines = Vec::new();
    let mut done = 0;
    while done < 2 {
        let mut line = String::new();
        assert!(r.read_line(&mut line).unwrap() > 0);
        if line.contains("\"done\"") {
            done += 1;
        }
        lines.push(line);
    }
    // Both ids must stream tokens before the first done of either.
    let first_done = lines.iter().position(|l| {
        Json::parse(l).unwrap().str("event").unwrap() == "done"
    });
    let first_done = first_done.unwrap();
    for id in [1u64, 2u64] {
        let streamed_before_done = lines[..first_done].iter().any(|l| {
            let j = Json::parse(l).unwrap();
            j.get("id").and_then(|v| v.as_u64()) == Some(id)
                && j.str("event").unwrap() == "tokens"
        });
        assert!(streamed_before_done, "request {id} did not stream before the first done");
    }
}

#[test]
fn disconnect_mid_stream_frees_session_and_kv_slots() {
    let engine = MockStepEngine::new(5, 1, 10_000);
    let slots = engine.slots_in_use.clone();
    let srv = Server::spawn("127.0.0.1:0", Box::new(engine), opts(4, true)).unwrap();
    {
        let sock = TcpStream::connect(srv.addr).unwrap();
        let mut w = sock.try_clone().unwrap();
        let mut r = BufReader::new(sock);
        writeln!(w, r#"{{"id": 9, "prompt": [1, 2, 3, 4], "max_new": 5000}}"#).unwrap();
        // Wait until the session is demonstrably generating…
        let mut line = String::new();
        assert!(r.read_line(&mut line).unwrap() > 0);
        assert!(line.contains("tokens"), "expected a stream event, got: {line}");
        assert!(slots.load(std::sync::atomic::Ordering::Relaxed) > 0);
        // …then vanish mid-generation.
    }
    // The scheduler must notice the disconnect, drop the session, and
    // free every simulated KV slot.
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let freed = slots.load(std::sync::atomic::Ordering::Relaxed) == 0;
        let cancelled = srv.stats.cancelled.load(std::sync::atomic::Ordering::Relaxed) == 1;
        let idle = srv.stats.active_sessions.load(std::sync::atomic::Ordering::Relaxed) == 0;
        let kv_gauge = srv.stats.kv_slots_in_use.load(std::sync::atomic::Ordering::Relaxed) == 0;
        if freed && cancelled && idle && kv_gauge {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "cancellation leak: slots={}, cancelled={}, active={}, kv_gauge={}",
            slots.load(std::sync::atomic::Ordering::Relaxed),
            srv.stats.cancelled.load(std::sync::atomic::Ordering::Relaxed),
            srv.stats.active_sessions.load(std::sync::atomic::Ordering::Relaxed),
            srv.stats.kv_slots_in_use.load(std::sync::atomic::Ordering::Relaxed),
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    // No tokens were ever counted as completed for the cancelled request.
    assert_eq!(srv.stats.tokens.load(std::sync::atomic::Ordering::Relaxed), 0);
}

#[test]
fn admission_control_rejects_prompts_beyond_kv_headroom() {
    // Capacity of 4 simulated KV slots cannot host a 10-token prompt.
    let srv =
        Server::spawn("127.0.0.1:0", Box::new(MockStepEngine::new(1, 1, 4)), opts(4, true))
            .unwrap();
    let mut c = Client::connect(&srv.addr).unwrap();
    let err = c.generate(1, &(0..10).collect::<Vec<u32>>(), 8).unwrap_err();
    assert!(
        format!("{err:#}").contains("insufficient KV headroom"),
        "unexpected error: {err:#}"
    );
    assert_eq!(srv.stats.rejected.load(std::sync::atomic::Ordering::Relaxed), 1);
    // A prompt that fits still works.
    let r = c.generate(2, &[1], 2).unwrap();
    assert_eq!(r.tokens.len(), 2);
}

#[test]
fn saturated_server_queues_and_reports_queueing_delay() {
    // One session slot: the second request must wait for the first.
    let srv =
        Server::spawn("127.0.0.1:0", Box::new(MockStepEngine::new(5, 1, 10_000)), opts(1, true))
            .unwrap();
    let addr = srv.addr;
    let long = std::thread::spawn(move || {
        let mut c = Client::connect(&addr).unwrap();
        c.generate(1, &[1], 40).unwrap() // ≥ 200 ms of device time
    });
    std::thread::sleep(Duration::from_millis(40)); // let request 1 admit
    let mut c = Client::connect(&srv.addr).unwrap();
    let r2 = c.generate(2, &[2], 2).unwrap();
    let r1 = long.join().unwrap();
    assert_eq!(r1.tokens.len(), 40);
    assert_eq!(r2.tokens.len(), 2);
    assert!(
        r2.queue_ms > 10.0,
        "expected a measurable queueing delay behind the saturated slot, got {} ms",
        r2.queue_ms
    );
    assert!(r1.queue_ms < r2.queue_ms, "first request should barely queue");
}

#[test]
fn two_sessions_in_one_batch_both_stream_correct_tokens() {
    // Batched rounds: both sessions ride one simulated device call per
    // round. Seed-offset mock tokens make any cross-session mixing of
    // the split batch outputs visible immediately.
    let srv =
        Server::spawn("127.0.0.1:0", Box::new(MockStepEngine::new(5, 2, 10_000)), opts(4, true))
            .unwrap();
    let addr = srv.addr;
    let handles: Vec<_> = [1000u32, 2000u32]
        .into_iter()
        .enumerate()
        .map(|(i, seed)| {
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                (seed, c.generate(i as u64, &[seed], 9).unwrap())
            })
        })
        .collect();
    for h in handles {
        let (seed, r) = h.join().unwrap();
        let expect: Vec<u32> = (0..9).map(|x| seed + x).collect();
        assert_eq!(r.tokens, expect, "session {seed} streamed foreign/mixed tokens");
        assert!(r.stream_events >= 2, "expected streamed chunks");
    }
    assert_eq!(srv.stats.requests.load(std::sync::atomic::Ordering::Relaxed), 2);
}

#[test]
fn batched_rounds_outscale_round_robin_throughput() {
    // 20 ms of simulated device time per call. Round-robin charges it
    // per session per round; batched charges it once per round. At 4
    // concurrent clients the batched server must clear the ≥1.5× bar
    // (ideal is ~4×, so the margin absorbs scheduler jitter).
    let prompts: Vec<Vec<u32>> = (0..4).map(|i| vec![1000 * (i + 1) as u32]).collect();
    let mut tput = Vec::new();
    for batched in [false, true] {
        let srv = Server::spawn(
            "127.0.0.1:0",
            Box::new(MockStepEngine::new(20, 2, 10_000)),
            ServeOpts { max_queue: 32, max_sessions: 4, stream: true, batched },
        )
        .unwrap();
        let w = yggdrasil::server::client_wave(srv.addr, 4, &prompts, 16).unwrap();
        assert_eq!(w.tokens, 64, "all four clients complete");
        tput.push(w.tok_per_s);
    }
    let speedup = tput[1] / tput[0];
    assert!(
        speedup >= 1.5,
        "batched serving {:.1} tok/s vs round-robin {:.1} tok/s = {speedup:.2}x (< 1.5x)",
        tput[1],
        tput[0]
    );
}

// ---------------------------------------------------------------------------
// Real-artifact tests (skip without `artifacts/`).
// ---------------------------------------------------------------------------

fn spawn_real_server(max_sessions: usize, stream: bool) -> Option<Server> {
    let dir = Path::new("artifacts");
    if !(dir.join("manifest.json").exists() && dir.join("dft-xs.weights.bin").exists() && dir.join("tgt-lg.weights.bin").exists()) {
        return None;
    }
    let rt = Runtime::load(dir, &["dft-xs", "tgt-sm"]).unwrap();
    let lat =
        profiling::load_or_profile(&rt, "dft-xs", "tgt-sm", Some(&dir.join("profile.json")), 2)
            .unwrap();
    let mut cfg = EngineConfig::default();
    cfg.use_depth_predictor = false;
    let engine = SpecDecoder::new(&rt, cfg, lat, None);
    Some(Server::spawn("127.0.0.1:0", Box::new(engine), opts(max_sessions, stream)).unwrap())
}

#[test]
fn batched_real_engine_sessions_stay_isolated_and_deterministic() {
    let dir = Path::new("artifacts");
    if !(dir.join("manifest.json").exists()
        && dir.join("dft-xs.weights.bin").exists()
        && dir.join("tgt-lg.weights.bin").exists())
    {
        return;
    }
    let rt = Runtime::load(dir, &["dft-xs", "tgt-sm"]).unwrap();
    let lat =
        profiling::load_or_profile(&rt, "dft-xs", "tgt-sm", Some(&dir.join("profile.json")), 2)
            .unwrap();
    // Envelope sized to the per-session quota of the 4-way shared cache.
    let mut cfg = EngineConfig::default();
    cfg.use_depth_predictor = false;
    cfg.max_depth = 3;
    cfg.max_width = 4;
    cfg.max_verify = 16;
    cfg.batch.enabled = true;
    cfg.batch.max_sessions = 4;
    let engine = SpecDecoder::new(&rt, cfg, lat, None);
    let srv = Server::spawn(
        "127.0.0.1:0",
        Box::new(engine),
        ServeOpts { max_queue: 32, max_sessions: 4, stream: true, batched: true },
    )
    .unwrap();
    let prompt: Vec<u32> = (0..12).map(|i| (i * 29 + 11) % 1024).collect();
    // Solo pass fixes the greedy-deterministic expectation…
    let mut c = Client::connect(&srv.addr).unwrap();
    let solo = c.generate(1, &prompt, 12).unwrap();
    assert_eq!(solo.tokens.len(), 12);
    // …then two concurrent sessions batched into shared verifier calls
    // must reproduce it exactly: block-diagonal masks mean a rider in
    // the same device batch cannot perturb the other session's logits.
    let addr = srv.addr;
    let handles: Vec<_> = (0..2)
        .map(|i| {
            let p = prompt.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                c.generate(10 + i, &p, 12).unwrap()
            })
        })
        .collect();
    for h in handles {
        let r = h.join().unwrap();
        assert_eq!(r.tokens, solo.tokens, "batched session diverged from solo run");
    }
}

#[test]
fn real_engine_serves_streaming_requests() {
    let Some(srv) = spawn_real_server(4, true) else { return };
    let prompt: Vec<u32> = (0..12).map(|i| (i * 31 + 3) % 1024).collect();
    let mut c = Client::connect(&srv.addr).unwrap();
    let r1 = c.generate(1, &prompt, 16).unwrap();
    assert_eq!(r1.tokens.len(), 16);
    assert!(r1.stream_events >= 1, "expected streamed chunks");
    assert!(r1.aal >= 1.0);
    // Same prompt again: greedy decoding is deterministic.
    let r2 = c.generate(2, &prompt, 16).unwrap();
    assert_eq!(r1.tokens, r2.tokens);
}

#[test]
fn concurrent_real_clients_all_complete() {
    let Some(srv) = spawn_real_server(4, false) else { return };
    let addr = srv.addr;
    let handles: Vec<_> = (0..3)
        .map(|i| {
            std::thread::spawn(move || {
                let prompt: Vec<u32> = (0..10).map(|j| ((j + i) * 17 + 5) % 1024).collect();
                let mut c = Client::connect(&addr).unwrap();
                c.generate(i as u64, &prompt, 12).unwrap()
            })
        })
        .collect();
    for h in handles {
        let r = h.join().unwrap();
        assert_eq!(r.tokens.len(), 12);
    }
    assert_eq!(srv.stats.requests.load(std::sync::atomic::Ordering::Relaxed), 3);
}

#[test]
fn concurrent_real_clients_interleave_streams() {
    let Some(srv) = spawn_real_server(4, true) else { return };
    let addr = srv.addr;
    let handles: Vec<_> = (0..2)
        .map(|i| {
            std::thread::spawn(move || {
                let prompt: Vec<u32> = (0..10).map(|j| ((j + i) * 13 + 7) % 1024).collect();
                timed_request(addr, i as u64, &prompt, 24)
            })
        })
        .collect();
    let results: Vec<(Instant, Instant, usize)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    for (_, _, tokens) in &results {
        assert_eq!(*tokens, 24);
    }
    assert!(results[0].0 < results[1].1, "no interleaving: client 0 starved");
    assert!(results[1].0 < results[0].1, "no interleaving: client 1 starved");
}
