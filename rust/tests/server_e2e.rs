//! Server integration over the real artifacts: spawn the TCP server with a
//! SpecDecoder engine, run concurrent clients, verify streamed tokens match
//! the final answer and that results are deterministic. Skips without
//! artifacts.

use std::path::Path;

use yggdrasil::config::EngineConfig;
use yggdrasil::engine::{profiling, SpecDecoder};
use yggdrasil::runtime::Runtime;
use yggdrasil::server::{Client, Server};

fn spawn_real_server(stream: bool) -> Option<Server> {
    let dir = Path::new("artifacts");
    if !(dir.join("manifest.json").exists() && dir.join("dft-xs.weights.bin").exists() && dir.join("tgt-lg.weights.bin").exists()) {
        return None;
    }
    let rt = Runtime::load(dir, &["dft-xs", "tgt-sm"]).unwrap();
    let lat =
        profiling::load_or_profile(&rt, "dft-xs", "tgt-sm", Some(&dir.join("profile.json")), 2)
            .unwrap();
    let mut cfg = EngineConfig::default();
    cfg.use_depth_predictor = false;
    let engine = SpecDecoder::new(&rt, cfg, lat, None);
    Some(Server::spawn("127.0.0.1:0", Box::new(engine), 16, stream).unwrap())
}

#[test]
fn real_engine_serves_streaming_requests() {
    let Some(srv) = spawn_real_server(true) else { return };
    let prompt: Vec<u32> = (0..12).map(|i| (i * 31 + 3) % 1024).collect();
    let mut c = Client::connect(&srv.addr).unwrap();
    let r1 = c.generate(1, &prompt, 16).unwrap();
    assert_eq!(r1.tokens.len(), 16);
    assert!(r1.stream_events >= 1, "expected streamed chunks");
    assert!(r1.aal >= 1.0);
    // Same prompt again: greedy decoding is deterministic.
    let r2 = c.generate(2, &prompt, 16).unwrap();
    assert_eq!(r1.tokens, r2.tokens);
}

#[test]
fn concurrent_real_clients_all_complete() {
    let Some(srv) = spawn_real_server(false) else { return };
    let addr = srv.addr;
    let handles: Vec<_> = (0..3)
        .map(|i| {
            std::thread::spawn(move || {
                let prompt: Vec<u32> = (0..10).map(|j| ((j + i) * 17 + 5) % 1024).collect();
                let mut c = Client::connect(&addr).unwrap();
                c.generate(i as u64, &prompt, 12).unwrap()
            })
        })
        .collect();
    for h in handles {
        let r = h.join().unwrap();
        assert_eq!(r.tokens.len(), 12);
    }
    assert_eq!(srv.stats.requests.load(std::sync::atomic::Ordering::Relaxed), 3);
}
