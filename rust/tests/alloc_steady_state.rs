//! Steady-state allocation audit of the per-round CPU path (DESIGN.md
//! §13): after warm-up, one mock batched round — per-session word-wise
//! mask build, ownership check, incremental block-diagonal pack, dense
//! expansion at the call boundary, and the arena acceptance walk — must
//! perform **zero** heap allocations. A counting `#[global_allocator]`
//! enforces this; any new per-round `Vec` shows up as a test failure
//! here before it shows up as a latency regression.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use yggdrasil::kvcache::{SlotOwnership, SlotRange};
use yggdrasil::sampling::XorShiftRng;
use yggdrasil::trace::{Name, Tracer};
use yggdrasil::tree::{
    grow_step, owner_words, rows_owned_bits, Frontier, MaskBuilder, RoundArena, TokenTree,
};

static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// Passthrough to the system allocator that counts every `alloc` and
/// `realloc` (frees are irrelevant to the steady-state criterion).
struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

const CAPACITY: usize = 640;
const SESSIONS: usize = 8;
const DEPTH: usize = 6;

fn grown_tree(seed: u64) -> TokenTree {
    let mut rng = XorShiftRng::new(seed);
    let mut tree = TokenTree::new(0);
    let mut frontier = Frontier::new(DEPTH);
    let cands = |rng: &mut XorShiftRng| {
        let mut v: Vec<(u32, f32)> = (0..4)
            .map(|_| (rng.next_u64() as u32 % 1024, rng.next_f32()))
            .collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v
    };
    frontier.push_candidates(&tree, 0, cands(&mut rng));
    for _ in 0..DEPTH {
        let ids = grow_step(&mut tree, &mut frontier, 4);
        for id in ids {
            let c = cands(&mut rng);
            frontier.push_candidates(&tree, id, c);
        }
    }
    tree
}

/// Everything a round reads; built (and allowed to allocate) once.
struct Fixture {
    trees: Vec<TokenTree>,
    builders: Vec<MaskBuilder>,
    node_lists: Vec<Vec<usize>>,
    slot_ofs: Vec<Vec<Option<u32>>>,
    keeps: Vec<Vec<usize>>,
    owners: Vec<Vec<u64>>,
    total_rows: usize,
}

fn fixture() -> Fixture {
    let mut fx = Fixture {
        trees: Vec::new(),
        builders: Vec::new(),
        node_lists: Vec::new(),
        slot_ofs: Vec::new(),
        keeps: Vec::new(),
        owners: Vec::new(),
        total_rows: 0,
    };
    for i in 0..SESSIONS {
        let tree = grown_tree(7 + i as u64);
        let base = (i * 70) as u32;
        let mut mb = MaskBuilder::new(CAPACITY);
        for p in 0..16u32 {
            mb.commit_slot(base + p);
        }
        let nodes: Vec<usize> = (0..tree.len()).collect();
        let slot_of: Vec<Option<u32>> =
            (0..tree.len()).map(|j| Some(base + 16 + j as u32)).collect();
        let keep: Vec<usize> = (0..tree.len()).filter(|&j| j == 0 || j % 3 != 2).collect();
        let owner = SlotOwnership::Range(SlotRange { base, len: 70 });
        let mut words = Vec::new();
        owner_words(&owner, CAPACITY, &mut words);
        fx.total_rows += tree.len();
        fx.trees.push(tree);
        fx.builders.push(mb);
        fx.node_lists.push(nodes);
        fx.slot_ofs.push(slot_of);
        fx.keeps.push(keep);
        fx.owners.push(words);
    }
    fx
}

/// One mock batched round over every borrow the engine's round loop
/// takes from its [`RoundArena`]. Returns a checksum so nothing is
/// optimised away.
///
/// The flight recorder is **on** for the audit (DESIGN.md §17): the
/// round records the same span/instant mix the serving scheduler does —
/// a round span, stage spans, and per-session grant instants — so any
/// allocation the tracer sneaks onto the hot path fails this test too.
fn round(
    fx: &Fixture,
    builders: &mut [MaskBuilder],
    arena: &mut RoundArena,
    tracer: &Tracer,
    round_no: u64,
) -> u64 {
    tracer.set_round(round_no);
    let round_span = tracer.begin(Name::Round, 0);
    // Mask half: word-wise per-session build, ownership word-test,
    // incremental block-diagonal pack, one dense expansion at the end.
    let build_span = tracer.begin(Name::CpuBuild, 0);
    arena.packed.reshape(CAPACITY, fx.total_rows);
    let mut at = 0usize;
    for i in 0..fx.trees.len() {
        let bits = builders[i].build_bits(
            &fx.trees[i],
            &fx.node_lists[i],
            &fx.slot_ofs[i],
            fx.trees[i].len(),
        );
        assert!(rows_owned_bits(bits, &fx.owners[i]));
        arena.packed.copy_rows_from(bits, at);
        at += fx.trees[i].len();
        tracer.instant(Name::AllocGrant, i as u64 + 1, fx.trees[i].len() as i64);
    }
    let mut dense = arena.take_f32();
    arena.packed.expand_into(&mut dense);
    let mut acc = dense.iter().filter(|&&v| v != 0.0).count() as u64;
    arena.put_f32(dense);
    tracer.end(Name::CpuBuild, 0, build_span);

    // Walk half: the arena acceptance walk (node→row table + reused
    // stacks), descending to the largest-token kept child.
    let walk_span = tracer.begin(Name::AcceptWalk, 0);
    for (tree, keep) in fx.trees.iter().zip(&fx.keeps) {
        arena.row_of.clear();
        arena.row_of.resize(tree.len(), -1);
        for (r, &node) in keep.iter().enumerate() {
            arena.row_of[node] = r as i32;
        }
        arena.walk_path.clear();
        arena.walk_path.push(0);
        let mut cur = 0usize;
        loop {
            acc += arena.row_of[cur] as u64;
            arena.walk_kids.clear();
            arena.walk_tokens.clear();
            for &c in tree.children(cur) {
                if arena.row_of[c] >= 0 {
                    arena.walk_kids.push(c);
                    arena.walk_tokens.push(tree.token(c));
                }
            }
            let Some((i, _)) = arena.walk_tokens.iter().enumerate().max_by_key(|&(_, &t)| t)
            else {
                break;
            };
            cur = arena.walk_kids[i];
            arena.walk_path.push(cur);
        }
        acc += arena.walk_path.len() as u64;
    }
    tracer.end(Name::AcceptWalk, 0, walk_span);
    tracer.end(Name::Round, 0, round_span);
    acc
}

#[test]
fn round_loop_has_zero_steady_state_allocations() {
    let mut fx = fixture();
    let mut builders = std::mem::take(&mut fx.builders);
    let mut arena = RoundArena::new();
    // A small ring so the measured rounds also exercise wraparound
    // overwrites; the slots preallocate here, before the audit window.
    let tracer = Tracer::new(0, 256);

    // Warm-up: the first rounds grow the builder scratch, the packed
    // words, the f32 pool entry, and the walk stacks to their final
    // capacities.
    let mut sink = 0u64;
    for r in 0..3 {
        sink += round(&fx, &mut builders, &mut arena, &tracer, r + 1);
    }

    let before = ALLOCS.load(Ordering::Relaxed);
    for r in 0..50 {
        sink += round(&fx, &mut builders, &mut arena, &tracer, r + 4);
    }
    let after = ALLOCS.load(Ordering::Relaxed);

    assert!(sink > 0, "rounds must do observable work");
    assert_eq!(
        after - before,
        0,
        "steady-state rounds must not touch the heap (got {} allocations over 50 rounds \
         with tracing enabled)",
        after - before,
    );
    // The recorder really ran: every round pushed its span edges and
    // per-session grant instants.
    assert_eq!(tracer.pushed(), 53 * (6 + SESSIONS as u64));
}
