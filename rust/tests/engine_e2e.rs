//! End-to-end engine tests over the real artifacts: every engine preset
//! must generate tokens; the speculative engines must agree with vanilla
//! greedy decoding (losslessness at T = 0); Yggdrasil must post a higher
//! AAL than sequence speculation.

use std::path::Path;

use yggdrasil::baselines::{build_engine, VanillaEngine};
use yggdrasil::config::EngineConfig;
use yggdrasil::engine::{profile_latency_model, Engine, SpecDecoder};
use yggdrasil::runtime::Runtime;

fn setup() -> Option<(Runtime, yggdrasil::objective::LatencyModel)> {
    let dir = Path::new("artifacts");
    if !(dir.join("manifest.json").exists() && dir.join("dft-xs.weights.bin").exists() && dir.join("tgt-lg.weights.bin").exists()) {
        return None;
    }
    let rt = Runtime::load(dir, &["dft-xs", "tgt-sm"]).unwrap();
    let lat = profile_latency_model(&rt, "dft-xs", "tgt-sm", 1).unwrap();
    Some((rt, lat))
}

fn prompt() -> Vec<u32> {
    (0..16).map(|i| (i * 37 + 11) % 1024).collect()
}

#[test]
fn greedy_speculation_is_lossless_vs_vanilla() {
    let Some((rt, lat)) = setup() else { return };
    let mut vanilla = VanillaEngine::new(&rt, "tgt-sm", true);
    let reference = vanilla.generate(&prompt(), 24).unwrap();

    for name in ["seqspec", "specinfer", "sequoia", "vllmspec", "yggdrasil"] {
        let mut e = build_engine(&rt, name, ("dft-xs", "tgt-sm"), &lat).unwrap();
        let g = e.generate(&prompt(), 24).unwrap();
        assert_eq!(
            g.tokens, reference.tokens,
            "{name} diverged from greedy decoding (AAL {:.2})",
            g.aal()
        );
        assert!(g.aal() >= 1.0, "{name}: AAL {}", g.aal());
    }
}

#[test]
fn yggdrasil_aal_beats_sequence_baseline() {
    let Some((rt, lat)) = setup() else { return };
    let mut ygg = build_engine(&rt, "yggdrasil", ("dft-xs", "tgt-sm"), &lat).unwrap();
    let mut seq = build_engine(&rt, "vllmspec", ("dft-xs", "tgt-sm"), &lat).unwrap();
    let mut a = 0.0;
    let mut b = 0.0;
    for (i, p) in [prompt(), (0..16).map(|i| (i * 13 + 5) % 1024).collect()].iter().enumerate() {
        let _ = i;
        a += ygg.generate(p, 32).unwrap().aal();
        b += seq.generate(p, 32).unwrap().aal();
    }
    assert!(a >= b * 0.9, "yggdrasil AAL {a:.2} << sequence {b:.2}");
}

#[test]
fn stochastic_generation_runs_and_differs_by_seed() {
    let Some((rt, lat)) = setup() else { return };
    let mk = |seed: u64| {
        let mut cfg = EngineConfig::default();
        cfg.drafter = "dft-xs".into();
        cfg.target = "tgt-sm".into();
        cfg.sampling.temperature = 0.8;
        cfg.sampling.seed = seed;
        SpecDecoder::new(&rt, cfg, lat.clone(), None)
    };
    let a = mk(1).generate(&prompt(), 24).unwrap();
    let b = mk(2).generate(&prompt(), 24).unwrap();
    assert_eq!(a.tokens.len(), 24);
    assert!(a.tokens != b.tokens, "different seeds produced identical samples");
    // Determinism per seed.
    let a2 = mk(1).generate(&prompt(), 24).unwrap();
    assert_eq!(a.tokens, a2.tokens);
}
