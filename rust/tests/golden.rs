//! Golden-numerics integration test: the Rust runtime (PJRT, compiled HLO,
//! buffer-resident weights, threaded KV cache) must reproduce the exact
//! outputs `python/compile/aot.py` recorded from the JAX forward pass.
//! This is the cross-language contract test for the whole AOT bridge.
//!
//! Skipped (cleanly) when `artifacts/` has not been built.

use std::path::Path;

use yggdrasil::runtime::{ExecMode, ForwardRequest, Runtime};

struct Golden {
    tokens: Vec<i32>,
    positions: Vec<i32>,
    slots: Vec<i32>,
    mask: Vec<f32>,
    logits: Vec<f32>,
    hidden: Vec<f32>,
    cache_checksum: f32,
}

fn read_golden(path: &Path, w: usize, c: usize, v: usize, d: usize) -> Golden {
    let bytes = std::fs::read(path).unwrap();
    let mut off = 0usize;
    let mut take_i32 = |n: usize| -> Vec<i32> {
        let out = bytes[off..off + 4 * n]
            .chunks_exact(4)
            .map(|b| i32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        off += 4 * n;
        out
    };
    let tokens = take_i32(w);
    let positions = take_i32(w);
    let slots = take_i32(w);
    let mut take_f32 = |n: usize| -> Vec<f32> {
        let out = bytes[off..off + 4 * n]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        off += 4 * n;
        out
    };
    let mask = take_f32(w * c);
    let logits = take_f32(w * v);
    let hidden = take_f32(w * d);
    let cache_checksum = take_f32(1)[0];
    assert_eq!(off, bytes.len(), "golden file fully consumed");
    Golden { tokens, positions, slots, mask, logits, hidden, cache_checksum }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn runtime_matches_jax_golden_vectors() {
    let dir = Path::new("artifacts");
    if !(dir.join("manifest.json").exists() && dir.join("dft-xs.weights.bin").exists() && dir.join("tgt-lg.weights.bin").exists()) {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let manifest = yggdrasil::runtime::Manifest::load(dir).unwrap();
    let names: Vec<String> = manifest.golden.keys().cloned().collect();
    let name_refs: Vec<&str> = names.iter().map(|s| s.as_str()).collect();
    let rt = Runtime::load(dir, &name_refs).unwrap();

    for name in &names {
        let spec = rt.spec(name).unwrap().clone();
        let gspec = &manifest.golden[name];
        let g = read_golden(
            &dir.join(&gspec.file),
            gspec.width,
            spec.cache_capacity,
            spec.vocab,
            spec.d_model,
        );
        let cache = rt.new_cache(name).unwrap();
        let reply = rt
            .forward(ForwardRequest {
                model: name.clone(),
                width: gspec.width,
                cache,
                tokens: g.tokens.clone(),
                positions: g.positions.clone(),
                slots: g.slots.clone(),
                mask: g.mask.clone(),
                mode: ExecMode::Resident,
            })
            .unwrap();

        let dl = max_abs_diff(&reply.logits, &g.logits);
        let dh = max_abs_diff(&reply.hidden, &g.hidden);
        // fp32 end-to-end across two XLA builds: tight but not bit-exact.
        assert!(dl < 1e-2, "{name}: logits max|Δ| = {dl}");
        assert!(dh < 1e-3, "{name}: hidden max|Δ| = {dh}");

        // The updated cache must round-trip through a second call: decode
        // one more token attending to the first four and check it does not
        // blow up (shape/threading smoke check on the same cache id).
        let mut mask2 = vec![0f32; spec.cache_capacity];
        for s in 0..=4 {
            mask2[s] = 1.0;
        }
        let reply2 = rt
            .forward(ForwardRequest {
                model: name.clone(),
                width: 1,
                cache,
                tokens: vec![7],
                positions: vec![4],
                slots: vec![4],
                mask: mask2,
                mode: ExecMode::Resident,
            })
            .unwrap();
        assert!(reply2.logits.iter().all(|x| x.is_finite()), "{name}: NaN after threading");
        rt.drop_cache(cache);
        let _ = g.cache_checksum; // checksum covered indirectly by reply2 finiteness + dl
        println!("golden {name}: logits Δ {dl:.2e}, hidden Δ {dh:.2e} ✓");
    }
}

#[test]
fn weights_by_value_mode_matches_resident() {
    let dir = Path::new("artifacts");
    if !(dir.join("manifest.json").exists() && dir.join("dft-xs.weights.bin").exists() && dir.join("tgt-lg.weights.bin").exists()) {
        return;
    }
    let rt = Runtime::load(dir, &["dft-xs"]).unwrap();
    let spec = rt.spec("dft-xs").unwrap().clone();
    let mk = |cache, mode| ForwardRequest {
        model: "dft-xs".into(),
        width: 1,
        cache,
        tokens: vec![3],
        positions: vec![0],
        slots: vec![0],
        mask: {
            let mut m = vec![0f32; spec.cache_capacity];
            m[0] = 1.0;
            m
        },
        mode,
    };
    let c1 = rt.new_cache("dft-xs").unwrap();
    let c2 = rt.new_cache("dft-xs").unwrap();
    let a = rt.forward(mk(c1, ExecMode::Resident)).unwrap();
    let b = rt.forward(mk(c2, ExecMode::WeightsByValue)).unwrap();
    assert_eq!(a.logits.len(), b.logits.len());
    let d = a
        .logits
        .iter()
        .zip(&b.logits)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    assert!(d < 1e-4, "exec modes disagree: {d}");
}

#[test]
fn cold_compile_is_measurably_expensive() {
    let dir = Path::new("artifacts");
    if !(dir.join("manifest.json").exists() && dir.join("dft-xs.weights.bin").exists() && dir.join("tgt-lg.weights.bin").exists()) {
        return;
    }
    let rt = Runtime::load(dir, &["dft-xs"]).unwrap();
    let secs = rt.cold_compile_seconds("dft-xs", 1).unwrap();
    assert!(secs > 1e-4, "compile took {secs}s — suspiciously instant");
}
