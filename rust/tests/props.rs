//! Property-based tests (in-tree prop harness, `util::prop`) over the
//! pure-algorithm invariants: tree structure, EGT growth, the pruning DP,
//! Sequoia construction, mask building, scheduling and the JSON substrate.
//! Reproduce failures with `YGG_PROP_SEED=<seed> cargo test --test props`.

use std::sync::{Arc, Mutex};

use yggdrasil::kvcache::{BlockPool, SlotCache, SlotOwnership, SlotPartition, SlotRange};
use yggdrasil::pruning::SubtreeDp;
use yggdrasil::sampling::XorShiftRng;
use yggdrasil::scheduler::{plan_latency, search_best_plan, Plan, StageDurations};
use yggdrasil::tree::{
    grow_step, owner_words, pack_block_diagonal, pack_block_diagonal_bits, rows_confined,
    rows_confined_bits, rows_owned, rows_owned_bits, BitMask, Frontier, MaskBuilder, TokenTree,
    TreeShape,
};
use yggdrasil::util::json::Json;
use yggdrasil::util::prop::{run_prop, shrink_usize, PropConfig};

/// Random tree generator: either EGT-grown or ad-hoc random attachment.
fn random_tree(rng: &mut XorShiftRng) -> TokenTree {
    let mut tree = TokenTree::new(rng.next_u64() as u32 % 1024);
    if rng.next_f32() < 0.5 {
        let depth = 1 + rng.next_range(6);
        let width = 1 + rng.next_range(8);
        let mut f = Frontier::new(depth);
        fn mk(rng: &mut XorShiftRng) -> Vec<(u32, f32)> {
            let k = 1 + rng.next_range(6);
            let mut v: Vec<(u32, f32)> =
                (0..k).map(|_| (rng.next_u64() as u32 % 1024, rng.next_f32())).collect();
            v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            v
        }
        let c = mk(rng);
        f.push_candidates(&tree, 0, c);
        for _ in 0..depth {
            let ids = grow_step(&mut tree, &mut f, width);
            if ids.is_empty() {
                break;
            }
            for id in ids {
                let c = mk(rng);
                f.push_candidates(&tree, id, c);
            }
        }
    } else {
        let n = rng.next_range(40);
        for _ in 0..n {
            let parent = rng.next_range(tree.len());
            tree.add_node(parent, rng.next_u64() as u32 % 1024, rng.next_f32());
        }
    }
    tree
}

#[test]
fn prop_tree_invariants_hold() {
    run_prop(
        "tree-invariants",
        PropConfig::default(),
        |rng| random_tree(rng),
        |_| vec![],
        |t| t.check_invariants(),
    );
}

#[test]
fn prop_pruning_dp_selection_consistent() {
    run_prop(
        "pruning-dp",
        PropConfig { cases: 128, ..Default::default() },
        |rng| {
            let t = random_tree(rng);
            let budget = 1 + rng.next_range(t.len());
            (t, budget)
        },
        |(t, b)| shrink_usize(*b, 1).map(|b2| (t.clone(), b2)).into_iter().collect(),
        |(tree, budget)| {
            let values: Vec<f64> = (0..tree.len()).map(|i| tree.path_prob(i) as f64).collect();
            let dp = SubtreeDp::solve(tree, &values, *budget);
            let keep = dp.select_at_most(tree, *budget);
            if keep.len() > *budget || !keep.contains(&0) {
                return Err(format!("bad keep set {keep:?} for budget {budget}"));
            }
            for &v in &keep {
                if let Some(p) = tree.parent(v) {
                    if !keep.contains(&p) {
                        return Err(format!("node {v} kept without parent {p}"));
                    }
                }
            }
            let got: f64 = keep.iter().map(|&v| values[v]).sum();
            let want = dp.value_at_most(*budget);
            if (got - want).abs() > 1e-6 {
                return Err(format!("selection value {got} != dp value {want}"));
            }
            if *budget > 1 && dp.value_at_most(*budget) + 1e-9 < dp.value_at_most(*budget - 1) {
                return Err("value decreased with budget".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sequoia_dominates_chain_and_kary_under_its_model() {
    run_prop(
        "sequoia-optimal",
        PropConfig { cases: 64, ..Default::default() },
        |rng| {
            let k = 2 + rng.next_range(6);
            let mut p: Vec<f64> = (0..k).map(|_| rng.next_f64()).collect();
            p.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let s: f64 = p.iter().sum::<f64>().max(1.0);
            let p: Vec<f64> = p.iter().map(|x| x / s).collect();
            let budget = 1 + rng.next_range(32);
            (p, budget)
        },
        |(p, b)| shrink_usize(*b, 1).map(|b2| (p.clone(), b2)).into_iter().collect(),
        |(p, budget)| {
            let sq = TreeShape::sequoia(p, *budget);
            if sq.len() > *budget {
                return Err(format!("sequoia used {} > budget {budget}", sq.len()));
            }
            let v = sq.expected_aal(p);
            let chain = TreeShape::sequence(*budget).expected_aal(p);
            let kary = TreeShape::k_ary(2, 8, *budget).expected_aal(p);
            if v + 1e-9 < chain || v + 1e-9 < kary {
                return Err(format!("sequoia {v} < chain {chain} / kary {kary}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_mask_rows_visible_iff_prefix_or_ancestor() {
    run_prop(
        "mask-semantics",
        PropConfig { cases: 96, ..Default::default() },
        |rng| {
            let t = random_tree(rng);
            let committed = rng.next_range(64);
            let seed = rng.next_u64();
            (t, committed, seed)
        },
        |_| vec![],
        |(tree, committed, seed)| {
            let cap = 320usize;
            let mut rng = XorShiftRng::new(*seed);
            let mut mb = MaskBuilder::new(cap);
            let mut prefix = Vec::new();
            for _ in 0..*committed {
                let s = 100 + rng.next_range(100) as u32;
                if !prefix.contains(&s) {
                    mb.commit_slot(s);
                    prefix.push(s);
                }
            }
            let slot_of: Vec<Option<u32>> = (0..tree.len()).map(|i| Some(i as u32)).collect();
            let nodes: Vec<usize> = (0..tree.len()).collect();
            let m = mb.build(tree, &nodes, &slot_of, tree.len()).to_vec();
            for (row, &node) in nodes.iter().enumerate() {
                let anc: Vec<usize> = tree.ancestors(node).collect();
                for slot in 0..cap {
                    let visible = m[row * cap + slot] > 0.0;
                    let is_prefix = prefix.contains(&(slot as u32));
                    let is_anc = slot < tree.len() && anc.contains(&slot);
                    if visible != (is_prefix || is_anc) {
                        return Err(format!(
                            "node {node} slot {slot}: visible={visible}, prefix={is_prefix}, anc={is_anc}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_plan_search_is_argmin() {
    run_prop(
        "plan-search",
        PropConfig { cases: 256, ..Default::default() },
        |rng| StageDurations {
            head_draft: rng.next_f64() * 5e-3,
            tree_draft: rng.next_f64() * 2e-2,
            cpu_build: rng.next_f64() * 2e-3,
            cpu_mask: rng.next_f64() * 1e-3,
            verify: rng.next_f64() * 2e-2,
            tail_draft: rng.next_f64() * 5e-3,
            cpu_walk: rng.next_f64() * 2e-3,
            accept: rng.next_f64() * 3e-3,
            bookkeep: rng.next_f64() * 3e-3,
            tail_hit_rate: rng.next_f64(),
        },
        |_| vec![],
        |d| {
            let (best, t) = search_best_plan(d);
            for p in Plan::ALL {
                if plan_latency(d, p) + 1e-15 < t {
                    return Err(format!(
                        "{} ({}) beats chosen {} ({t})",
                        p.name(),
                        plan_latency(d, p),
                        best.name()
                    ));
                }
            }
            if !t.is_finite() || t <= 0.0 {
                return Err(format!("degenerate latency {t}"));
            }
            Ok(())
        },
    );
}

/// Flight-recorder ring invariants (DESIGN.md §17): over random event
/// streams and ring capacities (including the inert capacity-0 ring),
/// the dump equals the most recent ≤ capacity events in push order
/// (checked against an unbounded model vector) and `total()` counts
/// every push (none on the inert capacity-0 ring); and over random
/// interleavings of span begin/end and
/// instant records through a roomy [`Tracer`], the retained stream keeps
/// every span balanced — each id begun once, ended once after its begin,
/// with matching name and uid — while instants carry span id 0 and every
/// event wears the tracer's worker stamp.
#[test]
fn prop_flight_recorder_ring_and_span_balance() {
    use std::collections::BTreeMap;
    use yggdrasil::trace::{FlightRecorder, Kind, Name, TraceEvent, Tracer};
    run_prop(
        "flight-recorder-ring",
        PropConfig { cases: 128, ..Default::default() },
        |rng| rng.next_u64(),
        |_| vec![],
        |&seed| {
            let mut rng = XorShiftRng::new(seed);

            // Half 1: wraparound against the unbounded model.
            let cap = rng.next_range(33); // 0..=32
            let n = rng.next_range(120);
            let mut ring = FlightRecorder::new(cap);
            let mut model: Vec<TraceEvent> = Vec::new();
            for i in 0..n {
                let ev = TraceEvent {
                    uid: i as u64,
                    t_us: rng.next_u64() % 1_000,
                    arg: (rng.next_u64() % 64) as i64,
                    ..TraceEvent::EMPTY
                };
                ring.push(ev);
                model.push(ev);
            }
            // A capacity-0 ring is inert: pushes return before counting.
            let want_total = if cap == 0 { 0 } else { n as u64 };
            if ring.total() != want_total {
                return Err(format!("total {} != {want_total} after {n} pushes", ring.total()));
            }
            let want: Vec<u64> = model.iter().rev().take(cap).rev().map(|e| e.uid).collect();
            let got: Vec<u64> = ring.to_vec().iter().map(|e| e.uid).collect();
            if got != want {
                return Err(format!(
                    "dump diverged from the most recent ≤{cap} (got {got:?}, want {want:?})"
                ));
            }

            // Half 2: span balance through a Tracer that retains all.
            let t = Tracer::new(3, 4096);
            let names = [Name::Round, Name::HeadDraft, Name::TreeDraft, Name::Verify];
            let mut open: Vec<(Name, u64, u32)> = Vec::new();
            for _ in 0..(1 + rng.next_range(200)) {
                match rng.next_range(3) {
                    0 => {
                        let nm = names[rng.next_range(names.len())];
                        let uid = rng.next_u64() % 8;
                        let span = t.begin(nm, uid);
                        open.push((nm, uid, span));
                    }
                    1 => {
                        if !open.is_empty() {
                            let k = rng.next_range(open.len());
                            let (nm, uid, span) = open.swap_remove(k);
                            t.end(nm, uid, span);
                        }
                    }
                    _ => t.instant(Name::Admit, rng.next_u64() % 8, 1),
                }
            }
            for (nm, uid, span) in open.drain(..) {
                t.end(nm, uid, span);
            }
            let evs = t.events();
            let mut begun: BTreeMap<u32, usize> = BTreeMap::new();
            let mut ended = 0usize;
            for (i, e) in evs.iter().enumerate() {
                if e.worker != 3 {
                    return Err(format!("event {i} lost the worker stamp: {}", e.worker));
                }
                match e.kind {
                    Kind::SpanBegin => {
                        if begun.insert(e.span, i).is_some() {
                            return Err(format!("span id {} begun twice", e.span));
                        }
                    }
                    Kind::SpanEnd => {
                        let Some(&bi) = begun.get(&e.span) else {
                            return Err(format!("span id {} ended before its begin", e.span));
                        };
                        let b = &evs[bi];
                        if b.name != e.name || b.uid != e.uid {
                            return Err(format!(
                                "span id {} closed under a different name/uid",
                                e.span
                            ));
                        }
                        ended += 1;
                    }
                    Kind::Instant => {
                        if e.span != 0 {
                            return Err(format!("instant {i} carries span id {}", e.span));
                        }
                    }
                }
            }
            if begun.len() != ended {
                return Err(format!("{} begins vs {ended} ends", begun.len()));
            }
            Ok(())
        },
    );
}

/// Round-level allocator invariants (DESIGN.md §15): over random
/// session mixes and budgets, the global allocation never exceeds the
/// round budget, the pool-headroom snapshot, or any session's static
/// envelope ∧ headroom; adaptive budgets land on the compiled-width
/// grid; and indistinguishable sessions (equal acceptance estimates and
/// SLO classes) degenerate bit-exactly to the uniform water-fill
/// fallback.
#[test]
fn prop_round_allocator_respects_budget_envelope_and_uniform_degeneracy() {
    use yggdrasil::config::GRAPH_WIDTHS;
    use yggdrasil::scheduler::alloc::{
        allocate_verify_budget, uniform_verify_budget, SessionDemand,
    };
    run_prop(
        "round-allocator",
        PropConfig { cases: 256, ..Default::default() },
        |rng| rng.next_u64(),
        |_| vec![],
        |&seed| {
            let mut rng = XorShiftRng::new(seed);
            let n = 1 + rng.next_range(8);
            let demands: Vec<SessionDemand> = (0..n)
                .map(|_| SessionDemand {
                    q: rng.next_f64().clamp(0.01, 0.99),
                    envelope: rng.next_range(65),
                    headroom: rng.next_range(81),
                    latency_class: rng.next_f32() < 0.5,
                })
                .collect();
            let global = rng.next_range(257);
            let pool = rng.next_range(257);
            let got = allocate_verify_budget(&demands, global, pool, None);
            if got.len() != n {
                return Err(format!("{} budgets for {n} sessions", got.len()));
            }
            let total: usize = got.iter().sum();
            if total > global || total > pool {
                return Err(format!(
                    "granted {total} rows > budget {global} / pool {pool}: {got:?}"
                ));
            }
            for (b, d) in got.iter().zip(&demands) {
                if *b > d.envelope.min(d.headroom) {
                    return Err(format!(
                        "budget {b} exceeds envelope {} / headroom {}",
                        d.envelope, d.headroom
                    ));
                }
            }
            let distinguishable = demands.windows(2).any(|w| {
                (w[0].q - w[1].q).abs() >= 1e-9 || w[0].latency_class != w[1].latency_class
            });
            if distinguishable {
                for &b in &got {
                    if b != 0 && !GRAPH_WIDTHS.contains(&b) {
                        return Err(format!(
                            "budget {b} off the compiled-width grid: {got:?}"
                        ));
                    }
                }
            }
            // Flatten the mix to one acceptance estimate + one class: the
            // adaptive path must reproduce the uniform water-fill exactly.
            let flat: Vec<SessionDemand> = demands
                .iter()
                .map(|d| SessionDemand {
                    q: demands[0].q,
                    latency_class: demands[0].latency_class,
                    ..*d
                })
                .collect();
            let adaptive = allocate_verify_budget(&flat, global, pool, None);
            let uniform = uniform_verify_budget(&flat, global.min(pool));
            if adaptive != uniform {
                return Err(format!(
                    "equal profiles diverged: adaptive {adaptive:?} != uniform {uniform:?}"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut XorShiftRng, depth: usize) -> Json {
        match if depth > 3 { rng.next_range(4) } else { rng.next_range(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_f32() < 0.5),
            2 => Json::Num((rng.next_f64() * 2e6).round() / 64.0 - 1e4),
            3 => {
                let n = rng.next_range(12);
                Json::Str(
                    (0..n)
                        .map(|_| char::from_u32(0x20 + rng.next_range(0x250) as u32).unwrap_or('x'))
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.next_range(5)).map(|_| random_json(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.next_range(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    run_prop(
        "json-roundtrip",
        PropConfig { cases: 256, ..Default::default() },
        |rng| random_json(rng, 0),
        |_| vec![],
        |j| {
            let text = j.to_string();
            let back = Json::parse(&text).map_err(|e| format!("parse failed: {e} on {text}"))?;
            if &back != j {
                return Err(format!("roundtrip mismatch: {text}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_induced_subtree_preserves_probs() {
    run_prop(
        "induced-subtree",
        PropConfig { cases: 96, ..Default::default() },
        |rng| {
            let t = random_tree(rng);
            let mut keep = vec![0usize];
            for v in 1..t.len() {
                let p = t.parent(v).unwrap();
                if keep.contains(&p) && rng.next_f32() < 0.7 {
                    keep.push(v);
                }
            }
            (t, keep)
        },
        |_| vec![],
        |(t, keep)| {
            let (sub, map) = t.induced_subtree(keep);
            sub.check_invariants()?;
            if sub.len() != keep.len() {
                return Err(format!("size {} != keep {}", sub.len(), keep.len()));
            }
            for &old in keep {
                let new = map[old].ok_or_else(|| format!("node {old} unmapped"))?;
                if sub.token(new) != t.token(old) {
                    return Err("token mismatch".into());
                }
                if (sub.path_prob(new) - t.path_prob(old)).abs() > 1e-5 {
                    return Err(format!(
                        "path prob mismatch at {old}: {} vs {}",
                        sub.path_prob(new),
                        t.path_prob(old)
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Paged-cache safety (DESIGN.md §10 + §12): under random interleavings
/// of session admit / prefix-attach / alloc / reject-release / preempt /
/// disconnect-with-donation / LRU-evict over one shared refcounted
/// [`BlockPool`] with a [`PrefixCache`] layered on top, every built (and
/// packed) verify row's mask references only slots in blocks *currently
/// owned or shared* by that session, block refcounts never drift or
/// underflow (every reference — exclusive, read-shared, or trie-held —
/// is accounted for exactly), the free list never disagrees with the
/// refcounts, and an evicted (freed) block is never referenced by any
/// live session's ownership set.
#[test]
fn prop_paged_masks_reference_only_owned_blocks() {
    use yggdrasil::kvcache::{PrefixCache, SlotOwnership};
    struct Sim {
        cache: SlotCache,
        outstanding: Vec<u32>,
    }
    // The global token stream every session commits along: committed
    // slot j of any session holds token seq(j), so sessions share
    // prefixes and the radix trie gets genuine hits.
    fn seq(j: usize) -> u32 {
        (j as u32).wrapping_mul(31).wrapping_add(7) % 256
    }
    run_prop(
        "paged-block-ownership",
        PropConfig { cases: 64, ..Default::default() },
        |rng| rng.next_u64(),
        |_| vec![],
        |&seed| {
            let mut rng = XorShiftRng::new(seed);
            let block_size = 2 + rng.next_range(6); // 2..=7
            let nblocks = 4 + rng.next_range(12); // 4..=15
            let capacity = block_size * nblocks + 1 + rng.next_range(3); // slack + trash
            let pool = Arc::new(Mutex::new(
                BlockPool::new(capacity, block_size, Some(nblocks)).map_err(|e| e.to_string())?,
            ));
            let prefix = Arc::new(Mutex::new(
                PrefixCache::new(vec![pool.clone()]).map_err(|e| e.to_string())?,
            ));
            let mut sims: Vec<Option<Sim>> = (0..4).map(|_| None).collect();
            for _ in 0..(40 + rng.next_range(60)) {
                let k = rng.next_range(sims.len());
                match rng.next_range(7) {
                    // Admit: open a paged session in a free seat and try
                    // to attach a cached prefix of the shared stream.
                    0 => {
                        if sims[k].is_none() {
                            let mut cache =
                                SlotCache::paged_with_prefix(pool.clone(), prefix.clone());
                            let want = rng.next_range(4) * block_size;
                            let tokens: Vec<u32> = (0..want).map(seq).collect();
                            let hit = prefix.lock().unwrap().acquire(&tokens);
                            if hit.tokens > 0 {
                                cache.attach_prefix(&hit.blocks[0]);
                            }
                            sims[k] = Some(Sim { cache, outstanding: Vec::new() });
                        }
                    }
                    // Alloc: lease on demand (evicting LRU cached blocks
                    // when dry), build rows, check ownership, commit the
                    // next run of the shared stream, keep the rest
                    // outstanding.
                    1 => {
                        if let Some(s) = &mut sims[k] {
                            let n = 1 + rng.next_range(2 * block_size);
                            if let Some(slots) = s.cache.alloc(n) {
                                let own = s.cache.ownership();
                                for &sl in &slots {
                                    if !own.contains(sl) {
                                        return Err(format!(
                                            "alloc handed out unowned slot {sl}"
                                        ));
                                    }
                                }
                                let rows =
                                    s.cache.mask_builder().build_linear(&slots, n, n).to_vec();
                                if !rows_owned(&rows, capacity, &s.cache.ownership()) {
                                    return Err("mask row escaped owned blocks".into());
                                }
                                let c = rng.next_range(slots.len() + 1);
                                for &sl in &slots[..c] {
                                    s.cache.commit(sl);
                                }
                                s.outstanding.extend(&slots[c..]);
                            }
                        }
                    }
                    // Reject-release: return every outstanding draft slot
                    // (fully-free blocks flow back to the pool).
                    2 => {
                        if let Some(s) = &mut sims[k] {
                            let out = std::mem::take(&mut s.outstanding);
                            s.cache.release(&out);
                        }
                    }
                    // Preempt / disconnect: drop the session whole —
                    // usually donating its committed prefix blocks into
                    // the trie first (completion), sometimes not (a
                    // session that never reached teardown insertion).
                    3 => {
                        if let Some(mut s) = sims[k].take() {
                            if rng.next_f32() < 0.7 {
                                let n = s.cache.committed_len();
                                let tokens: Vec<u32> = (0..n).map(seq).collect();
                                prefix.lock().unwrap().insert(&tokens, &mut [&mut s.cache]);
                            }
                        }
                    }
                    // LRU eviction pass, as a dry pool would trigger it.
                    4 => {
                        prefix.lock().unwrap().evict(1 + rng.next_range(3));
                    }
                    // Prefix re-lookup on a live session's stream: takes
                    // and immediately drops read references (an admission
                    // probe whose task was rejected).
                    5 => {
                        let want = rng.next_range(5) * block_size;
                        let tokens: Vec<u32> = (0..want).map(seq).collect();
                        let hit = prefix.lock().unwrap().acquire(&tokens);
                        let mut p = pool.lock().unwrap();
                        for b in &hit.blocks[0] {
                            p.try_release(*b).map_err(|e| format!("probe refs: {e}"))?;
                        }
                    }
                    // Packed verify: one row per live session, packed
                    // block-diagonally; re-check each row against its
                    // owner and the padding rows against zero.
                    _ => {
                        let mut blocks_rows: Vec<(yggdrasil::kvcache::SlotOwnership, Vec<f32>)> =
                            Vec::new();
                        let mut taken: Vec<(usize, u32)> = Vec::new();
                        for (i, slot) in sims.iter_mut().enumerate() {
                            let Some(s) = slot else { continue };
                            let Some(sl) = s.cache.alloc(1) else { continue };
                            let rows =
                                s.cache.mask_builder().build_linear(&sl, 1, 1).to_vec();
                            blocks_rows.push((s.cache.ownership(), rows));
                            taken.push((i, sl[0]));
                        }
                        let total: usize = blocks_rows.len();
                        let width = total + rng.next_range(3);
                        let refs: Vec<&[f32]> =
                            blocks_rows.iter().map(|(_, r)| r.as_slice()).collect();
                        let packed = pack_block_diagonal(&refs, capacity, width);
                        for (row, (own, _)) in blocks_rows.iter().enumerate() {
                            let r = &packed[row * capacity..(row + 1) * capacity];
                            if !rows_owned(r, capacity, own) {
                                return Err(format!("packed row {row} escaped its owner"));
                            }
                        }
                        for row in total..width {
                            if packed[row * capacity..(row + 1) * capacity]
                                .iter()
                                .any(|&v| v != 0.0)
                            {
                                return Err(format!("padding row {row} not all-zero"));
                            }
                        }
                        for (i, sl) in taken {
                            sims[i].as_mut().unwrap().cache.release(&[sl]);
                        }
                    }
                }
                // Accounting invariant: every block's refcount equals
                // exactly the references we can enumerate — one per
                // session owning/sharing it plus one when the trie holds
                // it — so refcounts can never have underflowed; the free
                // list agrees with the zero-ref set; and no freed
                // (evicted) block is referenced by any live ownership.
                let mut expected: Vec<u32> = vec![0; nblocks];
                for s in sims.iter().flatten() {
                    if let SlotOwnership::Blocks { blocks, shared, .. } = s.cache.ownership() {
                        for b in blocks.iter().chain(shared.iter()) {
                            expected[*b as usize] += 1;
                        }
                    }
                }
                let p = pool.lock().unwrap();
                let mut zero_refs = 0usize;
                for b in 0..nblocks as u32 {
                    let want = expected[b as usize] + u32::from(p.is_cached(b));
                    let got = p.ref_count(b);
                    if got != want {
                        return Err(format!(
                            "block {b}: refcount {got} != {want} enumerated references"
                        ));
                    }
                    if got == 0 {
                        zero_refs += 1;
                    } else if expected[b as usize] == 0 && !p.is_cached(b) {
                        return Err(format!("block {b}: refs held by nobody"));
                    }
                }
                if p.free_blocks() != zero_refs {
                    return Err(format!(
                        "free list {} blocks != {zero_refs} zero-ref blocks",
                        p.free_blocks()
                    ));
                }
                // The O(1) maintained evictable gauge must agree with a
                // from-scratch recount at every step.
                let recount = (0..nblocks as u32)
                    .filter(|&b| p.is_cached(b) && p.ref_count(b) == 1)
                    .count();
                if p.evictable_blocks() != recount {
                    return Err(format!(
                        "evictable gauge {} != recount {recount}",
                        p.evictable_blocks()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Stage-aligned batched drafting safety (DESIGN.md §11), the
/// drafter-side mirror of `prop_paged_masks_reference_only_owned_blocks`:
/// sessions grow random draft trees over one shared paged *drafter*
/// cache, and every level's rows — built by each session's own builder,
/// then packed block-diagonally like the batched draft phase does — may
/// reference only slots in blocks currently owned by that session;
/// padding rows stay all-zero and the pool's block accounting never
/// leaks across iterations of commit/release/preempt churn.
#[test]
fn prop_packed_draft_level_masks_reference_only_owned_blocks() {
    struct Sess {
        cache: SlotCache,
        tree: TokenTree,
        slot_of: Vec<Option<u32>>,
    }
    run_prop(
        "packed-draft-level-ownership",
        PropConfig { cases: 64, ..Default::default() },
        |rng| rng.next_u64(),
        |_| vec![],
        |&seed| {
            let mut rng = XorShiftRng::new(seed);
            let block_size = 2 + rng.next_range(6); // 2..=7
            let nblocks = 6 + rng.next_range(10); // 6..=15
            let capacity = block_size * nblocks + 1; // + trash
            let pool = Arc::new(Mutex::new(
                BlockPool::new(capacity, block_size, Some(nblocks)).map_err(|e| e.to_string())?,
            ));
            let nsess = 2 + rng.next_range(3); // 2..=4
            let mut sessions: Vec<SlotCache> =
                (0..nsess).map(|_| SlotCache::paged(pool.clone())).collect();
            for _iter in 0..(2 + rng.next_range(3)) {
                // Open one draft tree per session whose root slot fits.
                let mut drafting: Vec<(usize, Sess)> = Vec::new();
                for (si, slot) in sessions.iter_mut().enumerate() {
                    let mut cache = std::mem::replace(slot, SlotCache::new(2));
                    let tree = TokenTree::new(1);
                    let mut slot_of = vec![None];
                    if let Some(s) = cache.alloc(1) {
                        slot_of[0] = Some(s[0]);
                        drafting.push((si, Sess { cache, tree, slot_of }));
                    } else {
                        // Pool dry: this session sits the iteration out.
                        *slot = cache;
                    }
                }
                // Grow level by level; each level packs across sessions.
                let depth = 1 + rng.next_range(4);
                let width = 1 + rng.next_range(4);
                for _ in 0..depth {
                    let mut level: Vec<(yggdrasil::kvcache::SlotOwnership, Vec<f32>)> =
                        Vec::new();
                    for (_, s) in drafting.iter_mut() {
                        let mut ids = Vec::new();
                        for _ in 0..width {
                            let parent = rng.next_range(s.tree.len());
                            let id = s.tree.add_node(parent, rng.next_u64() as u32 % 64, 0.5);
                            s.slot_of.push(None);
                            ids.push(id);
                        }
                        let Some(slots) = s.cache.alloc(ids.len()) else {
                            continue; // dry: level skipped (growth stops)
                        };
                        for (i, &id) in ids.iter().enumerate() {
                            s.slot_of[id] = Some(slots[i]);
                        }
                        let n = ids.len();
                        let rows =
                            s.cache.mask_builder().build(&s.tree, &ids, &s.slot_of, n).to_vec();
                        if !rows_owned(&rows, capacity, &s.cache.ownership()) {
                            return Err("draft rows escaped their owned blocks".into());
                        }
                        level.push((s.cache.ownership(), rows));
                    }
                    if level.is_empty() {
                        continue;
                    }
                    // Pack the level block-diagonally with some padding,
                    // exactly like the batched draft phase, and re-check
                    // every row against its owner.
                    let total: usize = level.iter().map(|(_, r)| r.len() / capacity).sum();
                    let padded = total + rng.next_range(4);
                    let refs: Vec<&[f32]> = level.iter().map(|(_, r)| r.as_slice()).collect();
                    let packed = pack_block_diagonal(&refs, capacity, padded);
                    let mut row = 0usize;
                    for (own, r) in &level {
                        for _ in 0..r.len() / capacity {
                            let slice = &packed[row * capacity..(row + 1) * capacity];
                            if !rows_owned(slice, capacity, own) {
                                return Err(format!("packed draft row {row} escaped its owner"));
                            }
                            row += 1;
                        }
                    }
                    for r in row..padded {
                        if packed[r * capacity..(r + 1) * capacity].iter().any(|&v| v != 0.0) {
                            return Err(format!("padding row {r} is not all-zero"));
                        }
                    }
                }
                // Iteration end: commit a random accepted subset, release
                // the rest (bookkeeping), occasionally preempt whole
                // sessions (drop: every block returns).
                for (si, s) in drafting {
                    let Sess { mut cache, slot_of, .. } = s;
                    if rng.next_f32() < 0.2 {
                        drop(cache); // preempt/disconnect
                        sessions[si] = SlotCache::paged(pool.clone());
                        continue;
                    }
                    let mut rejected = Vec::new();
                    for slot in slot_of.into_iter().flatten() {
                        if rng.next_f32() < 0.4 {
                            cache.commit(slot);
                        } else {
                            rejected.push(slot);
                        }
                    }
                    cache.release(&rejected);
                    sessions[si] = cache;
                }
                // Accounting invariant: free + owned == total, always.
                let owned: usize = sessions.iter().map(|c| c.owned_blocks()).sum();
                let free = pool.lock().unwrap().free_blocks();
                if free + owned != nblocks {
                    return Err(format!("block leak: free {free} + owned {owned} != {nblocks}"));
                }
            }
            Ok(())
        },
    );
}

/// Cross-session batching safety (DESIGN.md §9): over random packings of
/// random per-session trees into one shared cache, no session's mask rows
/// may ever reference another session's slots — the packed batch mask is
/// block-diagonal by construction, and padding rows are all-zero.
#[test]
fn prop_block_diagonal_masks_never_cross_sessions() {
    run_prop(
        "block-diagonal-masks",
        PropConfig::default(),
        |rng| rng.next_u64(),
        |_| vec![],
        |&seed| {
            let mut rng = XorShiftRng::new(seed);
            let sessions = 2 + rng.next_range(3); // 2..=4 concurrent sessions
            let per = 12 + rng.next_range(5); // region length 12..=16
            let capacity = sessions * per + 1; // + shared trash slot
            let mut part = SlotPartition::new(capacity, sessions).map_err(|e| e.to_string())?;
            let trash = part.trash_slot();
            let mut blocks: Vec<(SlotRange, Vec<f32>)> = Vec::new();
            for _ in 0..sessions {
                let range = part.lease().ok_or_else(|| "lease failed".to_string())?;
                let mut cache = SlotCache::with_range(range, capacity, trash);
                // Random committed prefix.
                let ncommit = rng.next_range(4);
                let committed =
                    cache.alloc(ncommit).ok_or_else(|| "prefix alloc failed".to_string())?;
                for &s in &committed {
                    cache.commit(s);
                }
                // Random tree, slots from this session's range only.
                let mut tree = TokenTree::new(1);
                let nnodes = 1 + rng.next_range(5);
                let mut nodes = Vec::new();
                for _ in 0..nnodes {
                    let parent = rng.next_range(tree.len());
                    nodes.push(tree.add_node(parent, rng.next_u64() as u32 % 64, 0.5));
                }
                let slots = cache
                    .alloc(nodes.len() + 1)
                    .ok_or_else(|| "tree alloc failed".to_string())?;
                let mut slot_of = vec![None; tree.len()];
                slot_of[0] = Some(slots[0]);
                for (i, &n) in nodes.iter().enumerate() {
                    slot_of[n] = Some(slots[i + 1]);
                }
                let rows =
                    cache.mask_builder().build(&tree, &nodes, &slot_of, nodes.len()).to_vec();
                if !rows_confined(&rows, capacity, range) {
                    return Err(format!("session rows escaped their range {range:?}"));
                }
                blocks.push((range, rows));
            }
            // Pack and re-check row by row against the owning range.
            let total_rows: usize = blocks.iter().map(|(_, b)| b.len() / capacity).sum();
            let width = total_rows + rng.next_range(4); // some padding rows
            let refs: Vec<&[f32]> = blocks.iter().map(|(_, b)| b.as_slice()).collect();
            let packed = pack_block_diagonal(&refs, capacity, width);
            let mut row = 0usize;
            for (range, b) in &blocks {
                for _ in 0..b.len() / capacity {
                    let r = &packed[row * capacity..(row + 1) * capacity];
                    for (col, &v) in r.iter().enumerate() {
                        if v != 0.0 && !range.contains(col as u32) {
                            return Err(format!(
                                "packed row {row} sees foreign slot {col} (own range {range:?})"
                            ));
                        }
                    }
                    row += 1;
                }
            }
            for r in row..width {
                if packed[r * capacity..(r + 1) * capacity].iter().any(|&v| v != 0.0) {
                    return Err(format!("padding row {r} is not all-zero"));
                }
            }
            Ok(())
        },
    );
}

/// Chunked prefill (DESIGN.md §14): over random prompt lengths × chunk
/// sizes × cache layouts (counted / shared-paged / equal-partition) ×
/// mid-prefill preemption points, a chunked mock session must take
/// exactly ⌈prompt/chunk⌉ prefill steps and stream the same tokens, bit
/// for bit, as the one-shot baseline.
#[test]
fn prop_chunked_prefill_streams_bit_exact() {
    use yggdrasil::engine::{DecodeTask, StepEngine, TaskState};
    use yggdrasil::server::MockStepEngine;

    fn drive(
        engine: &mut MockStepEngine,
        prompt: &[u32],
        max_new: usize,
        preempt_after: Option<usize>,
    ) -> Result<(Vec<u32>, usize), String> {
        let mut task = engine.begin(prompt, max_new).map_err(|e| e.to_string())?;
        if let Some(k) = preempt_after {
            for _ in 0..k {
                if task.state() != TaskState::Prefill {
                    break;
                }
                task.step().map_err(|e| e.to_string())?;
            }
            // Mid-prefill preemption: drop the task (every leased block
            // or region returns) and re-begin the same prompt — the
            // re-prefill resume path.
            drop(task);
            task = engine.begin(prompt, max_new).map_err(|e| e.to_string())?;
        }
        let mut stream = Vec::new();
        let mut prefill_steps = 0usize;
        loop {
            let was_prefill = task.state() == TaskState::Prefill;
            let out = task.step().map_err(|e| e.to_string())?;
            if was_prefill {
                prefill_steps += 1;
            }
            stream.extend_from_slice(&out.tokens);
            if out.done() {
                break;
            }
        }
        Ok((stream, prefill_steps))
    }

    run_prop(
        "chunked-prefill-bit-exact",
        PropConfig { cases: 96, ..Default::default() },
        |rng| rng.next_u64(),
        |_| vec![],
        |&seed| {
            let mut rng = XorShiftRng::new(seed);
            let prompt_len = 1 + rng.next_range(40);
            let max_new = rng.next_range(17);
            let per_step = 1 + rng.next_range(4);
            let chunk = 1 + rng.next_range(9);
            let layout = rng.next_range(3);
            let block_size = 1 + rng.next_range(8);
            let capacity = prompt_len + max_new + per_step + 16;
            let prompt: Vec<u32> = (0..prompt_len).map(|j| 5 + j as u32).collect();
            let mk = |chunk: usize| -> Result<MockStepEngine, String> {
                let e = match layout {
                    0 => MockStepEngine::new(0, per_step, capacity),
                    1 => MockStepEngine::with_paged_pool(0, per_step, capacity, block_size)
                        .map_err(|e| e.to_string())?,
                    _ => MockStepEngine::with_equal_partition(0, per_step, capacity, 1)
                        .map_err(|e| e.to_string())?,
                };
                Ok(e.with_prefill_chunk(chunk))
            };
            let (baseline, base_steps) = drive(&mut mk(0)?, &prompt, max_new, None)?;
            if base_steps != 1 {
                return Err(format!("one-shot baseline took {base_steps} prefill steps"));
            }
            let want_steps = prompt_len.div_ceil(chunk);
            let preempt_after = rng.next_range(want_steps);
            let (chunked, steps) = drive(&mut mk(chunk)?, &prompt, max_new, Some(preempt_after))?;
            if chunked != baseline {
                return Err(format!(
                    "stream mismatch (layout {layout}, chunk {chunk}, \
                     preempted after {preempt_after}): {chunked:?} != {baseline:?}"
                ));
            }
            if steps != want_steps {
                return Err(format!(
                    "{steps} prefill steps, want {want_steps} (prompt {prompt_len}, chunk {chunk})"
                ));
            }
            let (unpreempted, steps2) = drive(&mut mk(chunk)?, &prompt, max_new, None)?;
            if unpreempted != baseline || steps2 != want_steps {
                return Err("unpreempted chunked run diverged from the baseline".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_bitmask_paths_match_f32_reference() {
    run_prop(
        "bitmask-parity",
        PropConfig { cases: 96, ..Default::default() },
        |rng| rng.next_u64(),
        |_| vec![],
        |seed| {
            let cap = 320usize;
            let mut rng = XorShiftRng::new(*seed);
            let sessions = 1 + rng.next_range(3);
            let mut f32_blocks: Vec<Vec<f32>> = Vec::new();
            let mut bit_blocks: Vec<BitMask> = Vec::new();
            for s in 0..sessions {
                let tree = random_tree(&mut rng);
                let base = (s * 100) as u32;
                let mut mb = MaskBuilder::new(cap);
                for _ in 0..rng.next_range(24) {
                    mb.commit_slot(base + 60 + rng.next_range(40) as u32);
                }
                let nodes: Vec<usize> = (0..tree.len()).collect();
                let slot_of: Vec<Option<u32>> = (0..tree.len())
                    .map(|j| if j % 7 == 6 { None } else { Some(base + (j % 60) as u32) })
                    .collect();
                let rows = tree.len() + rng.next_range(3);
                let dense = mb.build(&tree, &nodes, &slot_of, rows).to_vec();
                let bits = mb.build_bits(&tree, &nodes, &slot_of, rows).clone();
                if bits.to_f32() != dense {
                    return Err(format!("tree build parity broke (session {s})"));
                }

                // Ownership / confinement answers must agree in both layouts,
                // for passing and failing owners alike.
                let owner = if rng.next_f32() < 0.5 {
                    SlotOwnership::Range(SlotRange { base, len: 40 + rng.next_range(80) as u32 })
                } else {
                    let blocks: Vec<u32> =
                        (0..(cap / 16) as u32).filter(|_| rng.next_f32() < 0.5).collect();
                    let shared: Vec<u32> =
                        (0..(cap / 16) as u32).filter(|_| rng.next_f32() < 0.1).collect();
                    SlotOwnership::Blocks { block_size: 16, blocks, shared }
                };
                let mut allowed = Vec::new();
                owner_words(&owner, cap, &mut allowed);
                if rows_owned(&dense, cap, &owner) != rows_owned_bits(&bits, &allowed) {
                    return Err(format!("rows_owned parity broke (session {s}, {owner:?})"));
                }
                let cr = SlotRange {
                    base: rng.next_range(cap) as u32,
                    len: rng.next_range(cap) as u32,
                };
                if rows_confined(&dense, cap, cr) != rows_confined_bits(&bits, cr) {
                    return Err(format!("rows_confined parity broke (session {s}, {cr:?})"));
                }

                // The linear prefill-chunk builder, same builder instance.
                let k = 1 + rng.next_range(40);
                let chunk_slots: Vec<u32> = (0..k).map(|j| base + j as u32).collect();
                let n = rng.next_range(k + 1);
                let rows_l = n + rng.next_range(3);
                let dl = mb.build_linear(&chunk_slots, n, rows_l).to_vec();
                let bl = mb.build_linear_bits(&chunk_slots, n, rows_l);
                if bl.to_f32() != dl {
                    return Err(format!("linear build parity broke (session {s})"));
                }

                f32_blocks.push(dense);
                bit_blocks.push(bits);
            }

            // Block-diagonal pack parity across the whole batch.
            let total: usize = f32_blocks.iter().map(|b| b.len() / cap).sum();
            let width = total + rng.next_range(4);
            let refs: Vec<&[f32]> = f32_blocks.iter().map(|b| b.as_slice()).collect();
            let dense_packed = pack_block_diagonal(&refs, cap, width);
            let bit_refs: Vec<&BitMask> = bit_blocks.iter().collect();
            let mut packed = BitMask::new(cap);
            pack_block_diagonal_bits(&bit_refs, cap, width, &mut packed);
            if packed.to_f32() != dense_packed {
                return Err("block-diagonal pack parity broke".to_string());
            }
            Ok(())
        },
    );
}
