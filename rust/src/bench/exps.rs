//! One function per paper table/figure. Each prints the same rows/series
//! the paper reports and saves CSV; EXPERIMENTS.md records paper-vs-ours.

use crate::config::{EngineConfig, Objective, SchedulePlan, GRAPH_WIDTHS};
use crate::metrics::Table;
use crate::runtime::ExecMode;
use crate::simulator::{
    self, llama2_13b, llama2_7b, llama_160m, llama_68m, GpuProfile, LlmDims, SpecSim, A100, A40,
};
use crate::tree::TreeShape;

use super::Lab;

/// Table 1: qualitative comparison of prior art (reproduced verbatim —
/// the code below *implements* every row as an engine preset).
pub fn table1(lab: &mut Lab) -> crate::Result<()> {
    let mut t = Table::new(&["system", "draft adaptivity", "structure", "draft compiled", "verify compiled"])
        .with_title("Table 1 — design-space comparison (each row is runnable here)");
    t.row(&["Speculative Decoding [22] (`seqspec`)", "static", "sequence", "no", "no"]);
    t.row(&["DISCO [29] (dynamic seq ≈ `seqspec`+pred)", "dynamic", "sequence", "no", "no"]);
    t.row(&["SpecInfer [31] (`specinfer`)", "static", "tree", "no", "no"]);
    t.row(&["vLLM-Spec [27] (`vllmspec`)", "static", "sequence", "yes", "yes"]);
    t.row(&["Sequoia [8] (`sequoia`)", "static", "tree", "yes", "no"]);
    t.row(&["Yggdrasil (`yggdrasil`)", "dynamic", "tree", "yes", "yes"]);
    lab.emit("table1", &t)
}

/// Fig. 4: what static compilation buys — per-call latency of the eager
/// path (weights restaged, CUDA-graph-less analog) vs the compiled
/// resident path, plus the recompilation cost dynamic shapes would pay.
pub fn fig4(lab: &mut Lab) -> crate::Result<()> {
    let reps = if lab.opts.quick { 3 } else { 10 };
    let mut t = Table::new(&["model", "width", "eager_ms", "compiled_ms", "speedup", "recompile_s"])
        .with_title("Fig. 4 — runtime comparison (measured, CPU PJRT)");
    for model in ["tgt-sm", "dft-xs"] {
        for &w in &[1usize, 8, 64] {
            let eager = lab.rt.profile_width(model, w, reps, 1, ExecMode::WeightsByValue)?;
            let compiled = lab.rt.profile_width(model, w, reps, 1, ExecMode::Resident)?;
            let recompile = lab.rt.cold_compile_seconds(model, w)?;
            t.row(&[
                model.to_string(),
                w.to_string(),
                format!("{:.3}", eager * 1e3),
                format!("{:.3}", compiled * 1e3),
                format!("{:.2}x", eager / compiled),
                format!("{recompile:.3}"),
            ]);
        }
    }
    lab.emit("fig4", &t)
}

/// Fig. 5: (a) verification latency vs token count (measured + simulated
/// A100); (b) AAL-proxy speedup (Eq. 1) vs actual per-token speedup as the
/// verification width grows — the divergence that motivates Eq. 3.
pub fn fig5(lab: &mut Lab) -> crate::Result<()> {
    // (a) latency curves.
    let lat = lab.latency("dft-xs", "tgt-sm")?;
    let a100 = simulator::latency_curve(&llama2_7b(), &A100, 256, true);
    let mut ta = Table::new(&["width", "measured_tgt_sm_ms", "sim_a100_7b_ms"])
        .with_title("Fig. 5a — verification latency vs parallel tokens");
    for &w in GRAPH_WIDTHS.iter() {
        ta.row(&[
            w.to_string(),
            format!("{:.3}", lat.t_verify(w) * 1e3),
            format!("{:.3}", a100.at(w as f64) * 1e3),
        ]);
    }
    lab.emit("fig5a", &ta)?;

    // (b) measured: EGT with fixed depth/width, sweep verification budget.
    let n = lab.opts.prompts().min(3);
    let max_new = lab.opts.max_new();
    let vanilla_tpot = {
        let mut v = lab.vanilla("tgt-sm");
        lab.run(&mut v, "c4s", n, max_new)?.tpot
    };
    let mut tb = Table::new(&["w_verify", "aal", "aal_speedup_eq1", "true_speedup"])
        .with_title("Fig. 5b — AAL speedup vs actual speedup (measured)");
    let budgets: &[usize] = if lab.opts.quick { &[8, 64] } else { &[4, 8, 16, 32, 64] };
    for &wv in budgets {
        let mut cfg = EngineConfig::default();
        cfg.drafter = "dft-xs".into();
        cfg.target = "tgt-sm".into();
        cfg.use_depth_predictor = false;
        cfg.objective = Objective::Aal; // isolate the budget effect
        cfg.prune = true;
        cfg.max_verify = wv;
        let mut e = lab.spec(cfg)?;
        let r = lab.run(&mut e, "c4s", n, max_new)?;
        tb.row(&[
            wv.to_string(),
            format!("{:.2}", r.aal),
            format!("{:.2}x", r.aal), // Eq. 1 treats AAL as the speedup
            format!("{:.2}x", vanilla_tpot / r.tpot),
        ]);
    }
    lab.emit("fig5b", &tb)
}

/// Fig. 6: AAL / per-step latency / per-token latency across the system
/// archetypes — the "no one wins both axes" motivation figure.
pub fn fig6(lab: &mut Lab) -> crate::Result<()> {
    let n = lab.opts.prompts();
    let max_new = lab.opts.max_new();
    let mut t = Table::new(&["engine", "AAL", "step_ms", "tpot_ms"])
        .with_title("Fig. 6 — AAL vs step latency vs token latency (measured, c4s)");
    let mut vanilla = lab.vanilla("tgt-sm");
    let r = lab.run(&mut vanilla, "c4s", n, max_new)?;
    t.row(&[
        "vanilla".into(),
        format!("{:.2}", r.aal),
        format!("{:.2}", r.step_latency * 1e3),
        format!("{:.2}", r.tpot * 1e3),
    ]);
    for name in ["seqspec", "specinfer", "sequoia", "vllmspec", "yggdrasil"] {
        let mut e = lab.engine(name, ("dft-xs", "tgt-sm"))?;
        let r = lab.run(e.as_mut(), "c4s", n, max_new)?;
        t.row(&[
            name.to_string(),
            format!("{:.2}", r.aal),
            format!("{:.2}", r.step_latency * 1e3),
            format!("{:.2}", r.tpot * 1e3),
        ]);
    }
    lab.emit("fig6", &t)
}

/// Fig. 10: end-to-end TPOT speedup over SpecInfer across model pairs ×
/// datasets, measured on the real stack, plus the A100/A40 paper-scale
/// simulation.
pub fn fig10(lab: &mut Lab) -> crate::Result<()> {
    let n = lab.opts.prompts().min(3);
    let max_new = lab.opts.max_new();
    let engines = ["specinfer", "sequoia", "vllmspec", "yggdrasil"];
    let mut t = Table::new(&["pair", "dataset", "engine", "AAL", "tpot_ms", "speedup_vs_specinfer"])
        .with_title("Fig. 10 — end-to-end TPOT speedup over SpecInfer (measured)");
    let pairs: &[(&str, &str)] =
        if lab.opts.quick { &super::PAIRS[..1] } else { &super::PAIRS[..] };
    let datasets: &[&str] = if lab.opts.quick { &["c4s"] } else { &["c4s", "wiki", "cnnd"] };
    for &(dft, tgt) in pairs {
        for &ds in datasets {
            let mut base_tpot = None;
            for name in engines {
                let mut e = lab.engine(name, (dft, tgt))?;
                let r = lab.run(e.as_mut(), ds, n, max_new)?;
                if name == "specinfer" {
                    base_tpot = Some(r.tpot);
                }
                t.row(&[
                    format!("{dft}->{tgt}"),
                    ds.to_string(),
                    name.to_string(),
                    format!("{:.2}", r.aal),
                    format!("{:.2}", r.tpot * 1e3),
                    format!("{:.2}x", base_tpot.unwrap() / r.tpot),
                ]);
            }
        }
    }
    lab.emit("fig10_measured", &t)?;

    // Paper-scale simulation: Llama-2 pairs on A100/A40.
    let mut ts = Table::new(&["gpu", "pair", "dataset", "engine", "AAL", "tpot_ms", "speedup_vs_specinfer"])
        .with_title("Fig. 10 — A100/A40 simulation (roofline model + measured acceptance)");
    let sim_pairs: [(&str, (&str, &str), LlmDims, LlmDims); 4] = [
        ("68m->7b", ("dft-xs", "tgt-sm"), llama_68m(), llama2_7b()),
        ("160m->7b", ("dft-sm", "tgt-sm"), llama_160m(), llama2_7b()),
        ("68m->13b", ("dft-xs", "tgt-lg"), llama_68m(), llama2_13b()),
        ("160m->13b", ("dft-sm", "tgt-lg"), llama_160m(), llama2_13b()),
    ];
    for gpu in [&A100, &A40] {
        for (label, pair, dft, tgt) in &sim_pairs {
            for &ds in datasets {
                let ranks = lab.rank_model(*pair, ds)?;
                let rows = simulate_fig10_row(gpu, dft, tgt, &ranks);
                for (engine, r) in rows {
                    ts.row(&[
                        gpu.name.to_string(),
                        label.to_string(),
                        ds.to_string(),
                        engine.to_string(),
                        format!("{:.2}", r.0),
                        format!("{:.3}", r.1 * 1e3),
                        format!("{:.2}x", r.2),
                    ]);
                }
            }
        }
    }
    lab.emit("fig10_simulated", &ts)
}

/// (engine, (aal, tpot, speedup-vs-specinfer)) rows for one simulated cell.
fn simulate_fig10_row(
    gpu: &GpuProfile,
    dft: &LlmDims,
    tgt: &LlmDims,
    ranks: &[f64],
) -> Vec<(&'static str, (f64, f64, f64))> {
    let cpu = 3e-4; // CPU bookkeeping per iteration (paper's Xeon E5)
    let compiled = simulator::pair_latency_model(dft, tgt, gpu, 256, true, cpu);
    let eager = simulator::pair_latency_model(dft, tgt, gpu, 256, false, cpu * 4.0);
    let sim_c = SpecSim::new(compiled, ranks.to_vec());
    let sim_e = SpecSim::new(eager, ranks.to_vec());

    // SpecInfer: eager runtime, static 4-ary depth-4 tree.
    let specinfer = sim_e.score_shape(&TreeShape::k_ary(4, 4, 63));
    // Sequoia: compiled draft, static optimal tree for the rank model.
    let sequoia = sim_c.score_shape(&TreeShape::sequoia(ranks, 32));
    // vLLM-Spec: compiled sequence, depth 5.
    let vllm = sim_c.score_shape(&TreeShape::sequence(5));
    // Yggdrasil: compiled + Eq.3-optimal EGT + scheduling overlap (the
    // CPU term is hidden behind the AOT stages).
    let mut ygg_lat = sim_c.lat.clone();
    ygg_lat.cpu_overhead *= 0.25;
    let ygg_sim = SpecSim::new(ygg_lat, ranks.to_vec());
    let (_, _, _, ygg) = ygg_sim.best_egt(8, 8, 64);

    let base = specinfer.tpot;
    vec![
        ("specinfer", (specinfer.aal, specinfer.tpot, 1.0)),
        ("sequoia", (sequoia.aal, sequoia.tpot, base / sequoia.tpot)),
        ("vllmspec", (vllm.aal, vllm.tpot, base / vllm.tpot)),
        ("yggdrasil", (ygg.aal, ygg.tpot, base / ygg.tpot)),
    ]
}

/// Fig. 11: (a) AAL vs verification budget per tree structure (measured);
/// (b) theoretical Eq. 3 speedup per structure (simulated A100 latencies +
/// measured acceptance).
pub fn fig11(lab: &mut Lab) -> crate::Result<()> {
    let n = lab.opts.prompts().min(2);
    let max_new = lab.opts.max_new();
    let budgets: &[usize] = if lab.opts.quick { &[8, 32] } else { &[4, 8, 16, 32, 64] };

    let mut ta = Table::new(&["structure", "budget", "AAL"])
        .with_title("Fig. 11a — AAL vs verification budget (measured, wiki)");
    for &b in budgets {
        let mut configs: Vec<(String, EngineConfig)> = Vec::new();
        let mut seq = EngineConfig::preset_vllmspec((b - 1).min(8));
        seq.max_verify = b;
        configs.push(("sequence".into(), seq));
        let mut kary = EngineConfig::preset_specinfer(2, 6, b);
        kary.compiled = true;
        configs.push(("kary-2".into(), kary));
        let mut sqa = EngineConfig::preset_sequoia(b);
        sqa.max_verify = b;
        configs.push(("sequoia".into(), sqa));
        for w in [2usize, 4, 8] {
            let mut egt = EngineConfig::default();
            egt.use_depth_predictor = false;
            egt.objective = Objective::Aal;
            egt.max_width = w;
            egt.max_verify = b;
            configs.push((format!("egt-w{w}"), egt));
        }
        for (name, mut cfg) in configs {
            cfg.drafter = "dft-xs".into();
            cfg.target = "tgt-sm".into();
            let mut e = lab.spec(cfg)?;
            let r = lab.run(&mut e, "wiki", n, max_new)?;
            ta.row(&[name, b.to_string(), format!("{:.3}", r.aal)]);
        }
    }
    lab.emit("fig11a", &ta)?;

    // (b) theoretical speedup under Eq. 3 with A100 roofline latencies.
    let ranks = lab.rank_model(("dft-xs", "tgt-sm"), "wiki")?;
    let lat = simulator::pair_latency_model(&llama_68m(), &llama2_7b(), &A100, 256, true, 1e-4);
    let sim = SpecSim::new(lat, ranks);
    let mut tb = Table::new(&["structure", "budget", "theoretical_speedup_eq3"])
        .with_title("Fig. 11b — theoretical Eq. 3 speedup (A100 roofline)");
    let vanilla = sim.score_vanilla().tpot;
    for &b in budgets {
        let shapes: Vec<(String, TreeShape)> = vec![
            ("sequence".into(), TreeShape::sequence((b - 1).min(8))),
            ("kary-2".into(), TreeShape::k_ary(2, 6, b - 1)),
            ("sequoia".into(), TreeShape::sequoia(&sim.accept_by_rank, b - 1)),
        ];
        for (name, shape) in shapes {
            let r = sim.score_shape(&shape);
            tb.row(&[name, b.to_string(), format!("{:.2}x", vanilla / r.tpot)]);
        }
        for w in [2usize, 4, 8] {
            let r = sim.score_egt(6, w, b);
            tb.row(&[format!("egt-w{w}"), b.to_string(), format!("{:.2}x", vanilla / r.tpot)]);
        }
    }
    lab.emit("fig11b", &tb)
}

/// Fig. 12: the O1–O5 optimization breakdown (cumulative, measured).
pub fn fig12(lab: &mut Lab) -> crate::Result<()> {
    let n = lab.opts.prompts().min(3);
    let max_new = lab.opts.max_new();
    let base = |lab: &mut Lab| -> EngineConfig {
        let _ = &lab;
        let mut c = EngineConfig::default();
        c.drafter = "dft-xs".into();
        c.target = "tgt-sm".into();
        c
    };

    let mut o1 = base(lab); // latency-optimal tree speculation only
    o1.compiled = false;
    o1.prune = false;
    o1.schedule = SchedulePlan::Sequential;
    o1.use_depth_predictor = false;

    let mut o2 = o1.clone(); // + graph compilation
    o2.compiled = true;

    let mut o3 = o2.clone(); // + verification-width pruning
    o3.prune = true;

    let mut o4 = o3.clone(); // + stage-based scheduling
    o4.schedule = SchedulePlan::ProfileSearch;

    let mut o5 = o4.clone(); // + depth predictor
    o5.use_depth_predictor = true;

    let mut t = Table::new(&["config", "AAL", "tpot_ms", "cumulative_speedup", "step_gain"])
        .with_title("Fig. 12 — optimization breakdown on dft-xs → tgt-sm (measured, c4s)");
    let mut prev: Option<f64> = None;
    let mut first: Option<f64> = None;
    // Train a quick predictor for O5 from O4's samples.
    let mut predictor = None;
    for (name, cfg) in [
        ("O1 tree+objective", o1),
        ("O2 +compiled", o2),
        ("O3 +prune", o3),
        ("O4 +schedule", o4),
        ("O5 +predictor", o5),
    ] {
        let mut dec = lab.spec(cfg)?;
        if name.contains("predictor") {
            dec.set_predictor(predictor.take());
        }
        let r = lab.run(&mut dec, "c4s", n, max_new)?;
        if name.contains("schedule") {
            // Harvest training data for the predictor from this config.
            let samples: Vec<crate::predictor::DepthSample> = dec
                .take_depth_samples()
                .into_iter()
                .map(|(hidden, accepted)| crate::predictor::DepthSample { hidden, accepted })
                .collect();
            if samples.len() >= 8 {
                let dim = samples[0].hidden.len();
                let mut p = crate::predictor::DepthPredictor::new(dim, 32, 8, 7);
                p.train(&samples, 6, 1e-3, 3);
                predictor = Some(p);
            }
        }
        let f = *first.get_or_insert(r.tpot);
        let gain = prev.map_or(1.0, |p| p / r.tpot);
        t.row(&[
            name.to_string(),
            format!("{:.2}", r.aal),
            format!("{:.2}", r.tpot * 1e3),
            format!("{:.2}x", f / r.tpot),
            format!("{gain:.2}x"),
        ]);
        prev = Some(r.tpot);
    }
    lab.emit("fig12", &t)
}

/// Fig. 13: EGT parameter sensitivity grid.
pub fn fig13(lab: &mut Lab) -> crate::Result<()> {
    let n = if lab.opts.quick { 1 } else { 2 };
    let max_new = lab.opts.max_new();
    let (ds, ws, vs): (&[usize], &[usize], &[usize]) = if lab.opts.quick {
        (&[2, 8], &[2, 8], &[16, 64])
    } else {
        (&[2, 4, 8], &[2, 4, 8], &[16, 32, 64])
    };
    let mut t = Table::new(&["D_draft", "W_draft", "W_verify", "AAL", "tpot_ms"])
        .with_title("Fig. 13 — EGT parameter sensitivity (measured, c4s)");
    let mut best = (f64::MAX, 0, 0, 0);
    for &d in ds {
        for &w in ws {
            for &v in vs {
                if v <= w {
                    continue;
                }
                let mut cfg = EngineConfig::default();
                cfg.drafter = "dft-xs".into();
                cfg.target = "tgt-sm".into();
                cfg.use_depth_predictor = false;
                cfg.max_depth = d;
                cfg.max_width = w;
                cfg.max_verify = v;
                let mut e = lab.spec(cfg)?;
                let r = lab.run(&mut e, "c4s", n, max_new)?;
                if r.tpot < best.0 {
                    best = (r.tpot, d, w, v);
                }
                t.row(&[
                    d.to_string(),
                    w.to_string(),
                    v.to_string(),
                    format!("{:.2}", r.aal),
                    format!("{:.2}", r.tpot * 1e3),
                ]);
            }
        }
    }
    println!(
        "best static configuration: D={} W={} Wv={} ({:.2} ms/token)",
        best.1,
        best.2,
        best.3,
        best.0 * 1e3
    );
    lab.emit("fig13", &t)
}

/// Fig. 14: speedup-objective (Eq. 3) vs AAL-objective ablation.
pub fn fig14(lab: &mut Lab) -> crate::Result<()> {
    let n = lab.opts.prompts().min(3);
    let max_new = lab.opts.max_new();
    let mut t = Table::new(&["pair", "objective", "AAL", "tpot_ms", "gain_over_aal_obj"])
        .with_title("Fig. 14 — optimizing Eq. 3 vs optimizing AAL (measured, c4s)");
    let pairs: &[(&str, &str)] = if lab.opts.quick { &super::PAIRS[..1] } else { &super::PAIRS[..] };
    for &(dft, tgt) in pairs {
        let mut tpots = Vec::new();
        for obj in [Objective::Aal, Objective::Speedup] {
            let mut cfg = EngineConfig::default();
            cfg.drafter = dft.into();
            cfg.target = tgt.into();
            cfg.use_depth_predictor = false;
            cfg.objective = obj;
            let mut e = lab.spec(cfg)?;
            let r = lab.run(&mut e, "c4s", n, max_new)?;
            tpots.push(r.tpot);
            let gain = if tpots.len() == 2 { tpots[0] / tpots[1] } else { 1.0 };
            t.row(&[
                format!("{dft}->{tgt}"),
                obj.as_str().to_string(),
                format!("{:.2}", r.aal),
                format!("{:.2}", r.tpot * 1e3),
                format!("{gain:.3}x"),
            ]);
        }
    }
    lab.emit("fig14", &t)
}

/// Fig. 15: sampling-temperature sweep, Sequoia vs Yggdrasil.
pub fn fig15(lab: &mut Lab) -> crate::Result<()> {
    let n = lab.opts.prompts().min(2);
    let max_new = lab.opts.max_new();
    let temps: &[f32] = if lab.opts.quick { &[0.0, 0.75] } else { &[0.0, 0.25, 0.5, 0.75, 1.0] };
    let mut t = Table::new(&["temperature", "engine", "AAL", "tpot_ms", "ygg_speedup"])
        .with_title("Fig. 15 — temperature impact (measured, c4s)");
    for &temp in temps {
        let mut results = Vec::new();
        for name in ["sequoia", "yggdrasil"] {
            let mut cfg = match name {
                "sequoia" => EngineConfig::preset_sequoia(32),
                _ => EngineConfig::default(),
            };
            cfg.drafter = "dft-xs".into();
            cfg.target = "tgt-sm".into();
            cfg.sampling.temperature = temp;
            cfg.sampling.seed = 42;
            if name == "yggdrasil" {
                cfg.use_depth_predictor = false;
            }
            let mut e = lab.spec(cfg)?;
            let r = lab.run(&mut e, "c4s", n, max_new)?;
            results.push((name, r));
        }
        let speedup = results[0].1.tpot / results[1].1.tpot;
        for (name, r) in &results {
            t.row(&[
                format!("{temp:.2}"),
                name.to_string(),
                format!("{:.2}", r.aal),
                format!("{:.2}", r.tpot * 1e3),
                if *name == "yggdrasil" { format!("{speedup:.2}x") } else { "-".into() },
            ]);
        }
    }
    lab.emit("fig15", &t)
}

/// Serving: throughput vs per-request latency as concurrent clients grow
/// across the three scheduling regimes — round-robin time-slicing,
/// verify-only cross-session batching (`batched_nodraft`, DESIGN.md §9 /
/// `--no-batch-draft`), and stage-aligned batched drafting (`batched`,
/// DESIGN.md §11, the default). One server (4 session slots) absorbs
/// each client wave; time-to-first-token and queueing delay come from
/// the server's own `done` metrics. The headline check: batched
/// throughput at ≥4 clients clears the round-robin baseline, and
/// batched drafting clears verify-only batching (the drafter stops
/// serializing N× across sessions).
///
/// A second table (`serving_paged.csv`) sweeps a *heterogeneous*
/// short/long prompt mix at fixed total cache capacity, comparing the
/// paged block-granular cache (DESIGN.md §10) against the equal-partition
/// baseline on admitted concurrency, rejection rate, and
/// preemption/resume counts.
pub fn serving(lab: &mut Lab) -> crate::Result<()> {
    use crate::server::{client_wave, ServeOpts, Server, WaveStats};

    const MAX_SESSIONS: usize = 4;
    let max_new = lab.opts.max_new().min(24);
    let prompts = lab.prompts("c4s")?;
    let sweep: &[usize] = if lab.opts.quick { &[1, 2] } else { &[1, 2, 4, 8] };

    // Shrink the tree envelope so four sessions fit the shared cache's
    // per-session quota (capacity/4 slots each); the round-robin baseline
    // runs the same envelope so the comparison isolates scheduling.
    let cfg_for = |batched: bool, batch_draft: bool| {
        let mut cfg = EngineConfig::default();
        cfg.drafter = "dft-xs".into();
        cfg.target = "tgt-sm".into();
        cfg.use_depth_predictor = false;
        cfg.max_depth = 4;
        cfg.max_width = 4;
        cfg.max_verify = 16;
        cfg.batch.enabled = batched;
        cfg.batch.batch_draft = batch_draft;
        cfg.batch.max_sessions = MAX_SESSIONS;
        cfg
    };

    let mut results: Vec<(&str, usize, WaveStats)> = Vec::new();
    for (mode, batched, batch_draft) in [
        ("round_robin", false, false),
        ("batched_nodraft", true, false),
        ("batched", true, true),
    ] {
        let engine = lab.spec(cfg_for(batched, batch_draft))?;
        let srv = Server::spawn(
            "127.0.0.1:0",
            Box::new(engine),
            ServeOpts {
                max_queue: 64,
                max_sessions: MAX_SESSIONS,
                batched,
                ..ServeOpts::default()
            },
        )?;
        for &clients in sweep {
            let w = client_wave(srv.addr, clients, &prompts.prompts, max_new)?;
            results.push((mode, clients, w));
        }
    }

    let mut t = Table::new(&[
        "mode",
        "clients",
        "tok_per_s",
        "e2e_ms_mean",
        "ttft_ms_mean",
        "queue_ms_mean",
        "speedup_vs_rr",
    ])
    .with_title(
        "Serving — round-robin vs verify-only batching vs stage-aligned batched \
         drafting (measured)",
    );
    for (mode, clients, w) in &results {
        let rr = results
            .iter()
            .find(|(m, c, _)| *m == "round_robin" && c == clients)
            .map(|(_, _, w)| w.tok_per_s)
            .unwrap_or(f64::NAN);
        t.row(&[
            mode.to_string(),
            clients.to_string(),
            format!("{:.1}", w.tok_per_s),
            format!("{:.1}", w.e2e_ms_mean),
            format!("{:.1}", w.ttft_ms_mean),
            format!("{:.1}", w.queue_ms_mean),
            format!("{:.2}x", w.tok_per_s / rr),
        ]);
    }
    lab.emit("serving", &t)?;
    serving_paged_sweep(lab)
}

/// Headless mock-engine serving smoke (`--exp serving_mock`, no AOT
/// artifacts needed): the same three-regime sweep as [`serving`] —
/// round-robin vs verify-only batching vs stage-aligned batched
/// drafting — over a drafting-bound [`crate::server::MockStepEngine`]
/// (one simulated verify delay per round, one draft delay per session
/// or per round). CI runs this so round-loop regressions in the
/// continuous-serving scheduler fail fast; it also enforces the
/// batched-draft acceptance bar: ≥ 1.3× verify-only round throughput at
/// 4 drafting-bound clients.
pub fn serving_mock(opts: &super::BenchOpts) -> crate::Result<()> {
    use crate::server::{client_wave, MockStepEngine, ServeOpts, Server};

    let sweep: &[usize] = if opts.quick { &[2, 4] } else { &[1, 2, 4, 8] };
    let prompts: Vec<Vec<u32>> = (0..8).map(|i| vec![1000 * (i + 1) as u32]).collect();
    let mut results: Vec<(&str, usize, f64, f64)> = Vec::new();
    for (mode, batched, batch_draft) in [
        ("round_robin", false, false),
        ("batched_nodraft", true, false),
        ("batched", true, true),
    ] {
        // 4 ms simulated verify per round, 12 ms drafter per session —
        // the drafting-bound regime batched drafting exists for.
        let engine = MockStepEngine::new(4, 2, 10_000).with_draft_stage(12, batch_draft);
        let srv = Server::spawn(
            "127.0.0.1:0",
            Box::new(engine),
            ServeOpts { max_queue: 64, max_sessions: 8, batched, ..ServeOpts::default() },
        )?;
        for &clients in sweep {
            let w = client_wave(srv.addr, clients, &prompts, 16)?;
            results.push((mode, clients, w.tok_per_s, w.e2e_ms_mean));
        }
    }
    let mut t = Table::new(&["mode", "clients", "tok_per_s", "e2e_ms_mean", "speedup_vs_rr"])
        .with_title("Serving smoke — mock engine, drafting-bound round loop (headless)");
    for (mode, clients, tps, e2e) in &results {
        let rr = results
            .iter()
            .find(|(m, c, _, _)| *m == "round_robin" && c == clients)
            .map(|r| r.2)
            .unwrap_or(f64::NAN);
        t.row(&[
            mode.to_string(),
            clients.to_string(),
            format!("{tps:.1}"),
            format!("{e2e:.1}"),
            format!("{:.2}x", tps / rr),
        ]);
    }
    println!("{}", t.to_markdown());
    t.save_csv(&opts.out_dir.join("serving_mock.csv"))?;
    // The acceptance bar, enforced headless so CI catches regressions.
    let at4 = |mode: &str| {
        results.iter().find(|(m, c, _, _)| *m == mode && *c == 4).map(|r| r.2)
    };
    if let (Some(draft), Some(nodraft)) = (at4("batched"), at4("batched_nodraft")) {
        anyhow::ensure!(
            draft >= 1.3 * nodraft,
            "batched-draft serving {draft:.1} tok/s < 1.3x verify-only {nodraft:.1} tok/s \
             at 4 drafting-bound clients"
        );
    }
    Ok(())
}

/// Shared-system-prompt sweep over the cross-request prefix cache
/// (DESIGN.md §12): every client's prompt opens with one shared system
/// prefix (≥ 4 cache blocks long) followed by a distinct per-client
/// suffix — the dominant shape of real serving traffic. One cold client
/// warms the radix trie, then a concurrent wave of clients hits it. The
/// table compares prefix-cache-on vs -off on hit rate, tokens served
/// from cache, warm-request TTFT, and throughput; the machine-
/// independent acceptance bar (≥ 2× fewer prefilled tokens, better warm
/// TTFT, zero confinement violations) is pinned by the mock serving e2e
/// test and the headless [`serving_prefix_mock`] CI smoke.
pub fn serving_prefix(lab: &mut Lab) -> crate::Result<()> {
    use crate::server::{Client, ServeOpts, Server};

    let block_size = 8usize;
    let vocab = lab.rt.spec("dft-xs")?.vocab as u32;
    let sys_len = 4 * block_size; // the shared system prompt: 4 blocks
    let sys: Vec<u32> = (0..sys_len).map(|i| (17 * i as u32 + 3) % vocab).collect();
    let clients = if lab.opts.quick { 4 } else { 5 };
    let suffix_len = 6usize;
    let max_new = if lab.opts.quick { 6 } else { 10 };
    let mk_prompt = |c: usize| -> Vec<u32> {
        let mut p = sys.clone();
        p.extend((0..suffix_len).map(|i| (911 * (c as u32 + 1) + i as u32) % vocab));
        p
    };

    let mut t = Table::new(&[
        "mode",
        "clients",
        "hit_rate",
        "tokens_reused",
        "cached_blocks",
        "evictions",
        "warm_ttft_ms_mean",
        "tok_per_s",
    ])
    .with_title("Serving (prefix) — shared-system-prompt reuse (DESIGN.md §12)");
    for (mode, prefix_on) in [("prefix_off", false), ("prefix_on", true)] {
        let mut cfg = EngineConfig::default();
        cfg.drafter = "dft-xs".into();
        cfg.target = "tgt-sm".into();
        cfg.use_depth_predictor = false;
        cfg.max_depth = 2;
        cfg.max_width = 2;
        cfg.max_verify = 8;
        cfg.batch.enabled = true;
        cfg.batch.paged = true;
        cfg.batch.block_size = block_size;
        cfg.batch.prefix_cache = prefix_on;
        cfg.batch.max_sessions = clients;
        let engine = lab.spec(cfg)?;
        let srv = Server::spawn(
            "127.0.0.1:0",
            Box::new(engine),
            ServeOpts { max_queue: 64, max_sessions: clients, ..ServeOpts::default() },
        )?;
        // Cold warm-up request seeds the trie (or just runs, when off).
        let mut warm = Client::connect(&srv.addr)?;
        let _ = warm.generate(0, &mk_prompt(0), max_new)?;
        // Warm wave: every prompt shares the system prefix.
        let t0 = std::time::Instant::now();
        let addr = srv.addr;
        let handles: Vec<_> = (1..clients)
            .map(|c| {
                let p = mk_prompt(c);
                std::thread::spawn(move || -> crate::Result<(usize, f64)> {
                    let mut cl = Client::connect(&addr)?;
                    let r = cl.generate(c as u64, &p, max_new)?;
                    Ok((r.tokens.len(), r.ttft_ms))
                })
            })
            .collect();
        let mut tokens = 0usize;
        let mut ttft = 0.0f64;
        for h in handles {
            let (tk, tf) = h.join().map_err(|_| anyhow::anyhow!("client panicked"))??;
            tokens += tk;
            ttft += tf;
        }
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        let s = warm.stats()?;
        let lookups = s.u64("prefix_lookups").unwrap_or(0);
        let hits = s.u64("prefix_hits").unwrap_or(0);
        let hit_rate = if lookups > 0 { hits as f64 / lookups as f64 } else { 0.0 };
        t.row(&[
            mode.to_string(),
            clients.to_string(),
            format!("{hit_rate:.2}"),
            s.u64("prefix_tokens_reused").unwrap_or(0).to_string(),
            s.u64("prefix_cached_blocks").unwrap_or(0).to_string(),
            s.u64("prefix_evictions").unwrap_or(0).to_string(),
            format!("{:.1}", ttft / (clients - 1).max(1) as f64),
            format!("{:.1}", tokens as f64 / wall),
        ]);
    }
    lab.emit("serving_prefix", &t)
}

/// Headless mock twin of [`serving_prefix`] (`--exp serving_prefix_mock`,
/// no AOT artifacts): a paged [`crate::server::MockStepEngine`] with the
/// prefix cache on/off serves one cold client then a warm wave sharing a
/// 5-block system prompt, with a per-token simulated prefill cost so
/// TTFT tracks the cached prefix. Enforces the acceptance bar — prefix
/// cache on must prefill ≤ half the tokens of cache-off and improve mean
/// warm TTFT with zero ownership violations — so CI fails fast on
/// regressions.
pub fn serving_prefix_mock(opts: &super::BenchOpts) -> crate::Result<()> {
    use crate::server::{Client, MockStepEngine, ServeOpts, Server};
    use std::sync::atomic::Ordering;

    let block_size = 8usize;
    let sys: Vec<u32> = (0..5 * block_size as u32).map(|i| 3000 + i).collect();
    let clients = 5usize; // 1 cold + 4 warm
    let max_new = 8usize;
    let mk_prompt = |c: usize| -> Vec<u32> {
        let mut p = sys.clone();
        p.extend([9000 + 13 * c as u32, 9001 + 13 * c as u32, 9002 + 13 * c as u32]);
        p
    };

    let mut rows: Vec<(&str, usize, f64, f64)> = Vec::new();
    let mut violations_total = 0usize;
    for (mode, prefix_on) in [("prefix_off", false), ("prefix_on", true)] {
        let mut engine =
            MockStepEngine::with_paged_pool(2, 2, 24 * block_size + 1, block_size)?
                .with_prefill_cost(1000);
        if prefix_on {
            engine = engine.with_prefix_cache();
        }
        let prefilled = engine.prefilled_tokens.clone();
        let violations = engine.violations.clone();
        let srv = Server::spawn(
            "127.0.0.1:0",
            Box::new(engine),
            ServeOpts { max_queue: 64, max_sessions: clients, ..ServeOpts::default() },
        )?;
        // Cold request warms the trie…
        let mut warm = Client::connect(&srv.addr)?;
        let _ = warm.generate(0, &mk_prompt(0), max_new)?;
        // …then the warm wave shares its system prompt.
        let addr = srv.addr;
        let handles: Vec<_> = (1..clients)
            .map(|c| {
                let p = mk_prompt(c);
                std::thread::spawn(move || -> crate::Result<f64> {
                    let mut cl = Client::connect(&addr)?;
                    let r = cl.generate(c as u64, &p, max_new)?;
                    anyhow::ensure!(r.tokens.len() == max_new, "short stream");
                    Ok(r.ttft_ms)
                })
            })
            .collect();
        let mut ttft = 0.0f64;
        for h in handles {
            ttft += h.join().map_err(|_| anyhow::anyhow!("client panicked"))??;
        }
        violations_total += violations.load(Ordering::Relaxed);
        rows.push((
            mode,
            prefilled.load(Ordering::Relaxed),
            ttft / (clients - 1) as f64,
            srv.stats.prefix_tokens_reused.load(Ordering::Relaxed) as f64,
        ));
    }
    let mut t = Table::new(&[
        "mode",
        "clients",
        "prefilled_tokens",
        "warm_ttft_ms_mean",
        "tokens_reused",
    ])
    .with_title("Serving smoke (prefix) — mock shared-system-prompt reuse (headless)");
    for (mode, prefilled, ttft, reused) in &rows {
        t.row(&[
            mode.to_string(),
            clients.to_string(),
            prefilled.to_string(),
            format!("{ttft:.1}"),
            format!("{reused:.0}"),
        ]);
    }
    println!("{}", t.to_markdown());
    t.save_csv(&opts.out_dir.join("serving_prefix_mock.csv"))?;
    // The acceptance bar (machine-independent: token counts are exact,
    // and the 1 ms/token prefill cost gives warm TTFT a ≥ 40 ms edge).
    let (off, on) = (&rows[0], &rows[1]);
    anyhow::ensure!(violations_total == 0, "mask rows escaped their owned/shared blocks");
    anyhow::ensure!(
        off.1 >= 2 * on.1,
        "prefix cache saved too little prefill: {} tokens with cache on vs {} off",
        on.1,
        off.1
    );
    anyhow::ensure!(
        on.2 < off.2,
        "warm TTFT did not improve: {:.1} ms with cache on vs {:.1} ms off",
        on.2,
        off.2
    );
    Ok(())
}

/// Headless head-of-line-blocking smoke (`--exp serving_hol_mock`, no
/// AOT artifacts): three latency-class warm streams run a steady decode
/// wave while one 8×-block-size cold prompt (128 tokens, throughput
/// class) arrives mid-wave. With chunked prefill (DESIGN.md §14) the
/// cold prompt's simulated prefill cost is spread one chunk per round,
/// so the warm streams' p95 inter-token latency must stay within 1.5×
/// the no-long-prompt baseline — the ROADMAP acceptance bar this smoke
/// enforces in CI. A monolithic-prefill phase (chunking off) is
/// reported alongside for contrast, and every stream in every phase
/// must stay bit-exact.
pub fn serving_hol_mock(opts: &super::BenchOpts) -> crate::Result<()> {
    use crate::server::{Client, MockStepEngine, ServeOpts, Server, SloClass};

    let block = 16usize;
    let warm_clients = 3usize;
    let warm_new = 40usize;
    let cold_prompt: Vec<u32> = (0..8 * block as u32).map(|i| 7000 + i).collect();
    let expected = |p: &[u32], n: usize| -> Vec<u32> {
        (0..n).map(|i| p[0].wrapping_add((p.len() - 1 + i) as u32)).collect()
    };

    let mut rows: Vec<(&str, f64, f64, u64)> = Vec::new();
    for (mode, inject, chunk) in
        [("baseline", false, block), ("hol_chunked", true, block), ("hol_monolithic", true, 0)]
    {
        // 10 ms verify rounds; each prefilled token costs 150 µs of
        // simulated device time, so the 128-token cold prompt is a
        // ~19 ms monolithic stall but only ~2.4 ms per 16-token chunk.
        let engine = MockStepEngine::with_paged_pool(10, 2, 64 * block + 1, block)?
            .with_prefill_chunk(chunk)
            .with_prefill_cost(150);
        let srv = Server::spawn(
            "127.0.0.1:0",
            Box::new(engine),
            ServeOpts { max_queue: 64, max_sessions: 4, ..ServeOpts::default() },
        )?;
        let addr = srv.addr;
        let warm: Vec<_> = (0..warm_clients)
            .map(|c| {
                let p = vec![1000 * (c as u32 + 1), 1000 * (c as u32 + 1) + 7];
                let want = expected(&p, warm_new);
                std::thread::spawn(move || -> crate::Result<()> {
                    let mut cl = Client::connect(&addr)?;
                    let r = cl.generate(c as u64, &p, warm_new)?;
                    anyhow::ensure!(r.tokens == want, "warm stream not bit-exact");
                    Ok(())
                })
            })
            .collect();
        let cold = inject.then(|| {
            let p = cold_prompt.clone();
            let want = expected(&p, 4);
            std::thread::spawn(move || -> crate::Result<()> {
                // Mid-wave arrival: the warm streams are in steady-state
                // decode when the long prompt shows up.
                std::thread::sleep(std::time::Duration::from_millis(80));
                let mut cl = Client::connect(&addr)?;
                let r = cl.generate_classed(100, &p, 4, SloClass::Throughput)?;
                anyhow::ensure!(r.tokens == want, "cold stream not bit-exact");
                Ok(())
            })
        });
        for h in warm {
            h.join().map_err(|_| anyhow::anyhow!("warm client panicked"))??;
        }
        if let Some(h) = cold {
            h.join().map_err(|_| anyhow::anyhow!("cold client panicked"))??;
        }
        let snap = srv.stats.snapshot();
        rows.push((mode, snap.itl_ms_p50_latency, snap.itl_ms_p95_latency, snap.prefill_chunks));
    }
    let mut t = Table::new(&["mode", "warm_clients", "itl_ms_p50", "itl_ms_p95", "prefill_chunks"])
        .with_title("Serving smoke (HOL) — chunked prefill vs a mid-wave long prompt (headless)");
    for (mode, p50, p95, chunks) in &rows {
        t.row(&[
            mode.to_string(),
            warm_clients.to_string(),
            format!("{p50:.1}"),
            format!("{p95:.1}"),
            chunks.to_string(),
        ]);
    }
    println!("{}", t.to_markdown());
    t.save_csv(&opts.out_dir.join("serving_hol_mock.csv"))?;
    // The acceptance bar (ROADMAP): a mid-wave long prompt may not
    // degrade warm p95 inter-token latency beyond 1.5× the baseline.
    let (base, hol) = (&rows[0], &rows[1]);
    anyhow::ensure!(
        base.2.is_finite() && hol.2.is_finite(),
        "warm ITL series missing from the stats snapshot"
    );
    anyhow::ensure!(
        hol.2 <= 1.5 * base.2,
        "head-of-line blocking: warm p95 ITL {:.1} ms with a chunked long prompt vs {:.1} ms \
         baseline (> 1.5x)",
        hol.2,
        base.2
    );
    anyhow::ensure!(
        hol.3 >= (cold_prompt.len() / block) as u64,
        "long prompt was not chunked: {} prefill chunks",
        hol.3
    );
    Ok(())
}

/// Headless round-allocator smoke (`--exp serving_alloc_mock`, no AOT
/// artifacts): a mixed wave of easy (q = 0.9) and hard (q = 0.1)
/// sessions runs against the alloc-model [`crate::server::MockStepEngine`],
/// once with the uniform per-session budget split and once with the
/// adaptive greedy allocator (DESIGN.md §15). Each granted verification
/// row costs simulated device time, so concentrating rows on
/// high-acceptance sessions must raise aggregate throughput at
/// equal-or-better p95 inter-token latency — the ROADMAP acceptance bar
/// this smoke enforces in CI. An identical-profiles phase pins the
/// degenerate case: with every session at the same acceptance rate the
/// adaptive streams must match the uniform streams exactly (the
/// schedule-level twin lives in the server's unit tests).
pub fn serving_alloc_mock(opts: &super::BenchOpts) -> crate::Result<()> {
    use crate::server::{Client, MockStepEngine, ServeOpts, Server};

    let easy = 4usize;
    let hard = 4usize;
    let clients = easy + hard;
    let max_new = if opts.quick { 32 } else { 64 };
    // Interleave easy/hard so client_wave's round-robin assignment
    // splits the wave evenly; prompt[0] encodes the session's true
    // acceptance rate as a percentage (90% vs 10%).
    let prompts: Vec<Vec<u32>> = (0..clients)
        .map(|c| {
            if c % 2 == 0 {
                vec![90, 200 + c as u32]
            } else {
                vec![10, 300 + c as u32]
            }
        })
        .collect();

    let mut rows: Vec<(&str, f64, f64, f64, f64, u64, u64)> = Vec::new();
    for (mode, adaptive) in [("uniform", false), ("adaptive", true)] {
        // 1 ms fixed round overhead + 100 µs of simulated device time
        // per granted verification row, 8 rows/session baseline budget.
        let engine = MockStepEngine::new(1, 2, 1 << 20).with_alloc_model(8, 100, adaptive);
        let srv = Server::spawn(
            "127.0.0.1:0",
            Box::new(engine),
            ServeOpts { max_queue: 64, max_sessions: clients, ..ServeOpts::default() },
        )?;
        let w = crate::server::client_wave(srv.addr, clients, &prompts, max_new)?;
        let snap = srv.stats.snapshot();
        rows.push((
            mode,
            w.tok_per_s,
            snap.itl_ms_p95_latency,
            snap.accept_rate_p50,
            snap.accept_rate_p95,
            snap.alloc_budget_total,
            snap.alloc_rounds,
        ));
    }

    // Identical-profiles phase: every session at q = 0.5. The adaptive
    // allocator must degenerate to the uniform water-fill, so each
    // client's stream must be identical across the two modes.
    let flat_new = 24usize;
    let flat_prompts: Vec<Vec<u32>> = (0..4u32).map(|c| vec![50, 400 + c]).collect();
    let mut flat_streams: Vec<Vec<Vec<u32>>> = Vec::new();
    for adaptive in [false, true] {
        let engine = MockStepEngine::new(0, 2, 1 << 20).with_alloc_model(4, 0, adaptive);
        let srv = Server::spawn(
            "127.0.0.1:0",
            Box::new(engine),
            ServeOpts { max_queue: 64, max_sessions: 4, ..ServeOpts::default() },
        )?;
        let addr = srv.addr;
        let handles: Vec<_> = flat_prompts
            .iter()
            .enumerate()
            .map(|(c, p)| {
                let p = p.clone();
                std::thread::spawn(move || -> crate::Result<Vec<u32>> {
                    let mut cl = Client::connect(&addr)?;
                    Ok(cl.generate(c as u64, &p, flat_new)?.tokens)
                })
            })
            .collect();
        let mut streams = Vec::new();
        for h in handles {
            streams.push(h.join().map_err(|_| anyhow::anyhow!("client panicked"))??);
        }
        flat_streams.push(streams);
    }

    let mut t = Table::new(&[
        "mode",
        "clients",
        "tok_per_s",
        "itl_ms_p95",
        "accept_rate_p50",
        "accept_rate_p95",
        "alloc_budget_total",
        "alloc_rounds",
    ])
    .with_title("Serving smoke (alloc) — adaptive vs uniform round budgets (headless)");
    for (mode, tps, p95, a50, a95, budget, rounds) in &rows {
        t.row(&[
            mode.to_string(),
            clients.to_string(),
            format!("{tps:.1}"),
            format!("{p95:.1}"),
            format!("{a50:.3}"),
            format!("{a95:.3}"),
            budget.to_string(),
            rounds.to_string(),
        ]);
    }
    println!("{}", t.to_markdown());
    t.save_csv(&opts.out_dir.join("serving_alloc_mock.csv"))?;

    // The acceptance bars (ROADMAP): adaptive allocation must beat the
    // uniform split on aggregate throughput at equal-or-better p95
    // inter-token latency, the allocator must actually have run, and
    // identical profiles must degenerate to the uniform streams.
    let (uni, ada) = (&rows[0], &rows[1]);
    anyhow::ensure!(
        ada.6 > 0 && uni.6 > 0,
        "the round allocator never resolved a batched round"
    );
    anyhow::ensure!(
        ada.3.is_finite() && ada.4.is_finite(),
        "accept_rate percentiles missing from the stats snapshot"
    );
    anyhow::ensure!(
        ada.1 >= 1.1 * uni.1,
        "adaptive allocation {:.1} tok/s < 1.1x uniform {:.1} tok/s on the mixed wave",
        ada.1,
        uni.1
    );
    anyhow::ensure!(
        uni.2.is_finite() && ada.2 <= 1.15 * uni.2,
        "adaptive p95 ITL {:.1} ms regressed past uniform {:.1} ms",
        ada.2,
        uni.2
    );
    anyhow::ensure!(
        flat_streams[0] == flat_streams[1],
        "identical acceptance profiles did not reproduce the uniform streams"
    );
    Ok(())
}

/// Heterogeneous-prompt sweep at fixed total cache capacity: paged
/// block-granular leasing vs the equal-partition baseline (DESIGN.md
/// §10). Long prompts strand an equal-partition cache — every region
/// must be sized for the longest request — while the paged pool lets
/// block counts follow the actual footprint, admitting more sessions
/// concurrently at the cost of occasional preempt/resume churn.
fn serving_paged_sweep(lab: &mut Lab) -> crate::Result<()> {
    use crate::server::{Client, ServeOpts, Server};
    use std::sync::atomic::Ordering;

    let cap = lab.rt.spec("tgt-sm")?.cache_capacity.min(lab.rt.spec("dft-xs")?.cache_capacity);
    let usable = cap.saturating_sub(1);
    let vocab = lab.rt.spec("dft-xs")?.vocab as u32;
    let max_new = if lab.opts.quick { 6 } else { 10 };
    // Long prompts are sized to overflow an equal-partition region's
    // admission headroom (region minus the tree budget) while fitting
    // comfortably in the shared pool: equal mode must reject them, paged
    // mode serves them alongside the shorts.
    let sessions_eq = 3usize;
    let region = usable / sessions_eq;
    let long_len = region.saturating_sub(16).max(24);
    let short_len = (long_len / 6).max(2);
    let clients = if lab.opts.quick { 4 } else { 6 };
    let mk_prompt = |len: usize, seed: u32| -> Vec<u32> {
        (0..len).map(|i| (seed.wrapping_mul(31).wrapping_add(i as u32 * 7)) % vocab).collect()
    };
    // One long prompt per three clients, shorts in between.
    let prompts: Vec<Vec<u32>> = (0..clients)
        .map(|i| {
            let len = if i % 3 == 0 { long_len } else { short_len };
            mk_prompt(len, i as u32 + 1)
        })
        .collect();

    let mut t = Table::new(&[
        "mode",
        "clients",
        "admitted_peak",
        "rejected",
        "preempted",
        "resumed",
        "completed",
        "tok_per_s",
    ])
    .with_title(
        "Serving (paged) — heterogeneous prompt mix at fixed cache capacity \
         (DESIGN.md §10)",
    );
    for (mode, paged) in [("equal_partition", false), ("paged", true)] {
        let mut cfg = EngineConfig::default();
        cfg.drafter = "dft-xs".into();
        cfg.target = "tgt-sm".into();
        cfg.use_depth_predictor = false;
        cfg.max_depth = 2;
        cfg.max_width = 2;
        cfg.max_verify = 8;
        cfg.batch.enabled = true;
        cfg.batch.paged = paged;
        cfg.batch.max_sessions = sessions_eq;
        cfg.batch.block_size = 16;
        let engine = lab.spec(cfg)?;
        let srv = Server::spawn(
            "127.0.0.1:0",
            Box::new(engine),
            ServeOpts {
                max_queue: 64,
                max_sessions: if paged { clients } else { sessions_eq },
                ..ServeOpts::default()
            },
        )?;
        // Tolerant wave: equal-partition mode is *expected* to reject the
        // long prompts, so per-client errors count instead of failing.
        let t0 = std::time::Instant::now();
        let addr = srv.addr;
        let handles: Vec<_> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let p = p.clone();
                std::thread::spawn(move || -> (usize, bool) {
                    let Ok(mut c) = Client::connect(&addr) else { return (0, false) };
                    match c.generate(i as u64, &p, max_new) {
                        Ok(r) => (r.tokens.len(), true),
                        Err(_) => (0, false),
                    }
                })
            })
            .collect();
        let mut tokens = 0usize;
        let mut completed = 0usize;
        for h in handles {
            let (tk, ok) = h.join().map_err(|_| anyhow::anyhow!("client panicked"))?;
            tokens += tk;
            completed += ok as usize;
        }
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        t.row(&[
            mode.to_string(),
            clients.to_string(),
            srv.stats.peak_sessions.load(Ordering::Relaxed).to_string(),
            srv.stats.rejected.load(Ordering::Relaxed).to_string(),
            srv.stats.preemptions.load(Ordering::Relaxed).to_string(),
            srv.stats.resumes.load(Ordering::Relaxed).to_string(),
            completed.to_string(),
            format!("{:.1}", tokens as f64 / wall),
        ]);
    }
    lab.emit("serving_paged", &t)
}

/// Multi-worker sharded serving smoke (DESIGN.md §16), fully headless.
///
/// Phase A (scaling): a uniform 16-client wave against 1 vs 4 mock
/// workers under serial per-session stepping (`batched: false`) and
/// round-robin placement — N workers divide the serial step budget N
/// ways, so the 4-worker fleet must reach ≥ 3.5× one worker's aggregate
/// throughput, with every client's stream bit-exact against the mock's
/// closed form on both fleet sizes (the single-worker parity gate).
///
/// Phase B (affinity): a clustered-prefix wave — 4 groups sharing a
/// 32-token system prompt — against 4 prefix-cached workers. After a
/// seed pass donates each group's prefix somewhere, cache-aware affinity
/// routing must land followers on their group's worker while round-robin
/// scatters them, showing up as a ≥ 1.5× fleet prefix-hit-rate gap.
pub fn serving_shard_mock(opts: &super::BenchOpts) -> crate::Result<()> {
    use crate::engine::StepEngine;
    use crate::server::{Client, MockStepEngine, RoutingPolicy, ServeOpts, Server};
    use std::time::{Duration, Instant};

    // --- Phase A: uniform wave, 1 worker vs 4 ---------------------------
    let clients = 16usize;
    let max_new = if opts.quick { 40 } else { 64 };
    let prompts: Vec<Vec<u32>> = (0..clients).map(|i| vec![10 + i as u32, 3, 7]).collect();
    let expected = |p: &[u32], n: usize| -> Vec<u32> {
        (0..n).map(|k| p[0].wrapping_add((p.len() - 1 + k) as u32)).collect()
    };
    let mut scale: Vec<(usize, f64)> = Vec::new(); // (workers, tok_per_s)
    for workers in [1usize, 4] {
        let engines: Vec<Box<dyn StepEngine + Send>> = (0..workers)
            .map(|_| Box::new(MockStepEngine::new(3, 1, 1 << 20)) as Box<dyn StepEngine + Send>)
            .collect();
        let srv = Server::spawn_fleet(
            "127.0.0.1:0",
            engines,
            ServeOpts {
                max_queue: 64,
                max_sessions: clients,
                batched: false,
                routing: RoutingPolicy::RoundRobin,
                ..ServeOpts::default()
            },
        )?;
        let addr = srv.addr;
        let t0 = Instant::now();
        let handles: Vec<_> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let p = p.clone();
                std::thread::spawn(move || -> crate::Result<Vec<u32>> {
                    let mut c = Client::connect(&addr)?;
                    Ok(c.generate(i as u64, &p, max_new)?.tokens)
                })
            })
            .collect();
        let mut tokens = 0usize;
        for (i, h) in handles.into_iter().enumerate() {
            let stream = h.join().map_err(|_| anyhow::anyhow!("client panicked"))??;
            anyhow::ensure!(
                stream == expected(&prompts[i], max_new),
                "client {i} stream diverged on the {workers}-worker fleet"
            );
            tokens += stream.len();
        }
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        scale.push((workers, tokens as f64 / wall));
    }

    // --- Phase B: clustered-prefix wave, affinity vs round-robin --------
    let groups = 4usize;
    let per_group = 4usize;
    let prefix_len = 32usize;
    let wave_new = 6usize;
    // Group g's prompt: a 32-token shared prefix (two 16-token blocks)
    // plus a unique 1-token tail per request.
    let clustered = |g: usize, tail: u32| -> Vec<u32> {
        let mut p: Vec<u32> = (0..prefix_len).map(|i| 1000 * (g as u32 + 1) + i as u32).collect();
        p.push(tail);
        p
    };
    let total_requests = groups + groups * per_group;
    let mut hit_rates: Vec<(&str, f64, u64, u64, u64)> = Vec::new();
    for (mode, policy) in
        [("round_robin", RoutingPolicy::RoundRobin), ("affinity", RoutingPolicy::Affinity)]
    {
        let engines: Vec<Box<dyn StepEngine + Send>> = (0..4)
            .map(|_| {
                Ok(Box::new(
                    MockStepEngine::with_paged_pool(1, 2, 4096, 16)?.with_prefix_cache(),
                ) as Box<dyn StepEngine + Send>)
            })
            .collect::<crate::Result<_>>()?;
        let srv = Server::spawn_fleet(
            "127.0.0.1:0",
            engines,
            ServeOpts {
                max_queue: 64,
                max_sessions: 8,
                routing: policy,
                affinity_chunk: 16,
                ..ServeOpts::default()
            },
        )?;
        let mut c = Client::connect(&srv.addr)?;
        // Seed pass: one completed request per group donates its prefix
        // blocks to whichever worker served it. Sequential, so placement
        // and donation order are deterministic under both policies.
        for g in 0..groups {
            let p = clustered(g, 9_000 + g as u32);
            let r = c.generate(g as u64, &p, wave_new)?;
            anyhow::ensure!(r.tokens == expected(&p, wave_new), "seed {g} stream diverged");
        }
        // Clustered wave, group-major order: under round-robin, client i
        // (group i/4) lands on worker i%4, matching its group's seeded
        // worker only on the diagonal; affinity follows the prefix.
        for i in 0..groups * per_group {
            let p = clustered(i / per_group, 7_000 + i as u32);
            let r = c.generate(100 + i as u64, &p, wave_new)?;
            anyhow::ensure!(
                r.tokens == expected(&p, wave_new),
                "wave client {i} stream diverged under {mode} routing"
            );
        }
        // The per-worker prefix gauges flush after the round that finishes
        // a session; wait for every admission's lookup to land.
        let deadline = Instant::now() + Duration::from_secs(5);
        let snap = loop {
            let s = srv.router.fleet_snapshot();
            if s.merged.prefix_lookups >= total_requests as u64 || Instant::now() > deadline {
                break s;
            }
            std::thread::sleep(Duration::from_millis(5));
        };
        anyhow::ensure!(
            snap.merged.prefix_lookups == total_requests as u64,
            "{mode}: expected {total_requests} prefix lookups, saw {}",
            snap.merged.prefix_lookups
        );
        let rate = snap.merged.prefix_hits as f64 / snap.merged.prefix_lookups.max(1) as f64;
        hit_rates.push((
            mode,
            rate,
            snap.affinity_hits,
            snap.fallback_placements,
            snap.steals,
        ));
    }

    let mut t = Table::new(&[
        "phase",
        "mode",
        "workers",
        "requests",
        "tok_per_s",
        "prefix_hit_rate",
        "affinity_hits",
        "fallback",
        "steals",
    ])
    .with_title(
        "Serving smoke (shard) — multi-worker scaling and prefix-affinity \
         routing (headless)",
    );
    for (workers, tps) in &scale {
        t.row(&[
            "scaling".into(),
            "round_robin".into(),
            workers.to_string(),
            clients.to_string(),
            format!("{tps:.1}"),
            "-".into(),
            "0".into(),
            "0".into(),
            "0".into(),
        ]);
    }
    for (mode, rate, aff, fb, steals) in &hit_rates {
        t.row(&[
            "clustered".into(),
            mode.to_string(),
            "4".into(),
            total_requests.to_string(),
            "-".into(),
            format!("{rate:.3}"),
            aff.to_string(),
            fb.to_string(),
            steals.to_string(),
        ]);
    }
    println!("{}", t.to_markdown());
    t.save_csv(&opts.out_dir.join("serving_shard_mock.csv"))?;

    // The acceptance bars (ROADMAP): near-linear scaling on the uniform
    // wave, and cache-aware routing must beat round-robin's fleet prefix
    // hit rate by the paper-motivated margin.
    let (one, four) = (&scale[0], &scale[1]);
    anyhow::ensure!(
        four.1 >= 3.5 * one.1,
        "4-worker fleet {:.1} tok/s < 3.5x one worker's {:.1} tok/s on the uniform wave",
        four.1,
        one.1
    );
    let (rr, aff) = (&hit_rates[0], &hit_rates[1]);
    anyhow::ensure!(
        rr.2 == 0,
        "round-robin placement must never count affinity hits, saw {}",
        rr.2
    );
    anyhow::ensure!(
        aff.1 >= 1.5 * rr.1.max(1e-9),
        "affinity hit rate {:.3} < 1.5x round-robin {:.3} on the clustered wave",
        aff.1,
        rr.1
    );
    anyhow::ensure!(
        aff.2 > 0,
        "affinity routing never matched a prefix summary on the clustered wave"
    );
    Ok(())
}

/// Request-lifecycle tracing smoke (DESIGN.md §17), fully headless.
///
/// Phase A (capture): a 4-client wave against a 2-worker batched mock
/// fleet with the flight recorder on. A live `{"metrics": true}` request
/// must answer with parseable Prometheus text exposition, and after
/// shutdown the per-worker rings must show (a) balanced `request` spans
/// — every admitted uid opens exactly one span, closes it with the same
/// span id, and is bracketed by one `admit` and one `done` instant; (b)
/// every scheduling round as exactly one balanced `round` span per
/// worker; (c) balanced engine stage spans; and (d) a Chrome trace-event
/// export that round-trips through the in-tree JSON parser
/// event-for-event.
///
/// Phase B (overhead): the same wave with the recorder on (default ring)
/// vs off (`--trace-ring 0`), best-of-two walls each. The recorder's
/// mutex pushes are nanoseconds against the mock's millisecond device
/// sleeps, so the measured gap sits well under the 5% acceptance bar;
/// the assertion adds a small absolute slack term so one scheduler
/// hiccup on a ~100 ms wall cannot flake CI.
pub fn serving_trace_mock(opts: &super::BenchOpts) -> crate::Result<()> {
    use crate::engine::StepEngine;
    use crate::server::{Client, MockStepEngine, ServeOpts, Server};
    use crate::trace::{chrome_trace, validate_prometheus, Kind, Name, DEFAULT_RING};
    use crate::util::json::Json;
    use std::collections::BTreeMap;
    use std::time::Instant;

    let clients = 4usize;
    let max_new = if opts.quick { 24 } else { 48 };
    let prompts: Vec<Vec<u32>> = (0..clients).map(|i| vec![20 + i as u32, 3, 7]).collect();

    // 1 ms verify + 1 ms batched draft per round: sleep-dominated, so the
    // overhead phase measures the recorder against realistic stage costs.
    let spawn = |trace_ring: usize| -> crate::Result<Server> {
        let engines: Vec<Box<dyn StepEngine + Send>> = (0..2)
            .map(|_| {
                Box::new(MockStepEngine::new(1, 1, 1 << 20).with_draft_stage(1, true))
                    as Box<dyn StepEngine + Send>
            })
            .collect();
        Server::spawn_fleet(
            "127.0.0.1:0",
            engines,
            ServeOpts {
                max_queue: 16,
                max_sessions: clients,
                trace_ring,
                ..ServeOpts::default()
            },
        )
    };
    let run_wave = |srv: &Server| -> crate::Result<f64> {
        let addr = srv.addr;
        let t0 = Instant::now();
        let handles: Vec<_> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let p = p.clone();
                std::thread::spawn(move || -> crate::Result<usize> {
                    let mut c = Client::connect(&addr)?;
                    Ok(c.generate(i as u64, &p, max_new)?.tokens.len())
                })
            })
            .collect();
        let mut tokens = 0usize;
        for h in handles {
            tokens += h.join().map_err(|_| anyhow::anyhow!("client panicked"))??;
        }
        anyhow::ensure!(
            tokens == clients * max_new,
            "wave produced {tokens} tokens, expected {}",
            clients * max_new
        );
        Ok(t0.elapsed().as_secs_f64())
    };

    // --- Phase A: capture, exposition, and trace invariants -------------
    let srv = spawn(DEFAULT_RING)?;
    let wall_capture = run_wave(&srv)?;
    let mut c = Client::connect(&srv.addr)?;
    let body = c.metrics()?;
    validate_prometheus(&body)?;
    anyhow::ensure!(
        body.contains("ygg_requests_total{worker=\"fleet\"}"),
        "exposition is missing the fleet-aggregated requests counter:\n{body}"
    );
    drop(c);
    // Join the scheduler threads so every in-flight round has closed its
    // span before the rings are read.
    srv.router.shutdown();

    let mut all: Vec<crate::trace::TraceEvent> = Vec::new();
    for w in srv.router.workers() {
        let evs = w.tracer.events();
        // (b) every scheduling round is exactly one balanced span.
        let mut rounds: BTreeMap<u64, (usize, usize)> = BTreeMap::new();
        for e in &evs {
            if e.name == Name::Round {
                let ent = rounds.entry(e.round).or_default();
                match e.kind {
                    Kind::SpanBegin => ent.0 += 1,
                    Kind::SpanEnd => ent.1 += 1,
                    Kind::Instant => {}
                }
            }
        }
        anyhow::ensure!(!rounds.is_empty(), "worker {} traced no rounds", w.id);
        for (r, (b, e)) in &rounds {
            anyhow::ensure!(
                *b == 1 && *e == 1,
                "worker {}: round {r} has {b} begins / {e} ends (want exactly one span)",
                w.id
            );
        }
        // (c) engine stage spans balance (the mock records the draft and
        // packed-verify stages).
        for stage in [Name::TreeDraft, Name::Verify] {
            let b = evs.iter().filter(|e| e.name == stage && e.kind == Kind::SpanBegin).count();
            let e = evs.iter().filter(|e| e.name == stage && e.kind == Kind::SpanEnd).count();
            anyhow::ensure!(
                b == e && b > 0,
                "worker {}: stage {} spans unbalanced ({b} begins / {e} ends)",
                w.id,
                stage.as_str()
            );
        }
        all.extend(evs);
    }

    // (a) balanced request lifecycles: one span pair + one admit + one
    // done per admitted uid, with matching span ids and ordered stamps.
    #[derive(Default)]
    struct ReqTrace {
        begins: usize,
        ends: usize,
        admits: usize,
        dones: usize,
        begin_span: u32,
        end_span: u32,
        admit_us: u64,
        done_us: u64,
    }
    let mut by_uid: BTreeMap<u64, ReqTrace> = BTreeMap::new();
    for e in &all {
        let t = by_uid.entry(e.uid).or_default();
        match (e.name, e.kind) {
            (Name::Request, Kind::SpanBegin) => {
                t.begins += 1;
                t.begin_span = e.span;
            }
            (Name::Request, Kind::SpanEnd) => {
                t.ends += 1;
                t.end_span = e.span;
            }
            (Name::Admit, _) => {
                t.admits += 1;
                t.admit_us = e.t_us;
            }
            (Name::Done, _) => {
                t.dones += 1;
                t.done_us = e.t_us;
            }
            _ => {}
        }
    }
    let traced: Vec<(&u64, &ReqTrace)> =
        by_uid.iter().filter(|(uid, _)| **uid != 0).collect();
    anyhow::ensure!(
        traced.len() == clients,
        "expected {clients} traced requests, saw {}",
        traced.len()
    );
    for (uid, t) in traced {
        anyhow::ensure!(
            t.begins == 1 && t.ends == 1 && t.admits == 1 && t.dones == 1,
            "uid {uid}: request span/admit/done counts ({}, {}, {}, {}) — want 1 each",
            t.begins,
            t.ends,
            t.admits,
            t.dones
        );
        anyhow::ensure!(
            t.begin_span == t.end_span,
            "uid {uid}: request span ids diverge ({} vs {})",
            t.begin_span,
            t.end_span
        );
        anyhow::ensure!(
            t.admit_us <= t.done_us,
            "uid {uid}: done stamped before admit"
        );
    }

    // (d) the Chrome export round-trips through the in-tree parser.
    let doc = chrome_trace(&all);
    let back = Json::parse(&doc.to_string())?;
    let evs = back.arr("traceEvents")?;
    anyhow::ensure!(
        evs.len() == all.len(),
        "chrome trace export dropped events ({} of {})",
        evs.len(),
        all.len()
    );

    // --- Phase B: recorder on/off overhead ------------------------------
    let mut wall_on = wall_capture;
    let mut wall_off = f64::MAX;
    for _ in 0..2 {
        let on = spawn(DEFAULT_RING)?;
        wall_on = wall_on.min(run_wave(&on)?);
        let off = spawn(0)?;
        wall_off = wall_off.min(run_wave(&off)?);
        anyhow::ensure!(
            off.router.workers().iter().all(|w| w.tracer.pushed() == 0),
            "a zero-capacity ring must record nothing"
        );
    }
    let overhead = wall_on / wall_off.max(1e-9) - 1.0;

    let mut t = Table::new(&["phase", "workers", "requests", "events", "wall_s", "overhead_pct"])
        .with_title(
            "Serving smoke (trace) — flight recorder, Chrome export, and \
             Prometheus exposition (headless)",
        );
    t.row(&[
        "capture".into(),
        "2".into(),
        clients.to_string(),
        all.len().to_string(),
        format!("{wall_capture:.3}"),
        "-".into(),
    ]);
    t.row(&[
        "overhead".into(),
        "2".into(),
        clients.to_string(),
        "-".into(),
        format!("{wall_on:.3}"),
        format!("{:.2}", overhead * 100.0),
    ]);
    println!("{}", t.to_markdown());
    t.save_csv(&opts.out_dir.join("serving_trace_mock.csv"))?;

    // The acceptance bar: tracing must stay within 5% of the untraced
    // round loop (plus 25 ms absolute slack for scheduler jitter on
    // sub-second walls).
    anyhow::ensure!(
        wall_on <= wall_off * 1.05 + 0.025,
        "recorder-on wall {wall_on:.3}s exceeds 5% over recorder-off {wall_off:.3}s"
    );
    Ok(())
}
