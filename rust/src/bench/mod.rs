//! Figure harness: regenerates every table and figure of the paper's
//! evaluation (§7) — see DESIGN.md §5 for the experiment index.
//!
//! [`Lab`] owns the shared setup (runtime with all four models, per-pair
//! latency profiles, per-(pair, dataset) acceptance calibrations) and the
//! generation helpers; [`exps`] implements one function per table/figure.
//! `yggdrasil figures --exp fig10` (or `all`) drives them; every
//! experiment prints Markdown tables and writes CSV under `results/`.

pub mod exps;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::baselines::{build_engine, VanillaEngine};
use crate::config::EngineConfig;
use crate::corpus::PromptSet;
use crate::engine::{profiling, Engine, SpecDecoder};
use crate::metrics::{Recorder, Table};
use crate::objective::LatencyModel;
use crate::runtime::Runtime;

/// The paper's (drafter, target) model pairs (the Fig. 10 grid).
pub const PAIRS: [(&str, &str); 4] = [
    ("dft-xs", "tgt-sm"),
    ("dft-sm", "tgt-sm"),
    ("dft-xs", "tgt-lg"),
    ("dft-sm", "tgt-lg"),
];

/// Harness options.
#[derive(Debug, Clone)]
pub struct BenchOpts {
    /// AOT artifact bundle directory.
    pub artifacts_dir: PathBuf,
    /// Where experiment CSVs are written.
    pub out_dir: PathBuf,
    /// Quick mode: fewer prompts / shorter generations (CI).
    pub quick: bool,
    /// Base RNG seed for the workload.
    pub seed: u64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        Self {
            artifacts_dir: PathBuf::from("artifacts"),
            out_dir: PathBuf::from("results"),
            quick: false,
            seed: 0,
        }
    }
}

impl BenchOpts {
    /// Prompts per experiment cell.
    pub fn prompts(&self) -> usize {
        if self.quick {
            2
        } else {
            5
        }
    }

    /// Generation length per prompt.
    pub fn max_new(&self) -> usize {
        if self.quick {
            24
        } else {
            48
        }
    }
}

/// Aggregated result of running one engine over a prompt set.
#[derive(Debug, Clone)]
pub struct RunSummary {
    /// Engine label (for table rows).
    pub engine: String,
    /// Mean average accepted length.
    pub aal: f64,
    /// Mean per-token latency (seconds).
    pub tpot: f64,
    /// Mean per-iteration latency (seconds).
    pub step_latency: f64,
    /// Total tokens generated across the prompts.
    pub tokens: usize,
    /// Merged per-stage recorder across the runs.
    pub recorder: Recorder,
}

/// Shared experiment state.
pub struct Lab {
    /// Device runtime with all four models loaded.
    pub rt: Runtime,
    /// Harness options.
    pub opts: BenchOpts,
    lat: HashMap<(String, String), LatencyModel>,
    prompts: HashMap<String, PromptSet>,
    /// Measured acceptance-by-rank per (drafter, target, dataset).
    ranks: HashMap<(String, String, String), Vec<f64>>,
}

impl Lab {
    /// Loads the runtime over the artifact bundle.
    pub fn new(opts: BenchOpts) -> crate::Result<Self> {
        let rt = Runtime::load(&opts.artifacts_dir, &["dft-xs", "dft-sm", "tgt-sm", "tgt-lg"])?;
        Ok(Self { rt, opts, lat: HashMap::new(), prompts: HashMap::new(), ranks: HashMap::new() })
    }

    /// Cached latency model for a (drafter, target) pair.
    pub fn latency(&mut self, drafter: &str, target: &str) -> crate::Result<LatencyModel> {
        let key = (drafter.to_string(), target.to_string());
        if let Some(l) = self.lat.get(&key) {
            return Ok(l.clone());
        }
        let profile_file = self.opts.artifacts_dir.join("profile.json");
        let reps = if self.opts.quick { 2 } else { 5 };
        let l = profiling::load_or_profile(&self.rt, drafter, target, Some(&profile_file), reps)?;
        self.lat.insert(key, l.clone());
        Ok(l)
    }

    /// Cached prompt set for a dataset.
    pub fn prompts(&mut self, dataset: &str) -> crate::Result<PromptSet> {
        if let Some(p) = self.prompts.get(dataset) {
            return Ok(p.clone());
        }
        let p = PromptSet::load(&self.opts.artifacts_dir, dataset)?;
        self.prompts.insert(dataset.to_string(), p.clone());
        Ok(p)
    }

    /// Runs `engine` over the first `n` prompts of `dataset`; averages.
    pub fn run(
        &mut self,
        engine: &mut dyn Engine,
        dataset: &str,
        n: usize,
        max_new: usize,
    ) -> crate::Result<RunSummary> {
        let ps = self.prompts(dataset)?;
        let mut aal = 0.0;
        let mut tpot = 0.0;
        let mut step = 0.0;
        let mut tokens = 0usize;
        let mut recorder = Recorder::new();
        let n = n.min(ps.len()).max(1);
        // Warm-up generation: triggers lazy graph compilation for every
        // width this engine uses so measured runs are compile-free.
        let _ = engine.generate(&ps.prompts[0], 4)?;
        for p in ps.prompts.iter().take(n) {
            let g = engine.generate(p, max_new)?;
            aal += g.aal();
            tpot += g.tpot();
            step += g.step_latency();
            tokens += g.tokens.len();
            recorder.merge(&g.recorder);
        }
        Ok(RunSummary {
            engine: engine.name(),
            aal: aal / n as f64,
            tpot: tpot / n as f64,
            step_latency: step / n as f64,
            tokens,
            recorder,
        })
    }

    /// Builds a named baseline engine for a pair.
    pub fn engine(&mut self, name: &str, pair: (&str, &str)) -> crate::Result<Box<dyn Engine>> {
        let lat = self.latency(pair.0, pair.1)?;
        build_engine(&self.rt, name, pair, &lat)
    }

    /// Builds a SpecDecoder from an explicit config.
    pub fn spec(&mut self, cfg: EngineConfig) -> crate::Result<SpecDecoder> {
        let lat = self.latency(&cfg.drafter, &cfg.target)?;
        Ok(SpecDecoder::new(&self.rt, cfg, lat, None))
    }

    /// Builds the non-speculative floor engine.
    pub fn vanilla(&self, target: &str) -> VanillaEngine {
        VanillaEngine::new(&self.rt, target, true)
    }

    /// Measured acceptance-by-rank vector for a pair on a dataset
    /// (calibrated once with a short Yggdrasil run, then cached).
    pub fn rank_model(
        &mut self,
        pair: (&str, &str),
        dataset: &str,
    ) -> crate::Result<Vec<f64>> {
        let key = (pair.0.to_string(), pair.1.to_string(), dataset.to_string());
        if let Some(r) = self.ranks.get(&key) {
            return Ok(r.clone());
        }
        let mut cfg = EngineConfig::default();
        cfg.drafter = pair.0.into();
        cfg.target = pair.1.into();
        cfg.use_depth_predictor = false;
        let mut dec = self.spec(cfg)?;
        let n = if self.opts.quick { 1 } else { 2 };
        let max_new = self.opts.max_new();
        let ps = self.prompts(dataset)?;
        for p in ps.prompts.iter().take(n) {
            let _ = dec.generate(p, max_new)?;
        }
        let ranks = dec.stats().accept_by_rank;
        self.ranks.insert(key, ranks.clone());
        Ok(ranks)
    }

    /// Saves a table as CSV under the results dir and prints it.
    pub fn emit(&self, name: &str, table: &Table) -> crate::Result<()> {
        println!("{}", table.to_markdown());
        table.save_csv(&self.out_csv(name))?;
        Ok(())
    }

    /// CSV output path for an experiment.
    pub fn out_csv(&self, name: &str) -> PathBuf {
        self.opts.out_dir.join(format!("{name}.csv"))
    }
}

/// Returns true when artifacts exist (experiments are skipped otherwise).
pub fn artifacts_available(dir: &Path) -> bool {
    dir.join("manifest.json").exists() && dir.join("dft-xs.weights.bin").exists() && dir.join("tgt-lg.weights.bin").exists()
}

/// Every experiment name `--exp` accepts (also what `--exp all` runs).
/// EXPERIMENTS.md's inventory table lists exactly these names — a unit
/// test parses that table and fails on drift in either direction.
pub const EXPERIMENTS: [&str; 18] = [
    "table1", "fig4", "fig5", "fig6", "fig10", "fig11", "fig12", "fig13", "fig14", "fig15",
    "serving", "serving_mock", "serving_prefix", "serving_prefix_mock", "serving_hol_mock",
    "serving_alloc_mock", "serving_shard_mock", "serving_trace_mock",
];

/// Experiments that run without the AOT artifact bundle (mock-engine
/// smokes CI runs headless).
const ARTIFACT_FREE: [&str; 6] = [
    "serving_mock",
    "serving_prefix_mock",
    "serving_hol_mock",
    "serving_alloc_mock",
    "serving_shard_mock",
    "serving_trace_mock",
];

/// Runs one experiment (or `all`) by name. Artifact-backed experiments
/// require `make artifacts`; artifact-free ones (see [`ARTIFACT_FREE`])
/// run anywhere, which is what lets CI smoke the serving round loop
/// headless.
pub fn run_experiment(name: &str, opts: BenchOpts) -> crate::Result<()> {
    let list: Vec<&str> = if name == "all" { EXPERIMENTS.to_vec() } else { vec![name] };
    std::fs::create_dir_all(&opts.out_dir)?;
    let needs_artifacts = list.iter().any(|e| !ARTIFACT_FREE.contains(e));
    let mut lab = if needs_artifacts {
        anyhow::ensure!(
            artifacts_available(&opts.artifacts_dir),
            "artifacts not built — run `make artifacts` (only {:?} run without)",
            ARTIFACT_FREE
        );
        Some(Lab::new(opts.clone())?)
    } else {
        None
    };
    for exp in list {
        println!("\n================ {exp} ================\n");
        if exp == "serving_mock" {
            exps::serving_mock(&opts)?;
            continue;
        }
        if exp == "serving_prefix_mock" {
            exps::serving_prefix_mock(&opts)?;
            continue;
        }
        if exp == "serving_hol_mock" {
            exps::serving_hol_mock(&opts)?;
            continue;
        }
        if exp == "serving_alloc_mock" {
            exps::serving_alloc_mock(&opts)?;
            continue;
        }
        if exp == "serving_shard_mock" {
            exps::serving_shard_mock(&opts)?;
            continue;
        }
        if exp == "serving_trace_mock" {
            exps::serving_trace_mock(&opts)?;
            continue;
        }
        // Typed guard rather than a panic: if the artifact-free list and
        // this dispatch ever drift, the CLI errors instead of crashing.
        let Some(lab) = lab.as_mut() else {
            anyhow::bail!("experiment '{exp}' requires artifacts — run `make artifacts`");
        };
        match exp {
            "table1" => exps::table1(lab)?,
            "fig4" => exps::fig4(lab)?,
            "fig5" => exps::fig5(lab)?,
            "fig6" => exps::fig6(lab)?,
            "fig10" => exps::fig10(lab)?,
            "fig11" => exps::fig11(lab)?,
            "fig12" => exps::fig12(lab)?,
            "fig13" => exps::fig13(lab)?,
            "fig14" => exps::fig14(lab)?,
            "fig15" => exps::fig15(lab)?,
            "serving" => exps::serving(lab)?,
            "serving_prefix" => exps::serving_prefix(lab)?,
            other => anyhow::bail!("unknown experiment '{other}'"),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// EXPERIMENTS.md's inventory table and the `--exp` registry must
    /// name exactly the same experiments (the docs-drift guard).
    #[test]
    fn experiments_md_matches_exp_registry() {
        let md = include_str!(concat!(env!("CARGO_MANIFEST_DIR"), "/../EXPERIMENTS.md"));
        let inventory = md
            .split("## Inventory")
            .nth(1)
            .expect("EXPERIMENTS.md has an '## Inventory' section")
            .split("\n## ")
            .next()
            .unwrap();
        let mut documented: Vec<&str> = Vec::new();
        for line in inventory.lines() {
            // Table rows: `| `name` | ... ` — first backticked cell.
            let Some(rest) = line.strip_prefix("| `") else { continue };
            let Some(name) = rest.split('`').next() else { continue };
            documented.push(name);
        }
        assert!(!documented.is_empty(), "no experiment rows parsed from EXPERIMENTS.md");
        for name in &documented {
            assert!(
                EXPERIMENTS.contains(name),
                "EXPERIMENTS.md documents '{name}' but --exp does not accept it"
            );
        }
        for name in EXPERIMENTS {
            assert!(
                documented.contains(&name),
                "--exp accepts '{name}' but EXPERIMENTS.md does not document it"
            );
        }
    }
}
