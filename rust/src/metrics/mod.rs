//! Latency metrics: timers, histograms, and experiment tables.
//!
//! Every engine step records per-stage wall time into a [`Recorder`]; the
//! benchmark harness renders [`Table`]s in both Markdown (for
//! EXPERIMENTS.md) and CSV (for plotting). Percentiles come from an
//! exact sorted-sample implementation — sample counts here are small
//! (thousands), so there is no need for sketches.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::time::Instant;

/// A named series of f64 samples (seconds, ratios, counts…).
#[derive(Debug, Default)]
pub struct Series {
    samples: Vec<f64>,
    /// Retention bound set by [`Series::record_windowed`]; `None` means
    /// the series keeps its full history. Combined on merge — see
    /// [`Recorder::merge`].
    window: Option<usize>,
    /// Scratch for the percentile selection, reused across calls so
    /// repeated percentile queries stop allocating once it has grown to
    /// the series length.
    scratch: std::sync::Mutex<Vec<f64>>,
}

impl Clone for Series {
    fn clone(&self) -> Self {
        // The scratch is a cache, not state: clones start cold.
        Self {
            samples: self.samples.clone(),
            window: self.window,
            scratch: std::sync::Mutex::new(Vec::new()),
        }
    }
}

impl Series {
    /// Appends one sample.
    pub fn record(&mut self, x: f64) {
        self.samples.push(x);
    }

    /// Records keeping only the most recent `window` samples — for
    /// indefinitely-running consumers (the serving stats) where an
    /// unbounded series would be a slow leak and percentile scans over
    /// the full history would grow without bound. The bound sticks to
    /// the series (latest call wins) so merges can combine retention.
    pub fn record_windowed(&mut self, x: f64, window: usize) {
        self.window = Some(window);
        self.samples.push(x);
        if self.samples.len() > window {
            let excess = self.samples.len() - window;
            self.samples.drain(..excess);
        }
    }

    /// The retention bound, if [`Series::record_windowed`] (or a merge
    /// of windowed series) set one.
    pub fn window(&self) -> Option<usize> {
        self.window
    }

    /// Sample count.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True when no samples were recorded.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Sum of all samples.
    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }

    /// Mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.sum() / self.samples.len() as f64
    }

    /// Exact nearest-rank percentile, `p` in [0, 100] (NaN when empty).
    ///
    /// Selects the rank with `select_nth_unstable_by` over a reused
    /// scratch buffer — O(n) per query instead of the previous
    /// clone-and-full-sort O(n log n), and allocation-free once the
    /// scratch has grown to the series length. The rank convention is
    /// unchanged: index `round(p/100 · (n-1))` of the sorted samples.
    pub fn percentile(&self, p: f64) -> f64 {
        let n = self.samples.len();
        if n == 0 {
            return f64::NAN;
        }
        let idx = (((p / 100.0) * (n - 1) as f64).round() as usize).min(n - 1);
        let mut scratch = self.scratch.lock().unwrap();
        scratch.clear();
        scratch.extend_from_slice(&self.samples);
        let (_, nth, _) =
            scratch.select_nth_unstable_by(idx, |a, b| a.partial_cmp(b).unwrap());
        *nth
    }

    /// Smallest sample (NaN when empty).
    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NAN, f64::min)
    }

    /// Largest sample (NaN when empty).
    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NAN, f64::max)
    }

    /// The raw samples, in record order.
    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// Named collection of series, keyed by stage/metric name.
#[derive(Debug, Clone, Default)]
pub struct Recorder {
    series: BTreeMap<String, Series>,
}

impl Recorder {
    /// An empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Appends `value` to the series named `name`.
    pub fn record(&mut self, name: &str, value: f64) {
        self.series.entry(name.to_string()).or_default().record(value);
    }

    /// Sliding-window variant of [`Recorder::record`] (see
    /// [`Series::record_windowed`]).
    pub fn record_windowed(&mut self, name: &str, value: f64, window: usize) {
        self.series.entry(name.to_string()).or_default().record_windowed(value, window);
    }

    /// Times `f` and records its wall-clock seconds under `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(name, t0.elapsed().as_secs_f64());
        out
    }

    /// The series named `name`, if any.
    pub fn get(&self, name: &str) -> Option<&Series> {
        self.series.get(name)
    }

    /// Mean of a series (NaN when absent).
    pub fn mean(&self, name: &str) -> f64 {
        self.get(name).map(|s| s.mean()).unwrap_or(f64::NAN)
    }

    /// Sum of a series (0 when absent).
    pub fn sum(&self, name: &str) -> f64 {
        self.get(name).map(|s| s.sum()).unwrap_or(0.0)
    }

    /// Percentile of a series (NaN when absent) — the serving stats
    /// surface p50/p99 queueing delay and time-to-first-token from here.
    pub fn percentile(&self, name: &str, p: f64) -> f64 {
        self.get(name).map(|s| s.percentile(p)).unwrap_or(f64::NAN)
    }

    /// Sample count of a series (0 when absent).
    pub fn count(&self, name: &str) -> usize {
        self.get(name).map(|s| s.len()).unwrap_or(0)
    }

    /// All series names, sorted.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.series.keys().map(|s| s.as_str())
    }

    /// Concatenates every series of `other` onto this recorder.
    ///
    /// Pinned semantics (relied on by the fleet snapshot and the
    /// Prometheus exposition): the merge **never drops samples** — a
    /// pooled percentile must rank over every worker's observations,
    /// even when the concatenation exceeds either side's window — and
    /// windowed retention combines rather than clobbers:
    ///
    /// * a fresh (empty, unwindowed) destination adopts the source's
    ///   window;
    /// * two bounded series sum their windows, so the merged retention
    ///   covers both sources' shares of the population;
    /// * an unbounded participant on either side makes the result
    ///   unbounded (its full history must survive future truncation).
    pub fn merge(&mut self, other: &Recorder) {
        for (k, v) in &other.series {
            let e = self.series.entry(k.clone()).or_default();
            e.window = match (e.window, v.window) {
                (None, w) if e.samples.is_empty() => w,
                (Some(a), Some(b)) => Some(a + b),
                _ => None,
            };
            e.samples.extend_from_slice(&v.samples);
        }
    }

    /// Summary table: one row per series with mean/p50/p99.
    pub fn summary(&self) -> Table {
        let mut t = Table::new(&["metric", "n", "mean", "p50", "p99", "max"]);
        for (name, s) in &self.series {
            t.row(&[
                name.clone(),
                s.len().to_string(),
                format!("{:.6}", s.mean()),
                format!("{:.6}", s.percentile(50.0)),
                format!("{:.6}", s.percentile(99.0)),
                format!("{:.6}", s.max()),
            ]);
        }
        t
    }
}

/// A simple experiment table rendered as Markdown or CSV.
#[derive(Debug, Clone)]
pub struct Table {
    /// Column headers.
    pub headers: Vec<String>,
    /// Rows (stringified cells).
    pub rows: Vec<Vec<String>>,
    /// Optional caption rendered above the Markdown form.
    pub title: Option<String>,
}

impl Table {
    /// An empty table with the given column headers.
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    /// Sets the caption (builder-style).
    pub fn with_title(mut self, t: &str) -> Self {
        self.title = Some(t.to_string());
        self
    }

    /// Appends a row; panics on arity mismatch.
    pub fn row<S: ToString>(&mut self, cells: &[S]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.iter().map(|c| c.to_string()).collect());
    }

    /// Renders as a Markdown table.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        if let Some(t) = &self.title {
            let _ = writeln!(out, "### {t}\n");
        }
        let _ = writeln!(out, "| {} |", self.headers.join(" | "));
        let _ = writeln!(out, "|{}|", self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
        for r in &self.rows {
            let _ = writeln!(out, "| {} |", r.join(" | "));
        }
        out
    }

    /// Renders as CSV (headers first).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.join(","));
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.join(","));
        }
        out
    }

    /// Writes the CSV form, creating parent directories.
    pub fn save_csv(&self, path: &std::path::Path) -> crate::Result<()> {
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_stats() {
        let mut s = Series::default();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.record(x);
        }
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 4.0);
        assert_eq!(s.percentile(50.0), 3.0); // nearest-rank on even n
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn recorder_time_measures_something() {
        let mut r = Recorder::new();
        let v = r.time("work", || {
            std::thread::sleep(std::time::Duration::from_millis(2));
            42
        });
        assert_eq!(v, 42);
        assert!(r.mean("work") >= 0.002);
    }

    #[test]
    fn windowed_series_keeps_only_recent_samples() {
        let mut r = Recorder::new();
        for x in 0..10 {
            r.record_windowed("w", x as f64, 4);
        }
        assert_eq!(r.count("w"), 4);
        assert_eq!(r.get("w").unwrap().samples(), &[6.0, 7.0, 8.0, 9.0]);
    }

    #[test]
    fn recorder_percentile_and_count() {
        let mut r = Recorder::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            r.record("lat", x);
        }
        assert_eq!(r.count("lat"), 4);
        assert_eq!(r.percentile("lat", 0.0), 1.0);
        assert_eq!(r.percentile("lat", 100.0), 4.0);
        assert_eq!(r.count("missing"), 0);
        assert!(r.percentile("missing", 50.0).is_nan());
    }

    #[test]
    fn recorder_merge_concatenates() {
        let mut a = Recorder::new();
        a.record("x", 1.0);
        let mut b = Recorder::new();
        b.record("x", 3.0);
        a.merge(&b);
        assert_eq!(a.get("x").unwrap().len(), 2);
        assert_eq!(a.mean("x"), 2.0);
    }

    #[test]
    fn merge_concatenates_series_for_fleet_percentiles() {
        // Fleet stats merge per-worker recorders by concatenating samples,
        // so a merged percentile ranks over *all* observations — not an
        // average of per-worker percentiles (which would hide a slow
        // worker's tail behind fast workers' medians).
        let mut fast = Recorder::new();
        for _ in 0..9 {
            fast.record("itl", 1.0);
        }
        let mut slow = Recorder::new();
        slow.record("itl", 100.0);
        let mut merged = Recorder::new();
        merged.merge(&fast);
        merged.merge(&slow);
        assert_eq!(merged.get("itl").unwrap().len(), 10);
        // p95 over the pooled samples lands on the slow worker's outlier;
        // averaging per-recorder p95s (≈ 50.5) would not.
        assert_eq!(merged.percentile("itl", 95.0), 100.0);
        assert_eq!(merged.percentile("itl", 50.0), 1.0);
    }

    #[test]
    fn percentile_selection_matches_naive_clone_and_sort() {
        // Regression pin for the select_nth_unstable rewrite: on a
        // deterministic pseudo-random stream (an LCG — no RNG dep), the
        // selected rank must equal what the old clone-and-full-sort
        // implementation returned at every probed percentile.
        let mut state = 0x2545_f491_4f6c_dd1du64;
        let mut s = Series::default();
        let mut vals = Vec::new();
        for _ in 0..257 {
            state = state
                .wrapping_mul(6_364_136_223_846_793_005)
                .wrapping_add(1_442_695_040_888_963_407);
            let x = (state >> 11) as f64 / (1u64 << 53) as f64;
            s.record(x);
            vals.push(x);
        }
        let mut sorted = vals.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for p in [0.0, 1.0, 10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 100.0] {
            let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
            assert_eq!(s.percentile(p), sorted[idx], "nearest-rank mismatch at p{p}");
        }
        // Repeated queries reuse the scratch and must agree.
        assert_eq!(s.percentile(50.0), s.percentile(50.0));
        // The samples themselves stay in record order (selection runs on
        // the scratch, never on the series).
        assert_eq!(s.samples(), vals.as_slice());
    }

    #[test]
    fn merge_combines_windows_and_never_drops_samples() {
        // Two workers each keep a 4-sample window of the same series;
        // the fleet merge must pool *both* windows. A merged retention
        // equal to one worker's window would silently discard the other
        // worker's share of the percentile population.
        let mut a = Recorder::new();
        let mut b = Recorder::new();
        for x in 0..10 {
            a.record_windowed("itl", f64::from(x), 4);
            b.record_windowed("itl", f64::from(100 + x), 4);
        }
        let mut fleet = Recorder::new();
        fleet.merge(&a);
        assert_eq!(fleet.get("itl").unwrap().window(), Some(4), "fresh dest adopts");
        fleet.merge(&b);
        let s = fleet.get("itl").unwrap();
        assert_eq!(s.len(), 8, "both 4-sample windows survive the merge");
        assert_eq!(s.window(), Some(8), "retention covers the sum of the parts");
        assert_eq!(s.samples(), &[6.0, 7.0, 8.0, 9.0, 106.0, 107.0, 108.0, 109.0]);
        // An unbounded participant makes the merged series unbounded —
        // and still nothing is dropped.
        let mut unbounded = Recorder::new();
        unbounded.record("itl", 1.0);
        fleet.merge(&unbounded);
        assert_eq!(fleet.get("itl").unwrap().window(), None);
        assert_eq!(fleet.count("itl"), 9);
    }

    #[test]
    fn table_renders_markdown_and_csv() {
        let mut t = Table::new(&["a", "b"]).with_title("T");
        t.row(&["1", "2"]);
        let md = t.to_markdown();
        assert!(md.contains("### T"));
        assert!(md.contains("| 1 | 2 |"));
        let csv = t.to_csv();
        assert!(csv.contains("a,b"));
        assert!(csv.contains("1,2"));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1"]);
    }
}
