//! Verification-width pruning — the "maximum-value subtree" problem of §4.2.
//!
//! After equal-growth drafting, the tree may hold more nodes than it is
//! worth verifying: verification latency rises with token count (Fig. 5-(a)),
//! so Eq. 3 is maximised by a *subtree* of the draft. Each node's value is
//! its path probability (its marginal expected-AAL contribution), and the
//! chosen set must contain the root and be closed under parents.
//!
//! [`SubtreeDp`] solves this bottom-up in one pass for **every** budget
//! `1..=k` simultaneously (classic tree-knapsack, O(n·k²) worst case but
//! O(n·k) in practice for the shallow-wide trees EGT grows), so the width
//! selector can sweep Eq. 3 over all candidate `W_verify` graph widths and
//! pick the argmax with zero extra DP work.

use crate::tree::{NodeId, TokenTree};

/// Dynamic program over a [`TokenTree`] for max-value subtrees.
#[derive(Debug)]
pub struct SubtreeDp {
    /// `dp[v][j]` = best value of a subtree of v's subtree that contains v
    /// and exactly `j` nodes (index 0 unused).
    dp: Vec<Vec<f64>>,
    /// For reconstruction: `split[v]` records, per child processed in
    /// order, the budget table before merging that child.
    split: Vec<Vec<Vec<f64>>>,
    kmax: usize,
}

impl SubtreeDp {
    /// Runs the DP with per-node `values` (usually `tree.path_prob`) and
    /// budget cap `kmax`.
    pub fn solve(tree: &TokenTree, values: &[f64], kmax: usize) -> Self {
        let n = tree.len();
        assert_eq!(values.len(), n);
        let kmax = kmax.min(n).max(1);
        let mut dp = vec![Vec::new(); n];
        let mut split = vec![Vec::new(); n];

        // Children appear after parents in storage order, so a reverse scan
        // processes every child before its parent.
        for v in (0..n).rev() {
            // Start: subtree = {v}.
            let mut cur = vec![f64::MIN; kmax + 1];
            cur[1] = values[v];
            let mut pre = Vec::new();
            for &c in tree.children(v) {
                pre.push(cur.clone());
                let child = &dp[c];
                // Merge: cur'[j] = max(cur[j], max_m cur[j-m] + child[m]).
                let mut merged = cur.clone();
                for j in (2..=kmax).rev() {
                    for m in 1..j {
                        if cur[j - m] == f64::MIN || child.get(m).copied().unwrap_or(f64::MIN) == f64::MIN {
                            continue;
                        }
                        let cand = cur[j - m] + child[m];
                        if cand > merged[j] {
                            merged[j] = cand;
                        }
                    }
                }
                cur = merged;
            }
            dp[v] = cur;
            split[v] = pre;
        }
        Self { dp, split, kmax }
    }

    /// Largest subtree size the table was solved for.
    pub fn kmax(&self) -> usize {
        self.kmax
    }

    /// Best total value of a root-containing subtree with **exactly**
    /// `j` nodes (`f64::MIN` if infeasible).
    pub fn value_exact(&self, j: usize) -> f64 {
        if j == 0 || j > self.kmax {
            return f64::MIN;
        }
        self.dp[0][j]
    }

    /// Best value with **at most** `budget` nodes.
    pub fn value_at_most(&self, budget: usize) -> f64 {
        (1..=budget.min(self.kmax))
            .map(|j| self.value_exact(j))
            .fold(f64::MIN, f64::max)
    }

    /// Node count attaining [`Self::value_at_most`].
    pub fn best_size(&self, budget: usize) -> usize {
        let mut best = (f64::MIN, 1);
        for j in 1..=budget.min(self.kmax) {
            let v = self.value_exact(j);
            // Prefer smaller trees on (near-)ties: verification cost is
            // monotone in size while value gain here is zero.
            if v > best.0 + 1e-12 {
                best = (v, j);
            }
        }
        best.1
    }

    /// Reconstructs one optimal subtree with exactly `j` nodes. Returns
    /// node ids (always includes the root, closed under parents).
    pub fn select_exact(&self, tree: &TokenTree, j: usize) -> Vec<NodeId> {
        assert!(j >= 1 && j <= self.kmax && self.value_exact(j) > f64::MIN);
        let mut keep = Vec::new();
        self.recover(tree, 0, j, &mut keep);
        keep.sort_unstable();
        keep
    }

    /// Reconstructs the best subtree within `budget` nodes.
    pub fn select_at_most(&self, tree: &TokenTree, budget: usize) -> Vec<NodeId> {
        self.select_exact(tree, self.best_size(budget))
    }

    fn recover(&self, tree: &TokenTree, v: NodeId, j: usize, keep: &mut Vec<NodeId>) {
        keep.push(v);
        let mut j = j;
        // Undo the child merges in reverse order.
        let kids = tree.children(v);
        let mut assigned = vec![0usize; kids.len()];
        let mut cur_val = self.dp[v][j];
        for ci in (0..kids.len()).rev() {
            let pre = &self.split[v][ci];
            let child = &self.dp[kids[ci]];
            // Find m such that pre[j-m] + child[m] == cur_val (m=0 means
            // the child was skipped and cur_val == pre[j]).
            if (pre[j] - cur_val).abs() < 1e-7 && pre[j] != f64::MIN {
                cur_val = pre[j];
                continue;
            }
            let mut found = false;
            for m in 1..j {
                let a = pre[j - m];
                let b = child.get(m).copied().unwrap_or(f64::MIN);
                if a == f64::MIN || b == f64::MIN {
                    continue;
                }
                if (a + b - cur_val).abs() < 1e-7 {
                    assigned[ci] = m;
                    j -= m;
                    cur_val = a;
                    found = true;
                    break;
                }
            }
            if !found {
                // Numerical fallback: child skipped.
                cur_val = pre[j];
            }
        }
        debug_assert_eq!(j, 1, "after removing children only v remains");
        for (ci, &m) in assigned.iter().enumerate() {
            if m > 0 {
                self.recover(tree, kids[ci], m, keep);
            }
        }
    }
}

/// Convenience wrapper: prune `tree` to the subtree maximising the Eq. 3
/// speedup over the candidate verification widths. Returns the kept node
/// ids (sorted) and the chosen padded graph width.
pub fn prune_for_objective(
    tree: &TokenTree,
    lat: &crate::objective::LatencyModel,
    draft_widths: &[usize],
    max_verify: usize,
) -> (Vec<NodeId>, usize) {
    let values: Vec<f64> = (0..tree.len()).map(|i| tree.path_prob(i) as f64).collect();
    let dp = SubtreeDp::solve(tree, &values, max_verify.min(tree.len()));
    let mut best: Option<(f64, usize, usize)> = None; // (speedup, j, width)
    for &w in crate::config::GRAPH_WIDTHS.iter().filter(|&&w| w <= max_verify) {
        let j = w.min(dp.kmax());
        let val = dp.value_at_most(j);
        if val == f64::MIN {
            continue;
        }
        // Expected AAL of the pruned subtree = Σ path-probs (root counts 1
        // for its bonus token).
        let speedup = lat.speedup_tree(val, draft_widths, w);
        if best.map_or(true, |(s, _, _)| speedup > s) {
            best = Some((speedup, j, w));
        }
    }
    let (_, j, w) = best.expect("at least width 1 is feasible");
    (dp.select_at_most(tree, j), w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::{LatencyCurve, LatencyModel};

    fn star_tree() -> TokenTree {
        // root with 4 children of descending value.
        let mut t = TokenTree::new(0);
        for (tok, p) in [(1, 0.5), (2, 0.3), (3, 0.15), (4, 0.05)] {
            t.add_node(0, tok, p);
        }
        t
    }

    fn values(t: &TokenTree) -> Vec<f64> {
        (0..t.len()).map(|i| t.path_prob(i) as f64).collect()
    }

    #[test]
    fn exact_budgets_pick_best_children_first() {
        let t = star_tree();
        let dp = SubtreeDp::solve(&t, &values(&t), 5);
        assert!((dp.value_exact(1) - 1.0).abs() < 1e-6); // root only
        assert!((dp.value_exact(2) - 1.5).abs() < 1e-6); // + 0.5 child
        assert!((dp.value_exact(3) - 1.8).abs() < 1e-6);
        assert!((dp.value_exact(5) - 2.0).abs() < 1e-6);
        let keep = dp.select_exact(&t, 3);
        assert_eq!(keep, vec![0, 1, 2]);
    }

    #[test]
    fn deep_chain_vs_wide_star() {
        // A strong chain must beat weak star children under a tight budget.
        let mut t = TokenTree::new(0);
        let a = t.add_node(0, 1, 0.9);
        let b = t.add_node(a, 2, 0.9); // path 0.81
        let _ = t.add_node(0, 3, 0.2);
        let _ = t.add_node(0, 4, 0.1);
        let dp = SubtreeDp::solve(&t, &values(&t), 3);
        let keep = dp.select_exact(&t, 3);
        assert_eq!(keep, vec![0, a, b]);
        assert!((dp.value_exact(3) - (1.0 + 0.9 + 0.81)).abs() < 1e-6);
    }

    #[test]
    fn selection_is_closed_under_parents() {
        let mut t = TokenTree::new(0);
        let a = t.add_node(0, 1, 0.3);
        let b = t.add_node(a, 2, 0.9); // path 0.27: grandchild forces a in
        let _ = t.add_node(0, 3, 0.2); // weaker sibling loses to the chain
        let dp = SubtreeDp::solve(&t, &values(&t), 4);
        for j in 1..=4 {
            let keep = dp.select_exact(&t, j);
            for &v in &keep {
                if let Some(p) = t.parent(v) {
                    assert!(keep.contains(&p), "budget {j}: node {v} without parent");
                }
            }
        }
        let keep3 = dp.select_exact(&t, 3);
        assert!(keep3.contains(&b) && keep3.contains(&a));
    }

    #[test]
    fn value_at_most_is_monotone() {
        let t = star_tree();
        let dp = SubtreeDp::solve(&t, &values(&t), 5);
        let mut prev = f64::MIN;
        for b in 1..=5 {
            let v = dp.value_at_most(b);
            assert!(v >= prev);
            prev = v;
        }
    }

    #[test]
    fn prune_for_objective_prefers_small_widths_when_values_decay() {
        let lat = LatencyModel {
            drafter: LatencyCurve::new(&[(1, 1e-3), (64, 2e-3)]),
            verifier: LatencyCurve::new(&[(1, 8e-3), (16, 9e-3), (64, 30e-3)]),
            cpu_overhead: 0.0,
        };
        // 40-node tree where almost all value is in the top 4 nodes.
        let mut t = TokenTree::new(0);
        let mut cur = 0;
        for _ in 0..3 {
            cur = t.add_node(cur, 1, 0.9);
        }
        for _ in 0..36 {
            t.add_node(0, 2, 0.01);
        }
        let (keep, w) = prune_for_objective(&t, &lat, &[4; 3], 64);
        assert!(w <= 16, "chose width {w}");
        assert!(keep.len() <= w);
        assert!(keep.contains(&1) && keep.contains(&2) && keep.contains(&3));
    }

    #[test]
    fn single_node_tree_budget_one() {
        let t = TokenTree::new(7);
        let dp = SubtreeDp::solve(&t, &[1.0], 1);
        assert_eq!(dp.select_at_most(&t, 1), vec![0]);
    }
}
