//! Deterministic xorshift64* RNG.
//!
//! Every stochastic component (sampling, acceptance, workload generation)
//! takes an explicit seeded RNG so whole experiments are reproducible from
//! the bench config alone — no global state, no platform dependence.

/// xorshift64* — tiny, fast, and good enough for sampling experiments.
#[derive(Debug, Clone)]
pub struct XorShiftRng {
    state: u64,
}

impl XorShiftRng {
    /// Seeds the generator (splitmixed; any seed works, including 0).
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point; splitmix the seed once for
        // decorrelation of small consecutive seeds.
        let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^= z >> 31;
        Self { state: z | 1 }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn next_range(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Derives an independent stream (for per-request RNGs).
    pub fn fork(&mut self, tag: u64) -> Self {
        Self::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = XorShiftRng::new(1);
        let mut b = XorShiftRng::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = XorShiftRng::new(1);
        let mut b = XorShiftRng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f32_in_unit_interval_and_roughly_uniform() {
        let mut rng = XorShiftRng::new(5);
        let mut sum = 0.0f64;
        for _ in 0..10_000 {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn range_covers_all_buckets() {
        let mut rng = XorShiftRng::new(11);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[rng.next_range(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = XorShiftRng::new(42);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
