//! Sampling primitives and token-acceptance rules.
//!
//! Greedy decoding accepts a draft child iff it equals the verifier's
//! argmax. Temperature sampling uses the SpecInfer-style *multi-branch
//! residual* rule ([`stochastic_accept`]): candidate children are tried in
//! order against the verifier distribution, each rejection subtracting the
//! drafter's mass from a residual; if all fail, the bonus token is sampled
//! from the residual. Both rules preserve the target distribution exactly
//! (losslessness is speculative decoding's defining property) — see the
//! unit tests, which verify the stationary distribution empirically.

pub mod rng;

pub use rng::XorShiftRng;

/// Numerically-stable in-place softmax with optional temperature.
/// `temperature == 0` is handled by callers via [`argmax`].
pub fn softmax_inplace(logits: &mut [f32], temperature: f32) {
    let t = temperature.max(1e-6);
    let m = logits.iter().copied().fold(f32::MIN, f32::max);
    let mut sum = 0.0f32;
    for x in logits.iter_mut() {
        *x = ((*x - m) / t).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in logits.iter_mut() {
        *x *= inv;
    }
}

/// Index of the maximum element (first on ties).
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Top-`k` (index, value) pairs, sorted descending by value. O(V·k) with a
/// small insertion buffer — faster than a full sort for k ≤ 16 at V ≈ 1k.
pub fn top_k(xs: &[f32], k: usize) -> Vec<(usize, f32)> {
    let k = k.min(xs.len());
    let mut out: Vec<(usize, f32)> = Vec::with_capacity(k + 1);
    for (i, &x) in xs.iter().enumerate() {
        if out.len() < k || x > out[out.len() - 1].1 {
            let pos = out.partition_point(|&(_, v)| v >= x);
            out.insert(pos, (i, x));
            if out.len() > k {
                out.pop();
            }
        }
    }
    out
}

/// Samples an index from a probability vector.
pub fn categorical(probs: &[f32], rng: &mut XorShiftRng) -> usize {
    let r = rng.next_f32();
    let mut acc = 0.0f32;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if r < acc {
            return i;
        }
    }
    probs.len() - 1 // floating-point tail
}

/// Outcome of verifying one node's children.
#[derive(Debug, Clone, PartialEq)]
pub enum AcceptOutcome {
    /// Child at this index (into the candidate list) was accepted.
    Child(usize),
    /// All children rejected; commit this bonus token instead.
    Bonus(u32),
}

/// Greedy acceptance: the child is accepted iff it *is* the verifier's
/// argmax token. Returns the outcome and the verifier's greedy token.
pub fn greedy_accept(verifier_logits: &[f32], child_tokens: &[u32]) -> (AcceptOutcome, u32) {
    let t = argmax(verifier_logits) as u32;
    match child_tokens.iter().position(|&c| c == t) {
        Some(i) => (AcceptOutcome::Child(i), t),
        None => (AcceptOutcome::Bonus(t), t),
    }
}

/// SpecInfer-style multi-round stochastic acceptance.
///
/// `p_target` — verifier probabilities at the node (temperature applied).
/// `q_draft`  — drafter probabilities at the node (same temperature).
/// `child_tokens` — candidate children **drawn i.i.d. from `q_draft`**, in
/// the order they were drafted.
///
/// Round `i`: child `c_i` is accepted with probability
/// `min(1, p_i(c_i) / q(c_i))`; on rejection the residual target becomes
/// `p_{i+1} = normalize(max(p_i − q, 0))`. If every child is rejected the
/// bonus token is drawn from the final residual. With i.i.d. draws from
/// `q` this is SpecInfer's multi-round speculative sampling and the
/// committed token's marginal distribution equals `p_target` exactly
/// (verified empirically in the tests below).
pub fn stochastic_accept(
    p_target: &[f32],
    q_draft: &[f32],
    child_tokens: &[u32],
    rng: &mut XorShiftRng,
) -> AcceptOutcome {
    let v = p_target.len();
    let mut p_res: Vec<f32> = p_target.to_vec();
    for (i, &c) in child_tokens.iter().enumerate() {
        let c = c as usize;
        debug_assert!(c < v);
        let qc = q_draft[c].max(1e-20);
        let ratio = (p_res[c] / qc).min(1.0);
        if rng.next_f32() < ratio {
            return AcceptOutcome::Child(i);
        }
        // Reject: subtract the proposal distribution and renormalise.
        let mut sum = 0.0f32;
        for j in 0..v {
            p_res[j] = (p_res[j] - q_draft[j]).max(0.0);
            sum += p_res[j];
        }
        if sum <= 1e-12 {
            // Degenerate (q ≥ p everywhere): any residual draw is valid —
            // fall back to the target itself, which preserves the marginal
            // because this branch has probability 0 under exact arithmetic.
            p_res.copy_from_slice(p_target);
            sum = p_res.iter().sum();
        }
        let inv = 1.0 / sum;
        p_res.iter_mut().for_each(|x| *x *= inv);
    }
    AcceptOutcome::Bonus(categorical(&p_res, rng) as u32)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let mut x = vec![1.0, 2.0, 3.0];
        softmax_inplace(&mut x, 1.0);
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(x[2] > x[1] && x[1] > x[0]);
    }

    #[test]
    fn low_temperature_sharpens() {
        let mut a = vec![1.0, 2.0];
        let mut b = vec![1.0, 2.0];
        softmax_inplace(&mut a, 1.0);
        softmax_inplace(&mut b, 0.25);
        assert!(b[1] > a[1]);
    }

    #[test]
    fn top_k_sorted_and_correct() {
        let xs = vec![0.1, 5.0, 3.0, 4.0, -1.0];
        let t = top_k(&xs, 3);
        assert_eq!(t.iter().map(|p| p.0).collect::<Vec<_>>(), vec![1, 3, 2]);
        assert_eq!(top_k(&xs, 10).len(), 5);
    }

    #[test]
    fn greedy_accept_matches_argmax_only() {
        let logits = vec![0.0, 9.0, 1.0];
        let (o, t) = greedy_accept(&logits, &[2, 1]);
        assert_eq!(t, 1);
        assert_eq!(o, AcceptOutcome::Child(1));
        let (o2, _) = greedy_accept(&logits, &[0, 2]);
        assert_eq!(o2, AcceptOutcome::Bonus(1));
    }

    #[test]
    fn categorical_is_unbiased() {
        let mut rng = XorShiftRng::new(42);
        let probs = vec![0.2, 0.5, 0.3];
        let mut counts = [0usize; 3];
        for _ in 0..20_000 {
            counts[categorical(&probs, &mut rng)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let f = c as f32 / 20_000.0;
            assert!((f - probs[i]).abs() < 0.02, "idx {i}: {f} vs {}", probs[i]);
        }
    }

    /// The defining property: speculative acceptance must leave the
    /// *marginal* distribution of the committed token equal to p_target,
    /// no matter what the drafter proposes.
    #[test]
    fn stochastic_acceptance_is_lossless() {
        let mut rng = XorShiftRng::new(7);
        let p = vec![0.5, 0.3, 0.15, 0.05];
        let q = vec![0.1, 0.6, 0.25, 0.05];
        let mut counts = [0usize; 4];
        let n = 200_000;
        for _ in 0..n {
            // Children must be drawn i.i.d. from q (the drafter) for the
            // lossless guarantee — this mirrors what the engine does at
            // temperature > 0.
            let children = [
                categorical(&q, &mut rng) as u32,
                categorical(&q, &mut rng) as u32,
            ];
            let tok = match stochastic_accept(&p, &q, &children, &mut rng) {
                AcceptOutcome::Child(i) => children[i],
                AcceptOutcome::Bonus(b) => b,
            };
            counts[tok as usize] += 1;
        }
        for i in 0..4 {
            let f = counts[i] as f32 / n as f32;
            assert!(
                (f - p[i]).abs() < 0.01,
                "token {i}: empirical {f:.3} vs target {:.3}",
                p[i]
            );
        }
    }

    #[test]
    fn stochastic_accepts_perfect_drafter_always() {
        let mut rng = XorShiftRng::new(3);
        let p = vec![0.7, 0.3];
        for _ in 0..1000 {
            match stochastic_accept(&p, &p, &[0, 1], &mut rng) {
                AcceptOutcome::Child(_) => {}
                AcceptOutcome::Bonus(_) => panic!("perfect drafter must always land"),
            }
        }
    }

    #[test]
    fn stochastic_rejects_impossible_tokens() {
        let mut rng = XorShiftRng::new(9);
        // Target puts zero mass on token 1; drafter proposes it anyway.
        let p = vec![1.0, 0.0];
        let q = vec![0.01, 0.99];
        for _ in 0..500 {
            match stochastic_accept(&p, &q, &[1], &mut rng) {
                AcceptOutcome::Child(_) => panic!("accepted zero-probability token"),
                AcceptOutcome::Bonus(b) => assert_eq!(b, 0),
            }
        }
    }
}
