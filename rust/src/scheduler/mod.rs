//! Stage-based scheduling runtime — §5 of the paper.
//!
//! One speculative iteration decomposes into stages with a dependency
//! graph (Fig. 9-(c)):
//!
//! ```text
//!   HeadDraft → TreeDraft(×D) → Prune → Verify → Accept → Bookkeep
//!                                   ↘ TailDraft ↗    ↘ next HeadDraft
//! ```
//!
//! Two resources execute stages: the **device** (model calls, FIFO) and the
//! **CPU** (tree building, masks, acceptance walk, cache management). The
//! naive plan serialises everything; *ahead-of-time* execution breaks two
//! dependencies speculatively (§5.1):
//!
//! * **AOT tail draft** — instead of conditionally drafting the next-root
//!   continuation after acceptance, the top leaf continuations are drafted
//!   speculatively, queued right behind verification, overlapping with the
//!   CPU acceptance walk. A superset of the needed tokens is computed; the
//!   accepted one is reused, the rest discarded.
//! * **AOT head draft** — the next iteration's head draft is issued the
//!   moment the bonus token is known, overlapping drafter execution with
//!   cache-management bookkeeping.
//!
//! [`search_best_plan`] is the profile-guided execution-plan search of
//! §5.2: with measured per-stage durations it list-schedules each candidate
//! plan on the two resources and picks the minimum-latency one. The search
//! space is tiny (the paper's "well-defined dependency graph"), so an
//! exhaustive sweep is exact.

use crate::config::SchedulePlan;

pub mod alloc;

/// The concrete overlap decisions for one iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Plan {
    /// Speculatively draft next-root continuations behind the verify.
    pub aot_tail: bool,
    /// Issue the next head draft before bookkeeping finishes.
    pub aot_head: bool,
}

impl Plan {
    /// The no-overlap plan.
    pub const SEQUENTIAL: Plan = Plan { aot_tail: false, aot_head: false };
    /// Every plan in the (tiny) search space.
    pub const ALL: [Plan; 4] = [
        Plan { aot_tail: false, aot_head: false },
        Plan { aot_tail: true, aot_head: false },
        Plan { aot_tail: false, aot_head: true },
        Plan { aot_tail: true, aot_head: true },
    ];

    /// Stable plan label (config / logs).
    pub fn name(&self) -> &'static str {
        match (self.aot_tail, self.aot_head) {
            (false, false) => "sequential",
            (true, false) => "aot_tail",
            (false, true) => "aot_head",
            (true, true) => "aot_tail_head",
        }
    }
}

/// Measured (or estimated) seconds per stage of one iteration.
#[derive(Debug, Clone, Copy)]
pub struct StageDurations {
    /// Drafter call on the confirmed root (width 1).
    pub head_draft: f64,
    /// All D equal-growth drafter calls together.
    pub tree_draft: f64,
    /// CPU: frontier updates + pruning DP.
    pub cpu_build: f64,
    /// CPU: attention-mask assembly (bit-packed build + expansion),
    /// measured as `stage.cpu_mask`. Serial between draft and verify, so
    /// it is priced into every plan's core — previously this cost was
    /// unmeasured and implicitly assumed free.
    pub cpu_mask: f64,
    /// Verifier call on the pruned tree.
    pub verify: f64,
    /// Speculative tail-draft drafter call (only issued under AOT-tail).
    pub tail_draft: f64,
    /// CPU acceptance-walk loop over the verified tree, measured as
    /// `stage.cpu_walk`. Priced together with `accept` (the two split
    /// what used to be one blended stage).
    pub cpu_walk: f64,
    /// CPU post-walk acceptance bookkeeping (coverage stats, tail-hit
    /// resolution, predictor features).
    pub accept: f64,
    /// CPU cache management / bookkeeping.
    pub bookkeep: f64,
    /// Probability that the AOT tail draft covers the next head token
    /// (measured online; determines how often the head draft is free).
    pub tail_hit_rate: f64,
}

impl StageDurations {
    /// Measured stage durations from one generation's recorder (the
    /// per-session plan-search input). Each decode task carries its own
    /// recorder, so under multi-session interleaving every session's plan
    /// search sees *its* stage timings, not a blend of whoever shared the
    /// device — a session decoding long prompts and a session decoding
    /// short ones can legitimately pick different plans. Missing series
    /// fall back to the floor values (the `max` with NaN selects the
    /// floor), matching the pre-measurement estimate's scale.
    pub fn from_recorder(rec: &crate::metrics::Recorder, tail_hit_rate: f64) -> Self {
        Self {
            head_draft: rec.mean("stage.head_draft").max(1e-6),
            tree_draft: rec.mean("stage.tree_draft").max(1e-6),
            cpu_build: rec.mean("stage.cpu_build").max(1e-7),
            cpu_mask: rec.mean("stage.cpu_mask").max(1e-7),
            verify: rec.mean("stage.verify").max(1e-6),
            tail_draft: rec.mean("stage.tail_draft").max(1e-6),
            cpu_walk: rec.mean("stage.cpu_walk").max(1e-7),
            accept: rec.mean("stage.accept").max(1e-7),
            bookkeep: rec.mean("stage.bookkeep").max(1e-7),
            tail_hit_rate,
        }
    }

    /// Rough estimate from a latency model before any measurement exists.
    pub fn estimate(
        lat: &crate::objective::LatencyModel,
        depth: usize,
        width: usize,
        w_verify: usize,
        tail_width: usize,
    ) -> Self {
        // The splits preserve the measured-era sums the formulas price
        // (`cpu_build + cpu_mask` in the core, `cpu_walk + accept` after
        // the verify), so estimates predate measurement without shifting
        // any plan's pre-profile latency.
        Self {
            head_draft: lat.t_draft(1),
            tree_draft: depth as f64 * lat.t_draft(width),
            cpu_build: lat.cpu_overhead * 0.4,
            cpu_mask: lat.cpu_overhead * 0.1,
            verify: lat.t_verify(w_verify),
            tail_draft: lat.t_draft(tail_width),
            cpu_walk: lat.cpu_overhead * 0.15,
            accept: lat.cpu_overhead * 0.1,
            bookkeep: lat.cpu_overhead * 0.25,
            tail_hit_rate: 0.5,
        }
    }
}

/// Expected wall-clock seconds of one iteration under `plan`.
///
/// Accounting is per-iteration-closed: each iteration is charged its own
/// head draft at the start; AOT transforms convert serial segments into
/// `max(device, cpu)` overlaps and discount the head draft by the tail
/// hit rate:
///
/// With `build = cpu_build + cpu_mask` (both serial between draft and
/// verify) and `walk = cpu_walk + accept` (the split acceptance stage):
///
/// ```text
/// sequential : head + tree + build + verify + walk + bookkeep
/// aot_tail   : (1-hit)·head + tree + build + verify + max(tail, walk) + bookkeep
/// aot_head   : tree + build + verify + walk + max(head, bookkeep)
/// both       : (tree + build + verify + max(tail, walk)
///               + max((1-hit)·head, bookkeep))
/// ```
pub fn plan_latency(d: &StageDurations, plan: Plan) -> f64 {
    let core = d.tree_draft + d.cpu_build + d.cpu_mask + d.verify;
    let walk = d.cpu_walk + d.accept;
    match (plan.aot_tail, plan.aot_head) {
        (false, false) => d.head_draft + core + walk + d.bookkeep,
        (true, false) => {
            (1.0 - d.tail_hit_rate) * d.head_draft + core + d.tail_draft.max(walk) + d.bookkeep
        }
        (false, true) => core + walk + d.head_draft.max(d.bookkeep),
        (true, true) => {
            core + d.tail_draft.max(walk)
                + ((1.0 - d.tail_hit_rate) * d.head_draft).max(d.bookkeep)
        }
    }
}

/// The shape of an engine's packed device calls — what the batched plan
/// search needs to price an S-way ride (DESIGN.md §9/§11).
#[derive(Debug, Clone, Copy)]
pub struct BatchShape {
    /// Sessions expected to share each packed call.
    pub sessions: usize,
    /// Verification rows one session contributes (its pruned tree size).
    pub verify_rows: usize,
    /// Equal-growth width one session contributes per draft level.
    pub draft_width: usize,
    /// Whether the draft stages (head + tree levels) are packed too, or
    /// only the verify call (`--no-batch-draft`).
    pub batch_draft: bool,
}

/// Per-rider share of an S-way packed device call.
///
/// The packed call is *wider* than a solo call — sub-linear in the rider
/// count, but not free — so each rider is charged `packed / S` where
/// `packed` is the latency-curve cost at `rows × S` (clamped to the
/// widest compiled graph by the curve's own extrapolation). Charging
/// `solo / S` — the old accounting — is the degenerate "the packed call
/// costs no more than a solo one" case and systematically optimistic;
/// it survives only as the lower bound when the curve is flat. A rider
/// never pays more than going solo (the scheduler would simply not pack
/// a super-linear call).
pub fn amortized_share(
    solo: f64,
    rows: usize,
    sessions: usize,
    curve: &crate::objective::LatencyCurve,
) -> f64 {
    let s = sessions.max(1) as f64;
    let rows = rows.max(1) as f64;
    let ratio = (curve.at(rows * s) / curve.at(rows).max(1e-12)).max(1.0);
    ((solo * ratio) / s).min(solo)
}

/// Per-session stage durations when `sessions` concurrent sessions share
/// one batched verifier call (cross-session batching, DESIGN.md §9):
/// the verify stage is charged its [`amortized_share`] of the packed
/// call at `rows × sessions`. Draft and CPU stages pass through.
pub fn amortize_verify(
    d: &StageDurations,
    sessions: usize,
    rows: usize,
    curve: &crate::objective::LatencyCurve,
) -> StageDurations {
    StageDurations { verify: amortized_share(d.verify, rows, sessions, curve), ..*d }
}

/// Draft-side analog of [`amortize_verify`] for stage-aligned batched
/// drafting (DESIGN.md §11): the head draft packs `sessions` width-1
/// rows into one call and every tree-draft level packs `sessions`
/// width-`width` levels, so both stages are charged their per-rider
/// share of the packed call. CPU and verify stages pass through.
pub fn amortize_draft(
    d: &StageDurations,
    sessions: usize,
    width: usize,
    curve: &crate::objective::LatencyCurve,
) -> StageDurations {
    StageDurations {
        head_draft: amortized_share(d.head_draft, 1, sessions, curve),
        tree_draft: amortized_share(d.tree_draft, width, sessions, curve),
        ..*d
    }
}

/// Splits *measured* packed-call durations across the measured rider
/// counts. A batched run's recorder logs the shared verify call's wall
/// time (`stage.verify`) — and, under batched drafting, the packed
/// draft-phase calls (`stage.tree_draft`) — identically on every rider,
/// alongside the rider counts (`batch.sessions` /
/// `batch.draft_sessions`). The per-session charge is therefore the
/// measured call over the measured mean riders: nothing is modelled.
/// NaN rider counts (the run never batched that stage) pass the stage
/// through unchanged.
pub fn split_measured_batched(
    d: &StageDurations,
    verify_riders: f64,
    draft_riders: f64,
) -> StageDurations {
    let share = |x: f64, riders: f64| {
        if riders.is_finite() && riders > 1.0 {
            x / riders
        } else {
            x
        }
    };
    StageDurations {
        verify: share(d.verify, verify_riders),
        tree_draft: share(d.tree_draft, draft_riders),
        ..*d
    }
}

/// Plan search under packed device calls: [`search_best_plan`] over the
/// [`amortize_verify`] (and, when `shape.batch_draft`,
/// [`amortize_draft`]) durations priced against the measured latency
/// curves.
pub fn search_best_plan_batched(
    d: &StageDurations,
    shape: &BatchShape,
    lat: &crate::objective::LatencyModel,
) -> (Plan, f64) {
    let mut a = amortize_verify(d, shape.sessions, shape.verify_rows, &lat.verifier);
    if shape.batch_draft {
        a = amortize_draft(&a, shape.sessions, shape.draft_width, &lat.drafter);
    }
    search_best_plan(&a)
}

/// [`resolve`] for a batched engine: explicit schedule choices pass
/// through; `ProfileSearch` searches over the amortized durations.
pub fn resolve_batched(
    schedule: SchedulePlan,
    d: &StageDurations,
    shape: &BatchShape,
    lat: &crate::objective::LatencyModel,
) -> Plan {
    match schedule {
        SchedulePlan::ProfileSearch => search_best_plan_batched(d, shape, lat).0,
        other => resolve(other, d),
    }
}

/// Clamps a config-derived per-iteration tree budget to the shared
/// pool's current headroom (paged serving, DESIGN.md §10): a session may
/// spend at most half the slots it could still reach on speculation, so
/// the other half stays available for the committed prefix it is about
/// to grow (and for its neighbours). The floor of 2 keeps a starved but
/// servable session drafting (a root plus one candidate) — but it must
/// never exceed the *actual* headroom: a dry pool reporting 0 available
/// slots must yield a 0 budget (admission then rejects or parks the
/// request cleanly) rather than a 2-slot budget that guarantees an
/// immediate `PoolExhausted` → preemption churn loop bounded only by
/// `max_resumes`.
///
/// Under the cross-request prefix cache (DESIGN.md §12) the `available`
/// argument is already *post-reuse*: an attached cached prefix consumes
/// no free blocks, and blocks held only by the trie count as reclaimable
/// (the LRU eviction pass frees them before any preemption), so a warm
/// request's speculation budget reflects the headroom it actually has
/// after reuse rather than a cold-prefill worst case.
pub fn clamp_tree_budget(envelope: usize, available: usize) -> usize {
    envelope.min((available / 2).max(2.min(available)))
}

/// How many exhaustion-free rounds walk the overload ladder back down
/// one rung (hysteresis: pressure must stay gone for a while before the
/// scheduler re-arms full speculation).
pub const LADDER_RELAX_ROUNDS: u32 = 8;

/// Rung 1: shrink per-session tree budgets (halved verify envelope).
pub const RUNG_SHRINK_BUDGET: u8 = 1;
/// Rung 2: skip drafting for throughput-class sessions (verify-only,
/// one token per round — no speculative slots at all).
pub const RUNG_SKIP_DRAFT: u8 = 2;
/// Rung 3: chunk cold-prompt prefill harder (halved chunk size).
pub const RUNG_CHUNK_HARDER: u8 = 3;
/// Rung 4: preemption — the last resort the ladder exists to delay.
pub const RUNG_PREEMPT: u8 = 4;

/// Overload-degradation ladder (DESIGN.md §14): when the shared pool
/// runs dry mid-round the server escalates one rung per pressured round
/// — shrink tree budgets → skip drafting for low-priority sessions →
/// chunk prefill harder → only then preempt — instead of jumping
/// straight to preemption and its re-prefill churn. Each rung strictly
/// reduces the speculative/cold slot demand of the next round, so most
/// pressure spikes drain without ever reaching [`RUNG_PREEMPT`].
/// Exhaustion-free rounds relax the ladder back down with hysteresis
/// ([`LADDER_RELAX_ROUNDS`]).
#[derive(Debug, Clone, Default)]
pub struct DegradationLadder {
    rung: u8,
    clean_rounds: u32,
}

impl DegradationLadder {
    /// A fresh, un-pressured ladder.
    pub fn new() -> Self {
        Self::default()
    }

    /// The current rung (0 = no degradation, [`RUNG_PREEMPT`] = worst).
    pub fn rung(&self) -> u8 {
        self.rung
    }

    /// One pool-exhaustion event: climb one rung (saturating at
    /// [`RUNG_PREEMPT`]) and reset the relax hysteresis. Returns the new
    /// rung.
    pub fn escalate(&mut self) -> u8 {
        self.clean_rounds = 0;
        if self.rung < RUNG_PREEMPT {
            self.rung += 1;
        }
        self.rung
    }

    /// Whether any degradation is currently active.
    pub fn pressured(&self) -> bool {
        self.rung > 0
    }

    /// Whether the ladder has exhausted its gentler rungs — only now may
    /// the scheduler preempt.
    pub fn at_preempt(&self) -> bool {
        self.rung >= RUNG_PREEMPT
    }

    /// One exhaustion-free round: after [`LADDER_RELAX_ROUNDS`] in a row,
    /// step back down one rung. Returns true when the rung changed.
    pub fn relax(&mut self) -> bool {
        if self.rung == 0 {
            return false;
        }
        self.clean_rounds += 1;
        if self.clean_rounds >= LADDER_RELAX_ROUNDS {
            self.clean_rounds = 0;
            self.rung -= 1;
            return true;
        }
        false
    }
}

/// Work-stealing rebalance policy (DESIGN.md §16): given each worker's
/// pending backlog and total routing load (backlog + live sessions),
/// picks one job migration `(src, dst)` — from the back of the deepest
/// backlog to the least-loaded other worker — or `None` when the fleet
/// is balanced. A move requires the source backlog to exceed
/// `threshold` *and* the destination to stay strictly lighter than the
/// source even after the move (`loads[dst] + 1 < loads[src]`), so
/// repeated application terminates instead of ping-ponging one job
/// between two equally-loaded workers. Pure — the router applies the
/// decision; determinism (ties break toward the lowest index) keeps
/// seeded routing sweeps reproducible.
pub fn steal_move(backlogs: &[usize], loads: &[usize], threshold: usize) -> Option<(usize, usize)> {
    debug_assert_eq!(backlogs.len(), loads.len());
    if backlogs.len() < 2 {
        return None;
    }
    // Deepest backlog, lowest index on ties (max_by_key prefers later
    // elements on ties, so scan explicitly).
    let mut src = 0;
    for (i, &b) in backlogs.iter().enumerate() {
        if b > backlogs[src] {
            src = i;
        }
    }
    if backlogs[src] <= threshold {
        return None;
    }
    let mut dst = src;
    for (i, &l) in loads.iter().enumerate() {
        if i != src && (dst == src || l < loads[dst]) {
            dst = i;
        }
    }
    (dst != src && loads[dst] + 1 < loads[src]).then_some((src, dst))
}

/// Exhaustive profile-guided plan search (§5.2).
pub fn search_best_plan(d: &StageDurations) -> (Plan, f64) {
    // Most-overlapping plans first so exact ties resolve toward overlap
    // (it additionally hides jitter the point estimates cannot see).
    let mut order = Plan::ALL;
    order.reverse();
    order
        .iter()
        .map(|&p| (p, plan_latency(d, p)))
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap()
}

/// Resolves a config-level schedule choice into a concrete plan.
pub fn resolve(schedule: SchedulePlan, durations: &StageDurations) -> Plan {
    match schedule {
        SchedulePlan::Sequential => Plan::SEQUENTIAL,
        SchedulePlan::AotTail => Plan { aot_tail: true, aot_head: false },
        SchedulePlan::AotTailHead => Plan { aot_tail: true, aot_head: true },
        SchedulePlan::ProfileSearch => search_best_plan(durations).0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn durations() -> StageDurations {
        StageDurations {
            head_draft: 1.0e-3,
            tree_draft: 4.0e-3,
            cpu_build: 0.5e-3,
            cpu_mask: 0.1e-3,
            verify: 6.0e-3,
            tail_draft: 1.2e-3,
            cpu_walk: 0.5e-3,
            accept: 0.3e-3,
            bookkeep: 0.7e-3,
            tail_hit_rate: 0.6,
        }
    }

    #[test]
    fn overlap_never_hurts_in_the_model() {
        let d = durations();
        let seq = plan_latency(&d, Plan::SEQUENTIAL);
        for p in Plan::ALL {
            assert!(
                plan_latency(&d, p) <= seq + 1e-12,
                "{} slower than sequential",
                p.name()
            );
        }
    }

    #[test]
    fn search_picks_full_overlap_when_cpu_is_expensive() {
        let mut d = durations();
        d.accept = 3e-3;
        d.bookkeep = 3e-3;
        let (p, t) = search_best_plan(&d);
        assert!(p.aot_tail && p.aot_head, "picked {}", p.name());
        assert!(t < plan_latency(&d, Plan::SEQUENTIAL));
    }

    #[test]
    fn sequential_wins_only_by_tie() {
        // With zero CPU cost there is nothing to overlap: all plans equal
        // except the tail-draft device cost under AOT-tail.
        let d = StageDurations {
            head_draft: 1e-3,
            tree_draft: 4e-3,
            cpu_build: 0.0,
            cpu_mask: 0.0,
            verify: 6e-3,
            tail_draft: 2e-3,
            cpu_walk: 0.0,
            accept: 0.0,
            bookkeep: 0.0,
            tail_hit_rate: 0.0,
        };
        let (p, _) = search_best_plan(&d);
        // A miss-only tail draft pays 2ms for nothing; search must not
        // pick it.
        assert!(!p.aot_tail, "picked {}", p.name());
    }

    #[test]
    fn resolve_honours_explicit_choices() {
        let d = durations();
        assert_eq!(resolve(SchedulePlan::Sequential, &d), Plan::SEQUENTIAL);
        assert!(resolve(SchedulePlan::AotTail, &d).aot_tail);
        let p = resolve(SchedulePlan::AotTailHead, &d);
        assert!(p.aot_tail && p.aot_head);
    }

    #[test]
    fn from_recorder_reads_measured_stages_and_floors_missing_ones() {
        let mut rec = crate::metrics::Recorder::new();
        rec.record("stage.head_draft", 2e-3);
        rec.record("stage.tree_draft", 5e-3);
        rec.record("stage.verify", 7e-3);
        // cpu_build / cpu_mask / tail_draft / cpu_walk / accept /
        // bookkeep unmeasured.
        let d = StageDurations::from_recorder(&rec, 0.4);
        assert!((d.head_draft - 2e-3).abs() < 1e-12);
        assert!((d.tree_draft - 5e-3).abs() < 1e-12);
        assert!((d.verify - 7e-3).abs() < 1e-12);
        assert_eq!(d.cpu_build, 1e-7, "missing series floors, not NaN");
        assert_eq!(d.cpu_mask, 1e-7);
        assert_eq!(d.cpu_walk, 1e-7);
        assert_eq!(d.tail_draft, 1e-6);
        assert!((d.tail_hit_rate - 0.4).abs() < 1e-12);
        // The floored durations feed the search without poisoning it.
        let (_, t) = search_best_plan(&d);
        assert!(t.is_finite());
    }

    fn lat_model() -> crate::objective::LatencyModel {
        crate::objective::LatencyModel {
            drafter: crate::objective::LatencyCurve::new(&[
                (1, 1.0e-3),
                (8, 1.4e-3),
                (64, 3.0e-3),
            ]),
            verifier: crate::objective::LatencyCurve::new(&[
                (1, 4.0e-3),
                (16, 6.0e-3),
                (64, 1.2e-2),
            ]),
            cpu_overhead: 1e-3,
        }
    }

    #[test]
    fn amortized_verify_shrinks_with_batch_size_but_is_never_free() {
        let d = durations();
        let lat = lat_model();
        for p in Plan::ALL {
            let solo = plan_latency(&d, p);
            let mut prev = solo;
            for s in [2usize, 4, 8] {
                let t = plan_latency(&amortize_verify(&d, s, 16, &lat.verifier), p);
                assert!(t <= prev + 1e-12, "{} got slower at {s} sessions", p.name());
                prev = t;
            }
        }
        // Non-verify stages are untouched.
        let a = amortize_verify(&d, 4, 16, &lat.verifier);
        assert!((a.tree_draft - d.tree_draft).abs() < 1e-15);
        assert!((a.accept - d.accept).abs() < 1e-15);
        // The packed call is wider than the solo one, so the per-rider
        // share is strictly MORE than the naive `verify / sessions`
        // (sub-linear, not free) while still cheaper than going solo.
        assert!(a.verify > d.verify / 4.0, "old optimistic accounting resurfaced");
        assert!(a.verify < d.verify);
        // The exact share: verifier cost grows 6ms → 12ms from width 16
        // to the 64-wide packed call, so each of 4 riders pays 2×/4.
        let expect = d.verify * (lat.t_verify(64) / lat.t_verify(16)) / 4.0;
        assert!((a.verify - expect).abs() < 1e-12);
    }

    #[test]
    fn amortize_draft_charges_packed_head_and_levels() {
        let d = durations();
        let lat = lat_model();
        let a = amortize_draft(&d, 4, 8, &lat.drafter);
        // Sub-linear, not free — same bound as the verify side.
        assert!(a.head_draft > d.head_draft / 4.0 && a.head_draft < d.head_draft);
        assert!(a.tree_draft > d.tree_draft / 4.0 && a.tree_draft < d.tree_draft);
        // Verify/CPU stages pass through.
        assert!((a.verify - d.verify).abs() < 1e-15);
        assert!((a.bookkeep - d.bookkeep).abs() < 1e-15);
        // One rider degenerates to solo.
        let solo = amortize_draft(&d, 1, 8, &lat.drafter);
        assert!((solo.tree_draft - d.tree_draft).abs() < 1e-15);
    }

    #[test]
    fn split_measured_batched_divides_only_measured_stages() {
        let d = durations();
        let s = split_measured_batched(&d, 4.0, 2.0);
        assert!((s.verify - d.verify / 4.0).abs() < 1e-15);
        assert!((s.tree_draft - d.tree_draft / 2.0).abs() < 1e-15);
        assert!((s.accept - d.accept).abs() < 1e-15);
        // NaN rider counts (stage never batched) pass through.
        let n = split_measured_batched(&d, f64::NAN, f64::NAN);
        assert!((n.verify - d.verify).abs() < 1e-15);
        assert!((n.tree_draft - d.tree_draft).abs() < 1e-15);
    }

    #[test]
    fn batched_search_still_prefers_overlap_for_expensive_cpu() {
        let mut d = durations();
        d.accept = 3e-3;
        d.bookkeep = 3e-3;
        let lat = lat_model();
        let shape =
            BatchShape { sessions: 4, verify_rows: 16, draft_width: 8, batch_draft: true };
        let (p, t) = search_best_plan_batched(&d, &shape, &lat);
        assert!(p.aot_tail && p.aot_head, "picked {}", p.name());
        let amortized = amortize_draft(
            &amortize_verify(&d, 4, 16, &lat.verifier),
            4,
            8,
            &lat.drafter,
        );
        assert!(t < plan_latency(&amortized, Plan::SEQUENTIAL));
    }

    #[test]
    fn resolve_batched_honours_explicit_choices() {
        let d = durations();
        let lat = lat_model();
        let shape =
            BatchShape { sessions: 4, verify_rows: 16, draft_width: 8, batch_draft: false };
        assert_eq!(
            resolve_batched(SchedulePlan::Sequential, &d, &shape, &lat),
            Plan::SEQUENTIAL
        );
        assert!(resolve_batched(SchedulePlan::AotTail, &d, &shape, &lat).aot_tail);
    }

    #[test]
    fn clamp_tree_budget_tracks_pool_headroom() {
        // Roomy pool: the envelope passes through untouched.
        assert_eq!(clamp_tree_budget(40, 200), 40);
        // Tight pool: at most half the reachable slots go to speculation.
        assert_eq!(clamp_tree_budget(40, 30), 15);
        // Starved pool: floored at 2 while the pool can still supply it.
        assert_eq!(clamp_tree_budget(40, 3), 2);
        assert_eq!(clamp_tree_budget(40, 2), 2);
    }

    #[test]
    fn clamp_tree_budget_never_exceeds_a_dry_pool() {
        // The old floor of 2 exceeded `available` when the pool was dry,
        // admitting sessions doomed to an immediate PoolExhausted →
        // preempt → resume churn loop. The budget must respect actual
        // headroom instead.
        assert_eq!(clamp_tree_budget(40, 0), 0, "dry pool must yield a zero budget");
        assert_eq!(clamp_tree_budget(40, 1), 1);
        for avail in 0..64usize {
            assert!(
                clamp_tree_budget(40, avail) <= avail.max(2),
                "budget exceeds headroom at available={avail}"
            );
            if avail >= 2 {
                assert!(clamp_tree_budget(40, avail) >= 2, "floor lost at {avail}");
            }
        }
    }

    #[test]
    fn windowed_stage_series_forget_cold_start_outliers() {
        // Regression (plan-search staleness): a slow first iteration —
        // the lazy graph-compile stall — must stop dominating the plan
        // choice once enough steady-state iterations have been recorded.
        // Stage series are recorded with `record_windowed`, so the
        // lifetime mean ages the outlier out entirely.
        const W: usize = 32;
        let mut rec = crate::metrics::Recorder::new();
        // Cold start: a 1-second verify (compile stall).
        rec.record_windowed("stage.verify", 1.0, W);
        rec.record_windowed("stage.tail_draft", 1.0, W);
        let skewed = StageDurations::from_recorder(&rec, 0.5);
        // With only the outlier, AOT-tail looks catastrophic (a 1 s tail
        // draft the accept walk cannot hide).
        assert!(skewed.tail_draft > 0.5);
        // Steady state: W fast iterations evict the outlier.
        for _ in 0..W {
            rec.record_windowed("stage.verify", 6e-3, W);
            rec.record_windowed("stage.tail_draft", 1.2e-3, W);
            rec.record_windowed("stage.accept", 3e-3, W);
            rec.record_windowed("stage.bookkeep", 3e-3, W);
            rec.record_windowed("stage.head_draft", 1e-3, W);
            rec.record_windowed("stage.tree_draft", 4e-3, W);
            rec.record_windowed("stage.cpu_build", 0.5e-3, W);
            rec.record_windowed("stage.cpu_mask", 0.1e-3, W);
            rec.record_windowed("stage.cpu_walk", 0.4e-3, W);
        }
        let steady = StageDurations::from_recorder(&rec, 0.6);
        assert!(
            (steady.verify - 6e-3).abs() < 1e-9,
            "outlier still skews the mean: {}",
            steady.verify
        );
        assert!((steady.tail_draft - 1.2e-3).abs() < 1e-9);
        // And the plan search now picks the overlap the steady state
        // justifies (expensive CPU, cheap tail draft).
        let (p, _) = search_best_plan(&steady);
        assert!(p.aot_tail, "stale outlier would have vetoed AOT-tail: {}", p.name());
    }

    #[test]
    fn ladder_escalates_one_rung_at_a_time_and_saturates() {
        let mut l = DegradationLadder::new();
        assert!(!l.pressured());
        assert_eq!(l.escalate(), RUNG_SHRINK_BUDGET);
        assert_eq!(l.escalate(), RUNG_SKIP_DRAFT);
        assert_eq!(l.escalate(), RUNG_CHUNK_HARDER);
        assert!(!l.at_preempt(), "three gentle rungs before preemption");
        assert_eq!(l.escalate(), RUNG_PREEMPT);
        assert!(l.at_preempt());
        assert_eq!(l.escalate(), RUNG_PREEMPT, "saturates at the top");
    }

    #[test]
    fn ladder_relaxes_with_hysteresis() {
        let mut l = DegradationLadder::new();
        l.escalate();
        l.escalate();
        // One clean round is not enough to step down…
        assert!(!l.relax());
        assert_eq!(l.rung(), RUNG_SKIP_DRAFT);
        // …an exhaustion resets the streak…
        for _ in 0..LADDER_RELAX_ROUNDS - 2 {
            assert!(!l.relax());
        }
        l.escalate();
        assert_eq!(l.rung(), RUNG_CHUNK_HARDER);
        // …and a full clean streak steps down exactly one rung.
        for _ in 0..LADDER_RELAX_ROUNDS - 1 {
            assert!(!l.relax());
        }
        assert!(l.relax());
        assert_eq!(l.rung(), RUNG_SKIP_DRAFT);
        // Fully relaxing reaches rung 0 and stays there.
        for _ in 0..3 * LADDER_RELAX_ROUNDS {
            l.relax();
        }
        assert_eq!(l.rung(), 0);
        assert!(!l.relax(), "rung 0 never underflows");
    }

    #[test]
    fn estimate_is_positive_and_ordered() {
        let lat = crate::objective::LatencyModel {
            drafter: crate::objective::LatencyCurve::new(&[(1, 1e-3), (8, 1.5e-3)]),
            verifier: crate::objective::LatencyCurve::new(&[(1, 5e-3), (64, 2e-2)]),
            cpu_overhead: 1e-3,
        };
        let d = StageDurations::estimate(&lat, 4, 8, 32, 4);
        assert!(d.tree_draft > d.head_draft);
        assert!(d.verify > 0.0);
        // The CPU split sums to the full overhead — nothing dropped.
        let cpu = d.cpu_build + d.cpu_mask + d.cpu_walk + d.accept + d.bookkeep;
        assert!((cpu - lat.cpu_overhead).abs() < 1e-12);
    }

    #[test]
    fn cpu_mask_is_priced_into_every_plan() {
        // Mask assembly is serial between draft and verify: no plan can
        // hide it, so adding Δ to cpu_mask adds exactly Δ to every plan.
        let d = durations();
        let mut heavier = d;
        heavier.cpu_mask += 2e-3;
        for p in Plan::ALL {
            let delta = plan_latency(&heavier, p) - plan_latency(&d, p);
            assert!((delta - 2e-3).abs() < 1e-12, "{} hid mask CPU", p.name());
        }
    }

    #[test]
    fn cpu_walk_prices_with_accept() {
        // The split acceptance stage prices as a sum: moving cost between
        // cpu_walk and accept changes no plan's latency.
        let d = durations();
        let mut moved = d;
        moved.cpu_walk = d.accept;
        moved.accept = d.cpu_walk;
        for p in Plan::ALL {
            let a = plan_latency(&d, p);
            let b = plan_latency(&moved, p);
            assert!((a - b).abs() < 1e-15, "{} distinguishes the split", p.name());
        }
    }

    #[test]
    fn steal_move_targets_deep_backlogs_and_light_destinations() {
        // Worker 1's backlog (6) exceeds the threshold (2); worker 2 is
        // the lightest destination.
        assert_eq!(steal_move(&[1, 6, 0], &[3, 6, 1], 2), Some((1, 2)));
        // Under the threshold: balanced, no move.
        assert_eq!(steal_move(&[1, 2, 0], &[3, 6, 1], 2), None);
        // A move that would not leave the destination strictly lighter
        // is refused (no ping-pong between near-equal workers).
        assert_eq!(steal_move(&[0, 5], &[4, 5], 2), None);
        // Ties break toward the lowest index on both sides.
        assert_eq!(steal_move(&[5, 5, 0], &[9, 9, 0], 2), Some((0, 2)));
        // Degenerate fleets never move anything.
        assert_eq!(steal_move(&[9], &[9], 2), None);
        assert_eq!(steal_move(&[], &[], 0), None);
    }
}
