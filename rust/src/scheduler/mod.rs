//! Stage-based scheduling runtime — §5 of the paper.
//!
//! One speculative iteration decomposes into stages with a dependency
//! graph (Fig. 9-(c)):
//!
//! ```text
//!   HeadDraft → TreeDraft(×D) → Prune → Verify → Accept → Bookkeep
//!                                   ↘ TailDraft ↗    ↘ next HeadDraft
//! ```
//!
//! Two resources execute stages: the **device** (model calls, FIFO) and the
//! **CPU** (tree building, masks, acceptance walk, cache management). The
//! naive plan serialises everything; *ahead-of-time* execution breaks two
//! dependencies speculatively (§5.1):
//!
//! * **AOT tail draft** — instead of conditionally drafting the next-root
//!   continuation after acceptance, the top leaf continuations are drafted
//!   speculatively, queued right behind verification, overlapping with the
//!   CPU acceptance walk. A superset of the needed tokens is computed; the
//!   accepted one is reused, the rest discarded.
//! * **AOT head draft** — the next iteration's head draft is issued the
//!   moment the bonus token is known, overlapping drafter execution with
//!   cache-management bookkeeping.
//!
//! [`search_best_plan`] is the profile-guided execution-plan search of
//! §5.2: with measured per-stage durations it list-schedules each candidate
//! plan on the two resources and picks the minimum-latency one. The search
//! space is tiny (the paper's "well-defined dependency graph"), so an
//! exhaustive sweep is exact.

use crate::config::SchedulePlan;

/// The concrete overlap decisions for one iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Plan {
    /// Speculatively draft next-root continuations behind the verify.
    pub aot_tail: bool,
    /// Issue the next head draft before bookkeeping finishes.
    pub aot_head: bool,
}

impl Plan {
    /// The no-overlap plan.
    pub const SEQUENTIAL: Plan = Plan { aot_tail: false, aot_head: false };
    /// Every plan in the (tiny) search space.
    pub const ALL: [Plan; 4] = [
        Plan { aot_tail: false, aot_head: false },
        Plan { aot_tail: true, aot_head: false },
        Plan { aot_tail: false, aot_head: true },
        Plan { aot_tail: true, aot_head: true },
    ];

    /// Stable plan label (config / logs).
    pub fn name(&self) -> &'static str {
        match (self.aot_tail, self.aot_head) {
            (false, false) => "sequential",
            (true, false) => "aot_tail",
            (false, true) => "aot_head",
            (true, true) => "aot_tail_head",
        }
    }
}

/// Measured (or estimated) seconds per stage of one iteration.
#[derive(Debug, Clone, Copy)]
pub struct StageDurations {
    /// Drafter call on the confirmed root (width 1).
    pub head_draft: f64,
    /// All D equal-growth drafter calls together.
    pub tree_draft: f64,
    /// CPU: frontier updates + pruning DP + mask building.
    pub cpu_build: f64,
    /// Verifier call on the pruned tree.
    pub verify: f64,
    /// Speculative tail-draft drafter call (only issued under AOT-tail).
    pub tail_draft: f64,
    /// CPU acceptance walk.
    pub accept: f64,
    /// CPU cache management / bookkeeping.
    pub bookkeep: f64,
    /// Probability that the AOT tail draft covers the next head token
    /// (measured online; determines how often the head draft is free).
    pub tail_hit_rate: f64,
}

impl StageDurations {
    /// Measured stage durations from one generation's recorder (the
    /// per-session plan-search input). Each decode task carries its own
    /// recorder, so under multi-session interleaving every session's plan
    /// search sees *its* stage timings, not a blend of whoever shared the
    /// device — a session decoding long prompts and a session decoding
    /// short ones can legitimately pick different plans. Missing series
    /// fall back to the floor values (the `max` with NaN selects the
    /// floor), matching the pre-measurement estimate's scale.
    pub fn from_recorder(rec: &crate::metrics::Recorder, tail_hit_rate: f64) -> Self {
        Self {
            head_draft: rec.mean("stage.head_draft").max(1e-6),
            tree_draft: rec.mean("stage.tree_draft").max(1e-6),
            cpu_build: rec.mean("stage.cpu_build").max(1e-7),
            verify: rec.mean("stage.verify").max(1e-6),
            tail_draft: rec.mean("stage.tail_draft").max(1e-6),
            accept: rec.mean("stage.accept").max(1e-7),
            bookkeep: rec.mean("stage.bookkeep").max(1e-7),
            tail_hit_rate,
        }
    }

    /// Rough estimate from a latency model before any measurement exists.
    pub fn estimate(
        lat: &crate::objective::LatencyModel,
        depth: usize,
        width: usize,
        w_verify: usize,
        tail_width: usize,
    ) -> Self {
        Self {
            head_draft: lat.t_draft(1),
            tree_draft: depth as f64 * lat.t_draft(width),
            cpu_build: lat.cpu_overhead * 0.5,
            verify: lat.t_verify(w_verify),
            tail_draft: lat.t_draft(tail_width),
            accept: lat.cpu_overhead * 0.25,
            bookkeep: lat.cpu_overhead * 0.25,
            tail_hit_rate: 0.5,
        }
    }
}

/// Expected wall-clock seconds of one iteration under `plan`.
///
/// Accounting is per-iteration-closed: each iteration is charged its own
/// head draft at the start; AOT transforms convert serial segments into
/// `max(device, cpu)` overlaps and discount the head draft by the tail
/// hit rate:
///
/// ```text
/// sequential : head + tree + build + verify + accept + bookkeep
/// aot_tail   : (1-hit)·head + tree + build + verify + max(tail, accept) + bookkeep
/// aot_head   : tree + build + verify + accept + max(head, bookkeep)
/// both       : (tree + build + verify + max(tail, accept)
///               + max((1-hit)·head, bookkeep))
/// ```
pub fn plan_latency(d: &StageDurations, plan: Plan) -> f64 {
    let core = d.tree_draft + d.cpu_build + d.verify;
    match (plan.aot_tail, plan.aot_head) {
        (false, false) => d.head_draft + core + d.accept + d.bookkeep,
        (true, false) => {
            (1.0 - d.tail_hit_rate) * d.head_draft
                + core
                + d.tail_draft.max(d.accept)
                + d.bookkeep
        }
        (false, true) => core + d.accept + d.head_draft.max(d.bookkeep),
        (true, true) => {
            core + d.tail_draft.max(d.accept)
                + ((1.0 - d.tail_hit_rate) * d.head_draft).max(d.bookkeep)
        }
    }
}

/// Per-session stage durations when `sessions` concurrent sessions share
/// one batched verifier call (cross-session batching, DESIGN.md §9).
///
/// The verify stage is the only device call the batch merges, so its cost
/// amortizes across the riders: each session is charged `verify /
/// sessions` of the (wider, but sub-linear) batched call. Draft stages
/// stay per-session — drafting is not batched — and CPU stages are
/// per-session by construction. Feeding the amortized durations to
/// [`search_best_plan`] yields the plan the batched regime actually
/// wants: with the verify share shrunk, hiding the CPU walk behind AOT
/// stages matters *more*, never less.
pub fn amortize_verify(d: &StageDurations, sessions: usize) -> StageDurations {
    let s = sessions.max(1) as f64;
    StageDurations { verify: d.verify / s, ..*d }
}

/// Plan search under an S-way batched verify: [`search_best_plan`] over
/// the [`amortize_verify`] durations.
pub fn search_best_plan_batched(d: &StageDurations, sessions: usize) -> (Plan, f64) {
    search_best_plan(&amortize_verify(d, sessions))
}

/// Clamps a config-derived per-iteration tree budget to the shared
/// pool's current headroom (paged serving, DESIGN.md §10): a session may
/// spend at most half the slots it could still reach on speculation, so
/// the other half stays available for the committed prefix it is about
/// to grow (and for its neighbours). Floored at 2 — a starved session
/// still drafts a root plus one candidate rather than wedging at zero.
pub fn clamp_tree_budget(envelope: usize, available: usize) -> usize {
    envelope.min((available / 2).max(2))
}

/// Exhaustive profile-guided plan search (§5.2).
pub fn search_best_plan(d: &StageDurations) -> (Plan, f64) {
    // Most-overlapping plans first so exact ties resolve toward overlap
    // (it additionally hides jitter the point estimates cannot see).
    let mut order = Plan::ALL;
    order.reverse();
    order
        .iter()
        .map(|&p| (p, plan_latency(d, p)))
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap()
}

/// Resolves a config-level schedule choice into a concrete plan.
pub fn resolve(schedule: SchedulePlan, durations: &StageDurations) -> Plan {
    match schedule {
        SchedulePlan::Sequential => Plan::SEQUENTIAL,
        SchedulePlan::AotTail => Plan { aot_tail: true, aot_head: false },
        SchedulePlan::AotTailHead => Plan { aot_tail: true, aot_head: true },
        SchedulePlan::ProfileSearch => search_best_plan(durations).0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn durations() -> StageDurations {
        StageDurations {
            head_draft: 1.0e-3,
            tree_draft: 4.0e-3,
            cpu_build: 0.5e-3,
            verify: 6.0e-3,
            tail_draft: 1.2e-3,
            accept: 0.8e-3,
            bookkeep: 0.7e-3,
            tail_hit_rate: 0.6,
        }
    }

    #[test]
    fn overlap_never_hurts_in_the_model() {
        let d = durations();
        let seq = plan_latency(&d, Plan::SEQUENTIAL);
        for p in Plan::ALL {
            assert!(
                plan_latency(&d, p) <= seq + 1e-12,
                "{} slower than sequential",
                p.name()
            );
        }
    }

    #[test]
    fn search_picks_full_overlap_when_cpu_is_expensive() {
        let mut d = durations();
        d.accept = 3e-3;
        d.bookkeep = 3e-3;
        let (p, t) = search_best_plan(&d);
        assert!(p.aot_tail && p.aot_head, "picked {}", p.name());
        assert!(t < plan_latency(&d, Plan::SEQUENTIAL));
    }

    #[test]
    fn sequential_wins_only_by_tie() {
        // With zero CPU cost there is nothing to overlap: all plans equal
        // except the tail-draft device cost under AOT-tail.
        let d = StageDurations {
            head_draft: 1e-3,
            tree_draft: 4e-3,
            cpu_build: 0.0,
            verify: 6e-3,
            tail_draft: 2e-3,
            accept: 0.0,
            bookkeep: 0.0,
            tail_hit_rate: 0.0,
        };
        let (p, _) = search_best_plan(&d);
        // A miss-only tail draft pays 2ms for nothing; search must not
        // pick it.
        assert!(!p.aot_tail, "picked {}", p.name());
    }

    #[test]
    fn resolve_honours_explicit_choices() {
        let d = durations();
        assert_eq!(resolve(SchedulePlan::Sequential, &d), Plan::SEQUENTIAL);
        assert!(resolve(SchedulePlan::AotTail, &d).aot_tail);
        let p = resolve(SchedulePlan::AotTailHead, &d);
        assert!(p.aot_tail && p.aot_head);
    }

    #[test]
    fn from_recorder_reads_measured_stages_and_floors_missing_ones() {
        let mut rec = crate::metrics::Recorder::new();
        rec.record("stage.head_draft", 2e-3);
        rec.record("stage.tree_draft", 5e-3);
        rec.record("stage.verify", 7e-3);
        // cpu_build / tail_draft / accept / bookkeep unmeasured.
        let d = StageDurations::from_recorder(&rec, 0.4);
        assert!((d.head_draft - 2e-3).abs() < 1e-12);
        assert!((d.tree_draft - 5e-3).abs() < 1e-12);
        assert!((d.verify - 7e-3).abs() < 1e-12);
        assert_eq!(d.cpu_build, 1e-7, "missing series floors, not NaN");
        assert_eq!(d.tail_draft, 1e-6);
        assert!((d.tail_hit_rate - 0.4).abs() < 1e-12);
        // The floored durations feed the search without poisoning it.
        let (_, t) = search_best_plan(&d);
        assert!(t.is_finite());
    }

    #[test]
    fn amortized_verify_shrinks_with_batch_size() {
        let d = durations();
        for p in Plan::ALL {
            let solo = plan_latency(&d, p);
            let mut prev = solo;
            for s in [2usize, 4, 8] {
                let t = plan_latency(&amortize_verify(&d, s), p);
                assert!(t <= prev + 1e-12, "{} got slower at {s} sessions", p.name());
                prev = t;
            }
        }
        // Non-verify stages are untouched.
        let a = amortize_verify(&d, 4);
        assert!((a.tree_draft - d.tree_draft).abs() < 1e-15);
        assert!((a.accept - d.accept).abs() < 1e-15);
        assert!((a.verify - d.verify / 4.0).abs() < 1e-15);
    }

    #[test]
    fn batched_search_still_prefers_overlap_for_expensive_cpu() {
        let mut d = durations();
        d.accept = 3e-3;
        d.bookkeep = 3e-3;
        let (p, t) = search_best_plan_batched(&d, 4);
        assert!(p.aot_tail && p.aot_head, "picked {}", p.name());
        assert!(t < plan_latency(&amortize_verify(&d, 4), Plan::SEQUENTIAL));
    }

    #[test]
    fn clamp_tree_budget_tracks_pool_headroom() {
        // Roomy pool: the envelope passes through untouched.
        assert_eq!(clamp_tree_budget(40, 200), 40);
        // Tight pool: at most half the reachable slots go to speculation.
        assert_eq!(clamp_tree_budget(40, 30), 15);
        // Starved pool: floored, never zero (the task must still draft).
        assert_eq!(clamp_tree_budget(40, 3), 2);
        assert_eq!(clamp_tree_budget(40, 0), 2);
    }

    #[test]
    fn estimate_is_positive_and_ordered() {
        let lat = crate::objective::LatencyModel {
            drafter: crate::objective::LatencyCurve::new(&[(1, 1e-3), (8, 1.5e-3)]),
            verifier: crate::objective::LatencyCurve::new(&[(1, 5e-3), (64, 2e-2)]),
            cpu_overhead: 1e-3,
        };
        let d = StageDurations::estimate(&lat, 4, 8, 32, 4);
        assert!(d.tree_draft > d.head_draft);
        assert!(d.verify > 0.0);
    }
}
