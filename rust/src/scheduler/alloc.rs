//! Round-level global speculation allocator (DESIGN.md §15).
//!
//! Every batched round packs several sessions' trees into shared device
//! calls, so verification rows are a *round-wide* resource: a row spent
//! on a low-acceptance session buys almost no accepted tokens but still
//! widens (and slows) the packed verify call for everyone. This module
//! solves one small allocation problem per round — distribute a global
//! verification-token budget across the packed sessions by marginal
//! expected-accepted-tokens per unit of packed-call latency — instead of
//! handing every session the same uniform clamp.
//!
//! The model is the truncated-geometric acceptance chain the Eq. 3
//! objective already uses: a session whose per-level acceptance estimate
//! is `q` expects `q^(k+1)` additional accepted tokens from its
//! `(k+1)`-th verification row, so marginal gains are decreasing and the
//! greedy grant order is exactly optimal for the separable concave
//! knapsack. Latency enters through the verifier's profiled
//! [`LatencyCurve`]: a grant stops being worth buying once its expected
//! gain falls below a small fraction of the marginal packed-row cost.
//!
//! Two invariants matter for correctness and reproducibility:
//!
//! * the total never exceeds the global budget or the pool headroom, and
//!   no session exceeds its static call envelope — so the satellite
//!   headroom-snapshot fix (one pool read per round, grants sum to at
//!   most the snapshot) falls out of the allocator for free;
//! * with indistinguishable sessions (equal acceptance estimates and
//!   equal SLO class) the allocation degenerates to the deterministic
//!   uniform water-fill, which is also the `--no-global-alloc` fallback
//!   path — identical inputs therefore produce bit-identical streams.

use crate::config::GRAPH_WIDTHS;
use crate::objective::LatencyCurve;

/// One packed session's claim on the round's verification budget.
#[derive(Debug, Clone, Copy)]
pub struct SessionDemand {
    /// Per-level acceptance estimate in `[0, 1)` (the probability that
    /// one more tree level covers the verifier's next token).
    pub q: f64,
    /// Static per-session cap: the configured verify envelope after any
    /// degradation-rung shrink (compiled graphs are sized for it).
    pub envelope: usize,
    /// This session's own KV headroom (paged sessions all report the
    /// shared pool; equal-partition sessions report their lease).
    pub headroom: usize,
    /// `true` biases shares toward the latency SLO class.
    pub latency_class: bool,
}

impl SessionDemand {
    /// The hard per-session cap: envelope ∧ headroom.
    fn cap(&self) -> usize {
        self.envelope.min(self.headroom)
    }
}

/// Multiplicative marginal-gain bias for latency-class sessions: under
/// contention a latency-class session wins ties (and near-ties) for the
/// next verification row over a throughput-class one.
pub const LATENCY_BIAS: f64 = 1.25;

/// A grant must buy at least this fraction of an accepted token per
/// normalized marginal row cost before the greedy stops spending on it —
/// rows cheaper than this are pure packed-call padding.
const MIN_MARGINAL_GAIN: f64 = 0.02;

/// Snaps a budget down to the static call envelopes: the largest
/// compiled graph width that fits, so per-session row counts stay on
/// the width grid the packed-call planner pads to. Budgets below the
/// smallest width pass through (a 1-row root-only verify is always
/// representable).
pub fn snap_to_envelope(budget: usize, envelope: usize) -> usize {
    let b = budget.min(envelope);
    GRAPH_WIDTHS.iter().copied().filter(|&w| w <= b).max().unwrap_or(b)
}

/// The deterministic uniform fallback (`--no-global-alloc`, and the
/// degenerate case of [`allocate_verify_budget`]): water-fill the
/// budget one row at a time, round-robin over every session still under
/// its cap. With an ample budget every session reaches its cap — the
/// legacy per-session clamp — and under contention the shares differ by
/// at most one row.
pub fn uniform_verify_budget(demands: &[SessionDemand], global_budget: usize) -> Vec<usize> {
    let n = demands.len();
    let mut budgets = vec![0usize; n];
    let mut remaining = global_budget;
    let mut open = n;
    while remaining > 0 && open > 0 {
        open = 0;
        for (b, d) in budgets.iter_mut().zip(demands) {
            if *b >= d.cap() || remaining == 0 {
                continue;
            }
            *b += 1;
            remaining -= 1;
            open += 1;
        }
    }
    budgets
}

/// Solves the round's global allocation: distributes at most
/// `min(global_budget, pool_headroom)` verification rows across
/// `demands` by greedy marginal expected-accepted-tokens, biased by SLO
/// class and priced against the verifier's latency `curve` when one is
/// supplied. Returns one budget per demand, each snapped to the static
/// call envelopes.
///
/// Guarantees (property-tested): `Σ budgets ≤ global_budget`,
/// `Σ budgets ≤ pool_headroom`, `budgets[i] ≤ demands[i].envelope`, and
/// equal acceptance estimates + equal SLO classes degenerate to
/// [`uniform_verify_budget`] exactly.
pub fn allocate_verify_budget(
    demands: &[SessionDemand],
    global_budget: usize,
    pool_headroom: usize,
    curve: Option<&LatencyCurve>,
) -> Vec<usize> {
    let n = demands.len();
    if n == 0 {
        return Vec::new();
    }
    let total = global_budget.min(pool_headroom);

    // Indistinguishable sessions: the greedy would round-robin anyway;
    // take the uniform path so the degenerate case is *exactly* the
    // `--no-global-alloc` fallback (bit-identical budgets).
    let uniform = demands.windows(2).all(|w| {
        (w[0].q - w[1].q).abs() < 1e-9 && w[0].latency_class == w[1].latency_class
    });
    if uniform {
        return uniform_verify_budget(demands, total);
    }

    // Floors: every live session gets one row (the root / bonus chain)
    // as long as the budget covers it — a zero-row session could not
    // commit even its bonus token.
    let mut budgets = vec![0usize; n];
    let mut granted = 0usize;
    for (b, d) in budgets.iter_mut().zip(demands) {
        if granted >= total || d.cap() == 0 {
            continue;
        }
        *b = 1;
        granted += 1;
    }

    // Greedy marginal grants: session `i` holding `b` rows values its
    // next row at `bias_i · q_i^b` expected accepted tokens (the root
    // row is certain; row `b+1` extends the acceptance chain by one
    // level). Decreasing in `b`, so the argmax order is optimal.
    let unit_cost = curve.map(|c| c.at(1.0).max(1e-12));
    while granted < total {
        let mut best: Option<(usize, f64)> = None;
        for (i, d) in demands.iter().enumerate() {
            if budgets[i] >= d.cap() || budgets[i] == 0 {
                continue;
            }
            let bias = if d.latency_class { LATENCY_BIAS } else { 1.0 };
            let gain = bias * d.q.clamp(0.0, 0.999).powi(budgets[i] as i32);
            let better = match best {
                None => true,
                // Strict improvement only: ties resolve to the lowest
                // index, then the smallest holding (set by scan order).
                Some((j, g)) => {
                    gain > g + 1e-15 || (gain > g - 1e-15 && budgets[i] < budgets[j])
                }
            };
            if better {
                best = Some((i, gain));
            }
        }
        let Some((i, gain)) = best else { break };
        // Latency pricing: the marginal packed-row cost at the current
        // total, normalized by the one-row call. Rows whose expected
        // yield is under `MIN_MARGINAL_GAIN` of that cost are padding —
        // stop (every later grant is worth even less).
        if let (Some(c), Some(u)) = (curve, unit_cost) {
            let w = granted as f64;
            let marginal = (c.at(w + 1.0) - c.at(w)).max(0.0) / u;
            if gain < MIN_MARGINAL_GAIN * marginal.max(1e-3) {
                break;
            }
        } else if gain < MIN_MARGINAL_GAIN {
            break;
        }
        budgets[i] += 1;
        granted += 1;
    }

    // Snap to the compiled-width grid so packed verify calls keep
    // hitting the static envelopes (never snaps *up*, so every bound
    // above survives).
    for (b, d) in budgets.iter_mut().zip(demands) {
        *b = snap_to_envelope(*b, d.envelope);
    }
    budgets
}

/// One round's grant vector rolled up for the observability layer
/// (DESIGN.md §17): the serving scheduler mirrors each per-session grant
/// as an `alloc_grant` trace instant and feeds this summary to the
/// `ygg_alloc_budget_rows` gauge and the flight-recorder dump header.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct GrantSummary {
    /// Sessions granted at least one verification row.
    pub sessions: usize,
    /// Total verification rows granted this round.
    pub total: usize,
    /// Smallest non-zero grant (0 when nothing was granted).
    pub min: usize,
    /// Largest grant.
    pub max: usize,
}

impl GrantSummary {
    /// Folds one session's grant in. Zero-row grants (sessions the
    /// allocator skipped) are ignored — they would poison the min — and
    /// the scheduler loop calls this per live session precisely so the
    /// summary needs no intermediate `Vec` on the steady path.
    pub fn add(&mut self, rows: usize) {
        if rows == 0 {
            return;
        }
        self.sessions += 1;
        self.total += rows;
        self.min = if self.min == 0 { rows } else { self.min.min(rows) };
        self.max = self.max.max(rows);
    }

    /// True when no session received a grant this round.
    pub fn is_empty(&self) -> bool {
        self.sessions == 0
    }
}

/// Rolls one round's per-session budgets up into a [`GrantSummary`].
/// A wide `max - min` spread under a near-uniform acceptance profile is
/// the telemetry smell that the greedy is starving someone.
pub fn summarize_grants(budgets: &[usize]) -> GrantSummary {
    let mut s = GrantSummary::default();
    for &b in budgets {
        s.add(b);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn d(q: f64, envelope: usize, headroom: usize, latency: bool) -> SessionDemand {
        SessionDemand { q, envelope, headroom, latency_class: latency }
    }

    #[test]
    fn equal_profiles_degenerate_to_the_uniform_water_fill() {
        let ds = vec![d(0.7, 16, 100, true); 4];
        let got = allocate_verify_budget(&ds, 64, 1000, None);
        assert_eq!(got, uniform_verify_budget(&ds, 64));
        assert_eq!(got, vec![16; 4], "ample budget reaches every cap");
    }

    #[test]
    fn high_acceptance_sessions_take_deeper_trees() {
        let ds = vec![d(0.9, 64, 1000, false), d(0.05, 64, 1000, false)];
        let got = allocate_verify_budget(&ds, 32, 1000, None);
        assert!(
            got[0] >= 8 * got[1].max(1),
            "easy session should dominate the split, got {got:?}"
        );
        assert!(got[1] >= 1, "hard session keeps its bonus row");
    }

    #[test]
    fn never_exceeds_budget_pool_or_envelope() {
        let ds = vec![d(0.9, 8, 5, false), d(0.6, 64, 5, true), d(0.3, 4, 5, false)];
        let got = allocate_verify_budget(&ds, 9, 5, None);
        assert!(got.iter().sum::<usize>() <= 5, "pool bound, got {got:?}");
        for (g, dd) in got.iter().zip(&ds) {
            assert!(*g <= dd.envelope);
        }
    }

    #[test]
    fn latency_class_wins_near_ties() {
        let ds = vec![d(0.5, 8, 100, true), d(0.5 + 1e-6, 8, 100, false)];
        let got = allocate_verify_budget(&ds, 8, 100, None);
        assert!(got[0] >= got[1], "bias must favor the latency class, got {got:?}");
    }

    #[test]
    fn budgets_snap_to_the_width_grid() {
        let ds = vec![d(0.95, 64, 1000, false), d(0.2, 64, 1000, false)];
        let got = allocate_verify_budget(&ds, 40, 1000, None);
        for &g in &got {
            assert!(
                g <= 1 || GRAPH_WIDTHS.contains(&g),
                "budget {g} is off the compiled-width grid"
            );
        }
    }

    #[test]
    fn curve_pricing_stops_buying_padding_rows() {
        // Steep verifier curve: rows past the first widths cost a lot.
        let curve = LatencyCurve::new(&[(1, 1e-3), (64, 1.0)]);
        let ds = vec![d(0.3, 64, 1000, false), d(0.2, 64, 1000, false)];
        let spent: usize =
            allocate_verify_budget(&ds, 128, 1000, Some(&curve)).iter().sum();
        let free: usize = allocate_verify_budget(&ds, 128, 1000, None).iter().sum();
        assert!(spent <= free, "pricing can only trim the spend");
        assert!(spent < 128, "a steep curve must leave budget unspent");
    }

    #[test]
    fn grant_summary_skips_zero_rows_and_tracks_the_spread() {
        assert_eq!(summarize_grants(&[]), GrantSummary::default());
        assert_eq!(summarize_grants(&[0, 0]), GrantSummary::default());
        let s = summarize_grants(&[4, 0, 1, 8]);
        assert_eq!(s, GrantSummary { sessions: 3, total: 13, min: 1, max: 8 });
    }

    #[test]
    fn uniform_water_fill_is_fair_under_contention() {
        let ds = vec![d(0.5, 16, 100, false); 3];
        let got = uniform_verify_budget(&ds, 10);
        assert_eq!(got.iter().sum::<usize>(), 10);
        let (lo, hi) = (got.iter().min().unwrap(), got.iter().max().unwrap());
        assert!(hi - lo <= 1, "shares differ by at most one row, got {got:?}");
    }
}
