//! Static tree *shapes* for the baseline structures (Fig. 3 / Fig. 11).
//!
//! A [`TreeShape`] is a topology without tokens: node 0 is the root, every
//! other shape-node says "attach the rank-`r` drafter candidate under
//! parent `p`". Engines instantiate a shape level by level: all nodes at
//! depth *d* are materialised from their parents' drafter distributions and
//! evaluated in one width-padded drafter call — so even the *static*
//! baselines run on the compiled static-width graphs, exactly like the
//! paper's compilation-friendly baselines (Sequoia, vLLM-Spec).
//!
//! Three constructions:
//! * [`TreeShape::sequence`] — classic chain speculation.
//! * [`TreeShape::k_ary`] — SpecInfer-style top-K expansion.
//! * [`TreeShape::sequoia`] — the Sequoia dynamic program: given a
//!   rank-acceptance vector measured on a calibration set, find the
//!   `budget`-node tree maximising expected accepted length.


/// One non-root node of a static shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShapeNode {
    /// Index of the parent in the shape (0 = root).
    pub parent: usize,
    /// Candidate rank in the parent's drafter distribution (0 = top-1).
    pub rank: usize,
}

/// A static draft-tree topology. Node ids: 0 is the implicit root; node
/// `i >= 1` is `nodes[i-1]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TreeShape {
    /// Nodes in insertion order; node id `i + 1` is `nodes[i]`.
    pub nodes: Vec<ShapeNode>,
}

impl TreeShape {
    /// Chain of `depth` rank-0 nodes (vanilla sequence speculation).
    pub fn sequence(depth: usize) -> Self {
        let nodes = (0..depth).map(|i| ShapeNode { parent: i, rank: 0 }).collect();
        Self { nodes }
    }

    /// Full K-ary tree truncated to `budget` nodes, breadth-first
    /// (SpecInfer's static top-K construction).
    pub fn k_ary(k: usize, depth: usize, budget: usize) -> Self {
        let mut nodes = Vec::new();
        let mut depth_of = vec![0usize]; // per shape id (0 = root)
        let mut frontier = vec![0usize];
        'outer: while let Some(&parent) = frontier.first() {
            frontier.remove(0);
            if depth_of[parent] >= depth {
                continue;
            }
            for rank in 0..k {
                if nodes.len() >= budget {
                    break 'outer;
                }
                nodes.push(ShapeNode { parent, rank });
                let id = nodes.len(); // shape id of the new node
                depth_of.push(depth_of[parent] + 1);
                frontier.push(id);
            }
        }
        Self { nodes }
    }

    /// Sequoia's offline construction: maximise expected accepted length
    /// for a `budget`-node tree under a rank-acceptance model.
    ///
    /// `accept_by_rank[r]` is the calibration-measured probability that the
    /// verifier accepts the drafter's rank-`r` candidate given its parent
    /// was accepted (non-increasing in `r`). The classic tree-DP:
    ///
    /// ```text
    /// S(m)    = 1 + F(m-1, 0)                         value of an m-node accepted subtree
    /// F(b, r) = max_{m=0..b} [m>0: p_r·S(m) + F(b-m, r+1); m=0: F(b, r+1)]
    /// ```
    pub fn sequoia(accept_by_rank: &[f64], budget: usize) -> Self {
        assert!(!accept_by_rank.is_empty());
        let rmax = accept_by_rank.len();
        // Row width of the flattened (budget+1) × (rmax+1) DP tables:
        // entry (b, r) lives at b * rw + r — one allocation per table
        // instead of budget+1 inner Vecs.
        let rw = rmax + 1;
        // s[m] for m in 0..=budget (s[0] = 0 unused), f[b * rw + r].
        let mut s = vec![0.0f64; budget + 1];
        let mut f = vec![0.0f64; (budget + 1) * rw];
        // choice[b * rw + r] = number of nodes m given to the rank-r child.
        let mut choice = vec![0usize; (budget + 1) * rw];

        for m in 1..=budget {
            // F rows only depend on S(m') for m' < m? No: F(b,·) uses
            // S(m'<=b); compute S in increasing m and F(b,·) for b = m-1
            // right before S(m) needs it. Simplest: recompute F fully each
            // m over budgets 0..m-1 — budget ≤ 64 keeps this trivial.
            for b in 0..m {
                for r in (0..rmax).rev() {
                    let skip = f[b * rw + r + 1];
                    let mut best = skip;
                    let mut best_m = 0usize;
                    for take in 1..=b {
                        let v = accept_by_rank[r] * s[take] + f[(b - take) * rw + r + 1];
                        if v > best + 1e-12 {
                            best = v;
                            best_m = take;
                        }
                    }
                    f[b * rw + r] = best;
                    choice[b * rw + r] = best_m;
                }
            }
            s[m] = 1.0 + f[(m - 1) * rw];
        }
        // Final forest table for the root with the full budget.
        for r in (0..rmax).rev() {
            let skip = f[budget * rw + r + 1];
            let mut best = skip;
            let mut best_m = 0usize;
            for take in 1..=budget {
                let v = accept_by_rank[r] * s[take] + f[(budget - take) * rw + r + 1];
                if v > best + 1e-12 {
                    best = v;
                    best_m = take;
                }
            }
            f[budget * rw + r] = best;
            choice[budget * rw + r] = best_m;
        }

        // Reconstruct.
        let mut shape = TreeShape { nodes: Vec::new() };
        build_forest(&mut shape, 0, budget, 0, &choice, rmax);
        shape
    }

    /// Node count (excluding the implicit root).
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True for the root-only shape.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Depth of shape node `id` (0 = root).
    pub fn depth_of(&self, id: usize) -> usize {
        let mut d = 0;
        let mut cur = id;
        while cur != 0 {
            cur = self.nodes[cur - 1].parent;
            d += 1;
        }
        d
    }

    /// Deepest node's depth.
    pub fn max_depth(&self) -> usize {
        (1..=self.nodes.len()).map(|i| self.depth_of(i)).max().unwrap_or(0)
    }

    /// Shape-node ids grouped by depth (1-based ids; level 0 = depth 1).
    /// Engines materialise one level per drafter call.
    pub fn levels(&self) -> Vec<Vec<usize>> {
        let mut levels: Vec<Vec<usize>> = Vec::new();
        for id in 1..=self.nodes.len() {
            let d = self.depth_of(id);
            if levels.len() < d {
                levels.resize(d, Vec::new());
            }
            levels[d - 1].push(id);
        }
        levels
    }

    /// Expected accepted length of this shape under a rank-acceptance
    /// model (used by tests and by the Fig. 11 theoretical comparison).
    pub fn expected_aal(&self, accept_by_rank: &[f64]) -> f64 {
        let mut path = vec![1.0f64]; // per shape id
        let mut total = 1.0; // the root / bonus token
        for (i, n) in self.nodes.iter().enumerate() {
            let p_edge = accept_by_rank.get(n.rank).copied().unwrap_or(0.0);
            let p = path[n.parent] * p_edge;
            path.push(p);
            let _ = i;
            total += p;
        }
        total
    }
}

/// Recursively appends the best forest under `parent` using `choice`
/// (flattened row-major, `(rmax + 1)`-wide rows).
fn build_forest(
    shape: &mut TreeShape,
    parent: usize,
    budget: usize,
    rank: usize,
    choice: &[usize],
    rmax: usize,
) {
    if budget == 0 || rank >= rmax {
        return;
    }
    let take = choice[budget * (rmax + 1) + rank];
    if take > 0 {
        shape.nodes.push(ShapeNode { parent, rank });
        let id = shape.nodes.len();
        // The child's subtree uses `take` nodes: itself + a (take-1) forest.
        build_forest(shape, id, take - 1, 0, choice, rmax);
        build_forest(shape, parent, budget - take, rank + 1, choice, rmax);
    } else {
        build_forest(shape, parent, budget, rank + 1, choice, rmax);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequence_is_a_chain() {
        let s = TreeShape::sequence(4);
        assert_eq!(s.len(), 4);
        assert_eq!(s.max_depth(), 4);
        assert_eq!(s.levels().iter().map(Vec::len).collect::<Vec<_>>(), vec![1, 1, 1, 1]);
        assert!(s.nodes.iter().all(|n| n.rank == 0));
    }

    #[test]
    fn k_ary_counts() {
        let s = TreeShape::k_ary(3, 2, 100);
        // depth1: 3 nodes, depth2: 9 nodes
        assert_eq!(s.len(), 12);
        let lv = s.levels();
        assert_eq!(lv[0].len(), 3);
        assert_eq!(lv[1].len(), 9);
    }

    #[test]
    fn k_ary_budget_truncates() {
        let s = TreeShape::k_ary(4, 8, 10);
        assert_eq!(s.len(), 10);
    }

    #[test]
    fn sequoia_degenerates_to_chain_when_only_rank0_accepts() {
        let p = [0.8, 0.0, 0.0];
        let s = TreeShape::sequoia(&p, 5);
        assert_eq!(s.len(), 5);
        assert_eq!(s.max_depth(), 5, "with p1=0 extra width is worthless: {:?}", s.nodes);
        assert!(s.nodes.iter().all(|n| n.rank == 0));
    }

    #[test]
    fn sequoia_widens_under_flat_acceptance() {
        // rank-insensitive acceptance: width is as good as depth per node,
        // but depth multiplies probabilities — optimal tree is bushy.
        let p = [0.5, 0.5, 0.5, 0.5];
        let s = TreeShape::sequoia(&p, 8);
        assert_eq!(s.len(), 8);
        assert!(s.max_depth() < 8, "flat acceptance must not give a chain");
    }

    #[test]
    fn sequoia_beats_naive_shapes_on_its_own_model() {
        let p = [0.7, 0.25, 0.08, 0.02];
        let budget = 12;
        let sq = TreeShape::sequoia(&p, budget);
        let chain = TreeShape::sequence(budget);
        let kary = TreeShape::k_ary(3, 3, budget);
        let v = |s: &TreeShape| s.expected_aal(&p);
        assert_eq!(sq.len(), budget);
        assert!(v(&sq) >= v(&chain) - 1e-9, "{} vs chain {}", v(&sq), v(&chain));
        assert!(v(&sq) >= v(&kary) - 1e-9, "{} vs kary {}", v(&sq), v(&kary));
    }

    #[test]
    fn expected_aal_of_chain_is_geometric_sum() {
        let s = TreeShape::sequence(3);
        let aal = s.expected_aal(&[0.5]);
        assert!((aal - (1.0 + 0.5 + 0.25 + 0.125)).abs() < 1e-9);
    }

    #[test]
    fn levels_cover_all_nodes_once() {
        let p = [0.6, 0.3, 0.1];
        let s = TreeShape::sequoia(&p, 20);
        let mut seen: Vec<usize> = s.levels().concat();
        seen.sort_unstable();
        assert_eq!(seen, (1..=20).collect::<Vec<_>>());
    }
}
