//! Token trees for speculative decoding.
//!
//! A [`TokenTree`] holds one iteration's draft: node 0 is the *root* — the
//! bonus token produced by the previous verification (or the last prompt
//! token right after prefill). Every other node is a candidate token whose
//! parent path is a possible continuation. The tree is built either by the
//! Equal-Growth algorithm ([`egt`]) or by one of the static structures
//! ([`shapes`]), then optionally pruned ([`crate::pruning`]) and verified in
//! a single target-model call.
//!
//! Nodes are stored in insertion order, which is guaranteed to be a
//! topological order (parents precede children) — several algorithms
//! (mask building, pruning DP, acceptance walks) rely on this.

pub mod egt;
pub mod mask;
pub mod shapes;

pub use egt::{grow_step, Expansion, Frontier};
pub use mask::{
    owner_words, pack_block_diagonal, pack_block_diagonal_bits, rows_confined,
    rows_confined_bits, rows_owned, rows_owned_bits, BitMask, MaskBuilder, RoundArena,
};
pub use shapes::TreeShape;

/// Index of a node inside a [`TokenTree`].
pub type NodeId = usize;

/// One iteration's draft tree.
#[derive(Debug, Clone)]
pub struct TokenTree {
    tokens: Vec<u32>,
    parents: Vec<i32>, // -1 for the root
    depths: Vec<u32>,  // root = 0
    /// Drafter probability of this token given its parent path — the
    /// acceptance surrogate the paper uses for expected-AAL values.
    edge_probs: Vec<f32>,
    /// Product of edge probabilities along the path from the root
    /// (root = 1.0). This is the node's marginal expected-AAL value.
    path_probs: Vec<f32>,
    children: Vec<Vec<NodeId>>,
}

impl TokenTree {
    /// A fresh tree containing only the root token.
    pub fn new(root_token: u32) -> Self {
        Self {
            tokens: vec![root_token],
            parents: vec![-1],
            depths: vec![0],
            edge_probs: vec![1.0],
            path_probs: vec![1.0],
            children: vec![Vec::new()],
        }
    }

    /// Adds a candidate `token` under `parent` with drafter probability
    /// `edge_prob`; returns the new node's id.
    pub fn add_node(&mut self, parent: NodeId, token: u32, edge_prob: f32) -> NodeId {
        debug_assert!(parent < self.len());
        let id = self.tokens.len();
        self.tokens.push(token);
        self.parents.push(parent as i32);
        self.depths.push(self.depths[parent] + 1);
        self.edge_probs.push(edge_prob);
        self.path_probs.push(self.path_probs[parent] * edge_prob);
        self.children.push(Vec::new());
        self.children[parent].push(id);
        id
    }

    /// Node count (root included).
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    /// Always false — a tree has at least its root.
    pub fn is_empty(&self) -> bool {
        false // a tree always has its root
    }

    /// Token at `id`.
    pub fn token(&self, id: NodeId) -> u32 {
        self.tokens[id]
    }

    /// Parent of `id` (`None` for the root).
    pub fn parent(&self, id: NodeId) -> Option<NodeId> {
        (self.parents[id] >= 0).then(|| self.parents[id] as NodeId)
    }

    /// Depth of `id` (root = 0).
    pub fn depth(&self, id: NodeId) -> u32 {
        self.depths[id]
    }

    /// Drafter probability of `id` given its parent.
    pub fn edge_prob(&self, id: NodeId) -> f32 {
        self.edge_probs[id]
    }

    /// Product of edge probabilities from the root to `id`.
    pub fn path_prob(&self, id: NodeId) -> f32 {
        self.path_probs[id]
    }

    /// Children of `id`, in insertion order.
    pub fn children(&self, id: NodeId) -> &[NodeId] {
        &self.children[id]
    }

    /// Maximum node depth (the root is 0).
    pub fn max_depth(&self) -> u32 {
        self.depths.iter().copied().max().unwrap_or(0)
    }

    /// Ids of leaf nodes (no children).
    pub fn leaves(&self) -> Vec<NodeId> {
        (0..self.len()).filter(|&i| self.children[i].is_empty()).collect()
    }

    /// Walks ancestors from `id` up to (and including) the root.
    pub fn ancestors(&self, id: NodeId) -> AncestorIter<'_> {
        AncestorIter { tree: self, cur: Some(id) }
    }

    /// The token path from the root's first child down to `id` (exclusive
    /// of the root itself, which is already committed).
    pub fn path_tokens(&self, id: NodeId) -> Vec<u32> {
        let mut path: Vec<u32> =
            self.ancestors(id).filter(|&a| a != 0).map(|a| self.tokens[a]).collect();
        path.reverse();
        path
    }

    /// Expected number of tokens committed if this whole tree is verified:
    /// 1 (the bonus token) + Σ path-probability of every candidate node.
    /// This is the AAL surrogate from §4.1 of the paper.
    pub fn expected_aal(&self) -> f64 {
        1.0 + (1..self.len()).map(|i| self.path_probs[i] as f64).sum::<f64>()
    }

    /// Checks the structural invariants (used by tests / debug assertions).
    pub fn check_invariants(&self) -> Result<(), String> {
        let n = self.len();
        for i in 1..n {
            let p = self.parents[i];
            if p < 0 || p as usize >= i {
                return Err(format!("node {i}: parent {p} not before child"));
            }
            let p = p as usize;
            if self.depths[i] != self.depths[p] + 1 {
                return Err(format!("node {i}: depth mismatch"));
            }
            let pp = self.path_probs[p] * self.edge_probs[i];
            if (self.path_probs[i] - pp).abs() > 1e-5 {
                return Err(format!("node {i}: path prob mismatch"));
            }
            if !self.children[p].contains(&i) {
                return Err(format!("node {i}: missing from parent child list"));
            }
        }
        Ok(())
    }

    /// Returns the sub-tree induced by `keep` (which must contain the root
    /// and be closed under parents), remapping ids; `map[old] = new`.
    pub fn induced_subtree(&self, keep: &[NodeId]) -> (TokenTree, Vec<Option<NodeId>>) {
        assert!(keep.contains(&0), "subtree must contain the root");
        let mut sorted = keep.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        let mut map: Vec<Option<NodeId>> = vec![None; self.len()];
        let mut out = TokenTree::new(self.tokens[0]);
        map[0] = Some(0);
        for &old in &sorted {
            if old == 0 {
                continue;
            }
            let parent_old = self.parents[old] as usize;
            let parent_new = map[parent_old]
                .unwrap_or_else(|| panic!("keep-set not closed under parents at {old}"));
            let new = out.add_node(parent_new, self.tokens[old], self.edge_probs[old]);
            map[old] = Some(new);
        }
        (out, map)
    }

    /// Pretty-prints the tree (used by the `tree_explorer` example).
    pub fn render(&self, labels: Option<&[String]>) -> String {
        let mut s = String::new();
        self.render_node(0, "", true, labels, &mut s);
        s
    }

    fn render_node(
        &self,
        id: NodeId,
        prefix: &str,
        last: bool,
        labels: Option<&[String]>,
        out: &mut String,
    ) {
        let connector = if id == 0 {
            ""
        } else if last {
            "└─ "
        } else {
            "├─ "
        };
        let label = labels
            .and_then(|l| l.get(id).cloned())
            .unwrap_or_else(|| format!("tok={} p={:.3}", self.tokens[id], self.path_probs[id]));
        out.push_str(&format!("{prefix}{connector}{label}\n"));
        let child_prefix = if id == 0 {
            String::new()
        } else {
            format!("{prefix}{}", if last { "   " } else { "│  " })
        };
        let kids = &self.children[id];
        for (i, &c) in kids.iter().enumerate() {
            self.render_node(c, &child_prefix, i + 1 == kids.len(), labels, out);
        }
    }
}

/// Iterator over a node's ancestors, including itself, ending at the root.
pub struct AncestorIter<'a> {
    tree: &'a TokenTree,
    cur: Option<NodeId>,
}

impl Iterator for AncestorIter<'_> {
    type Item = NodeId;

    fn next(&mut self) -> Option<NodeId> {
        let cur = self.cur?;
        self.cur = self.tree.parent(cur);
        Some(cur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain(n: usize) -> TokenTree {
        let mut t = TokenTree::new(0);
        let mut cur = 0;
        for i in 0..n {
            cur = t.add_node(cur, i as u32 + 1, 0.5);
        }
        t
    }

    #[test]
    fn chain_shape() {
        let t = chain(4);
        assert_eq!(t.len(), 5);
        assert_eq!(t.max_depth(), 4);
        assert_eq!(t.leaves(), vec![4]);
        assert_eq!(t.path_tokens(4), vec![1, 2, 3, 4]);
        t.check_invariants().unwrap();
    }

    #[test]
    fn path_probs_multiply() {
        let t = chain(3);
        assert!((t.path_prob(3) - 0.125).abs() < 1e-6);
        // AAL = 1 + 0.5 + 0.25 + 0.125
        assert!((t.expected_aal() - 1.875).abs() < 1e-6);
    }

    #[test]
    fn ancestors_walk_to_root() {
        let mut t = TokenTree::new(9);
        let a = t.add_node(0, 1, 0.9);
        let b = t.add_node(a, 2, 0.8);
        let c = t.add_node(0, 3, 0.1);
        assert_eq!(t.ancestors(b).collect::<Vec<_>>(), vec![b, a, 0]);
        assert_eq!(t.ancestors(c).collect::<Vec<_>>(), vec![c, 0]);
    }

    #[test]
    fn induced_subtree_remaps() {
        let mut t = TokenTree::new(0);
        let a = t.add_node(0, 1, 0.9);
        let _b = t.add_node(a, 2, 0.8);
        let c = t.add_node(0, 3, 0.7);
        let (sub, map) = t.induced_subtree(&[0, a, c]);
        assert_eq!(sub.len(), 3);
        sub.check_invariants().unwrap();
        assert_eq!(map[a], Some(1));
        assert_eq!(map[c], Some(2));
        assert_eq!(map[2], None); // b dropped
        assert_eq!(sub.token(1), 1);
        assert_eq!(sub.token(2), 3);
    }

    #[test]
    #[should_panic(expected = "closed under parents")]
    fn induced_subtree_requires_closure() {
        let mut t = TokenTree::new(0);
        let a = t.add_node(0, 1, 0.9);
        let b = t.add_node(a, 2, 0.8);
        let _ = t.induced_subtree(&[0, b]); // a missing
    }

    #[test]
    fn render_contains_tokens() {
        let t = chain(2);
        let s = t.render(None);
        assert!(s.contains("tok=1"));
        assert!(s.contains("tok=2"));
    }
}
