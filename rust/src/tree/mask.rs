//! Attention-mask construction for tree calls.
//!
//! Every model call (draft step, verification, prefill chunk) passes an
//! explicit `[W, C]` validity mask: row *i* marks which cache slots token
//! *i* may attend to — the committed causal prefix plus its own tree
//! ancestors plus itself. Because validity is entirely mask-encoded, tree
//! tokens live at arbitrary slots, rejected slots are simply reused, and
//! the *shape* of every operator stays static (DESIGN.md §7). This mirrors
//! the tree-dependency mask of §4.2 / FastTree.
//!
//! Mask building is on the per-iteration critical path, so it runs
//! bit-packed (DESIGN.md §13): a [`BitMask`] row is
//! `capacity.div_ceil(64)` `u64` words — the dependency structure is pure
//! ancestor reachability, so bits suffice (SpecInfer's tree-attention
//! formulation; sglang's `eagle_utils` ships the same u64-word packing).
//! Rows are built by whole-word prefix copies plus per-ancestor bit ORs,
//! packed word-wise, ownership-checked word-wise, and expanded to the
//! runtime's `Vec<f32>` only at the device-call boundary
//! ([`BitMask::expand_into`]). The f32 builders below are kept as the
//! reference path; property tests pin the two bit-exact.
//!
//! For cross-session batched verification (DESIGN.md §9) the per-session
//! row blocks — each built by that session's own builder over its own
//! leased slot set — are concatenated by [`pack_block_diagonal`] (or its
//! word-wise form [`pack_block_diagonal_bits`]) into one
//! `[rows, capacity]` batch mask. Because every session's slots come
//! from a disjoint [`SlotOwnership`] set (a contiguous [`SlotRange`] in
//! equal-partition mode, a set of owned blocks in paged mode, DESIGN.md
//! §10), the packed mask is block-diagonal: session A's rows are
//! structurally unable to attend to session B's slots ([`rows_owned`] is
//! the checkable form of that invariant; [`rows_confined`] is its
//! contiguous-range specialization, and [`rows_owned_bits`] /
//! [`rows_confined_bits`] their word-test forms).

use crate::kvcache::{SlotOwnership, SlotRange};
use crate::util::bits::{self, WORD_BITS};

use super::{NodeId, TokenTree};

/// Concatenates per-session mask row blocks (each `k_i × capacity`,
/// row-major) into one `[rows, capacity]` batch mask, zero-padding any
/// rows past the blocks' total. Panics if a block is not a whole number
/// of rows or the blocks exceed `rows`.
pub fn pack_block_diagonal(blocks: &[&[f32]], capacity: usize, rows: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(rows * capacity);
    for b in blocks {
        assert!(b.len() % capacity == 0, "block is not whole rows");
        out.extend_from_slice(b);
    }
    assert!(out.len() <= rows * capacity, "blocks exceed the batch width");
    out.resize(rows * capacity, 0.0);
    out
}

/// True when every row of `block` (`k × capacity`, row-major) references
/// only slots inside `range` — the per-session confinement invariant that
/// makes a packed batch mask block-diagonal. Contiguous-range form kept
/// for equal-partition leases; [`rows_owned`] is the general check.
pub fn rows_confined(block: &[f32], capacity: usize, range: SlotRange) -> bool {
    rows_owned(block, capacity, &SlotOwnership::Range(range))
}

/// Block-ownership generalization of [`rows_confined`]: true when every
/// row of `block` (`k × capacity`, row-major) references only slots in
/// `owner` — a contiguous range *or* a paged session's set of owned
/// blocks (DESIGN.md §10). Used by tests and debug assertions in the
/// batched scheduler.
pub fn rows_owned(block: &[f32], capacity: usize, owner: &SlotOwnership) -> bool {
    debug_assert!(block.len() % capacity == 0);
    block.chunks(capacity).all(|row| {
        row.iter()
            .enumerate()
            .all(|(slot, &v)| v == 0.0 || owner.contains(slot as u32))
    })
}

/// A bit-packed `[rows, capacity]` attention mask: each row is
/// `capacity.div_ceil(64)` `u64` words, bit *s* marking slot *s*
/// visible. 32× denser than the f32 rows, built with whole-word copies,
/// and convertible to the runtime's dense layout only at the call
/// boundary via [`BitMask::expand_into`].
#[derive(Debug, Clone)]
pub struct BitMask {
    capacity: usize,
    words_per_row: usize,
    rows: usize,
    words: Vec<u64>,
}

impl BitMask {
    /// An empty (0-row) mask over a `capacity`-slot cache.
    pub fn new(capacity: usize) -> Self {
        Self { capacity, words_per_row: bits::words_for(capacity), rows: 0, words: Vec::new() }
    }

    /// Mask row width in slots.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Current row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// `u64` words per row (`capacity.div_ceil(64)`).
    pub fn words_per_row(&self) -> usize {
        self.words_per_row
    }

    /// Clears the mask to `rows` all-zero rows at the current capacity.
    /// Reuses the word buffer: after warm-up this allocates nothing.
    pub fn reset(&mut self, rows: usize) {
        self.rows = rows;
        self.words.clear();
        self.words.resize(rows * self.words_per_row, 0);
    }

    /// Re-shapes to a (possibly different) capacity and `rows` all-zero
    /// rows, still reusing the word buffer. Used by the packed batch
    /// scratch in [`RoundArena`], which serves caches of both models.
    pub fn reshape(&mut self, capacity: usize, rows: usize) {
        self.capacity = capacity;
        self.words_per_row = bits::words_for(capacity);
        self.reset(rows);
    }

    /// The words of row `i`.
    pub fn row(&self, i: usize) -> &[u64] {
        &self.words[i * self.words_per_row..(i + 1) * self.words_per_row]
    }

    /// Mutable words of row `i`.
    pub fn row_mut(&mut self, i: usize) -> &mut [u64] {
        let w = self.words_per_row;
        &mut self.words[i * w..(i + 1) * w]
    }

    /// Sets bit `slot` of row `i`.
    pub fn set(&mut self, i: usize, slot: usize) {
        debug_assert!(slot < self.capacity);
        bits::set_bit(self.row_mut(i), slot);
    }

    /// Reads bit `slot` of row `i`.
    pub fn get(&self, i: usize, slot: usize) -> bool {
        debug_assert!(slot < self.capacity);
        bits::get_bit(self.row(i), slot)
    }

    /// All backing words, row-major.
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Copies all of `src`'s rows into this mask starting at `at_row`
    /// (whole-word `copy_from_slice`; capacities must match). This is the
    /// incremental form of [`pack_block_diagonal_bits`] — the arena packs
    /// one session at a time without holding borrows of every builder.
    pub fn copy_rows_from(&mut self, src: &BitMask, at_row: usize) {
        assert_eq!(src.capacity, self.capacity, "block capacity mismatch");
        assert!(at_row + src.rows <= self.rows, "blocks exceed the batch width");
        let w = self.words_per_row;
        self.words[at_row * w..(at_row + src.rows) * w]
            .copy_from_slice(&src.words[..src.rows * w]);
    }

    /// Expands into the dense `rows × capacity` f32 layout the runtime
    /// consumes, reusing `out`'s storage (no allocation once `out` has
    /// warmed up to capacity). Zero words are skipped wholesale.
    pub fn expand_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.resize(self.rows * self.capacity, 0.0);
        for r in 0..self.rows {
            let base = r * self.capacity;
            let row = self.row(r);
            for (wi, &word) in row.iter().enumerate() {
                let mut w = word;
                while w != 0 {
                    let b = w.trailing_zeros() as usize;
                    out[base + wi * WORD_BITS + b] = 1.0;
                    w &= w - 1;
                }
            }
        }
    }

    /// Allocating convenience form of [`BitMask::expand_into`].
    pub fn to_f32(&self) -> Vec<f32> {
        let mut v = Vec::new();
        self.expand_into(&mut v);
        v
    }

    /// Packs a dense `k × capacity` f32 block (the reference layout) into
    /// bits — the test-side bridge for parity checks. Any non-zero entry
    /// sets the bit.
    pub fn from_f32(block: &[f32], capacity: usize) -> Self {
        assert!(capacity > 0 && block.len() % capacity == 0, "block is not whole rows");
        let mut m = Self::new(capacity);
        m.reset(block.len() / capacity);
        for (i, row) in block.chunks(capacity).enumerate() {
            for (slot, &v) in row.iter().enumerate() {
                if v != 0.0 {
                    m.set(i, slot);
                }
            }
        }
        m
    }
}

/// Word-wise [`pack_block_diagonal`]: concatenates per-session
/// [`BitMask`] row blocks into `out` (re-shaped to `rows` all-zero rows
/// at `capacity`), copying whole words instead of `capacity` floats per
/// row. Panics on capacity mismatch or overflow, like the f32 form.
pub fn pack_block_diagonal_bits(
    blocks: &[&BitMask],
    capacity: usize,
    rows: usize,
    out: &mut BitMask,
) {
    out.reshape(capacity, rows);
    let mut at = 0usize;
    for b in blocks {
        out.copy_rows_from(b, at);
        at += b.rows();
    }
}

/// Expands a [`SlotOwnership`] into its allowed-slot bit words
/// (`capacity.div_ceil(64)` words written into `out`): the precomputable
/// half of [`rows_owned_bits`], so a round derives it once per session
/// and every ownership check becomes a pure `AND-NOT` word test.
pub fn owner_words(owner: &SlotOwnership, capacity: usize, out: &mut Vec<u64>) {
    out.clear();
    out.resize(bits::words_for(capacity), 0);
    match owner {
        SlotOwnership::Range(r) => {
            let lo = (r.base as usize).min(capacity);
            let hi = (r.base as usize + r.len as usize).min(capacity);
            for (w, word) in out.iter_mut().enumerate() {
                *word = bits::range_word_mask(w, lo, hi);
            }
        }
        SlotOwnership::Blocks { block_size, blocks, shared } => {
            let bs = *block_size as usize;
            for &b in blocks.iter().chain(shared.iter()) {
                let lo = (b as usize * bs).min(capacity);
                let hi = (b as usize * bs + bs).min(capacity);
                if lo >= hi {
                    continue;
                }
                for w in lo / WORD_BITS..=(hi - 1) / WORD_BITS {
                    out[w] |= bits::range_word_mask(w, lo, hi);
                }
            }
        }
    }
}

/// Word-wise [`rows_owned`]: true when every row of `m` references only
/// slots allowed by `allowed` (from [`owner_words`]) — one `AND-NOT`
/// test per word instead of `capacity` float compares per row.
pub fn rows_owned_bits(m: &BitMask, allowed: &[u64]) -> bool {
    debug_assert_eq!(allowed.len(), m.words_per_row());
    m.words()
        .chunks(m.words_per_row().max(1))
        .all(|row| row.iter().zip(allowed).all(|(&w, &a)| w & !a == 0))
}

/// Word-wise [`rows_confined`]: pure arithmetic (no owner-word scratch
/// needed) since a [`SlotRange`]'s allow mask per word is closed-form.
pub fn rows_confined_bits(m: &BitMask, range: SlotRange) -> bool {
    let lo = (range.base as usize).min(m.capacity());
    let hi = (range.base as usize + range.len as usize).min(m.capacity());
    for r in 0..m.rows() {
        for (wi, &w) in m.row(r).iter().enumerate() {
            if w & !bits::range_word_mask(wi, lo, hi) != 0 {
                return false;
            }
        }
    }
    true
}

/// Reusable mask builder for one model instance (one cache).
///
/// Maintains the committed prefix in *both* layouts — the f32 row the
/// reference path copies, and the bit words the packed path ORs — kept in
/// lockstep by [`MaskBuilder::commit_slot`] / [`MaskBuilder::release_slot`].
#[derive(Debug, Clone)]
pub struct MaskBuilder {
    capacity: usize,
    /// 1.0 at slots holding committed (always-visible) tokens.
    prefix_row: Vec<f32>,
    /// Bit-packed twin of `prefix_row` (bit = committed slot).
    prefix_words: Vec<u64>,
    /// Scratch output buffer, `width × capacity`, reused across calls.
    buf: Vec<f32>,
    /// Bit-packed scratch output, reused across calls.
    bits: BitMask,
}

impl MaskBuilder {
    /// A builder for a `capacity`-slot cache (no slots committed yet).
    pub fn new(capacity: usize) -> Self {
        Self {
            capacity,
            prefix_row: vec![0.0; capacity],
            prefix_words: vec![0; bits::words_for(capacity)],
            buf: Vec::new(),
            bits: BitMask::new(capacity),
        }
    }

    /// Mask row width (the cache capacity).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Marks `slot` as committed (visible to all future tokens).
    pub fn commit_slot(&mut self, slot: u32) {
        self.prefix_row[slot as usize] = 1.0;
        bits::set_bit(&mut self.prefix_words, slot as usize);
    }

    /// Unmarks a slot (used when a session resets or a cache is recycled).
    pub fn release_slot(&mut self, slot: u32) {
        self.prefix_row[slot as usize] = 0.0;
        bits::clear_bit(&mut self.prefix_words, slot as usize);
    }

    /// Number of committed (always-visible) slots.
    pub fn committed_count(&self) -> usize {
        bits::count_ones(&self.prefix_words)
    }

    /// The maintained prefix row (`capacity` wide, 1.0 at committed
    /// slots) — what every built mask row starts from. Lets callers that
    /// need a single prefix-plus-self row (the deferred head draft of
    /// DESIGN.md §11) assemble it without cloning the whole builder.
    pub fn prefix_row(&self) -> &[f32] {
        &self.prefix_row
    }

    /// Bit-packed twin of [`MaskBuilder::prefix_row`].
    pub fn prefix_words(&self) -> &[u64] {
        &self.prefix_words
    }

    /// Builds the mask for evaluating tree `nodes` (in call order) whose
    /// cache slots are given by `slot_of[node]`. `rows` must equal the
    /// compiled graph width; rows beyond `nodes.len()` are zeroed padding.
    ///
    /// Row semantics: prefix slots ∪ ancestor slots (ancestors must appear
    /// in `slot_of`) ∪ the node's own slot (its K/V are scattered before
    /// attention runs).
    pub fn build(
        &mut self,
        tree: &TokenTree,
        nodes: &[NodeId],
        slot_of: &[Option<u32>], // indexed by NodeId; None = not in this cache
        rows: usize,
    ) -> &[f32] {
        assert!(nodes.len() <= rows);
        let c = self.capacity;
        self.buf.resize(rows * c, 0.0);
        for (i, &node) in nodes.iter().enumerate() {
            let row = &mut self.buf[i * c..(i + 1) * c];
            row.copy_from_slice(&self.prefix_row);
            for anc in tree.ancestors(node) {
                if let Some(Some(slot)) = slot_of.get(anc) {
                    row[*slot as usize] = 1.0;
                }
            }
        }
        for i in nodes.len()..rows {
            self.buf[i * c..(i + 1) * c].fill(0.0);
        }
        &self.buf[..rows * c]
    }

    /// Word-wise [`MaskBuilder::build`]: each row is a whole-word copy of
    /// the committed prefix words plus one bit OR per ancestor, into the
    /// builder's reusable [`BitMask`] scratch. Bit-exact with `build`
    /// (property-tested); ~`capacity/64` the writes per row.
    pub fn build_bits(
        &mut self,
        tree: &TokenTree,
        nodes: &[NodeId],
        slot_of: &[Option<u32>],
        rows: usize,
    ) -> &BitMask {
        assert!(nodes.len() <= rows);
        self.bits.reset(rows);
        for (i, &node) in nodes.iter().enumerate() {
            let row = self.bits.row_mut(i);
            row.copy_from_slice(&self.prefix_words);
            for anc in tree.ancestors(node) {
                if let Some(Some(slot)) = slot_of.get(anc) {
                    bits::set_bit(row, *slot as usize);
                }
            }
        }
        &self.bits
    }

    /// Builds the mask for a *linear* prefill chunk: token `i` of the chunk
    /// attends to the committed prefix plus chunk tokens `0..=i` (their
    /// slots given by `chunk_slots`). Rows beyond `n` are zero padding.
    pub fn build_linear(&mut self, chunk_slots: &[u32], n: usize, rows: usize) -> &[f32] {
        assert!(n <= chunk_slots.len() && n <= rows);
        let c = self.capacity;
        self.buf.resize(rows * c, 0.0);
        for i in 0..n {
            let row = &mut self.buf[i * c..(i + 1) * c];
            row.copy_from_slice(&self.prefix_row);
            for &s in &chunk_slots[..=i] {
                row[s as usize] = 1.0;
            }
        }
        for i in n..rows {
            self.buf[i * c..(i + 1) * c].fill(0.0);
        }
        &self.buf[..rows * c]
    }

    /// Word-wise [`MaskBuilder::build_linear`]. Row `i` copies row `i-1`
    /// (prefix words for row 0) and ORs one chunk-slot bit — the causal
    /// staircase costs one word-copy + one OR per row.
    pub fn build_linear_bits(&mut self, chunk_slots: &[u32], n: usize, rows: usize) -> &BitMask {
        assert!(n <= chunk_slots.len() && n <= rows);
        self.bits.reset(rows);
        let w = self.bits.words_per_row();
        for i in 0..n {
            if i == 0 {
                self.bits.row_mut(0).copy_from_slice(&self.prefix_words);
            } else {
                let (prev, cur) = self.bits.words.split_at_mut(i * w);
                cur[..w].copy_from_slice(&prev[(i - 1) * w..i * w]);
            }
            bits::set_bit(self.bits.row_mut(i), chunk_slots[i] as usize);
        }
        &self.bits
    }
}

/// Reusable per-decoder scratch for one scheduling round (DESIGN.md §13):
/// recycled f32 mask buffers, the packed block-diagonal bit words, the
/// acceptance-walk stacks and the node→row table. The decode hot loop
/// borrows and resets these instead of allocating — after warm-up a
/// steady-state round performs zero heap allocations on the CPU side
/// (pinned by the `alloc_steady_state` integration test).
#[derive(Debug, Default)]
pub struct RoundArena {
    /// Recycled dense-mask buffers: [`RoundArena::take_f32`] pops one
    /// (cleared, capacity intact), [`RoundArena::put_f32`] returns it.
    pool_f32: Vec<Vec<f32>>,
    /// Packed block-diagonal batch-mask words (the batched call path).
    pub packed: BitMask,
    /// Acceptance walk: accepted node path, root first.
    pub walk_path: Vec<usize>,
    /// Acceptance walk: in-keep children of the current node.
    pub walk_kids: Vec<usize>,
    /// Acceptance walk: their tokens, parallel to `walk_kids`.
    pub walk_tokens: Vec<u32>,
    /// Node id → verify-row index (`-1` = pruned away), reset per walk.
    pub row_of: Vec<i32>,
    /// Ownership word scratch for word-wise confinement checks.
    pub owner: Vec<u64>,
}

impl Default for BitMask {
    fn default() -> Self {
        Self::new(0)
    }
}

impl RoundArena {
    /// A fresh arena; buffers warm up over the first rounds.
    pub fn new() -> Self {
        Self::default()
    }

    /// Pops a recycled f32 buffer (cleared, capacity intact) or mints an
    /// empty one. Pair with [`RoundArena::put_f32`] once the device call
    /// that consumed the expansion has been issued.
    pub fn take_f32(&mut self) -> Vec<f32> {
        let mut v = self.pool_f32.pop().unwrap_or_default();
        v.clear();
        v
    }

    /// Returns a buffer to the pool, retaining its capacity.
    pub fn put_f32(&mut self, v: Vec<f32>) {
        self.pool_f32.push(v);
    }

    /// Number of pooled f32 buffers (diagnostics/tests).
    pub fn pooled_f32(&self) -> usize {
        self.pool_f32.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slots(pairs: &[(NodeId, u32)], n: usize) -> Vec<Option<u32>> {
        let mut v = vec![None; n];
        for &(id, s) in pairs {
            v[id] = Some(s);
        }
        v
    }

    #[test]
    fn tree_rows_see_prefix_ancestors_and_self() {
        let mut tree = TokenTree::new(0);
        let a = tree.add_node(0, 1, 0.9);
        let b = tree.add_node(a, 2, 0.8);
        let c2 = tree.add_node(0, 3, 0.1);

        let mut mb = MaskBuilder::new(8);
        mb.commit_slot(0); // prefix token
        let slot_of = slots(&[(0, 1), (a, 2), (b, 3), (c2, 4)], tree.len());
        let m = mb.build(&tree, &[a, b, c2], &slot_of, 4).to_vec();

        let row = |i: usize| &m[i * 8..(i + 1) * 8];
        // a: prefix(0) + root(1) + self(2)
        assert_eq!(row(0), &[1., 1., 1., 0., 0., 0., 0., 0.]);
        // b: prefix + root + a + self
        assert_eq!(row(1), &[1., 1., 1., 1., 0., 0., 0., 0.]);
        // c2: prefix + root + self(4); must NOT see a or b (sibling branch)
        assert_eq!(row(2), &[1., 1., 0., 0., 1., 0., 0., 0.]);
        // padding row all-zero
        assert_eq!(row(3), &[0.; 8]);

        // The bit-packed build is bit-exact with the reference.
        let mbits = mb.build_bits(&tree, &[a, b, c2], &slot_of, 4).to_f32();
        assert_eq!(mbits, m);
    }

    #[test]
    fn linear_mask_is_causal_over_chunk() {
        let mut mb = MaskBuilder::new(6);
        mb.commit_slot(5);
        let m = mb.build_linear(&[0, 1, 2], 3, 4).to_vec();
        let row = |i: usize| &m[i * 6..(i + 1) * 6];
        assert_eq!(row(0), &[1., 0., 0., 0., 0., 1.]);
        assert_eq!(row(1), &[1., 1., 0., 0., 0., 1.]);
        assert_eq!(row(2), &[1., 1., 1., 0., 0., 1.]);
        assert_eq!(row(3), &[0.; 6]);
        let mbits = mb.build_linear_bits(&[0, 1, 2], 3, 4).to_f32();
        assert_eq!(mbits, m);
    }

    #[test]
    fn commit_release_roundtrip() {
        let mut mb = MaskBuilder::new(4);
        mb.commit_slot(2);
        assert_eq!(mb.committed_count(), 1);
        assert_eq!(mb.prefix_words(), &[0b100]);
        mb.release_slot(2);
        assert_eq!(mb.committed_count(), 0);
        assert_eq!(mb.prefix_words(), &[0]);
    }

    #[test]
    fn pack_block_diagonal_concatenates_and_pads() {
        let a = [1.0f32, 0.0, 0.0, 0.0]; // one row, capacity 4
        let b = [0.0f32, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 1.0]; // two rows
        let m = pack_block_diagonal(&[&a, &b], 4, 4);
        assert_eq!(m.len(), 16);
        assert_eq!(&m[0..4], &a);
        assert_eq!(&m[4..12], &b);
        assert!(m[12..].iter().all(|&x| x == 0.0), "padding row zeroed");
    }

    #[test]
    fn pack_block_diagonal_bits_matches_f32_pack() {
        let a = [1.0f32, 0.0, 0.0, 0.0];
        let b = [0.0f32, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 1.0];
        let reference = pack_block_diagonal(&[&a, &b], 4, 4);
        let (ba, bb) = (BitMask::from_f32(&a, 4), BitMask::from_f32(&b, 4));
        let mut packed = BitMask::new(4);
        pack_block_diagonal_bits(&[&ba, &bb], 4, 4, &mut packed);
        assert_eq!(packed.rows(), 4);
        assert_eq!(packed.to_f32(), reference);
    }

    #[test]
    fn rows_confined_detects_escapes() {
        let range = SlotRange { base: 2, len: 2 };
        let ok = [0.0f32, 0.0, 1.0, 1.0, 0.0, 0.0];
        let bad = [0.0f32, 1.0, 1.0, 0.0, 0.0, 0.0];
        assert!(rows_confined(&ok, 6, range));
        assert!(!rows_confined(&bad, 6, range));
        assert!(rows_confined_bits(&BitMask::from_f32(&ok, 6), range));
        assert!(!rows_confined_bits(&BitMask::from_f32(&bad, 6), range));
    }

    #[test]
    fn rows_owned_checks_block_sets() {
        // Capacity 8, blocks of 2; session owns blocks 0 and 3
        // (slots 0, 1, 6, 7).
        let own = crate::kvcache::SlotOwnership::Blocks {
            block_size: 2,
            blocks: vec![0, 3],
            shared: vec![],
        };
        let ok = [1.0f32, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 1.0];
        let bad = [1.0f32, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0]; // slot 3 foreign
        assert!(rows_owned(&ok, 8, &own));
        assert!(!rows_owned(&bad, 8, &own));
        // Multiple rows: one escape anywhere fails the whole block.
        let two =
            [1.0f32, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        assert!(!rows_owned(&two, 8, &own), "row 2 references foreign slot 2");
        // Read-shared prefix blocks are referenceable, exactly like owned
        // ones (DESIGN.md §12): a committed shared-prefix slot in a mask
        // row is not an escape.
        let own = crate::kvcache::SlotOwnership::Blocks {
            block_size: 2,
            blocks: vec![3],
            shared: vec![0],
        };
        assert!(rows_owned(&ok, 8, &own), "shared block 0 must be referenceable");
    }

    #[test]
    fn owner_words_and_word_checks_match_reference() {
        let owners = [
            SlotOwnership::Range(SlotRange { base: 2, len: 3 }),
            SlotOwnership::Blocks { block_size: 2, blocks: vec![0, 3], shared: vec![] },
            SlotOwnership::Blocks { block_size: 2, blocks: vec![3], shared: vec![0] },
        ];
        let rows: [&[f32]; 3] = [
            &[1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 1.0],
            &[1.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0],
            &[0.0, 0.0, 1.0, 1.0, 1.0, 0.0, 0.0, 0.0],
        ];
        let mut allowed = Vec::new();
        for own in &owners {
            owner_words(own, 8, &mut allowed);
            let bits_flat = allowed.iter().flat_map(|&w| (0..8).map(move |b| (w >> b) & 1));
            for (slot, bit) in bits_flat.enumerate() {
                assert_eq!(bit == 1, own.contains(slot as u32), "owner {own:?} slot {slot}");
            }
            for block in &rows {
                assert_eq!(
                    rows_owned_bits(&BitMask::from_f32(block, 8), &allowed),
                    rows_owned(block, 8, own),
                    "owner {own:?} block {block:?}"
                );
            }
        }
    }

    #[test]
    fn rebuild_reuses_buffer_and_clears_stale_rows() {
        let tree = TokenTree::new(0);
        let mut mb = MaskBuilder::new(4);
        let slot_of = slots(&[(0, 0)], 1);
        let first = mb.build(&tree, &[0], &slot_of, 2).to_vec();
        assert_eq!(&first[0..4], &[1., 0., 0., 0.]);
        // second build with zero nodes: all rows must be padding
        let second = mb.build(&tree, &[], &slot_of, 2).to_vec();
        assert!(second.iter().all(|&x| x == 0.0));
        // same for the bit path
        let fb = mb.build_bits(&tree, &[0], &slot_of, 2).to_f32();
        assert_eq!(&fb[0..4], &[1., 0., 0., 0.]);
        let sb = mb.build_bits(&tree, &[], &slot_of, 2).to_f32();
        assert!(sb.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn arena_recycles_f32_buffers() {
        let mut arena = RoundArena::new();
        let mut v = arena.take_f32();
        v.resize(128, 1.0);
        let cap = v.capacity();
        arena.put_f32(v);
        assert_eq!(arena.pooled_f32(), 1);
        let v2 = arena.take_f32();
        assert!(v2.is_empty() && v2.capacity() == cap, "capacity retained, contents cleared");
        assert_eq!(arena.pooled_f32(), 0);
    }

    #[test]
    fn expand_into_reuses_storage() {
        let mut m = BitMask::new(70);
        m.reset(2);
        m.set(0, 0);
        m.set(1, 69);
        let mut out = Vec::new();
        m.expand_into(&mut out);
        assert_eq!(out.len(), 140);
        assert_eq!(out[0], 1.0);
        assert_eq!(out[70 + 69], 1.0);
        assert_eq!(out.iter().filter(|&&x| x != 0.0).count(), 2);
        let cap = out.capacity();
        m.reset(1);
        m.expand_into(&mut out);
        assert_eq!(out.len(), 70);
        assert!(out.iter().all(|&x| x == 0.0));
        assert_eq!(out.capacity(), cap);
    }
}
