//! Attention-mask construction for tree calls.
//!
//! Every model call (draft step, verification, prefill chunk) passes an
//! explicit `[W, C]` validity mask: row *i* marks which cache slots token
//! *i* may attend to — the committed causal prefix plus its own tree
//! ancestors plus itself. Because validity is entirely mask-encoded, tree
//! tokens live at arbitrary slots, rejected slots are simply reused, and
//! the *shape* of every operator stays static (DESIGN.md §7). This mirrors
//! the tree-dependency mask of §4.2 / FastTree.
//!
//! Mask building is on the per-iteration critical path, so the builder
//! reuses one flat buffer and writes rows with `copy_from_slice` of a
//! maintained prefix row (no per-call allocation after warm-up).
//!
//! For cross-session batched verification (DESIGN.md §9) the per-session
//! row blocks — each built by that session's own builder over its own
//! leased slot set — are concatenated by [`pack_block_diagonal`] into
//! one `[rows, capacity]` batch mask. Because every session's slots come
//! from a disjoint [`SlotOwnership`] set (a contiguous [`SlotRange`] in
//! equal-partition mode, a set of owned blocks in paged mode, DESIGN.md
//! §10), the packed mask is block-diagonal: session A's rows are
//! structurally unable to attend to session B's slots ([`rows_owned`] is
//! the checkable form of that invariant; [`rows_confined`] is its
//! contiguous-range specialization).

use crate::kvcache::{SlotOwnership, SlotRange};

use super::{NodeId, TokenTree};

/// Concatenates per-session mask row blocks (each `k_i × capacity`,
/// row-major) into one `[rows, capacity]` batch mask, zero-padding any
/// rows past the blocks' total. Panics if a block is not a whole number
/// of rows or the blocks exceed `rows`.
pub fn pack_block_diagonal(blocks: &[&[f32]], capacity: usize, rows: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(rows * capacity);
    for b in blocks {
        assert!(b.len() % capacity == 0, "block is not whole rows");
        out.extend_from_slice(b);
    }
    assert!(out.len() <= rows * capacity, "blocks exceed the batch width");
    out.resize(rows * capacity, 0.0);
    out
}

/// True when every row of `block` (`k × capacity`, row-major) references
/// only slots inside `range` — the per-session confinement invariant that
/// makes a packed batch mask block-diagonal. Contiguous-range form kept
/// for equal-partition leases; [`rows_owned`] is the general check.
pub fn rows_confined(block: &[f32], capacity: usize, range: SlotRange) -> bool {
    rows_owned(block, capacity, &SlotOwnership::Range(range))
}

/// Block-ownership generalization of [`rows_confined`]: true when every
/// row of `block` (`k × capacity`, row-major) references only slots in
/// `owner` — a contiguous range *or* a paged session's set of owned
/// blocks (DESIGN.md §10). Used by tests and debug assertions in the
/// batched scheduler.
pub fn rows_owned(block: &[f32], capacity: usize, owner: &SlotOwnership) -> bool {
    debug_assert!(block.len() % capacity == 0);
    block.chunks(capacity).all(|row| {
        row.iter()
            .enumerate()
            .all(|(slot, &v)| v == 0.0 || owner.contains(slot as u32))
    })
}

/// Reusable mask builder for one model instance (one cache).
#[derive(Debug, Clone)]
pub struct MaskBuilder {
    capacity: usize,
    /// 1.0 at slots holding committed (always-visible) tokens.
    prefix_row: Vec<f32>,
    /// Scratch output buffer, `width × capacity`, reused across calls.
    buf: Vec<f32>,
}

impl MaskBuilder {
    /// A builder for a `capacity`-slot cache (no slots committed yet).
    pub fn new(capacity: usize) -> Self {
        Self { capacity, prefix_row: vec![0.0; capacity], buf: Vec::new() }
    }

    /// Mask row width (the cache capacity).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Marks `slot` as committed (visible to all future tokens).
    pub fn commit_slot(&mut self, slot: u32) {
        self.prefix_row[slot as usize] = 1.0;
    }

    /// Unmarks a slot (used when a session resets or a cache is recycled).
    pub fn release_slot(&mut self, slot: u32) {
        self.prefix_row[slot as usize] = 0.0;
    }

    /// Number of committed (always-visible) slots.
    pub fn committed_count(&self) -> usize {
        self.prefix_row.iter().filter(|&&x| x > 0.0).count()
    }

    /// The maintained prefix row (`capacity` wide, 1.0 at committed
    /// slots) — what every built mask row starts from. Lets callers that
    /// need a single prefix-plus-self row (the deferred head draft of
    /// DESIGN.md §11) assemble it without cloning the whole builder.
    pub fn prefix_row(&self) -> &[f32] {
        &self.prefix_row
    }

    /// Builds the mask for evaluating tree `nodes` (in call order) whose
    /// cache slots are given by `slot_of[node]`. `rows` must equal the
    /// compiled graph width; rows beyond `nodes.len()` are zeroed padding.
    ///
    /// Row semantics: prefix slots ∪ ancestor slots (ancestors must appear
    /// in `slot_of`) ∪ the node's own slot (its K/V are scattered before
    /// attention runs).
    pub fn build(
        &mut self,
        tree: &TokenTree,
        nodes: &[NodeId],
        slot_of: &[Option<u32>], // indexed by NodeId; None = not in this cache
        rows: usize,
    ) -> &[f32] {
        assert!(nodes.len() <= rows);
        let c = self.capacity;
        self.buf.resize(rows * c, 0.0);
        for (i, &node) in nodes.iter().enumerate() {
            let row = &mut self.buf[i * c..(i + 1) * c];
            row.copy_from_slice(&self.prefix_row);
            for anc in tree.ancestors(node) {
                if let Some(Some(slot)) = slot_of.get(anc) {
                    row[*slot as usize] = 1.0;
                }
            }
        }
        for i in nodes.len()..rows {
            self.buf[i * c..(i + 1) * c].fill(0.0);
        }
        &self.buf[..rows * c]
    }

    /// Builds the mask for a *linear* prefill chunk: token `i` of the chunk
    /// attends to the committed prefix plus chunk tokens `0..=i` (their
    /// slots given by `chunk_slots`). Rows beyond `n` are zero padding.
    pub fn build_linear(&mut self, chunk_slots: &[u32], n: usize, rows: usize) -> &[f32] {
        assert!(n <= chunk_slots.len() && n <= rows);
        let c = self.capacity;
        self.buf.resize(rows * c, 0.0);
        for i in 0..n {
            let row = &mut self.buf[i * c..(i + 1) * c];
            row.copy_from_slice(&self.prefix_row);
            for &s in &chunk_slots[..=i] {
                row[s as usize] = 1.0;
            }
        }
        for i in n..rows {
            self.buf[i * c..(i + 1) * c].fill(0.0);
        }
        &self.buf[..rows * c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slots(pairs: &[(NodeId, u32)], n: usize) -> Vec<Option<u32>> {
        let mut v = vec![None; n];
        for &(id, s) in pairs {
            v[id] = Some(s);
        }
        v
    }

    #[test]
    fn tree_rows_see_prefix_ancestors_and_self() {
        let mut tree = TokenTree::new(0);
        let a = tree.add_node(0, 1, 0.9);
        let b = tree.add_node(a, 2, 0.8);
        let c2 = tree.add_node(0, 3, 0.1);

        let mut mb = MaskBuilder::new(8);
        mb.commit_slot(0); // prefix token
        let slot_of = slots(&[(0, 1), (a, 2), (b, 3), (c2, 4)], tree.len());
        let m = mb.build(&tree, &[a, b, c2], &slot_of, 4).to_vec();

        let row = |i: usize| &m[i * 8..(i + 1) * 8];
        // a: prefix(0) + root(1) + self(2)
        assert_eq!(row(0), &[1., 1., 1., 0., 0., 0., 0., 0.]);
        // b: prefix + root + a + self
        assert_eq!(row(1), &[1., 1., 1., 1., 0., 0., 0., 0.]);
        // c2: prefix + root + self(4); must NOT see a or b (sibling branch)
        assert_eq!(row(2), &[1., 1., 0., 0., 1., 0., 0., 0.]);
        // padding row all-zero
        assert_eq!(row(3), &[0.; 8]);
    }

    #[test]
    fn linear_mask_is_causal_over_chunk() {
        let mut mb = MaskBuilder::new(6);
        mb.commit_slot(5);
        let m = mb.build_linear(&[0, 1, 2], 3, 4).to_vec();
        let row = |i: usize| &m[i * 6..(i + 1) * 6];
        assert_eq!(row(0), &[1., 0., 0., 0., 0., 1.]);
        assert_eq!(row(1), &[1., 1., 0., 0., 0., 1.]);
        assert_eq!(row(2), &[1., 1., 1., 0., 0., 1.]);
        assert_eq!(row(3), &[0.; 6]);
    }

    #[test]
    fn commit_release_roundtrip() {
        let mut mb = MaskBuilder::new(4);
        mb.commit_slot(2);
        assert_eq!(mb.committed_count(), 1);
        mb.release_slot(2);
        assert_eq!(mb.committed_count(), 0);
    }

    #[test]
    fn pack_block_diagonal_concatenates_and_pads() {
        let a = [1.0f32, 0.0, 0.0, 0.0]; // one row, capacity 4
        let b = [0.0f32, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 1.0]; // two rows
        let m = pack_block_diagonal(&[&a, &b], 4, 4);
        assert_eq!(m.len(), 16);
        assert_eq!(&m[0..4], &a);
        assert_eq!(&m[4..12], &b);
        assert!(m[12..].iter().all(|&x| x == 0.0), "padding row zeroed");
    }

    #[test]
    fn rows_confined_detects_escapes() {
        let range = SlotRange { base: 2, len: 2 };
        let ok = [0.0f32, 0.0, 1.0, 1.0, 0.0, 0.0];
        let bad = [0.0f32, 1.0, 1.0, 0.0, 0.0, 0.0];
        assert!(rows_confined(&ok, 6, range));
        assert!(!rows_confined(&bad, 6, range));
    }

    #[test]
    fn rows_owned_checks_block_sets() {
        // Capacity 8, blocks of 2; session owns blocks 0 and 3
        // (slots 0, 1, 6, 7).
        let own = crate::kvcache::SlotOwnership::Blocks {
            block_size: 2,
            blocks: vec![0, 3],
            shared: vec![],
        };
        let ok = [1.0f32, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 1.0];
        let bad = [1.0f32, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0]; // slot 3 foreign
        assert!(rows_owned(&ok, 8, &own));
        assert!(!rows_owned(&bad, 8, &own));
        // Multiple rows: one escape anywhere fails the whole block.
        let two = [1.0f32, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        assert!(!rows_owned(&two, 8, &own), "row 2 references foreign slot 2");
        // Read-shared prefix blocks are referenceable, exactly like owned
        // ones (DESIGN.md §12): a committed shared-prefix slot in a mask
        // row is not an escape.
        let own = crate::kvcache::SlotOwnership::Blocks {
            block_size: 2,
            blocks: vec![3],
            shared: vec![0],
        };
        assert!(rows_owned(&ok, 8, &own), "shared block 0 must be referenceable");
    }

    #[test]
    fn rebuild_reuses_buffer_and_clears_stale_rows() {
        let tree = TokenTree::new(0);
        let mut mb = MaskBuilder::new(4);
        let slot_of = slots(&[(0, 0)], 1);
        let first = mb.build(&tree, &[0], &slot_of, 2).to_vec();
        assert_eq!(&first[0..4], &[1., 0., 0., 0.]);
        // second build with zero nodes: all rows must be padding
        let second = mb.build(&tree, &[], &slot_of, 2).to_vec();
        assert!(second.iter().all(|&x| x == 0.0));
    }
}
