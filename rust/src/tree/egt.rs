//! Equal-Growth Tree frontier — §4.2 of the paper.
//!
//! EGT grows the draft tree in `D_draft` steps of **exactly** `W_draft` new
//! leaves each, so every drafter call has a static shape (one compiled graph
//! per width, zero recompilation). The *positions* of the new leaves are
//! dynamic: each growth step takes the `W_draft` expansions with the highest
//! path probability from a global frontier — a leaf may attach anywhere in
//! the partial tree, including as the k-th sibling of an already-expanded
//! node. Path-wise drafter probabilities act as the acceptance surrogate
//! (the paper cites OPT-Tree for this).
//!
//! The frontier is a max-heap of [`Expansion`]s. When a node is evaluated by
//! the drafter, its top-`branch_candidates` child tokens enter the heap via
//! [`Frontier::push_candidates`]. Popping the rank-`r` child of a node
//! automatically re-inserts the rank-`r+1` sibling, which is what makes the
//! "attach anywhere" property cheap: the heap always holds the single best
//! unexplored sibling of every partially-expanded node.

use super::{NodeId, TokenTree};
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// A candidate expansion: attach `token` as a child of `parent`.
#[derive(Debug, Clone, Copy)]
pub struct Expansion {
    /// Node the candidate attaches under.
    pub parent: NodeId,
    /// Rank of this token in the parent's drafter distribution (0 = top-1).
    pub rank: usize,
    /// Candidate token id.
    pub token: u32,
    /// Drafter probability of `token` at `parent`.
    pub edge_prob: f32,
    /// Path probability of the resulting node (parent path × edge).
    pub path_prob: f32,
}

impl PartialEq for Expansion {
    fn eq(&self, other: &Self) -> bool {
        self.path_prob == other.path_prob
    }
}
impl Eq for Expansion {}
impl PartialOrd for Expansion {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Expansion {
    fn cmp(&self, other: &Self) -> Ordering {
        // Max-heap by path probability; ties broken toward shallower
        // parents (favours breadth, deterministic across runs).
        self.path_prob
            .partial_cmp(&other.path_prob)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.parent.cmp(&self.parent))
            .then_with(|| other.rank.cmp(&self.rank))
    }
}

/// Per-evaluated-node candidate list (token, prob), sorted descending.
#[derive(Debug, Clone)]
struct NodeCandidates {
    items: Vec<(u32, f32)>,
}

/// The global EGT frontier.
#[derive(Debug)]
pub struct Frontier {
    heap: BinaryHeap<Expansion>,
    candidates: Vec<Option<NodeCandidates>>, // indexed by NodeId
    max_depth: usize,
}

impl Frontier {
    /// `max_depth` bounds node depth (tree positions must fit the cache
    /// window); expansions of nodes at `max_depth` are never offered.
    pub fn new(max_depth: usize) -> Self {
        Self { heap: BinaryHeap::new(), candidates: Vec::new(), max_depth }
    }

    /// Registers the drafter's top candidates at `node` (sorted descending
    /// by probability) and seeds the heap with the rank-0 expansion.
    pub fn push_candidates(
        &mut self,
        tree: &TokenTree,
        node: NodeId,
        top: Vec<(u32, f32)>,
    ) {
        if self.candidates.len() <= node {
            self.candidates.resize(node + 1, None);
        }
        debug_assert!(
            top.windows(2).all(|w| w[0].1 >= w[1].1),
            "candidates must be sorted descending"
        );
        if tree.depth(node) as usize >= self.max_depth {
            return; // children would exceed the depth budget
        }
        if let Some(&(token, p)) = top.first() {
            self.heap.push(Expansion {
                parent: node,
                rank: 0,
                token,
                edge_prob: p,
                path_prob: tree.path_prob(node) * p,
            });
        }
        self.candidates[node] = Some(NodeCandidates { items: top });
    }

    /// Pops the best expansion and re-inserts the parent's next-rank
    /// sibling (the "attach anywhere" mechanism).
    pub fn pop_best(&mut self, tree: &TokenTree) -> Option<Expansion> {
        let best = self.heap.pop()?;
        let next_rank = best.rank + 1;
        if let Some(Some(c)) = self.candidates.get(best.parent) {
            if let Some(&(token, p)) = c.items.get(next_rank) {
                self.heap.push(Expansion {
                    parent: best.parent,
                    rank: next_rank,
                    token,
                    edge_prob: p,
                    path_prob: tree.path_prob(best.parent) * p,
                });
            }
        }
        Some(best)
    }

    /// Takes the `w` best expansions (fewer if the frontier is exhausted).
    pub fn pop_w(&mut self, tree: &TokenTree, w: usize) -> Vec<Expansion> {
        let mut out = Vec::with_capacity(w);
        while out.len() < w {
            match self.pop_best(tree) {
                Some(e) => out.push(e),
                None => break,
            }
        }
        out
    }

    /// Best path probability currently available without popping.
    pub fn peek_path_prob(&self) -> Option<f32> {
        self.heap.peek().map(|e| e.path_prob)
    }

    /// True when no expansions remain.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// Grows `tree` by one equal-growth step: pops the `w` globally-best
/// expansions and materialises them as nodes. Returns the new node ids
/// (length ≤ w; caller pads the drafter call to the compiled width).
pub fn grow_step(tree: &mut TokenTree, frontier: &mut Frontier, w: usize) -> Vec<NodeId> {
    let picks = frontier.pop_w(tree, w);
    picks
        .into_iter()
        .map(|e| tree.add_node(e.parent, e.token, e.edge_prob))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn top(v: &[(u32, f32)]) -> Vec<(u32, f32)> {
        v.to_vec()
    }

    #[test]
    fn first_step_takes_root_children_in_order() {
        let mut tree = TokenTree::new(0);
        let mut f = Frontier::new(8);
        f.push_candidates(&tree, 0, top(&[(10, 0.6), (11, 0.3), (12, 0.1)]));
        let ids = grow_step(&mut tree, &mut f, 2);
        assert_eq!(ids.len(), 2);
        assert_eq!(tree.token(ids[0]), 10);
        assert_eq!(tree.token(ids[1]), 11);
        assert_eq!(tree.parent(ids[0]), Some(0));
    }

    #[test]
    fn attach_anywhere_prefers_deep_path_over_shallow_sibling() {
        // root -> a (0.9). a's best child has path 0.9*0.8 = 0.72, which
        // beats the root's rank-1 child (0.05): EGT must deepen, not widen.
        let mut tree = TokenTree::new(0);
        let mut f = Frontier::new(8);
        f.push_candidates(&tree, 0, top(&[(1, 0.9), (2, 0.05)]));
        let ids = grow_step(&mut tree, &mut f, 1);
        let a = ids[0];
        f.push_candidates(&tree, a, top(&[(3, 0.8), (4, 0.1)]));
        let ids2 = grow_step(&mut tree, &mut f, 1);
        assert_eq!(tree.parent(ids2[0]), Some(a));
        assert_eq!(tree.token(ids2[0]), 3);
    }

    #[test]
    fn sibling_reinsertion_widens_when_path_decays() {
        // After taking a's best child (path 0.9*0.2=0.18), the root's
        // rank-1 child (0.5) must be offered next.
        let mut tree = TokenTree::new(0);
        let mut f = Frontier::new(8);
        f.push_candidates(&tree, 0, top(&[(1, 0.9), (2, 0.5)]));
        let a = grow_step(&mut tree, &mut f, 1)[0];
        f.push_candidates(&tree, a, top(&[(3, 0.2)]));
        let picks = f.pop_w(&tree, 2);
        assert_eq!(picks[0].parent, 0);
        assert_eq!(picks[0].token, 2);
        assert_eq!(picks[1].parent, a);
        assert_eq!(picks[1].token, 3);
    }

    #[test]
    fn equal_growth_pads_when_frontier_exhausts() {
        let mut tree = TokenTree::new(0);
        let mut f = Frontier::new(8);
        f.push_candidates(&tree, 0, top(&[(1, 1.0)]));
        let ids = grow_step(&mut tree, &mut f, 4);
        assert_eq!(ids.len(), 1); // only one candidate existed
        assert!(f.is_empty());
    }

    #[test]
    fn depth_budget_blocks_expansion() {
        let mut tree = TokenTree::new(0);
        let mut f = Frontier::new(1);
        f.push_candidates(&tree, 0, top(&[(1, 0.9)]));
        let a = grow_step(&mut tree, &mut f, 1)[0];
        // a is at depth 1 == max_depth: its candidates must be ignored.
        f.push_candidates(&tree, a, top(&[(2, 0.9)]));
        assert!(f.is_empty());
    }

    #[test]
    fn grown_tree_keeps_invariants() {
        let mut tree = TokenTree::new(0);
        let mut f = Frontier::new(4);
        f.push_candidates(&tree, 0, top(&[(1, 0.5), (2, 0.3), (3, 0.2)]));
        for _ in 0..3 {
            let ids = grow_step(&mut tree, &mut f, 2);
            for id in ids {
                f.push_candidates(&tree, id, top(&[(7, 0.6), (8, 0.4)]));
            }
        }
        tree.check_invariants().unwrap();
        assert_eq!(tree.len(), 7); // root + 3 steps × 2
        assert!(tree.expected_aal() > 1.0);
    }
}
