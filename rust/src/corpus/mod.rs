//! Workloads: the synthetic prompt datasets and a byte-level tokenizer.
//!
//! The AOT driver emits `prompts_<dataset>.json` files — prompt sets
//! sampled from the world model at dataset-specific temperatures (the
//! C4 / Wikipedia / CNN-Daily analogs, DESIGN.md §2). [`PromptSet`] loads
//! them; [`synthetic_prompts`] generates seeded uniform-random prompts for
//! tests that must run without artifacts. [`ByteTokenizer`] gives the
//! server demo a human-usable (lossless, byte-level) text interface into
//! the model's token space.

use std::path::Path;

use crate::sampling::XorShiftRng;

/// Dataset names baked by the AOT driver, in paper order.
pub const DATASETS: [&str; 3] = ["c4s", "wiki", "cnnd"];

/// One dataset's prompt list, loaded from the artifact bundle.
#[derive(Debug, Clone)]
pub struct PromptSet {
    /// Dataset name (see [`DATASETS`]).
    pub dataset: String,
    /// Tokenized prompts.
    pub prompts: Vec<Vec<u32>>,
}

impl PromptSet {
    /// Loads `prompts_<dataset>.json` from the artifact bundle.
    pub fn load(artifacts_dir: &Path, dataset: &str) -> crate::Result<Self> {
        let path = artifacts_dir.join(format!("prompts_{dataset}.json"));
        let j = crate::util::json::Json::parse_file(&path)?;
        let prompts = j
            .arr("prompts")?
            .iter()
            .map(|p| {
                p.as_arr()
                    .ok_or_else(|| anyhow::anyhow!("prompt not an array"))?
                    .iter()
                    .map(|t| {
                        t.as_usize()
                            .map(|x| x as u32)
                            .ok_or_else(|| anyhow::anyhow!("bad token"))
                    })
                    .collect::<crate::Result<Vec<u32>>>()
            })
            .collect::<crate::Result<Vec<_>>>()?;
        let ps = PromptSet { dataset: j.str("dataset")?.to_string(), prompts };
        anyhow::ensure!(!ps.prompts.is_empty(), "empty prompt set {dataset}");
        Ok(ps)
    }

    /// Deterministic round-robin prompt iterator.
    pub fn cycle(&self) -> impl Iterator<Item = &Vec<u32>> + '_ {
        self.prompts.iter().cycle()
    }

    /// Number of prompts.
    pub fn len(&self) -> usize {
        self.prompts.len()
    }

    /// True when the set has no prompts (never, post-load).
    pub fn is_empty(&self) -> bool {
        self.prompts.is_empty()
    }
}

/// Seeded uniform-random prompts (vocab-bounded) for artifact-free tests.
pub fn synthetic_prompts(n: usize, len: usize, vocab: u32, seed: u64) -> Vec<Vec<u32>> {
    let mut rng = XorShiftRng::new(seed);
    (0..n)
        .map(|_| (0..len).map(|_| rng.next_u64() as u32 % vocab).collect())
        .collect()
}

/// Lossless byte-level tokenizer: token id = byte value (ids ≥ 256 are
/// reserved for the model's synthetic token space and never produced from
/// text). Lets the serving demo accept and emit UTF-8.
#[derive(Debug, Clone, Copy, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    /// Text → byte token ids.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.as_bytes().iter().map(|&b| b as u32).collect()
    }

    /// Token ids → text (non-byte ids render as `#`).
    pub fn decode(&self, tokens: &[u32]) -> String {
        let bytes: Vec<u8> = tokens
            .iter()
            .map(|&t| if t < 256 { t as u8 } else { b'#' }) // non-byte ids rendered opaquely
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_prompts_are_deterministic_and_bounded() {
        let a = synthetic_prompts(4, 8, 100, 7);
        let b = synthetic_prompts(4, 8, 100, 7);
        assert_eq!(a, b);
        assert!(a.iter().flatten().all(|&t| t < 100));
        assert_ne!(a, synthetic_prompts(4, 8, 100, 8));
    }

    #[test]
    fn byte_tokenizer_roundtrips_ascii() {
        let tk = ByteTokenizer;
        let ids = tk.encode("hello");
        assert_eq!(ids, vec![104, 101, 108, 108, 111]);
        assert_eq!(tk.decode(&ids), "hello");
    }

    #[test]
    fn byte_tokenizer_masks_model_tokens() {
        let tk = ByteTokenizer;
        assert_eq!(tk.decode(&[104, 900]), "h#");
    }

    #[test]
    fn prompt_set_loads_artifacts_if_present() {
        let dir = std::path::Path::new("artifacts");
        if dir.join("prompts_c4s.json").exists() {
            let ps = PromptSet::load(dir, "c4s").unwrap();
            assert_eq!(ps.dataset, "c4s");
            assert!(ps.len() >= 16);
            let first = ps.cycle().next().unwrap();
            assert!(!first.is_empty());
        }
    }
}
