//! Analytical GPU simulator — regenerates the *paper-scale* figure series.
//!
//! The real end-to-end runs in this repository execute on the CPU PJRT
//! backend with small models; who-wins-where at A100/A40 + Llama-2 scale
//! depends on the GPU roofline shape (memory-bound decode, saturating
//! verification curve — Fig. 5-(a)). This module models exactly that:
//!
//! * [`GpuProfile`] — peak FP16 FLOPs, HBM bandwidth and per-call launch
//!   overheads (eager vs compiled) for A100-80G and A40;
//! * [`LlmDims`] — Llama-2-7B/13B targets and Llama-68M/160M drafters;
//! * [`forward_latency`] — roofline latency of a width-`W` forward pass:
//!   `max(compute, memory) + overhead`;
//! * [`SpecSim`] — closed-form speculative-iteration simulator combining
//!   the latency model with a rank-acceptance process (measured on the
//!   real system and transplanted), producing AAL / step latency / TPOT
//!   for every engine archetype of Figs. 5, 6, 10 and 11-(b).
//!
//! Numbers are *estimates of shape*, not of absolute wall time; DESIGN.md
//! §2 records this substitution.

use crate::objective::{LatencyCurve, LatencyModel};
use crate::tree::TreeShape;

/// Accelerator roofline profile.
#[derive(Debug, Clone)]
pub struct GpuProfile {
    /// Marketing name (table labels).
    pub name: &'static str,
    /// Peak dense FP16 TFLOP/s.
    pub peak_tflops: f64,
    /// HBM bandwidth, GB/s.
    pub hbm_gbps: f64,
    /// Achievable fraction of peak for decode-shaped GEMMs.
    pub flops_eff: f64,
    /// Achievable fraction of bandwidth.
    pub bw_eff: f64,
    /// Per-forward CPU launch overhead, eager runtime (per layer).
    pub eager_overhead_per_layer: f64,
    /// Memory-traffic multiplier of the eager runtime (unfused kernels
    /// re-read activations; no CUDA-graph capture).
    pub eager_mem_penalty: f64,
    /// Per-forward overhead under CUDA-Graph/compiled execution (whole
    /// model).
    pub compiled_overhead: f64,
}

/// NVIDIA A100-80G roofline profile.
pub const A100: GpuProfile = GpuProfile {
    name: "A100-80G",
    peak_tflops: 312.0,
    hbm_gbps: 2039.0,
    flops_eff: 0.55,
    bw_eff: 0.75,
    eager_overhead_per_layer: 55e-6,
    eager_mem_penalty: 1.35,
    compiled_overhead: 30e-6,
};

/// NVIDIA A40 roofline profile.
pub const A40: GpuProfile = GpuProfile {
    name: "A40",
    peak_tflops: 149.7,
    hbm_gbps: 696.0,
    flops_eff: 0.5,
    bw_eff: 0.7,
    eager_overhead_per_layer: 55e-6,
    eager_mem_penalty: 1.35,
    compiled_overhead: 30e-6,
};

/// Transformer dimension set (FP16 weights).
#[derive(Debug, Clone)]
pub struct LlmDims {
    /// Model name.
    pub name: &'static str,
    /// Parameter count.
    pub params: f64,
    /// Transformer layers.
    pub layers: usize,
    /// Residual width.
    pub d_model: usize,
}

/// Llama-2-7B dims.
pub fn llama2_7b() -> LlmDims {
    LlmDims { name: "Llama-2-7B", params: 6.74e9, layers: 32, d_model: 4096 }
}

/// Llama-2-13B dims.
pub fn llama2_13b() -> LlmDims {
    LlmDims { name: "Llama-2-13B", params: 13.0e9, layers: 40, d_model: 5120 }
}

/// Llama-68M drafter dims.
pub fn llama_68m() -> LlmDims {
    LlmDims { name: "Llama-68M", params: 68e6, layers: 2, d_model: 768 }
}

/// Llama-160M drafter dims.
pub fn llama_160m() -> LlmDims {
    LlmDims { name: "Llama-160M", params: 162e6, layers: 12, d_model: 768 }
}

/// Roofline latency of one width-`w` forward pass at context length `ctx`.
pub fn forward_latency(m: &LlmDims, g: &GpuProfile, w: usize, ctx: usize, compiled: bool) -> f64 {
    let w = w.max(1) as f64;
    // GEMM compute: 2 FLOPs per weight per token.
    let flops = 2.0 * m.params * w
        // attention score/value compute against the KV cache
        + 4.0 * (m.layers * m.d_model) as f64 * w * ctx as f64;
    // Memory: weights stream once per forward (decode is memory-bound);
    // KV cache read for the attended context.
    let bytes = 2.0 * m.params + 4.0 * (m.layers * m.d_model * ctx) as f64;
    let t_compute = flops / (g.peak_tflops * 1e12 * g.flops_eff);
    let bytes = if compiled { bytes } else { bytes * g.eager_mem_penalty };
    let t_memory = bytes / (g.hbm_gbps * 1e9 * g.bw_eff);
    let overhead = if compiled {
        g.compiled_overhead
    } else {
        g.eager_overhead_per_layer * m.layers as f64
    };
    t_compute.max(t_memory) + overhead
}

/// Latency curve over the graph widths (plugs into the Eq. 3 machinery).
pub fn latency_curve(m: &LlmDims, g: &GpuProfile, ctx: usize, compiled: bool) -> LatencyCurve {
    let pts: Vec<(usize, f64)> = crate::config::GRAPH_WIDTHS
        .iter()
        .map(|&w| (w, forward_latency(m, g, w, ctx, compiled)))
        .collect();
    LatencyCurve::new(&pts)
}

/// Full latency model for a (drafter, verifier) pair on a GPU.
pub fn pair_latency_model(
    dft: &LlmDims,
    tgt: &LlmDims,
    g: &GpuProfile,
    ctx: usize,
    compiled: bool,
    cpu_overhead: f64,
) -> LatencyModel {
    LatencyModel {
        drafter: latency_curve(dft, g, ctx, compiled),
        verifier: latency_curve(tgt, g, ctx, compiled),
        cpu_overhead,
    }
}

/// Closed-form speculative-decoding simulator.
///
/// The acceptance process is summarised by `accept_by_rank` (probability
/// that the verifier's token is the drafter's rank-r candidate, measured
/// on the real system per dataset) — enough to score any static tree shape
/// and the EGT envelope.
#[derive(Debug, Clone)]
pub struct SpecSim {
    /// Latency model driving the iteration cost.
    pub lat: LatencyModel,
    /// Measured acceptance-by-rank process.
    pub accept_by_rank: Vec<f64>,
}

/// Simulated outcome of one engine configuration.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Expected average accepted length.
    pub aal: f64,
    /// Seconds per iteration.
    pub step_latency: f64,
    /// Seconds per token.
    pub tpot: f64,
}

impl SpecSim {
    /// A simulator from a latency model and an acceptance process.
    pub fn new(lat: LatencyModel, accept_by_rank: Vec<f64>) -> Self {
        Self { lat, accept_by_rank }
    }

    /// Coverage probability of a width-`w` growth step (the chance the
    /// true token is among the top-w candidates).
    pub fn q(&self, w: usize) -> f64 {
        self.accept_by_rank.iter().take(w).sum::<f64>().min(0.999)
    }

    /// Scores a static tree shape (sequence / K-ary / Sequoia): expected
    /// AAL from the rank model, iteration latency from per-level widths.
    pub fn score_shape(&self, shape: &TreeShape) -> SimResult {
        let aal = shape.expected_aal(&self.accept_by_rank);
        let draft_widths: Vec<usize> = shape
            .levels()
            .iter()
            .map(|l| crate::config::width_for(l.len()).unwrap_or(64))
            .collect();
        let w_verify = crate::config::width_for(shape.len() + 1).unwrap_or(64);
        self.finish(aal, &draft_widths, w_verify)
    }

    /// Scores an EGT envelope (depth D, width W, verification budget Wv)
    /// with the truncated-geometric AAL model `1 + Σ q_W^d`.
    pub fn score_egt(&self, depth: usize, width: usize, w_verify: usize) -> SimResult {
        // Per-level continuation probability: a width-W equal-growth step
        // spreads its W leaves across the whole tree, so the accepted
        // path's node typically carries only a handful of children — cap
        // the rank coverage at the effective per-node branch.
        let q = self.q(width.min(4));
        let mut aal = 1.0;
        let mut p = 1.0;
        for _ in 0..depth {
            p *= q;
            aal += p;
        }
        let draft_widths = vec![crate::config::width_for(width).unwrap_or(64); depth];
        self.finish(aal, &draft_widths, w_verify)
    }

    /// Scores vanilla autoregressive decoding.
    pub fn score_vanilla(&self) -> SimResult {
        let t = self.lat.t_verify(1);
        SimResult { aal: 1.0, step_latency: t, tpot: t }
    }

    fn finish(&self, aal: f64, draft_widths: &[usize], w_verify: usize) -> SimResult {
        let step = self.lat.iteration_seconds(draft_widths, w_verify);
        SimResult { aal, step_latency: step, tpot: step / aal }
    }

    /// Picks the best EGT configuration under the Eq. 3 objective — the
    /// simulated Yggdrasil (context-averaged).
    pub fn best_egt(
        &self,
        max_depth: usize,
        max_width: usize,
        max_verify: usize,
    ) -> (usize, usize, usize, SimResult) {
        let mut best: Option<(usize, usize, usize, SimResult)> = None;
        for &w in crate::config::GRAPH_WIDTHS.iter().filter(|&&w| w <= max_width) {
            for d in 1..=max_depth {
                for &wv in crate::config::GRAPH_WIDTHS.iter().filter(|&&x| x <= max_verify) {
                    if wv < w + 1 {
                        continue;
                    }
                    let r = self.score_egt(d, w, wv.min(d * w + 1));
                    if best.as_ref().map_or(true, |(_, _, _, b)| r.tpot < b.tpot) {
                        best = Some((d, w, wv, r));
                    }
                }
            }
        }
        best.unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rank_model() -> Vec<f64> {
        vec![0.62, 0.12, 0.05, 0.03, 0.02, 0.01, 0.01, 0.01]
    }

    #[test]
    fn decode_is_memory_bound_on_a100() {
        let m = llama2_7b();
        // At w=1 memory dominates: latency ≈ weight-streaming time.
        let t1 = forward_latency(&m, &A100, 1, 256, true);
        let t_mem = 2.0 * m.params / (A100.hbm_gbps * 1e9 * A100.bw_eff);
        assert!((t1 - t_mem - A100.compiled_overhead).abs() / t1 < 0.2);
        // The curve is flat in the memory-bound region then rises: the
        // Fig. 5-(a) saturation shape.
        let t8 = forward_latency(&m, &A100, 8, 256, true);
        let t64 = forward_latency(&m, &A100, 64, 256, true);
        let t256 = forward_latency(&m, &A100, 256, 256, true);
        assert!((t8 - t1) / t1 < 0.05, "w=8 should ride the memory bound");
        assert!(t256 > t64, "eventually compute-bound");
    }

    #[test]
    fn eager_overhead_dwarfs_compiled_for_deep_models() {
        let m = llama2_7b();
        let e = forward_latency(&m, &A100, 1, 128, false);
        let c = forward_latency(&m, &A100, 1, 128, true);
        assert!(e > c, "eager {e} vs compiled {c}");
        let d = llama_160m();
        let ed = forward_latency(&d, &A100, 1, 128, false);
        let cd = forward_latency(&d, &A100, 1, 128, true);
        assert!(ed / cd > 1.05, "compiled wins hardest on small models");
    }

    #[test]
    fn a40_is_slower_than_a100() {
        let m = llama2_7b();
        assert!(
            forward_latency(&m, &A40, 1, 128, true) > forward_latency(&m, &A100, 1, 128, true)
        );
    }

    #[test]
    fn speculation_beats_vanilla_in_sim() {
        let lat = pair_latency_model(&llama_68m(), &llama2_7b(), &A100, 256, true, 1e-4);
        let sim = SpecSim::new(lat, rank_model());
        let vanilla = sim.score_vanilla();
        let seq = sim.score_shape(&TreeShape::sequence(5));
        assert!(seq.aal > 1.8);
        assert!(seq.tpot < vanilla.tpot, "sequence spec must win on A100");
        let (d, w, wv, egt) = sim.best_egt(16, 16, 64);
        assert!(egt.tpot <= seq.tpot, "EGT ({d},{w},{wv}) must beat a fixed chain");
    }

    #[test]
    fn oversized_verification_hurts_tpot() {
        let lat = pair_latency_model(&llama_68m(), &llama2_7b(), &A100, 256, true, 1e-4);
        let sim = SpecSim::new(lat, rank_model());
        let small = sim.score_egt(4, 2, 16);
        let huge = sim.score_egt(4, 2, 64);
        assert!(small.tpot <= huge.tpot + 1e-12);
        assert!((small.aal - huge.aal).abs() < 1e-12);
    }

    #[test]
    fn q_is_monotone_in_width() {
        let lat = pair_latency_model(&llama_68m(), &llama2_7b(), &A100, 128, true, 1e-4);
        let sim = SpecSim::new(lat, rank_model());
        assert!(sim.q(1) < sim.q(4));
        assert!(sim.q(8) <= 0.999);
    }
}
