//! The tree-speculation decode engine.
//!
//! One engine implements the whole design space of Table 1: the tree
//! structure (sequence / K-ary / Sequoia / EGT), the optimization objective
//! (AAL vs Eq. 3), verification-width pruning, the depth predictor, the
//! eager-vs-compiled runtime, and the §5 stage-scheduling plans. The
//! Yggdrasil configuration is simply "all of them on"
//! ([`crate::config::EngineConfig::default`]); every baseline is a preset.
//!
//! ## One decoding iteration (Fig. 9)
//!
//! ```text
//! head-draft(root)                       drafter w1   (skipped on AOT-head/tail hit)
//! D × tree-draft (equal growth, W wide)  drafter wW
//! prune (tree-knapsack DP, Eq. 3)        CPU
//! verify (pruned tree + root)            verifier wWv
//!   └ AOT tail draft (top leaf conts.)   drafter wT   (queued behind verify)
//! accept (greedy / stochastic walk)      CPU          (overlaps tail draft)
//!   └ AOT head draft (bonus token)       drafter w1   (overlaps bookkeeping)
//! bookkeeping (commit/free slots, stats) CPU
//! ```

use std::time::Instant;

use crate::config::{width_for, EngineConfig, TreeStructure};
use crate::metrics::Recorder;
use crate::objective::{select_draft_width, AcceptanceStats, LatencyModel};
use crate::predictor::DepthPredictor;
use crate::pruning::prune_for_objective;
use crate::runtime::{ForwardReply, Pending, Runtime};
use crate::sampling::{
    categorical, softmax_inplace, stochastic_accept, top_k, AcceptOutcome, XorShiftRng,
};
use crate::scheduler::{self, Plan, StageDurations};
use crate::tree::{grow_step, Frontier, NodeId, TokenTree, TreeShape};

use super::session::Session;
use super::Generation;

/// A head draft issued ahead of time (or satisfied by a tail-draft hit).
struct PendingHead {
    /// In-flight call, or `None` when the reply is already materialised.
    pending: Option<Pending<ForwardReply>>,
    reply: Option<HeadReply>,
    /// Drafter slot holding the root's K/V.
    slot: u32,
    /// The token this head draft evaluated (must equal the next root).
    token: u32,
}

/// Extracted row of a drafter reply for the head token.
#[derive(Clone)]
struct HeadReply {
    logits: Vec<f32>,
}

/// Per-iteration tree bookkeeping, parallel to [`TokenTree`] node ids.
struct IterState {
    tree: TokenTree,
    /// Drafter cache slot per node (Some for every drafter-evaluated node).
    dslots: Vec<Option<u32>>,
    /// Verifier cache slot per node (Some for nodes in the pruned set).
    vslots: Vec<Option<u32>>,
    /// Drafter candidate children per evaluated node: (token, prob) sorted
    /// descending (top-k at T=0; i.i.d. samples deduped at T>0).
    cands: Vec<Option<Vec<(u32, f32)>>>,
    /// Full drafter probability vector per evaluated node (kept only at
    /// temperature > 0, for the stochastic acceptance rule).
    dists: Vec<Option<Vec<f32>>>,
}

impl IterState {
    fn new(root: u32) -> Self {
        Self {
            tree: TokenTree::new(root),
            dslots: vec![None],
            vslots: vec![None],
            cands: vec![None],
            dists: vec![None],
        }
    }

    fn push_nodes(&mut self, n: usize) {
        self.dslots.resize(self.dslots.len() + n, None);
        self.vslots.resize(self.vslots.len() + n, None);
        self.cands.resize(self.cands.len() + n, None);
        self.dists.resize(self.dists.len() + n, None);
    }
}

/// The speculative decoding engine.
pub struct SpecDecoder {
    rt: Runtime,
    pub cfg: EngineConfig,
    pub lat: LatencyModel,
    pub stats: AcceptanceStats,
    pub predictor: Option<DepthPredictor>,
    plan: Plan,
    /// EWMA of the AOT-tail hit rate (next head token pre-drafted).
    tail_hit_rate: f64,
    /// Cached Sequoia shape per (budget, stats-epoch).
    sequoia_cache: Option<(usize, TreeShape)>,
    /// Depth predicted for the next iteration (from the last verify's
    /// hidden state).
    depth_hint: Option<usize>,
    /// (hidden state, accepted count of the *following* iteration) pairs —
    /// the depth predictor's training data.
    depth_samples: Vec<(Vec<f32>, usize)>,
    label: String,
}

impl SpecDecoder {
    pub fn new(
        rt: &Runtime,
        cfg: EngineConfig,
        lat: LatencyModel,
        predictor: Option<DepthPredictor>,
    ) -> Self {
        let est = StageDurations::estimate(
            &lat,
            cfg.max_depth,
            cfg.max_width,
            cfg.max_verify,
            width_for(4).unwrap(),
        );
        let plan = scheduler::resolve(cfg.schedule, &est);
        // Compile every width graph up front: the adaptive ⟨D, W, Wv⟩
        // selection may touch any of them, and a mid-decode compile stall
        // (~1 s) is exactly the "dynamic shapes break static runtimes"
        // failure mode this system exists to avoid.
        let _ = rt.precompile(&cfg.drafter, &crate::config::GRAPH_WIDTHS);
        let _ = rt.precompile(&cfg.target, &crate::config::GRAPH_WIDTHS);
        let label = format!(
            "spec[{}|{}|{}{}{}{}]",
            cfg.tree.as_str(),
            cfg.objective.as_str(),
            if cfg.compiled { "compiled" } else { "eager" },
            if cfg.prune { "+prune" } else { "" },
            if cfg.use_depth_predictor { "+pred" } else { "" },
            format_args!("+{}", plan.name()),
        );
        Self {
            rt: rt.clone(),
            cfg,
            lat,
            stats: AcceptanceStats::default(),
            predictor,
            plan,
            tail_hit_rate: 0.3,
            sequoia_cache: None,
            depth_hint: None,
            depth_samples: Vec::new(),
            label,
        }
    }

    pub fn plan(&self) -> Plan {
        self.plan
    }

    /// Re-runs the profile-guided plan search with *measured* stage
    /// durations from `rec` (call after a calibration generation).
    pub fn research_plan(&mut self, rec: &Recorder) {
        if self.cfg.schedule != crate::config::SchedulePlan::ProfileSearch {
            return;
        }
        let d = StageDurations {
            head_draft: rec.mean("stage.head_draft").max(1e-6),
            tree_draft: rec.mean("stage.tree_draft").max(1e-6),
            cpu_build: rec.mean("stage.cpu_build").max(1e-7),
            verify: rec.mean("stage.verify").max(1e-6),
            tail_draft: rec.mean("stage.tail_draft").max(1e-6),
            accept: rec.mean("stage.accept").max(1e-7),
            bookkeep: rec.mean("stage.bookkeep").max(1e-7),
            tail_hit_rate: self.tail_hit_rate,
        };
        let (plan, _) = scheduler::search_best_plan(&d);
        self.plan = plan;
    }

    // ------------------------------------------------------------------
    // Drafting
    // ------------------------------------------------------------------

    /// Candidate children of a node from its drafter logits: top-k at
    /// T = 0, i.i.d. samples (deduped, q-sorted) at T > 0 — the latter is
    /// what the stochastic acceptance rule's lossless guarantee expects.
    fn candidates(&self, logits: &[f32], k: usize, rng: &mut XorShiftRng) -> Vec<(u32, f32)> {
        let temp = self.cfg.sampling.temperature;
        if temp == 0.0 {
            let mut probs = logits.to_vec();
            softmax_inplace(&mut probs, 1.0);
            return top_k(&probs, k).into_iter().map(|(i, p)| (i as u32, p)).collect();
        }
        let mut probs = logits.to_vec();
        softmax_inplace(&mut probs, temp);
        let mut out: Vec<(u32, f32)> = Vec::with_capacity(k);
        for _ in 0..k {
            let t = categorical(&probs, rng) as u32;
            if !out.iter().any(|&(x, _)| x == t) {
                out.push((t, probs[t as usize]));
            }
        }
        out.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        out
    }

    fn temp_probs(&self, logits: &[f32]) -> Vec<f32> {
        let mut p = logits.to_vec();
        softmax_inplace(&mut p, self.cfg.sampling.temperature.max(1e-6));
        p
    }

    /// Evaluates `nodes` (all newly added, same growth step) through the
    /// drafter. Fills slots/cands/dists.
    fn draft_nodes(
        &mut self,
        sess: &mut Session,
        st: &mut IterState,
        nodes: &[NodeId],
        root_pos: i32,
    ) -> crate::Result<bool> {
        let n = nodes.len();
        let Some(width) = width_for(n) else {
            anyhow::bail!("draft step of {n} tokens exceeds compiled widths")
        };
        let Some(slots) = sess.drafter.slots.alloc(n) else {
            return Ok(false); // cache exhausted — caller stops growth
        };
        for (i, &node) in nodes.iter().enumerate() {
            st.dslots[node] = Some(slots[i]);
        }
        let tokens: Vec<u32> = nodes.iter().map(|&id| st.tree.token(id)).collect();
        let positions: Vec<i32> =
            nodes.iter().map(|&id| root_pos + st.tree.depth(id) as i32).collect();
        let mask = sess
            .drafter
            .slots
            .mask_builder()
            .build(&st.tree, nodes, &st.dslots, width)
            .to_vec();
        let req =
            sess.drafter
                .padded_request(width, &tokens, &positions, &slots, &mask, sess.exec_mode());
        let reply = self.rt.forward(req)?;
        let vocab = sess.drafter.spec.vocab;
        let keep_dist = self.cfg.sampling.temperature > 0.0;
        for (i, &node) in nodes.iter().enumerate() {
            let row = &reply.logits[i * vocab..(i + 1) * vocab];
            let cands = self.candidates(row, self.cfg.branch_candidates, &mut sess.rng);
            st.cands[node] = Some(cands);
            if keep_dist {
                st.dists[node] = Some(self.temp_probs(row));
            }
        }
        Ok(true)
    }

    /// Grows the draft tree according to the configured structure.
    /// Returns the per-step drafter widths (for the Eq. 3 denominator).
    fn build_tree(
        &mut self,
        sess: &mut Session,
        st: &mut IterState,
        depth: usize,
        width: usize,
        root_pos: i32,
    ) -> crate::Result<Vec<usize>> {
        let mut draft_widths = Vec::new();
        match self.cfg.tree {
            TreeStructure::Egt => {
                let mut frontier = Frontier::new(depth);
                let root_cands = st.cands[0].clone().unwrap_or_default();
                frontier.push_candidates(&st.tree, 0, root_cands);
                // With pruning on, over-grow (the DP trims to budget);
                // without it the grown tree itself must stay verifiable.
                let cap = if self.cfg.prune {
                    self.cfg.max_verify * 2
                } else {
                    self.cfg.max_verify
                }
                .min(64 + 64 * self.cfg.prune as usize);
                for _ in 0..depth {
                    let remaining = cap.saturating_sub(st.tree.len());
                    if remaining == 0 {
                        break;
                    }
                    let w = width.min(remaining);
                    let before = st.tree.len();
                    let ids = grow_step(&mut st.tree, &mut frontier, w);
                    if ids.is_empty() {
                        break;
                    }
                    st.push_nodes(st.tree.len() - before);
                    if !self.draft_nodes(sess, st, &ids, root_pos)? {
                        break;
                    }
                    draft_widths.push(width_for(ids.len()).unwrap_or(64));
                    for &id in &ids {
                        let cands = st.cands[id].clone().unwrap_or_default();
                        frontier.push_candidates(&st.tree, id, cands);
                    }
                }
            }
            _ => {
                let shape = self.static_shape();
                // Map shape ids (0 = root) to tree node ids.
                let mut node_of: Vec<Option<NodeId>> = vec![None; shape.len() + 1];
                node_of[0] = Some(0);
                for level in shape.levels() {
                    let mut new_nodes = Vec::new();
                    for sid in level {
                        let sn = shape.nodes[sid - 1];
                        let Some(parent) = node_of[sn.parent] else { continue };
                        let Some(cands) = &st.cands[parent] else { continue };
                        let Some(&(token, prob)) = cands.get(sn.rank) else { continue };
                        let before = st.tree.len();
                        let id = st.tree.add_node(parent, token, prob);
                        st.push_nodes(st.tree.len() - before);
                        node_of[sid] = Some(id);
                        new_nodes.push(id);
                    }
                    if new_nodes.is_empty() {
                        break;
                    }
                    if !self.draft_nodes(sess, st, &new_nodes, root_pos)? {
                        break;
                    }
                    draft_widths.push(width_for(new_nodes.len()).unwrap_or(64));
                }
            }
        }
        Ok(draft_widths)
    }

    /// The static shape for the configured baseline structure.
    fn static_shape(&mut self) -> TreeShape {
        let budget = self.cfg.max_verify.min(64).saturating_sub(1).max(1);
        match self.cfg.tree {
            TreeStructure::Sequence => TreeShape::sequence(self.cfg.max_depth.min(budget)),
            TreeStructure::KAry => {
                TreeShape::k_ary(self.cfg.max_width, self.cfg.max_depth, budget)
            }
            TreeStructure::Sequoia => {
                if let Some((b, shape)) = &self.sequoia_cache {
                    if *b == budget {
                        return shape.clone();
                    }
                }
                let shape = TreeShape::sequoia(&self.stats.accept_by_rank, budget);
                self.sequoia_cache = Some((budget, shape.clone()));
                shape
            }
            TreeStructure::Egt => unreachable!("EGT has no static shape"),
        }
    }

    // ------------------------------------------------------------------
    // The decoding iteration
    // ------------------------------------------------------------------

    /// Runs one full iteration. Returns the tokens committed by it (the
    /// accepted path plus the bonus token) and the new pending head.
    #[allow(clippy::too_many_lines)]
    fn iteration(
        &mut self,
        sess: &mut Session,
        head: PendingHead,
        rec: &mut Recorder,
    ) -> crate::Result<(Vec<u32>, Option<PendingHead>, Vec<f32>)> {
        let root_pos = (sess.committed_len() - 1) as i32;
        let root_token = *sess.committed.last().unwrap();
        debug_assert_eq!(head.token, root_token);

        // -------- head draft (possibly already satisfied) ----------------
        let t0 = Instant::now();
        let head_logits = match (head.reply, head.pending) {
            (Some(r), _) => r.logits,
            (None, Some(p)) => {
                let reply = p.wait()?;
                let v = sess.drafter.spec.vocab;
                reply.logits[..v].to_vec()
            }
            (None, None) => unreachable!("head draft neither pending nor ready"),
        };
        rec.record("stage.head_draft", t0.elapsed().as_secs_f64());

        let mut st = IterState::new(root_token);
        st.dslots[0] = Some(head.slot);
        st.cands[0] = Some(self.candidates(&head_logits, self.cfg.branch_candidates, &mut sess.rng));
        if self.cfg.sampling.temperature > 0.0 {
            st.dists[0] = Some(self.temp_probs(&head_logits));
        }

        // -------- depth / width decisions (O1 + O5) ----------------------
        // The depth predictor (O5), when trained, supplies the per-context
        // depth; otherwise Eq. 3 selects the latency-optimal ⟨D, W⟩ from
        // the profiled curves and the online acceptance stats. The AAL
        // objective (Fig. 14 ablation / baselines) degenerates to the
        // maximal envelope, reproducing prior work's behaviour.
        let (depth, width) = match self.cfg.tree {
            TreeStructure::Egt => {
                let hinted = self.cfg.use_depth_predictor.then(|| self.depth_hint.take()).flatten();
                match hinted {
                    Some(d) => {
                        let d = d.clamp(1, self.cfg.max_depth);
                        let w = select_draft_width(
                            &self.stats,
                            &self.lat,
                            self.cfg.objective,
                            d,
                            self.cfg.max_width,
                            self.cfg.max_verify,
                        );
                        (d, w)
                    }
                    None => crate::objective::select_depth_width(
                        &self.stats,
                        &self.lat,
                        self.cfg.objective,
                        self.cfg.max_depth,
                        self.cfg.max_width,
                        self.cfg.max_verify,
                    ),
                }
            }
            _ => (self.cfg.max_depth, self.cfg.max_width),
        };
        rec.record("depth", depth as f64);
        rec.record("width", width as f64);

        // -------- tree drafting ------------------------------------------
        let t0 = Instant::now();
        let draft_widths = self.build_tree(sess, &mut st, depth, width, root_pos)?;
        rec.record("stage.tree_draft", t0.elapsed().as_secs_f64());
        rec.record("tree_size", st.tree.len() as f64);

        // -------- pruning (O3) -------------------------------------------
        let t0 = Instant::now();
        let (keep, w_verify) = if self.cfg.prune && st.tree.len() > 2 {
            prune_for_objective(&st.tree, &self.lat, &draft_widths, self.cfg.max_verify)
        } else {
            let keep: Vec<NodeId> = (0..st.tree.len()).collect();
            let w = width_for(keep.len())
                .ok_or_else(|| anyhow::anyhow!("tree of {} nodes unverifiable", keep.len()))?;
            (keep, w)
        };
        rec.record("stage.cpu_build", t0.elapsed().as_secs_f64());
        rec.record("w_verify", w_verify as f64);

        // -------- verification -------------------------------------------
        let Some(vslots) = sess.target.slots.alloc(keep.len()) else {
            anyhow::bail!("verifier cache exhausted")
        };
        for (i, &node) in keep.iter().enumerate() {
            st.vslots[node] = Some(vslots[i]);
        }
        let vtokens: Vec<u32> = keep.iter().map(|&id| st.tree.token(id)).collect();
        let vpositions: Vec<i32> =
            keep.iter().map(|&id| root_pos + st.tree.depth(id) as i32).collect();
        let vmask = sess
            .target
            .slots
            .mask_builder()
            .build(&st.tree, &keep, &st.vslots, w_verify)
            .to_vec();
        let vreq = sess.target.padded_request(
            w_verify,
            &vtokens,
            &vpositions,
            &vslots,
            &vmask,
            sess.exec_mode(),
        );
        let t0 = Instant::now();
        let verify_pending = self.rt.submit(vreq)?;

        // -------- AOT tail draft (§5.1) -----------------------------------
        // Queue the most likely next-root continuations behind the verify
        // call; they execute while the CPU walks acceptance.
        let mut tail: Vec<(NodeId, u32, u32)> = Vec::new(); // (leaf, token, slot)
        let mut tail_pending: Option<Pending<ForwardReply>> = None;
        if self.plan.aot_tail {
            let t_tail = Instant::now();
            let mut leaves: Vec<NodeId> = keep
                .iter()
                .copied()
                .filter(|&id| {
                    // leaf within the pruned set
                    !st.tree.children(id).iter().any(|c| keep.contains(c))
                })
                .collect();
            leaves.sort_by(|&a, &b| {
                st.tree.path_prob(b).partial_cmp(&st.tree.path_prob(a)).unwrap()
            });
            let t_width = 4usize;
            let picks: Vec<NodeId> = leaves
                .into_iter()
                .filter(|&l| st.cands[l].as_ref().map_or(false, |c| !c.is_empty()))
                .take(t_width)
                .collect();
            if !picks.is_empty() {
                if let Some(slots) = sess.drafter.slots.alloc(picks.len()) {
                    let mut tokens = Vec::new();
                    let mut positions = Vec::new();
                    let mut dsl = st.dslots.clone();
                    // Temporarily extend the tree with the tail nodes so the
                    // mask builder sees their ancestry.
                    let mut tmp_tree = st.tree.clone();
                    let mut nodes = Vec::new();
                    for (i, &leaf) in picks.iter().enumerate() {
                        let (tok, p) = st.cands[leaf].as_ref().unwrap()[0];
                        let id = tmp_tree.add_node(leaf, tok, p);
                        dsl.push(Some(slots[i]));
                        nodes.push(id);
                        tokens.push(tok);
                        positions.push(root_pos + tmp_tree.depth(id) as i32);
                        tail.push((leaf, tok, slots[i]));
                    }
                    let width = width_for(picks.len()).unwrap();
                    let mask = sess
                        .drafter
                        .slots
                        .mask_builder()
                        .build(&tmp_tree, &nodes, &dsl, width)
                        .to_vec();
                    let req = sess.drafter.padded_request(
                        width,
                        &tokens,
                        &positions,
                        &slots,
                        &mask,
                        sess.exec_mode(),
                    );
                    tail_pending = Some(self.rt.submit(req)?);
                }
            }
            rec.record("stage.tail_submit", t_tail.elapsed().as_secs_f64());
        }

        let vreply = verify_pending.wait()?;
        rec.record("stage.verify", t0.elapsed().as_secs_f64());
        rec.record("stage.verify_exec", vreply.exec_seconds);

        // -------- acceptance walk ----------------------------------------
        let t0 = Instant::now();
        let vocab = sess.target.spec.vocab;
        let row_of = |node: NodeId| -> usize { keep.iter().position(|&k| k == node).unwrap() };
        let mut accepted_path: Vec<NodeId> = vec![0];
        let mut cur = 0usize;
        let bonus: u32;
        loop {
            let row = &vreply.logits[row_of(cur) * vocab..(row_of(cur) + 1) * vocab];
            // Children of cur inside the pruned set, in candidate order.
            let kids: Vec<NodeId> = st
                .tree
                .children(cur)
                .iter()
                .copied()
                .filter(|c| keep.contains(c))
                .collect();
            let kid_tokens: Vec<u32> = kids.iter().map(|&k| st.tree.token(k)).collect();
            let outcome = if self.cfg.sampling.temperature == 0.0 {
                let (o, truth) = crate::sampling::greedy_accept(row, &kid_tokens);
                // Rank bookkeeping for Sequoia / Fig. 11.
                let rank = st.cands[cur]
                    .as_ref()
                    .and_then(|c| c.iter().position(|&(t, _)| t == truth));
                self.stats.record_rank(rank);
                o
            } else {
                let p = self.temp_probs(row);
                let q = st.dists[cur].clone().unwrap_or_else(|| vec![1.0 / vocab as f32; vocab]);
                let o = stochastic_accept(&p, &q, &kid_tokens, &mut sess.rng);
                if let AcceptOutcome::Child(i) = o {
                    let rank = st.cands[cur]
                        .as_ref()
                        .and_then(|c| c.iter().position(|&(t, _)| t == kid_tokens[i]));
                    self.stats.record_rank(rank);
                } else {
                    self.stats.record_rank(None);
                }
                o
            };
            match outcome {
                AcceptOutcome::Child(i) => {
                    cur = kids[i];
                    accepted_path.push(cur);
                }
                AcceptOutcome::Bonus(b) => {
                    bonus = b;
                    break;
                }
            }
        }
        let accepted_draft = accepted_path.len() - 1; // excludes root
        rec.record("stage.accept", t0.elapsed().as_secs_f64());
        rec.record("accepted", (accepted_draft + 1) as f64);

        // Coverage stats for the width selector: growth step d covered the
        // true continuation iff the walk descended at least d times.
        let steps_grown = draft_widths.len();
        for d in 1..=steps_grown {
            self.stats.record_step(width, d <= accepted_draft);
        }

        // Depth-predictor hint for the next iteration, from the hidden
        // state at the deepest accepted node (the bonus context).
        let d_model = sess.target.spec.d_model;
        let hid_row = row_of(cur);
        let hidden = vreply.hidden[hid_row * d_model..(hid_row + 1) * d_model].to_vec();
        if self.cfg.use_depth_predictor {
            if let Some(p) = &self.predictor {
                if p.input_dim == d_model {
                    self.depth_hint = Some(p.predict_depth(&hidden, 0.45));
                }
            }
        }

        // -------- AOT head draft / tail-hit resolution --------------------
        let t0 = Instant::now();
        let mut tail_rows: Option<ForwardReply> = None;
        if let Some(p) = tail_pending {
            // The tail draft finished during the acceptance walk (device
            // FIFO); this wait is usually instant.
            let r = p.wait()?;
            rec.record("stage.tail_draft", r.exec_seconds);
            tail_rows = Some(r);
        }
        let mut next_head: Option<PendingHead> = None;
        let mut tail_hit = false;
        if let Some(rows) = &tail_rows {
            let v = sess.drafter.spec.vocab;
            for (i, &(leaf, tok, slot)) in tail.iter().enumerate() {
                if leaf == cur && tok == bonus {
                    // The speculative tail draft already evaluated the next
                    // root: reuse its logits row and slot.
                    next_head = Some(PendingHead {
                        pending: None,
                        reply: Some(HeadReply { logits: rows.logits[i * v..(i + 1) * v].to_vec() }),
                        slot,
                        token: bonus,
                    });
                    tail_hit = true;
                    break;
                }
            }
        }
        self.tail_hit_rate = 0.95 * self.tail_hit_rate + 0.05 * (tail_hit as u8 as f64);
        rec.record("tail_hit", tail_hit as u8 as f64);

        if next_head.is_none() {
            // Issue the (real) head draft for the bonus token. Under the
            // AOT-head plan this submission happens *before* bookkeeping so
            // the drafter runs while the CPU cleans up.
            if let Some(slot) = sess.drafter.slots.alloc(1).map(|v| v[0]) {
                let mut dsl = st.dslots.clone();
                let mut tmp_tree = st.tree.clone();
                let id = tmp_tree.add_node(cur, bonus, 1.0);
                dsl.push(Some(slot));
                let mask = sess
                    .drafter
                    .slots
                    .mask_builder()
                    .build(&tmp_tree, &[id], &dsl, 1)
                    .to_vec();
                let positions = vec![root_pos + tmp_tree.depth(id) as i32];
                let req = sess.drafter.padded_request(
                    1,
                    &[bonus],
                    &positions,
                    &[slot],
                    &mask,
                    sess.exec_mode(),
                );
                let pending = self.rt.submit(req)?;
                let mut head = PendingHead { pending: Some(pending), reply: None, slot, token: bonus };
                if !self.plan.aot_head {
                    // Sequential plan: block right here.
                    let reply = head.pending.take().unwrap().wait()?;
                    let v = sess.drafter.spec.vocab;
                    head.reply = Some(HeadReply { logits: reply.logits[..v].to_vec() });
                }
                next_head = Some(head);
            }
        }
        rec.record("stage.head_submit", t0.elapsed().as_secs_f64());

        // -------- bookkeeping ---------------------------------------------
        let t0 = Instant::now();
        // Commit accepted slots on both sides; free the rest.
        for node in 0..st.tree.len() {
            let on_path = accepted_path.contains(&node);
            if let Some(s) = st.dslots[node] {
                if on_path {
                    sess.drafter.slots.commit(s);
                } else {
                    sess.drafter.slots.release(&[s]);
                }
            }
            if let Some(s) = st.vslots[node] {
                if on_path {
                    sess.target.slots.commit(s);
                } else {
                    sess.target.slots.release(&[s]);
                }
            }
        }
        // Tail slots: the hit (if any) lives on as the next head slot.
        for &(_, _, slot) in &tail {
            let kept = next_head.as_ref().map_or(false, |h| h.slot == slot);
            if !kept {
                sess.drafter.slots.release(&[slot]);
            }
        }
        let mut out: Vec<u32> = accepted_path[1..].iter().map(|&n| st.tree.token(n)).collect();
        out.push(bonus);
        sess.committed.extend_from_slice(&out);
        rec.record("stage.bookkeep", t0.elapsed().as_secs_f64());

        Ok((out, next_head, hidden))
    }

    /// Collected depth-predictor training sample: hidden state paired with
    /// the *next* iteration's accepted count (filled by the trainer).
    pub fn take_depth_samples(&mut self) -> Vec<(Vec<f32>, usize)> {
        std::mem::take(&mut self.depth_samples)
    }
}

// Fields that need interior iteration state (declared separately for
// readability of the main impl above).
impl SpecDecoder {
    fn initial_head(&self, sess: &mut Session) -> crate::Result<PendingHead> {
        let root_token = *sess.committed.last().unwrap();
        let root_pos = (sess.committed_len() - 1) as i32;
        let slot = sess
            .drafter
            .slots
            .alloc(1)
            .ok_or_else(|| anyhow::anyhow!("drafter cache exhausted at start"))?[0];
        let mut mb = sess.drafter.slots.mask_builder().clone();
        mb.commit_slot(slot); // root attends to itself + prefix
        let tree = TokenTree::new(root_token);
        let mask = mb.build(&tree, &[0], &[Some(slot)], 1).to_vec();
        let req = sess.drafter.padded_request(
            1,
            &[root_token],
            &[root_pos],
            &[slot],
            &mask,
            sess.exec_mode(),
        );
        let reply = self.rt.forward(req)?;
        let v = sess.drafter.spec.vocab;
        Ok(PendingHead {
            pending: None,
            reply: Some(HeadReply { logits: reply.logits[..v].to_vec() }),
            slot,
            token: root_token,
        })
    }
}

impl super::Engine for SpecDecoder {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn generate_with(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        sink: super::TokenSink,
    ) -> crate::Result<Generation> {
        let mut sess = Session::new(
            &self.rt,
            &self.cfg.drafter,
            &self.cfg.target,
            self.cfg.sampling.seed,
            self.cfg.compiled,
        )?;
        let t_prefill = Instant::now();
        let prefill_reply = sess.prefill(prompt)?;
        let prefill_seconds = t_prefill.elapsed().as_secs_f64();

        // Seed the depth hint from the prefill hidden state.
        if let (Some(p), Some(r)) = (&self.predictor, &prefill_reply) {
            let d = sess.target.spec.d_model;
            if p.input_dim == d && r.hidden.len() >= d {
                let last = &r.hidden[r.hidden.len() - d..];
                self.depth_hint = Some(p.predict_depth(last, 0.45));
            }
        }

        let mut rec = Recorder::new();
        let mut tokens = Vec::new();
        let mut iterations = 0usize;
        // The context embedding that *preceded* each iteration (predictor
        // training pairs it with that iteration's accepted count).
        let mut prev_hidden: Option<Vec<f32>> = prefill_reply.as_ref().and_then(|r| {
            let d = sess.target.spec.d_model;
            (r.hidden.len() >= d).then(|| r.hidden[r.hidden.len() - d..].to_vec())
        });
        let t0 = Instant::now();
        let mut head = self.initial_head(&mut sess)?;
        // Keep enough headroom for one full tree + tail + bonus chain.
        let tree_budget = self.cfg.max_depth * self.cfg.max_width + self.cfg.max_verify + 8;
        while tokens.len() < max_new && sess.headroom(tree_budget) > 0 {
            let t_iter = Instant::now();
            let (out, next_head, hidden) = self.iteration(&mut sess, head, &mut rec)?;
            rec.record("stage.iter", t_iter.elapsed().as_secs_f64());
            iterations += 1;
            // Depth-predictor training data: the hidden state seen *before*
            // this iteration, labelled with how many draft tokens it
            // accepted.
            if let Some(ph) = prev_hidden.take() {
                self.depth_samples.push((ph, out.len().saturating_sub(1)));
            }
            prev_hidden = Some(hidden);
            let room = max_new.saturating_sub(tokens.len());
            sink(&out[..out.len().min(room)]);
            tokens.extend_from_slice(&out);
            match next_head {
                Some(h) => head = h,
                None => break, // cache exhausted
            }
            // Refresh the measured CPU-overhead term of the objective.
            let cpu = rec.mean("stage.cpu_build") + rec.mean("stage.accept") + rec.mean("stage.bookkeep");
            if cpu.is_finite() {
                self.lat.cpu_overhead = 0.9 * self.lat.cpu_overhead + 0.1 * cpu;
            }
        }
        let seconds = t0.elapsed().as_secs_f64();
        tokens.truncate(max_new);
        // §5.2: refresh the profile-guided plan with the *measured* stage
        // durations of this generation (takes effect next request).
        self.research_plan(&rec);
        Ok(Generation { tokens, iterations, seconds, prefill_seconds, recorder: rec })
    }
}
