//! The tree-speculation decode engine.
//!
//! One engine implements the whole design space of Table 1: the tree
//! structure (sequence / K-ary / Sequoia / EGT), the optimization objective
//! (AAL vs Eq. 3), verification-width pruning, the depth predictor, the
//! eager-vs-compiled runtime, and the §5 stage-scheduling plans. The
//! Yggdrasil configuration is simply "all of them on"
//! ([`crate::config::EngineConfig::default`]); every baseline is a preset.
//!
//! ## One decoding iteration (Fig. 9)
//!
//! ```text
//! head-draft(root)                       drafter w1   (skipped on AOT-head/tail hit)
//! D × tree-draft (equal growth, W wide)  drafter wW
//! prune (tree-knapsack DP, Eq. 3)        CPU
//! verify (pruned tree + root)            verifier wWv
//!   └ AOT tail draft (top leaf conts.)   drafter wT   (queued behind verify)
//! accept (greedy / stochastic walk)      CPU          (overlaps tail draft)
//!   └ AOT head draft (bonus token)       drafter w1   (overlaps bookkeeping)
//! bookkeeping (commit/free slots, stats) CPU
//! ```
//!
//! ## Step-driven decomposition
//!
//! The iteration above is the body of [`SpecTask::step`]: a generation is
//! a resumable [`DecodeTask`] (`Prefill → Iterate → Done`) rather than a
//! blocking loop, so the server can interleave many sessions on one
//! device. Per-generation state (KV [`Session`], recorder, depth hints,
//! the scheduling [`Plan`] snapshot) lives on the task; the online
//! adaptive state every generation feeds and reads — acceptance
//! statistics, the latency model's measured CPU term, the AOT-tail hit
//! rate, the profile-searched plan, depth-predictor training samples —
//! lives in [`SpecShared`] behind the engine's `Arc<Mutex<_>>`, shared by
//! all concurrent tasks. [`SpecDecoder`] itself is just configuration +
//! that shared state; `generate_with` drives one task to completion.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::config::{width_for, EngineConfig, SchedulePlan, TreeStructure};
use crate::metrics::Recorder;
use crate::objective::{
    select_draft_width, AcceptanceEstimator, AcceptanceStats, LatencyModel,
};
use crate::predictor::DepthPredictor;
use crate::pruning::prune_for_objective;
use crate::runtime::{
    plan_batches, plan_batches_enveloped, ExecMode, ForwardReply, ForwardRequest, Pending,
    Runtime,
};
use crate::sampling::{
    categorical, softmax_inplace, stochastic_accept, top_k, AcceptOutcome, XorShiftRng,
};
use crate::scheduler::{self, Plan, StageDurations};
use crate::tree::{grow_step, Frontier, NodeId, RoundArena, TokenTree, TreeShape};

use super::session::{Session, SharedCachePool};
use super::task::{self, DecodeTask, StepEngine, StepOutcome, TaskState};
use super::Generation;

/// Sliding window for the per-task `stage.*` / `batch.*` series. The
/// profile-guided plan search reads their means, so an unbounded series
/// would let a single cold-start outlier — the lazy graph-compile stall
/// of a task's first iteration — skew the chosen plan for the task's
/// whole lifetime; windowing ages it out after `STAGE_WINDOW` steady
/// iterations.
const STAGE_WINDOW: usize = 32;

/// Where a head draft's logits are (or will come from).
enum HeadState {
    /// In-flight device call (the AOT-head overlap).
    Pending(Pending<ForwardReply>),
    /// Reply already materialised (tail-draft hit, a blocking plan, or a
    /// packed batched head call that already resolved).
    Ready(HeadReply),
    /// Slot claimed but no call issued yet: the batched draft phase
    /// packs every session's deferred head into one width-padded drafter
    /// call at the start of the next round (DESIGN.md §11). A stranded
    /// deferred head (its session fell out of the batched round) is
    /// resolved by a solo width-1 call instead.
    Deferred,
}

/// A head draft issued ahead of time, satisfied by a tail-draft hit, or
/// deferred into the next batched round's packed head call.
struct PendingHead {
    state: HeadState,
    /// Drafter slot holding the root's K/V.
    slot: u32,
    /// The token this head draft evaluated (must equal the next root).
    token: u32,
}

/// Extracted row of a drafter reply for the head token.
#[derive(Clone)]
struct HeadReply {
    logits: Vec<f32>,
}

/// Per-iteration tree bookkeeping, parallel to [`TokenTree`] node ids.
struct IterState {
    tree: TokenTree,
    /// Drafter cache slot per node (Some for every drafter-evaluated node).
    dslots: Vec<Option<u32>>,
    /// Verifier cache slot per node (Some for nodes in the pruned set).
    vslots: Vec<Option<u32>>,
    /// Drafter candidate children per evaluated node: (token, prob) sorted
    /// descending (top-k at T=0; i.i.d. samples deduped at T>0).
    cands: Vec<Option<Vec<(u32, f32)>>>,
    /// Full drafter probability vector per evaluated node (kept only at
    /// temperature > 0, for the stochastic acceptance rule).
    dists: Vec<Option<Vec<f32>>>,
}

impl IterState {
    fn new(root: u32) -> Self {
        Self {
            tree: TokenTree::new(root),
            dslots: vec![None],
            vslots: vec![None],
            cands: vec![None],
            dists: vec![None],
        }
    }

    fn push_nodes(&mut self, n: usize) {
        self.dslots.resize(self.dslots.len() + n, None);
        self.vslots.resize(self.vslots.len() + n, None);
        self.cands.resize(self.cands.len() + n, None);
        self.dists.resize(self.dists.len() + n, None);
    }
}

/// The unpadded device-call inputs for one session's verification rows:
/// `tokens.len()` real rows, mask rows over the full cache capacity. The
/// single-session path pads these to one graph width; the batched path
/// concatenates many sessions' parts into one block-diagonal call
/// (DESIGN.md §9).
struct VerifyParts {
    tokens: Vec<u32>,
    positions: Vec<i32>,
    slots: Vec<u32>,
    /// `tokens.len() × cache_capacity` visibility rows.
    mask: Vec<f32>,
}

/// The unpadded drafter-call inputs for one draft-stage step of one
/// session — a deferred head draft, or one tree-growth level:
/// `tokens.len()` real rows, mask rows over the full drafter cache
/// capacity. Solo stepping pads these into a session-local call; the
/// batched scheduler concatenates many sessions' same-level parts into
/// one block-diagonal packed drafter call (DESIGN.md §11), exactly as
/// [`VerifyParts`] does for the verifier side.
struct DraftParts {
    tokens: Vec<u32>,
    positions: Vec<i32>,
    slots: Vec<u32>,
    /// `tokens.len() × cache_capacity` visibility rows.
    mask: Vec<f32>,
}

/// Incremental tree growth, one level at a time, so the draft stage can
/// pause at level boundaries — where the batched scheduler packs every
/// ready session's same-level rows into one drafter call.
enum Grower {
    /// Equal-growth (§4.2): the frontier supplies each step's `width`
    /// globally-best expansions.
    Egt {
        frontier: Frontier,
        /// Node-count cap (over-grow ×2 under pruning; see `begin_draft`).
        cap: usize,
        /// Equal-growth width per step.
        width: usize,
        /// Growth steps still allowed (the chosen depth).
        steps_left: usize,
    },
    /// Static baseline shapes, materialised level by level.
    Static {
        shape: TreeShape,
        /// Tree node per shape id (0 = root).
        node_of: Vec<Option<NodeId>>,
        /// Shape ids grouped by depth.
        levels: Vec<Vec<usize>>,
        next_level: usize,
    },
}

impl Grower {
    /// Materialises the next level's nodes into `st.tree` (empty when
    /// growth is finished). The nodes still need drafter evaluation.
    fn next_nodes(&mut self, st: &mut IterState) -> Vec<NodeId> {
        match self {
            Grower::Egt { frontier, cap, width, steps_left } => {
                if *steps_left == 0 {
                    return Vec::new();
                }
                let remaining = cap.saturating_sub(st.tree.len());
                if remaining == 0 {
                    return Vec::new();
                }
                let w = (*width).min(remaining);
                let before = st.tree.len();
                let ids = grow_step(&mut st.tree, frontier, w);
                if ids.is_empty() {
                    return Vec::new();
                }
                st.push_nodes(st.tree.len() - before);
                *steps_left -= 1;
                ids
            }
            Grower::Static { shape, node_of, levels, next_level } => {
                let Some(level) = levels.get(*next_level) else { return Vec::new() };
                *next_level += 1;
                let mut new_nodes = Vec::new();
                for &sid in level {
                    let sn = shape.nodes[sid - 1];
                    let Some(parent) = node_of[sn.parent] else { continue };
                    let Some(cands) = &st.cands[parent] else { continue };
                    let Some(&(token, prob)) = cands.get(sn.rank) else { continue };
                    let before = st.tree.len();
                    let id = st.tree.add_node(parent, token, prob);
                    st.push_nodes(st.tree.len() - before);
                    node_of[sid] = Some(id);
                    new_nodes.push(id);
                }
                if new_nodes.is_empty() {
                    // Dead level (no parent produced candidates): growth
                    // ends, matching the level-loop `break` semantics.
                    *next_level = levels.len();
                }
                new_nodes
            }
        }
    }

    /// Feeds a freshly drafted level back into the growth state (EGT
    /// pushes the new nodes' candidates onto the frontier; static shapes
    /// read `st.cands` directly at the next level).
    fn absorb(&mut self, st: &IterState, ids: &[NodeId]) {
        if let Grower::Egt { frontier, .. } = self {
            for &id in ids {
                let cands = st.cands[id].clone().unwrap_or_default();
                frontier.push_candidates(&st.tree, id, cands);
            }
        }
    }
}

/// Draft-stage state carried across the per-level drafter calls, from
/// [`SpecTask::begin_draft`] to [`SpecTask::finish_draft`].
struct DraftInFlight {
    st: IterState,
    grower: Grower,
    root_pos: i32,
    /// Per-growth-step drafter widths (Eq. 3 denominator bookkeeping).
    draft_widths: Vec<usize>,
    /// The ⟨W⟩ the width selector chose for this iteration.
    draft_width: usize,
    /// Nodes of the level currently awaiting drafter logits (call order).
    pending_nodes: Vec<NodeId>,
    done: bool,
}

/// Iteration state carried across the verification device call, from
/// [`SpecTask::prepare_verify`] to [`SpecTask::complete_verify`].
struct VerifyPrep {
    st: IterState,
    /// Pruned node set, in verify-row order.
    keep: Vec<NodeId>,
    /// Graph width a solo verify of these rows pads to.
    w_verify: usize,
    root_pos: i32,
    /// Per-growth-step drafter widths (Eq. 3 denominator bookkeeping).
    draft_widths: Vec<usize>,
    /// The ⟨W⟩ the width selector chose for this iteration.
    draft_width: usize,
    /// (leaf, token, slot) of in-flight AOT tail drafts.
    tail: Vec<(NodeId, u32, u32)>,
    tail_pending: Option<Pending<ForwardReply>>,
}

/// Concatenates per-member unpadded rows — `(tokens, positions, slots,
/// mask)` each — into one width-padded packed device call against a
/// shared cache: block-diagonal mask, padding rows scattered to the
/// trash slot (the caches' reserved last slot). Shared by the batched
/// verify (§9) and batched draft (§11) phases.
fn packed_request(
    model: String,
    cache: crate::runtime::CacheId,
    capacity: usize,
    width: usize,
    member_parts: &[(&[u32], &[i32], &[u32], &[f32])],
    mode: ExecMode,
) -> ForwardRequest {
    let trash = capacity as i32 - 1;
    let mut tokens: Vec<i32> = Vec::with_capacity(width);
    let mut positions: Vec<i32> = Vec::with_capacity(width);
    let mut slots: Vec<i32> = Vec::with_capacity(width);
    let mut blocks: Vec<&[f32]> = Vec::with_capacity(member_parts.len());
    for &(t, p, s, m) in member_parts {
        tokens.extend(t.iter().map(|&x| x as i32));
        positions.extend_from_slice(p);
        slots.extend(s.iter().map(|&x| x as i32));
        blocks.push(m);
    }
    let mask = crate::tree::pack_block_diagonal(&blocks, capacity, width);
    tokens.resize(width, 0);
    positions.resize(width, 0);
    slots.resize(width, trash);
    ForwardRequest { model, width, cache, tokens, positions, slots, mask, mode }
}

/// Candidate children of a node from its drafter logits: top-k at T = 0,
/// i.i.d. samples (deduped, q-sorted) at T > 0 — the latter is what the
/// stochastic acceptance rule's lossless guarantee expects.
fn candidates(temp: f32, logits: &[f32], k: usize, rng: &mut XorShiftRng) -> Vec<(u32, f32)> {
    if temp == 0.0 {
        let mut probs = logits.to_vec();
        softmax_inplace(&mut probs, 1.0);
        return top_k(&probs, k).into_iter().map(|(i, p)| (i as u32, p)).collect();
    }
    let mut probs = logits.to_vec();
    softmax_inplace(&mut probs, temp);
    let mut out: Vec<(u32, f32)> = Vec::with_capacity(k);
    for _ in 0..k {
        let t = categorical(&probs, rng) as u32;
        if !out.iter().any(|&(x, _)| x == t) {
            out.push((t, probs[t as usize]));
        }
    }
    out.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    out
}

fn temp_probs(temp: f32, logits: &[f32]) -> Vec<f32> {
    let mut p = logits.to_vec();
    softmax_inplace(&mut p, temp.max(1e-6));
    p
}

/// Verification-width pruning (O3) as a pure function of one session's
/// grown tree, so the batched build phase can fan the per-session plans
/// out across CPU threads without borrowing the tasks: the Eq. 3
/// knapsack DP when pruning is on and the tree is non-trivial, otherwise
/// the full keep-set (which must then fit a compiled width).
fn plan_prune(
    prune: bool,
    tree: &TokenTree,
    lat: &LatencyModel,
    draft_widths: &[usize],
    verify_budget: usize,
) -> crate::Result<(Vec<NodeId>, usize)> {
    if prune && tree.len() > 2 {
        Ok(prune_for_objective(tree, lat, draft_widths, verify_budget))
    } else {
        let keep: Vec<NodeId> = (0..tree.len()).collect();
        let w = width_for(keep.len())
            .ok_or_else(|| anyhow::anyhow!("tree of {} nodes unverifiable", keep.len()))?;
        Ok((keep, w))
    }
}

/// Online adaptive state shared by every task of one engine: what one
/// generation measures, the next (possibly concurrent) generation uses.
struct SpecShared {
    lat: LatencyModel,
    stats: AcceptanceStats,
    /// The currently preferred execution plan (updated by per-session
    /// profile-guided search at each generation's end).
    plan: Plan,
    /// EWMA of the AOT-tail hit rate (next head token pre-drafted).
    tail_hit_rate: f64,
    /// Cached Sequoia shape per (budget, stats-epoch).
    sequoia_cache: Option<(usize, TreeShape)>,
    /// (hidden state, accepted count of the *following* iteration) pairs —
    /// the depth predictor's training data.
    depth_samples: Vec<(Vec<f32>, usize)>,
    predictor: Option<DepthPredictor>,
    /// Recycled per-round CPU scratch (DESIGN.md §13): dense-mask buffer
    /// pool, acceptance-walk stacks, the node→row table, ownership
    /// words. Lives here because every path that needs it already holds
    /// the shared-state lock.
    arena: RoundArena,
}

/// The packed-call shape a batched engine's plan search prices against
/// (sessions × per-session rows per stage; DESIGN.md §9/§11).
fn batch_shape(cfg: &EngineConfig) -> scheduler::BatchShape {
    scheduler::BatchShape {
        sessions: cfg.batch.max_sessions,
        verify_rows: cfg.max_verify,
        draft_width: cfg.max_width,
        batch_draft: cfg.batch.batch_draft,
    }
}

/// Profile-guided plan re-search (§5.2) shared by task finish and the
/// explicit calibration entry point: batched engines search over the
/// amortized packed-call costs, solo engines over the raw ones.
///
/// When the recorder saw batched rounds, `stage.verify` (and, under
/// batched drafting, `stage.tree_draft`) already measure the *packed*
/// call, and `batch.sessions` / `batch.draft_sessions` the rider counts
/// — so the per-session charge is the measured call split across the
/// measured riders. A batch-configured engine that only ever ran solo
/// falls back to modelling the packed call from the latency curves.
fn research_plan_into(sh: &mut SpecShared, cfg: &EngineConfig, rec: &Recorder) {
    let d = StageDurations::from_recorder(rec, sh.tail_hit_rate);
    sh.plan = if cfg.batch.enabled {
        let verify_riders = rec.mean("batch.sessions");
        let draft_riders = rec.mean("batch.draft_sessions");
        if verify_riders.is_finite() || draft_riders.is_finite() {
            let split = scheduler::split_measured_batched(&d, verify_riders, draft_riders);
            scheduler::search_best_plan(&split).0
        } else {
            scheduler::search_best_plan_batched(&d, &batch_shape(cfg), &sh.lat).0
        }
    } else {
        scheduler::search_best_plan(&d).0
    };
}

/// The speculative decoding engine.
pub struct SpecDecoder {
    rt: Runtime,
    /// The engine configuration (a preset or the full Yggdrasil default).
    pub cfg: EngineConfig,
    shared: Arc<Mutex<SpecShared>>,
    /// Shared device caches for cross-session batching; created lazily on
    /// the first `begin()` when `cfg.batch.enabled` (DESIGN.md §9).
    pool: Option<Arc<SharedCachePool>>,
    /// The serving layer's overload-degradation rung (DESIGN.md §14),
    /// cloned into every task. An atomic — not a `SpecShared` field —
    /// because tasks read it while holding the shared-state lock.
    degrade: Arc<AtomicU8>,
    /// The serving worker's flight recorder (DESIGN.md §17): batched
    /// rounds wrap their packed phases — deferred-head draft, per-level
    /// tree draft, CPU build, packed verify, accept walk — in uid-0
    /// stage spans. `None` outside the serving stack (solo decode
    /// records stage wall time into its task recorder instead).
    tracer: Option<Arc<crate::trace::Tracer>>,
    label: String,
}

impl SpecDecoder {
    /// Builds an engine over `rt` with a latency model (profiled or
    /// loaded) and an optional trained depth predictor.
    pub fn new(
        rt: &Runtime,
        cfg: EngineConfig,
        lat: LatencyModel,
        predictor: Option<DepthPredictor>,
    ) -> Self {
        let est = StageDurations::estimate(
            &lat,
            cfg.max_depth,
            cfg.max_width,
            cfg.max_verify,
            width_for(4).unwrap(),
        );
        // Under cross-session batching the packed stages amortize across
        // the sessions sharing each call; resolve the plan against the
        // per-session (amortized, sub-linear — not free) durations.
        let plan = if cfg.batch.enabled {
            scheduler::resolve_batched(cfg.schedule, &est, &batch_shape(&cfg), &lat)
        } else {
            scheduler::resolve(cfg.schedule, &est)
        };
        // Compile every width graph up front: the adaptive ⟨D, W, Wv⟩
        // selection may touch any of them, and a mid-decode compile stall
        // (~1 s) is exactly the "dynamic shapes break static runtimes"
        // failure mode this system exists to avoid.
        let _ = rt.precompile(&cfg.drafter, &crate::config::GRAPH_WIDTHS);
        let _ = rt.precompile(&cfg.target, &crate::config::GRAPH_WIDTHS);
        let label = format!(
            "spec[{}|{}|{}{}{}{}]",
            cfg.tree.as_str(),
            cfg.objective.as_str(),
            if cfg.compiled { "compiled" } else { "eager" },
            if cfg.prune { "+prune" } else { "" },
            if cfg.use_depth_predictor { "+pred" } else { "" },
            format_args!("+{}", plan.name()),
        );
        Self {
            rt: rt.clone(),
            cfg,
            shared: Arc::new(Mutex::new(SpecShared {
                lat,
                stats: AcceptanceStats::default(),
                plan,
                tail_hit_rate: 0.3,
                sequoia_cache: None,
                depth_samples: Vec::new(),
                predictor,
                arena: RoundArena::new(),
            })),
            pool: None,
            degrade: Arc::new(AtomicU8::new(0)),
            tracer: None,
            label,
        }
    }

    /// The execution plan new tasks will snapshot.
    pub fn plan(&self) -> Plan {
        self.shared.lock().unwrap().plan
    }

    /// Snapshot of the online acceptance statistics.
    pub fn stats(&self) -> AcceptanceStats {
        self.shared.lock().unwrap().stats.clone()
    }

    /// Snapshot of the latency model (including the measured CPU term).
    pub fn latency_model(&self) -> LatencyModel {
        self.shared.lock().unwrap().lat.clone()
    }

    /// Installs (or clears) the trained depth predictor.
    pub fn set_predictor(&mut self, predictor: Option<DepthPredictor>) {
        self.shared.lock().unwrap().predictor = predictor;
    }

    /// Re-runs the profile-guided plan search with *measured* stage
    /// durations from `rec` (tasks do this automatically at finish; this
    /// entry point exists for explicit calibration runs).
    pub fn research_plan(&mut self, rec: &Recorder) {
        if self.cfg.schedule != SchedulePlan::ProfileSearch {
            return;
        }
        let mut sh = self.shared.lock().unwrap();
        research_plan_into(&mut sh, &self.cfg, rec);
    }

    /// Collected depth-predictor training samples: hidden state paired
    /// with the *next* iteration's accepted count.
    pub fn take_depth_samples(&mut self) -> Vec<(Vec<f32>, usize)> {
        std::mem::take(&mut self.shared.lock().unwrap().depth_samples)
    }
}

/// One resumable speculative generation (the [`DecodeTask`] of
/// [`SpecDecoder`]). Owns the KV [`Session`] for both model sides, so
/// dropping the task frees its cache state immediately.
pub struct SpecTask {
    rt: Runtime,
    cfg: EngineConfig,
    shared: Arc<Mutex<SpecShared>>,
    sess: Session,
    state: TaskState,
    prompt: Vec<u32>,
    max_new: usize,
    /// Keep enough headroom for one full tree + tail + bonus chain.
    tree_budget: usize,
    /// Prompt tokens served by the cross-request prefix cache (DESIGN.md
    /// §12): prefill resumes at this offset, and admission budgets only
    /// for the remainder.
    reused_prefix: usize,
    /// The engine-wide degradation rung (DESIGN.md §14), shared with
    /// [`SpecDecoder::set_degradation`]'s atomic.
    degrade: Arc<AtomicU8>,
    /// SLO class (DESIGN.md §14): `true` = latency-class (protected by
    /// the degradation ladder), `false` = throughput-class (drafting is
    /// shed first under pressure).
    latency_class: bool,
    /// Per-session online acceptance estimate (DESIGN.md §15), seeded
    /// from the shared stats and updated by every acceptance walk — the
    /// global round allocator's input for this session.
    accept_est: AcceptanceEstimator,
    /// The global allocator's verification-row grant for the current
    /// batched round; `None` outside allocator-driven rounds (solo
    /// stepping, verify-only batching), which fall back to the
    /// per-session clamp.
    round_budget: Option<usize>,
    /// Per-session plan snapshot: a concurrent session finishing (and
    /// re-searching the shared plan) never changes this task mid-flight.
    plan: Plan,
    head: Option<PendingHead>,
    /// Depth predicted for the next iteration (from the last verify's
    /// hidden state).
    depth_hint: Option<usize>,
    /// The context embedding that *preceded* each iteration (predictor
    /// training pairs it with that iteration's accepted count).
    prev_hidden: Option<Vec<f32>>,
    rec: Recorder,
    tokens: Vec<u32>,
    iterations: usize,
    /// Accumulated decode seconds (sum of step wall times; excludes
    /// prefill, excludes time the task spends parked between steps).
    seconds: f64,
    prefill_seconds: f64,
}

impl SpecTask {
    // ------------------------------------------------------------------
    // Drafting — split into prepare/submit/complete halves, like the
    // verify stage, so the batched scheduler can pack every ready
    // session's same-level rows into one drafter call (DESIGN.md §11).
    // ------------------------------------------------------------------

    /// First half of the draft stage: resolves the head draft's logits,
    /// selects ⟨D, W⟩, and seeds the iteration state + growth plan.
    /// No tree-level drafter call is issued here.
    fn begin_draft(
        &mut self,
        head: PendingHead,
        sh: &mut SpecShared,
    ) -> crate::Result<DraftInFlight> {
        let root_pos = (self.sess.committed_len() - 1) as i32;
        let root_token = *self.sess.committed.last().unwrap();
        debug_assert_eq!(head.token, root_token);
        let temp = self.cfg.sampling.temperature;

        // -------- head draft (possibly already satisfied) ----------------
        let t0 = Instant::now();
        let head_logits = match head.state {
            HeadState::Ready(r) => r.logits,
            HeadState::Pending(p) => {
                let reply = p.wait()?;
                let v = self.sess.drafter.spec.vocab;
                reply.logits[..v].to_vec()
            }
            HeadState::Deferred => {
                // Stranded deferred head (this session fell out of the
                // batched round, or a solo driver stepped it): evaluate
                // with its own width-1 call.
                let parts = self.deferred_head_parts(head.slot, head.token, &mut sh.arena);
                let req = self.sess.drafter.padded_request(
                    1,
                    &parts.tokens,
                    &parts.positions,
                    &parts.slots,
                    &parts.mask,
                    self.sess.exec_mode(),
                );
                sh.arena.put_f32(parts.mask);
                let reply = self.rt.forward(req)?;
                let v = self.sess.drafter.spec.vocab;
                reply.logits[..v].to_vec()
            }
        };
        self.rec.record_windowed(
            "stage.head_draft",
            t0.elapsed().as_secs_f64(),
            STAGE_WINDOW,
        );

        let mut st = IterState::new(root_token);
        st.dslots[0] = Some(head.slot);
        st.cands[0] = Some(candidates(
            temp,
            &head_logits,
            self.cfg.branch_candidates,
            &mut self.sess.rng,
        ));
        if temp > 0.0 {
            st.dists[0] = Some(temp_probs(temp, &head_logits));
        }

        // -------- depth / width decisions (O1 + O5) ----------------------
        // The depth predictor (O5), when trained, supplies the per-context
        // depth; otherwise Eq. 3 selects the latency-optimal ⟨D, W⟩ from
        // the profiled curves and the online acceptance stats. The AAL
        // objective (Fig. 14 ablation / baselines) degenerates to the
        // maximal envelope, reproducing prior work's behaviour.
        //
        // The global allocator's round grant (DESIGN.md §15), when one
        // was resolved, caps the verify scope the selectors price: a
        // session granted few rows stops growing trees those rows cannot
        // verify. Without a grant (solo stepping, verify-only batching)
        // the configured envelope applies unchanged.
        let w_verify_budget =
            self.round_budget.unwrap_or(self.cfg.max_verify).clamp(1, self.cfg.max_verify);
        let (depth, width) = match self.cfg.tree {
            TreeStructure::Egt => {
                let hinted =
                    self.cfg.use_depth_predictor.then(|| self.depth_hint.take()).flatten();
                match hinted {
                    Some(d) => {
                        let d = d.clamp(1, self.cfg.max_depth);
                        let w = select_draft_width(
                            &sh.stats,
                            &sh.lat,
                            self.cfg.objective,
                            d,
                            self.cfg.max_width,
                            w_verify_budget,
                        );
                        (d, w)
                    }
                    None => crate::objective::select_depth_width(
                        &sh.stats,
                        &sh.lat,
                        self.cfg.objective,
                        self.cfg.max_depth,
                        self.cfg.max_width,
                        w_verify_budget,
                    ),
                }
            }
            _ => (self.cfg.max_depth, self.cfg.max_width),
        };
        // Degradation rung 2+ (DESIGN.md §14): throughput-class sessions
        // stop drafting entirely — a root-only tree still commits one
        // bonus token per round — so latency-class sessions keep their
        // speculative speedup under pressure. A floor-level allocator
        // grant (≤ 1 verification row) skips drafting the same way: the
        // row covers exactly the root, which still commits the bonus.
        let depth = if (self.degrade_rung() >= scheduler::RUNG_SKIP_DRAFT
            && !self.latency_class)
            || self.round_budget.is_some_and(|b| b <= 1)
        {
            0
        } else {
            depth
        };
        self.rec.record("depth", depth as f64);
        self.rec.record("width", width as f64);

        let grower = match self.cfg.tree {
            TreeStructure::Egt => {
                let mut frontier = Frontier::new(depth);
                let root_cands = st.cands[0].clone().unwrap_or_default();
                frontier.push_candidates(&st.tree, 0, root_cands);
                // With pruning on, over-grow (the DP trims to budget);
                // without it the grown tree itself must stay verifiable.
                let cap = if self.cfg.prune {
                    self.cfg.max_verify * 2
                } else {
                    self.cfg.max_verify
                }
                .min(64 + 64 * self.cfg.prune as usize);
                Grower::Egt { frontier, cap, width, steps_left: depth }
            }
            _ => {
                let shape = self.static_shape(sh);
                let levels = shape.levels();
                // Map shape ids (0 = root) to tree node ids.
                let mut node_of: Vec<Option<NodeId>> = vec![None; shape.len() + 1];
                node_of[0] = Some(0);
                Grower::Static { shape, node_of, levels, next_level: 0 }
            }
        };
        Ok(DraftInFlight {
            st,
            grower,
            root_pos,
            draft_widths: Vec::new(),
            draft_width: width,
            pending_nodes: Vec::new(),
            done: false,
        })
    }

    /// Grows the next tree level and assembles its unpadded drafter-call
    /// rows. `None` once growth is finished — the frontier dried up, the
    /// depth budget is spent, or the drafter cache cannot host another
    /// level (growth stops gracefully; the grown-so-far tree verifies).
    fn next_draft_parts(
        &mut self,
        d: &mut DraftInFlight,
        arena: &mut RoundArena,
    ) -> crate::Result<Option<DraftParts>> {
        if d.done {
            return Ok(None);
        }
        debug_assert!(d.pending_nodes.is_empty(), "draft level already in flight");
        let ids = d.grower.next_nodes(&mut d.st);
        if ids.is_empty() {
            d.done = true;
            return Ok(None);
        }
        let n = ids.len();
        anyhow::ensure!(
            width_for(n).is_some(),
            "draft step of {n} tokens exceeds compiled widths"
        );
        let Some(slots) = self.sess.drafter.slots.alloc(n) else {
            d.done = true; // cache exhausted — growth stops
            return Ok(None);
        };
        debug_assert!(self.sess.drafter.slots.owns_all(&slots));
        for (i, &node) in ids.iter().enumerate() {
            d.st.dslots[node] = Some(slots[i]);
        }
        let tokens: Vec<u32> = ids.iter().map(|&id| d.st.tree.token(id)).collect();
        let positions: Vec<i32> =
            ids.iter().map(|&id| d.root_pos + d.st.tree.depth(id) as i32).collect();
        // Word-wise mask build into the builder's bit scratch, expanded
        // to dense f32 only at the device-call boundary — into a recycled
        // arena buffer, so the steady-state round allocates nothing here.
        let t_mask = Instant::now();
        #[cfg(debug_assertions)]
        crate::tree::owner_words(
            &self.sess.drafter.slots.ownership(),
            self.sess.drafter.spec.cache_capacity,
            &mut arena.owner,
        );
        let mut mask = arena.take_f32();
        let bits = self
            .sess
            .drafter
            .slots
            .mask_builder()
            .build_bits(&d.st.tree, &ids, &d.st.dslots, n);
        // The drafter-side block-diagonal invariant batched drafting
        // relies on: this session's rows reference only slots it owns —
        // checked word-wise on the packed rows.
        debug_assert!(crate::tree::rows_owned_bits(bits, &arena.owner));
        bits.expand_into(&mut mask);
        self.rec.record_windowed(
            "stage.cpu_mask",
            t_mask.elapsed().as_secs_f64(),
            STAGE_WINDOW,
        );
        d.pending_nodes = ids;
        Ok(Some(DraftParts { tokens, positions, slots, mask }))
    }

    /// Absorbs the drafter logits of the level issued by the last
    /// [`SpecTask::next_draft_parts`]: candidate extraction, (at T > 0)
    /// distribution capture, frontier feedback, Eq. 3 bookkeeping.
    fn complete_draft_level(&mut self, d: &mut DraftInFlight, logits: &[f32]) {
        let ids = std::mem::take(&mut d.pending_nodes);
        let vocab = self.sess.drafter.spec.vocab;
        let temp = self.cfg.sampling.temperature;
        let keep_dist = temp > 0.0;
        for (i, &node) in ids.iter().enumerate() {
            let row = &logits[i * vocab..(i + 1) * vocab];
            let cands =
                candidates(temp, row, self.cfg.branch_candidates, &mut self.sess.rng);
            d.st.cands[node] = Some(cands);
            if keep_dist {
                d.st.dists[node] = Some(temp_probs(temp, row));
            }
        }
        d.draft_widths.push(width_for(ids.len()).unwrap_or(64));
        d.grower.absorb(&d.st, &ids);
    }

    /// The packed-call row for a deferred head draft: the root token at
    /// its committed position, visible to the committed prefix plus its
    /// own slot. (Bookkeeping committed the accepted path before the
    /// head was deferred, so prefix + self is exactly the visibility the
    /// eagerly-submitted AOT head would have had — bit-identical
    /// logits.)
    fn deferred_head_parts(
        &mut self,
        slot: u32,
        token: u32,
        arena: &mut RoundArena,
    ) -> DraftParts {
        let root_pos = (self.sess.committed_len() - 1) as i32;
        // One row: the committed prefix plus the head's own slot —
        // assembled directly from the builder's prefix row into a
        // recycled arena buffer (cloning the whole builder would copy its
        // level-sized scratch buffer every round for nothing).
        let mut mask = arena.take_f32();
        mask.extend_from_slice(self.sess.drafter.slots.mask_builder().prefix_row());
        mask[slot as usize] = 1.0;
        debug_assert_eq!(mask.len(), self.sess.drafter.spec.cache_capacity);
        debug_assert!(crate::tree::rows_owned(
            &mask,
            self.sess.drafter.spec.cache_capacity,
            &self.sess.drafter.slots.ownership(),
        ));
        DraftParts { tokens: vec![token], positions: vec![root_pos], slots: vec![slot], mask }
    }

    /// The static shape for the configured baseline structure.
    fn static_shape(&mut self, sh: &mut SpecShared) -> TreeShape {
        let budget = self.cfg.max_verify.min(64).saturating_sub(1).max(1);
        match self.cfg.tree {
            TreeStructure::Sequence => TreeShape::sequence(self.cfg.max_depth.min(budget)),
            TreeStructure::KAry => {
                TreeShape::k_ary(self.cfg.max_width, self.cfg.max_depth, budget)
            }
            TreeStructure::Sequoia => {
                if let Some((b, shape)) = &sh.sequoia_cache {
                    if *b == budget {
                        return shape.clone();
                    }
                }
                let shape = TreeShape::sequoia(&sh.stats.accept_by_rank, budget);
                sh.sequoia_cache = Some((budget, shape.clone()));
                shape
            }
            TreeStructure::Egt => unreachable!("EGT has no static shape"),
        }
    }

    // ------------------------------------------------------------------
    // The decoding iteration
    // ------------------------------------------------------------------

    /// First half of one iteration (Fig. 9) on the *solo* path: resolves
    /// the head draft, grows the tree level by level (one session-local
    /// drafter call per level), prunes it, and assembles the
    /// verification rows — everything up to (but excluding) the verifier
    /// device call. The batched scheduler runs the same halves
    /// ([`SpecTask::begin_draft`] → per-level parts →
    /// [`SpecTask::finish_draft`]) but packs every ready session's
    /// same-level rows into one drafter call (DESIGN.md §11).
    fn prepare_verify(
        &mut self,
        head: PendingHead,
        sh: &mut SpecShared,
    ) -> crate::Result<(VerifyPrep, VerifyParts)> {
        // No global allocation ran for this iteration (solo stepping or
        // verify-only batching): drop any stale grant from an earlier
        // batched round so the per-session clamp applies.
        self.round_budget = None;
        let mut d = self.begin_draft(head, sh)?;
        let t0 = Instant::now();
        while let Some(parts) = self.next_draft_parts(&mut d, &mut sh.arena)? {
            let n = parts.tokens.len();
            let width = width_for(n).expect("validated by next_draft_parts");
            let req = self.sess.drafter.padded_request(
                width,
                &parts.tokens,
                &parts.positions,
                &parts.slots,
                &parts.mask,
                self.sess.exec_mode(),
            );
            sh.arena.put_f32(parts.mask);
            let reply = self.rt.forward(req)?;
            let vocab = self.sess.drafter.spec.vocab;
            self.complete_draft_level(&mut d, &reply.logits[..n * vocab]);
        }
        self.rec.record_windowed(
            "stage.tree_draft",
            t0.elapsed().as_secs_f64(),
            STAGE_WINDOW,
        );
        self.finish_draft(d, sh)
    }

    /// Second half of the draft stage, after every level is drafted:
    /// verification-width pruning (O3) and verify-row assembly. Shared
    /// verbatim by the solo and batched paths.
    fn finish_draft(
        &mut self,
        d: DraftInFlight,
        sh: &mut SpecShared,
    ) -> crate::Result<(VerifyPrep, VerifyParts)> {
        self.rec.record("tree_size", d.st.tree.len() as f64);

        // -------- pruning (O3) -------------------------------------------
        let t0 = Instant::now();
        let budget = self.verify_budget();
        let planned = plan_prune(self.cfg.prune, &d.st.tree, &sh.lat, &d.draft_widths, budget);
        self.rec.record_windowed(
            "stage.cpu_build",
            t0.elapsed().as_secs_f64(),
            STAGE_WINDOW,
        );
        let (keep, w_verify) = planned?;
        self.finish_draft_pruned(d, sh, keep, w_verify)
    }

    /// The verification budget right now: the configured cap clamped to
    /// what the target cache can actually supply. Paged serving: a
    /// crowded shared pool shrinks this session's tree instead of failing
    /// its verify (scheduler/plan interaction, DESIGN.md §10).
    /// Fixed-range caches see `available() == free`, preserving the solo
    /// behaviour.
    fn verify_budget(&self) -> usize {
        self.verify_envelope().min(self.sess.target.slots.available()).max(1)
    }

    /// The static half of [`SpecTask::verify_budget`]: the configured
    /// verify envelope after any degradation-rung shrink (DESIGN.md §14:
    /// rung 1+ halves it so every tree shrinks before anything is
    /// preempted), with **no** pool reads — the round allocator budgets
    /// against one headroom snapshot instead (DESIGN.md §15).
    fn verify_envelope(&self) -> usize {
        let mut cap = self.cfg.max_verify;
        if self.degrade_rung() >= scheduler::RUNG_SHRINK_BUDGET {
            cap = (cap / 2).max(1);
        }
        cap
    }

    /// The engine-wide overload-degradation rung right now (0 = none).
    fn degrade_rung(&self) -> u8 {
        self.degrade.load(Ordering::Relaxed)
    }

    /// Verify-row assembly after the keep-set is decided — serially by
    /// [`SpecTask::finish_draft`], or with the prune plans precomputed by
    /// the `--cpu-threads` fan-out of the batched build phase.
    fn finish_draft_pruned(
        &mut self,
        d: DraftInFlight,
        sh: &mut SpecShared,
        keep: Vec<NodeId>,
        w_verify: usize,
    ) -> crate::Result<(VerifyPrep, VerifyParts)> {
        let DraftInFlight { mut st, root_pos, draft_widths, draft_width, .. } = d;
        self.rec.record("w_verify", w_verify as f64);

        // -------- verification row assembly ------------------------------
        let Some(vslots) = self.sess.target.slots.alloc(keep.len()) else {
            // Typed in paged mode: a dry shared pool preempts the session
            // (blocks released, request requeued for re-prefill resume)
            // instead of failing the request.
            return Err(self.sess.target.slots.exhausted("verify row allocation"));
        };
        for (i, &node) in keep.iter().enumerate() {
            st.vslots[node] = Some(vslots[i]);
        }
        let vtokens: Vec<u32> = keep.iter().map(|&id| st.tree.token(id)).collect();
        let vpositions: Vec<i32> =
            keep.iter().map(|&id| root_pos + st.tree.depth(id) as i32).collect();
        // Word-wise mask build, expanded to dense f32 only at the
        // device-call boundary, into a recycled arena buffer.
        let t_mask = Instant::now();
        #[cfg(debug_assertions)]
        crate::tree::owner_words(
            &self.sess.target.slots.ownership(),
            self.sess.target.spec.cache_capacity,
            &mut sh.arena.owner,
        );
        let mut vmask = sh.arena.take_f32();
        let bits = self
            .sess
            .target
            .slots
            .mask_builder()
            .build_bits(&st.tree, &keep, &st.vslots, keep.len());
        // The block-diagonal invariant batched serving relies on: this
        // session's rows reference only slots it currently owns — a
        // contiguous range, or its leased block set in paged mode —
        // checked word-wise on the packed rows.
        debug_assert!(crate::tree::rows_owned_bits(bits, &sh.arena.owner));
        bits.expand_into(&mut vmask);
        self.rec.record_windowed(
            "stage.cpu_mask",
            t_mask.elapsed().as_secs_f64(),
            STAGE_WINDOW,
        );
        let parts =
            VerifyParts { tokens: vtokens, positions: vpositions, slots: vslots, mask: vmask };
        let prep = VerifyPrep {
            st,
            keep,
            w_verify,
            root_pos,
            draft_widths,
            draft_width,
            tail: Vec::new(),
            tail_pending: None,
        };
        Ok((prep, parts))
    }

    /// Submits the AOT tail draft (§5.1) for an iteration whose verify
    /// call is already queued: the most likely next-root continuations
    /// execute right behind it, overlapping the CPU acceptance walk.
    /// No-op for plans without `aot_tail`.
    fn submit_tail(&mut self, prep: &mut VerifyPrep) -> crate::Result<()> {
        if !self.plan.aot_tail {
            return Ok(());
        }
        let t_tail = Instant::now();
        let picks: Vec<NodeId> = {
            let st = &prep.st;
            let keep = &prep.keep;
            let mut leaves: Vec<NodeId> = keep
                .iter()
                .copied()
                .filter(|&id| {
                    // leaf within the pruned set
                    !st.tree.children(id).iter().any(|c| keep.contains(c))
                })
                .collect();
            leaves.sort_by(|&a, &b| {
                st.tree.path_prob(b).partial_cmp(&st.tree.path_prob(a)).unwrap()
            });
            let t_width = 4usize;
            leaves
                .into_iter()
                .filter(|&l| st.cands[l].as_ref().is_some_and(|c| !c.is_empty()))
                .take(t_width)
                .collect()
        };
        if !picks.is_empty() {
            if let Some(slots) = self.sess.drafter.slots.alloc(picks.len()) {
                let mut tokens = Vec::new();
                let mut positions = Vec::new();
                let mut dsl = prep.st.dslots.clone();
                // Temporarily extend the tree with the tail nodes so the
                // mask builder sees their ancestry.
                let mut tmp_tree = prep.st.tree.clone();
                let mut nodes = Vec::new();
                let mut tail = Vec::new();
                for (i, &leaf) in picks.iter().enumerate() {
                    let (tok, p) = prep.st.cands[leaf].as_ref().unwrap()[0];
                    let id = tmp_tree.add_node(leaf, tok, p);
                    dsl.push(Some(slots[i]));
                    nodes.push(id);
                    tokens.push(tok);
                    positions.push(prep.root_pos + tmp_tree.depth(id) as i32);
                    tail.push((leaf, tok, slots[i]));
                }
                let width = width_for(picks.len()).unwrap();
                let mask = self
                    .sess
                    .drafter
                    .slots
                    .mask_builder()
                    .build(&tmp_tree, &nodes, &dsl, width)
                    .to_vec();
                let req = self.sess.drafter.padded_request(
                    width,
                    &tokens,
                    &positions,
                    &slots,
                    &mask,
                    self.sess.exec_mode(),
                );
                prep.tail_pending = Some(self.rt.submit(req)?);
                prep.tail = tail;
            }
        }
        self.rec.record_windowed(
            "stage.tail_submit",
            t_tail.elapsed().as_secs_f64(),
            STAGE_WINDOW,
        );
        Ok(())
    }

    /// Second half of one iteration, after the verifier replied:
    /// acceptance walk over this session's `logits`/`hidden_rows` (its
    /// contiguous rows of the — possibly batched — reply), tail-hit
    /// resolution, the next head draft, and slot bookkeeping. Returns the
    /// committed tokens, the next pending head, and the bonus context's
    /// hidden state.
    ///
    /// `defer_head`: under stage-aligned batched drafting the next head
    /// draft only *claims its slot* here — keeping slot numbering
    /// identical to the solo path — while the device call is packed with
    /// every other session's head at the start of the next round's draft
    /// phase (DESIGN.md §11).
    #[allow(clippy::too_many_lines)]
    fn complete_verify(
        &mut self,
        prep: VerifyPrep,
        logits: &[f32],
        hidden_rows: &[f32],
        sh: &mut SpecShared,
        defer_head: bool,
    ) -> crate::Result<(Vec<u32>, Option<PendingHead>, Vec<f32>)> {
        let VerifyPrep { st, keep, root_pos, draft_widths, draft_width, tail, tail_pending, .. } =
            prep;
        let temp = self.cfg.sampling.temperature;

        // -------- acceptance walk ----------------------------------------
        let t0 = Instant::now();
        let vocab = self.sess.target.spec.vocab;
        // Node id → verify-row index through the arena table: O(1)
        // lookups instead of a `keep` scan per visited node, and the walk
        // stacks reuse the arena's buffers across rounds.
        sh.arena.row_of.clear();
        sh.arena.row_of.resize(st.tree.len(), -1);
        for (r, &node) in keep.iter().enumerate() {
            sh.arena.row_of[node] = r as i32;
        }
        sh.arena.walk_path.clear();
        sh.arena.walk_path.push(0);
        let mut cur = 0usize;
        let bonus: u32;
        loop {
            let r = sh.arena.row_of[cur] as usize;
            let row = &logits[r * vocab..(r + 1) * vocab];
            // Children of cur inside the pruned set, in candidate order.
            sh.arena.walk_kids.clear();
            sh.arena.walk_tokens.clear();
            for &c in st.tree.children(cur) {
                if sh.arena.row_of[c] >= 0 {
                    sh.arena.walk_kids.push(c);
                    sh.arena.walk_tokens.push(st.tree.token(c));
                }
            }
            let outcome = if temp == 0.0 {
                let (o, truth) = crate::sampling::greedy_accept(row, &sh.arena.walk_tokens);
                // Rank bookkeeping for Sequoia / Fig. 11.
                let rank = st.cands[cur]
                    .as_ref()
                    .and_then(|c| c.iter().position(|&(t, _)| t == truth));
                sh.stats.record_rank(rank);
                o
            } else {
                let p = temp_probs(temp, row);
                let q = st.dists[cur]
                    .clone()
                    .unwrap_or_else(|| vec![1.0 / vocab as f32; vocab]);
                let o = stochastic_accept(&p, &q, &sh.arena.walk_tokens, &mut self.sess.rng);
                if let AcceptOutcome::Child(i) = o {
                    let accepted_tok = sh.arena.walk_tokens[i];
                    let rank = st.cands[cur]
                        .as_ref()
                        .and_then(|c| c.iter().position(|&(t, _)| t == accepted_tok));
                    sh.stats.record_rank(rank);
                } else {
                    sh.stats.record_rank(None);
                }
                o
            };
            match outcome {
                AcceptOutcome::Child(i) => {
                    cur = sh.arena.walk_kids[i];
                    sh.arena.walk_path.push(cur);
                }
                AcceptOutcome::Bonus(b) => {
                    bonus = b;
                    break;
                }
            }
        }
        let accepted_draft = sh.arena.walk_path.len() - 1; // excludes root
        self.rec.record_windowed(
            "stage.cpu_walk",
            t0.elapsed().as_secs_f64(),
            STAGE_WINDOW,
        );
        self.rec.record("accepted", (accepted_draft + 1) as f64);

        // Post-walk acceptance bookkeeping — priced together with the
        // walk by the scheduler (`plan_latency` folds `cpu_walk` +
        // `accept` into one CPU term).
        let t0 = Instant::now();
        // Coverage stats for the width selector: growth step d covered the
        // true continuation iff the walk descended at least d times.
        let steps_grown = draft_widths.len();
        for d in 1..=steps_grown {
            sh.stats.record_step(draft_width, d <= accepted_draft);
        }
        // Session-local estimator (DESIGN.md §15): the same walk feeds
        // this session's own acceptance estimate, which the global round
        // allocator prices next round. A draft-skipped round (floor
        // grant) carries no signal, so the estimate drifts up instead —
        // the session periodically re-earns a probe tree rather than
        // starving on a stale low estimate.
        if steps_grown == 0 && self.round_budget.is_some_and(|b| b <= 1) {
            self.accept_est.drift_up();
        } else {
            self.accept_est.record_round(accepted_draft, steps_grown);
        }

        // Depth-predictor hint for the next iteration, from the hidden
        // state at the deepest accepted node (the bonus context).
        let d_model = self.sess.target.spec.d_model;
        let hid_row = sh.arena.row_of[cur] as usize;
        let hidden = hidden_rows[hid_row * d_model..(hid_row + 1) * d_model].to_vec();
        if self.cfg.use_depth_predictor {
            if let Some(p) = &sh.predictor {
                if p.input_dim == d_model {
                    self.depth_hint = Some(p.predict_depth(&hidden, 0.45));
                }
            }
        }
        self.rec.record_windowed("stage.accept", t0.elapsed().as_secs_f64(), STAGE_WINDOW);

        // -------- AOT head draft / tail-hit resolution --------------------
        let t0 = Instant::now();
        let mut tail_rows: Option<ForwardReply> = None;
        if let Some(p) = tail_pending {
            // The tail draft finished during the acceptance walk (device
            // FIFO); this wait is usually instant.
            let r = p.wait()?;
            self.rec.record_windowed("stage.tail_draft", r.exec_seconds, STAGE_WINDOW);
            tail_rows = Some(r);
        }
        let mut next_head: Option<PendingHead> = None;
        let mut tail_hit = false;
        if let Some(rows) = &tail_rows {
            let v = self.sess.drafter.spec.vocab;
            for (i, &(leaf, tok, slot)) in tail.iter().enumerate() {
                if leaf == cur && tok == bonus {
                    // The speculative tail draft already evaluated the next
                    // root: reuse its logits row and slot.
                    next_head = Some(PendingHead {
                        state: HeadState::Ready(HeadReply {
                            logits: rows.logits[i * v..(i + 1) * v].to_vec(),
                        }),
                        slot,
                        token: bonus,
                    });
                    tail_hit = true;
                    break;
                }
            }
        }
        sh.tail_hit_rate = 0.95 * sh.tail_hit_rate + 0.05 * (tail_hit as u8 as f64);
        self.rec.record("tail_hit", tail_hit as u8 as f64);

        if next_head.is_none() {
            // Issue the (real) head draft for the bonus token. Under the
            // AOT-head plan this submission happens *before* bookkeeping so
            // the drafter runs while the CPU cleans up.
            if let Some(slot) = self.sess.drafter.slots.alloc(1).map(|v| v[0]) {
                if defer_head {
                    // Batched rounds: claim the slot now (identical slot
                    // numbering to the solo path) but let the next
                    // round's draft phase pack the call with every other
                    // session's head. Bookkeeping below commits the
                    // accepted path, so the deferred mask — prefix +
                    // self — sees exactly what the eager mask would.
                    next_head =
                        Some(PendingHead { state: HeadState::Deferred, slot, token: bonus });
                } else {
                    let mut dsl = st.dslots.clone();
                    let mut tmp_tree = st.tree.clone();
                    let id = tmp_tree.add_node(cur, bonus, 1.0);
                    dsl.push(Some(slot));
                    let mask = self
                        .sess
                        .drafter
                        .slots
                        .mask_builder()
                        .build(&tmp_tree, &[id], &dsl, 1)
                        .to_vec();
                    let positions = vec![root_pos + tmp_tree.depth(id) as i32];
                    let req = self.sess.drafter.padded_request(
                        1,
                        &[bonus],
                        &positions,
                        &[slot],
                        &mask,
                        self.sess.exec_mode(),
                    );
                    let pending = self.rt.submit(req)?;
                    let mut head = PendingHead {
                        state: HeadState::Pending(pending),
                        slot,
                        token: bonus,
                    };
                    if !self.plan.aot_head {
                        // Sequential plan: block right here.
                        let HeadState::Pending(p) =
                            std::mem::replace(&mut head.state, HeadState::Deferred)
                        else {
                            unreachable!("head was just created pending")
                        };
                        let reply = p.wait()?;
                        let v = self.sess.drafter.spec.vocab;
                        head.state = HeadState::Ready(HeadReply {
                            logits: reply.logits[..v].to_vec(),
                        });
                    }
                    next_head = Some(head);
                }
            }
        }
        self.rec.record_windowed(
            "stage.head_submit",
            t0.elapsed().as_secs_f64(),
            STAGE_WINDOW,
        );

        // -------- bookkeeping ---------------------------------------------
        let t0 = Instant::now();
        // Commit accepted slots on both sides; free the rest.
        for node in 0..st.tree.len() {
            let on_path = sh.arena.walk_path.contains(&node);
            if let Some(s) = st.dslots[node] {
                if on_path {
                    self.sess.drafter.slots.commit(s);
                } else {
                    self.sess.drafter.slots.release(&[s]);
                }
            }
            if let Some(s) = st.vslots[node] {
                if on_path {
                    self.sess.target.slots.commit(s);
                } else {
                    self.sess.target.slots.release(&[s]);
                }
            }
        }
        // Tail slots: the hit (if any) lives on as the next head slot.
        for &(_, _, slot) in &tail {
            let kept = next_head.as_ref().is_some_and(|h| h.slot == slot);
            if !kept {
                self.sess.drafter.slots.release(&[slot]);
            }
        }
        let mut out: Vec<u32> =
            sh.arena.walk_path[1..].iter().map(|&n| st.tree.token(n)).collect();
        out.push(bonus);
        self.sess.committed.extend_from_slice(&out);
        self.rec.record_windowed(
            "stage.bookkeep",
            t0.elapsed().as_secs_f64(),
            STAGE_WINDOW,
        );

        Ok((out, next_head, hidden))
    }

    /// The one-off head draft for the first iteration's root.
    fn initial_head(&mut self) -> crate::Result<PendingHead> {
        let root_token = *self.sess.committed.last().unwrap();
        let root_pos = (self.sess.committed_len() - 1) as i32;
        let slot = self
            .sess
            .drafter
            .slots
            .alloc(1)
            .ok_or_else(|| self.sess.drafter.slots.exhausted("initial head draft"))?[0];
        let mut mb = self.sess.drafter.slots.mask_builder().clone();
        mb.commit_slot(slot); // root attends to itself + prefix
        let tree = TokenTree::new(root_token);
        let mask = mb.build(&tree, &[0], &[Some(slot)], 1).to_vec();
        let req = self.sess.drafter.padded_request(
            1,
            &[root_token],
            &[root_pos],
            &[slot],
            &mask,
            self.sess.exec_mode(),
        );
        let reply = self.rt.forward(req)?;
        let v = self.sess.drafter.spec.vocab;
        Ok(PendingHead {
            state: HeadState::Ready(HeadReply { logits: reply.logits[..v].to_vec() }),
            slot,
            token: root_token,
        })
    }

    // ------------------------------------------------------------------
    // Lifecycle steps
    // ------------------------------------------------------------------

    fn step_prefill(&mut self) -> crate::Result<StepOutcome> {
        // Chunked prefill (DESIGN.md §14): with `--prefill-chunk` set,
        // each step advances the prompt by one chunk and stays in
        // `Prefill` until the body is committed, so a long cold prompt
        // interleaves with warm sessions round by round instead of
        // stalling the wave. Rung 3+ of the degradation ladder halves
        // the chunk to shed prefill work harder.
        let mut chunk = self.cfg.batch.prefill_chunk;
        if chunk > 0 && self.degrade_rung() >= scheduler::RUNG_CHUNK_HARDER {
            chunk = (chunk / 2).max(1);
        }
        if self.sess.committed_len() == 0 {
            // This task was admitted: its attached prefix (if any) is now
            // consumed, so it counts toward the cache's hit-rate gauges.
            self.sess.record_prefix_reuse();
        }
        let prompt = std::mem::take(&mut self.prompt);
        let t_prefill = Instant::now();
        let step = if chunk == 0 {
            self.sess.prefill(&prompt).map(|r| (true, r))
        } else {
            self.sess.prefill_chunk(&prompt, chunk)
        };
        self.prefill_seconds += t_prefill.elapsed().as_secs_f64();
        let (done, prefill_reply) = match step {
            Ok(x) => x,
            Err(e) => {
                self.prompt = prompt;
                return Err(e);
            }
        };
        if !done {
            self.prompt = prompt;
            return Ok(StepOutcome { tokens: vec![], state: TaskState::Prefill });
        }

        let d = self.sess.target.spec.d_model;
        // Seed the depth hint from the prefill hidden state.
        {
            let sh = self.shared.lock().unwrap();
            if let (Some(p), Some(r)) = (&sh.predictor, &prefill_reply) {
                if p.input_dim == d && r.hidden.len() >= d {
                    let last = &r.hidden[r.hidden.len() - d..];
                    self.depth_hint = Some(p.predict_depth(last, 0.45));
                }
            }
        }
        self.prev_hidden = prefill_reply
            .as_ref()
            .and_then(|r| (r.hidden.len() >= d).then(|| r.hidden[r.hidden.len() - d..].to_vec()));

        let t0 = Instant::now();
        self.head = Some(self.initial_head()?);
        self.seconds += t0.elapsed().as_secs_f64();
        self.state = if self.max_new > 0 && self.kv_can_continue() {
            TaskState::Iterate
        } else {
            TaskState::Done
        };
        Ok(StepOutcome { tokens: vec![], state: self.state })
    }

    /// Whether the KV situation allows another iteration. Fixed-range
    /// sessions stop when their own headroom is gone (nobody else's slots
    /// can help). Paged sessions stop only at the *absolute* ceiling —
    /// they could not host another iteration even owning every block —
    /// because pool-wide headroom is transient under contention: a
    /// neighbour's lease is a reason to preempt-and-resume later
    /// (PoolExhausted), never to silently end the generation short.
    fn kv_can_continue(&self) -> bool {
        if self.sess.is_paged() {
            let held = self
                .sess
                .drafter
                .slots
                .committed_len()
                .max(self.sess.target.slots.committed_len());
            self.sess.lease_limit().saturating_sub(held) > self.tree_budget
        } else {
            self.sess.headroom(self.tree_budget) > 0
        }
    }

    fn step_iterate(&mut self) -> crate::Result<StepOutcome> {
        let Some(head) = self.head.take() else {
            self.state = TaskState::Done;
            return Ok(StepOutcome { tokens: vec![], state: self.state });
        };
        let t_iter = Instant::now();
        let shared = Arc::clone(&self.shared);
        let mut sh = shared.lock().unwrap();
        // Solo iteration: prepare → submit verify → overlap the tail
        // draft → wait → complete. The batched scheduler runs the same
        // halves but shares one verify call across sessions.
        let (mut prep, parts) = self.prepare_verify(head, &mut sh)?;
        let vreq = self.sess.target.padded_request(
            prep.w_verify,
            &parts.tokens,
            &parts.positions,
            &parts.slots,
            &parts.mask,
            self.sess.exec_mode(),
        );
        // The request owns a padded copy of the rows; the dense mask
        // buffer goes back to the arena pool.
        sh.arena.put_f32(parts.mask);
        let t0 = Instant::now();
        let verify_pending = self.rt.submit(vreq)?;
        self.submit_tail(&mut prep)?;
        let vreply = verify_pending.wait()?;
        self.rec.record_windowed("stage.verify", t0.elapsed().as_secs_f64(), STAGE_WINDOW);
        self.rec.record_windowed("stage.verify_exec", vreply.exec_seconds, STAGE_WINDOW);
        let n = prep.keep.len();
        let vocab = self.sess.target.spec.vocab;
        let d_model = self.sess.target.spec.d_model;
        let (out, next_head, hidden) = self.complete_verify(
            prep,
            &vreply.logits[..n * vocab],
            &vreply.hidden[..n * d_model],
            &mut sh,
            false,
        )?;
        let outcome = self.conclude_iteration(out, next_head, hidden, &mut sh, t_iter);
        drop(sh);
        Ok(outcome)
    }

    /// Post-iteration bookkeeping common to the solo and batched paths:
    /// per-task counters, predictor training data, the CPU-overhead EWMA,
    /// budget/headroom termination, and the streamed-token clipping.
    fn conclude_iteration(
        &mut self,
        out: Vec<u32>,
        next_head: Option<PendingHead>,
        hidden: Vec<f32>,
        sh: &mut SpecShared,
        t_iter: Instant,
    ) -> StepOutcome {
        self.rec.record_windowed("stage.iter", t_iter.elapsed().as_secs_f64(), STAGE_WINDOW);
        self.iterations += 1;
        // Depth-predictor training data: the hidden state seen *before*
        // this iteration, labelled with how many draft tokens it accepted.
        if let Some(ph) = self.prev_hidden.take() {
            sh.depth_samples.push((ph, out.len().saturating_sub(1)));
        }
        self.prev_hidden = Some(hidden);
        let room = self.max_new.saturating_sub(self.tokens.len());
        let visible = out[..out.len().min(room)].to_vec();
        self.tokens.extend_from_slice(&out);
        self.head = next_head;
        if self.head.is_some() {
            // Refresh the measured CPU-overhead term of the objective.
            // Absent series (NaN mean) count as zero: the mask/walk
            // splits may lack samples when a generation ends after very
            // few iterations.
            let nz = |x: f64| if x.is_finite() { x } else { 0.0 };
            let cpu = nz(self.rec.mean("stage.cpu_build"))
                + nz(self.rec.mean("stage.cpu_mask"))
                + nz(self.rec.mean("stage.cpu_walk"))
                + nz(self.rec.mean("stage.accept"))
                + nz(self.rec.mean("stage.bookkeep"));
            if cpu > 0.0 {
                sh.lat.cpu_overhead = 0.9 * sh.lat.cpu_overhead + 0.1 * cpu;
            }
        }
        self.seconds += t_iter.elapsed().as_secs_f64();
        if self.tokens.len() >= self.max_new || !self.kv_can_continue() || self.head.is_none()
        {
            self.state = TaskState::Done;
        }
        StepOutcome { tokens: visible, state: self.state }
    }
}

impl DecodeTask for SpecTask {
    fn state(&self) -> TaskState {
        self.state
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn step(&mut self) -> crate::Result<StepOutcome> {
        match self.state {
            TaskState::Done => Ok(StepOutcome { tokens: vec![], state: TaskState::Done }),
            TaskState::Prefill => self.step_prefill(),
            TaskState::Iterate => self.step_iterate(),
        }
    }

    fn headroom(&self) -> usize {
        self.sess.headroom(self.tree_budget)
    }

    fn uncached_prompt_len(&self) -> Option<usize> {
        // Admission budgets only for the prompt tail the prefix cache
        // did not cover (DESIGN.md §12). `prompt` is drained once the
        // prefill completes, so this naturally reaches 0 afterwards; a
        // chunked prefill in flight (DESIGN.md §14) shrinks it chunk by
        // chunk via the sides' committed resume point.
        let covered = self.reused_prefix.max(self.sess.attached_prefix_len());
        Some(self.prompt.len().saturating_sub(covered))
    }

    fn set_slo_class(&mut self, latency: bool) {
        self.latency_class = latency;
    }

    fn kv_slots_in_use(&self) -> usize {
        self.sess.drafter.slots.in_use() + self.sess.target.slots.in_use()
    }

    fn accept_rate(&self) -> Option<f64> {
        Some(self.accept_est.q())
    }

    fn allocated_budget(&self) -> Option<usize> {
        self.round_budget
    }

    fn finish(self: Box<Self>) -> Generation {
        let mut this = *self;
        this.tokens.truncate(this.max_new);
        // §5.2: refresh the profile-guided plan with the *measured* stage
        // durations of this generation (takes effect for tasks begun
        // after this point; running tasks keep their snapshot).
        if this.cfg.schedule == SchedulePlan::ProfileSearch && this.iterations > 0 {
            let mut sh = this.shared.lock().unwrap();
            research_plan_into(&mut sh, &this.cfg, &this.rec);
        }
        Generation {
            tokens: std::mem::take(&mut this.tokens),
            iterations: this.iterations,
            seconds: this.seconds,
            prefill_seconds: this.prefill_seconds,
            recorder: std::mem::take(&mut this.rec),
        }
    }
}

impl StepEngine for SpecDecoder {
    fn set_degradation(&mut self, rung: u8) {
        self.degrade.store(rung, Ordering::Relaxed);
    }

    fn set_tracer(&mut self, tracer: Arc<crate::trace::Tracer>) {
        self.tracer = Some(tracer);
    }

    fn begin(&mut self, prompt: &[u32], max_new: usize) -> crate::Result<Box<dyn DecodeTask>> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        let sess = if self.cfg.batch.enabled {
            // Batched mode: all sessions share one cache pair — leased as
            // equal ranges or paged blocks — so a scheduling round can
            // verify them in one call.
            if self.pool.is_none() {
                self.pool = Some(Arc::new(SharedCachePool::new(
                    &self.rt,
                    &self.cfg.drafter,
                    &self.cfg.target,
                    &self.cfg.batch,
                )?));
            }
            match Session::new_shared(
                &self.rt,
                self.pool.as_ref().unwrap(),
                self.cfg.sampling.seed,
                self.cfg.compiled,
            ) {
                Ok(s) => s,
                // More live sessions than shared regions (a server driving
                // more slots than `batch.max_sessions` in equal-partition
                // mode): degrade gracefully to an owned-cache session.
                // `step_batch` recognises the foreign cache and steps such
                // sessions serially instead of packing them into the
                // shared-cache batch.
                Err(_) => Session::new(
                    &self.rt,
                    &self.cfg.drafter,
                    &self.cfg.target,
                    self.cfg.sampling.seed,
                    self.cfg.compiled,
                )?,
            }
        } else {
            Session::new(
                &self.rt,
                &self.cfg.drafter,
                &self.cfg.target,
                self.cfg.sampling.seed,
                self.cfg.compiled,
            )?
        };
        // Cross-request prefix reuse (DESIGN.md §12): map the longest
        // cached prefix of the prompt read-shared into both sides before
        // any budgeting, so the tree-budget clamp below and the server's
        // admission check both see the *post-reuse* picture — attached
        // blocks consume no new pool blocks and the prefill demand
        // shrinks to the uncached tail.
        let mut sess = sess;
        let reused_prefix = sess.attach_prefix(prompt);
        // Keep enough headroom for one full tree + tail + bonus chain —
        // clamped to the shared pool's current headroom in paged mode, so
        // admission asks "does the pool cover prompt + tree budget", not
        // "is a worst-case region free" (DESIGN.md §10).
        let mut tree_budget = self.cfg.max_depth * self.cfg.max_width + self.cfg.max_verify + 8;
        if sess.is_paged() {
            let avail = sess
                .drafter
                .slots
                .available()
                .min(sess.target.slots.available());
            tree_budget = scheduler::clamp_tree_budget(tree_budget, avail);
        }
        // Seed the session's acceptance estimator from the shared stats
        // at its configured draft width (DESIGN.md §15): a fresh session
        // inherits the fleet's current estimate, and the allocator's
        // degenerate (all-equal) case keeps cold starts uniform.
        let (plan, accept_seed) = {
            let sh = self.shared.lock().unwrap();
            (sh.plan, sh.stats.q(self.cfg.max_width))
        };
        Ok(Box::new(SpecTask {
            rt: self.rt.clone(),
            cfg: self.cfg.clone(),
            shared: Arc::clone(&self.shared),
            sess,
            state: TaskState::Prefill,
            prompt: prompt.to_vec(),
            max_new,
            tree_budget,
            reused_prefix,
            degrade: Arc::clone(&self.degrade),
            latency_class: true,
            accept_est: AcceptanceEstimator::seeded(accept_seed),
            round_budget: None,
            plan,
            head: None,
            depth_hint: None,
            prev_hidden: None,
            rec: Recorder::new(),
            tokens: Vec::new(),
            iterations: 0,
            seconds: 0.0,
            prefill_seconds: 0.0,
        }))
    }

    /// Cross-session batched scheduling round (DESIGN.md §9 + §11).
    ///
    /// The round is *stage-aligned* (DESIGN.md §11): first a **draft
    /// phase** — every ready session's deferred head rows ride one
    /// packed drafter call, then the sessions grow their trees level by
    /// level with each level's rows packed into one drafter call per
    /// [`plan_batches_enveloped`] group — then a CPU **build phase**
    /// (per-session pruning + verify-row assembly), then the **verify
    /// phase** of DESIGN.md §9: one width-padded verifier call per
    /// group under a block-diagonal mask, tail drafts queued right
    /// behind it, and the reply's rows split back into per-task
    /// acceptance walks. With `--no-batch-draft` the draft phase runs
    /// per session (the verify-only batching of §9).
    /// Prefilling/finished/foreign tasks fall back to serial stepping
    /// inside the same round.
    #[allow(clippy::too_many_lines)]
    fn step_batch(
        &mut self,
        tasks: &mut [&mut dyn DecodeTask],
    ) -> Vec<crate::Result<StepOutcome>> {
        let Some(pool) = self.pool.clone() else {
            // Batching disabled (or no session ever admitted): serial.
            return tasks.iter_mut().map(|t| t.step()).collect();
        };
        let n = tasks.len();
        let mut results: Vec<Option<crate::Result<StepOutcome>>> = Vec::with_capacity(n);
        results.resize_with(n, || None);

        // Phase 0: split the round into batchable mid-iteration SpecTasks
        // and everything else (prefill steps, finished tasks), which
        // steps serially within the same round.
        let mut batchable: Vec<usize> = Vec::new();
        for (i, t) in tasks.iter_mut().enumerate() {
            let joins = t.as_any_mut().downcast_mut::<SpecTask>().is_some_and(|s| {
                s.state == TaskState::Iterate
                    && s.head.is_some()
                    // Only sessions on the shared caches can ride one
                    // device call; overflow sessions (owned caches, see
                    // `begin`) step serially.
                    && s.sess.target.cache == pool.target_cache()
                    && s.sess.drafter.cache == pool.drafter_cache()
            });
            if joins {
                batchable.push(i);
            } else {
                results[i] = Some(t.step());
            }
        }
        if batchable.is_empty() {
            return results.into_iter().map(Option::unwrap).collect();
        }

        // Only a few scalars of the model specs are needed per round; do
        // not clone whole ModelSpecs (tensor layout etc.) on the hot
        // path.
        let target_spec =
            self.rt.spec(&self.cfg.target).map(|s| (s.vocab, s.d_model, s.cache_capacity));
        let drafter_spec =
            self.rt.spec(&self.cfg.drafter).map(|s| (s.vocab, s.cache_capacity));
        let ((vocab, d_model, capacity), (dvocab, dcapacity)) =
            match (target_spec, drafter_spec) {
                (Ok(t), Ok(d)) => (t, d),
                (Err(e), _) | (_, Err(e)) => {
                    let msg = format!("{e:#}");
                    for i in batchable {
                        results[i] = Some(Err(anyhow::anyhow!("batched round: {msg}")));
                    }
                    return results.into_iter().map(Option::unwrap).collect();
                }
            };

        let max_w = *crate::config::GRAPH_WIDTHS.last().unwrap();
        let mode =
            if self.cfg.compiled { ExecMode::Resident } else { ExecMode::WeightsByValue };
        let batch_draft = self.cfg.batch.batch_draft;
        // Engine-side stage spans (DESIGN.md §17): uid 0 — each span
        // covers the whole packed phase, not one request — and the round
        // stamp the scheduler set groups them under the current round.
        let tracer = self.tracer.clone();
        let tr = tracer.as_deref();
        let shared = Arc::clone(&self.shared);
        let mut sh = shared.lock().unwrap();

        // Draft + build phases → per-session verification rows.
        struct Entry {
            idx: usize,
            prep: VerifyPrep,
            parts: VerifyParts,
            t_iter: Instant,
        }
        let mut entries: Vec<Option<Entry>> = Vec::new();

        if batch_draft {
            // ---------- draft phase (stage-aligned, DESIGN.md §11) ----------
            struct Drafting {
                idx: usize,
                head: Option<PendingHead>,
                d: Option<DraftInFlight>,
                t_iter: Instant,
                /// Packed draft-call wall seconds this session rode
                /// (head + every level) — its `stage.tree_draft` sample.
                draft_secs: f64,
            }
            let mut dents: Vec<Option<Drafting>> = Vec::new();
            for &i in &batchable {
                let task = tasks[i].as_any_mut().downcast_mut::<SpecTask>().unwrap();
                let head = task.head.take().unwrap();
                dents.push(Some(Drafting {
                    idx: i,
                    head: Some(head),
                    d: None,
                    t_iter: Instant::now(),
                    draft_secs: 0.0,
                }));
            }

            // ---------- round budget resolution (DESIGN.md §15) ----------
            // One pool-headroom snapshot and one global allocation decide
            // every session's verification budget *before* any tree is
            // grown: the allocator (the default) splits a round-wide
            // budget by marginal expected-accepted-tokens priced against
            // the verifier curve; `--no-global-alloc` water-fills the
            // same snapshot uniformly. Either way the grants sum to at
            // most the snapshot, so a session pruned late in the build
            // fan-out can no longer overestimate paged headroom consumed
            // by an earlier one (typed preemption stays as the
            // belt-and-braces fallback for anything else that moves).
            {
                let mut demands: Vec<scheduler::alloc::SessionDemand> =
                    Vec::with_capacity(dents.len());
                let mut pool_headroom = usize::MAX;
                for dent in &dents {
                    let idx = dent.as_ref().unwrap().idx;
                    let task = tasks[idx].as_any_mut().downcast_mut::<SpecTask>().unwrap();
                    let headroom = task.sess.target.slots.available();
                    if task.sess.is_paged() {
                        // Paged sessions share one pool: every task
                        // reports the same availability, which is also
                        // the round's global constraint.
                        pool_headroom = headroom;
                    }
                    demands.push(scheduler::alloc::SessionDemand {
                        q: task.accept_est.q(),
                        envelope: task.verify_envelope(),
                        headroom,
                        latency_class: task.latency_class,
                    });
                }
                let global: usize =
                    demands.iter().map(|dm| dm.envelope.min(dm.headroom).max(1)).sum();
                let budgets = if self.cfg.batch.global_alloc {
                    scheduler::alloc::allocate_verify_budget(
                        &demands,
                        global,
                        pool_headroom,
                        Some(&sh.lat.verifier),
                    )
                } else {
                    scheduler::alloc::uniform_verify_budget(
                        &demands,
                        global.min(pool_headroom),
                    )
                };
                for (k, &b) in budgets.iter().enumerate() {
                    let idx = dents[k].as_ref().unwrap().idx;
                    let task = tasks[idx].as_any_mut().downcast_mut::<SpecTask>().unwrap();
                    task.round_budget = Some(b);
                }
            }

            // (a) Pack every deferred head into one drafter call: the
            // narrow per-session width-1 head drafts of the solo path
            // become one width-S call per round.
            let deferred: Vec<usize> = (0..dents.len())
                .filter(|&k| {
                    dents[k].as_ref().is_some_and(|e| {
                        matches!(e.head.as_ref().unwrap().state, HeadState::Deferred)
                    })
                })
                .collect();
            if !deferred.is_empty() {
                let sp_head = tr.map(|t| t.begin(crate::trace::Name::HeadDraft, 0));
                let mut head_parts: Vec<DraftParts> = Vec::with_capacity(deferred.len());
                for &k in &deferred {
                    let (idx, slot, token) = {
                        let e = dents[k].as_ref().unwrap();
                        let h = e.head.as_ref().unwrap();
                        (e.idx, h.slot, h.token)
                    };
                    let task = tasks[idx].as_any_mut().downcast_mut::<SpecTask>().unwrap();
                    head_parts.push(task.deferred_head_parts(slot, token, &mut sh.arena));
                }
                let rows: Vec<usize> = head_parts.iter().map(|p| p.tokens.len()).collect();
                let head_env = self.cfg.batch.max_sessions.min(max_w);
                for g in plan_batches_enveloped(&rows, max_w, head_env) {
                    let member_parts: Vec<(&[u32], &[i32], &[u32], &[f32])> = g
                        .members
                        .iter()
                        .map(|&m| {
                            let p = &head_parts[m];
                            (
                                p.tokens.as_slice(),
                                p.positions.as_slice(),
                                p.slots.as_slice(),
                                p.mask.as_slice(),
                            )
                        })
                        .collect();
                    let req = packed_request(
                        self.cfg.drafter.clone(),
                        pool.drafter_cache(),
                        dcapacity,
                        g.width,
                        &member_parts,
                        mode,
                    );
                    let t0 = Instant::now();
                    match self.rt.submit(req).and_then(|p| p.wait()) {
                        Err(e) => {
                            let msg = format!("{e:#}");
                            for &m in &g.members {
                                if let Some(en) = dents[deferred[m]].take() {
                                    results[en.idx] =
                                        Some(Err(anyhow::anyhow!("batched head draft: {msg}")));
                                }
                            }
                        }
                        Ok(reply) => {
                            let dt = t0.elapsed().as_secs_f64();
                            for (off, &m) in g.members.iter().enumerate() {
                                let en = dents[deferred[m]].as_mut().unwrap();
                                let h = en.head.as_mut().unwrap();
                                h.state = HeadState::Ready(HeadReply {
                                    logits: reply.logits
                                        [off * dvocab..(off + 1) * dvocab]
                                        .to_vec(),
                                });
                                en.draft_secs += dt;
                                let task = tasks[en.idx]
                                    .as_any_mut()
                                    .downcast_mut::<SpecTask>()
                                    .unwrap();
                                task.rec.record_windowed(
                                    "batch.draft_sessions",
                                    g.members.len() as f64,
                                    STAGE_WINDOW,
                                );
                            }
                        }
                    }
                }
                // The packed calls own padded copies of every row; the
                // dense head-mask buffers go back to the arena pool.
                for p in head_parts {
                    sh.arena.put_f32(p.mask);
                }
                if let (Some(t), Some(s)) = (tr, sp_head) {
                    t.end(crate::trace::Name::HeadDraft, 0, s);
                }
            }

            // (b) Resolve heads and open each session's draft.
            for dent in dents.iter_mut() {
                let begun = {
                    let Some(en) = dent.as_mut() else { continue };
                    let idx = en.idx;
                    let head = en.head.take().unwrap();
                    let task = tasks[idx].as_any_mut().downcast_mut::<SpecTask>().unwrap();
                    match task.begin_draft(head, &mut sh) {
                        Ok(d) => {
                            en.d = Some(d);
                            Ok(())
                        }
                        Err(e) => Err((idx, e)),
                    }
                };
                if let Err((idx, e)) = begun {
                    *dent = None;
                    results[idx] = Some(Err(e));
                }
            }

            // (c) Level loop: every session still growing contributes its
            // next level; same-level rows pack into one drafter call per
            // group. The envelope pins the padded width so rounds whose
            // level sizes fluctuate reuse one compiled graph.
            let draft_env = (self.cfg.batch.max_sessions * self.cfg.max_width).min(max_w);
            let sp_draft = tr.map(|t| t.begin(crate::trace::Name::TreeDraft, 0));
            loop {
                let mut lvl: Vec<(usize, DraftParts)> = Vec::new();
                for (k, dent) in dents.iter_mut().enumerate() {
                    let stepped = {
                        let Some(en) = dent.as_mut() else { continue };
                        let idx = en.idx;
                        let task =
                            tasks[idx].as_any_mut().downcast_mut::<SpecTask>().unwrap();
                        (idx, task.next_draft_parts(en.d.as_mut().unwrap(), &mut sh.arena))
                    };
                    match stepped {
                        (_, Ok(Some(p))) => lvl.push((k, p)),
                        (_, Ok(None)) => {}
                        (idx, Err(e)) => {
                            *dent = None;
                            results[idx] = Some(Err(e));
                        }
                    }
                }
                if lvl.is_empty() {
                    break;
                }
                let rows: Vec<usize> = lvl.iter().map(|(_, p)| p.tokens.len()).collect();
                for g in plan_batches_enveloped(&rows, max_w, draft_env) {
                    let member_parts: Vec<(&[u32], &[i32], &[u32], &[f32])> = g
                        .members
                        .iter()
                        .map(|&m| {
                            let p = &lvl[m].1;
                            (
                                p.tokens.as_slice(),
                                p.positions.as_slice(),
                                p.slots.as_slice(),
                                p.mask.as_slice(),
                            )
                        })
                        .collect();
                    let req = packed_request(
                        self.cfg.drafter.clone(),
                        pool.drafter_cache(),
                        dcapacity,
                        g.width,
                        &member_parts,
                        mode,
                    );
                    let t0 = Instant::now();
                    match self.rt.submit(req).and_then(|p| p.wait()) {
                        Err(e) => {
                            let msg = format!("{e:#}");
                            for &m in &g.members {
                                let k = lvl[m].0;
                                if let Some(en) = dents[k].take() {
                                    results[en.idx] =
                                        Some(Err(anyhow::anyhow!("batched tree draft: {msg}")));
                                }
                            }
                        }
                        Ok(reply) => {
                            let dt = t0.elapsed().as_secs_f64();
                            let mut off = 0usize;
                            for &m in &g.members {
                                let (k, p) = &lvl[m];
                                let nrows = p.tokens.len();
                                let en = dents[*k].as_mut().unwrap();
                                let task = tasks[en.idx]
                                    .as_any_mut()
                                    .downcast_mut::<SpecTask>()
                                    .unwrap();
                                task.complete_draft_level(
                                    en.d.as_mut().unwrap(),
                                    &reply.logits[off * dvocab..(off + nrows) * dvocab],
                                );
                                task.rec.record_windowed(
                                    "batch.draft_sessions",
                                    g.members.len() as f64,
                                    STAGE_WINDOW,
                                );
                                en.draft_secs += dt;
                                off += nrows;
                            }
                        }
                    }
                }
                // Recycle the level's dense mask buffers (the packed
                // calls own padded copies of the rows).
                for (_, p) in lvl {
                    sh.arena.put_f32(p.mask);
                }
            }
            if let (Some(t), Some(s)) = (tr, sp_draft) {
                t.end(crate::trace::Name::TreeDraft, 0, s);
            }

            // ---------- build phase (CPU: prune + verify assembly) ----------
            // With `--cpu-threads > 1`, the per-session prune plans — the
            // knapsack DP, a pure function of each grown tree — fan out
            // across scoped threads (DESIGN.md §13). Mask assembly and
            // slot allocation stay serial: they mutate the shared caches.
            let sp_build = tr.map(|t| t.begin(crate::trace::Name::CpuBuild, 0));
            let threads = crate::util::par::effective_threads(self.cfg.batch.cpu_threads);
            let mut pre: Vec<Option<(crate::Result<(Vec<NodeId>, usize)>, f64)>> =
                Vec::with_capacity(dents.len());
            pre.resize_with(dents.len(), || None);
            let live: Vec<usize> = (0..dents.len())
                .filter(|&k| dents[k].as_ref().is_some_and(|e| e.d.is_some()))
                .collect();
            // Budgets are the round grants resolved against one headroom
            // snapshot before drafting (DESIGN.md §15) — not live pool
            // reads, so the fan-out below prices exactly what the
            // round's grants sum to. The floor of 1 keeps a starved
            // session's root-only verify; if even that overcommits, the
            // typed-preemption fallback catches it at allocation time.
            let budgets: Vec<usize> = live
                .iter()
                .map(|&k| {
                    let idx = dents[k].as_ref().unwrap().idx;
                    let task = tasks[idx].as_any_mut().downcast_mut::<SpecTask>().unwrap();
                    match task.round_budget {
                        Some(b) => b.max(1),
                        None => task.verify_budget(),
                    }
                })
                .collect();
            if threads > 1 && live.len() > 1 {
                let lat = sh.lat.clone();
                let prune_cfg = self.cfg.prune;
                let jobs: Vec<(&DraftInFlight, usize)> = live
                    .iter()
                    .zip(&budgets)
                    .map(|(&k, &b)| (dents[k].as_ref().unwrap().d.as_ref().unwrap(), b))
                    .collect();
                let outs = crate::util::par::parallel_map(&jobs, threads, |&(d, budget)| {
                    let t0 = Instant::now();
                    let r = plan_prune(prune_cfg, &d.st.tree, &lat, &d.draft_widths, budget);
                    (r, t0.elapsed().as_secs_f64())
                });
                for (&k, o) in live.iter().zip(outs) {
                    pre[k] = Some(o);
                }
            } else {
                // Serial build: same grants, same plan function — only
                // the fan-out is skipped.
                for (&k, &budget) in live.iter().zip(&budgets) {
                    let d = dents[k].as_ref().unwrap().d.as_ref().unwrap();
                    let t0 = Instant::now();
                    let r =
                        plan_prune(self.cfg.prune, &d.st.tree, &sh.lat, &d.draft_widths, budget);
                    pre[k] = Some((r, t0.elapsed().as_secs_f64()));
                }
            }
            for (k, en) in dents.into_iter().enumerate() {
                let Some(Drafting { idx, d, t_iter, draft_secs, .. }) = en else { continue };
                let task = tasks[idx].as_any_mut().downcast_mut::<SpecTask>().unwrap();
                task.rec.record_windowed("stage.tree_draft", draft_secs, STAGE_WINDOW);
                let d = d.expect("draft opened in phase (b)");
                let built = match pre[k].take() {
                    Some((Ok((keep, w)), secs)) => {
                        task.rec.record_windowed("stage.cpu_build", secs, STAGE_WINDOW);
                        task.rec.record("tree_size", d.st.tree.len() as f64);
                        task.finish_draft_pruned(d, &mut sh, keep, w)
                    }
                    Some((Err(e), _)) => Err(e),
                    None => task.finish_draft(d, &mut sh),
                };
                match built {
                    Ok((prep, parts)) => {
                        entries.push(Some(Entry { idx, prep, parts, t_iter }))
                    }
                    Err(e) => results[idx] = Some(Err(e)),
                }
            }
            if let (Some(t), Some(s)) = (tr, sp_build) {
                t.end(crate::trace::Name::CpuBuild, 0, s);
            }
        } else {
            // Verify-only batching (`--no-batch-draft`, the §9 regime):
            // every session drafts serially, only the verify packs.
            for &i in &batchable {
                let task = tasks[i].as_any_mut().downcast_mut::<SpecTask>().unwrap();
                let head = task.head.take().unwrap();
                let t_iter = Instant::now();
                match task.prepare_verify(head, &mut sh) {
                    Ok((prep, parts)) => {
                        entries.push(Some(Entry { idx: i, prep, parts, t_iter }))
                    }
                    Err(e) => results[i] = Some(Err(e)),
                }
            }
        }

        // ---------- verify phase (DESIGN.md §9) ----------
        // One verifier call per group, tail drafts queued right behind.
        let rows: Vec<usize> = entries
            .iter()
            .map(|e| e.as_ref().unwrap().parts.tokens.len())
            .collect();
        let sp_verify = tr.map(|t| t.begin(crate::trace::Name::Verify, 0));
        for g in plan_batches(&rows, max_w) {
            let req = {
                let member_parts: Vec<(&[u32], &[i32], &[u32], &[f32])> = g
                    .members
                    .iter()
                    .map(|&m| {
                        let e = entries[m].as_ref().unwrap();
                        (
                            e.parts.tokens.as_slice(),
                            e.parts.positions.as_slice(),
                            e.parts.slots.as_slice(),
                            e.parts.mask.as_slice(),
                        )
                    })
                    .collect();
                packed_request(
                    self.cfg.target.clone(),
                    pool.target_cache(),
                    capacity,
                    g.width,
                    &member_parts,
                    mode,
                )
            };
            let t0 = Instant::now();
            let pending = match self.rt.submit(req) {
                Ok(p) => p,
                Err(e) => {
                    let msg = format!("{e:#}");
                    for &m in &g.members {
                        let en = entries[m].take().unwrap();
                        results[en.idx] =
                            Some(Err(anyhow::anyhow!("batched verify submit: {msg}")));
                    }
                    continue;
                }
            };
            // AOT tail drafts overlap the batched verify exactly as they
            // overlap a solo one. A failed submit only costs the overlap;
            // a dead device surfaces at the verify wait below.
            for &m in &g.members {
                let en = entries[m].as_mut().unwrap();
                let task = tasks[en.idx].as_any_mut().downcast_mut::<SpecTask>().unwrap();
                let _ = task.submit_tail(&mut en.prep);
            }
            match pending.wait() {
                Err(e) => {
                    let msg = format!("{e:#}");
                    for &m in &g.members {
                        let en = entries[m].take().unwrap();
                        results[en.idx] = Some(Err(anyhow::anyhow!("batched verify: {msg}")));
                    }
                }
                Ok(vreply) => {
                    // The per-member reply handling below is the accept
                    // walk (plus bookkeeping) — a nested uid-0 span.
                    let sp_walk = tr.map(|t| t.begin(crate::trace::Name::AcceptWalk, 0));
                    let dt = t0.elapsed().as_secs_f64();
                    let mut off = 0usize;
                    for &m in &g.members {
                        let mut en = entries[m].take().unwrap();
                        let nrows = en.parts.tokens.len();
                        // The packed request owns a padded copy of the
                        // rows; the dense mask goes back to the pool.
                        sh.arena.put_f32(std::mem::take(&mut en.parts.mask));
                        let task =
                            tasks[en.idx].as_any_mut().downcast_mut::<SpecTask>().unwrap();
                        task.rec.record_windowed("stage.verify", dt, STAGE_WINDOW);
                        task.rec.record_windowed(
                            "stage.verify_exec",
                            vreply.exec_seconds,
                            STAGE_WINDOW,
                        );
                        task.rec.record_windowed(
                            "batch.sessions",
                            g.members.len() as f64,
                            STAGE_WINDOW,
                        );
                        let lo = off * vocab;
                        let hi = (off + nrows) * vocab;
                        let hlo = off * d_model;
                        let hhi = (off + nrows) * d_model;
                        let r = match task.complete_verify(
                            en.prep,
                            &vreply.logits[lo..hi],
                            &vreply.hidden[hlo..hhi],
                            &mut sh,
                            batch_draft,
                        ) {
                            Ok((out, next_head, hidden)) => Ok(task.conclude_iteration(
                                out,
                                next_head,
                                hidden,
                                &mut sh,
                                en.t_iter,
                            )),
                            Err(e) => Err(e),
                        };
                        results[en.idx] = Some(r);
                        off += nrows;
                    }
                    if let (Some(t), Some(s)) = (tr, sp_walk) {
                        t.end(crate::trace::Name::AcceptWalk, 0, s);
                    }
                }
            }
        }
        if let (Some(t), Some(s)) = (tr, sp_verify) {
            t.end(crate::trace::Name::Verify, 0, s);
        }
        drop(sh);
        results.into_iter().map(Option::unwrap).collect()
    }

    fn cache_occupancy(&self) -> Option<(u64, u64)> {
        self.pool
            .as_ref()
            .and_then(|p| p.block_occupancy())
            .map(|(used, total)| (used as u64, total as u64))
    }

    fn prefix_stats(&self) -> Option<crate::kvcache::PrefixCacheStats> {
        self.pool.as_ref().and_then(|p| p.prefix_stats())
    }
}

impl super::Engine for SpecDecoder {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn generate_with(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        sink: super::TokenSink,
    ) -> crate::Result<Generation> {
        let task = self.begin(prompt, max_new)?;
        task::drive(task, sink)
    }
}
