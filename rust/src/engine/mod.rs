//! Decode engines.
//!
//! [`SpecDecoder`] (in [`spec`]) is the general tree-speculation engine: it
//! implements the full Yggdrasil pipeline (EGT drafting, latency-aware
//! width/verify selection, verification-width pruning, depth predictor,
//! stage-scheduled overlap) *and* — via [`crate::config::EngineConfig`]
//! presets — every speculative baseline (classic sequence speculation,
//! SpecInfer K-ary trees, Sequoia static trees, vLLM-Spec). The paper's
//! Fig. 12 breakdown toggles exactly these switches.
//!
//! [`crate::baselines::VanillaEngine`] provides the non-speculative
//! autoregressive floor.
//!
//! Both engines expose two equivalent interfaces: the blocking
//! [`Engine::generate_with`] loop, and the resumable step-driven form in
//! [`task`] — [`StepEngine::begin`] opens a [`DecodeTask`] whose
//! [`DecodeTask::step`] runs exactly one verification iteration. The
//! blocking form is implemented as a driver over `step()`
//! ([`task::drive`]), and the server (`crate::server`) round-robins
//! `step()` across many concurrent tasks (continuous serving).

pub mod profiling;
pub mod session;
pub mod spec;
pub mod task;

pub use profiling::profile_latency_model;
pub use session::{Session, SharedCachePool};
pub use spec::SpecDecoder;
pub use task::{drive, DecodeTask, StepEngine, StepOutcome, TaskState};

use crate::metrics::Recorder;

/// Result of one `generate` call.
#[derive(Debug, Clone)]
pub struct Generation {
    /// Newly generated tokens (prompt excluded).
    pub tokens: Vec<u32>,
    /// Decoding iterations (verification steps) used.
    pub iterations: usize,
    /// Wall-clock seconds for the whole generation (prefill excluded).
    pub seconds: f64,
    /// Prefill seconds.
    pub prefill_seconds: f64,
    /// Per-stage timings and per-iteration acceptance counts.
    pub recorder: Recorder,
}

impl Generation {
    /// Average accepted length: tokens committed per verification step
    /// (the paper's AAL metric; includes the bonus token).
    pub fn aal(&self) -> f64 {
        if self.iterations == 0 {
            return f64::NAN;
        }
        self.tokens.len() as f64 / self.iterations as f64
    }

    /// Per-token latency (the paper's TPOT headline metric).
    pub fn tpot(&self) -> f64 {
        if self.tokens.is_empty() {
            return f64::NAN;
        }
        self.seconds / self.tokens.len() as f64
    }

    /// Mean per-iteration (per-step) latency.
    pub fn step_latency(&self) -> f64 {
        if self.iterations == 0 {
            return f64::NAN;
        }
        self.seconds / self.iterations as f64
    }
}

/// Streaming sink: called with each batch of newly committed tokens.
pub type TokenSink<'a> = &'a mut dyn FnMut(&[u32]);

/// Common engine interface used by the benchmark harness and the server.
pub trait Engine {
    /// Human-readable engine label (used in tables and logs).
    fn name(&self) -> String;

    /// Generates up to `max_new` tokens continuing `prompt`.
    fn generate(&mut self, prompt: &[u32], max_new: usize) -> crate::Result<Generation> {
        self.generate_with(prompt, max_new, &mut |_| {})
    }

    /// Like [`Engine::generate`] but streams committed tokens through
    /// `sink` as each verification completes (server streaming mode).
    fn generate_with(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        sink: TokenSink,
    ) -> crate::Result<Generation>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_metrics() {
        let g = Generation {
            tokens: vec![1; 30],
            iterations: 10,
            seconds: 0.6,
            prefill_seconds: 0.1,
            recorder: Recorder::new(),
        };
        assert!((g.aal() - 3.0).abs() < 1e-9);
        assert!((g.tpot() - 0.02).abs() < 1e-9);
        assert!((g.step_latency() - 0.06).abs() < 1e-9);
    }

    #[test]
    fn empty_generation_is_nan_not_panic() {
        let g = Generation {
            tokens: vec![],
            iterations: 0,
            seconds: 0.0,
            prefill_seconds: 0.0,
            recorder: Recorder::new(),
        };
        assert!(g.aal().is_nan());
        assert!(g.tpot().is_nan());
    }
}
