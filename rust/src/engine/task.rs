//! Resumable decode tasks: the step-driven core of the serving layer.
//!
//! A [`DecodeTask`] is one generation turned into an explicit state
//! machine: `Prefill → Iterate → Done`. Each [`DecodeTask::step`] call runs
//! exactly one unit of schedulable work — the prompt prefill, or one
//! verification iteration — and returns the tokens that step committed.
//! [`Engine::generate_with`](super::Engine::generate_with) is a thin
//! driver ([`drive`]) over `step()`, so the blocking single-request path
//! and the multi-session server execute the *same* code: the server merely
//! round-robins `step()` across live tasks instead of looping one to
//! completion.
//!
//! Tasks are self-contained (they own their [`super::Session`] — KV caches
//! both sides — and per-generation recorder/counters) so dropping a task
//! at any point frees its device cache state immediately; this is what
//! makes mid-generation cancellation in the server a plain `drop`.

use super::{Generation, TokenSink};

/// Lifecycle of a [`DecodeTask`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskState {
    /// Created; the next `step()` runs the prompt prefill.
    Prefill,
    /// Prefilled; each `step()` runs one verification iteration.
    Iterate,
    /// Generation finished (budget, cache exhaustion, or `max_new`);
    /// further `step()` calls are no-ops.
    Done,
}

/// What one `step()` produced.
#[derive(Debug, Clone)]
pub struct StepOutcome {
    /// Tokens committed by this step, already clipped to the request's
    /// `max_new` budget (what a streaming sink should see). Empty for the
    /// prefill step and for `step()` on a finished task.
    pub tokens: Vec<u32>,
    /// Task state *after* the step.
    pub state: TaskState,
}

impl StepOutcome {
    /// True once the task reached [`TaskState::Done`].
    pub fn done(&self) -> bool {
        self.state == TaskState::Done
    }
}

/// One resumable generation. See the module docs for the lifecycle.
pub trait DecodeTask: Send + std::any::Any {
    /// Current lifecycle state.
    fn state(&self) -> TaskState;

    /// Concrete-type escape hatch for engines whose
    /// [`StepEngine::step_batch`] needs its own task type (the batched
    /// scheduler downcasts to pack many tasks' verify rows into one
    /// device call). Implementations return `self`.
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any;

    /// Runs exactly one unit of work (one prefill, or one verification
    /// iteration) and returns the tokens it committed. Idempotent once
    /// [`TaskState::Done`] is reached.
    fn step(&mut self) -> crate::Result<StepOutcome>;

    /// Remaining KV-slot headroom in tokens (how much more this task can
    /// generate before its caches fill). The server's admission control
    /// checks this against the prompt length before scheduling a task.
    fn headroom(&self) -> usize;

    /// Prompt tokens this task still has to prefill into *fresh* KV
    /// slots — the prompt minus any prefix reused from a cross-request
    /// prefix cache (DESIGN.md §12). `None` when the engine cannot tell;
    /// admission then budgets for the whole prompt. Only meaningful
    /// before the prefill step runs.
    fn uncached_prompt_len(&self) -> Option<usize> {
        None
    }

    /// KV slots currently held by this task across both model sides
    /// (observability: the server surfaces the aggregate in its stats).
    fn kv_slots_in_use(&self) -> usize {
        0
    }

    /// Tags the task with its request's SLO class (DESIGN.md §14):
    /// `latency = true` for interactive requests whose inter-token
    /// latency the server protects, `false` for throughput-class batch
    /// work the degradation ladder sheds first. Default: ignored —
    /// engines without per-class behavior need no plumbing.
    fn set_slo_class(&mut self, _latency: bool) {}

    /// Whether a failed `step()` left the task in a consistent state it
    /// can retry from on a later round (e.g. pool exhaustion detected
    /// *before* any cache mutation). `false` — the conservative default —
    /// makes the serving layer preempt or fail the task immediately
    /// instead of re-stepping it under the degradation ladder.
    fn retryable(&self) -> bool {
        false
    }

    /// This session's online per-level acceptance estimate in `[0, 1)`
    /// (DESIGN.md §15), when the engine tracks one — the server mirrors
    /// it into the `accept_rate` stats percentiles. Default: untracked.
    fn accept_rate(&self) -> Option<f64> {
        None
    }

    /// The verification-row budget the global round allocator granted
    /// this task for its latest batched round (DESIGN.md §15), when one
    /// ran — the server sums it into the `alloc_budget_total` gauge.
    /// Default: no allocator.
    fn allocated_budget(&self) -> Option<usize> {
        None
    }

    /// Consumes the task and returns the completed [`Generation`].
    /// Callers normally invoke this once `step()` reports `Done`, but it
    /// is valid earlier (early client disconnect): the generation then
    /// covers what was committed so far.
    fn finish(self: Box<Self>) -> Generation;
}

/// Drives a task to completion, streaming each step's committed tokens
/// through `sink` — the run-to-completion path used by `generate_with`.
pub fn drive(mut task: Box<dyn DecodeTask>, sink: TokenSink) -> crate::Result<Generation> {
    loop {
        let out = task.step()?;
        if !out.tokens.is_empty() {
            sink(&out.tokens);
        }
        if out.done() {
            return Ok(task.finish());
        }
    }
}

/// An engine that can open resumable decode tasks. The blocking
/// [`super::Engine`] interface stays available (it is implemented on top
/// of `begin` + [`drive`]); the server requires `StepEngine` so it can
/// interleave many sessions on one device.
pub trait StepEngine: super::Engine {
    /// Starts a generation: allocates the task's KV caches and captures
    /// the prompt, but performs no model call yet (the first `step()`
    /// prefills). Cheap enough to use for admission control.
    fn begin(&mut self, prompt: &[u32], max_new: usize) -> crate::Result<Box<dyn DecodeTask>>;

    /// Runs one scheduling round over many live tasks, returning one
    /// outcome per task (same order).
    ///
    /// The default steps each task serially — time-sliced round-robin,
    /// exactly what the pre-batching server did. Engines that can share
    /// device work across sessions override this to pack the round into
    /// fewer device calls (see `SpecDecoder`'s cross-session batched
    /// verification, DESIGN.md §9). A per-task error fails that task
    /// only; the other tasks' outcomes are still returned.
    fn step_batch(
        &mut self,
        tasks: &mut [&mut dyn DecodeTask],
    ) -> Vec<crate::Result<StepOutcome>> {
        tasks.iter_mut().map(|t| t.step()).collect()
    }

    /// Block occupancy of the engine's shared *paged* KV cache, as
    /// `(blocks in use, total blocks)` summed over both model sides —
    /// `None` when the engine has no paged pool (owned caches, or the
    /// equal-partition layout). The serving layer mirrors this into its
    /// `ServerStats` occupancy gauges once per scheduling round.
    fn cache_occupancy(&self) -> Option<(u64, u64)> {
        None
    }

    /// Counters of the engine's cross-request prefix cache (DESIGN.md
    /// §12) — hit rate, reused tokens, evictions, cached-block gauge —
    /// or `None` when the engine runs without one. Mirrored into the
    /// serving stats once per scheduling round, like
    /// [`StepEngine::cache_occupancy`].
    fn prefix_stats(&self) -> Option<crate::kvcache::PrefixCacheStats> {
        None
    }

    /// Applies the serving layer's overload-degradation rung (DESIGN.md
    /// §14): `0` = no pressure; higher rungs progressively shrink verify
    /// budgets, skip drafting for throughput-class sessions, and halve
    /// the prefill chunk (see `scheduler::DegradationLadder`). Default:
    /// ignored — engines without degradation hooks run at full budgets.
    fn set_degradation(&mut self, _rung: u8) {}

    /// Hands the engine its worker's flight-recorder tracer (DESIGN.md
    /// §17) so round-internal stage spans — deferred-head draft,
    /// per-level tree draft, CPU build, packed verify, accept walk —
    /// land in the same ring as the scheduler's lifecycle events.
    /// Engine-side spans use uid 0 (they cover the whole batch) and
    /// inherit the round stamp the scheduler set. Default: ignored —
    /// engines without stage instrumentation need no plumbing.
    fn set_tracer(&mut self, _tracer: std::sync::Arc<crate::trace::Tracer>) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Recorder;

    /// Minimal in-memory task for driver tests.
    struct CountTask {
        produced: usize,
        max_new: usize,
        per_step: usize,
        state: TaskState,
    }

    impl DecodeTask for CountTask {
        fn state(&self) -> TaskState {
            self.state
        }

        fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
            self
        }

        fn step(&mut self) -> crate::Result<StepOutcome> {
            match self.state {
                TaskState::Done => Ok(StepOutcome { tokens: vec![], state: TaskState::Done }),
                TaskState::Prefill => {
                    self.state =
                        if self.max_new == 0 { TaskState::Done } else { TaskState::Iterate };
                    Ok(StepOutcome { tokens: vec![], state: self.state })
                }
                TaskState::Iterate => {
                    let n = self.per_step.min(self.max_new - self.produced);
                    let tokens: Vec<u32> =
                        (self.produced..self.produced + n).map(|x| x as u32).collect();
                    self.produced += n;
                    if self.produced >= self.max_new {
                        self.state = TaskState::Done;
                    }
                    Ok(StepOutcome { tokens, state: self.state })
                }
            }
        }

        fn headroom(&self) -> usize {
            self.max_new - self.produced
        }

        fn finish(self: Box<Self>) -> Generation {
            Generation {
                tokens: (0..self.produced).map(|x| x as u32).collect(),
                iterations: self.produced.div_ceil(self.per_step.max(1)),
                seconds: 1e-6,
                prefill_seconds: 1e-6,
                recorder: Recorder::new(),
            }
        }
    }

    #[test]
    fn drive_runs_prefill_then_iterations() {
        let task = Box::new(CountTask {
            produced: 0,
            max_new: 7,
            per_step: 3,
            state: TaskState::Prefill,
        });
        let mut seen: Vec<u32> = Vec::new();
        let mut chunks = 0usize;
        let g = drive(task, &mut |t| {
            seen.extend_from_slice(t);
            chunks += 1;
        })
        .unwrap();
        assert_eq!(g.tokens, seen);
        assert_eq!(g.tokens.len(), 7);
        assert_eq!(chunks, 3, "7 tokens at 3/step = 3 sink calls");
    }

    #[test]
    fn zero_budget_task_finishes_without_iterating() {
        let task = Box::new(CountTask {
            produced: 0,
            max_new: 0,
            per_step: 3,
            state: TaskState::Prefill,
        });
        let g = drive(task, &mut |_| panic!("no tokens expected")).unwrap();
        assert!(g.tokens.is_empty());
    }

    #[test]
    fn done_tasks_step_idempotently() {
        let mut t = CountTask { produced: 0, max_new: 1, per_step: 1, state: TaskState::Prefill };
        while !t.step().unwrap().done() {}
        let again = t.step().unwrap();
        assert!(again.tokens.is_empty() && again.done());
    }
}
