//! Hardware latency profiling — the measurement side of §4.1.
//!
//! The latency-aware objective needs `T_drafter(W)` and `T_verifier(W)`
//! curves for *this* machine and artifact bundle. [`profile_latency_model`]
//! measures them over the compiled graph widths via the runtime (results
//! persist as `artifacts/profile.json` through `yggdrasil profile`, so
//! serving startup is instant). The CPU bookkeeping term is measured by the
//! scheduler's plan search and folded in there.

use crate::config::GRAPH_WIDTHS;
use crate::objective::{LatencyCurve, LatencyModel};
use crate::runtime::{ExecMode, Runtime};

/// Measures both curves. `reps` per width (plus one warm-up that also
/// triggers lazy compilation).
pub fn profile_latency_model(
    rt: &Runtime,
    drafter: &str,
    target: &str,
    reps: usize,
) -> crate::Result<LatencyModel> {
    let mut curves = Vec::new();
    for model in [drafter, target] {
        let mut pts = Vec::new();
        for &w in GRAPH_WIDTHS.iter() {
            let secs = rt.profile_width(model, w, reps, 1, ExecMode::Resident)?;
            pts.push((w, secs));
        }
        curves.push(LatencyCurve::new(&pts));
    }
    let verifier = curves.pop().unwrap();
    let drafter_curve = curves.pop().unwrap();
    Ok(LatencyModel {
        drafter: drafter_curve,
        verifier,
        // Seeded with a small constant; replaced by the measured value
        // after the first calibration generation (see SpecDecoder).
        cpu_overhead: 2e-4,
    })
}

/// Loads the persisted profile or measures a fresh one.
pub fn load_or_profile(
    rt: &Runtime,
    drafter: &str,
    target: &str,
    profile_file: Option<&std::path::Path>,
    reps: usize,
) -> crate::Result<LatencyModel> {
    if let Some(path) = profile_file {
        // Profiles are stored per model pair.
        let keyed = keyed_path(path, drafter, target);
        if keyed.exists() {
            return LatencyModel::load(&keyed);
        }
    }
    let model = profile_latency_model(rt, drafter, target, reps)?;
    if let Some(path) = profile_file {
        let keyed = keyed_path(path, drafter, target);
        let _ = model.save(&keyed);
    }
    Ok(model)
}

/// `profile.json` → `profile.dft-xs.tgt-sm.json`.
pub fn keyed_path(base: &std::path::Path, drafter: &str, target: &str) -> std::path::PathBuf {
    let stem = base.file_stem().and_then(|s| s.to_str()).unwrap_or("profile");
    let ext = base.extension().and_then(|s| s.to_str()).unwrap_or("json");
    base.with_file_name(format!("{stem}.{drafter}.{target}.{ext}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keyed_path_inserts_pair() {
        let p = keyed_path(std::path::Path::new("a/profile.json"), "d", "t");
        assert_eq!(p, std::path::PathBuf::from("a/profile.d.t.json"));
    }

    #[test]
    fn profile_measures_monotone_ish_curves() {
        let dir = std::path::Path::new("artifacts");
        if !(dir.join("manifest.json").exists() && dir.join("dft-xs.weights.bin").exists() && dir.join("tgt-lg.weights.bin").exists()) {
            return;
        }
        let rt = Runtime::load(dir, &["dft-xs", "tgt-sm"]).unwrap();
        let m = profile_latency_model(&rt, "dft-xs", "tgt-sm", 2).unwrap();
        // Verifier is bigger than the drafter at every width.
        assert!(m.t_verify(1) > m.t_draft(1));
        // Latency grows from w=1 to w=64 (saturation on CPU).
        assert!(m.t_verify(64) > m.t_verify(1));
        // Persisted roundtrip.
        let p = std::env::temp_dir().join("ygg_profile_test.json");
        m.save(&p).unwrap();
        let back = LatencyModel::load(&p).unwrap();
        assert!((back.t_verify(8) - m.t_verify(8)).abs() < 1e-12);
    }
}
