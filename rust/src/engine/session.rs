//! Per-request session state: one KV cache + slot allocator per model side
//! (drafter and verifier), the committed token history, and prefill.
//!
//! Prefill processes `prompt[..P-1]` through **both** models in
//! width-padded chunks; the final prompt token becomes the first iteration's
//! tree root, so every decode iteration has a uniform shape (the root is
//! always a not-yet-evaluated token — see DESIGN.md §7).
//!
//! Sessions come in three cache-ownership flavours:
//!
//! * **Owned** ([`Session::new`]) — the session allocates its own device
//!   cache per model side and drops them with it (the single-request and
//!   round-robin serving mode).
//! * **Shared, equal partition** ([`Session::new_shared`] over an
//!   equal-layout [`SharedCachePool`]) — all sessions of one engine share
//!   a single device cache per side; each session leases a disjoint
//!   [`SlotRange`] and returns it on drop (DESIGN.md §9).
//! * **Shared, paged** ([`Session::new_shared`] over a paged pool) — the
//!   shared cache is a [`BlockPool`] of fixed-size blocks; the session's
//!   [`SlotCache`] leases blocks on demand as generation proceeds and
//!   returns them on rejection, completion, or disconnect (DESIGN.md
//!   §10). Capacity follows the token footprint instead of a per-session
//!   quota.
//!
//! Either shared flavour is what lets the batched scheduler pack many
//! sessions' tree tokens into one device call — same cache buffer,
//! block-diagonal masks.

use std::sync::{Arc, Mutex};

use crate::config::BatchConfig;
use crate::kvcache::{
    BlockPool, CacheConfigError, PrefixCache, PrefixCacheStats, SlotCache, SlotPartition,
    SlotRange,
};
use crate::runtime::{CacheId, ExecMode, ForwardReply, ForwardRequest, ModelSpec, Runtime};
use crate::sampling::XorShiftRng;

/// How a [`SharedCachePool`] carves its device caches into per-session
/// slot sets.
enum SharedLayout {
    /// Equal contiguous regions, leased and released whole (DESIGN.md §9).
    Equal { drafter: Mutex<SlotPartition>, target: Mutex<SlotPartition> },
    /// Fixed-size blocks leased on demand (DESIGN.md §10), optionally
    /// with the cross-request prefix cache layered on top (DESIGN.md
    /// §12; side 0 = drafter, side 1 = target).
    Paged {
        drafter: Arc<Mutex<BlockPool>>,
        target: Arc<Mutex<BlockPool>>,
        prefix: Option<Arc<Mutex<PrefixCache>>>,
    },
}

/// Shared device caches + slot bookkeeping backing cross-session batched
/// serving: one cache per model side, carved either into equal
/// per-session [`SlotRange`] regions (DESIGN.md §9) or into a paged
/// [`BlockPool`] leased block-by-block (DESIGN.md §10). Dropping the pool
/// frees the device caches; sessions must not outlive it (they hold an
/// [`Arc`]).
pub struct SharedCachePool {
    rt: Runtime,
    drafter_name: String,
    target_name: String,
    drafter_cache: CacheId,
    target_cache: CacheId,
    layout: SharedLayout,
}

impl SharedCachePool {
    /// Allocates one shared device cache per model side and prepares the
    /// layout `batch` asks for: a paged [`BlockPool`] per side when
    /// `batch.paged`, equal [`SlotPartition`]s for `batch.max_sessions`
    /// otherwise. Layout errors surface as typed
    /// [`crate::kvcache::CacheConfigError`]s — a startup/admission
    /// failure, never a panic on the serving worker thread.
    pub fn new(
        rt: &Runtime,
        drafter: &str,
        target: &str,
        batch: &BatchConfig,
    ) -> crate::Result<Self> {
        let dcap = rt.spec(drafter)?.cache_capacity;
        let tcap = rt.spec(target)?.cache_capacity;
        let layout = if batch.paged {
            let dpool = Arc::new(Mutex::new(BlockPool::new(
                dcap,
                batch.block_size,
                batch.cache_blocks,
            )?));
            let tpool = Arc::new(Mutex::new(BlockPool::new(
                tcap,
                batch.block_size,
                batch.cache_blocks,
            )?));
            // Cross-request prefix cache (DESIGN.md §12): one trie whose
            // nodes carry a (drafter, target) block pair, so both sides'
            // cached prompt K/V attach and evict in lockstep.
            let prefix = batch
                .prefix_cache
                .then(|| PrefixCache::new(vec![dpool.clone(), tpool.clone()]))
                .transpose()?
                .map(|pc| Arc::new(Mutex::new(pc)));
            SharedLayout::Paged { drafter: dpool, target: tpool, prefix }
        } else {
            SharedLayout::Equal {
                drafter: Mutex::new(SlotPartition::new(dcap, batch.max_sessions)?),
                target: Mutex::new(SlotPartition::new(tcap, batch.max_sessions)?),
            }
        };
        let drafter_cache = rt.new_cache(drafter)?;
        let target_cache = rt.new_cache(target)?;
        Ok(Self {
            rt: rt.clone(),
            drafter_name: drafter.to_string(),
            target_name: target.to_string(),
            drafter_cache,
            target_cache,
            layout,
        })
    }

    /// The shared drafter-side device cache.
    pub fn drafter_cache(&self) -> CacheId {
        self.drafter_cache
    }

    /// The shared verifier-side device cache.
    pub fn target_cache(&self) -> CacheId {
        self.target_cache
    }

    /// True when this pool leases fixed-size blocks on demand instead of
    /// equal per-session regions.
    pub fn is_paged(&self) -> bool {
        matches!(self.layout, SharedLayout::Paged { .. })
    }

    /// `(blocks in use, total blocks)` across both model sides in paged
    /// mode — the serving layer's block-occupancy gauge. `None` for the
    /// equal-partition layout.
    pub fn block_occupancy(&self) -> Option<(usize, usize)> {
        match &self.layout {
            SharedLayout::Paged { drafter, target, .. } => {
                let d = drafter.lock().unwrap();
                let t = target.lock().unwrap();
                Some((d.blocks_in_use() + t.blocks_in_use(), d.num_blocks() + t.num_blocks()))
            }
            SharedLayout::Equal { .. } => None,
        }
    }

    /// The cross-request prefix cache, when this pool runs the paged
    /// layout with prefix caching enabled (DESIGN.md §12).
    pub fn prefix(&self) -> Option<&Arc<Mutex<PrefixCache>>> {
        match &self.layout {
            SharedLayout::Paged { prefix, .. } => prefix.as_ref(),
            SharedLayout::Equal { .. } => None,
        }
    }

    /// Counters of the prefix cache (hit rate, reused tokens, evictions)
    /// for the serving layer's gauges; `None` without a prefix cache.
    pub fn prefix_stats(&self) -> Option<PrefixCacheStats> {
        self.prefix().map(|pc| pc.lock().unwrap().stats())
    }

    fn lease_pair(&self) -> Option<(SlotRange, SlotRange)> {
        let SharedLayout::Equal { drafter, target } = &self.layout else { return None };
        let d = drafter.lock().unwrap().lease()?;
        match target.lock().unwrap().lease() {
            Some(t) => Some((d, t)),
            None => {
                drafter.lock().unwrap().release(d);
                None
            }
        }
    }

    fn release_pair(&self, d: SlotRange, t: SlotRange) {
        if let SharedLayout::Equal { drafter, target } = &self.layout {
            drafter.lock().unwrap().release(d);
            target.lock().unwrap().release(t);
        }
    }
}

impl Drop for SharedCachePool {
    fn drop(&mut self) {
        self.rt.drop_cache(self.drafter_cache);
        self.rt.drop_cache(self.target_cache);
    }
}

/// One model's view of a session.
pub struct ModelSide {
    /// Model name in the artifact manifest.
    pub name: String,
    /// The model's architecture/capacity spec.
    pub spec: ModelSpec,
    /// Device cache this session's forward calls scatter into (owned, or
    /// the engine-shared cache in batched mode).
    pub cache: CacheId,
    /// Slot allocator over the cache (whole array, or a leased range).
    pub slots: SlotCache,
}

impl ModelSide {
    /// The trash-slot index of a `capacity`-slot cache, validated via the
    /// typed [`CacheConfigError`] path: a manifest declaring a 0- or
    /// 1-slot cache used to underflow `capacity - 1` (a debug-build
    /// panic on the serving worker) instead of surfacing a construction
    /// error.
    fn trash_for(capacity: usize) -> Result<u32, CacheConfigError> {
        if capacity < 2 {
            return Err(CacheConfigError::NoTrashSlot { capacity });
        }
        Ok(capacity as u32 - 1)
    }

    fn new(rt: &Runtime, name: &str) -> crate::Result<Self> {
        let spec = rt.spec(name)?.clone();
        Self::trash_for(spec.cache_capacity)?;
        let cache = rt.new_cache(name)?;
        Ok(Self {
            name: name.to_string(),
            spec: spec.clone(),
            cache,
            slots: SlotCache::new(spec.cache_capacity),
        })
    }

    /// A side over a shared cache: allocates only inside `range`, pads to
    /// the shared trash slot.
    fn with_shared(
        rt: &Runtime,
        name: &str,
        cache: CacheId,
        range: SlotRange,
    ) -> crate::Result<Self> {
        let spec = rt.spec(name)?.clone();
        let trash = Self::trash_for(spec.cache_capacity)?;
        Ok(Self {
            name: name.to_string(),
            spec: spec.clone(),
            cache,
            slots: SlotCache::with_range(range, spec.cache_capacity, trash),
        })
    }

    /// A side over a shared *paged* cache: leases blocks of `pool` on
    /// demand, pads to the pool's trash slot (DESIGN.md §10). With a
    /// prefix cache, a dry pool evicts unreferenced cached prompt blocks
    /// before an allocation fails (DESIGN.md §12).
    fn with_paged(
        rt: &Runtime,
        name: &str,
        cache: CacheId,
        pool: Arc<Mutex<BlockPool>>,
        prefix: Option<Arc<Mutex<PrefixCache>>>,
    ) -> crate::Result<Self> {
        let spec = rt.spec(name)?.clone();
        let slots = match prefix {
            Some(pc) => SlotCache::paged_with_prefix(pool, pc),
            None => SlotCache::paged(pool),
        };
        Ok(Self { name: name.to_string(), spec, cache, slots })
    }

    /// Builds a width-padded forward request for `n` real tokens. Padding
    /// rows use token 0 / position 0 / the trash slot / an all-zero mask
    /// row, so they cannot perturb real state.
    pub fn padded_request(
        &self,
        width: usize,
        tokens: &[u32],
        positions: &[i32],
        slots: &[u32],
        mask_rows: &[f32], // n * capacity, built by the caller
        mode: ExecMode,
    ) -> ForwardRequest {
        let n = tokens.len();
        debug_assert!(n <= width);
        let c = self.spec.cache_capacity;
        let trash = self.slots.trash_slot() as i32;
        let mut t: Vec<i32> = tokens.iter().map(|&x| x as i32).collect();
        let mut p: Vec<i32> = positions.to_vec();
        let mut s: Vec<i32> = slots.iter().map(|&x| x as i32).collect();
        t.resize(width, 0);
        p.resize(width, 0);
        s.resize(width, trash);
        let mut m = mask_rows.to_vec();
        m.resize(width * c, 0.0);
        ForwardRequest {
            model: self.name.clone(),
            width,
            cache: self.cache,
            tokens: t,
            positions: p,
            slots: s,
            mask: m,
            mode,
        }
    }
}

/// What a shared-cache session must give back (or merely keep alive) when
/// it drops.
enum SharedLease {
    /// Equal-partition ranges to return to the pool's partitions.
    Equal(Arc<SharedCachePool>, SlotRange, SlotRange),
    /// Paged mode: the session's `SlotCache`s return their own blocks on
    /// drop; the `Arc` only keeps the shared device caches alive.
    Paged(Arc<SharedCachePool>),
}

/// A generation session over a (drafter, verifier) pair.
pub struct Session {
    /// Handle to the device thread.
    pub rt: Runtime,
    /// Drafter-side cache + slots.
    pub drafter: ModelSide,
    /// Verifier-side cache + slots.
    pub target: ModelSide,
    /// All committed tokens: prompt then generated (the tree root — the
    /// latest bonus token — is `committed.last()`, not yet in any cache).
    pub committed: Vec<u32>,
    /// Length of the original prompt.
    pub prompt_len: usize,
    /// Per-session sampling RNG.
    pub rng: XorShiftRng,
    exec_mode: ExecMode,
    /// Leases to return on drop (shared-cache mode only).
    shared: Option<SharedLease>,
}

impl Session {
    /// A session owning its own device caches (single-session mode).
    pub fn new(
        rt: &Runtime,
        drafter: &str,
        target: &str,
        seed: u64,
        compiled: bool,
    ) -> crate::Result<Self> {
        Ok(Self {
            rt: rt.clone(),
            drafter: ModelSide::new(rt, drafter)?,
            target: ModelSide::new(rt, target)?,
            committed: Vec::new(),
            prompt_len: 0,
            rng: XorShiftRng::new(seed),
            exec_mode: if compiled { ExecMode::Resident } else { ExecMode::WeightsByValue },
            shared: None,
        })
    }

    /// A session over `pool`'s shared caches (batched serving mode).
    ///
    /// Equal-partition layout: leases one region per side up front and
    /// fails when every region is taken — the serving layer surfaces this
    /// as an admission rejection. Paged layout: opens with **zero**
    /// blocks and leases on demand as the generation actually needs slots
    /// (token-level admission happens against pool headroom instead).
    pub fn new_shared(
        rt: &Runtime,
        pool: &Arc<SharedCachePool>,
        seed: u64,
        compiled: bool,
    ) -> crate::Result<Self> {
        let (drafter, target, lease) = match &pool.layout {
            SharedLayout::Paged { drafter: dp, target: tp, prefix } => (
                ModelSide::with_paged(
                    rt,
                    &pool.drafter_name,
                    pool.drafter_cache,
                    dp.clone(),
                    prefix.clone(),
                )?,
                ModelSide::with_paged(
                    rt,
                    &pool.target_name,
                    pool.target_cache,
                    tp.clone(),
                    prefix.clone(),
                )?,
                SharedLease::Paged(Arc::clone(pool)),
            ),
            SharedLayout::Equal { .. } => {
                let (dr, tr) = pool.lease_pair().ok_or_else(|| {
                    anyhow::anyhow!("no free batch session region in the shared cache")
                })?;
                (
                    ModelSide::with_shared(rt, &pool.drafter_name, pool.drafter_cache, dr)?,
                    ModelSide::with_shared(rt, &pool.target_name, pool.target_cache, tr)?,
                    SharedLease::Equal(Arc::clone(pool), dr, tr),
                )
            }
        };
        Ok(Self {
            rt: rt.clone(),
            drafter,
            target,
            committed: Vec::new(),
            prompt_len: 0,
            rng: XorShiftRng::new(seed),
            exec_mode: if compiled { ExecMode::Resident } else { ExecMode::WeightsByValue },
            shared: Some(lease),
        })
    }

    /// How this session's forward calls treat weights/executables.
    pub fn exec_mode(&self) -> ExecMode {
        self.exec_mode
    }

    /// Number of committed tokens (the logical sequence position of the
    /// next tree root is `committed_len() - 1`).
    pub fn committed_len(&self) -> usize {
        self.committed.len()
    }

    /// Looks up the longest cached prefix of the *prefilled* prompt body
    /// (`prompt[..P-1]`) in the cross-request prefix cache and maps its
    /// blocks read-shared into both sides' block tables (refcounts
    /// bumped; DESIGN.md §12). [`Session::prefill`] then starts at the
    /// first uncached token. Returns the number of reused tokens — 0
    /// outside the paged+prefix layout, and for prompts shorter than one
    /// block.
    pub fn attach_prefix(&mut self, prompt: &[u32]) -> usize {
        if prompt.len() < 2 {
            return 0;
        }
        let Some(SharedLease::Paged(pool)) = &self.shared else { return 0 };
        let Some(pc) = pool.prefix() else { return 0 };
        let body = &prompt[..prompt.len() - 1];
        let hit = pc.lock().unwrap().acquire(body);
        if hit.tokens == 0 {
            return 0;
        }
        self.drafter.slots.attach_prefix(&hit.blocks[0]);
        self.target.slots.attach_prefix(&hit.blocks[1]);
        hit.tokens
    }

    /// Prefills `prompt[..P-1]` into both caches and seeds `committed`
    /// with the whole prompt. When a cached prefix was attached
    /// ([`Session::attach_prefix`]), each side resumes at its first
    /// uncached token instead of token zero. Returns the verifier reply
    /// of the last prefill chunk (its hidden state seeds the depth
    /// predictor); `None` when the whole body came from cache.
    pub fn prefill(&mut self, prompt: &[u32]) -> crate::Result<Option<ForwardReply>> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        anyhow::ensure!(self.committed.is_empty(), "session already prefilled");
        self.committed = prompt.to_vec();
        self.prompt_len = prompt.len();
        let body = &prompt[..prompt.len() - 1];
        let rt = self.rt.clone();
        let mode = self.exec_mode;
        prefill_side(&rt, &mut self.drafter, body, mode)?;
        prefill_side(&rt, &mut self.target, body, mode)
    }

    /// Chunked prefill (DESIGN.md §14): advances each side's prefill by
    /// at most `limit` tokens and returns `(done, reply)`. The first call
    /// seeds `committed` with the whole prompt (like [`Session::prefill`]);
    /// each later call resumes from the sides' committed slot counts —
    /// the same resume point preemption and cached-prefix attach use —
    /// so a cold prompt interleaves with warm sessions one chunk per
    /// scheduling round instead of stalling the wave. `done` turns true
    /// once both sides committed the whole body `prompt[..P-1]`; `reply`
    /// is the verifier reply of the last chunk this call ran (`None`
    /// when the verifier side had nothing left to prefill).
    pub fn prefill_chunk(
        &mut self,
        prompt: &[u32],
        limit: usize,
    ) -> crate::Result<(bool, Option<ForwardReply>)> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        anyhow::ensure!(limit > 0, "prefill chunk must be > 0");
        if self.committed.is_empty() {
            self.committed = prompt.to_vec();
            self.prompt_len = prompt.len();
        } else {
            anyhow::ensure!(
                self.prompt_len == prompt.len() && self.committed.len() == prompt.len(),
                "prefill_chunk resumed with a different prompt"
            );
        }
        let body = &prompt[..prompt.len() - 1];
        let rt = self.rt.clone();
        let mode = self.exec_mode;
        prefill_side_capped(&rt, &mut self.drafter, body, limit, mode)?;
        let reply = prefill_side_capped(&rt, &mut self.target, body, limit, mode)?;
        let done = self.drafter.slots.committed_len() >= body.len()
            && self.target.slots.committed_len() >= body.len();
        Ok((done, reply))
    }

    /// Prompt tokens both sides hold committed before any prefill call —
    /// the cached-prefix resume point (0 without an attached prefix).
    pub fn attached_prefix_len(&self) -> usize {
        self.drafter.slots.committed_len().min(self.target.slots.committed_len())
    }

    /// Counts this session's consumed prefix reuse into the cache's
    /// hit-rate gauges. Called once by the task's prefill step — i.e.
    /// only for *admitted* sessions — so rejected or parked admission
    /// probes (whose acquired references release unused) never inflate
    /// the stats. No-op outside the paged+prefix layout.
    pub fn record_prefix_reuse(&self) {
        let Some(SharedLease::Paged(pool)) = &self.shared else { return };
        let Some(pc) = pool.prefix() else { return };
        pc.lock().unwrap().record_reuse(self.attached_prefix_len());
    }

    /// Remaining generation headroom given a per-iteration tree budget.
    /// In paged mode this counts the shared pool's free blocks, so it is
    /// the token-level admission signal: the pool either covers prompt +
    /// tree budget or it does not.
    pub fn headroom(&self, tree_budget: usize) -> usize {
        self.drafter
            .slots
            .headroom(tree_budget)
            .min(self.target.slots.headroom(tree_budget))
    }

    /// True when this session leases blocks of a shared paged pool — the
    /// mode whose mid-flight allocation failures are preemptible rather
    /// than terminal.
    pub fn is_paged(&self) -> bool {
        self.drafter.slots.is_paged()
    }

    /// The most tokens this session could ever hold per side even owning
    /// every block — the absolute generation ceiling paged tasks stop at
    /// (pool-wide *current* headroom is transient under contention, so it
    /// must not be a stop condition).
    pub fn lease_limit(&self) -> usize {
        self.drafter.slots.lease_limit().min(self.target.slots.lease_limit())
    }
}

/// Streams `body` through one model side in width-padded chunks. The
/// side's already-committed slot count is the resume point: an attached
/// cached prefix (DESIGN.md §12) covers tokens `0..committed_len`, so
/// prefill starts there — positions continue the sequence, and the mask's
/// prefix row already names the shared slots.
fn prefill_side(
    rt: &Runtime,
    side: &mut ModelSide,
    body: &[u32],
    mode: ExecMode,
) -> crate::Result<Option<ForwardReply>> {
    prefill_side_capped(rt, side, body, usize::MAX, mode)
}

/// [`prefill_side`] advancing at most `limit` tokens past the side's
/// committed resume point — the per-round unit of chunked prefill
/// (DESIGN.md §14). Tokens already committed (prior chunks, or an
/// attached cached prefix) never re-run.
fn prefill_side_capped(
    rt: &Runtime,
    side: &mut ModelSide,
    body: &[u32],
    limit: usize,
    mode: ExecMode,
) -> crate::Result<Option<ForwardReply>> {
    let mut pos = side.slots.committed_len();
    let end = body.len().min(pos.saturating_add(limit));
    let mut reply = None;
    while pos < end {
        let n = (end - pos).min(64);
        let width = crate::config::width_for(n).unwrap();
        let chunk = &body[pos..pos + n];
        let slots = side
            .slots
            .alloc(n)
            // Typed in paged mode: the serving layer preempts + requeues
            // instead of failing the request.
            .ok_or_else(|| side.slots.exhausted("prefill"))?;
        let positions: Vec<i32> = (pos as i32..(pos + n) as i32).collect();
        let mask = side.slots.mask_builder().build_linear(&slots, n, width).to_vec();
        let req = side.padded_request(width, chunk, &positions, &slots, &mask, mode);
        reply = Some(rt.forward(req)?);
        for &s in &slots {
            side.slots.commit(s);
        }
        pos += n;
    }
    Ok(reply)
}

impl Drop for Session {
    fn drop(&mut self) {
        // Prefix-cache insertion (DESIGN.md §12): completion, disconnect
        // and preemption all land here. Fully-committed prompt blocks are
        // donated to the trie instead of freed — committed slot j holds
        // token committed[j] on both sides, so the trie is keyed by the
        // exact token prefix. A preempted session's resumed incarnation
        // re-prefills the same context and hits these blocks immediately.
        if let Some(SharedLease::Paged(pool)) = &self.shared {
            if let Some(pc) = pool.prefix() {
                let n = self
                    .drafter
                    .slots
                    .committed_len()
                    .min(self.target.slots.committed_len())
                    .min(self.committed.len());
                if n > 0 {
                    let tokens = self.committed[..n].to_vec();
                    pc.lock().unwrap().insert(
                        &tokens,
                        &mut [&mut self.drafter.slots, &mut self.target.slots],
                    );
                }
            }
        }
        match self.shared.take() {
            // Shared caches outlive the session: just return the leases
            // (stale K/V stays in the buffer but no mask can see it).
            Some(SharedLease::Equal(pool, dr, tr)) => pool.release_pair(dr, tr),
            // Paged: each side's SlotCache returns its own blocks when it
            // drops right after this; the Arc kept the device caches
            // alive until now.
            Some(SharedLease::Paged(_pool)) => {}
            None => {
                self.rt.drop_cache(self.drafter.cache);
                self.rt.drop_cache(self.target.cache);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn runtime() -> Option<Runtime> {
        let dir = Path::new("artifacts");
        (dir.join("manifest.json").exists()
            && dir.join("dft-xs.weights.bin").exists()
            && dir.join("tgt-sm.weights.bin").exists())
        .then(|| Runtime::load(dir, &["tgt-sm", "dft-xs"]).unwrap())
    }

    #[test]
    fn degenerate_cache_capacity_is_a_typed_error_not_an_underflow() {
        // `capacity - 1` on a 0-slot cache used to underflow (debug
        // panic on the serving worker); it must be a CacheConfigError.
        assert_eq!(
            ModelSide::trash_for(0).unwrap_err(),
            CacheConfigError::NoTrashSlot { capacity: 0 }
        );
        assert!(ModelSide::trash_for(1).is_err());
        assert_eq!(ModelSide::trash_for(2).unwrap(), 1);
    }

    #[test]
    fn prefill_commits_prompt_minus_one() {
        let Some(rt) = runtime() else { return };
        let mut s = Session::new(&rt, "dft-xs", "tgt-sm", 0, true).unwrap();
        let prompt: Vec<u32> = (1..=9).collect();
        let reply = s.prefill(&prompt).unwrap().unwrap();
        assert_eq!(s.committed_len(), 9);
        // prompt[..8] prefilled => 8 slots committed on each side.
        assert_eq!(s.drafter.slots.committed_len(), 8);
        assert_eq!(s.target.slots.committed_len(), 8);
        assert!(reply.logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn prefill_chunks_long_prompts() {
        let Some(rt) = runtime() else { return };
        let mut s = Session::new(&rt, "dft-xs", "tgt-sm", 0, true).unwrap();
        let prompt: Vec<u32> = (0..100).map(|i| (i % 50) as u32).collect();
        s.prefill(&prompt).unwrap();
        assert_eq!(s.target.slots.committed_len(), 99);
    }

    #[test]
    fn prefill_chunk_matches_one_shot_commit_counts() {
        let Some(rt) = runtime() else { return };
        let mut s = Session::new(&rt, "dft-xs", "tgt-sm", 0, true).unwrap();
        let prompt: Vec<u32> = (0..30).map(|i| (i % 11) as u32).collect();
        let mut rounds = 0usize;
        loop {
            let (done, _) = s.prefill_chunk(&prompt, 7).unwrap();
            rounds += 1;
            if done {
                break;
            }
        }
        assert_eq!(rounds, 29usize.div_ceil(7), "29-token body at 7/chunk");
        assert_eq!(s.committed_len(), 30);
        assert_eq!(s.drafter.slots.committed_len(), 29);
        assert_eq!(s.target.slots.committed_len(), 29);
        // Re-stepping a finished prefill is a done no-op.
        let (done, reply) = s.prefill_chunk(&prompt, 7).unwrap();
        assert!(done && reply.is_none());
    }

    #[test]
    fn padded_request_is_inert_in_padding() {
        let Some(rt) = runtime() else { return };
        let s = Session::new(&rt, "dft-xs", "tgt-sm", 0, true).unwrap();
        let c = s.drafter.spec.cache_capacity;
        let req = s.drafter.padded_request(
            4,
            &[5],
            &[0],
            &[3],
            &vec![1.0; c][..].to_vec(),
            ExecMode::Resident,
        );
        assert_eq!(req.tokens, vec![5, 0, 0, 0]);
        assert_eq!(req.slots[1], s.drafter.slots.trash_slot() as i32);
        assert!(req.mask[c..].iter().all(|&x| x == 0.0));
    }
}
