//! Per-request session state: one KV cache + slot allocator per model side
//! (drafter and verifier), the committed token history, and prefill.
//!
//! Prefill processes `prompt[..P-1]` through **both** models in
//! width-padded chunks; the final prompt token becomes the first iteration's
//! tree root, so every decode iteration has a uniform shape (the root is
//! always a not-yet-evaluated token — see DESIGN.md §7).
//!
//! Sessions come in two cache-ownership flavours:
//!
//! * **Owned** ([`Session::new`]) — the session allocates its own device
//!   cache per model side and drops them with it (the single-request and
//!   round-robin serving mode).
//! * **Shared** ([`Session::new_shared`]) — all sessions of one engine
//!   share a single device cache per side ([`SharedCachePool`]); each
//!   session leases a disjoint [`SlotRange`] and returns it on drop.
//!   This is what lets the batched scheduler pack many sessions' tree
//!   tokens into one device call (DESIGN.md §9) — same cache buffer,
//!   block-diagonal masks.

use std::sync::{Arc, Mutex};

use crate::kvcache::{SlotCache, SlotPartition, SlotRange};
use crate::runtime::{CacheId, ExecMode, ForwardReply, ForwardRequest, ModelSpec, Runtime};
use crate::sampling::XorShiftRng;

/// Shared device caches + slot partitions backing cross-session batched
/// serving: one cache per model side, carved into equal per-session
/// [`SlotRange`] regions (DESIGN.md §9). Dropping the pool frees the
/// device caches; sessions must not outlive it (they hold an [`Arc`]).
pub struct SharedCachePool {
    rt: Runtime,
    drafter_name: String,
    target_name: String,
    drafter_cache: CacheId,
    target_cache: CacheId,
    drafter_part: Mutex<SlotPartition>,
    target_part: Mutex<SlotPartition>,
}

impl SharedCachePool {
    /// Allocates one shared device cache per model side and partitions
    /// each for `sessions` concurrent sessions.
    pub fn new(
        rt: &Runtime,
        drafter: &str,
        target: &str,
        sessions: usize,
    ) -> crate::Result<Self> {
        let dspec = rt.spec(drafter)?.clone();
        let tspec = rt.spec(target)?.clone();
        // Validate before SlotPartition's programmer-contract assert: a
        // misconfigured session count must surface as a per-request
        // admission error, not a panic on the serving worker thread.
        let min_cap = dspec.cache_capacity.min(tspec.cache_capacity);
        anyhow::ensure!(
            sessions >= 1 && min_cap.saturating_sub(1) / sessions >= 2,
            "cache capacity {min_cap} cannot host {sessions} batched sessions \
             (each needs ≥ 2 slots)"
        );
        let drafter_cache = rt.new_cache(drafter)?;
        let target_cache = rt.new_cache(target)?;
        Ok(Self {
            rt: rt.clone(),
            drafter_name: drafter.to_string(),
            target_name: target.to_string(),
            drafter_cache,
            target_cache,
            drafter_part: Mutex::new(SlotPartition::new(dspec.cache_capacity, sessions)),
            target_part: Mutex::new(SlotPartition::new(tspec.cache_capacity, sessions)),
        })
    }

    /// The shared drafter-side device cache.
    pub fn drafter_cache(&self) -> CacheId {
        self.drafter_cache
    }

    /// The shared verifier-side device cache.
    pub fn target_cache(&self) -> CacheId {
        self.target_cache
    }

    /// Per-session slot quota on (drafter, target) — sizes the largest
    /// tree envelope a batched session can run.
    pub fn session_quota(&self) -> (usize, usize) {
        (
            self.drafter_part.lock().unwrap().region_len() as usize,
            self.target_part.lock().unwrap().region_len() as usize,
        )
    }

    /// Session regions still leasable (the admission-control signal).
    pub fn free_sessions(&self) -> usize {
        self.drafter_part
            .lock()
            .unwrap()
            .free_regions()
            .min(self.target_part.lock().unwrap().free_regions())
    }

    fn lease_pair(&self) -> Option<(SlotRange, SlotRange)> {
        let d = self.drafter_part.lock().unwrap().lease()?;
        match self.target_part.lock().unwrap().lease() {
            Some(t) => Some((d, t)),
            None => {
                self.drafter_part.lock().unwrap().release(d);
                None
            }
        }
    }

    fn release_pair(&self, d: SlotRange, t: SlotRange) {
        self.drafter_part.lock().unwrap().release(d);
        self.target_part.lock().unwrap().release(t);
    }
}

impl Drop for SharedCachePool {
    fn drop(&mut self) {
        self.rt.drop_cache(self.drafter_cache);
        self.rt.drop_cache(self.target_cache);
    }
}

/// One model's view of a session.
pub struct ModelSide {
    /// Model name in the artifact manifest.
    pub name: String,
    /// The model's architecture/capacity spec.
    pub spec: ModelSpec,
    /// Device cache this session's forward calls scatter into (owned, or
    /// the engine-shared cache in batched mode).
    pub cache: CacheId,
    /// Slot allocator over the cache (whole array, or a leased range).
    pub slots: SlotCache,
}

impl ModelSide {
    fn new(rt: &Runtime, name: &str) -> crate::Result<Self> {
        let spec = rt.spec(name)?.clone();
        let cache = rt.new_cache(name)?;
        Ok(Self {
            name: name.to_string(),
            spec: spec.clone(),
            cache,
            slots: SlotCache::new(spec.cache_capacity),
        })
    }

    /// A side over a shared cache: allocates only inside `range`, pads to
    /// the shared trash slot.
    fn with_shared(
        rt: &Runtime,
        name: &str,
        cache: CacheId,
        range: SlotRange,
    ) -> crate::Result<Self> {
        let spec = rt.spec(name)?.clone();
        let trash = spec.cache_capacity as u32 - 1;
        Ok(Self {
            name: name.to_string(),
            spec: spec.clone(),
            cache,
            slots: SlotCache::with_range(range, spec.cache_capacity, trash),
        })
    }

    /// Builds a width-padded forward request for `n` real tokens. Padding
    /// rows use token 0 / position 0 / the trash slot / an all-zero mask
    /// row, so they cannot perturb real state.
    pub fn padded_request(
        &self,
        width: usize,
        tokens: &[u32],
        positions: &[i32],
        slots: &[u32],
        mask_rows: &[f32], // n * capacity, built by the caller
        mode: ExecMode,
    ) -> ForwardRequest {
        let n = tokens.len();
        debug_assert!(n <= width);
        let c = self.spec.cache_capacity;
        let trash = self.slots.trash_slot() as i32;
        let mut t: Vec<i32> = tokens.iter().map(|&x| x as i32).collect();
        let mut p: Vec<i32> = positions.to_vec();
        let mut s: Vec<i32> = slots.iter().map(|&x| x as i32).collect();
        t.resize(width, 0);
        p.resize(width, 0);
        s.resize(width, trash);
        let mut m = mask_rows.to_vec();
        m.resize(width * c, 0.0);
        ForwardRequest {
            model: self.name.clone(),
            width,
            cache: self.cache,
            tokens: t,
            positions: p,
            slots: s,
            mask: m,
            mode,
        }
    }
}

/// A generation session over a (drafter, verifier) pair.
pub struct Session {
    /// Handle to the device thread.
    pub rt: Runtime,
    /// Drafter-side cache + slots.
    pub drafter: ModelSide,
    /// Verifier-side cache + slots.
    pub target: ModelSide,
    /// All committed tokens: prompt then generated (the tree root — the
    /// latest bonus token — is `committed.last()`, not yet in any cache).
    pub committed: Vec<u32>,
    /// Length of the original prompt.
    pub prompt_len: usize,
    /// Per-session sampling RNG.
    pub rng: XorShiftRng,
    exec_mode: ExecMode,
    /// Leases to return on drop (shared-cache mode only).
    shared: Option<(Arc<SharedCachePool>, SlotRange, SlotRange)>,
}

impl Session {
    /// A session owning its own device caches (single-session mode).
    pub fn new(
        rt: &Runtime,
        drafter: &str,
        target: &str,
        seed: u64,
        compiled: bool,
    ) -> crate::Result<Self> {
        Ok(Self {
            rt: rt.clone(),
            drafter: ModelSide::new(rt, drafter)?,
            target: ModelSide::new(rt, target)?,
            committed: Vec::new(),
            prompt_len: 0,
            rng: XorShiftRng::new(seed),
            exec_mode: if compiled { ExecMode::Resident } else { ExecMode::WeightsByValue },
            shared: None,
        })
    }

    /// A session leasing slot ranges of `pool`'s shared caches (batched
    /// serving mode). Fails when every session region is leased — the
    /// serving layer surfaces this as an admission rejection.
    pub fn new_shared(
        rt: &Runtime,
        pool: &Arc<SharedCachePool>,
        seed: u64,
        compiled: bool,
    ) -> crate::Result<Self> {
        let (dr, tr) = pool
            .lease_pair()
            .ok_or_else(|| anyhow::anyhow!("no free batch session region in the shared cache"))?;
        let drafter = ModelSide::with_shared(rt, &pool.drafter_name, pool.drafter_cache, dr)?;
        let target = ModelSide::with_shared(rt, &pool.target_name, pool.target_cache, tr)?;
        Ok(Self {
            rt: rt.clone(),
            drafter,
            target,
            committed: Vec::new(),
            prompt_len: 0,
            rng: XorShiftRng::new(seed),
            exec_mode: if compiled { ExecMode::Resident } else { ExecMode::WeightsByValue },
            shared: Some((Arc::clone(pool), dr, tr)),
        })
    }

    /// How this session's forward calls treat weights/executables.
    pub fn exec_mode(&self) -> ExecMode {
        self.exec_mode
    }

    /// Number of committed tokens (the logical sequence position of the
    /// next tree root is `committed_len() - 1`).
    pub fn committed_len(&self) -> usize {
        self.committed.len()
    }

    /// Prefills `prompt[..P-1]` into both caches and seeds `committed`
    /// with the whole prompt. Returns the verifier reply of the last
    /// prefill chunk (its hidden state seeds the depth predictor).
    pub fn prefill(&mut self, prompt: &[u32]) -> crate::Result<Option<ForwardReply>> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        anyhow::ensure!(self.committed.is_empty(), "session already prefilled");
        self.committed = prompt.to_vec();
        self.prompt_len = prompt.len();
        let body = &prompt[..prompt.len() - 1];
        let rt = self.rt.clone();
        let mode = self.exec_mode;
        prefill_side(&rt, &mut self.drafter, body, mode)?;
        prefill_side(&rt, &mut self.target, body, mode)
    }

    /// Remaining generation headroom given a per-iteration tree budget.
    pub fn headroom(&self, tree_budget: usize) -> usize {
        self.drafter
            .slots
            .headroom(tree_budget)
            .min(self.target.slots.headroom(tree_budget))
    }
}

/// Streams `body` through one model side in width-padded chunks.
fn prefill_side(
    rt: &Runtime,
    side: &mut ModelSide,
    body: &[u32],
    mode: ExecMode,
) -> crate::Result<Option<ForwardReply>> {
    let mut pos = 0usize;
    let mut reply = None;
    while pos < body.len() {
        let n = (body.len() - pos).min(64);
        let width = crate::config::width_for(n).unwrap();
        let chunk = &body[pos..pos + n];
        let slots = side
            .slots
            .alloc(n)
            .ok_or_else(|| anyhow::anyhow!("cache exhausted during prefill"))?;
        let positions: Vec<i32> = (pos as i32..(pos + n) as i32).collect();
        let mask = side.slots.mask_builder().build_linear(&slots, n, width).to_vec();
        let req = side.padded_request(width, chunk, &positions, &slots, &mask, mode);
        reply = Some(rt.forward(req)?);
        for &s in &slots {
            side.slots.commit(s);
        }
        pos += n;
    }
    Ok(reply)
}

impl Drop for Session {
    fn drop(&mut self) {
        match self.shared.take() {
            // Shared caches outlive the session: just return the leases
            // (stale K/V stays in the buffer but no mask can see it).
            Some((pool, dr, tr)) => pool.release_pair(dr, tr),
            None => {
                self.rt.drop_cache(self.drafter.cache);
                self.rt.drop_cache(self.target.cache);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn runtime() -> Option<Runtime> {
        let dir = Path::new("artifacts");
        (dir.join("manifest.json").exists()
            && dir.join("dft-xs.weights.bin").exists()
            && dir.join("tgt-sm.weights.bin").exists())
        .then(|| Runtime::load(dir, &["tgt-sm", "dft-xs"]).unwrap())
    }

    #[test]
    fn prefill_commits_prompt_minus_one() {
        let Some(rt) = runtime() else { return };
        let mut s = Session::new(&rt, "dft-xs", "tgt-sm", 0, true).unwrap();
        let prompt: Vec<u32> = (1..=9).collect();
        let reply = s.prefill(&prompt).unwrap().unwrap();
        assert_eq!(s.committed_len(), 9);
        // prompt[..8] prefilled => 8 slots committed on each side.
        assert_eq!(s.drafter.slots.committed_len(), 8);
        assert_eq!(s.target.slots.committed_len(), 8);
        assert!(reply.logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn prefill_chunks_long_prompts() {
        let Some(rt) = runtime() else { return };
        let mut s = Session::new(&rt, "dft-xs", "tgt-sm", 0, true).unwrap();
        let prompt: Vec<u32> = (0..100).map(|i| (i % 50) as u32).collect();
        s.prefill(&prompt).unwrap();
        assert_eq!(s.target.slots.committed_len(), 99);
    }

    #[test]
    fn padded_request_is_inert_in_padding() {
        let Some(rt) = runtime() else { return };
        let s = Session::new(&rt, "dft-xs", "tgt-sm", 0, true).unwrap();
        let c = s.drafter.spec.cache_capacity;
        let req = s.drafter.padded_request(
            4,
            &[5],
            &[0],
            &[3],
            &vec![1.0; c][..].to_vec(),
            ExecMode::Resident,
        );
        assert_eq!(req.tokens, vec![5, 0, 0, 0]);
        assert_eq!(req.slots[1], s.drafter.slots.trash_slot() as i32);
        assert!(req.mask[c..].iter().all(|&x| x == 0.0));
    }
}
