//! Per-request session state: one KV cache + slot allocator per model side
//! (drafter and verifier), the committed token history, and prefill.
//!
//! Prefill processes `prompt[..P-1]` through **both** models in
//! width-padded chunks; the final prompt token becomes the first iteration's
//! tree root, so every decode iteration has a uniform shape (the root is
//! always a not-yet-evaluated token — see DESIGN.md §7).

use crate::kvcache::SlotCache;
use crate::runtime::{CacheId, ExecMode, ForwardReply, ForwardRequest, ModelSpec, Runtime};
use crate::sampling::XorShiftRng;

/// One model's view of a session.
pub struct ModelSide {
    pub name: String,
    pub spec: ModelSpec,
    pub cache: CacheId,
    pub slots: SlotCache,
}

impl ModelSide {
    fn new(rt: &Runtime, name: &str) -> crate::Result<Self> {
        let spec = rt.spec(name)?.clone();
        let cache = rt.new_cache(name)?;
        Ok(Self {
            name: name.to_string(),
            spec: spec.clone(),
            cache,
            slots: SlotCache::new(spec.cache_capacity),
        })
    }

    /// Builds a width-padded forward request for `n` real tokens. Padding
    /// rows use token 0 / position 0 / the trash slot / an all-zero mask
    /// row, so they cannot perturb real state.
    pub fn padded_request(
        &self,
        width: usize,
        tokens: &[u32],
        positions: &[i32],
        slots: &[u32],
        mask_rows: &[f32], // n * capacity, built by the caller
        mode: ExecMode,
    ) -> ForwardRequest {
        let n = tokens.len();
        debug_assert!(n <= width);
        let c = self.spec.cache_capacity;
        let trash = self.slots.trash_slot() as i32;
        let mut t: Vec<i32> = tokens.iter().map(|&x| x as i32).collect();
        let mut p: Vec<i32> = positions.to_vec();
        let mut s: Vec<i32> = slots.iter().map(|&x| x as i32).collect();
        t.resize(width, 0);
        p.resize(width, 0);
        s.resize(width, trash);
        let mut m = mask_rows.to_vec();
        m.resize(width * c, 0.0);
        ForwardRequest {
            model: self.name.clone(),
            width,
            cache: self.cache,
            tokens: t,
            positions: p,
            slots: s,
            mask: m,
            mode,
        }
    }
}

/// A generation session over a (drafter, verifier) pair.
pub struct Session {
    pub rt: Runtime,
    pub drafter: ModelSide,
    pub target: ModelSide,
    /// All committed tokens: prompt then generated (the tree root — the
    /// latest bonus token — is `committed.last()`, not yet in any cache).
    pub committed: Vec<u32>,
    pub prompt_len: usize,
    pub rng: XorShiftRng,
    exec_mode: ExecMode,
}

impl Session {
    pub fn new(
        rt: &Runtime,
        drafter: &str,
        target: &str,
        seed: u64,
        compiled: bool,
    ) -> crate::Result<Self> {
        Ok(Self {
            rt: rt.clone(),
            drafter: ModelSide::new(rt, drafter)?,
            target: ModelSide::new(rt, target)?,
            committed: Vec::new(),
            prompt_len: 0,
            rng: XorShiftRng::new(seed),
            exec_mode: if compiled { ExecMode::Resident } else { ExecMode::WeightsByValue },
        })
    }

    pub fn exec_mode(&self) -> ExecMode {
        self.exec_mode
    }

    /// Number of committed tokens (the logical sequence position of the
    /// next tree root is `committed_len() - 1`).
    pub fn committed_len(&self) -> usize {
        self.committed.len()
    }

    /// Prefills `prompt[..P-1]` into both caches and seeds `committed`
    /// with the whole prompt. Returns the verifier reply of the last
    /// prefill chunk (its hidden state seeds the depth predictor).
    pub fn prefill(&mut self, prompt: &[u32]) -> crate::Result<Option<ForwardReply>> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        anyhow::ensure!(self.committed.is_empty(), "session already prefilled");
        self.committed = prompt.to_vec();
        self.prompt_len = prompt.len();
        let body = &prompt[..prompt.len() - 1];
        let rt = self.rt.clone();
        let mode = self.exec_mode;
        prefill_side(&rt, &mut self.drafter, body, mode)?;
        prefill_side(&rt, &mut self.target, body, mode)
    }

    /// Remaining generation headroom given a per-iteration tree budget.
    pub fn headroom(&self, tree_budget: usize) -> usize {
        self.drafter
            .slots
            .headroom(tree_budget)
            .min(self.target.slots.headroom(tree_budget))
    }
}

/// Streams `body` through one model side in width-padded chunks.
fn prefill_side(
    rt: &Runtime,
    side: &mut ModelSide,
    body: &[u32],
    mode: ExecMode,
) -> crate::Result<Option<ForwardReply>> {
    let mut pos = 0usize;
    let mut reply = None;
    while pos < body.len() {
        let n = (body.len() - pos).min(64);
        let width = crate::config::width_for(n).unwrap();
        let chunk = &body[pos..pos + n];
        let slots = side
            .slots
            .alloc(n)
            .ok_or_else(|| anyhow::anyhow!("cache exhausted during prefill"))?;
        let positions: Vec<i32> = (pos as i32..(pos + n) as i32).collect();
        let mask = side.slots.mask_builder().build_linear(&slots, n, width).to_vec();
        let req = side.padded_request(width, chunk, &positions, &slots, &mask, mode);
        reply = Some(rt.forward(req)?);
        for &s in &slots {
            side.slots.commit(s);
        }
        pos += n;
    }
    Ok(reply)
}

impl Drop for Session {
    fn drop(&mut self) {
        self.rt.drop_cache(self.drafter.cache);
        self.rt.drop_cache(self.target.cache);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn runtime() -> Option<Runtime> {
        let dir = Path::new("artifacts");
        (dir.join("manifest.json").exists()
            && dir.join("dft-xs.weights.bin").exists()
            && dir.join("tgt-sm.weights.bin").exists())
        .then(|| Runtime::load(dir, &["tgt-sm", "dft-xs"]).unwrap())
    }

    #[test]
    fn prefill_commits_prompt_minus_one() {
        let Some(rt) = runtime() else { return };
        let mut s = Session::new(&rt, "dft-xs", "tgt-sm", 0, true).unwrap();
        let prompt: Vec<u32> = (1..=9).collect();
        let reply = s.prefill(&prompt).unwrap().unwrap();
        assert_eq!(s.committed_len(), 9);
        // prompt[..8] prefilled => 8 slots committed on each side.
        assert_eq!(s.drafter.slots.committed_len(), 8);
        assert_eq!(s.target.slots.committed_len(), 8);
        assert!(reply.logits.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn prefill_chunks_long_prompts() {
        let Some(rt) = runtime() else { return };
        let mut s = Session::new(&rt, "dft-xs", "tgt-sm", 0, true).unwrap();
        let prompt: Vec<u32> = (0..100).map(|i| (i % 50) as u32).collect();
        s.prefill(&prompt).unwrap();
        assert_eq!(s.target.slots.committed_len(), 99);
    }

    #[test]
    fn padded_request_is_inert_in_padding() {
        let Some(rt) = runtime() else { return };
        let s = Session::new(&rt, "dft-xs", "tgt-sm", 0, true).unwrap();
        let c = s.drafter.spec.cache_capacity;
        let req = s.drafter.padded_request(
            4,
            &[5],
            &[0],
            &[3],
            &vec![1.0; c][..].to_vec(),
            ExecMode::Resident,
        );
        assert_eq!(req.tokens, vec![5, 0, 0, 0]);
        assert_eq!(req.slots[1], s.drafter.slots.trash_slot() as i32);
        assert!(req.mask[c..].iter().all(|&x| x == 0.0));
    }
}
