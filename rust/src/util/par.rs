//! Scoped-thread fan-out for the per-session CPU stages of a batched
//! round (`--cpu-threads`). The build is fully offline (no rayon, see
//! Cargo.toml), so this is the rayon-shaped substitute:
//! `std::thread::scope` gives the same fork-join structure over borrowed
//! inputs with deterministic, order-preserving output.

/// Resolves a `--cpu-threads` request: `0` means auto (the machine's
/// available parallelism), anything else is taken literally. `1` is the
/// serial default.
pub fn effective_threads(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        requested
    }
}

/// Maps `f` over `items` on up to `threads` OS threads (contiguous block
/// partition, output order matches input order). With `threads <= 1` or
/// fewer than two items the map runs inline on the caller thread — the
/// serial path spawns nothing and allocates only the output Vec.
///
/// A worker panic propagates to the caller (the scope joins all threads
/// first), so a panicking `f` cannot silently drop items.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if threads <= 1 || n <= 1 {
        return items.iter().map(&f).collect();
    }
    let workers = threads.min(n);
    let chunk = n.div_ceil(workers);
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let fr = &f;
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(workers);
        for (ci, chunk_items) in items.chunks(chunk).enumerate() {
            handles.push(s.spawn(move || {
                let mut res = Vec::with_capacity(chunk_items.len());
                for t in chunk_items {
                    res.push(fr(t));
                }
                (ci, res)
            }));
        }
        for h in handles {
            let (ci, res) = h.join().expect("parallel_map worker panicked");
            for (j, r) in res.into_iter().enumerate() {
                out[ci * chunk + j] = Some(r);
            }
        }
    });
    out.into_iter().map(|r| r.expect("every chunk joined")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_serial_map_and_preserves_order() {
        let items: Vec<usize> = (0..37).collect();
        let serial: Vec<usize> = items.iter().map(|&x| x * x + 1).collect();
        for threads in [1, 2, 3, 8, 64] {
            let par = parallel_map(&items, threads, |&x| x * x + 1);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn handles_empty_and_single() {
        let none: Vec<u32> = Vec::new();
        assert!(parallel_map(&none, 4, |&x| x).is_empty());
        assert_eq!(parallel_map(&[7u32], 4, |&x| x + 1), vec![8]);
    }

    #[test]
    fn effective_threads_resolves_auto() {
        assert_eq!(effective_threads(3), 3);
        assert!(effective_threads(0) >= 1);
    }

    #[test]
    #[should_panic(expected = "parallel_map worker panicked")]
    fn worker_panic_propagates_to_the_caller() {
        let items: Vec<usize> = (0..8).collect();
        parallel_map(&items, 4, |&x| {
            assert!(x != 5, "boom");
            x
        });
    }

    #[test]
    fn auto_thread_count_matches_the_serial_map() {
        // `--cpu-threads 0` resolves to the machine's parallelism; the
        // fan-out must stay order-preserving whatever that lands on.
        let items: Vec<usize> = (0..129).collect();
        let serial: Vec<usize> = items.iter().map(|&x| x * 3 + 7).collect();
        let auto = parallel_map(&items, effective_threads(0), |&x| x * 3 + 7);
        assert_eq!(auto, serial);
    }
}
