//! Minimal-but-complete JSON: a recursive-descent parser, a serializer and
//! ergonomic accessors. Covers the full grammar (objects, arrays, strings
//! with escapes incl. `\uXXXX`, numbers incl. exponents, bools, null);
//! rejects trailing garbage and deeply-nested bombs. This is the only JSON
//! implementation in the repository — the artifact manifest, prompt sets,
//! latency profiles, predictor weights, configs and the server protocol all
//! go through it.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value. Object keys are ordered (BTreeMap) so serialization
/// is deterministic — experiment outputs diff cleanly.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (ordered keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------- accessors

    /// Object member lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object member lookup that reports *which* key was missing.
    pub fn req(&self, key: &str) -> crate::Result<&Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing JSON key '{key}'"))
    }

    /// Numeric value, if a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Non-negative integral value, if exactly representable.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as usize)
    }

    /// Unsigned-64 accessor for identifiers. Accepts an integral number
    /// (exact for magnitudes below 2^53 — the f64 integer range) or a
    /// decimal string (exact for the full u64 range; the server protocol
    /// uses this form for ids that do not fit a JSON number losslessly).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= 9.007_199_254_740_992e15 => {
                Some(*x as u64)
            }
            Json::Str(s) => s.parse::<u64>().ok(),
            _ => None,
        }
    }

    /// String value, if a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value, if a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array elements, if an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object map, if an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required numeric member.
    pub fn f64(&self, key: &str) -> crate::Result<f64> {
        self.req(key)?.as_f64().ok_or_else(|| anyhow::anyhow!("'{key}' not a number"))
    }

    /// Required non-negative-integer member.
    pub fn usize(&self, key: &str) -> crate::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("'{key}' not a non-negative integer"))
    }

    /// Required u64 member (number or decimal string).
    pub fn u64(&self, key: &str) -> crate::Result<u64> {
        self.req(key)?
            .as_u64()
            .ok_or_else(|| anyhow::anyhow!("'{key}' not a u64 (number or decimal string)"))
    }

    /// Required string member.
    pub fn str(&self, key: &str) -> crate::Result<&str> {
        self.req(key)?.as_str().ok_or_else(|| anyhow::anyhow!("'{key}' not a string"))
    }

    /// Required array member.
    pub fn arr(&self, key: &str) -> crate::Result<&[Json]> {
        self.req(key)?.as_arr().ok_or_else(|| anyhow::anyhow!("'{key}' not an array"))
    }

    /// Numeric vector helper (`[1, 2, 3]` → `Vec<f64>`).
    pub fn f64_vec(&self, key: &str) -> crate::Result<Vec<f64>> {
        self.arr(key)?
            .iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow::anyhow!("'{key}' has non-number")))
            .collect()
    }

    /// Required integer-array member.
    pub fn usize_vec(&self, key: &str) -> crate::Result<Vec<usize>> {
        self.arr(key)?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("'{key}' has non-integer")))
            .collect()
    }

    // -------------------------------------------------------- constructors

    /// Object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Serializes a u64 identifier losslessly: a JSON number while the
    /// value fits the f64 integer range, a decimal string beyond it
    /// (mirrors [`Json::as_u64`], which accepts both).
    pub fn from_u64(x: u64) -> Json {
        if x <= (1u64 << 53) {
            Json::Num(x as f64)
        } else {
            Json::Str(x.to_string())
        }
    }

    /// Array from an f32 slice.
    pub fn from_f32s(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Array from an f64 slice.
    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Array from a usize slice.
    pub fn from_usizes(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // -------------------------------------------------------- serialization

    /// Serializes to compact JSON text.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else if x.is_finite() {
                    // Round-trippable shortest float.
                    let _ = write!(out, "{x:e}");
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // -------------------------------------------------------------- parsing

    /// Parses a complete JSON document (rejects trailing garbage).
    pub fn parse(text: &str) -> crate::Result<Json> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        anyhow::ensure!(p.i == bytes.len(), "trailing garbage at byte {}", p.i);
        Ok(v)
    }

    /// Parses a JSON file.
    pub fn parse_file(path: &std::path::Path) -> crate::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
    }

    /// Writes as JSON text, creating parent directories.
    pub fn save(&self, path: &std::path::Path) -> crate::Result<()> {
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        std::fs::write(path, self.to_string())?;
        Ok(())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

const MAX_DEPTH: usize = 128;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> crate::Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> crate::Result<()> {
        anyhow::ensure!(self.peek()? == c, "expected '{}' at byte {}", c as char, self.i);
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> crate::Result<Json> {
        anyhow::ensure!(self.depth < MAX_DEPTH, "nesting too deep");
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> crate::Result<Json> {
        anyhow::ensure!(
            self.b[self.i..].starts_with(word.as_bytes()),
            "invalid literal at byte {}",
            self.i
        );
        self.i += word.len();
        Ok(v)
    }

    fn object(&mut self) -> crate::Result<Json> {
        self.eat(b'{')?;
        self.depth += 1;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    break;
                }
                c => anyhow::bail!("expected ',' or '}}', got '{}' at {}", c as char, self.i),
            }
        }
        self.depth -= 1;
        Ok(Json::Obj(m))
    }

    fn array(&mut self) -> crate::Result<Json> {
        self.eat(b'[')?;
        self.depth += 1;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    break;
                }
                c => anyhow::bail!("expected ',' or ']', got '{}' at {}", c as char, self.i),
            }
        }
        self.depth -= 1;
        Ok(Json::Arr(v))
    }

    fn string(&mut self) -> crate::Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000C}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // Surrogate pair handling.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                self.eat(b'\\')?;
                                self.eat(b'u')?;
                                let lo = self.hex4()?;
                                anyhow::ensure!(
                                    (0xDC00..0xE000).contains(&lo),
                                    "invalid low surrogate"
                                );
                                let c =
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(c)
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.ok_or_else(|| anyhow::anyhow!("bad codepoint"))?);
                        }
                        _ => anyhow::bail!("bad escape at byte {}", self.i - 1),
                    }
                }
                _ => {
                    // Re-borrow the raw bytes to keep UTF-8 intact.
                    let start = self.i - 1;
                    let mut end = self.i;
                    while end < self.b.len() && self.b[end] != b'"' && self.b[end] != b'\\' {
                        end += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| anyhow::anyhow!("invalid utf-8 in string"))?,
                    );
                    self.i = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> crate::Result<u32> {
        anyhow::ensure!(self.i + 4 <= self.b.len(), "truncated \\u escape");
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| anyhow::anyhow!("bad \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| anyhow::anyhow!("bad \\u escape"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> crate::Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        let x: f64 = s
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid number '{s}' at byte {start}"))?;
        Ok(Json::Num(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.f64_vec("a").is_err(), true); // heterogeneous
        assert_eq!(v.str("c").unwrap(), "x");
        let a = v.arr("a").unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let original = Json::Str("a\"b\\c\nd\te\u{0007}é☃".into());
        let text = original.to_string();
        assert_eq!(Json::parse(&text).unwrap(), original);
    }

    #[test]
    fn unicode_escape_and_surrogates() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
        assert_eq!(Json::parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
        assert!(Json::parse(r#""\ud83d""#).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn rejects_nesting_bomb() {
        let bomb = "[".repeat(300) + &"]".repeat(300);
        assert!(Json::parse(&bomb).is_err());
    }

    #[test]
    fn numbers_roundtrip() {
        for x in [0.0, 1.0, -17.0, 0.25, 1e-9, 3.141592653589793, 1e15] {
            let s = Json::Num(x).to_string();
            let back = Json::parse(&s).unwrap().as_f64().unwrap();
            assert_eq!(back, x, "via {s}");
        }
    }

    #[test]
    fn u64_ids_roundtrip_losslessly() {
        // Small ids travel as numbers.
        let small = Json::from_u64(7);
        assert_eq!(small, Json::Num(7.0));
        assert_eq!(Json::parse(&small.to_string()).unwrap().as_u64(), Some(7));
        // Ids beyond the f64 integer range travel as decimal strings.
        let big_val = u64::MAX - 3;
        let big = Json::from_u64(big_val);
        assert_eq!(Json::parse(&big.to_string()).unwrap().as_u64(), Some(big_val));
        // Rejections: negatives, fractions, non-numeric strings.
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1.5).as_u64(), None);
        assert_eq!(Json::Str("12x".into()).as_u64(), None);
        assert_eq!(Json::Str("12".into()).as_u64(), Some(12));
    }

    #[test]
    fn object_serialization_is_deterministic() {
        let a = Json::obj(vec![("b", Json::Num(1.0)), ("a", Json::Num(2.0))]);
        assert_eq!(a.to_string(), r#"{"a":2,"b":1}"#);
    }

    #[test]
    fn helper_vectors() {
        let v = Json::parse(r#"{"xs": [1, 2, 3]}"#).unwrap();
        assert_eq!(v.f64_vec("xs").unwrap(), vec![1.0, 2.0, 3.0]);
        assert_eq!(v.usize_vec("xs").unwrap(), vec![1, 2, 3]);
        let bad = Json::parse(r#"{"xs": [1.5]}"#).unwrap();
        assert!(bad.usize_vec("xs").is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("ygg_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("x.json");
        let v = Json::obj(vec![("k", Json::from_f64s(&[1.0, 0.5]))]);
        v.save(&p).unwrap();
        assert_eq!(Json::parse_file(&p).unwrap(), v);
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let p = std::path::Path::new("artifacts/manifest.json");
        if p.exists() {
            let m = Json::parse_file(p).unwrap();
            assert_eq!(m.usize("format_version").unwrap(), 1);
            assert!(m.req("models").unwrap().get("tgt-sm").is_some());
        }
    }
}
