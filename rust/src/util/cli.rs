//! Tiny CLI argument parser (the in-tree clap substitute).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, typed
//! accessors with defaults, and collects positional arguments. Unknown
//! options are an error — typos should not silently run a 20-minute bench
//! with default parameters.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First positional token, e.g. `serve`.
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Remaining positional arguments.
    pub positional: Vec<String>,
    /// Option names the caller declared (for unknown-option errors).
    known: Vec<String>,
}

impl Args {
    /// Parses `argv[1..]`. `known_opts` lists valid `--key value` names and
    /// `known_flags` valid boolean `--flag` names.
    pub fn parse(
        argv: &[String],
        known_opts: &[&str],
        known_flags: &[&str],
    ) -> crate::Result<Self> {
        let mut out = Args::default();
        out.known = known_opts.iter().map(|s| s.to_string()).collect();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                if known_flags.contains(&key.as_str()) {
                    anyhow::ensure!(inline_val.is_none(), "flag --{key} takes no value");
                    out.flags.push(key);
                } else if known_opts.contains(&key.as_str()) {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| anyhow::anyhow!("--{key} needs a value"))?
                        }
                    };
                    out.opts.insert(key, val);
                } else {
                    anyhow::bail!("unknown option --{key}");
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    /// True when `--name` was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Value of `--name`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Integer option with default.
    pub fn usize_or(&self, name: &str, default: usize) -> crate::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} must be an integer")),
        }
    }

    /// Float option with default.
    pub fn f64_or(&self, name: &str, default: f64) -> crate::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} must be a number")),
        }
    }

    /// u64 option with default.
    pub fn u64_or(&self, name: &str, default: u64) -> crate::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} must be an integer")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn subcommand_options_flags_positional() {
        let a = Args::parse(
            &argv(&["serve", "--addr", "1.2.3.4:5", "--stream", "extra"]),
            &["addr"],
            &["stream"],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("addr"), Some("1.2.3.4:5"));
        assert!(a.flag("stream"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn equals_syntax() {
        let a = Args::parse(&argv(&["x", "--n=5"]), &["n"], &[]).unwrap();
        assert_eq!(a.usize_or("n", 0).unwrap(), 5);
    }

    #[test]
    fn unknown_option_fails() {
        assert!(Args::parse(&argv(&["--nope"]), &["yes"], &[]).is_err());
    }

    #[test]
    fn missing_value_fails() {
        assert!(Args::parse(&argv(&["--n"]), &["n"], &[]).is_err());
    }

    #[test]
    fn typed_defaults() {
        let a = Args::parse(&argv(&[]), &["n"], &[]).unwrap();
        assert_eq!(a.usize_or("n", 7).unwrap(), 7);
        assert_eq!(a.f64_or("t", 0.5).unwrap(), 0.5);
        assert_eq!(a.str_or("s", "d"), "d");
    }

    #[test]
    fn bad_typed_value_fails() {
        let a = Args::parse(&argv(&["--n", "xyz"]), &["n"], &[]).unwrap();
        assert!(a.usize_or("n", 0).is_err());
    }
}
