//! Tiny leveled logger (the in-tree `log`/`env_logger` substitute).
//!
//! One global level (an atomic, set once from `--log-level`), one-line
//! output on stderr, and an optional `(worker, request uid)` context so
//! log lines correlate with the trace spans of DESIGN.md §17:
//!
//! ```text
//! [INFO w0 uid=281474976710657] admitted after 1.2ms queueing
//! ```
//!
//! Call sites format their message eagerly; callers on hot paths must
//! gate on [`enabled`] first (the serving round loop does not log at all
//! — it records trace events instead).

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Unrecoverable or data-losing conditions.
    Error = 0,
    /// Degraded operation (rung escalations, preemptions, spills).
    Warn = 1,
    /// Lifecycle milestones (startup banners, loaded artifacts).
    Info = 2,
    /// High-volume diagnostics (per-request, per-round).
    Debug = 3,
}

impl Level {
    /// Display tag, fixed-width enough for eyeballing.
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
        }
    }

    /// Parses a `--log-level` value (`error|warn|info|debug`).
    pub fn parse(s: &str) -> crate::Result<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Ok(Level::Error),
            "warn" | "warning" => Ok(Level::Warn),
            "info" => Ok(Level::Info),
            "debug" => Ok(Level::Debug),
            _ => anyhow::bail!("--log-level must be error|warn|info|debug, got '{s}'"),
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Sets the global level (everything at or above it prints).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// True when `level` would print — gate expensive formatting on this.
pub fn enabled(level: Level) -> bool {
    level as u8 <= LEVEL.load(Ordering::Relaxed)
}

/// Core emitter: one line on stderr with optional worker / request-uid
/// context. Prefer the [`error`]/[`warn`]/[`info`]/[`debug`] shorthands
/// when there is no context to attach.
pub fn log(level: Level, worker: Option<usize>, uid: Option<u64>, msg: &str) {
    if !enabled(level) {
        return;
    }
    let mut head = String::with_capacity(32);
    head.push('[');
    head.push_str(level.as_str());
    if let Some(w) = worker {
        head.push_str(" w");
        head.push_str(&w.to_string());
    }
    if let Some(u) = uid {
        head.push_str(" uid=");
        head.push_str(&u.to_string());
    }
    head.push(']');
    eprintln!("{head} {msg}");
}

/// Error-level line without context.
pub fn error(msg: &str) {
    log(Level::Error, None, None, msg);
}

/// Warn-level line without context.
pub fn warn(msg: &str) {
    log(Level::Warn, None, None, msg);
}

/// Info-level line without context.
pub fn info(msg: &str) {
    log(Level::Info, None, None, msg);
}

/// Debug-level line without context.
pub fn debug(msg: &str) {
    log(Level::Debug, None, None, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_parse_and_order() {
        assert_eq!(Level::parse("warn").unwrap(), Level::Warn);
        assert_eq!(Level::parse("WARNING").unwrap(), Level::Warn);
        assert!(Level::parse("loud").is_err());
        assert!(Level::Error < Level::Debug);
    }

    #[test]
    fn enabled_respects_the_global_level() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore the default for other tests
        assert!(enabled(Level::Info));
    }
}
