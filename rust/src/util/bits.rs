//! Bit-word utilities shared by the bit-packed mask path
//! ([`crate::tree::BitMask`]) and the KV-cache block gauge
//! ([`crate::kvcache::BlockPool`]): 64 slots per `u64` word, low bit of
//! word 0 = bit 0 — the same encoding sglang's `eagle_utils` uses for
//! its bit-packed tree masks (`QLEN_ONLY_BITPACKING`).

/// Bits per storage word.
pub const WORD_BITS: usize = 64;

/// Words needed to hold `bits` bits.
#[inline]
pub fn words_for(bits: usize) -> usize {
    bits.div_ceil(WORD_BITS)
}

/// Sets bit `i` in `words`.
#[inline]
pub fn set_bit(words: &mut [u64], i: usize) {
    words[i / WORD_BITS] |= 1u64 << (i % WORD_BITS);
}

/// Clears bit `i` in `words`.
#[inline]
pub fn clear_bit(words: &mut [u64], i: usize) {
    words[i / WORD_BITS] &= !(1u64 << (i % WORD_BITS));
}

/// Reads bit `i` of `words`.
#[inline]
pub fn get_bit(words: &[u64], i: usize) -> bool {
    (words[i / WORD_BITS] >> (i % WORD_BITS)) & 1 == 1
}

/// Number of set bits across `words`.
#[inline]
pub fn count_ones(words: &[u64]) -> usize {
    words.iter().map(|w| w.count_ones() as usize).sum()
}

/// The mask selecting, within word `w`, the bits whose *absolute* index
/// falls in `[lo, hi)`. Zero when the range misses the word entirely —
/// this is how a contiguous slot range becomes a per-word allow mask.
#[inline]
pub fn range_word_mask(w: usize, lo: usize, hi: usize) -> u64 {
    let base = w * WORD_BITS;
    let a = lo.max(base);
    let b = hi.min(base + WORD_BITS);
    if a >= b {
        return 0;
    }
    let span = b - a;
    let ones = if span == WORD_BITS { u64::MAX } else { (1u64 << span) - 1 };
    ones << (a - base)
}

/// A fixed-length bitset over `u64` words — the `Vec<bool>` replacement
/// used by [`crate::kvcache::BlockPool`]'s cached-flag gauge (8× denser,
/// word-at-a-time population counts).
#[derive(Debug, Clone)]
pub struct BitSet {
    words: Vec<u64>,
    len: usize,
}

impl BitSet {
    /// A set of `len` bits, all clear.
    pub fn new(len: usize) -> Self {
        Self { words: vec![0; words_for(len)], len }
    }

    /// Bit count (fixed at construction).
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the set holds zero bits.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Reads bit `i`. Panics when out of range.
    pub fn get(&self, i: usize) -> bool {
        assert!(i < self.len, "bit {i} out of range ({})", self.len);
        get_bit(&self.words, i)
    }

    /// Writes bit `i`. Panics when out of range.
    pub fn set(&mut self, i: usize, v: bool) {
        assert!(i < self.len, "bit {i} out of range ({})", self.len);
        if v {
            set_bit(&mut self.words, i);
        } else {
            clear_bit(&mut self.words, i);
        }
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> usize {
        count_ones(&self.words)
    }

    /// Backing words (low bit of word 0 = bit 0).
    pub fn words(&self) -> &[u64] {
        &self.words
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_math_round_trips() {
        assert_eq!(words_for(0), 0);
        assert_eq!(words_for(1), 1);
        assert_eq!(words_for(64), 1);
        assert_eq!(words_for(65), 2);
        let mut w = vec![0u64; 2];
        set_bit(&mut w, 0);
        set_bit(&mut w, 63);
        set_bit(&mut w, 64);
        assert!(get_bit(&w, 0) && get_bit(&w, 63) && get_bit(&w, 64));
        assert!(!get_bit(&w, 1));
        assert_eq!(count_ones(&w), 3);
        clear_bit(&mut w, 63);
        assert!(!get_bit(&w, 63));
        assert_eq!(count_ones(&w), 2);
    }

    #[test]
    fn range_word_mask_matches_per_bit_reference() {
        for &(lo, hi) in &[(0usize, 0usize), (0, 64), (3, 7), (60, 70), (64, 128), (5, 200)] {
            for w in 0..4 {
                let mask = range_word_mask(w, lo, hi);
                for b in 0..WORD_BITS {
                    let abs = w * WORD_BITS + b;
                    let expect = abs >= lo && abs < hi;
                    assert_eq!((mask >> b) & 1 == 1, expect, "w={w} lo={lo} hi={hi} bit={b}");
                }
            }
        }
    }

    #[test]
    fn bitset_get_set_count() {
        let mut s = BitSet::new(130);
        assert_eq!(s.len(), 130);
        assert!(!s.is_empty());
        assert!(!s.get(129));
        s.set(129, true);
        s.set(0, true);
        s.set(64, true);
        assert!(s.get(129) && s.get(0) && s.get(64));
        assert_eq!(s.count_ones(), 3);
        s.set(64, false);
        assert!(!s.get(64));
        assert_eq!(s.count_ones(), 2);
        assert_eq!(s.words().len(), 3);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn bitset_bounds_checked() {
        let s = BitSet::new(10);
        let _ = s.get(10);
    }
}
