//! Mini property-testing harness (the in-tree proptest substitute).
//!
//! [`run_prop`] drives a property over `cases` seeded-random inputs; on
//! failure it *shrinks* the failing seed's input via the caller-provided
//! shrink function before reporting, and prints the seed so failures
//! reproduce exactly. Used by the invariant tests on trees, pruning,
//! scheduling and the kernel-shape sweeps.

use crate::sampling::XorShiftRng;

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct PropConfig {
    /// Inputs to draw.
    pub cases: usize,
    /// Base RNG seed (override with `YGG_PROP_SEED`).
    pub seed: u64,
    /// Shrink-attempt budget after a failure.
    pub max_shrink_steps: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        // Seed overridable for reproduction: YGG_PROP_SEED=n cargo test
        let seed = std::env::var("YGG_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        Self { cases: 256, seed, max_shrink_steps: 200 }
    }
}

/// Runs `property` on `cases` inputs drawn by `gen`. On failure, applies
/// `shrink` (returning candidate smaller inputs) until no candidate fails,
/// then panics with the minimal counterexample's Debug rendering.
pub fn run_prop<T: std::fmt::Debug + Clone>(
    name: &str,
    cfg: PropConfig,
    mut gen: impl FnMut(&mut XorShiftRng) -> T,
    mut shrink: impl FnMut(&T) -> Vec<T>,
    mut property: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = XorShiftRng::new(cfg.seed);
    for case in 0..cfg.cases {
        let mut input = gen(&mut rng);
        let Err(mut err) = property(&input) else { continue };
        // Shrink.
        let mut steps = 0;
        'outer: while steps < cfg.max_shrink_steps {
            for cand in shrink(&input) {
                steps += 1;
                if let Err(e) = property(&cand) {
                    input = cand;
                    err = e;
                    continue 'outer;
                }
                if steps >= cfg.max_shrink_steps {
                    break;
                }
            }
            break;
        }
        panic!(
            "property '{name}' failed (case {case}, seed {:#x}):\n  error: {err}\n  minimal input: {input:?}",
            cfg.seed
        );
    }
}

/// Shrinker for vectors: halves, removals and element-wise shrink.
pub fn shrink_vec<T: Clone>(v: &[T], shrink_elem: impl Fn(&T) -> Option<T>) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if !v.is_empty() {
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[v.len() / 2..].to_vec());
        if v.len() > 1 {
            let mut w = v.to_vec();
            w.pop();
            out.push(w);
        }
    }
    for (i, x) in v.iter().enumerate() {
        if let Some(s) = shrink_elem(x) {
            let mut w = v.to_vec();
            w[i] = s;
            out.push(w);
        }
    }
    out
}

/// Shrinker for usize toward a floor.
pub fn shrink_usize(x: usize, floor: usize) -> Option<usize> {
    (x > floor).then(|| floor + (x - floor) / 2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_silent() {
        run_prop(
            "sum-commutes",
            PropConfig { cases: 64, ..Default::default() },
            |rng| (rng.next_range(100), rng.next_range(100)),
            |_| vec![],
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "minimal input")]
    fn failing_property_reports_and_shrinks() {
        run_prop(
            "all-below-50",
            PropConfig { cases: 64, seed: 1, ..Default::default() },
            |rng| rng.next_range(100),
            |&x| shrink_usize(x, 0).into_iter().collect(),
            |&x| if x < 50 { Ok(()) } else { Err(format!("{x} >= 50")) },
        );
    }

    #[test]
    fn shrink_usize_converges() {
        let mut x = 100usize;
        let mut guard = 0;
        while let Some(y) = shrink_usize(x, 3) {
            assert!(y < x && y >= 3);
            x = y;
            guard += 1;
            assert!(guard < 20);
        }
        assert_eq!(x, 3);
    }

    #[test]
    fn shrink_vec_produces_smaller_candidates() {
        let v = vec![5usize, 6, 7, 8];
        let cands = shrink_vec(&v, |&x| shrink_usize(x, 0));
        assert!(cands.iter().any(|c| c.len() < v.len()));
        assert!(cands.iter().any(|c| c.len() == v.len()));
    }
}
