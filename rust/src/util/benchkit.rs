//! In-tree micro-benchmark harness (the criterion substitute).
//!
//! `cargo bench` targets are `harness = false` binaries that call
//! [`Bench::run`] per case: adaptive iteration count to hit a target
//! measurement time, warm-up, mean/median/p99 statistics and a compact
//! report. Designed for the millisecond-scale model calls and the
//! microsecond-scale tree ops this repo measures.

use std::time::{Duration, Instant};

/// Statistics of one benchmark case.
#[derive(Debug, Clone)]
pub struct CaseResult {
    /// Case label.
    pub name: String,
    /// Measured iterations.
    pub iters: usize,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Median seconds.
    pub median_s: f64,
    /// 99th-percentile seconds.
    pub p99_s: f64,
    /// Fastest iteration.
    pub min_s: f64,
}

impl CaseResult {
    fn fmt_time(s: f64) -> String {
        if s >= 1.0 {
            format!("{s:.3} s")
        } else if s >= 1e-3 {
            format!("{:.3} ms", s * 1e3)
        } else if s >= 1e-6 {
            format!("{:.3} µs", s * 1e6)
        } else {
            format!("{:.1} ns", s * 1e9)
        }
    }

    /// One-line human-readable summary.
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12}  median {:>12}  p99 {:>12}",
            self.name,
            self.iters,
            Self::fmt_time(self.mean_s),
            Self::fmt_time(self.median_s),
            Self::fmt_time(self.p99_s),
        )
    }
}

/// Adaptive micro-benchmark runner.
pub struct Bench {
    /// Measurement window per case.
    pub target_time: Duration,
    /// Warm-up window per case.
    pub warmup: Duration,
    /// Hard iteration cap.
    pub max_iters: usize,
    /// Completed case results.
    pub results: Vec<CaseResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            target_time: Duration::from_secs(1),
            warmup: Duration::from_millis(200),
            max_iters: 1_000_000,
            results: Vec::new(),
        }
    }
}

impl Bench {
    /// A runner with the default windows.
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick mode for CI (`YGG_BENCH_QUICK=1`): shorter windows.
    pub fn from_env() -> Self {
        if std::env::var("YGG_BENCH_QUICK").is_ok() {
            Self {
                target_time: Duration::from_millis(200),
                warmup: Duration::from_millis(50),
                ..Self::default()
            }
        } else {
            Self::default()
        }
    }

    /// Runs one case; `f` is invoked repeatedly and must not be optimised
    /// away (return something and let us black-box it).
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &CaseResult {
        // Warm-up + initial rate estimate.
        let w0 = Instant::now();
        let mut warm_iters = 0usize;
        while w0.elapsed() < self.warmup || warm_iters == 0 {
            black_box(f());
            warm_iters += 1;
            if warm_iters > self.max_iters {
                break;
            }
        }
        let per_iter = w0.elapsed().as_secs_f64() / warm_iters as f64;
        let n = ((self.target_time.as_secs_f64() / per_iter.max(1e-9)) as usize)
            .clamp(5, self.max_iters);

        // Measure in batches so Instant overhead stays negligible for
        // nanosecond-scale bodies.
        let batch = (n / 100).max(1);
        let mut samples = Vec::with_capacity(n / batch + 1);
        let mut done = 0;
        while done < n {
            let t0 = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            samples.push(t0.elapsed().as_secs_f64() / batch as f64);
            done += batch;
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let pick = |p: f64| samples[((p * (samples.len() - 1) as f64) as usize).min(samples.len() - 1)];
        let result = CaseResult {
            name: name.to_string(),
            iters: n,
            mean_s: mean,
            median_s: pick(0.5),
            p99_s: pick(0.99),
            min_s: samples[0],
        };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Writes all case results as CSV (used by the figure harness).
    pub fn save_csv(&self, path: &std::path::Path) -> crate::Result<()> {
        let mut out = String::from("name,iters,mean_s,median_s,p99_s,min_s\n");
        for r in &self.results {
            out.push_str(&format!(
                "{},{},{},{},{},{}\n",
                r.name, r.iters, r.mean_s, r.median_s, r.p99_s, r.min_s
            ));
        }
        if let Some(p) = path.parent() {
            std::fs::create_dir_all(p)?;
        }
        std::fs::write(path, out)?;
        Ok(())
    }
}

/// Optimisation barrier (std::hint::black_box stabilised in 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_a_known_sleep() {
        let mut b = Bench {
            target_time: Duration::from_millis(50),
            warmup: Duration::from_millis(5),
            ..Bench::default()
        };
        let r = b.run("sleep1ms", || std::thread::sleep(Duration::from_millis(1)));
        assert!(r.mean_s >= 0.0009, "mean {}", r.mean_s);
        assert!(r.mean_s < 0.01);
    }

    #[test]
    fn fast_bodies_get_many_iters() {
        let mut b = Bench {
            target_time: Duration::from_millis(20),
            warmup: Duration::from_millis(5),
            ..Bench::default()
        };
        let r = b.run("add", || 1u64.wrapping_add(2));
        assert!(r.iters > 1000);
    }

    #[test]
    fn csv_has_all_cases() {
        let mut b = Bench {
            target_time: Duration::from_millis(5),
            warmup: Duration::from_millis(1),
            ..Bench::default()
        };
        b.run("a", || 1);
        b.run("b", || 2);
        let p = std::env::temp_dir().join("ygg_bench_test.csv");
        b.save_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.lines().count() == 3);
    }
}
