//! Self-contained substrates the repository implements instead of pulling
//! dependencies: JSON ([`json`]), CLI parsing ([`cli`]), a benchmark
//! statistics harness ([`benchkit`]) and a mini property-testing helper
//! ([`prop`]). The build is fully offline (see Cargo.toml); everything a
//! deployment needs ships in-tree.

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod prop;
