//! Self-contained substrates the repository implements instead of pulling
//! dependencies: JSON ([`json`]), CLI parsing ([`cli`]), a leveled logger
//! ([`log`]), a benchmark statistics harness ([`benchkit`]), a mini
//! property-testing helper ([`prop`]), bit-word utilities ([`bits`]) and
//! scoped-thread fan-out ([`par`], the rayon substitute). The build is
//! fully offline (see Cargo.toml); everything a deployment needs ships
//! in-tree.

pub mod benchkit;
pub mod bits;
pub mod cli;
pub mod json;
pub mod log;
pub mod par;
pub mod prop;
