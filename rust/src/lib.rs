//! # Yggdrasil
//!
//! A reproduction of *"Yggdrasil: Bridging Dynamic Speculation and Static
//! Runtime for Latency-Optimal Tree-Based LLM Decoding"* (NeurIPS 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1 (Pallas)** — the tree-attention verification kernel, authored in
//!   `python/compile/kernels/` and lowered into the model graphs.
//! * **L2 (JAX)** — Llama-architecture drafter/verifier models with a
//!   slot-indexed functional KV cache, AOT-lowered once per static width to
//!   HLO text (`python/compile/aot.py` → `artifacts/`).
//! * **L3 (this crate)** — the paper's system contribution: the
//!   [`tree::TokenTree`] Equal-Growth Tree drafting algorithm, the
//!   latency-aware speedup objective ([`objective`]), verification-width
//!   pruning ([`pruning`]), the depth predictor ([`predictor`]), and the
//!   stage-based scheduling runtime ([`scheduler`]), all driving AOT-compiled
//!   PJRT executables through [`runtime`]. Python never runs at serve time.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-figure reproductions.

#![warn(missing_docs)]

pub mod baselines;
pub mod bench;
pub mod config;
pub mod corpus;
pub mod engine;
pub mod kvcache;
pub mod metrics;
pub mod objective;
pub mod predictor;
pub mod pruning;
pub mod runtime;
pub mod sampling;
pub mod scheduler;
pub mod server;
pub mod simulator;
pub mod trace;
pub mod tree;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
