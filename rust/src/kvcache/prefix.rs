//! Cross-request prefix cache: a block-granularity radix trie over
//! refcounted paged KV blocks (DESIGN.md §12).
//!
//! Real serving traffic is dominated by shared system prompts and
//! few-shot templates, yet every session used to prefill its prompt from
//! token zero. The trie keeps **fully-committed prompt blocks** alive
//! across requests: each node covers exactly one block —
//! [`PrefixCache::block_size`] consecutive token ids — on *every* model
//! side (drafter and verifier pools move in lockstep), so a lookup walks
//! the prompt chunk by chunk and returns the longest cached prefix.
//!
//! * **Attach** ([`PrefixCache::acquire`] → [`SlotCache::attach_prefix`])
//!   maps the matched blocks read-shared into a new session's block
//!   tables, bumping each block's pool refcount; the session's prefill
//!   then starts at the first uncached token. K/V reuse is sound because
//!   positions are baked into the K/V at write time and a prompt prefix
//!   always sits at positions `0..k`.
//! * **Copy-on-write divergence** — sharing is whole-block: the first
//!   partially-matched block is never attached; its tokens re-prefill
//!   into the session's own exclusive blocks.
//! * **Insert** ([`PrefixCache::insert`]) runs at session teardown
//!   (completion, disconnect, preemption): chunks whose committed slots
//!   fill exactly one exclusive block on every side are *donated* — the
//!   session's pool reference transfers to the trie instead of being
//!   released — so the next request with the same prefix hits.
//! * **Evict** ([`PrefixCache::evict`]) reclaims least-recently-used leaf
//!   nodes whose blocks nobody but the trie references, and runs whenever
//!   the pool runs dry — strictly *before* the serving layer considers
//!   preempting a live session.
//!
//! Lock order is always prefix-cache → block pool; [`SlotCache`] never
//! holds a pool lock while entering the trie.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::{BlockPool, CacheConfigError, SlotCache};

/// Aggregate counters of one [`PrefixCache`] — the serving layer mirrors
/// these into its stats gauges once per scheduling round.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixCacheStats {
    /// Consumed prefix lookups ([`PrefixCache::record_reuse`] calls: one
    /// per admitted request's prefill; admission probes whose acquired
    /// references release unused are not counted).
    pub lookups: u64,
    /// Consumed lookups that matched at least one cached block.
    pub hits: u64,
    /// Prompt tokens served from cache instead of prefilled.
    pub tokens_reused: u64,
    /// Blocks donated into the trie (per side).
    pub insertions: u64,
    /// Blocks evicted by the LRU pass (per side).
    pub evictions: u64,
    /// Gauge: blocks currently cached (per side) — live trie nodes.
    pub cached_blocks: u64,
}

/// The result of a prefix lookup: the longest cached prefix's blocks,
/// one list per model side, with one pool reference per block already
/// taken on the caller's behalf (transfer them to the session's
/// [`SlotCache::attach_prefix`], whose reset/drop releases them).
#[derive(Debug, Clone)]
pub struct PrefixHit {
    /// Matched blocks per side, in prefix order (side order = the pool
    /// order the cache was built with).
    pub blocks: Vec<Vec<u32>>,
    /// Prompt tokens the blocks cover (`matched chunks × block_size`).
    pub tokens: usize,
}

/// One trie node: a full block of tokens plus the block holding its K/V
/// on each model side.
struct Node {
    /// The `block_size` token ids this node covers.
    chunk: Vec<u32>,
    /// One block per side (same order as [`PrefixCache`]'s pools).
    blocks: Vec<u32>,
    /// Arena id of the parent node (`None` for depth-0 chunks).
    parent: Option<usize>,
    /// Children keyed by their token chunk.
    children: HashMap<Vec<u32>, usize>,
    /// LRU stamp (global tick at last lookup/insert touch).
    last_used: u64,
}

/// The cross-request radix prefix cache (see the module docs).
pub struct PrefixCache {
    pools: Vec<Arc<Mutex<BlockPool>>>,
    block_size: usize,
    /// Node arena; `None` marks freed (evicted) entries.
    nodes: Vec<Option<Node>>,
    free_nodes: Vec<usize>,
    /// Depth-0 children, keyed by token chunk.
    roots: HashMap<Vec<u32>, usize>,
    tick: u64,
    lookups: u64,
    hits: u64,
    tokens_reused: u64,
    insertions: u64,
    evictions: u64,
}

impl PrefixCache {
    /// A cache over one refcounted [`BlockPool`] per model side. All
    /// pools must share one block size (a trie node is one block of
    /// tokens on *every* side); mismatches are the typed
    /// [`CacheConfigError::BadBlockSize`].
    pub fn new(pools: Vec<Arc<Mutex<BlockPool>>>) -> Result<Self, CacheConfigError> {
        assert!(!pools.is_empty(), "prefix cache needs at least one pool");
        let sizes: Vec<(usize, usize)> = pools
            .iter()
            .map(|p| {
                let p = p.lock().unwrap();
                (p.block_size() as usize, p.total_capacity())
            })
            .collect();
        let block_size = sizes[0].0;
        for &(bs, cap) in &sizes {
            if bs != block_size {
                return Err(CacheConfigError::BadBlockSize { capacity: cap, block_size: bs });
            }
        }
        Ok(Self {
            pools,
            block_size,
            nodes: Vec::new(),
            free_nodes: Vec::new(),
            roots: HashMap::new(),
            tick: 0,
            lookups: 0,
            hits: 0,
            tokens_reused: 0,
            insertions: 0,
            evictions: 0,
        })
    }

    /// Tokens per cached block (shared by every side's pool).
    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Model sides (pools) each node carries a block for.
    pub fn sides(&self) -> usize {
        self.pools.len()
    }

    /// Gauge: blocks currently cached per side (live trie nodes).
    pub fn cached_blocks(&self) -> usize {
        self.nodes.iter().flatten().count()
    }

    /// Point-in-time counters (see [`PrefixCacheStats`]).
    pub fn stats(&self) -> PrefixCacheStats {
        PrefixCacheStats {
            lookups: self.lookups,
            hits: self.hits,
            tokens_reused: self.tokens_reused,
            insertions: self.insertions,
            evictions: self.evictions,
            cached_blocks: self.cached_blocks() as u64,
        }
    }

    /// Looks up the longest cached prefix of `tokens`, bumps each matched
    /// node's LRU stamp, and takes one pool reference per matched block
    /// on every side (see [`PrefixHit`] for the transfer contract).
    ///
    /// Deliberately does **not** count the hit-rate stats: admission
    /// probes acquire and release prefixes without ever serving them
    /// (parked resumes re-probe every few rounds), so the gauges are
    /// counted by [`PrefixCache::record_reuse`] only once a task's
    /// prefill actually consumes the attachment.
    pub fn acquire(&mut self, tokens: &[u32]) -> PrefixHit {
        self.tick += 1;
        let tick = self.tick;
        let mut path: Vec<usize> = Vec::new();
        let mut cur: Option<usize> = None;
        for chunk in tokens.chunks_exact(self.block_size) {
            let children = match cur {
                None => &self.roots,
                Some(id) => &self.nodes[id].as_ref().unwrap().children,
            };
            match children.get(chunk) {
                Some(&id) => {
                    path.push(id);
                    cur = Some(id);
                }
                None => break,
            }
        }
        let mut blocks: Vec<Vec<u32>> = vec![Vec::with_capacity(path.len()); self.pools.len()];
        for &id in &path {
            let node = self.nodes[id].as_mut().unwrap();
            node.last_used = tick;
            for (side, &b) in node.blocks.iter().enumerate() {
                blocks[side].push(b);
            }
        }
        // One lock round-trip per side for the whole path (acquire sits
        // on the admission hot path under the trie mutex).
        for (side, pool) in self.pools.iter().enumerate() {
            let mut p = pool.lock().unwrap();
            for &b in &blocks[side] {
                p.retain(b);
            }
        }
        PrefixHit { blocks, tokens: path.len() * self.block_size }
    }

    /// Counts one consumed prefix lookup into the hit-rate gauges:
    /// `tokens` cached prompt tokens actually served (0 = a miss). The
    /// engine calls this when an *admitted* task starts its prefill, so
    /// rejected or parked admission probes — whose acquired references
    /// release unused — never inflate `lookups`/`hits`/`tokens_reused`.
    pub fn record_reuse(&mut self, tokens: usize) {
        self.lookups += 1;
        if tokens > 0 {
            self.hits += 1;
            self.tokens_reused += tokens as u64;
        }
    }

    /// Inserts the committed token sequence of a session being torn down.
    /// `sides` are the session's slot caches in pool order (e.g. drafter,
    /// target); committed slot *j* of each must hold token `tokens[j]`.
    ///
    /// Chunks already in the trie refresh their LRU stamp; from the first
    /// missing chunk on, each chunk is **donated** when *every* side can
    /// split off its fully-committed block
    /// ([`SlotCache::take_donated_chunk`]) — the session's pool reference
    /// transfers to the trie — and insertion stops at the first chunk
    /// that cannot be donated whole. Returns the donated chunk count.
    /// The caches must be reset or dropped right after (they are mid-
    /// teardown; donated slots stay in their committed bookkeeping).
    pub fn insert(&mut self, tokens: &[u32], sides: &mut [&mut SlotCache]) -> usize {
        assert_eq!(sides.len(), self.pools.len(), "one slot cache per side");
        self.tick += 1;
        let tick = self.tick;
        let mut cur: Option<usize> = None;
        let mut donated = 0usize;
        for (i, chunk) in tokens.chunks_exact(self.block_size).enumerate() {
            let children = match cur {
                None => &self.roots,
                Some(id) => &self.nodes[id].as_ref().unwrap().children,
            };
            if let Some(&id) = children.get(chunk) {
                self.nodes[id].as_mut().unwrap().last_used = tick;
                cur = Some(id);
                continue;
            }
            // Donation is all-or-nothing across sides: check every side
            // before taking from any, so a half-donatable chunk leaks
            // nothing.
            if !sides.iter().all(|s| s.can_donate_chunk(i)) {
                break;
            }
            let blocks: Vec<u32> = sides
                .iter_mut()
                .map(|s| s.take_donated_chunk(i).expect("checked donatable"))
                .collect();
            for (side, &b) in blocks.iter().enumerate() {
                self.pools[side].lock().unwrap().mark_cached(b, true);
            }
            let node = Node {
                chunk: chunk.to_vec(),
                blocks,
                parent: cur,
                children: HashMap::new(),
                last_used: tick,
            };
            let id = self.alloc_node(node);
            match cur {
                None => {
                    self.roots.insert(chunk.to_vec(), id);
                }
                Some(p) => {
                    self.nodes[p].as_mut().unwrap().children.insert(chunk.to_vec(), id);
                }
            }
            cur = Some(id);
            donated += 1;
            self.insertions += 1;
        }
        donated
    }

    /// LRU eviction pass: removes leaf nodes whose blocks nobody but the
    /// trie references (pool refcount 1 on every side), least recently
    /// used first, until `need` nodes have been freed or nothing is
    /// evictable. One node frees one block on each side. Called by a
    /// paged [`SlotCache`] whose pool ran dry — strictly before the
    /// serving layer considers preemption. Returns freed node count.
    ///
    /// Each round collects every evictable leaf in one arena pass
    /// (locking each side's pool once for the whole scan; the caller
    /// holds the trie mutex, and sessions can only *gain* references
    /// through it, so a sole-referenced snapshot cannot go stale) and
    /// evicts in LRU order; the outer loop re-runs only when emptied
    /// leaves promote their parents into candidates.
    pub fn evict(&mut self, need: usize) -> usize {
        let mut freed = 0usize;
        while freed < need {
            let mut candidates: Vec<(u64, usize)> = {
                // Lock order: pools in side order, matching every other
                // multi-pool site (drafter before target).
                let guards: Vec<_> = self.pools.iter().map(|p| p.lock().unwrap()).collect();
                self.nodes
                    .iter()
                    .enumerate()
                    .filter_map(|(id, node)| {
                        let node = node.as_ref()?;
                        if !node.children.is_empty() {
                            return None; // interior: keeps its subtree reachable
                        }
                        let sole = node
                            .blocks
                            .iter()
                            .enumerate()
                            .all(|(side, &b)| guards[side].ref_count(b) == 1);
                        sole.then_some((node.last_used, id))
                    })
                    .collect()
            };
            if candidates.is_empty() {
                break;
            }
            candidates.sort_unstable();
            for (_, id) in candidates {
                if freed >= need {
                    break;
                }
                self.remove_node(id);
                freed += 1;
            }
        }
        freed
    }

    fn alloc_node(&mut self, node: Node) -> usize {
        match self.free_nodes.pop() {
            Some(id) => {
                self.nodes[id] = Some(node);
                id
            }
            None => {
                self.nodes.push(Some(node));
                self.nodes.len() - 1
            }
        }
    }

    fn remove_node(&mut self, id: usize) {
        let node = self.nodes[id].take().expect("evicting a freed node");
        debug_assert!(node.children.is_empty(), "evicting an interior node");
        match node.parent {
            None => {
                self.roots.remove(&node.chunk);
            }
            Some(p) => {
                self.nodes[p].as_mut().unwrap().children.remove(&node.chunk);
            }
        }
        for (side, &b) in node.blocks.iter().enumerate() {
            let mut pool = self.pools[side].lock().unwrap();
            pool.mark_cached(b, false);
            pool.release(b);
        }
        self.evictions += 1;
        self.free_nodes.push(id);
    }
}

/// FNV-1a offset basis (64-bit).
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a prime (64-bit).
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// One FNV-1a step over a token id's four little-endian bytes.
fn fnv_step(mut h: u64, tok: u32) -> u64 {
    for b in tok.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Order-sensitive FNV-1a hash of a whole token sequence — the router's
/// deterministic fallback spreader (DESIGN.md §16).
pub fn token_hash(tokens: &[u32]) -> u64 {
    tokens.iter().fold(FNV_OFFSET, |h, &t| fnv_step(h, t))
}

/// Cumulative prefix fingerprints at `chunk`-token boundaries: element
/// `k` hashes `tokens[..(k + 1) * chunk]`, so two prompts agree on the
/// first `k + 1` fingerprints iff they share that many whole chunks of
/// prefix. These are the radix-trie path summaries prefix-affinity
/// routing matches against per worker — a bounded stand-in for shipping
/// each worker's whole trie to the router, sound because the trie itself
/// caches at block (chunk) granularity. Empty when `tokens` is shorter
/// than one chunk.
pub fn chunk_hashes(tokens: &[u32], chunk: usize) -> Vec<u64> {
    let chunk = chunk.max(1);
    let mut out = Vec::with_capacity(tokens.len() / chunk);
    let mut h = FNV_OFFSET;
    for (i, &t) in tokens.iter().enumerate() {
        h = fnv_step(h, t);
        if (i + 1) % chunk == 0 {
            out.push(h);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(capacity: usize, block_size: usize) -> Arc<Mutex<BlockPool>> {
        Arc::new(Mutex::new(BlockPool::new(capacity, block_size, None).unwrap()))
    }

    /// Prefills `tokens` worth of committed slots into a fresh paged
    /// cache (one committed slot per token, in order) and returns it.
    fn committed_cache(p: &Arc<Mutex<BlockPool>>, n: usize) -> SlotCache {
        let mut c = SlotCache::paged(p.clone());
        let slots = c.alloc(n).unwrap();
        for &s in &slots {
            c.commit(s);
        }
        c
    }

    #[test]
    fn insert_then_acquire_roundtrips_the_shared_prefix() {
        let p = pool(65, 8); // 8 blocks
        let mut pc = PrefixCache::new(vec![p.clone()]).unwrap();
        let tokens: Vec<u32> = (100..120).collect(); // 2 full chunks + 4
        let mut donor = committed_cache(&p, tokens.len());
        assert_eq!(pc.insert(&tokens, &mut [&mut donor]), 2, "two pure chunks donated");
        drop(donor); // donated blocks must survive the donor
        assert_eq!(pc.cached_blocks(), 2);
        assert_eq!(p.lock().unwrap().evictable_blocks(), 2);

        // A new request with the same prompt start hits both chunks…
        let hit = pc.acquire(&tokens);
        pc.record_reuse(hit.tokens); // the "task" was admitted
        assert_eq!(hit.tokens, 16);
        assert_eq!(hit.blocks[0].len(), 2);
        let mut user = SlotCache::paged(p.clone());
        user.attach_prefix(&hit.blocks[0]);
        assert_eq!(user.committed_len(), 16, "prefill starts at token 16");
        // …and pins them against eviction while attached.
        assert_eq!(p.lock().unwrap().evictable_blocks(), 0);
        assert_eq!(pc.evict(2), 0, "referenced blocks are not evictable");
        drop(user);
        assert_eq!(p.lock().unwrap().evictable_blocks(), 2);

        // A diverging prompt matches only the common chunk.
        let mut other: Vec<u32> = tokens[..8].to_vec();
        other.extend(900..908);
        let hit = pc.acquire(&other);
        pc.record_reuse(hit.tokens);
        assert_eq!(hit.tokens, 8, "divergent second chunk is copy-on-write");
        for side in hit.blocks {
            for b in side {
                p.lock().unwrap().release(b);
            }
        }
        // An admission probe that acquires but is parked/rejected (refs
        // released unused) must not count toward the hit-rate gauges.
        let probe = pc.acquire(&tokens);
        for side in probe.blocks {
            for b in side {
                p.lock().unwrap().release(b);
            }
        }
        let s = pc.stats();
        assert_eq!(s.lookups, 2, "probe acquires are not lookups");
        assert_eq!(s.hits, 2);
        assert_eq!(s.tokens_reused, 24);
        assert_eq!(s.insertions, 2);
    }

    #[test]
    fn insert_refreshes_existing_chunks_and_extends_with_new_ones() {
        let p = pool(129, 8); // 16 blocks
        let mut pc = PrefixCache::new(vec![p.clone()]).unwrap();
        let base: Vec<u32> = (0..16).collect();
        let mut a = committed_cache(&p, 16);
        assert_eq!(pc.insert(&base, &mut [&mut a]), 2);
        drop(a);
        // A longer committed sequence with the same start donates only
        // the new deeper chunk; the existing ones keep their blocks.
        let longer: Vec<u32> = (0..24).collect();
        let mut b = committed_cache(&p, 24);
        assert_eq!(pc.insert(&longer, &mut [&mut b]), 1, "only the third chunk is new");
        drop(b);
        assert_eq!(pc.cached_blocks(), 3);
        assert_eq!(pc.acquire(&longer).tokens, 24);
        // Release the acquire's references so the pool balances.
        // (3 blocks at ref 2 → back to 1.)
        let held = p.lock().unwrap().num_blocks() - p.lock().unwrap().free_blocks();
        assert_eq!(held, 3, "only the cached blocks stay leased");
    }

    #[test]
    fn evict_reclaims_lru_leaves_first_and_keeps_the_trie_prefix_closed() {
        let p = pool(129, 8);
        let mut pc = PrefixCache::new(vec![p.clone()]).unwrap();
        let chain: Vec<u32> = (0..24).collect(); // 3 chained chunks
        let mut a = committed_cache(&p, 24);
        pc.insert(&chain, &mut [&mut a]);
        drop(a);
        let lone: Vec<u32> = (500..508).collect(); // an unrelated root chunk
        let mut b = committed_cache(&p, 8);
        pc.insert(&lone, &mut [&mut b]);
        drop(b);
        // Touch the lone chunk so the chain's leaf is the LRU leaf.
        let h = pc.acquire(&lone);
        for side in h.blocks {
            for blk in side {
                p.lock().unwrap().release(blk);
            }
        }
        assert_eq!(pc.evict(1), 1);
        // The chain lost its deepest chunk (leaf-first), not an interior
        // node: the remaining prefix still resolves.
        assert_eq!(pc.acquire(&chain).tokens, 16, "interior chunks survive");
        assert_eq!(pc.cached_blocks(), 3);
        assert_eq!(pc.stats().evictions, 1);
        // Evicting everything drains back to an empty trie.
        // (Drop the acquire refs first so the blocks are sole-referenced.)
        let held: Vec<u32> = {
            let pl = p.lock().unwrap();
            (0..pl.num_blocks() as u32).filter(|&blk| pl.ref_count(blk) > 1).collect()
        };
        for blk in held {
            p.lock().unwrap().release(blk);
        }
        assert_eq!(pc.evict(usize::MAX - 1), 3);
        assert_eq!(pc.cached_blocks(), 0);
        assert_eq!(p.lock().unwrap().free_blocks(), 16, "all blocks back in the pool");
    }

    #[test]
    fn two_sided_cache_moves_block_pairs_in_lockstep() {
        let dp = pool(65, 8);
        let tp = pool(129, 8); // different capacity, same block size: fine
        let mut pc = PrefixCache::new(vec![dp.clone(), tp.clone()]).unwrap();
        let tokens: Vec<u32> = (40..56).collect();
        let mut d = committed_cache(&dp, 16);
        let mut t = committed_cache(&tp, 16);
        assert_eq!(pc.insert(&tokens, &mut [&mut d, &mut t]), 2);
        drop(d);
        drop(t);
        let hit = pc.acquire(&tokens);
        assert_eq!(hit.blocks.len(), 2, "one block list per side");
        assert_eq!((hit.blocks[0].len(), hit.blocks[1].len()), (2, 2));
        let mut du = SlotCache::paged(dp.clone());
        let mut tu = SlotCache::paged(tp.clone());
        du.attach_prefix(&hit.blocks[0]);
        tu.attach_prefix(&hit.blocks[1]);
        assert_eq!(du.committed_len(), 16);
        assert_eq!(tu.committed_len(), 16);
        drop(du);
        drop(tu);
        assert_eq!(pc.evict(2), 2);
        assert_eq!(dp.lock().unwrap().free_blocks(), 8);
        assert_eq!(tp.lock().unwrap().free_blocks(), 16);
    }

    #[test]
    fn mismatched_block_sizes_are_a_typed_config_error() {
        let a = pool(65, 8);
        let b = pool(65, 16);
        assert!(matches!(
            PrefixCache::new(vec![a, b]),
            Err(CacheConfigError::BadBlockSize { .. })
        ));
    }

    #[test]
    fn donation_stops_at_the_first_impure_chunk_on_any_side() {
        let p = pool(65, 8);
        let mut pc = PrefixCache::new(vec![p.clone()]).unwrap();
        // Donor committed 12 tokens: chunk 0 pure, chunk 1 incomplete.
        let mut donor = committed_cache(&p, 12);
        let tokens: Vec<u32> = (0..12).collect();
        assert_eq!(pc.insert(&tokens, &mut [&mut donor]), 1);
        assert_eq!(donor.owned_blocks(), 1, "impure chunk's block stays with the donor");
        drop(donor);
        assert_eq!(pc.cached_blocks(), 1);
    }

    #[test]
    fn chunk_hashes_are_prefix_closed_and_order_sensitive() {
        let long: Vec<u32> = (0..40).collect();
        let h = chunk_hashes(&long, 16);
        assert_eq!(h.len(), 2, "two whole 16-token chunks in 40 tokens");
        // Prefix closure: a shared prefix shares the leading fingerprints…
        let mut fork = long.clone();
        fork[35] ^= 1; // diverges inside the partial third chunk only
        assert_eq!(chunk_hashes(&fork, 16), h);
        let mut early = long.clone();
        early[20] ^= 1; // diverges inside chunk 1
        let he = chunk_hashes(&early, 16);
        assert_eq!(he[0], h[0], "chunk 0 untouched");
        assert_ne!(he[1], h[1], "chunk 1 fingerprint must diverge");
        // …and order matters (a radix path, not a bag of tokens).
        let mut swapped = long.clone();
        swapped.swap(0, 1);
        assert_ne!(chunk_hashes(&swapped, 16)[0], h[0]);
        // Short prompts fingerprint nothing; the fallback hash still works.
        assert!(chunk_hashes(&long[..7], 16).is_empty());
        assert_ne!(token_hash(&long[..7]), token_hash(&long[..6]));
    }
}
