//! Slot-level KV-cache management.
//!
//! The L2 graphs treat the cache as a fixed-capacity array of *slots*
//! (DESIGN.md §7): each evaluated token writes its K/V at an arbitrary slot
//! and visibility is mask-encoded, so "memory management" reduces to a
//! free-list allocator plus the committed-slot set that [`MaskBuilder`]
//! (re)builds prefix rows from. Rejected draft slots are returned to the
//! free list and reused by the next iteration's tree — no copying, no
//! compaction, no rollback, which is exactly what keeps every operator
//! shape static for the AOT graphs.
//!
//! One reserved *trash slot* (the last slot) absorbs the K/V writes of
//! padding rows in width-padded calls; it is never marked visible.
//!
//! ## Shared-cache partitioning (DESIGN.md §9)
//!
//! For cross-session batched verification, many sessions share **one**
//! device cache array: a [`SlotPartition`] carves the array into equal
//! contiguous [`SlotRange`] regions (plus the common trash slot), each
//! session's [`SlotCache`] allocates only inside its leased range, and the
//! per-row masks therefore stay *block-diagonal* across sessions — a
//! session can never reference, let alone read, another session's slots.

use crate::tree::MaskBuilder;

/// A contiguous run of slots inside a shared cache array — one session's
/// lease from a [`SlotPartition`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotRange {
    /// First slot of the range.
    pub base: u32,
    /// Number of slots in the range.
    pub len: u32,
}

impl SlotRange {
    /// True when `slot` lies inside this range.
    pub fn contains(&self, slot: u32) -> bool {
        slot >= self.base && slot < self.base + self.len
    }
}

/// Carves one shared cache array into equal per-session regions.
///
/// The last slot of the array stays reserved as the shared trash slot;
/// the remaining `capacity - 1` slots split into `sessions` equal regions
/// (any remainder is left unused). Regions are leased and released whole:
/// a session's [`SlotCache`] owns the lease for its lifetime, so slot
/// ownership never fragments across sessions.
#[derive(Debug, Clone)]
pub struct SlotPartition {
    total_capacity: usize,
    region_len: u32,
    free_bases: Vec<u32>,
}

impl SlotPartition {
    /// Partitions a `capacity`-slot cache into `sessions` equal regions.
    ///
    /// Panics when the split leaves a region without at least two usable
    /// slots (a region must hold at least one token beyond bookkeeping).
    pub fn new(capacity: usize, sessions: usize) -> Self {
        assert!(sessions >= 1, "need at least one region");
        assert!(capacity >= 2, "need at least one usable slot plus trash");
        let usable = capacity - 1; // last slot is the shared trash
        let region_len = (usable / sessions) as u32;
        assert!(
            region_len >= 2,
            "capacity {capacity} cannot host {sessions} regions of ≥2 slots"
        );
        // Hand out low regions first (matches SlotCache's low-slot bias).
        let free_bases = (0..sessions as u32).map(|i| i * region_len).rev().collect();
        Self { total_capacity: capacity, region_len, free_bases }
    }

    /// The shared trash slot all sessions' padding rows scatter into.
    pub fn trash_slot(&self) -> u32 {
        self.total_capacity as u32 - 1
    }

    /// Total slots in the shared cache array (including trash).
    pub fn total_capacity(&self) -> usize {
        self.total_capacity
    }

    /// Slots per leased region.
    pub fn region_len(&self) -> u32 {
        self.region_len
    }

    /// Number of regions currently leasable.
    pub fn free_regions(&self) -> usize {
        self.free_bases.len()
    }

    /// Leases one region, or `None` when every region is taken (the
    /// serving layer surfaces this as an admission failure).
    pub fn lease(&mut self) -> Option<SlotRange> {
        self.free_bases.pop().map(|base| SlotRange { base, len: self.region_len })
    }

    /// Returns a leased region (called when its session drops).
    pub fn release(&mut self, range: SlotRange) {
        debug_assert_eq!(range.len, self.region_len, "foreign range returned");
        debug_assert!(
            range.base % self.region_len == 0,
            "misaligned range returned: base {}",
            range.base
        );
        debug_assert!(!self.free_bases.contains(&range.base), "double release");
        self.free_bases.push(range.base);
    }
}

/// Slot allocator + committed-set tracker for one model's cache.
///
/// Owns either a whole cache array ([`SlotCache::new`]) or a leased
/// [`SlotRange`] of a shared array ([`SlotCache::with_range`]); either
/// way it only ever hands out slots from its own region, which is what
/// keeps cross-session masks block-diagonal in batched serving.
#[derive(Debug, Clone)]
pub struct SlotCache {
    /// Size of the backing device array (the mask row width).
    total_capacity: usize,
    /// Slots this cache may allocate.
    range: SlotRange,
    /// The (possibly shared) padding-row slot; never allocated.
    trash: u32,
    free: Vec<u32>, // LIFO free list (excludes the trash slot)
    committed: Vec<u32>,
    mask: MaskBuilder,
}

impl SlotCache {
    /// A cache owning a whole `capacity`-slot array (single-session mode):
    /// the last slot is the trash slot, everything else is allocatable.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 2, "need at least one usable slot plus trash");
        let range = SlotRange { base: 0, len: capacity as u32 - 1 };
        Self::with_range(range, capacity, capacity as u32 - 1)
    }

    /// A cache allocating only inside `range` of a `total_capacity`-slot
    /// shared array whose padding rows scatter into `trash` (shared-cache
    /// batching mode; see [`SlotPartition`]).
    pub fn with_range(range: SlotRange, total_capacity: usize, trash: u32) -> Self {
        assert!(range.len >= 1, "empty slot range");
        assert!(
            (range.base + range.len) as usize <= total_capacity,
            "range beyond cache capacity"
        );
        assert!(!range.contains(trash), "trash slot inside allocatable range");
        // Hand out low slots first (helps locality of the scatter).
        let free = (range.base..range.base + range.len).rev().collect();
        Self {
            total_capacity,
            range,
            trash,
            free,
            committed: Vec::new(),
            mask: MaskBuilder::new(total_capacity),
        }
    }

    /// The reserved slot padding rows scatter their K/V into.
    pub fn trash_slot(&self) -> u32 {
        self.trash
    }

    /// Size of the backing device array (the mask row width) — **not**
    /// this cache's allocatable slot count; see [`SlotCache::usable`].
    pub fn capacity(&self) -> usize {
        self.total_capacity
    }

    /// Slots this cache may allocate (its range length).
    pub fn usable(&self) -> usize {
        self.range.len as usize
    }

    /// The slot range this cache allocates from.
    pub fn range(&self) -> SlotRange {
        self.range
    }

    /// Currently free (allocatable) slots.
    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    /// Slots currently held (committed prefix + outstanding draft slots;
    /// excludes the trash slot). The serving layer aggregates this across
    /// live sessions for its KV-utilization gauge, and the cancellation
    /// tests assert it returns to zero once a session is dropped.
    pub fn in_use(&self) -> usize {
        self.range.len as usize - self.free.len()
    }

    /// Number of committed (always-visible) slots.
    pub fn committed_len(&self) -> usize {
        self.committed.len()
    }

    /// The committed slots, in commit order.
    pub fn committed(&self) -> &[u32] {
        &self.committed
    }

    /// Allocates `n` slots for draft/tree tokens. Returns `None` when the
    /// cache cannot host the tree (callers shrink the envelope).
    pub fn alloc(&mut self, n: usize) -> Option<Vec<u32>> {
        if self.free.len() < n {
            return None;
        }
        Some((0..n).map(|_| self.free.pop().unwrap()).collect())
    }

    /// Returns draft slots that did not get committed.
    pub fn release(&mut self, slots: &[u32]) {
        for &s in slots {
            debug_assert!(s != self.trash);
            debug_assert!(self.range.contains(s), "releasing foreign slot {s}");
            debug_assert!(!self.committed.contains(&s), "releasing committed slot {s}");
            self.free.push(s);
        }
    }

    /// Promotes a draft slot to the committed prefix (visible to all
    /// future tokens of this session).
    pub fn commit(&mut self, slot: u32) {
        debug_assert!(self.range.contains(slot), "committing foreign slot {slot}");
        debug_assert!(!self.committed.contains(&slot));
        self.committed.push(slot);
        self.mask.commit_slot(slot);
    }

    /// Forgets everything (session reset). Stale K/V data stays in the
    /// device buffer but is unreachable — masks make it invisible.
    pub fn reset(&mut self) {
        for &s in &self.committed {
            self.mask.release_slot(s);
        }
        self.committed.clear();
        self.free = (self.range.base..self.range.base + self.range.len).rev().collect();
    }

    /// The mask builder whose prefix row tracks this cache's commits.
    pub fn mask_builder(&mut self) -> &mut MaskBuilder {
        &mut self.mask
    }

    /// Remaining generation headroom in tokens, keeping `tree_budget`
    /// slots available for drafting.
    pub fn headroom(&self, tree_budget: usize) -> usize {
        self.free.len().saturating_sub(tree_budget)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_roundtrip() {
        let mut c = SlotCache::new(8);
        assert_eq!(c.free_count(), 7); // one slot reserved as trash
        assert_eq!(c.in_use(), 0);
        let s = c.alloc(3).unwrap();
        assert_eq!(s.len(), 3);
        assert_eq!(c.free_count(), 4);
        assert_eq!(c.in_use(), 3);
        c.release(&s);
        assert_eq!(c.free_count(), 7);
        assert_eq!(c.in_use(), 0);
    }

    #[test]
    fn alloc_fails_when_exhausted() {
        let mut c = SlotCache::new(4);
        assert!(c.alloc(3).is_some());
        assert!(c.alloc(1).is_none());
    }

    #[test]
    fn trash_slot_is_never_allocated() {
        let mut c = SlotCache::new(4);
        let all = c.alloc(3).unwrap();
        assert!(!all.contains(&c.trash_slot()));
    }

    #[test]
    fn commit_updates_prefix_row() {
        let mut c = SlotCache::new(4);
        let s = c.alloc(2).unwrap();
        c.commit(s[0]);
        assert_eq!(c.committed_len(), 1);
        assert_eq!(c.mask_builder().committed_count(), 1);
    }

    #[test]
    fn reset_restores_everything() {
        let mut c = SlotCache::new(6);
        let s = c.alloc(4).unwrap();
        c.commit(s[0]);
        c.commit(s[1]);
        c.release(&s[2..]);
        c.reset();
        assert_eq!(c.free_count(), 5);
        assert_eq!(c.committed_len(), 0);
        assert_eq!(c.mask_builder().committed_count(), 0);
    }

    #[test]
    fn headroom_reserves_tree_budget() {
        let c = SlotCache::new(74); // 73 usable
        assert_eq!(c.headroom(64), 9);
        assert_eq!(c.headroom(100), 0);
    }

    #[test]
    fn slots_are_reused_lifo() {
        let mut c = SlotCache::new(8);
        let a = c.alloc(2).unwrap();
        c.release(&a);
        let b = c.alloc(2).unwrap();
        assert_eq!(b[0], a[1]);
        assert_eq!(b[1], a[0]);
    }

    #[test]
    fn partition_carves_equal_regions_with_shared_trash() {
        let mut p = SlotPartition::new(321, 4); // 320 usable → 80 per region
        assert_eq!(p.region_len(), 80);
        assert_eq!(p.trash_slot(), 320);
        assert_eq!(p.free_regions(), 4);
        let a = p.lease().unwrap();
        let b = p.lease().unwrap();
        assert_eq!(a, SlotRange { base: 0, len: 80 });
        assert_eq!(b, SlotRange { base: 80, len: 80 });
        assert_eq!(p.free_regions(), 2);
        p.release(a);
        assert_eq!(p.free_regions(), 3);
        // The freed region is leasable again.
        assert_eq!(p.lease().unwrap(), a);
    }

    #[test]
    fn partition_exhausts_then_refills() {
        let mut p = SlotPartition::new(9, 2); // 8 usable → 4 per region
        let a = p.lease().unwrap();
        let b = p.lease().unwrap();
        assert!(p.lease().is_none());
        p.release(b);
        p.release(a);
        assert_eq!(p.free_regions(), 2);
    }

    #[test]
    fn ranged_cache_stays_inside_its_lease() {
        let mut p = SlotPartition::new(17, 2); // 16 usable → 8 per region
        let ra = p.lease().unwrap();
        let rb = p.lease().unwrap();
        let mut a = SlotCache::with_range(ra, 17, p.trash_slot());
        let mut b = SlotCache::with_range(rb, 17, p.trash_slot());
        let sa = a.alloc(8).unwrap();
        let sb = b.alloc(8).unwrap();
        assert!(a.alloc(1).is_none(), "range exhausted");
        assert!(sa.iter().all(|&s| ra.contains(s)));
        assert!(sb.iter().all(|&s| rb.contains(s)));
        assert!(sa.iter().all(|&s| !rb.contains(s)), "ranges overlap");
        assert_eq!(a.capacity(), 17, "mask width covers the shared array");
        assert_eq!(a.usable(), 8);
        assert_eq!(a.trash_slot(), 16);
    }

    #[test]
    fn ranged_cache_reset_refills_only_its_range() {
        let r = SlotRange { base: 4, len: 4 };
        let mut c = SlotCache::with_range(r, 12, 11);
        let s = c.alloc(3).unwrap();
        c.commit(s[0]);
        c.reset();
        assert_eq!(c.free_count(), 4);
        let again = c.alloc(4).unwrap();
        assert!(again.iter().all(|&x| r.contains(x)));
    }
}
