//! Slot-level KV-cache management.
//!
//! The L2 graphs treat the cache as a fixed-capacity array of *slots*
//! (DESIGN.md §7): each evaluated token writes its K/V at an arbitrary slot
//! and visibility is mask-encoded, so "memory management" reduces to a
//! free-list allocator plus the committed-slot set that [`MaskBuilder`]
//! (re)builds prefix rows from. Rejected draft slots are returned to the
//! free list and reused by the next iteration's tree — no copying, no
//! compaction, no rollback, which is exactly what keeps every operator
//! shape static for the AOT graphs.
//!
//! One reserved *trash slot* (the last slot) absorbs the K/V writes of
//! padding rows in width-padded calls; it is never marked visible.
//!
//! ## Shared-cache layouts (DESIGN.md §9–§10)
//!
//! For cross-session batched verification, many sessions share **one**
//! device cache array. Two layouts carve it up:
//!
//! * **Equal partition** ([`SlotPartition`], DESIGN.md §9) — the array is
//!   split into equal contiguous [`SlotRange`] regions, leased and
//!   released whole. Simple, but capacity is stranded: a short session
//!   idles most of its region while a long-prompt request is rejected.
//! * **Paged blocks** ([`BlockPool`], DESIGN.md §10) — the array is split
//!   into fixed-size *blocks*; a session's [`SlotCache`] leases blocks on
//!   demand as generation proceeds and returns fully-free blocks on
//!   rejection, completion, or disconnect. The session's usable slot set
//!   is a *set of owned blocks* ([`SlotOwnership::Blocks`]) instead of one
//!   contiguous range; slots are addressed indirectly either way, so
//!   nothing about the static graph shapes changes.
//!
//! In both layouts a session's per-row masks reference only slots it owns
//! ([`SlotOwnership::contains`]): *writable* slot sets are disjoint, so a
//! session can never reference another session's private slots and
//! cross-session batch masks stay block-diagonal. The one deliberate
//! exception is read-shared prefix blocks (§12 below): many sessions may
//! *read* the same cached prompt blocks, whose K/V all of them agree on
//! byte-for-byte.
//!
//! ## Cross-request prefix reuse (DESIGN.md §12)
//!
//! Paged blocks are **refcounted**: [`BlockPool::lease`] hands a block
//! out at refcount 1, [`BlockPool::retain`] lets a second holder map the
//! same block *read-shared*, and a block only returns to the free list
//! when its last reference releases. On top of that sits the
//! [`prefix::PrefixCache`] — a block-granularity radix trie keyed on
//! token ids that keeps fully-committed prompt blocks alive across
//! requests, so a request whose prompt starts with a cached prefix
//! attaches those blocks read-shared ([`SlotCache::attach_prefix`]) and
//! prefills only the uncached tail. Divergence is copy-on-write at block
//! granularity: the first partially-matched block is never shared — its
//! tokens re-prefill into the session's own exclusive blocks.

use std::sync::{Arc, Mutex};

use crate::tree::MaskBuilder;

pub mod prefix;

pub use prefix::{chunk_hashes, token_hash, PrefixCache, PrefixCacheStats, PrefixHit};

/// A contiguous run of slots inside a shared cache array — one session's
/// lease from a [`SlotPartition`], or one block of a [`BlockPool`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotRange {
    /// First slot of the range.
    pub base: u32,
    /// Number of slots in the range.
    pub len: u32,
}

impl SlotRange {
    /// True when `slot` lies inside this range.
    pub fn contains(&self, slot: u32) -> bool {
        slot >= self.base && slot < self.base + self.len
    }
}

/// Configuration error from cache partition / block-pool construction.
///
/// Construction used to panic on impossible layouts; the serving layer
/// now surfaces these as typed startup/admission failures instead of
/// taking down the worker thread.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CacheConfigError {
    /// The capacity cannot host `sessions` equal regions of ≥ 2 slots.
    RegionsDontFit {
        /// Total cache capacity (slots, incl. trash).
        capacity: usize,
        /// Requested session count.
        sessions: usize,
    },
    /// The block size is out of range for the capacity (must be ≥ 2 and
    /// leave room for at least one block plus the trash slot).
    BadBlockSize {
        /// Total cache capacity (slots, incl. trash).
        capacity: usize,
        /// Requested slots per block.
        block_size: usize,
    },
    /// The cache cannot host even one usable slot plus the reserved
    /// trash slot (capacity < 2) — computing `capacity - 1` for the
    /// trash slot would underflow.
    NoTrashSlot {
        /// Total cache capacity (slots).
        capacity: usize,
    },
    /// An explicit block budget exceeds what the capacity can host (or
    /// is zero).
    BadBlockCount {
        /// Total cache capacity (slots, incl. trash).
        capacity: usize,
        /// Requested slots per block.
        block_size: usize,
        /// Requested number of blocks.
        blocks: usize,
    },
}

impl std::fmt::Display for CacheConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CacheConfigError::RegionsDontFit { capacity, sessions } => write!(
                f,
                "cache capacity {capacity} cannot host {sessions} equal regions of ≥ 2 slots"
            ),
            CacheConfigError::BadBlockSize { capacity, block_size } => write!(
                f,
                "block size {block_size} is invalid for a {capacity}-slot cache \
                 (need 2 ≤ block_size ≤ capacity - 1)"
            ),
            CacheConfigError::NoTrashSlot { capacity } => write!(
                f,
                "cache capacity {capacity} cannot host one usable slot plus the \
                 reserved trash slot (need capacity ≥ 2)"
            ),
            CacheConfigError::BadBlockCount { capacity, block_size, blocks } => write!(
                f,
                "{blocks} blocks of {block_size} slots do not fit a {capacity}-slot cache \
                 (need 1 ≤ blocks ≤ (capacity - 1) / block_size)"
            ),
        }
    }
}

impl std::error::Error for CacheConfigError {}

/// Typed "the shared block pool ran dry" marker error.
///
/// Raised (wrapped in `anyhow`) when a *paged* [`SlotCache`] cannot lease
/// enough blocks mid-generation. The serving layer recognises it and
/// **preempts** the session — releasing its blocks and requeueing it for a
/// re-prefill resume — instead of failing the request: under paged
/// sharing, exhaustion usually means a neighbour holds the blocks, not
/// that the request is unservable.
#[derive(Debug, Clone)]
pub struct PoolExhausted {
    /// Which allocation ran dry (for the error message).
    pub what: &'static str,
}

impl std::fmt::Display for PoolExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "shared KV block pool exhausted during {}", self.what)
    }
}

impl std::error::Error for PoolExhausted {}

/// Typed failure from [`BlockPool::try_release`]: the caller tried to
/// return a block the pool never handed out, or one whose refcount is
/// already zero (a double release). `release` debug-asserts on these and
/// ignores them in release builds — the free list stays duplicate-free
/// either way.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BlockReleaseError {
    /// `block` is not a block of this pool.
    ForeignBlock {
        /// The offending block id.
        block: u32,
        /// Blocks the pool actually has.
        num_blocks: u32,
    },
    /// `block` is already fully released (refcount 0) — releasing it
    /// again would underflow the refcount and duplicate it in the free
    /// list.
    NotLeased {
        /// The offending block id.
        block: u32,
    },
}

impl std::fmt::Display for BlockReleaseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BlockReleaseError::ForeignBlock { block, num_blocks } => {
                write!(f, "foreign block {block} returned to a {num_blocks}-block pool")
            }
            BlockReleaseError::NotLeased { block } => {
                write!(f, "double release of block {block} (refcount already 0)")
            }
        }
    }
}

impl std::error::Error for BlockReleaseError {}

/// The slot set a session may reference — the confinement domain its mask
/// rows are checked against ([`crate::tree::rows_owned`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SlotOwnership {
    /// One contiguous range (equal-partition lease or a whole owned cache).
    Range(SlotRange),
    /// A set of owned fixed-size blocks (paged mode): block `b` covers
    /// slots `[b · block_size, (b + 1) · block_size)`.
    Blocks {
        /// Slots per block.
        block_size: u32,
        /// Exclusively owned block indices (allocatable + referenceable).
        blocks: Vec<u32>,
        /// Read-shared prefix-cache blocks (DESIGN.md §12): the session
        /// may *reference* their slots in mask rows (they hold its
        /// committed prompt prefix) but never allocates from them — the
        /// blocks are refcounted in the pool and may be mapped into many
        /// sessions at once.
        shared: Vec<u32>,
    },
}

impl SlotOwnership {
    /// True when `slot` is inside this ownership set (exclusive or
    /// read-shared).
    pub fn contains(&self, slot: u32) -> bool {
        match self {
            SlotOwnership::Range(r) => r.contains(slot),
            SlotOwnership::Blocks { block_size, blocks, shared } => {
                let b = slot / block_size;
                blocks.contains(&b) || shared.contains(&b)
            }
        }
    }
}

/// Carves one shared cache array into equal per-session regions — the
/// fixed-partition layout (DESIGN.md §9), kept as the `--equal-partition`
/// fallback next to the paged [`BlockPool`].
///
/// The last slot of the array stays reserved as the shared trash slot;
/// the remaining `capacity - 1` slots split into `sessions` equal regions
/// (any remainder is left unused). Regions are leased and released whole:
/// a session's [`SlotCache`] owns the lease for its lifetime, so slot
/// ownership never fragments across sessions.
#[derive(Debug, Clone)]
pub struct SlotPartition {
    total_capacity: usize,
    region_len: u32,
    free_bases: Vec<u32>,
}

impl SlotPartition {
    /// Partitions a `capacity`-slot cache into `sessions` equal regions.
    ///
    /// Errors when the split would leave a region without at least two
    /// usable slots (a region must hold at least one token beyond
    /// bookkeeping) — a typed config error the server surfaces as a
    /// startup/admission failure.
    pub fn new(capacity: usize, sessions: usize) -> Result<Self, CacheConfigError> {
        if sessions < 1 || capacity < 2 {
            return Err(CacheConfigError::RegionsDontFit { capacity, sessions });
        }
        let usable = capacity - 1; // last slot is the shared trash
        let region_len = (usable / sessions) as u32;
        if region_len < 2 {
            return Err(CacheConfigError::RegionsDontFit { capacity, sessions });
        }
        // Hand out low regions first (matches SlotCache's low-slot bias).
        let free_bases = (0..sessions as u32).map(|i| i * region_len).rev().collect();
        Ok(Self { total_capacity: capacity, region_len, free_bases })
    }

    /// The shared trash slot all sessions' padding rows scatter into.
    pub fn trash_slot(&self) -> u32 {
        self.total_capacity as u32 - 1
    }

    /// Total slots in the shared cache array (including trash).
    pub fn total_capacity(&self) -> usize {
        self.total_capacity
    }

    /// Slots per leased region.
    pub fn region_len(&self) -> u32 {
        self.region_len
    }

    /// Number of regions currently leasable.
    pub fn free_regions(&self) -> usize {
        self.free_bases.len()
    }

    /// Leases one region, or `None` when every region is taken (the
    /// serving layer surfaces this as an admission failure).
    pub fn lease(&mut self) -> Option<SlotRange> {
        self.free_bases.pop().map(|base| SlotRange { base, len: self.region_len })
    }

    /// Returns a leased region (called when its session drops).
    pub fn release(&mut self, range: SlotRange) {
        debug_assert_eq!(range.len, self.region_len, "foreign range returned");
        debug_assert!(
            range.base % self.region_len == 0,
            "misaligned range returned: base {}",
            range.base
        );
        debug_assert!(!self.free_bases.contains(&range.base), "double release");
        self.free_bases.push(range.base);
    }
}

/// A shared cache array carved into fixed-size *blocks* — the paged
/// layout (DESIGN.md §10) that replaces equal-region leasing for serving.
///
/// Block `b` covers slots `[b · block_size, (b + 1) · block_size)`; the
/// last slot of the array stays the shared trash slot and any remainder
/// short of a whole block is left unused. Sessions lease blocks **on
/// demand** through a paged [`SlotCache`] and return them the moment they
/// are fully free, so capacity follows the actual token footprint instead
/// of a worst-case per-session quota.
///
/// Blocks are **refcounted** (DESIGN.md §12): [`BlockPool::lease`] hands
/// a block out at refcount 1 (exclusive), [`BlockPool::retain`] adds a
/// read-shared reference (how the prefix cache maps one cached prompt
/// block into many sessions), and a block only rejoins the free list when
/// its last reference releases.
#[derive(Debug)]
pub struct BlockPool {
    total_capacity: usize,
    block_size: u32,
    num_blocks: u32,
    free: Vec<u32>,
    /// Per-block reference count; 0 = in the free list.
    refs: Vec<u32>,
    /// Bit per block, set while the prefix trie holds a reference to it —
    /// the "cached, reclaimable once nobody else references it" flag the
    /// LRU eviction pass and the admission signal read. A u64 bitset
    /// (`util::bits`) rather than `Vec<bool>`: 8× denser under the pool
    /// lock, same O(1) reads.
    cached: crate::util::bits::BitSet,
    /// Maintained count of blocks with `cached && refs == 1`, so the
    /// admission-path [`BlockPool::evictable_blocks`] gauge is O(1)
    /// instead of a full-pool scan under the pool lock.
    evictable: usize,
}

impl BlockPool {
    /// A pool over a `capacity`-slot cache with `block_size` slots per
    /// block. `max_blocks` optionally caps the pool below what the
    /// capacity could host (the `--cache-blocks` knob). Errors on layouts
    /// the capacity cannot host — typed, so the server can surface a
    /// startup/admission failure instead of panicking.
    pub fn new(
        capacity: usize,
        block_size: usize,
        max_blocks: Option<usize>,
    ) -> Result<Self, CacheConfigError> {
        if block_size < 2 || block_size + 1 > capacity {
            return Err(CacheConfigError::BadBlockSize { capacity, block_size });
        }
        let fit = (capacity - 1) / block_size;
        let num = match max_blocks {
            None => fit,
            Some(b) if (1..=fit).contains(&b) => b,
            Some(b) => {
                return Err(CacheConfigError::BadBlockCount { capacity, block_size, blocks: b })
            }
        };
        // Hand out low blocks first (matches the free-list's low-slot bias).
        let free = (0..num as u32).rev().collect();
        Ok(Self {
            total_capacity: capacity,
            block_size: block_size as u32,
            num_blocks: num as u32,
            free,
            refs: vec![0; num],
            cached: crate::util::bits::BitSet::new(num),
            evictable: 0,
        })
    }

    /// Total slots in the shared cache array (including trash).
    pub fn total_capacity(&self) -> usize {
        self.total_capacity
    }

    /// The shared trash slot all sessions' padding rows scatter into.
    pub fn trash_slot(&self) -> u32 {
        self.total_capacity as u32 - 1
    }

    /// Slots per block.
    pub fn block_size(&self) -> u32 {
        self.block_size
    }

    /// Total blocks in the pool.
    pub fn num_blocks(&self) -> usize {
        self.num_blocks as usize
    }

    /// Blocks currently leasable.
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Blocks currently leased to sessions (the occupancy gauge).
    pub fn blocks_in_use(&self) -> usize {
        self.num_blocks as usize - self.free.len()
    }

    /// The slot range block `block` covers.
    pub fn range_of(&self, block: u32) -> SlotRange {
        debug_assert!(block < self.num_blocks, "foreign block id {block}");
        SlotRange { base: block * self.block_size, len: self.block_size }
    }

    /// Leases one block (refcount 0 → 1), or `None` when the pool is dry
    /// (the serving layer evicts cached prefix blocks, then turns a
    /// still-dry pool mid-generation into a preemption).
    pub fn lease(&mut self) -> Option<u32> {
        let b = self.free.pop()?;
        debug_assert_eq!(self.refs[b as usize], 0, "free block {b} had live refs");
        self.refs[b as usize] = 1;
        Some(b)
    }

    /// Re-derives the maintained evictable counter around a mutation of
    /// block `i`'s refcount or cached flag: `before` is whether the block
    /// counted as evictable (`cached && refs == 1`) going in.
    fn fix_evictable(&mut self, i: usize, before: bool) {
        let now = self.cached.get(i) && self.refs[i] == 1;
        match (before, now) {
            (false, true) => self.evictable += 1,
            (true, false) => self.evictable -= 1,
            _ => {}
        }
    }

    /// Adds a read-shared reference to an already-leased block — how a
    /// cached prefix block gets mapped into another session's block table
    /// (DESIGN.md §12). Retaining a free block is a bug.
    pub fn retain(&mut self, block: u32) {
        debug_assert!(block < self.num_blocks, "foreign block retained: {block}");
        debug_assert!(self.refs[block as usize] > 0, "retain of free block {block}");
        let i = block as usize;
        let before = self.cached.get(i) && self.refs[i] == 1;
        self.refs[i] += 1;
        self.fix_evictable(i, before);
    }

    /// Current reference count of `block` (0 = free).
    pub fn ref_count(&self, block: u32) -> u32 {
        self.refs[block as usize]
    }

    /// Flags (or unflags) `block` as held by the prefix trie. Drives the
    /// [`BlockPool::evictable_blocks`] reclaim signal; the trie sets it
    /// when a block is donated and clears it on eviction.
    pub fn mark_cached(&mut self, block: u32, cached: bool) {
        debug_assert!(block < self.num_blocks, "foreign block flagged: {block}");
        debug_assert!(!cached || self.refs[block as usize] > 0, "caching a free block");
        let i = block as usize;
        let before = self.cached.get(i) && self.refs[i] == 1;
        self.cached.set(i, cached);
        self.fix_evictable(i, before);
    }

    /// True while the prefix trie holds a reference to `block`.
    pub fn is_cached(&self, block: u32) -> bool {
        self.cached.get(block as usize)
    }

    /// Blocks held *only* by the prefix trie (cached, refcount 1): what
    /// an LRU eviction pass could free right now. Admission counts these
    /// as reachable headroom — the pool reclaims them before any
    /// preemption is considered (DESIGN.md §12). O(1): the count is
    /// maintained across lease/retain/release/mark transitions, since
    /// this gauge sits on the admission hot path under the pool lock.
    pub fn evictable_blocks(&self) -> usize {
        self.evictable
    }

    /// Drops one reference to a leased block; the block rejoins the free
    /// list when the count hits zero. Double releases and foreign blocks
    /// surface as a typed [`BlockReleaseError`] instead of corrupting the
    /// free list.
    pub fn try_release(&mut self, block: u32) -> Result<(), BlockReleaseError> {
        if block >= self.num_blocks {
            return Err(BlockReleaseError::ForeignBlock { block, num_blocks: self.num_blocks });
        }
        let i = block as usize;
        if self.refs[i] == 0 {
            return Err(BlockReleaseError::NotLeased { block });
        }
        let before = self.cached.get(i) && self.refs[i] == 1;
        self.refs[i] -= 1;
        if self.refs[i] == 0 {
            debug_assert!(!self.free.contains(&block), "block {block} already in free list");
            self.cached.set(i, false);
            self.free.push(block);
        }
        self.fix_evictable(i, before);
        Ok(())
    }

    /// Returns a leased block ([`BlockPool::try_release`] with the error
    /// path asserted away: callers that track their own block tables
    /// cannot double-release except by bug).
    pub fn release(&mut self, block: u32) {
        let r = self.try_release(block);
        debug_assert!(r.is_ok(), "{}", r.unwrap_err());
    }
}

/// What backs a [`SlotCache`]'s allocatable slot set.
#[derive(Debug)]
enum Backing {
    /// A fixed contiguous range: a whole owned array, or an equal-partition
    /// lease. The slot set never changes over the cache's lifetime.
    Fixed(SlotRange),
    /// Blocks leased on demand from a shared [`BlockPool`] and returned
    /// as soon as they are fully free.
    Paged {
        pool: Arc<Mutex<BlockPool>>,
        block_size: u32,
        /// Exclusively owned (allocatable) blocks.
        blocks: Vec<u32>,
        /// Read-shared prefix-cache blocks (DESIGN.md §12): referenced by
        /// masks, never allocated from; one pool reference each, dropped
        /// on reset/drop.
        shared: Vec<u32>,
        /// The cross-request prefix cache eviction routes through when
        /// the pool runs dry.
        prefix: Option<Arc<Mutex<PrefixCache>>>,
    },
}

/// Slot allocator + committed-set tracker for one model's cache.
///
/// Owns a whole cache array ([`SlotCache::new`]), a leased [`SlotRange`]
/// of a shared array ([`SlotCache::with_range`]), or a dynamic set of
/// blocks of a shared [`BlockPool`] ([`SlotCache::paged`]); in every mode
/// it only ever hands out slots it owns exclusively, which is what keeps
/// cross-session masks block-diagonal in batched serving. Read-shared
/// prefix blocks ([`SlotCache::attach_prefix`], DESIGN.md §12) are
/// additionally *referenceable* — but never allocated from — and may be
/// mapped into many sessions at once.
#[derive(Debug)]
pub struct SlotCache {
    /// Size of the backing device array (the mask row width).
    total_capacity: usize,
    /// The (possibly shared) padding-row slot; never allocated.
    trash: u32,
    /// The most slots this cache could ever own (range length, or the
    /// whole pool) — the absolute generation ceiling.
    lease_limit: usize,
    backing: Backing,
    free: Vec<u32>, // LIFO free list (excludes the trash slot)
    committed: Vec<u32>,
    mask: MaskBuilder,
}

impl SlotCache {
    /// A cache owning a whole `capacity`-slot array (single-session mode):
    /// the last slot is the trash slot, everything else is allocatable.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 2, "need at least one usable slot plus trash");
        let range = SlotRange { base: 0, len: capacity as u32 - 1 };
        Self::with_range(range, capacity, capacity as u32 - 1)
    }

    /// A cache allocating only inside `range` of a `total_capacity`-slot
    /// shared array whose padding rows scatter into `trash` (equal-
    /// partition batching mode; see [`SlotPartition`]).
    pub fn with_range(range: SlotRange, total_capacity: usize, trash: u32) -> Self {
        assert!(range.len >= 1, "empty slot range");
        assert!(
            (range.base + range.len) as usize <= total_capacity,
            "range beyond cache capacity"
        );
        assert!(!range.contains(trash), "trash slot inside allocatable range");
        // Hand out low slots first (helps locality of the scatter).
        let free = (range.base..range.base + range.len).rev().collect();
        Self {
            total_capacity,
            trash,
            lease_limit: range.len as usize,
            backing: Backing::Fixed(range),
            free,
            committed: Vec::new(),
            mask: MaskBuilder::new(total_capacity),
        }
    }

    /// A cache leasing blocks of `pool` on demand (paged batching mode;
    /// DESIGN.md §10). Starts with no blocks: the first `alloc` leases.
    pub fn paged(pool: Arc<Mutex<BlockPool>>) -> Self {
        Self::paged_inner(pool, None)
    }

    /// A paged cache wired to a cross-request [`PrefixCache`] (DESIGN.md
    /// §12) that has this cache's pool as one of its sides. A dry pool
    /// first evicts unreferenced cached prefix blocks (LRU) before an
    /// allocation fails, and [`SlotCache::available`] counts those
    /// evictable blocks as reachable headroom.
    pub fn paged_with_prefix(pool: Arc<Mutex<BlockPool>>, prefix: Arc<Mutex<PrefixCache>>) -> Self {
        Self::paged_inner(pool, Some(prefix))
    }

    fn paged_inner(pool: Arc<Mutex<BlockPool>>, prefix: Option<Arc<Mutex<PrefixCache>>>) -> Self {
        let (total_capacity, trash, block_size, limit) = {
            let p = pool.lock().unwrap();
            (
                p.total_capacity(),
                p.trash_slot(),
                p.block_size(),
                p.num_blocks() * p.block_size() as usize,
            )
        };
        Self {
            total_capacity,
            trash,
            lease_limit: limit,
            backing: Backing::Paged {
                pool,
                block_size,
                blocks: Vec::new(),
                shared: Vec::new(),
                prefix,
            },
            free: Vec::new(),
            committed: Vec::new(),
            mask: MaskBuilder::new(total_capacity),
        }
    }

    /// The reserved slot padding rows scatter their K/V into.
    pub fn trash_slot(&self) -> u32 {
        self.trash
    }

    /// Size of the backing device array (the mask row width) — **not**
    /// this cache's allocatable slot count; see [`SlotCache::usable`].
    pub fn capacity(&self) -> usize {
        self.total_capacity
    }

    /// Slots this cache currently owns or shares (range length, or
    /// exclusive + read-shared blocks × block size — grows and shrinks in
    /// paged mode).
    pub fn usable(&self) -> usize {
        match &self.backing {
            Backing::Fixed(r) => r.len as usize,
            Backing::Paged { block_size, blocks, shared, .. } => {
                (blocks.len() + shared.len()) * *block_size as usize
            }
        }
    }

    /// The most slots this cache could ever own: its fixed range length,
    /// or the whole block pool. `committed` can never exceed this — the
    /// absolute generation ceiling paged tasks stop at.
    pub fn lease_limit(&self) -> usize {
        self.lease_limit
    }

    /// True when this cache leases blocks of a shared [`BlockPool`].
    pub fn is_paged(&self) -> bool {
        matches!(self.backing, Backing::Paged { .. })
    }

    /// Blocks currently leased exclusively (paged mode; 0 otherwise).
    pub fn owned_blocks(&self) -> usize {
        match &self.backing {
            Backing::Fixed(_) => 0,
            Backing::Paged { blocks, .. } => blocks.len(),
        }
    }

    /// Read-shared prefix blocks currently attached (paged mode with a
    /// prefix cache; 0 otherwise).
    pub fn shared_blocks(&self) -> usize {
        match &self.backing {
            Backing::Fixed(_) => 0,
            Backing::Paged { shared, .. } => shared.len(),
        }
    }

    /// The slot set this cache may reference — the confinement domain
    /// its mask rows are checked against (see [`crate::tree::rows_owned`]).
    pub fn ownership(&self) -> SlotOwnership {
        match &self.backing {
            Backing::Fixed(r) => SlotOwnership::Range(*r),
            Backing::Paged { block_size, blocks, shared, .. } => SlotOwnership::Blocks {
                block_size: *block_size,
                blocks: blocks.clone(),
                shared: shared.clone(),
            },
        }
    }

    /// True when this cache currently owns every slot in `slots` — the
    /// drafter-side confinement check the batched draft phase asserts
    /// before a session's rows join a packed call (DESIGN.md §11).
    pub fn owns_all(&self, slots: &[u32]) -> bool {
        slots.iter().all(|&s| self.owns(s))
    }

    /// True when this cache currently owns `slot` (exclusively, or as a
    /// read-shared prefix block).
    pub fn owns(&self, slot: u32) -> bool {
        match &self.backing {
            Backing::Fixed(r) => r.contains(slot),
            Backing::Paged { block_size, blocks, shared, .. } => {
                let b = slot / *block_size;
                blocks.contains(&b) || shared.contains(&b)
            }
        }
    }

    /// Currently free (allocatable) slots already owned by this cache.
    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    /// Slots allocatable *right now*: the local free list plus (in paged
    /// mode) everything still leasable from the shared pool — including
    /// cached prefix blocks nobody references, which the LRU eviction
    /// pass reclaims on demand before any preemption (DESIGN.md §12).
    /// This is the token-level admission signal — the pool either covers
    /// a request's prompt + tree budget or it does not, regardless of how
    /// the slots fragment across blocks.
    pub fn available(&self) -> usize {
        let pooled = match &self.backing {
            Backing::Fixed(_) => 0,
            Backing::Paged { pool, block_size, prefix, .. } => {
                let p = pool.lock().unwrap();
                let reclaimable =
                    p.free_blocks() + if prefix.is_some() { p.evictable_blocks() } else { 0 };
                reclaimable * *block_size as usize
            }
        };
        self.free.len() + pooled
    }

    /// Slots currently held (committed prefix + outstanding draft slots;
    /// excludes the trash slot). The serving layer aggregates this across
    /// live sessions for its KV-utilization gauge, and the cancellation
    /// tests assert it returns to zero once a session is dropped.
    pub fn in_use(&self) -> usize {
        self.usable() - self.free.len()
    }

    /// Number of committed (always-visible) slots.
    pub fn committed_len(&self) -> usize {
        self.committed.len()
    }

    /// The committed slots, in commit order.
    pub fn committed(&self) -> &[u32] {
        &self.committed
    }

    /// Allocates `n` slots for draft/tree tokens, leasing blocks from the
    /// shared pool on demand in paged mode. A dry pool first reclaims
    /// unreferenced cached prefix blocks through the LRU eviction pass
    /// (DESIGN.md §12: eviction strictly before preemption). Returns
    /// `None` when the cache (or pool) still cannot host the tree —
    /// callers shrink the envelope, or surface [`SlotCache::exhausted`]
    /// so the serving layer can preempt.
    pub fn alloc(&mut self, n: usize) -> Option<Vec<u32>> {
        if self.free.len() < n {
            self.lease_blocks(n);
            if self.free.len() < n {
                // Eviction before preemption: ask the prefix cache to
                // free LRU blocks nobody references, then re-lease. The
                // pool lock is not held here (the eviction pass takes
                // prefix → pool itself).
                let evict = match &self.backing {
                    Backing::Paged { prefix: Some(pc), block_size, .. } => {
                        Some((Arc::clone(pc), *block_size as usize))
                    }
                    _ => None,
                };
                if let Some((pc, bs)) = evict {
                    let need = (n - self.free.len()).div_ceil(bs);
                    if pc.lock().unwrap().evict(need) > 0 {
                        self.lease_blocks(n);
                    }
                }
            }
            if self.free.len() < n {
                // Return any fully-free blocks a failed lease loop left
                // behind so two starved sessions cannot hoard each other
                // to death.
                self.shrink();
                return None;
            }
        }
        Some((0..n).map(|_| self.free.pop().unwrap()).collect())
    }

    /// Leases pool blocks until the local free list covers `n` slots (or
    /// the pool runs dry). No-op for fixed-range caches.
    fn lease_blocks(&mut self, n: usize) {
        if let Backing::Paged { pool, blocks, .. } = &mut self.backing {
            let mut p = pool.lock().unwrap();
            while self.free.len() < n {
                let Some(b) = p.lease() else { break };
                let r = p.range_of(b);
                blocks.push(b);
                // Low slots first, matching the fixed-mode bias.
                self.free.extend((r.base..r.base + r.len).rev());
            }
        }
    }

    /// Maps cached prefix blocks read-shared into this cache (DESIGN.md
    /// §12): every slot of every block becomes part of the committed
    /// prefix (mask-visible to all future rows) without consuming any new
    /// pool block. The pool references were already taken by
    /// [`PrefixCache::acquire`] and transfer to this cache — reset/drop
    /// releases them. Must run before any prefill commits (the committed
    /// sequence must start with the shared prefix).
    pub fn attach_prefix(&mut self, attach: &[u32]) {
        let Backing::Paged { pool, shared, .. } = &mut self.backing else {
            panic!("attach_prefix on a non-paged cache");
        };
        debug_assert!(self.committed.is_empty(), "prefix attach must precede prefill");
        let p = pool.lock().unwrap();
        for &b in attach {
            let r = p.range_of(b);
            shared.push(b);
            for s in r.base..r.base + r.len {
                self.committed.push(s);
                self.mask.commit_slot(s);
            }
        }
    }

    /// The exclusively-owned block holding committed chunk `chunk` —
    /// `Some` only when the chunk's `block_size` committed slots fill
    /// exactly one owned block (the donation purity condition: nothing
    /// else lives in the block, so its K/V is precisely those tokens).
    fn chunk_block(&self, chunk: usize) -> Option<u32> {
        let Backing::Paged { block_size, blocks, .. } = &self.backing else { return None };
        let bs = *block_size as usize;
        let lo = chunk * bs;
        if self.committed.len() < lo + bs {
            return None;
        }
        let slots = &self.committed[lo..lo + bs];
        let b = slots[0] / *block_size;
        if !blocks.contains(&b) {
            return None; // shared or foreign: not ours to donate
        }
        // `block_size` distinct committed slots inside one block cover it
        // entirely, so purity reduces to same-block membership.
        slots.iter().all(|&s| s / *block_size == b).then_some(b)
    }

    /// True when committed chunk `chunk` (tokens `[chunk·bs, (chunk+1)·bs)`
    /// of the committed sequence) could be donated to the prefix trie.
    pub fn can_donate_chunk(&self, chunk: usize) -> bool {
        self.chunk_block(chunk).is_some()
    }

    /// Splits committed chunk `chunk`'s block out of the owned set for
    /// donation to the prefix trie: the pool reference moves to the trie
    /// instead of being released. The cache must be reset or dropped
    /// right after the insertion walk — its committed bookkeeping still
    /// names the donated slots, which is only sound during teardown.
    pub fn take_donated_chunk(&mut self, chunk: usize) -> Option<u32> {
        let b = self.chunk_block(chunk)?;
        let Backing::Paged { blocks, .. } = &mut self.backing else { unreachable!() };
        let i = blocks.iter().position(|&x| x == b).unwrap();
        blocks.swap_remove(i);
        Some(b)
    }

    /// The error a failed [`SlotCache::alloc`] should surface: the typed
    /// [`PoolExhausted`] marker in paged mode (the serving layer preempts
    /// and requeues the session on it), a plain terminal message
    /// otherwise (a session-local cache running dry cannot be fixed by
    /// anyone else's blocks).
    pub fn exhausted(&self, what: &'static str) -> anyhow::Error {
        if self.is_paged() {
            anyhow::Error::new(PoolExhausted { what })
        } else {
            anyhow::anyhow!("KV cache exhausted during {what}")
        }
    }

    /// Returns draft slots that did not get committed. In paged mode any
    /// block that became fully free goes straight back to the shared pool
    /// (rejection is exactly when capacity should flow between sessions).
    pub fn release(&mut self, slots: &[u32]) {
        for &s in slots {
            debug_assert!(s != self.trash);
            debug_assert!(self.owns(s), "releasing foreign slot {s}");
            debug_assert!(!self.committed.contains(&s), "releasing committed slot {s}");
            self.free.push(s);
        }
        self.shrink();
    }

    /// Returns every fully-free owned block to the shared pool (no-op for
    /// fixed-range caches). A block stays leased while any of its slots
    /// is committed or outstanding.
    fn shrink(&mut self) {
        let Backing::Paged { pool, blocks, .. } = &mut self.backing else { return };
        if blocks.is_empty() {
            return;
        }
        let mut p = pool.lock().unwrap();
        let bs = p.block_size() as usize;
        let mut i = 0;
        while i < blocks.len() {
            let r = p.range_of(blocks[i]);
            let free_in = self.free.iter().filter(|&&s| r.contains(s)).count();
            if free_in == bs {
                self.free.retain(|&s| !r.contains(s));
                p.release(blocks.swap_remove(i));
            } else {
                i += 1;
            }
        }
    }

    /// Promotes a draft slot to the committed prefix (visible to all
    /// future tokens of this session).
    pub fn commit(&mut self, slot: u32) {
        debug_assert!(self.owns(slot), "committing foreign slot {slot}");
        debug_assert!(!self.committed.contains(&slot));
        self.committed.push(slot);
        self.mask.commit_slot(slot);
    }

    /// Forgets everything (session reset). Stale K/V data stays in the
    /// device buffer but is unreachable — masks make it invisible. Paged
    /// caches return every block to the shared pool.
    pub fn reset(&mut self) {
        for &s in &self.committed {
            self.mask.release_slot(s);
        }
        self.committed.clear();
        match &mut self.backing {
            Backing::Fixed(r) => {
                self.free = (r.base..r.base + r.len).rev().collect();
            }
            Backing::Paged { pool, blocks, shared, .. } => {
                self.free.clear();
                let mut p = pool.lock().unwrap();
                for b in blocks.drain(..) {
                    p.release(b);
                }
                // Shared prefix blocks: drop this session's read
                // reference (the trie's own reference keeps them cached).
                for b in shared.drain(..) {
                    p.release(b);
                }
            }
        }
    }

    /// The mask builder whose prefix row tracks this cache's commits.
    pub fn mask_builder(&mut self) -> &mut MaskBuilder {
        &mut self.mask
    }

    /// Remaining generation headroom in tokens, keeping `tree_budget`
    /// slots available for drafting. Counts the shared pool in paged mode
    /// (the admission formula: admit while the pool covers prompt + tree
    /// budget).
    pub fn headroom(&self, tree_budget: usize) -> usize {
        self.available().saturating_sub(tree_budget)
    }
}

impl Drop for SlotCache {
    fn drop(&mut self) {
        // Paged sessions return every leased block — and drop their
        // read-shared prefix references — on completion, cancellation or
        // preemption; fixed ranges are returned by their partition's
        // owner.
        if let Backing::Paged { pool, blocks, shared, .. } = &mut self.backing {
            if let Ok(mut p) = pool.lock() {
                for b in blocks.drain(..) {
                    p.release(b);
                }
                for b in shared.drain(..) {
                    p.release(b);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_roundtrip() {
        let mut c = SlotCache::new(8);
        assert_eq!(c.free_count(), 7); // one slot reserved as trash
        assert_eq!(c.in_use(), 0);
        let s = c.alloc(3).unwrap();
        assert_eq!(s.len(), 3);
        assert_eq!(c.free_count(), 4);
        assert_eq!(c.in_use(), 3);
        c.release(&s);
        assert_eq!(c.free_count(), 7);
        assert_eq!(c.in_use(), 0);
    }

    #[test]
    fn alloc_fails_when_exhausted() {
        let mut c = SlotCache::new(4);
        assert!(c.alloc(3).is_some());
        assert!(c.alloc(1).is_none());
    }

    #[test]
    fn trash_slot_is_never_allocated() {
        let mut c = SlotCache::new(4);
        let all = c.alloc(3).unwrap();
        assert!(!all.contains(&c.trash_slot()));
    }

    #[test]
    fn commit_updates_prefix_row() {
        let mut c = SlotCache::new(4);
        let s = c.alloc(2).unwrap();
        c.commit(s[0]);
        assert_eq!(c.committed_len(), 1);
        assert_eq!(c.mask_builder().committed_count(), 1);
    }

    #[test]
    fn reset_restores_everything() {
        let mut c = SlotCache::new(6);
        let s = c.alloc(4).unwrap();
        c.commit(s[0]);
        c.commit(s[1]);
        c.release(&s[2..]);
        c.reset();
        assert_eq!(c.free_count(), 5);
        assert_eq!(c.committed_len(), 0);
        assert_eq!(c.mask_builder().committed_count(), 0);
    }

    #[test]
    fn headroom_reserves_tree_budget() {
        let c = SlotCache::new(74); // 73 usable
        assert_eq!(c.headroom(64), 9);
        assert_eq!(c.headroom(100), 0);
    }

    #[test]
    fn slots_are_reused_lifo() {
        let mut c = SlotCache::new(8);
        let a = c.alloc(2).unwrap();
        c.release(&a);
        let b = c.alloc(2).unwrap();
        assert_eq!(b[0], a[1]);
        assert_eq!(b[1], a[0]);
    }

    #[test]
    fn partition_carves_equal_regions_with_shared_trash() {
        let mut p = SlotPartition::new(321, 4).unwrap(); // 320 usable → 80 per region
        assert_eq!(p.region_len(), 80);
        assert_eq!(p.trash_slot(), 320);
        assert_eq!(p.free_regions(), 4);
        let a = p.lease().unwrap();
        let b = p.lease().unwrap();
        assert_eq!(a, SlotRange { base: 0, len: 80 });
        assert_eq!(b, SlotRange { base: 80, len: 80 });
        assert_eq!(p.free_regions(), 2);
        p.release(a);
        assert_eq!(p.free_regions(), 3);
        // The freed region is leasable again.
        assert_eq!(p.lease().unwrap(), a);
    }

    #[test]
    fn partition_exhausts_then_refills() {
        let mut p = SlotPartition::new(9, 2).unwrap(); // 8 usable → 4 per region
        let a = p.lease().unwrap();
        let b = p.lease().unwrap();
        assert!(p.lease().is_none());
        p.release(b);
        p.release(a);
        assert_eq!(p.free_regions(), 2);
    }

    #[test]
    fn partition_rejects_impossible_layouts_with_typed_errors() {
        assert_eq!(
            SlotPartition::new(9, 5).unwrap_err(),
            CacheConfigError::RegionsDontFit { capacity: 9, sessions: 5 }
        );
        assert!(SlotPartition::new(1, 1).is_err());
        assert!(SlotPartition::new(100, 0).is_err());
        // The error renders a human-readable admission message.
        let msg = SlotPartition::new(9, 5).unwrap_err().to_string();
        assert!(msg.contains("9") && msg.contains("5"), "uninformative: {msg}");
    }

    #[test]
    fn ranged_cache_stays_inside_its_lease() {
        let mut p = SlotPartition::new(17, 2).unwrap(); // 16 usable → 8 per region
        let ra = p.lease().unwrap();
        let rb = p.lease().unwrap();
        let mut a = SlotCache::with_range(ra, 17, p.trash_slot());
        let mut b = SlotCache::with_range(rb, 17, p.trash_slot());
        let sa = a.alloc(8).unwrap();
        let sb = b.alloc(8).unwrap();
        assert!(a.alloc(1).is_none(), "range exhausted");
        assert!(sa.iter().all(|&s| ra.contains(s)));
        assert!(sb.iter().all(|&s| rb.contains(s)));
        assert!(sa.iter().all(|&s| !rb.contains(s)), "ranges overlap");
        assert_eq!(a.capacity(), 17, "mask width covers the shared array");
        assert_eq!(a.usable(), 8);
        assert_eq!(a.trash_slot(), 16);
        assert_eq!(a.ownership(), SlotOwnership::Range(ra));
    }

    #[test]
    fn ranged_cache_reset_refills_only_its_range() {
        let r = SlotRange { base: 4, len: 4 };
        let mut c = SlotCache::with_range(r, 12, 11);
        let s = c.alloc(3).unwrap();
        c.commit(s[0]);
        c.reset();
        assert_eq!(c.free_count(), 4);
        let again = c.alloc(4).unwrap();
        assert!(again.iter().all(|&x| r.contains(x)));
    }

    // ---------------------------------------------------------------
    // Paged block pool
    // ---------------------------------------------------------------

    #[test]
    fn block_pool_layout_and_lease_roundtrip() {
        let mut p = BlockPool::new(33, 8, None).unwrap(); // 32 usable → 4 blocks
        assert_eq!(p.num_blocks(), 4);
        assert_eq!(p.block_size(), 8);
        assert_eq!(p.trash_slot(), 32);
        assert_eq!(p.free_blocks(), 4);
        assert_eq!(p.blocks_in_use(), 0);
        let a = p.lease().unwrap();
        assert_eq!(p.range_of(a), SlotRange { base: a * 8, len: 8 });
        assert_eq!(p.blocks_in_use(), 1);
        p.release(a);
        assert_eq!(p.free_blocks(), 4);
    }

    #[test]
    fn block_pool_rejects_bad_layouts_with_typed_errors() {
        assert_eq!(
            BlockPool::new(8, 1, None).unwrap_err(),
            CacheConfigError::BadBlockSize { capacity: 8, block_size: 1 }
        );
        assert!(BlockPool::new(8, 8, None).is_err(), "no room for the trash slot");
        assert_eq!(
            BlockPool::new(33, 8, Some(5)).unwrap_err(),
            CacheConfigError::BadBlockCount { capacity: 33, block_size: 8, blocks: 5 }
        );
        assert!(BlockPool::new(33, 8, Some(0)).is_err());
        // An explicit budget below the fit is a valid way to reserve
        // device capacity for something else.
        assert_eq!(BlockPool::new(33, 8, Some(2)).unwrap().num_blocks(), 2);
    }

    fn pool(capacity: usize, block_size: usize) -> Arc<Mutex<BlockPool>> {
        Arc::new(Mutex::new(BlockPool::new(capacity, block_size, None).unwrap()))
    }

    #[test]
    fn paged_cache_leases_blocks_on_demand() {
        let p = pool(33, 8); // 4 blocks
        let mut c = SlotCache::paged(p.clone());
        assert_eq!(c.owned_blocks(), 0);
        assert_eq!(c.available(), 32, "whole pool reachable before any lease");
        let s = c.alloc(10).unwrap(); // needs 2 blocks
        assert_eq!(c.owned_blocks(), 2);
        assert_eq!(p.lock().unwrap().free_blocks(), 2);
        assert!(s.iter().all(|&x| c.owns(x)));
        assert_eq!(c.in_use(), 10);
        assert_eq!(c.free_count(), 6);
    }

    #[test]
    fn paged_cache_returns_fully_free_blocks_on_release() {
        let p = pool(33, 8);
        let mut c = SlotCache::paged(p.clone());
        let s = c.alloc(16).unwrap(); // 2 whole blocks
        c.commit(s[0]); // pins the first allocated slot's block
        c.release(&s[1..]);
        // The block holding the committed slot stays; the other returns.
        assert_eq!(c.owned_blocks(), 1);
        assert_eq!(p.lock().unwrap().free_blocks(), 3);
        assert!(c.owns(s[0]));
    }

    #[test]
    fn paged_cache_drop_returns_every_block() {
        let p = pool(33, 8);
        {
            let mut c = SlotCache::paged(p.clone());
            let s = c.alloc(20).unwrap();
            c.commit(s[0]);
            c.commit(s[1]);
            assert!(p.lock().unwrap().free_blocks() < 4);
        }
        assert_eq!(p.lock().unwrap().free_blocks(), 4, "drop must return all blocks");
    }

    #[test]
    fn paged_alloc_fails_without_hoarding_when_pool_dry() {
        let p = pool(17, 8); // 2 blocks
        let mut a = SlotCache::paged(p.clone());
        let mut b = SlotCache::paged(p.clone());
        let held = a.alloc(12).unwrap(); // takes both blocks
        assert!(b.alloc(4).is_none(), "pool dry");
        assert_eq!(b.owned_blocks(), 0, "failed alloc must not hoard blocks");
        a.release(&held);
        assert_eq!(p.lock().unwrap().free_blocks(), 2);
        assert!(b.alloc(4).is_some(), "freed blocks are leasable again");
    }

    #[test]
    fn paged_exhaustion_error_is_typed_for_preemption() {
        let p = pool(17, 8);
        let c = SlotCache::paged(p);
        let e = c.exhausted("unit test");
        assert!(e.is::<PoolExhausted>(), "paged exhaustion must downcast");
        // Fixed-range exhaustion is terminal, not preemptible.
        let f = SlotCache::new(4).exhausted("unit test");
        assert!(!f.is::<PoolExhausted>());
    }

    #[test]
    fn paged_headroom_counts_the_shared_pool() {
        let p = pool(33, 8);
        let mut a = SlotCache::paged(p.clone());
        let b = SlotCache::paged(p);
        let _s = a.alloc(8).unwrap(); // one block gone
        assert_eq!(b.available(), 24);
        assert_eq!(b.headroom(8), 16);
        assert_eq!(a.lease_limit(), 32);
    }

    #[test]
    fn no_trash_slot_error_renders_capacity() {
        let e = CacheConfigError::NoTrashSlot { capacity: 0 };
        let msg = e.to_string();
        assert!(msg.contains('0') && msg.contains("trash"), "uninformative: {msg}");
    }

    #[test]
    fn owns_all_checks_every_slot() {
        let p = pool(33, 8);
        let mut c = SlotCache::paged(p);
        let s = c.alloc(4).unwrap();
        assert!(c.owns_all(&s));
        assert!(!c.owns_all(&[s[0], 32]), "trash slot is never owned");
        assert!(c.owns_all(&[]), "vacuously true on empty");
    }

    #[test]
    fn block_ownership_contains_matches_block_math() {
        let own =
            SlotOwnership::Blocks { block_size: 4, blocks: vec![0, 3], shared: vec![] };
        for s in 0..4 {
            assert!(own.contains(s), "slot {s} is in block 0");
        }
        for s in 4..12 {
            assert!(!own.contains(s), "slot {s} is in an unowned block");
        }
        for s in 12..16 {
            assert!(own.contains(s), "slot {s} is in block 3");
        }
        // Read-shared prefix blocks count as referenceable too.
        let own =
            SlotOwnership::Blocks { block_size: 4, blocks: vec![0], shared: vec![2] };
        assert!(own.contains(9), "slot 9 is in shared block 2");
        assert!(!own.contains(5));
    }

    // ---------------------------------------------------------------
    // Refcounted blocks + prefix attach/donate (DESIGN.md §12)
    // ---------------------------------------------------------------

    #[test]
    fn block_release_is_hardened_against_double_release() {
        let mut p = BlockPool::new(33, 8, None).unwrap();
        let a = p.lease().unwrap();
        assert_eq!(p.ref_count(a), 1);
        assert!(p.try_release(a).is_ok());
        assert_eq!(p.ref_count(a), 0);
        // Second release: typed error, free list untouched.
        assert_eq!(p.try_release(a), Err(BlockReleaseError::NotLeased { block: a }));
        assert_eq!(
            p.try_release(99),
            Err(BlockReleaseError::ForeignBlock { block: 99, num_blocks: 4 })
        );
        // Free-list invariant: no block appears twice — leasing the whole
        // pool yields each block exactly once.
        let mut leased: Vec<u32> = (0..4).map(|_| p.lease().unwrap()).collect();
        assert!(p.lease().is_none());
        leased.sort_unstable();
        leased.dedup();
        assert_eq!(leased.len(), 4, "free list held a duplicate block");
        // The error messages are informative.
        let msg = BlockReleaseError::NotLeased { block: 7 }.to_string();
        assert!(msg.contains('7') && msg.contains("release"), "uninformative: {msg}");
    }

    #[test]
    fn retained_blocks_free_only_at_refcount_zero() {
        let mut p = BlockPool::new(33, 8, None).unwrap();
        let b = p.lease().unwrap();
        p.retain(b);
        assert_eq!(p.ref_count(b), 2);
        p.release(b);
        assert_eq!(p.ref_count(b), 1);
        assert_eq!(p.free_blocks(), 3, "block stays leased while referenced");
        p.release(b);
        assert_eq!(p.free_blocks(), 4);
        // Releasing past zero is the typed double-release error again.
        assert!(p.try_release(b).is_err());
    }

    #[test]
    fn cached_flag_drives_the_evictable_gauge() {
        let mut p = BlockPool::new(33, 8, None).unwrap();
        let a = p.lease().unwrap();
        let b = p.lease().unwrap();
        p.mark_cached(a, true);
        p.mark_cached(b, true);
        assert_eq!(p.evictable_blocks(), 2);
        // A session attaching block `a` read-shared pins it.
        p.retain(a);
        assert_eq!(p.evictable_blocks(), 1);
        p.release(a);
        assert_eq!(p.evictable_blocks(), 2);
        // Releasing the trie's reference clears the flag with the block.
        p.release(b);
        assert!(!p.is_cached(b));
        assert_eq!(p.evictable_blocks(), 1);
    }

    #[test]
    fn attach_prefix_commits_shared_slots_without_new_blocks() {
        let p = pool(33, 8);
        // Donor leases a block the "trie" will share out.
        let cached = p.lock().unwrap().lease().unwrap();
        p.lock().unwrap().retain(cached); // the attaching session's reference
        let mut c = SlotCache::paged(p.clone());
        c.attach_prefix(&[cached]);
        assert_eq!(c.shared_blocks(), 1);
        assert_eq!(c.owned_blocks(), 0, "attach consumes no new pool block");
        assert_eq!(c.committed_len(), 8, "every shared slot is committed");
        assert_eq!(c.mask_builder().committed_count(), 8);
        let own = c.ownership();
        let r = p.lock().unwrap().range_of(cached);
        assert!((r.base..r.base + r.len).all(|s| own.contains(s) && c.owns(s)));
        // Dropping the session releases only its read reference.
        drop(c);
        assert_eq!(p.lock().unwrap().ref_count(cached), 1);
        p.lock().unwrap().release(cached);
        assert_eq!(p.lock().unwrap().free_blocks(), 4);
    }

    #[test]
    fn chunk_donation_requires_a_pure_fully_committed_block() {
        let p = pool(33, 8);
        let mut c = SlotCache::paged(p.clone());
        let s = c.alloc(12).unwrap();
        for &sl in &s[..10] {
            c.commit(sl);
        }
        c.release(&s[10..]);
        // Chunk 0: 8 committed slots filling one block — donatable.
        assert!(c.can_donate_chunk(0));
        // Chunk 1: only 2 committed slots — not a full chunk.
        assert!(!c.can_donate_chunk(1));
        let b = c.take_donated_chunk(0).unwrap();
        assert_eq!(c.owned_blocks(), 1, "donated block left the owned set");
        // The pool reference moved with the donation: dropping the cache
        // must NOT free the donated block.
        drop(c);
        assert_eq!(p.lock().unwrap().ref_count(b), 1);
        assert_eq!(p.lock().unwrap().free_blocks(), 3);
    }

    #[test]
    fn paged_reset_returns_blocks_and_clears_commits() {
        let p = pool(33, 8);
        let mut c = SlotCache::paged(p.clone());
        let s = c.alloc(12).unwrap();
        c.commit(s[0]);
        c.reset();
        assert_eq!(c.owned_blocks(), 0);
        assert_eq!(c.committed_len(), 0);
        assert_eq!(p.lock().unwrap().free_blocks(), 4);
        assert_eq!(c.mask_builder().committed_count(), 0);
    }
}
