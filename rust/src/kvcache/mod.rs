//! Slot-level KV-cache management.
//!
//! The L2 graphs treat the cache as a fixed-capacity array of *slots*
//! (DESIGN.md §7): each evaluated token writes its K/V at an arbitrary slot
//! and visibility is mask-encoded, so "memory management" reduces to a
//! free-list allocator plus the committed-slot set that [`MaskBuilder`]
//! (re)builds prefix rows from. Rejected draft slots are returned to the
//! free list and reused by the next iteration's tree — no copying, no
//! compaction, no rollback, which is exactly what keeps every operator
//! shape static for the AOT graphs.
//!
//! One reserved *trash slot* (the last slot) absorbs the K/V writes of
//! padding rows in width-padded calls; it is never marked visible.

use crate::tree::MaskBuilder;

/// Slot allocator + committed-set tracker for one model's cache.
#[derive(Debug, Clone)]
pub struct SlotCache {
    capacity: usize,
    free: Vec<u32>, // LIFO free list (excludes the trash slot)
    committed: Vec<u32>,
    mask: MaskBuilder,
}

impl SlotCache {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 2, "need at least one usable slot plus trash");
        // Hand out low slots first (helps locality of the scatter).
        let free = (0..capacity as u32 - 1).rev().collect();
        Self { capacity, free, committed: Vec::new(), mask: MaskBuilder::new(capacity) }
    }

    /// The reserved slot padding rows scatter their K/V into.
    pub fn trash_slot(&self) -> u32 {
        self.capacity as u32 - 1
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    /// Slots currently held (committed prefix + outstanding draft slots;
    /// excludes the trash slot). The serving layer aggregates this across
    /// live sessions for its KV-utilization gauge, and the cancellation
    /// tests assert it returns to zero once a session is dropped.
    pub fn in_use(&self) -> usize {
        self.capacity - 1 - self.free.len()
    }

    pub fn committed_len(&self) -> usize {
        self.committed.len()
    }

    pub fn committed(&self) -> &[u32] {
        &self.committed
    }

    /// Allocates `n` slots for draft/tree tokens. Returns `None` when the
    /// cache cannot host the tree (callers shrink the envelope).
    pub fn alloc(&mut self, n: usize) -> Option<Vec<u32>> {
        if self.free.len() < n {
            return None;
        }
        Some((0..n).map(|_| self.free.pop().unwrap()).collect())
    }

    /// Returns draft slots that did not get committed.
    pub fn release(&mut self, slots: &[u32]) {
        for &s in slots {
            debug_assert!(s != self.trash_slot());
            debug_assert!(!self.committed.contains(&s), "releasing committed slot {s}");
            self.free.push(s);
        }
    }

    /// Promotes a draft slot to the committed prefix (visible to all
    /// future tokens).
    pub fn commit(&mut self, slot: u32) {
        debug_assert!(!self.committed.contains(&slot));
        self.committed.push(slot);
        self.mask.commit_slot(slot);
    }

    /// Forgets everything (session reset). Stale K/V data stays in the
    /// device buffer but is unreachable — masks make it invisible.
    pub fn reset(&mut self) {
        for &s in &self.committed {
            self.mask.release_slot(s);
        }
        self.committed.clear();
        self.free = (0..self.capacity as u32 - 1).rev().collect();
    }

    /// The mask builder whose prefix row tracks this cache's commits.
    pub fn mask_builder(&mut self) -> &mut MaskBuilder {
        &mut self.mask
    }

    /// Remaining generation headroom in tokens, keeping `tree_budget`
    /// slots available for drafting.
    pub fn headroom(&self, tree_budget: usize) -> usize {
        self.free.len().saturating_sub(tree_budget)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_roundtrip() {
        let mut c = SlotCache::new(8);
        assert_eq!(c.free_count(), 7); // one slot reserved as trash
        assert_eq!(c.in_use(), 0);
        let s = c.alloc(3).unwrap();
        assert_eq!(s.len(), 3);
        assert_eq!(c.free_count(), 4);
        assert_eq!(c.in_use(), 3);
        c.release(&s);
        assert_eq!(c.free_count(), 7);
        assert_eq!(c.in_use(), 0);
    }

    #[test]
    fn alloc_fails_when_exhausted() {
        let mut c = SlotCache::new(4);
        assert!(c.alloc(3).is_some());
        assert!(c.alloc(1).is_none());
    }

    #[test]
    fn trash_slot_is_never_allocated() {
        let mut c = SlotCache::new(4);
        let all = c.alloc(3).unwrap();
        assert!(!all.contains(&c.trash_slot()));
    }

    #[test]
    fn commit_updates_prefix_row() {
        let mut c = SlotCache::new(4);
        let s = c.alloc(2).unwrap();
        c.commit(s[0]);
        assert_eq!(c.committed_len(), 1);
        assert_eq!(c.mask_builder().committed_count(), 1);
    }

    #[test]
    fn reset_restores_everything() {
        let mut c = SlotCache::new(6);
        let s = c.alloc(4).unwrap();
        c.commit(s[0]);
        c.commit(s[1]);
        c.release(&s[2..]);
        c.reset();
        assert_eq!(c.free_count(), 5);
        assert_eq!(c.committed_len(), 0);
        assert_eq!(c.mask_builder().committed_count(), 0);
    }

    #[test]
    fn headroom_reserves_tree_budget() {
        let c = SlotCache::new(74); // 73 usable
        assert_eq!(c.headroom(64), 9);
        assert_eq!(c.headroom(100), 0);
    }

    #[test]
    fn slots_are_reused_lifo() {
        let mut c = SlotCache::new(8);
        let a = c.alloc(2).unwrap();
        c.release(&a);
        let b = c.alloc(2).unwrap();
        assert_eq!(b[0], a[1]);
        assert_eq!(b[1], a[0]);
    }
}
