//! Slot-level KV-cache management.
//!
//! The L2 graphs treat the cache as a fixed-capacity array of *slots*
//! (DESIGN.md §7): each evaluated token writes its K/V at an arbitrary slot
//! and visibility is mask-encoded, so "memory management" reduces to a
//! free-list allocator plus the committed-slot set that [`MaskBuilder`]
//! (re)builds prefix rows from. Rejected draft slots are returned to the
//! free list and reused by the next iteration's tree — no copying, no
//! compaction, no rollback, which is exactly what keeps every operator
//! shape static for the AOT graphs.
//!
//! One reserved *trash slot* (the last slot) absorbs the K/V writes of
//! padding rows in width-padded calls; it is never marked visible.
//!
//! ## Shared-cache layouts (DESIGN.md §9–§10)
//!
//! For cross-session batched verification, many sessions share **one**
//! device cache array. Two layouts carve it up:
//!
//! * **Equal partition** ([`SlotPartition`], DESIGN.md §9) — the array is
//!   split into equal contiguous [`SlotRange`] regions, leased and
//!   released whole. Simple, but capacity is stranded: a short session
//!   idles most of its region while a long-prompt request is rejected.
//! * **Paged blocks** ([`BlockPool`], DESIGN.md §10) — the array is split
//!   into fixed-size *blocks*; a session's [`SlotCache`] leases blocks on
//!   demand as generation proceeds and returns fully-free blocks on
//!   rejection, completion, or disconnect. The session's usable slot set
//!   is a *set of owned blocks* ([`SlotOwnership::Blocks`]) instead of one
//!   contiguous range; slots are addressed indirectly either way, so
//!   nothing about the static graph shapes changes.
//!
//! In both layouts a session's per-row masks reference only slots it owns
//! ([`SlotOwnership::contains`]), which keeps cross-session batch masks
//! block-diagonal — a session can never reference, let alone read, another
//! session's slots.

use std::sync::{Arc, Mutex};

use crate::tree::MaskBuilder;

/// A contiguous run of slots inside a shared cache array — one session's
/// lease from a [`SlotPartition`], or one block of a [`BlockPool`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotRange {
    /// First slot of the range.
    pub base: u32,
    /// Number of slots in the range.
    pub len: u32,
}

impl SlotRange {
    /// True when `slot` lies inside this range.
    pub fn contains(&self, slot: u32) -> bool {
        slot >= self.base && slot < self.base + self.len
    }
}

/// Configuration error from cache partition / block-pool construction.
///
/// Construction used to panic on impossible layouts; the serving layer
/// now surfaces these as typed startup/admission failures instead of
/// taking down the worker thread.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CacheConfigError {
    /// The capacity cannot host `sessions` equal regions of ≥ 2 slots.
    RegionsDontFit {
        /// Total cache capacity (slots, incl. trash).
        capacity: usize,
        /// Requested session count.
        sessions: usize,
    },
    /// The block size is out of range for the capacity (must be ≥ 2 and
    /// leave room for at least one block plus the trash slot).
    BadBlockSize {
        /// Total cache capacity (slots, incl. trash).
        capacity: usize,
        /// Requested slots per block.
        block_size: usize,
    },
    /// The cache cannot host even one usable slot plus the reserved
    /// trash slot (capacity < 2) — computing `capacity - 1` for the
    /// trash slot would underflow.
    NoTrashSlot {
        /// Total cache capacity (slots).
        capacity: usize,
    },
    /// An explicit block budget exceeds what the capacity can host (or
    /// is zero).
    BadBlockCount {
        /// Total cache capacity (slots, incl. trash).
        capacity: usize,
        /// Requested slots per block.
        block_size: usize,
        /// Requested number of blocks.
        blocks: usize,
    },
}

impl std::fmt::Display for CacheConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CacheConfigError::RegionsDontFit { capacity, sessions } => write!(
                f,
                "cache capacity {capacity} cannot host {sessions} equal regions of ≥ 2 slots"
            ),
            CacheConfigError::BadBlockSize { capacity, block_size } => write!(
                f,
                "block size {block_size} is invalid for a {capacity}-slot cache \
                 (need 2 ≤ block_size ≤ capacity - 1)"
            ),
            CacheConfigError::NoTrashSlot { capacity } => write!(
                f,
                "cache capacity {capacity} cannot host one usable slot plus the \
                 reserved trash slot (need capacity ≥ 2)"
            ),
            CacheConfigError::BadBlockCount { capacity, block_size, blocks } => write!(
                f,
                "{blocks} blocks of {block_size} slots do not fit a {capacity}-slot cache \
                 (need 1 ≤ blocks ≤ (capacity - 1) / block_size)"
            ),
        }
    }
}

impl std::error::Error for CacheConfigError {}

/// Typed "the shared block pool ran dry" marker error.
///
/// Raised (wrapped in `anyhow`) when a *paged* [`SlotCache`] cannot lease
/// enough blocks mid-generation. The serving layer recognises it and
/// **preempts** the session — releasing its blocks and requeueing it for a
/// re-prefill resume — instead of failing the request: under paged
/// sharing, exhaustion usually means a neighbour holds the blocks, not
/// that the request is unservable.
#[derive(Debug, Clone)]
pub struct PoolExhausted {
    /// Which allocation ran dry (for the error message).
    pub what: &'static str,
}

impl std::fmt::Display for PoolExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "shared KV block pool exhausted during {}", self.what)
    }
}

impl std::error::Error for PoolExhausted {}

/// The slot set a session may reference — the confinement domain its mask
/// rows are checked against ([`crate::tree::rows_owned`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SlotOwnership {
    /// One contiguous range (equal-partition lease or a whole owned cache).
    Range(SlotRange),
    /// A set of owned fixed-size blocks (paged mode): block `b` covers
    /// slots `[b · block_size, (b + 1) · block_size)`.
    Blocks {
        /// Slots per block.
        block_size: u32,
        /// Owned block indices.
        blocks: Vec<u32>,
    },
}

impl SlotOwnership {
    /// True when `slot` is inside this ownership set.
    pub fn contains(&self, slot: u32) -> bool {
        match self {
            SlotOwnership::Range(r) => r.contains(slot),
            SlotOwnership::Blocks { block_size, blocks } => {
                blocks.contains(&(slot / block_size))
            }
        }
    }
}

/// Carves one shared cache array into equal per-session regions — the
/// fixed-partition layout (DESIGN.md §9), kept as the `--equal-partition`
/// fallback next to the paged [`BlockPool`].
///
/// The last slot of the array stays reserved as the shared trash slot;
/// the remaining `capacity - 1` slots split into `sessions` equal regions
/// (any remainder is left unused). Regions are leased and released whole:
/// a session's [`SlotCache`] owns the lease for its lifetime, so slot
/// ownership never fragments across sessions.
#[derive(Debug, Clone)]
pub struct SlotPartition {
    total_capacity: usize,
    region_len: u32,
    free_bases: Vec<u32>,
}

impl SlotPartition {
    /// Partitions a `capacity`-slot cache into `sessions` equal regions.
    ///
    /// Errors when the split would leave a region without at least two
    /// usable slots (a region must hold at least one token beyond
    /// bookkeeping) — a typed config error the server surfaces as a
    /// startup/admission failure.
    pub fn new(capacity: usize, sessions: usize) -> Result<Self, CacheConfigError> {
        if sessions < 1 || capacity < 2 {
            return Err(CacheConfigError::RegionsDontFit { capacity, sessions });
        }
        let usable = capacity - 1; // last slot is the shared trash
        let region_len = (usable / sessions) as u32;
        if region_len < 2 {
            return Err(CacheConfigError::RegionsDontFit { capacity, sessions });
        }
        // Hand out low regions first (matches SlotCache's low-slot bias).
        let free_bases = (0..sessions as u32).map(|i| i * region_len).rev().collect();
        Ok(Self { total_capacity: capacity, region_len, free_bases })
    }

    /// The shared trash slot all sessions' padding rows scatter into.
    pub fn trash_slot(&self) -> u32 {
        self.total_capacity as u32 - 1
    }

    /// Total slots in the shared cache array (including trash).
    pub fn total_capacity(&self) -> usize {
        self.total_capacity
    }

    /// Slots per leased region.
    pub fn region_len(&self) -> u32 {
        self.region_len
    }

    /// Number of regions currently leasable.
    pub fn free_regions(&self) -> usize {
        self.free_bases.len()
    }

    /// Leases one region, or `None` when every region is taken (the
    /// serving layer surfaces this as an admission failure).
    pub fn lease(&mut self) -> Option<SlotRange> {
        self.free_bases.pop().map(|base| SlotRange { base, len: self.region_len })
    }

    /// Returns a leased region (called when its session drops).
    pub fn release(&mut self, range: SlotRange) {
        debug_assert_eq!(range.len, self.region_len, "foreign range returned");
        debug_assert!(
            range.base % self.region_len == 0,
            "misaligned range returned: base {}",
            range.base
        );
        debug_assert!(!self.free_bases.contains(&range.base), "double release");
        self.free_bases.push(range.base);
    }
}

/// A shared cache array carved into fixed-size *blocks* — the paged
/// layout (DESIGN.md §10) that replaces equal-region leasing for serving.
///
/// Block `b` covers slots `[b · block_size, (b + 1) · block_size)`; the
/// last slot of the array stays the shared trash slot and any remainder
/// short of a whole block is left unused. Sessions lease blocks **on
/// demand** through a paged [`SlotCache`] and return them the moment they
/// are fully free, so capacity follows the actual token footprint instead
/// of a worst-case per-session quota.
#[derive(Debug)]
pub struct BlockPool {
    total_capacity: usize,
    block_size: u32,
    num_blocks: u32,
    free: Vec<u32>,
}

impl BlockPool {
    /// A pool over a `capacity`-slot cache with `block_size` slots per
    /// block. `max_blocks` optionally caps the pool below what the
    /// capacity could host (the `--cache-blocks` knob). Errors on layouts
    /// the capacity cannot host — typed, so the server can surface a
    /// startup/admission failure instead of panicking.
    pub fn new(
        capacity: usize,
        block_size: usize,
        max_blocks: Option<usize>,
    ) -> Result<Self, CacheConfigError> {
        if block_size < 2 || block_size + 1 > capacity {
            return Err(CacheConfigError::BadBlockSize { capacity, block_size });
        }
        let fit = (capacity - 1) / block_size;
        let num = match max_blocks {
            None => fit,
            Some(b) if (1..=fit).contains(&b) => b,
            Some(b) => {
                return Err(CacheConfigError::BadBlockCount { capacity, block_size, blocks: b })
            }
        };
        // Hand out low blocks first (matches the free-list's low-slot bias).
        let free = (0..num as u32).rev().collect();
        Ok(Self {
            total_capacity: capacity,
            block_size: block_size as u32,
            num_blocks: num as u32,
            free,
        })
    }

    /// Total slots in the shared cache array (including trash).
    pub fn total_capacity(&self) -> usize {
        self.total_capacity
    }

    /// The shared trash slot all sessions' padding rows scatter into.
    pub fn trash_slot(&self) -> u32 {
        self.total_capacity as u32 - 1
    }

    /// Slots per block.
    pub fn block_size(&self) -> u32 {
        self.block_size
    }

    /// Total blocks in the pool.
    pub fn num_blocks(&self) -> usize {
        self.num_blocks as usize
    }

    /// Blocks currently leasable.
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Blocks currently leased to sessions (the occupancy gauge).
    pub fn blocks_in_use(&self) -> usize {
        self.num_blocks as usize - self.free.len()
    }

    /// The slot range block `block` covers.
    pub fn range_of(&self, block: u32) -> SlotRange {
        debug_assert!(block < self.num_blocks, "foreign block id {block}");
        SlotRange { base: block * self.block_size, len: self.block_size }
    }

    /// Leases one block, or `None` when the pool is dry (the serving
    /// layer turns a dry pool mid-generation into a preemption).
    pub fn lease(&mut self) -> Option<u32> {
        self.free.pop()
    }

    /// Returns a leased block.
    pub fn release(&mut self, block: u32) {
        debug_assert!(block < self.num_blocks, "foreign block returned: {block}");
        debug_assert!(!self.free.contains(&block), "double release of block {block}");
        self.free.push(block);
    }
}

/// What backs a [`SlotCache`]'s allocatable slot set.
#[derive(Debug)]
enum Backing {
    /// A fixed contiguous range: a whole owned array, or an equal-partition
    /// lease. The slot set never changes over the cache's lifetime.
    Fixed(SlotRange),
    /// Blocks leased on demand from a shared [`BlockPool`] and returned
    /// as soon as they are fully free.
    Paged {
        pool: Arc<Mutex<BlockPool>>,
        block_size: u32,
        blocks: Vec<u32>,
    },
}

/// Slot allocator + committed-set tracker for one model's cache.
///
/// Owns a whole cache array ([`SlotCache::new`]), a leased [`SlotRange`]
/// of a shared array ([`SlotCache::with_range`]), or a dynamic set of
/// blocks of a shared [`BlockPool`] ([`SlotCache::paged`]); in every mode
/// it only ever hands out slots it owns, which is what keeps
/// cross-session masks block-diagonal in batched serving.
#[derive(Debug)]
pub struct SlotCache {
    /// Size of the backing device array (the mask row width).
    total_capacity: usize,
    /// The (possibly shared) padding-row slot; never allocated.
    trash: u32,
    /// The most slots this cache could ever own (range length, or the
    /// whole pool) — the absolute generation ceiling.
    lease_limit: usize,
    backing: Backing,
    free: Vec<u32>, // LIFO free list (excludes the trash slot)
    committed: Vec<u32>,
    mask: MaskBuilder,
}

impl SlotCache {
    /// A cache owning a whole `capacity`-slot array (single-session mode):
    /// the last slot is the trash slot, everything else is allocatable.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 2, "need at least one usable slot plus trash");
        let range = SlotRange { base: 0, len: capacity as u32 - 1 };
        Self::with_range(range, capacity, capacity as u32 - 1)
    }

    /// A cache allocating only inside `range` of a `total_capacity`-slot
    /// shared array whose padding rows scatter into `trash` (equal-
    /// partition batching mode; see [`SlotPartition`]).
    pub fn with_range(range: SlotRange, total_capacity: usize, trash: u32) -> Self {
        assert!(range.len >= 1, "empty slot range");
        assert!(
            (range.base + range.len) as usize <= total_capacity,
            "range beyond cache capacity"
        );
        assert!(!range.contains(trash), "trash slot inside allocatable range");
        // Hand out low slots first (helps locality of the scatter).
        let free = (range.base..range.base + range.len).rev().collect();
        Self {
            total_capacity,
            trash,
            lease_limit: range.len as usize,
            backing: Backing::Fixed(range),
            free,
            committed: Vec::new(),
            mask: MaskBuilder::new(total_capacity),
        }
    }

    /// A cache leasing blocks of `pool` on demand (paged batching mode;
    /// DESIGN.md §10). Starts with no blocks: the first `alloc` leases.
    pub fn paged(pool: Arc<Mutex<BlockPool>>) -> Self {
        let (total_capacity, trash, block_size, limit) = {
            let p = pool.lock().unwrap();
            (
                p.total_capacity(),
                p.trash_slot(),
                p.block_size(),
                p.num_blocks() * p.block_size() as usize,
            )
        };
        Self {
            total_capacity,
            trash,
            lease_limit: limit,
            backing: Backing::Paged { pool, block_size, blocks: Vec::new() },
            free: Vec::new(),
            committed: Vec::new(),
            mask: MaskBuilder::new(total_capacity),
        }
    }

    /// The reserved slot padding rows scatter their K/V into.
    pub fn trash_slot(&self) -> u32 {
        self.trash
    }

    /// Size of the backing device array (the mask row width) — **not**
    /// this cache's allocatable slot count; see [`SlotCache::usable`].
    pub fn capacity(&self) -> usize {
        self.total_capacity
    }

    /// Slots this cache currently owns (range length, or leased blocks ×
    /// block size — grows and shrinks in paged mode).
    pub fn usable(&self) -> usize {
        match &self.backing {
            Backing::Fixed(r) => r.len as usize,
            Backing::Paged { block_size, blocks, .. } => {
                blocks.len() * *block_size as usize
            }
        }
    }

    /// The most slots this cache could ever own: its fixed range length,
    /// or the whole block pool. `committed` can never exceed this — the
    /// absolute generation ceiling paged tasks stop at.
    pub fn lease_limit(&self) -> usize {
        self.lease_limit
    }

    /// True when this cache leases blocks of a shared [`BlockPool`].
    pub fn is_paged(&self) -> bool {
        matches!(self.backing, Backing::Paged { .. })
    }

    /// Blocks currently leased (paged mode; 0 otherwise).
    pub fn owned_blocks(&self) -> usize {
        match &self.backing {
            Backing::Fixed(_) => 0,
            Backing::Paged { blocks, .. } => blocks.len(),
        }
    }

    /// The slot set this cache may reference — the confinement domain
    /// its mask rows are checked against (see [`crate::tree::rows_owned`]).
    pub fn ownership(&self) -> SlotOwnership {
        match &self.backing {
            Backing::Fixed(r) => SlotOwnership::Range(*r),
            Backing::Paged { block_size, blocks, .. } => {
                SlotOwnership::Blocks { block_size: *block_size, blocks: blocks.clone() }
            }
        }
    }

    /// True when this cache currently owns every slot in `slots` — the
    /// drafter-side confinement check the batched draft phase asserts
    /// before a session's rows join a packed call (DESIGN.md §11).
    pub fn owns_all(&self, slots: &[u32]) -> bool {
        slots.iter().all(|&s| self.owns(s))
    }

    /// True when this cache currently owns `slot`.
    pub fn owns(&self, slot: u32) -> bool {
        match &self.backing {
            Backing::Fixed(r) => r.contains(slot),
            Backing::Paged { block_size, blocks, .. } => {
                blocks.contains(&(slot / *block_size))
            }
        }
    }

    /// Currently free (allocatable) slots already owned by this cache.
    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    /// Slots allocatable *right now*: the local free list plus (in paged
    /// mode) everything still leasable from the shared pool. This is the
    /// token-level admission signal — the pool either covers a request's
    /// prompt + tree budget or it does not, regardless of how the slots
    /// fragment across blocks.
    pub fn available(&self) -> usize {
        let pooled = match &self.backing {
            Backing::Fixed(_) => 0,
            Backing::Paged { pool, block_size, .. } => {
                pool.lock().unwrap().free_blocks() * *block_size as usize
            }
        };
        self.free.len() + pooled
    }

    /// Slots currently held (committed prefix + outstanding draft slots;
    /// excludes the trash slot). The serving layer aggregates this across
    /// live sessions for its KV-utilization gauge, and the cancellation
    /// tests assert it returns to zero once a session is dropped.
    pub fn in_use(&self) -> usize {
        self.usable() - self.free.len()
    }

    /// Number of committed (always-visible) slots.
    pub fn committed_len(&self) -> usize {
        self.committed.len()
    }

    /// The committed slots, in commit order.
    pub fn committed(&self) -> &[u32] {
        &self.committed
    }

    /// Allocates `n` slots for draft/tree tokens, leasing blocks from the
    /// shared pool on demand in paged mode. Returns `None` when the cache
    /// (or pool) cannot host the tree — callers shrink the envelope, or
    /// surface [`SlotCache::exhausted`] so the serving layer can preempt.
    pub fn alloc(&mut self, n: usize) -> Option<Vec<u32>> {
        if self.free.len() < n {
            if let Backing::Paged { pool, blocks, .. } = &mut self.backing {
                let mut p = pool.lock().unwrap();
                while self.free.len() < n {
                    let Some(b) = p.lease() else { break };
                    let r = p.range_of(b);
                    blocks.push(b);
                    // Low slots first, matching the fixed-mode bias.
                    self.free.extend((r.base..r.base + r.len).rev());
                }
            }
            if self.free.len() < n {
                // Return any fully-free blocks a failed lease loop left
                // behind so two starved sessions cannot hoard each other
                // to death.
                self.shrink();
                return None;
            }
        }
        Some((0..n).map(|_| self.free.pop().unwrap()).collect())
    }

    /// The error a failed [`SlotCache::alloc`] should surface: the typed
    /// [`PoolExhausted`] marker in paged mode (the serving layer preempts
    /// and requeues the session on it), a plain terminal message
    /// otherwise (a session-local cache running dry cannot be fixed by
    /// anyone else's blocks).
    pub fn exhausted(&self, what: &'static str) -> anyhow::Error {
        if self.is_paged() {
            anyhow::Error::new(PoolExhausted { what })
        } else {
            anyhow::anyhow!("KV cache exhausted during {what}")
        }
    }

    /// Returns draft slots that did not get committed. In paged mode any
    /// block that became fully free goes straight back to the shared pool
    /// (rejection is exactly when capacity should flow between sessions).
    pub fn release(&mut self, slots: &[u32]) {
        for &s in slots {
            debug_assert!(s != self.trash);
            debug_assert!(self.owns(s), "releasing foreign slot {s}");
            debug_assert!(!self.committed.contains(&s), "releasing committed slot {s}");
            self.free.push(s);
        }
        self.shrink();
    }

    /// Returns every fully-free owned block to the shared pool (no-op for
    /// fixed-range caches). A block stays leased while any of its slots
    /// is committed or outstanding.
    fn shrink(&mut self) {
        let Backing::Paged { pool, blocks, .. } = &mut self.backing else { return };
        if blocks.is_empty() {
            return;
        }
        let mut p = pool.lock().unwrap();
        let bs = p.block_size() as usize;
        let mut i = 0;
        while i < blocks.len() {
            let r = p.range_of(blocks[i]);
            let free_in = self.free.iter().filter(|&&s| r.contains(s)).count();
            if free_in == bs {
                self.free.retain(|&s| !r.contains(s));
                p.release(blocks.swap_remove(i));
            } else {
                i += 1;
            }
        }
    }

    /// Promotes a draft slot to the committed prefix (visible to all
    /// future tokens of this session).
    pub fn commit(&mut self, slot: u32) {
        debug_assert!(self.owns(slot), "committing foreign slot {slot}");
        debug_assert!(!self.committed.contains(&slot));
        self.committed.push(slot);
        self.mask.commit_slot(slot);
    }

    /// Forgets everything (session reset). Stale K/V data stays in the
    /// device buffer but is unreachable — masks make it invisible. Paged
    /// caches return every block to the shared pool.
    pub fn reset(&mut self) {
        for &s in &self.committed {
            self.mask.release_slot(s);
        }
        self.committed.clear();
        match &mut self.backing {
            Backing::Fixed(r) => {
                self.free = (r.base..r.base + r.len).rev().collect();
            }
            Backing::Paged { pool, blocks, .. } => {
                self.free.clear();
                let mut p = pool.lock().unwrap();
                for b in blocks.drain(..) {
                    p.release(b);
                }
            }
        }
    }

    /// The mask builder whose prefix row tracks this cache's commits.
    pub fn mask_builder(&mut self) -> &mut MaskBuilder {
        &mut self.mask
    }

    /// Remaining generation headroom in tokens, keeping `tree_budget`
    /// slots available for drafting. Counts the shared pool in paged mode
    /// (the admission formula: admit while the pool covers prompt + tree
    /// budget).
    pub fn headroom(&self, tree_budget: usize) -> usize {
        self.available().saturating_sub(tree_budget)
    }
}

impl Drop for SlotCache {
    fn drop(&mut self) {
        // Paged sessions return every leased block on completion,
        // cancellation or preemption; fixed ranges are returned by their
        // partition's owner.
        if let Backing::Paged { pool, blocks, .. } = &mut self.backing {
            if let Ok(mut p) = pool.lock() {
                for b in blocks.drain(..) {
                    p.release(b);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_roundtrip() {
        let mut c = SlotCache::new(8);
        assert_eq!(c.free_count(), 7); // one slot reserved as trash
        assert_eq!(c.in_use(), 0);
        let s = c.alloc(3).unwrap();
        assert_eq!(s.len(), 3);
        assert_eq!(c.free_count(), 4);
        assert_eq!(c.in_use(), 3);
        c.release(&s);
        assert_eq!(c.free_count(), 7);
        assert_eq!(c.in_use(), 0);
    }

    #[test]
    fn alloc_fails_when_exhausted() {
        let mut c = SlotCache::new(4);
        assert!(c.alloc(3).is_some());
        assert!(c.alloc(1).is_none());
    }

    #[test]
    fn trash_slot_is_never_allocated() {
        let mut c = SlotCache::new(4);
        let all = c.alloc(3).unwrap();
        assert!(!all.contains(&c.trash_slot()));
    }

    #[test]
    fn commit_updates_prefix_row() {
        let mut c = SlotCache::new(4);
        let s = c.alloc(2).unwrap();
        c.commit(s[0]);
        assert_eq!(c.committed_len(), 1);
        assert_eq!(c.mask_builder().committed_count(), 1);
    }

    #[test]
    fn reset_restores_everything() {
        let mut c = SlotCache::new(6);
        let s = c.alloc(4).unwrap();
        c.commit(s[0]);
        c.commit(s[1]);
        c.release(&s[2..]);
        c.reset();
        assert_eq!(c.free_count(), 5);
        assert_eq!(c.committed_len(), 0);
        assert_eq!(c.mask_builder().committed_count(), 0);
    }

    #[test]
    fn headroom_reserves_tree_budget() {
        let c = SlotCache::new(74); // 73 usable
        assert_eq!(c.headroom(64), 9);
        assert_eq!(c.headroom(100), 0);
    }

    #[test]
    fn slots_are_reused_lifo() {
        let mut c = SlotCache::new(8);
        let a = c.alloc(2).unwrap();
        c.release(&a);
        let b = c.alloc(2).unwrap();
        assert_eq!(b[0], a[1]);
        assert_eq!(b[1], a[0]);
    }

    #[test]
    fn partition_carves_equal_regions_with_shared_trash() {
        let mut p = SlotPartition::new(321, 4).unwrap(); // 320 usable → 80 per region
        assert_eq!(p.region_len(), 80);
        assert_eq!(p.trash_slot(), 320);
        assert_eq!(p.free_regions(), 4);
        let a = p.lease().unwrap();
        let b = p.lease().unwrap();
        assert_eq!(a, SlotRange { base: 0, len: 80 });
        assert_eq!(b, SlotRange { base: 80, len: 80 });
        assert_eq!(p.free_regions(), 2);
        p.release(a);
        assert_eq!(p.free_regions(), 3);
        // The freed region is leasable again.
        assert_eq!(p.lease().unwrap(), a);
    }

    #[test]
    fn partition_exhausts_then_refills() {
        let mut p = SlotPartition::new(9, 2).unwrap(); // 8 usable → 4 per region
        let a = p.lease().unwrap();
        let b = p.lease().unwrap();
        assert!(p.lease().is_none());
        p.release(b);
        p.release(a);
        assert_eq!(p.free_regions(), 2);
    }

    #[test]
    fn partition_rejects_impossible_layouts_with_typed_errors() {
        assert_eq!(
            SlotPartition::new(9, 5).unwrap_err(),
            CacheConfigError::RegionsDontFit { capacity: 9, sessions: 5 }
        );
        assert!(SlotPartition::new(1, 1).is_err());
        assert!(SlotPartition::new(100, 0).is_err());
        // The error renders a human-readable admission message.
        let msg = SlotPartition::new(9, 5).unwrap_err().to_string();
        assert!(msg.contains("9") && msg.contains("5"), "uninformative: {msg}");
    }

    #[test]
    fn ranged_cache_stays_inside_its_lease() {
        let mut p = SlotPartition::new(17, 2).unwrap(); // 16 usable → 8 per region
        let ra = p.lease().unwrap();
        let rb = p.lease().unwrap();
        let mut a = SlotCache::with_range(ra, 17, p.trash_slot());
        let mut b = SlotCache::with_range(rb, 17, p.trash_slot());
        let sa = a.alloc(8).unwrap();
        let sb = b.alloc(8).unwrap();
        assert!(a.alloc(1).is_none(), "range exhausted");
        assert!(sa.iter().all(|&s| ra.contains(s)));
        assert!(sb.iter().all(|&s| rb.contains(s)));
        assert!(sa.iter().all(|&s| !rb.contains(s)), "ranges overlap");
        assert_eq!(a.capacity(), 17, "mask width covers the shared array");
        assert_eq!(a.usable(), 8);
        assert_eq!(a.trash_slot(), 16);
        assert_eq!(a.ownership(), SlotOwnership::Range(ra));
    }

    #[test]
    fn ranged_cache_reset_refills_only_its_range() {
        let r = SlotRange { base: 4, len: 4 };
        let mut c = SlotCache::with_range(r, 12, 11);
        let s = c.alloc(3).unwrap();
        c.commit(s[0]);
        c.reset();
        assert_eq!(c.free_count(), 4);
        let again = c.alloc(4).unwrap();
        assert!(again.iter().all(|&x| r.contains(x)));
    }

    // ---------------------------------------------------------------
    // Paged block pool
    // ---------------------------------------------------------------

    #[test]
    fn block_pool_layout_and_lease_roundtrip() {
        let mut p = BlockPool::new(33, 8, None).unwrap(); // 32 usable → 4 blocks
        assert_eq!(p.num_blocks(), 4);
        assert_eq!(p.block_size(), 8);
        assert_eq!(p.trash_slot(), 32);
        assert_eq!(p.free_blocks(), 4);
        assert_eq!(p.blocks_in_use(), 0);
        let a = p.lease().unwrap();
        assert_eq!(p.range_of(a), SlotRange { base: a * 8, len: 8 });
        assert_eq!(p.blocks_in_use(), 1);
        p.release(a);
        assert_eq!(p.free_blocks(), 4);
    }

    #[test]
    fn block_pool_rejects_bad_layouts_with_typed_errors() {
        assert_eq!(
            BlockPool::new(8, 1, None).unwrap_err(),
            CacheConfigError::BadBlockSize { capacity: 8, block_size: 1 }
        );
        assert!(BlockPool::new(8, 8, None).is_err(), "no room for the trash slot");
        assert_eq!(
            BlockPool::new(33, 8, Some(5)).unwrap_err(),
            CacheConfigError::BadBlockCount { capacity: 33, block_size: 8, blocks: 5 }
        );
        assert!(BlockPool::new(33, 8, Some(0)).is_err());
        // An explicit budget below the fit is a valid way to reserve
        // device capacity for something else.
        assert_eq!(BlockPool::new(33, 8, Some(2)).unwrap().num_blocks(), 2);
    }

    fn pool(capacity: usize, block_size: usize) -> Arc<Mutex<BlockPool>> {
        Arc::new(Mutex::new(BlockPool::new(capacity, block_size, None).unwrap()))
    }

    #[test]
    fn paged_cache_leases_blocks_on_demand() {
        let p = pool(33, 8); // 4 blocks
        let mut c = SlotCache::paged(p.clone());
        assert_eq!(c.owned_blocks(), 0);
        assert_eq!(c.available(), 32, "whole pool reachable before any lease");
        let s = c.alloc(10).unwrap(); // needs 2 blocks
        assert_eq!(c.owned_blocks(), 2);
        assert_eq!(p.lock().unwrap().free_blocks(), 2);
        assert!(s.iter().all(|&x| c.owns(x)));
        assert_eq!(c.in_use(), 10);
        assert_eq!(c.free_count(), 6);
    }

    #[test]
    fn paged_cache_returns_fully_free_blocks_on_release() {
        let p = pool(33, 8);
        let mut c = SlotCache::paged(p.clone());
        let s = c.alloc(16).unwrap(); // 2 whole blocks
        c.commit(s[0]); // pins the first allocated slot's block
        c.release(&s[1..]);
        // The block holding the committed slot stays; the other returns.
        assert_eq!(c.owned_blocks(), 1);
        assert_eq!(p.lock().unwrap().free_blocks(), 3);
        assert!(c.owns(s[0]));
    }

    #[test]
    fn paged_cache_drop_returns_every_block() {
        let p = pool(33, 8);
        {
            let mut c = SlotCache::paged(p.clone());
            let s = c.alloc(20).unwrap();
            c.commit(s[0]);
            c.commit(s[1]);
            assert!(p.lock().unwrap().free_blocks() < 4);
        }
        assert_eq!(p.lock().unwrap().free_blocks(), 4, "drop must return all blocks");
    }

    #[test]
    fn paged_alloc_fails_without_hoarding_when_pool_dry() {
        let p = pool(17, 8); // 2 blocks
        let mut a = SlotCache::paged(p.clone());
        let mut b = SlotCache::paged(p.clone());
        let held = a.alloc(12).unwrap(); // takes both blocks
        assert!(b.alloc(4).is_none(), "pool dry");
        assert_eq!(b.owned_blocks(), 0, "failed alloc must not hoard blocks");
        a.release(&held);
        assert_eq!(p.lock().unwrap().free_blocks(), 2);
        assert!(b.alloc(4).is_some(), "freed blocks are leasable again");
    }

    #[test]
    fn paged_exhaustion_error_is_typed_for_preemption() {
        let p = pool(17, 8);
        let c = SlotCache::paged(p);
        let e = c.exhausted("unit test");
        assert!(e.is::<PoolExhausted>(), "paged exhaustion must downcast");
        // Fixed-range exhaustion is terminal, not preemptible.
        let f = SlotCache::new(4).exhausted("unit test");
        assert!(!f.is::<PoolExhausted>());
    }

    #[test]
    fn paged_headroom_counts_the_shared_pool() {
        let p = pool(33, 8);
        let mut a = SlotCache::paged(p.clone());
        let b = SlotCache::paged(p);
        let _s = a.alloc(8).unwrap(); // one block gone
        assert_eq!(b.available(), 24);
        assert_eq!(b.headroom(8), 16);
        assert_eq!(a.lease_limit(), 32);
    }

    #[test]
    fn no_trash_slot_error_renders_capacity() {
        let e = CacheConfigError::NoTrashSlot { capacity: 0 };
        let msg = e.to_string();
        assert!(msg.contains('0') && msg.contains("trash"), "uninformative: {msg}");
    }

    #[test]
    fn owns_all_checks_every_slot() {
        let p = pool(33, 8);
        let mut c = SlotCache::paged(p);
        let s = c.alloc(4).unwrap();
        assert!(c.owns_all(&s));
        assert!(!c.owns_all(&[s[0], 32]), "trash slot is never owned");
        assert!(c.owns_all(&[]), "vacuously true on empty");
    }

    #[test]
    fn block_ownership_contains_matches_block_math() {
        let own = SlotOwnership::Blocks { block_size: 4, blocks: vec![0, 3] };
        for s in 0..4 {
            assert!(own.contains(s), "slot {s} is in block 0");
        }
        for s in 4..12 {
            assert!(!own.contains(s), "slot {s} is in an unowned block");
        }
        for s in 12..16 {
            assert!(own.contains(s), "slot {s} is in block 3");
        }
    }

    #[test]
    fn paged_reset_returns_blocks_and_clears_commits() {
        let p = pool(33, 8);
        let mut c = SlotCache::paged(p.clone());
        let s = c.alloc(12).unwrap();
        c.commit(s[0]);
        c.reset();
        assert_eq!(c.owned_blocks(), 0);
        assert_eq!(c.committed_len(), 0);
        assert_eq!(p.lock().unwrap().free_blocks(), 4);
        assert_eq!(c.mask_builder().committed_count(), 0);
    }
}
