//! Serving layer: a TCP JSON-lines server around one engine.
//!
//! The paper's core results target the *latency-optimal single-request*
//! regime (§9): one accelerator, one request at a time. The server mirrors
//! that: accepted connections enqueue requests into an ordered FCFS queue;
//! a single worker thread owns the engine and drains the queue, streaming
//! accepted tokens back per verification step. Concurrency lives at the
//! edges (one reader/writer thread pair per connection), the device stays
//! single-tenant — exactly the deployment the paper's evaluation models.
//!
//! ## Protocol (one JSON object per line)
//!
//! request:  `{"id": 7, "prompt": [1,2,3], "max_new": 32}`
//!           (or `"text": "..."` — byte-tokenized)
//! events:   `{"id": 7, "event": "tokens", "tokens": [5, 9]}` (stream mode)
//!           `{"id": 7, "event": "done", "tokens": [...], "aal": 2.31,
//!             "tpot_ms": 1.9, "iterations": 14}`
//!           `{"id": 7, "event": "error", "message": "..."}`

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use crate::corpus::ByteTokenizer;
use crate::engine::Engine;
use crate::util::json::Json;

/// One queued generation request.
struct Job {
    id: f64,
    prompt: Vec<u32>,
    max_new: usize,
    reply: mpsc::Sender<String>,
    stream: bool,
}

/// Server statistics (exposed via the `"stats"` request).
#[derive(Default)]
pub struct ServerStats {
    pub requests: AtomicU64,
    pub tokens: AtomicU64,
    pub errors: AtomicU64,
}

/// A running server; dropping it stops the accept loop.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    pub stats: Arc<ServerStats>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    worker_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Binds `addr` ("127.0.0.1:0" picks a free port) and serves requests
    /// with `engine` until dropped.
    pub fn spawn(
        addr: &str,
        engine: Box<dyn Engine + Send>,
        max_queue: usize,
        stream: bool,
    ) -> crate::Result<Self> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(ServerStats::default());
        let (job_tx, job_rx) = mpsc::sync_channel::<Job>(max_queue);

        // Worker: single-tenant engine loop (FCFS).
        let wstats = stats.clone();
        let wstop = stop.clone();
        let worker_thread = std::thread::Builder::new().name("ygg-worker".into()).spawn(
            move || {
                let mut engine = engine;
                while !wstop.load(Ordering::Relaxed) {
                    let Ok(job) = job_rx.recv_timeout(std::time::Duration::from_millis(50))
                    else {
                        continue;
                    };
                    wstats.requests.fetch_add(1, Ordering::Relaxed);
                    let reply = job.reply.clone();
                    let id = job.id;
                    let mut sink = |toks: &[u32]| {
                        if job.stream && !toks.is_empty() {
                            let msg = Json::obj(vec![
                                ("id", Json::Num(id)),
                                ("event", Json::Str("tokens".into())),
                                (
                                    "tokens",
                                    Json::Arr(
                                        toks.iter().map(|&t| Json::Num(t as f64)).collect(),
                                    ),
                                ),
                            ]);
                            let _ = reply.send(msg.to_string());
                        }
                    };
                    match engine.generate_with(&job.prompt, job.max_new, &mut sink) {
                        Ok(g) => {
                            wstats.tokens.fetch_add(g.tokens.len() as u64, Ordering::Relaxed);
                            let msg = Json::obj(vec![
                                ("id", Json::Num(id)),
                                ("event", Json::Str("done".into())),
                                (
                                    "tokens",
                                    Json::Arr(
                                        g.tokens.iter().map(|&t| Json::Num(t as f64)).collect(),
                                    ),
                                ),
                                ("aal", Json::Num(g.aal())),
                                ("tpot_ms", Json::Num(g.tpot() * 1e3)),
                                ("iterations", Json::Num(g.iterations as f64)),
                                ("prefill_ms", Json::Num(g.prefill_seconds * 1e3)),
                            ]);
                            let _ = job.reply.send(msg.to_string());
                        }
                        Err(e) => {
                            wstats.errors.fetch_add(1, Ordering::Relaxed);
                            let msg = Json::obj(vec![
                                ("id", Json::Num(id)),
                                ("event", Json::Str("error".into())),
                                ("message", Json::Str(format!("{e:#}"))),
                            ]);
                            let _ = job.reply.send(msg.to_string());
                        }
                    }
                }
            },
        )?;

        // Accept loop: one handler thread per connection.
        let astop = stop.clone();
        let astats = stats.clone();
        let accept_thread = std::thread::Builder::new().name("ygg-accept".into()).spawn(
            move || {
                while !astop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((sock, _)) => {
                            let tx = job_tx.clone();
                            let stats = astats.clone();
                            let _ = std::thread::Builder::new()
                                .name("ygg-conn".into())
                                .spawn(move || handle_conn(sock, tx, stats, stream));
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(20));
                        }
                        Err(_) => break,
                    }
                }
            },
        )?;

        Ok(Self {
            addr: local,
            stop,
            stats,
            accept_thread: Some(accept_thread),
            worker_thread: Some(worker_thread),
        })
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Poke the accept loop by connecting once.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        if let Some(t) = self.worker_thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_conn(
    sock: TcpStream,
    jobs: mpsc::SyncSender<Job>,
    stats: Arc<ServerStats>,
    stream: bool,
) {
    let peer_write = match sock.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let writer = Arc::new(Mutex::new(peer_write));
    let reader = BufReader::new(sock);

    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let response = parse_request(&line);
        match response {
            Ok(Req::Stats) => {
                let msg = Json::obj(vec![
                    ("event", Json::Str("stats".into())),
                    ("requests", Json::Num(stats.requests.load(Ordering::Relaxed) as f64)),
                    ("tokens", Json::Num(stats.tokens.load(Ordering::Relaxed) as f64)),
                    ("errors", Json::Num(stats.errors.load(Ordering::Relaxed) as f64)),
                ]);
                let _ = writeln!(writer.lock().unwrap(), "{}", msg.to_string());
            }
            Ok(Req::Generate { id, prompt, max_new }) => {
                let (tx, rx) = mpsc::channel::<String>();
                if jobs
                    .try_send(Job { id, prompt, max_new, reply: tx, stream })
                    .is_err()
                {
                    let msg = Json::obj(vec![
                        ("id", Json::Num(id)),
                        ("event", Json::Str("error".into())),
                        ("message", Json::Str("queue full".into())),
                    ]);
                    let _ = writeln!(writer.lock().unwrap(), "{}", msg.to_string());
                    continue;
                }
                // Pump worker events back to this connection until "done".
                let w = writer.clone();
                for msg in rx {
                    let done = msg.contains("\"event\":\"done\"") || msg.contains("\"event\":\"error\"");
                    if writeln!(w.lock().unwrap(), "{msg}").is_err() {
                        break;
                    }
                    if done {
                        break;
                    }
                }
            }
            Err(e) => {
                let msg = Json::obj(vec![
                    ("event", Json::Str("error".into())),
                    ("message", Json::Str(format!("{e:#}"))),
                ]);
                let _ = writeln!(writer.lock().unwrap(), "{}", msg.to_string());
            }
        }
    }
}

enum Req {
    Generate { id: f64, prompt: Vec<u32>, max_new: usize },
    Stats,
}

fn parse_request(line: &str) -> crate::Result<Req> {
    let j = Json::parse(line)?;
    if j.get("stats").is_some() {
        return Ok(Req::Stats);
    }
    let id = j.get("id").and_then(|v| v.as_f64()).unwrap_or(0.0);
    let prompt: Vec<u32> = if let Some(p) = j.get("prompt") {
        p.as_arr()
            .ok_or_else(|| anyhow::anyhow!("prompt must be an array"))?
            .iter()
            .map(|t| t.as_usize().map(|x| x as u32).ok_or_else(|| anyhow::anyhow!("bad token")))
            .collect::<crate::Result<_>>()?
    } else if let Some(t) = j.get("text").and_then(|v| v.as_str()) {
        ByteTokenizer.encode(t)
    } else {
        anyhow::bail!("request needs 'prompt' or 'text'")
    };
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    let max_new = j.get("max_new").and_then(|v| v.as_usize()).unwrap_or(32);
    Ok(Req::Generate { id, prompt, max_new })
}

/// Minimal blocking client for tests, benches and the e2e example.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// One completed generation as seen by a client.
#[derive(Debug, Clone)]
pub struct ClientResult {
    pub tokens: Vec<u32>,
    pub aal: f64,
    pub tpot_ms: f64,
    pub iterations: usize,
    pub stream_events: usize,
}

impl Client {
    pub fn connect(addr: &std::net::SocketAddr) -> crate::Result<Self> {
        let sock = TcpStream::connect(addr)?;
        let writer = sock.try_clone()?;
        Ok(Self { reader: BufReader::new(sock), writer })
    }

    /// Sends one request and blocks until its `done` event.
    pub fn generate(&mut self, id: u64, prompt: &[u32], max_new: usize) -> crate::Result<ClientResult> {
        let req = Json::obj(vec![
            ("id", Json::Num(id as f64)),
            ("prompt", Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect())),
            ("max_new", Json::Num(max_new as f64)),
        ]);
        writeln!(self.writer, "{}", req.to_string())?;
        let mut stream_events = 0usize;
        loop {
            let mut line = String::new();
            let n = self.reader.read_line(&mut line)?;
            anyhow::ensure!(n > 0, "server closed connection");
            let j = Json::parse(&line)?;
            match j.str("event")? {
                "tokens" => stream_events += 1,
                "done" => {
                    let tokens = j
                        .arr("tokens")?
                        .iter()
                        .map(|t| t.as_usize().unwrap_or(0) as u32)
                        .collect();
                    return Ok(ClientResult {
                        tokens,
                        aal: j.f64("aal")?,
                        tpot_ms: j.f64("tpot_ms")?,
                        iterations: j.usize("iterations")?,
                        stream_events,
                    });
                }
                "error" => anyhow::bail!("server error: {}", j.str("message")?),
                other => anyhow::bail!("unexpected event '{other}'"),
            }
        }
    }
}

/// In-process mock engine for protocol tests (echoes the prompt).
pub struct EchoEngine;

impl Engine for EchoEngine {
    fn name(&self) -> String {
        "echo".into()
    }

    fn generate_with(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        sink: crate::engine::TokenSink,
    ) -> crate::Result<crate::engine::Generation> {
        let tokens: Vec<u32> = prompt.iter().copied().cycle().take(max_new).collect();
        for chunk in tokens.chunks(3) {
            sink(chunk);
        }
        Ok(crate::engine::Generation {
            tokens,
            iterations: max_new.div_ceil(3),
            seconds: 1e-4,
            prefill_seconds: 1e-5,
            recorder: crate::metrics::Recorder::new(),
        })
    }
}

/// Keyed response demux used by tests that multiplex one connection.
pub fn group_events(lines: &[String]) -> BTreeMap<u64, Vec<Json>> {
    let mut out: BTreeMap<u64, Vec<Json>> = BTreeMap::new();
    for l in lines {
        if let Ok(j) = Json::parse(l) {
            let id = j.get("id").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
            out.entry(id).or_default().push(j);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn echo_roundtrip_with_streaming() {
        let srv = Server::spawn("127.0.0.1:0", Box::new(EchoEngine), 8, true).unwrap();
        let mut c = Client::connect(&srv.addr).unwrap();
        let r = c.generate(1, &[10, 20, 30], 7).unwrap();
        assert_eq!(r.tokens, vec![10, 20, 30, 10, 20, 30, 10]);
        assert!(r.stream_events >= 2, "expected streamed chunks");
        assert_eq!(srv.stats.requests.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn multiple_sequential_requests_share_the_engine() {
        let srv = Server::spawn("127.0.0.1:0", Box::new(EchoEngine), 8, false).unwrap();
        let mut c = Client::connect(&srv.addr).unwrap();
        for i in 0..5 {
            let r = c.generate(i, &[1, 2], 4).unwrap();
            assert_eq!(r.tokens.len(), 4);
            assert_eq!(r.stream_events, 0, "stream disabled");
        }
        assert_eq!(srv.stats.tokens.load(std::sync::atomic::Ordering::Relaxed), 20);
    }

    #[test]
    fn concurrent_clients_fcfs() {
        let srv = Server::spawn("127.0.0.1:0", Box::new(EchoEngine), 8, false).unwrap();
        let addr = srv.addr;
        let handles: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(&addr).unwrap();
                    c.generate(i, &[i as u32 + 1], 3).unwrap().tokens
                })
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            let toks = h.join().unwrap();
            assert_eq!(toks, vec![i as u32 + 1; 3]);
        }
    }

    #[test]
    fn malformed_requests_get_error_events() {
        let srv = Server::spawn("127.0.0.1:0", Box::new(EchoEngine), 8, false).unwrap();
        let sock = TcpStream::connect(srv.addr).unwrap();
        let mut w = sock.try_clone().unwrap();
        writeln!(w, "this is not json").unwrap();
        let mut r = BufReader::new(sock);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.str("event").unwrap(), "error");
    }

    #[test]
    fn text_requests_are_byte_tokenized() {
        let srv = Server::spawn("127.0.0.1:0", Box::new(EchoEngine), 8, false).unwrap();
        let sock = TcpStream::connect(srv.addr).unwrap();
        let mut w = sock.try_clone().unwrap();
        writeln!(w, r#"{{"id": 3, "text": "hi", "max_new": 2}}"#).unwrap();
        let mut r = BufReader::new(sock);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.str("event").unwrap(), "done");
        // "hi" = [104, 105] cycled twice
        let toks: Vec<usize> =
            j.arr("tokens").unwrap().iter().map(|t| t.as_usize().unwrap()).collect();
        assert_eq!(toks, vec![104, 105]);
    }
}
