//! Serving layer: a TCP JSON-lines server over a step-driven engine.
//!
//! The paper's core results target the latency-optimal single-request
//! regime (§9); the server generalizes that to **continuous multi-session
//! serving** without giving up the single-tenant device: each
//! [`EngineWorker`] owns one engine and round-robins one
//! [`crate::engine::DecodeTask::step`] across up to `max_sessions` live
//! sessions per scheduling round (see [`sessions`]), and a fleet of such
//! workers (`--workers N`, DESIGN.md §16) sits behind one listener with
//! the [`Router`] placing requests by prefix-cache affinity. Requests
//! beyond the live set queue; admission is gated on KV-cache headroom; a
//! client disconnect cancels its session and frees its caches
//! mid-generation. Concurrency still lives at the edges — one reader
//! thread plus one writer-pump thread per connection — and a single
//! connection may multiplex many concurrent requests, demuxed by `id`.
//!
//! ## Protocol (one JSON object per line)
//!
//! request:  `{"id": 7, "prompt": [1,2,3], "max_new": 32}`
//!           (or `"text": "..."` — byte-tokenized; `"id"` may be a number
//!           or a decimal string: ids are u64 end-to-end and serialize as
//!           strings beyond the f64-exact range)
//!           `{"stats": true}` — server statistics snapshot
//! events:   `{"id": 7, "event": "tokens", "tokens": [5, 9]}` (stream mode)
//!           `{"id": 7, "event": "done", "tokens": [...], "aal": 2.31,
//!             "tpot_ms": 1.9, "iterations": 14, "queue_ms": 0.1,
//!             "ttft_ms": 8.8, "tok_per_s": 512.0, "preemptions": 0}`
//!           `{"id": 7, "event": "error", "message": "..."}`
//!
//! Internally every event is a typed [`sessions::ServerEvent`]; JSON only
//! materializes at the connection writer.

pub mod router;
pub mod sessions;
pub mod worker;

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use crate::corpus::ByteTokenizer;
use crate::engine::{
    drive, DecodeTask, Engine, Generation, StepEngine, StepOutcome, TaskState,
};
use crate::metrics::Recorder;
use crate::util::json::Json;

pub use router::{FleetSnapshot, Placer, Router, RoutingPolicy, Ticket};
pub use sessions::{DoneSummary, Job, ServerEvent};
pub use worker::{EngineWorker, JobQueue};

/// Connection-level cancellation flag, shared with the worker.
pub type CancelFlag = Arc<AtomicBool>;

/// Per-request priority/SLO class (DESIGN.md §14). The scheduler packs
/// latency-class cold prompts first and sheds throughput-class drafting
/// first when the degradation ladder engages.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SloClass {
    /// Interactive: inter-token latency is protected (the default).
    #[default]
    Latency,
    /// Batch work: throughput matters; degraded first under overload.
    Throughput,
}

impl SloClass {
    /// Stable wire/CLI string form.
    pub fn as_str(&self) -> &'static str {
        match self {
            SloClass::Latency => "latency",
            SloClass::Throughput => "throughput",
        }
    }

    /// Parses the wire/CLI string form.
    pub fn from_str(s: &str) -> crate::Result<Self> {
        Ok(match s {
            "latency" => SloClass::Latency,
            "throughput" => SloClass::Throughput,
            _ => anyhow::bail!("unknown SLO class '{s}' (expected latency|throughput)"),
        })
    }

    /// True for the latency (interactive) class.
    pub fn is_latency(&self) -> bool {
        matches!(self, SloClass::Latency)
    }
}

/// Serving limits.
#[derive(Debug, Clone)]
pub struct ServeOpts {
    /// Bounded request queue (beyond it, requests get a `queue full`
    /// error immediately).
    pub max_queue: usize,
    /// Concurrent sessions the scheduler interleaves.
    pub max_sessions: usize,
    /// Stream per-step tokens (vs. only the final `done` event).
    pub stream: bool,
    /// Drive each scheduling round through [`StepEngine::step_batch`]
    /// so engines with shared caches pack the round into fewer device
    /// calls (cross-session batching, DESIGN.md §9). `false` forces the
    /// serial round-robin baseline regardless of engine support.
    pub batched: bool,
    /// Times one request may be preempted (paged pool exhaustion,
    /// DESIGN.md §10) and requeued for a re-prefill resume before the
    /// scheduler gives up with a terminal error.
    pub max_resumes: usize,
    /// SLO class assigned to requests that do not name one
    /// (`--slo-class`; per-request `"class"` overrides it).
    pub default_class: SloClass,
    /// Latency-class inter-token gap (ms) beyond which the scheduler
    /// counts an SLO violation (DESIGN.md §14).
    pub slo_target_ms: f64,
    /// Request-placement policy across the worker fleet (`--routing`,
    /// DESIGN.md §16). Irrelevant with one worker.
    pub routing: RoutingPolicy,
    /// Backlog depth beyond which the router's work-stealing rebalance
    /// migrates queued jobs to lighter workers (DESIGN.md §16).
    pub steal_threshold: usize,
    /// Prompt-chunk size (tokens) for the affinity router's prefix
    /// fingerprints; normally the prefix cache's block size.
    pub affinity_chunk: usize,
    /// Capacity (events) of each worker's flight-recorder ring
    /// (`--trace-ring`, DESIGN.md §17). `0` disables tracing entirely —
    /// every [`crate::trace::Tracer::push`] becomes a no-op.
    pub trace_ring: usize,
    /// Write a Chrome trace-event JSON file (Perfetto/`chrome://tracing`
    /// loadable) of every worker's flight-recorder contents on server
    /// shutdown (`--trace-out`, DESIGN.md §17).
    pub trace_out: Option<std::path::PathBuf>,
}

impl Default for ServeOpts {
    fn default() -> Self {
        Self {
            max_queue: 64,
            max_sessions: 4,
            stream: true,
            batched: true,
            max_resumes: 8,
            default_class: SloClass::Latency,
            slo_target_ms: 250.0,
            routing: RoutingPolicy::Affinity,
            steal_threshold: 4,
            affinity_chunk: 16,
            trace_ring: crate::trace::DEFAULT_RING,
            trace_out: None,
        }
    }
}

/// Server statistics (exposed via the `"stats"` request).
#[derive(Default)]
pub struct ServerStats {
    /// Requests dequeued (admitted or rejected).
    pub requests: AtomicU64,
    /// Tokens committed across completed generations.
    pub tokens: AtomicU64,
    /// Request-level failures.
    pub errors: AtomicU64,
    /// Sessions dropped because their client disconnected.
    pub cancelled: AtomicU64,
    /// Requests refused by KV-headroom admission control.
    pub rejected: AtomicU64,
    /// Sessions preempted under paged pool exhaustion (blocks released,
    /// request requeued for a re-prefill resume; DESIGN.md §10).
    pub preemptions: AtomicU64,
    /// Preempted sessions successfully re-admitted.
    pub resumes: AtomicU64,
    /// Gauge: live sessions after the last scheduling round.
    pub active_sessions: AtomicU64,
    /// High-water mark of concurrently admitted sessions.
    pub peak_sessions: AtomicU64,
    /// Gauge: KV slots held across live sessions (both model sides).
    pub kv_slots_in_use: AtomicU64,
    /// Gauge: shared-pool blocks leased across both model sides (paged
    /// layout only; 0 otherwise).
    pub blocks_in_use: AtomicU64,
    /// Gauge: total shared-pool blocks (paged layout only; 0 otherwise).
    pub blocks_total: AtomicU64,
    /// Prefix-cache lookups: one per *admitted* request's prefill —
    /// rejected/parked admission probes don't count (DESIGN.md §12).
    pub prefix_lookups: AtomicU64,
    /// Prefix-cache lookups that matched ≥ 1 cached block.
    pub prefix_hits: AtomicU64,
    /// Prompt tokens served from the prefix cache instead of prefilled.
    pub prefix_tokens_reused: AtomicU64,
    /// Cached blocks reclaimed by the LRU eviction pass (per side).
    pub prefix_evictions: AtomicU64,
    /// Gauge: blocks currently held by the prefix trie (per side).
    pub prefix_cached_blocks: AtomicU64,
    /// Prefill chunks stepped under chunked prefill (DESIGN.md §14) —
    /// one per cold-prompt round, so a prompt whose prefill spans N
    /// chunk-capped rounds counts N here (1 per prompt when unchunked).
    pub prefill_chunks: AtomicU64,
    /// Scheduling rounds run under a non-zero degradation rung.
    pub degraded_rounds: AtomicU64,
    /// Latency-class inter-token gaps that exceeded the SLO target.
    pub slo_violations: AtomicU64,
    /// Gauge: current overload-degradation rung (0 = no pressure; see
    /// [`crate::scheduler::DegradationLadder`]).
    pub degrade_rung: AtomicU64,
    /// Gauge: verification rows the global round allocator granted
    /// across the live sessions in the last batched round (DESIGN.md
    /// §15; 0 when the allocator never ran).
    pub alloc_budget_total: AtomicU64,
    /// Rounds in which the global allocator resolved per-session
    /// verification budgets (the allocator-decisions counter).
    pub alloc_rounds: AtomicU64,
    /// Per-request serving series: `server.queue_delay_s`,
    /// `server.ttft_s`, `server.tok_per_s`, `server.resume_delay_s`,
    /// the per-class inter-token series `server.itl_s.latency` /
    /// `server.itl_s.throughput`, and the per-round per-session
    /// acceptance-estimate series `server.accept_rate` (DESIGN.md §15).
    pub recorder: Mutex<Recorder>,
}

/// Point-in-time view of [`ServerStats`].
#[derive(Debug, Clone)]
pub struct StatsSnapshot {
    /// Total requests seen.
    pub requests: u64,
    /// Total committed tokens.
    pub tokens: u64,
    /// Request-level failures.
    pub errors: u64,
    /// Sessions dropped on client disconnect.
    pub cancelled: u64,
    /// Admission-control rejections.
    pub rejected: u64,
    /// Paged-pool preemptions (DESIGN.md §10).
    pub preemptions: u64,
    /// Preempted sessions re-admitted.
    pub resumes: u64,
    /// Live sessions after the last round.
    pub active_sessions: u64,
    /// High-water mark of concurrently admitted sessions.
    pub peak_sessions: u64,
    /// KV slots held across live sessions.
    pub kv_slots_in_use: u64,
    /// Shared-pool blocks currently leased (paged layout only).
    pub blocks_in_use: u64,
    /// Total shared-pool blocks (paged layout only).
    pub blocks_total: u64,
    /// Prefix-cache lookups (DESIGN.md §12; 0 without a prefix cache).
    pub prefix_lookups: u64,
    /// Prefix-cache lookups that matched ≥ 1 cached block.
    pub prefix_hits: u64,
    /// Prompt tokens served from the prefix cache instead of prefilled.
    pub prefix_tokens_reused: u64,
    /// Cached blocks reclaimed by the LRU eviction pass.
    pub prefix_evictions: u64,
    /// Blocks currently held by the prefix trie (per side).
    pub prefix_cached_blocks: u64,
    /// Prefill chunks stepped (DESIGN.md §14).
    pub prefill_chunks: u64,
    /// Rounds run under a non-zero degradation rung.
    pub degraded_rounds: u64,
    /// Latency-class inter-token gaps beyond the SLO target.
    pub slo_violations: u64,
    /// Current overload-degradation rung (0 = none).
    pub degrade_rung: u64,
    /// Verification rows granted by the global allocator in the last
    /// batched round (DESIGN.md §15; 0 when it never ran).
    pub alloc_budget_total: u64,
    /// Rounds the global allocator resolved budgets for.
    pub alloc_rounds: u64,
    /// Median per-session online acceptance estimate across recent
    /// rounds (DESIGN.md §15; NaN with no samples).
    pub accept_rate_p50: f64,
    /// 95th-percentile per-session acceptance estimate (NaN with no
    /// samples).
    pub accept_rate_p95: f64,
    /// Latency-class inter-token latency p50 (ms; NaN with no samples).
    pub itl_ms_p50_latency: f64,
    /// Latency-class inter-token latency p95 (ms; NaN with no samples).
    pub itl_ms_p95_latency: f64,
    /// Throughput-class inter-token latency p50 (ms; NaN with no samples).
    pub itl_ms_p50_throughput: f64,
    /// Throughput-class inter-token latency p95 (ms; NaN with no samples).
    pub itl_ms_p95_throughput: f64,
    /// Mean queueing delay (ms).
    pub queue_delay_ms_mean: f64,
    /// Median time-to-first-token (ms).
    pub ttft_ms_p50: f64,
    /// Mean per-request decode throughput.
    pub tok_per_s_mean: f64,
    /// Mean preempt-to-resume delay (ms; NaN when nothing resumed).
    pub resume_delay_ms_mean: f64,
}

impl ServerStats {
    /// A point-in-time copy of the counters and serving series.
    pub fn snapshot(&self) -> StatsSnapshot {
        let rec = self.recorder.lock().unwrap();
        StatsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            tokens: self.tokens.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            preemptions: self.preemptions.load(Ordering::Relaxed),
            resumes: self.resumes.load(Ordering::Relaxed),
            active_sessions: self.active_sessions.load(Ordering::Relaxed),
            peak_sessions: self.peak_sessions.load(Ordering::Relaxed),
            kv_slots_in_use: self.kv_slots_in_use.load(Ordering::Relaxed),
            blocks_in_use: self.blocks_in_use.load(Ordering::Relaxed),
            blocks_total: self.blocks_total.load(Ordering::Relaxed),
            prefix_lookups: self.prefix_lookups.load(Ordering::Relaxed),
            prefix_hits: self.prefix_hits.load(Ordering::Relaxed),
            prefix_tokens_reused: self.prefix_tokens_reused.load(Ordering::Relaxed),
            prefix_evictions: self.prefix_evictions.load(Ordering::Relaxed),
            prefix_cached_blocks: self.prefix_cached_blocks.load(Ordering::Relaxed),
            prefill_chunks: self.prefill_chunks.load(Ordering::Relaxed),
            degraded_rounds: self.degraded_rounds.load(Ordering::Relaxed),
            slo_violations: self.slo_violations.load(Ordering::Relaxed),
            degrade_rung: self.degrade_rung.load(Ordering::Relaxed),
            alloc_budget_total: self.alloc_budget_total.load(Ordering::Relaxed),
            alloc_rounds: self.alloc_rounds.load(Ordering::Relaxed),
            accept_rate_p50: rec.percentile("server.accept_rate", 50.0),
            accept_rate_p95: rec.percentile("server.accept_rate", 95.0),
            itl_ms_p50_latency: rec.percentile("server.itl_s.latency", 50.0) * 1e3,
            itl_ms_p95_latency: rec.percentile("server.itl_s.latency", 95.0) * 1e3,
            itl_ms_p50_throughput: rec.percentile("server.itl_s.throughput", 50.0) * 1e3,
            itl_ms_p95_throughput: rec.percentile("server.itl_s.throughput", 95.0) * 1e3,
            queue_delay_ms_mean: rec.mean("server.queue_delay_s") * 1e3,
            ttft_ms_p50: rec.percentile("server.ttft_s", 50.0) * 1e3,
            tok_per_s_mean: rec.mean("server.tok_per_s"),
            resume_delay_ms_mean: rec.mean("server.resume_delay_s") * 1e3,
        }
    }

    /// Folds another worker's stats into this one (fleet aggregation,
    /// DESIGN.md §16): counters and gauges sum, the degradation rung
    /// takes the fleet max, and the serving series concatenate so merged
    /// percentiles are computed over every worker's samples — not
    /// averaged per-worker percentiles, which would be wrong for tails.
    pub fn merge_from(&self, other: &ServerStats) {
        let add = |dst: &AtomicU64, src: &AtomicU64| {
            dst.fetch_add(src.load(Ordering::Relaxed), Ordering::Relaxed);
        };
        add(&self.requests, &other.requests);
        add(&self.tokens, &other.tokens);
        add(&self.errors, &other.errors);
        add(&self.cancelled, &other.cancelled);
        add(&self.rejected, &other.rejected);
        add(&self.preemptions, &other.preemptions);
        add(&self.resumes, &other.resumes);
        add(&self.active_sessions, &other.active_sessions);
        add(&self.peak_sessions, &other.peak_sessions);
        add(&self.kv_slots_in_use, &other.kv_slots_in_use);
        add(&self.blocks_in_use, &other.blocks_in_use);
        add(&self.blocks_total, &other.blocks_total);
        add(&self.prefix_lookups, &other.prefix_lookups);
        add(&self.prefix_hits, &other.prefix_hits);
        add(&self.prefix_tokens_reused, &other.prefix_tokens_reused);
        add(&self.prefix_evictions, &other.prefix_evictions);
        add(&self.prefix_cached_blocks, &other.prefix_cached_blocks);
        add(&self.prefill_chunks, &other.prefill_chunks);
        add(&self.degraded_rounds, &other.degraded_rounds);
        add(&self.slo_violations, &other.slo_violations);
        add(&self.alloc_budget_total, &other.alloc_budget_total);
        add(&self.alloc_rounds, &other.alloc_rounds);
        self.degrade_rung
            .fetch_max(other.degrade_rung.load(Ordering::Relaxed), Ordering::Relaxed);
        self.recorder.lock().unwrap().merge(&other.recorder.lock().unwrap());
    }
}

impl StatsSnapshot {
    /// Wire form of the `stats` event.
    ///
    /// Per-class ITL keys appear only for classes that recorded at least
    /// one sample: a class with zero samples has a NaN percentile, and
    /// the old unconditional emission turned that into a misleading
    /// `"itl_ms_p50_throughput": null` row on every latency-only server.
    pub fn to_json(&self) -> Json {
        let num = |x: f64| if x.is_finite() { Json::Num(x) } else { Json::Null };
        let mut fields = vec![
            ("event", Json::Str("stats".into())),
            ("requests", Json::Num(self.requests as f64)),
            ("tokens", Json::Num(self.tokens as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("cancelled", Json::Num(self.cancelled as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("preemptions", Json::Num(self.preemptions as f64)),
            ("resumes", Json::Num(self.resumes as f64)),
            ("active_sessions", Json::Num(self.active_sessions as f64)),
            ("peak_sessions", Json::Num(self.peak_sessions as f64)),
            ("kv_slots_in_use", Json::Num(self.kv_slots_in_use as f64)),
            ("blocks_in_use", Json::Num(self.blocks_in_use as f64)),
            ("blocks_total", Json::Num(self.blocks_total as f64)),
            ("prefix_lookups", Json::Num(self.prefix_lookups as f64)),
            ("prefix_hits", Json::Num(self.prefix_hits as f64)),
            ("prefix_tokens_reused", Json::Num(self.prefix_tokens_reused as f64)),
            ("prefix_evictions", Json::Num(self.prefix_evictions as f64)),
            ("prefix_cached_blocks", Json::Num(self.prefix_cached_blocks as f64)),
            ("prefill_chunks", Json::Num(self.prefill_chunks as f64)),
            ("degraded_rounds", Json::Num(self.degraded_rounds as f64)),
            ("slo_violations", Json::Num(self.slo_violations as f64)),
            ("degrade_rung", Json::Num(self.degrade_rung as f64)),
            ("alloc_budget_total", Json::Num(self.alloc_budget_total as f64)),
            ("alloc_rounds", Json::Num(self.alloc_rounds as f64)),
            ("accept_rate_p50", num(self.accept_rate_p50)),
            ("accept_rate_p95", num(self.accept_rate_p95)),
            ("queue_delay_ms_mean", num(self.queue_delay_ms_mean)),
            ("ttft_ms_p50", num(self.ttft_ms_p50)),
            ("tok_per_s_mean", num(self.tok_per_s_mean)),
            ("resume_delay_ms_mean", num(self.resume_delay_ms_mean)),
        ];
        if !self.itl_ms_p50_latency.is_nan() {
            fields.push(("itl_ms_p50_latency", num(self.itl_ms_p50_latency)));
            fields.push(("itl_ms_p95_latency", num(self.itl_ms_p95_latency)));
        }
        if !self.itl_ms_p50_throughput.is_nan() {
            fields.push(("itl_ms_p50_throughput", num(self.itl_ms_p50_throughput)));
            fields.push(("itl_ms_p95_throughput", num(self.itl_ms_p95_throughput)));
        }
        Json::obj(fields)
    }
}

/// A running server; dropping it stops the accept loop and every worker
/// (live sessions are aborted and their caches freed).
///
/// The server is a pure frontend (DESIGN.md §16): it owns no engine
/// state — only the TCP accept loop and the [`Router`], which owns the
/// [`EngineWorker`] fleet. Each worker holds its own engine, cache pool,
/// prefix trie, stats, and scheduler thread.
pub struct Server {
    /// Bound socket address.
    pub addr: std::net::SocketAddr,
    stop: CancelFlag,
    /// Worker 0's serving statistics (the whole fleet's when `--workers
    /// 1`, which keeps single-worker callers bit-compatible). Fleet-wide
    /// aggregates live in [`Router::fleet_snapshot`].
    pub stats: Arc<ServerStats>,
    /// Placement/rebalance/aggregation hub owning the worker fleet.
    pub router: Arc<Router>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    /// Where to dump the fleet's Chrome trace on shutdown (DESIGN.md
    /// §17); `None` skips the export.
    trace_out: Option<std::path::PathBuf>,
}

impl Server {
    /// Binds `addr` ("127.0.0.1:0" picks a free port) and serves requests
    /// with `engine` until dropped — a one-worker [`Server::spawn_fleet`].
    pub fn spawn(
        addr: &str,
        engine: Box<dyn StepEngine + Send>,
        opts: ServeOpts,
    ) -> crate::Result<Self> {
        Self::spawn_fleet(addr, vec![engine], opts)
    }

    /// Binds `addr` and serves requests across a fleet of workers, one
    /// per engine (`--workers N`; DESIGN.md §16). Placement follows
    /// `opts.routing`; the accept loop's poll tick doubles as the
    /// work-stealing rebalance cadence.
    pub fn spawn_fleet(
        addr: &str,
        engines: Vec<Box<dyn StepEngine + Send>>,
        opts: ServeOpts,
    ) -> crate::Result<Self> {
        anyhow::ensure!(!engines.is_empty(), "spawn_fleet needs at least one engine");
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop: CancelFlag = Arc::new(AtomicBool::new(false));

        let workers = engines
            .into_iter()
            .enumerate()
            .map(|(id, engine)| EngineWorker::spawn(id, engine, &opts))
            .collect::<crate::Result<Vec<_>>>()?;
        let router = Arc::new(Router::new(workers, &opts));
        let stats = router.workers()[0].stats.clone();

        // Accept loop: one reader + one writer pump per connection. Its
        // 20ms idle poll is also the rebalance tick.
        let astop = stop.clone();
        let arouter = router.clone();
        let stream = opts.stream;
        let default_class = opts.default_class;
        let accept_thread = std::thread::Builder::new().name("ygg-accept".into()).spawn(
            move || {
                while !astop.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((sock, _)) => {
                            let router = arouter.clone();
                            let _ = std::thread::Builder::new()
                                .name("ygg-conn".into())
                                .spawn(move || {
                                    handle_conn(sock, router, stream, default_class)
                                });
                        }
                        Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            arouter.rebalance();
                            std::thread::sleep(std::time::Duration::from_millis(20));
                        }
                        Err(_) => break,
                    }
                }
            },
        )?;

        Ok(Self {
            addr: local,
            stop,
            stats,
            router,
            accept_thread: Some(accept_thread),
            trace_out: opts.trace_out,
        })
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Poke the accept loop by connecting once.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        self.router.shutdown();
        // Workers are joined: their rings are quiescent, so the Chrome
        // trace export (DESIGN.md §17) sees every recorded event.
        if let Some(path) = &self.trace_out {
            let mut events = Vec::new();
            for w in self.router.workers() {
                events.extend(w.tracer.events());
            }
            let json = crate::trace::chrome_trace(&events).to_string();
            match std::fs::write(path, json) {
                Ok(()) => crate::util::log::info(&format!(
                    "wrote Chrome trace ({} events) to {}",
                    events.len(),
                    path.display()
                )),
                Err(e) => crate::util::log::error(&format!(
                    "failed to write Chrome trace to {}: {e}",
                    path.display()
                )),
            }
        }
    }
}

/// Per-connection reader: parses request lines, routes jobs through the
/// fleet's [`Router`] (the reply channel feeds this connection's writer
/// pump), and on EOF raises the connection's cancel flag so the owning
/// worker's scheduler frees any in-flight session.
fn handle_conn(
    sock: TcpStream,
    router: Arc<Router>,
    stream: bool,
    default_class: SloClass,
) {
    let Ok(wsock) = sock.try_clone() else { return };
    let cancelled: CancelFlag = Arc::new(AtomicBool::new(false));
    let (ev_tx, ev_rx) = mpsc::channel::<ServerEvent>();

    // Writer pump: the single writer for this connection; serializes
    // typed events to JSON lines. A failed write means the client is gone
    // — raise the cancel flag so the scheduler stops generating for it.
    let pump_cancel = cancelled.clone();
    let Ok(pump) = std::thread::Builder::new().name("ygg-conn-write".into()).spawn(move || {
        let mut w = wsock;
        for ev in ev_rx {
            if writeln!(w, "{}", ev.to_json().to_string()).is_err() {
                pump_cancel.store(true, Ordering::Relaxed);
                break;
            }
        }
    }) else {
        return;
    };

    let mut reader = BufReader::new(sock);
    loop {
        let mut line = String::new();
        match reader.read_line(&mut line) {
            // Clean EOF is only a half-close: a one-shot client may have
            // shut down its write side and still be reading replies, so
            // in-flight requests keep running. A truly vanished client is
            // detected by the pump's failed write (above), which raises
            // the cancel flag.
            Ok(0) => break,
            Err(_) => {
                // Read error (reset): the client is gone — cancel this
                // connection's in-flight sessions.
                cancelled.store(true, Ordering::Relaxed);
                break;
            }
            Ok(_) => {}
        }
        if line.trim().is_empty() {
            continue;
        }
        match parse_request(&line) {
            Ok(Req::Stats) => {
                let _ = ev_tx.send(ServerEvent::Stats(router.fleet_snapshot()));
            }
            Ok(Req::Metrics) => {
                let _ = ev_tx.send(ServerEvent::Metrics(router.metrics_text()));
            }
            Ok(Req::Generate { id, prompt, max_new, class }) => {
                let job = Job::new(
                    id,
                    prompt,
                    max_new,
                    class.unwrap_or(default_class),
                    ev_tx.clone(),
                    stream,
                    cancelled.clone(),
                );
                if router.submit(job).is_err() {
                    let _ = ev_tx.send(ServerEvent::Error {
                        id: Some(id),
                        message: "queue full".into(),
                    });
                }
            }
            Err(e) => {
                let _ = ev_tx.send(ServerEvent::Error { id: None, message: format!("{e:#}") });
            }
        }
    }
    drop(ev_tx);
    // The pump drains once in-flight replies finish (or their writes
    // fail, which flips the cancel flag and frees the sessions).
    let _ = pump.join();
}

enum Req {
    Generate { id: u64, prompt: Vec<u32>, max_new: usize, class: Option<SloClass> },
    Stats,
    Metrics,
}

fn parse_request(line: &str) -> crate::Result<Req> {
    let j = Json::parse(line)?;
    if j.get("stats").is_some() {
        return Ok(Req::Stats);
    }
    if j.get("metrics").is_some() {
        return Ok(Req::Metrics);
    }
    // Ids are u64 end-to-end; a fractional/negative/garbage id is a hard
    // error rather than a silent 0 (which would break client-side demux).
    let id = match j.get("id") {
        None => 0,
        Some(v) => v.as_u64().ok_or_else(|| {
            anyhow::anyhow!("'id' must be a non-negative integer (number or decimal string)")
        })?,
    };
    let prompt: Vec<u32> = if let Some(p) = j.get("prompt") {
        p.as_arr()
            .ok_or_else(|| anyhow::anyhow!("prompt must be an array"))?
            .iter()
            .map(|t| t.as_usize().map(|x| x as u32).ok_or_else(|| anyhow::anyhow!("bad token")))
            .collect::<crate::Result<_>>()?
    } else if let Some(t) = j.get("text").and_then(|v| v.as_str()) {
        ByteTokenizer.encode(t)
    } else {
        anyhow::bail!("request needs 'prompt' or 'text'")
    };
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    let max_new = j.get("max_new").and_then(|v| v.as_usize()).unwrap_or(32);
    // Optional per-request SLO class (DESIGN.md §14); absent falls back
    // to the server's `--slo-class` default. A present-but-bogus value
    // is a hard error, not a silent default.
    let class = match j.get("class") {
        None => None,
        Some(v) => {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("'class' must be a string"))?;
            Some(SloClass::from_str(s)?)
        }
    };
    Ok(Req::Generate { id, prompt, max_new, class })
}

/// Minimal blocking client for tests, benches and the e2e example.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

/// One completed generation as seen by a client.
#[derive(Debug, Clone)]
pub struct ClientResult {
    /// Generated tokens.
    pub tokens: Vec<u32>,
    /// Server-reported average accepted length.
    pub aal: f64,
    /// Server-reported per-token latency (ms).
    pub tpot_ms: f64,
    /// Verification iterations used.
    pub iterations: usize,
    /// `tokens` events seen before `done`.
    pub stream_events: usize,
    /// Server-side queueing delay for this request (ms).
    pub queue_ms: f64,
    /// Server-side time-to-first-token for this request (ms).
    pub ttft_ms: f64,
}

impl Client {
    /// Connects to a server.
    pub fn connect(addr: &std::net::SocketAddr) -> crate::Result<Self> {
        let sock = TcpStream::connect(addr)?;
        let writer = sock.try_clone()?;
        Ok(Self { reader: BufReader::new(sock), writer })
    }

    /// Sends one request and blocks until its `done` event. Events for
    /// other ids multiplexed on this connection are skipped.
    pub fn generate(
        &mut self,
        id: u64,
        prompt: &[u32],
        max_new: usize,
    ) -> crate::Result<ClientResult> {
        self.request(id, prompt, max_new, None)
    }

    /// Like [`Client::generate`] but tags the request with an explicit
    /// SLO class (DESIGN.md §14) instead of the server default.
    pub fn generate_classed(
        &mut self,
        id: u64,
        prompt: &[u32],
        max_new: usize,
        class: SloClass,
    ) -> crate::Result<ClientResult> {
        self.request(id, prompt, max_new, Some(class))
    }

    fn request(
        &mut self,
        id: u64,
        prompt: &[u32],
        max_new: usize,
        class: Option<SloClass>,
    ) -> crate::Result<ClientResult> {
        let mut fields = vec![
            ("id", Json::from_u64(id)),
            ("prompt", Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect())),
            ("max_new", Json::Num(max_new as f64)),
        ];
        if let Some(c) = class {
            fields.push(("class", Json::Str(c.as_str().into())));
        }
        let req = Json::obj(fields);
        writeln!(self.writer, "{}", req.to_string())?;
        let mut stream_events = 0usize;
        loop {
            let mut line = String::new();
            let n = self.reader.read_line(&mut line)?;
            anyhow::ensure!(n > 0, "server closed connection");
            let j = Json::parse(&line)?;
            if j.get("id").and_then(|v| v.as_u64()) != Some(id) {
                continue; // another request multiplexed on this connection
            }
            match j.str("event")? {
                "tokens" => stream_events += 1,
                "done" => {
                    // A malformed token is a protocol error, not token 0:
                    // silently mapping it would corrupt the stream the
                    // caller hands to the user.
                    let tokens = j
                        .arr("tokens")?
                        .iter()
                        .map(|t| {
                            t.as_usize().map(|x| x as u32).ok_or_else(|| {
                                anyhow::anyhow!(
                                    "malformed token in 'done' event: {}",
                                    t.to_string()
                                )
                            })
                        })
                        .collect::<crate::Result<Vec<u32>>>()?;
                    return Ok(ClientResult {
                        tokens,
                        aal: j.f64("aal")?,
                        tpot_ms: j.f64("tpot_ms")?,
                        iterations: j.usize("iterations")?,
                        stream_events,
                        queue_ms: j.get("queue_ms").and_then(|v| v.as_f64()).unwrap_or(0.0),
                        ttft_ms: j.get("ttft_ms").and_then(|v| v.as_f64()).unwrap_or(f64::NAN),
                    });
                }
                "error" => anyhow::bail!("server error: {}", j.str("message")?),
                other => anyhow::bail!("unexpected event '{other}'"),
            }
        }
    }

    /// Fetches a parsed stats snapshot.
    pub fn stats(&mut self) -> crate::Result<Json> {
        writeln!(self.writer, "{}", r#"{"stats": true}"#)?;
        loop {
            let mut line = String::new();
            let n = self.reader.read_line(&mut line)?;
            anyhow::ensure!(n > 0, "server closed connection");
            let j = Json::parse(&line)?;
            if j.get("event").and_then(|v| v.as_str()) == Some("stats") {
                return Ok(j);
            }
        }
    }

    /// Fetches the fleet's Prometheus text exposition (the body of a
    /// `{"metrics": true}` reply; DESIGN.md §17).
    pub fn metrics(&mut self) -> crate::Result<String> {
        writeln!(self.writer, "{}", r#"{"metrics": true}"#)?;
        loop {
            let mut line = String::new();
            let n = self.reader.read_line(&mut line)?;
            anyhow::ensure!(n > 0, "server closed connection");
            let j = Json::parse(&line)?;
            if j.get("event").and_then(|v| v.as_str()) == Some("metrics") {
                return Ok(j.str("body")?.to_string());
            }
        }
    }
}

/// Aggregate result of one concurrent-client wave against a server
/// (shared by the figures harness, `cargo bench`, and e2e drivers).
#[derive(Debug, Clone)]
pub struct WaveStats {
    /// Concurrent clients fired.
    pub clients: usize,
    /// Tokens received across all clients.
    pub tokens: usize,
    /// Wall-clock seconds for the whole wave.
    pub wall_s: f64,
    /// Aggregate throughput.
    pub tok_per_s: f64,
    /// Mean per-client end-to-end latency (ms).
    pub e2e_ms_mean: f64,
    /// Mean server-side time-to-first-token (ms).
    pub ttft_ms_mean: f64,
    /// Mean server-side queueing delay (ms).
    pub queue_ms_mean: f64,
}

/// Fires `clients` concurrent one-request clients (prompts assigned
/// round-robin) at `addr` and aggregates their latency/throughput.
pub fn client_wave(
    addr: std::net::SocketAddr,
    clients: usize,
    prompts: &[Vec<u32>],
    max_new: usize,
) -> crate::Result<WaveStats> {
    anyhow::ensure!(!prompts.is_empty(), "client_wave needs at least one prompt");
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|i| {
            let prompt = prompts[i % prompts.len()].clone();
            std::thread::spawn(move || -> crate::Result<(usize, f64, f64, f64)> {
                let mut c = Client::connect(&addr)?;
                let t = Instant::now();
                let r = c.generate(i as u64, &prompt, max_new)?;
                Ok((r.tokens.len(), t.elapsed().as_secs_f64(), r.ttft_ms, r.queue_ms))
            })
        })
        .collect();
    let mut tokens = 0usize;
    let (mut e2e, mut ttft, mut queue) = (0.0f64, 0.0f64, 0.0f64);
    for h in handles {
        let (tk, e, tf, q) =
            h.join().map_err(|_| anyhow::anyhow!("client thread panicked"))??;
        tokens += tk;
        e2e += e;
        ttft += tf;
        queue += q;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let n = clients.max(1) as f64;
    Ok(WaveStats {
        clients,
        tokens,
        wall_s,
        tok_per_s: tokens as f64 / wall_s.max(1e-9),
        e2e_ms_mean: e2e / n * 1e3,
        ttft_ms_mean: ttft / n,
        queue_ms_mean: queue / n,
    })
}

/// In-process mock engine for protocol tests (echoes the prompt, three
/// tokens per step).
pub struct EchoEngine;

struct EchoTask {
    tokens: Vec<u32>,
    emitted: usize,
    state: TaskState,
}

impl DecodeTask for EchoTask {
    fn state(&self) -> TaskState {
        self.state
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn step(&mut self) -> crate::Result<StepOutcome> {
        match self.state {
            TaskState::Done => Ok(StepOutcome { tokens: vec![], state: TaskState::Done }),
            TaskState::Prefill => {
                self.state = if self.tokens.is_empty() {
                    TaskState::Done
                } else {
                    TaskState::Iterate
                };
                Ok(StepOutcome { tokens: vec![], state: self.state })
            }
            TaskState::Iterate => {
                let n = 3.min(self.tokens.len() - self.emitted);
                let chunk = self.tokens[self.emitted..self.emitted + n].to_vec();
                self.emitted += n;
                if self.emitted >= self.tokens.len() {
                    self.state = TaskState::Done;
                }
                Ok(StepOutcome { tokens: chunk, state: self.state })
            }
        }
    }

    fn headroom(&self) -> usize {
        usize::MAX / 2
    }

    fn finish(self: Box<Self>) -> Generation {
        Generation {
            iterations: self.emitted.div_ceil(3),
            tokens: self.tokens[..self.emitted].to_vec(),
            seconds: 1e-4,
            prefill_seconds: 1e-5,
            recorder: Recorder::new(),
        }
    }
}

impl StepEngine for EchoEngine {
    fn begin(&mut self, prompt: &[u32], max_new: usize) -> crate::Result<Box<dyn DecodeTask>> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        let tokens: Vec<u32> = prompt.iter().copied().cycle().take(max_new).collect();
        Ok(Box::new(EchoTask { tokens, emitted: 0, state: TaskState::Prefill }))
    }
}

impl Engine for EchoEngine {
    fn name(&self) -> String {
        "echo".into()
    }

    fn generate_with(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        sink: crate::engine::TokenSink,
    ) -> crate::Result<Generation> {
        let task = self.begin(prompt, max_new)?;
        drive(task, sink)
    }
}

/// What backs a [`MockTask`]'s simulated KV slots.
enum MockKv {
    /// Plain counter against a per-session capacity (the original mock;
    /// no shared state between sessions).
    Counted { capacity: usize, held: usize },
    /// A real [`SlotCache`] over a shared pool — paged blocks or an
    /// equal-partition lease — so server tests exercise the actual
    /// kvcache admission/lease/return/confinement machinery without
    /// device artifacts.
    Cache {
        cache: crate::kvcache::SlotCache,
        /// Equal-mode lease to return on drop (paged caches return their
        /// own blocks).
        lease: Option<(Arc<Mutex<crate::kvcache::SlotPartition>>, crate::kvcache::SlotRange)>,
    },
    /// Equal mode with every region taken: headroom 0, so admission
    /// rejects the request before any stepping.
    Unleased,
}

/// Configurable mock step engine for scheduler tests: per-step latency,
/// chunked emission, a bounded "KV capacity" (per-session, or a *shared*
/// paged/equal cache over the real `kvcache` types), and a shared gauge
/// of slots held so tests can assert cancellation frees them.
pub struct MockStepEngine {
    /// Simulated device time per step.
    pub step_delay: std::time::Duration,
    /// Simulated *drafter* device time per session per round — the
    /// drafting-bound knob. In batched rounds it is charged once per
    /// round when `batch_draft` (the stage-aligned packed draft call,
    /// DESIGN.md §11) and once per live session otherwise (the
    /// verify-only batching of §9, where every session's draft calls
    /// issue serially). Zero by default, preserving the verify-only
    /// mock.
    pub draft_delay: std::time::Duration,
    /// Pack the simulated draft stage across sessions (mirrors
    /// `BatchConfig::batch_draft`).
    pub batch_draft: bool,
    /// Tokens emitted per iterate step.
    pub tokens_per_step: usize,
    /// Simulated per-session KV capacity in tokens (non-shared mode).
    pub capacity: usize,
    /// Live "KV slots" across all of this engine's sessions (prompt +
    /// generated tokens); decremented by task drop.
    pub slots_in_use: Arc<std::sync::atomic::AtomicUsize>,
    /// Mask-confinement violations observed by shared-cache tasks
    /// (every built row is checked against the session's ownership;
    /// tests assert this stays 0).
    pub violations: Arc<std::sync::atomic::AtomicUsize>,
    /// Prompt tokens actually prefilled into fresh KV slots across all
    /// sessions (the prefix cache's saving shows up here: attached
    /// prefix tokens are never counted).
    pub prefilled_tokens: Arc<std::sync::atomic::AtomicUsize>,
    /// Simulated prefill device time per *uncached* prompt token —
    /// makes TTFT visibly track the prefix cache's savings.
    pub prefill_cost: std::time::Duration,
    /// Max prompt tokens a task prefills per step (0 = one-shot; the
    /// mock analog of `BatchConfig::prefill_chunk`, DESIGN.md §14).
    pub prefill_chunk: usize,
    /// Engine-wide degradation rung shared with every task (the mock's
    /// [`StepEngine::set_degradation`] state).
    degrade: Arc<AtomicU8>,
    /// Every rung [`StepEngine::set_degradation`] received, in order —
    /// the ladder-walk-order assertion hook for fault-injection tests.
    pub rungs_seen: Arc<Mutex<Vec<u8>>>,
    /// Per-[`StepEngine::step_batch`] latency accounting: one record per
    /// call, so headless harnesses can assert how rounds spent their
    /// simulated device time.
    pub calls: Arc<Mutex<Vec<MockCall>>>,
    paged_pool: Option<Arc<Mutex<crate::kvcache::BlockPool>>>,
    equal_part: Option<Arc<Mutex<crate::kvcache::SlotPartition>>>,
    prefix: Option<Arc<Mutex<crate::kvcache::PrefixCache>>>,
    alloc: Option<MockAllocModel>,
    /// The owning worker's flight recorder (DESIGN.md §17): batched
    /// rounds wrap their simulated draft/verify sleeps in stage spans so
    /// mock serving traces have the same shape as the real decoder's.
    tracer: Option<Arc<crate::trace::Tracer>>,
}

/// The [`MockStepEngine`]'s simulated round-allocator regime
/// (DESIGN.md §15): per-session acceptance rates, per-row verification
/// pricing, and the adaptive-vs-uniform budget split.
#[derive(Debug, Clone, Copy)]
struct MockAllocModel {
    /// Per-session baseline verification budget (the uniform share).
    base_budget: usize,
    /// Simulated device time per granted verification row.
    row_cost: std::time::Duration,
    /// Route budgets through the adaptive greedy allocator (`true`) or
    /// the uniform water-fill baseline (`false`).
    adaptive: bool,
}

/// One [`MockStepEngine::step_batch`] call's latency accounting.
#[derive(Debug, Clone, Copy)]
pub struct MockCall {
    /// Live (not-Done) tasks stepped by this round.
    pub live: usize,
    /// Wall-clock seconds the call took (simulated device time +
    /// per-task bookkeeping).
    pub seconds: f64,
}

impl MockStepEngine {
    /// A mock with the given per-step delay, chunk size and per-session
    /// KV capacity (no shared cache).
    pub fn new(step_delay_ms: u64, tokens_per_step: usize, capacity: usize) -> Self {
        Self {
            step_delay: std::time::Duration::from_millis(step_delay_ms),
            draft_delay: std::time::Duration::ZERO,
            batch_draft: false,
            tokens_per_step: tokens_per_step.max(1),
            capacity,
            slots_in_use: Arc::new(std::sync::atomic::AtomicUsize::new(0)),
            violations: Arc::new(std::sync::atomic::AtomicUsize::new(0)),
            prefilled_tokens: Arc::new(std::sync::atomic::AtomicUsize::new(0)),
            prefill_cost: std::time::Duration::ZERO,
            prefill_chunk: 0,
            degrade: Arc::new(AtomicU8::new(0)),
            rungs_seen: Arc::new(Mutex::new(Vec::new())),
            calls: Arc::new(Mutex::new(Vec::new())),
            paged_pool: None,
            equal_part: None,
            prefix: None,
            alloc: None,
            tracer: None,
        }
    }

    /// Simulates the round-level speculation allocator (DESIGN.md §15):
    /// every batched round distributes `base_budget` verification rows
    /// per live session through [`crate::scheduler::alloc`] — the
    /// adaptive greedy when `adaptive`, the uniform water-fill baseline
    /// otherwise — charges `row_cost_us` of simulated device time per
    /// granted row, and each task emits the truncated-geometric
    /// expectation of its per-session acceptance rate, encoded in the
    /// prompt's first token as a percentage (`prompt[0] % 100`).
    pub fn with_alloc_model(
        mut self,
        base_budget: usize,
        row_cost_us: u64,
        adaptive: bool,
    ) -> Self {
        self.alloc = Some(MockAllocModel {
            base_budget: base_budget.max(1),
            row_cost: std::time::Duration::from_micros(row_cost_us),
            adaptive,
        });
        self
    }

    /// Caps each task's prefill at `chunk` prompt tokens per step (0 =
    /// one-shot), the mock analog of `--prefill-chunk` (DESIGN.md §14).
    pub fn with_prefill_chunk(mut self, chunk: usize) -> Self {
        self.prefill_chunk = chunk;
        self
    }

    /// Adds a simulated draft stage: `draft_delay_ms` of drafter device
    /// time per session per round, packed across sessions (charged once
    /// per round) when `batch_draft` — the mock analog of stage-aligned
    /// batched drafting (DESIGN.md §11).
    pub fn with_draft_stage(mut self, draft_delay_ms: u64, batch_draft: bool) -> Self {
        self.draft_delay = std::time::Duration::from_millis(draft_delay_ms);
        self.batch_draft = batch_draft;
        self
    }

    /// A mock whose sessions share one *paged* block pool (DESIGN.md
    /// §10): blocks lease on demand, fully-free blocks return, and a dry
    /// pool mid-step raises the typed `PoolExhausted` the scheduler
    /// preempts on.
    pub fn with_paged_pool(
        step_delay_ms: u64,
        tokens_per_step: usize,
        capacity: usize,
        block_size: usize,
    ) -> crate::Result<Self> {
        let pool = crate::kvcache::BlockPool::new(capacity, block_size, None)?;
        let mut e = Self::new(step_delay_ms, tokens_per_step, capacity);
        e.paged_pool = Some(Arc::new(Mutex::new(pool)));
        Ok(e)
    }

    /// Layers the cross-request prefix cache (DESIGN.md §12) over the
    /// mock's paged pool: completed sessions donate fully-committed
    /// prompt blocks into the radix trie and later sessions with a
    /// shared prompt prefix attach them instead of prefilling. Requires
    /// [`MockStepEngine::with_paged_pool`].
    pub fn with_prefix_cache(mut self) -> Self {
        let pool = self.paged_pool.as_ref().expect("prefix cache requires a paged pool");
        let pc = crate::kvcache::PrefixCache::new(vec![pool.clone()])
            .expect("single-pool prefix cache cannot mismatch block sizes");
        self.prefix = Some(Arc::new(Mutex::new(pc)));
        self
    }

    /// Charges `us_per_token` microseconds of simulated device time per
    /// *uncached* prompt token during the prefill step, so TTFT reflects
    /// how much prompt the prefix cache actually skipped.
    pub fn with_prefill_cost(mut self, us_per_token: u64) -> Self {
        self.prefill_cost = std::time::Duration::from_micros(us_per_token);
        self
    }

    /// A mock whose sessions share one cache split into `sessions` equal
    /// regions (DESIGN.md §9): the fixed-partition baseline the paged
    /// layout is measured against.
    pub fn with_equal_partition(
        step_delay_ms: u64,
        tokens_per_step: usize,
        capacity: usize,
        sessions: usize,
    ) -> crate::Result<Self> {
        let part = crate::kvcache::SlotPartition::new(capacity, sessions)?;
        let mut e = Self::new(step_delay_ms, tokens_per_step, capacity);
        e.equal_part = Some(Arc::new(Mutex::new(part)));
        Ok(e)
    }
}

struct MockTask {
    state: TaskState,
    prompt_len: usize,
    produced: usize,
    max_new: usize,
    per_step: usize,
    delay: std::time::Duration,
    /// Serial draft-stage device time (charged per session when the
    /// round is not draft-batched).
    draft_delay: std::time::Duration,
    /// First prompt token + prompt length offset the emitted counter
    /// tokens, so concurrent sessions' streams stay distinguishable
    /// (batch-mixing checks) *and* a preempted session's resumed
    /// incarnation — whose prompt grew by the generated prefix —
    /// continues the exact same sequence.
    seed_tok: u32,
    /// The full prompt (kept for prefix-trie keying; committed slot `j`
    /// holds token `prompt[j]` then generated token `j - prompt_len`).
    prompt: Vec<u32>,
    /// Prompt tokens served by the prefix cache: prefill starts here.
    prefill_skip: usize,
    /// Prompt tokens prefilled so far (the chunk resume point; starts
    /// at `prefill_skip`).
    prefill_pos: usize,
    /// Max prompt tokens prefilled per step (0 = one-shot).
    prefill_chunk: usize,
    /// The attached prefix was counted into the cache's hit gauges
    /// (once, on the first successful prefill chunk).
    reuse_counted: bool,
    /// SLO class: `true` = latency (drafting protected under pressure).
    latency_class: bool,
    /// Engine-wide degradation rung (DESIGN.md §14).
    degrade: Arc<AtomicU8>,
    /// Simulated device time per uncached prefill token.
    prefill_cost: std::time::Duration,
    /// Uncached-prefill-token counter (engine-wide).
    prefilled: Arc<std::sync::atomic::AtomicUsize>,
    /// The engine's prefix cache, for teardown donation.
    prefix: Option<Arc<Mutex<crate::kvcache::PrefixCache>>>,
    /// Slots this task holds (mirrored into the engine gauge).
    held: usize,
    gauge: Arc<std::sync::atomic::AtomicUsize>,
    violations: Arc<std::sync::atomic::AtomicUsize>,
    kv: MockKv,
    /// True per-level acceptance rate under the alloc-model regime
    /// (`prompt[0] % 100` as a fraction; `None` outside the regime).
    accept_q: Option<f64>,
    /// Online acceptance estimate fed back to the round allocator and
    /// mirrored into the server's `accept_rate` stats (DESIGN.md §15).
    accept_est: crate::objective::AcceptanceEstimator,
    /// Fractional-token accumulator: carries the non-integral part of
    /// the truncated-geometric expectation across rounds so emission
    /// stays deterministic.
    frac: f64,
    /// Verification rows the round allocator granted this round.
    round_budget: Option<usize>,
}

impl MockTask {
    fn kv_headroom(&self) -> usize {
        match &self.kv {
            MockKv::Counted { capacity, held } => capacity.saturating_sub(*held),
            MockKv::Cache { cache, .. } => cache.headroom(0),
            MockKv::Unleased => 0,
        }
    }

    /// The counter token emitted at generation index `i`: continuous
    /// across preemption because the resumed prompt includes the prefix.
    fn token_at(&self, i: usize) -> u32 {
        self.seed_tok.wrapping_add((self.prompt_len - 1 + i) as u32)
    }

    /// Allocates `n` simulated KV slots, committing `commit` of them
    /// (the rest model rejected draft slots and are released — which in
    /// paged mode returns fully-free blocks to the shared pool). Every
    /// built mask row is checked against the session's slot ownership.
    fn kv_take(&mut self, n: usize, commit: usize) -> crate::Result<bool> {
        debug_assert!(commit <= n);
        match &mut self.kv {
            MockKv::Counted { capacity, held } => {
                if capacity.saturating_sub(*held) < commit {
                    return Ok(false);
                }
                *held += commit;
                self.held += commit;
                self.gauge.fetch_add(commit, Ordering::Relaxed);
                Ok(true)
            }
            MockKv::Unleased => Ok(false),
            MockKv::Cache { cache, .. } => {
                let Some(slots) = cache.alloc(n) else {
                    if cache.is_paged() {
                        // Typed: the scheduler preempts instead of failing.
                        return Err(cache.exhausted("mock step"));
                    }
                    return Ok(false); // region full: graceful stop
                };
                let cap = cache.capacity();
                let rows = cache.mask_builder().build_linear(&slots, n, n).to_vec();
                if !crate::tree::rows_owned(&rows, cap, &cache.ownership()) {
                    self.violations.fetch_add(1, Ordering::Relaxed);
                }
                for &s in &slots[..commit] {
                    cache.commit(s);
                }
                cache.release(&slots[commit..]);
                let now = cache.in_use();
                if now > self.held {
                    self.gauge.fetch_add(now - self.held, Ordering::Relaxed);
                } else {
                    self.gauge.fetch_sub(self.held - now, Ordering::Relaxed);
                }
                self.held = now;
                Ok(true)
            }
        }
    }

    /// Advances one scheduling step *without* the simulated device delay
    /// — the per-task half of a step. `step()` charges the delay per
    /// task (serial rounds); `MockStepEngine::step_batch` charges it
    /// once per round (the batched-device analog).
    fn advance(&mut self) -> crate::Result<StepOutcome> {
        match self.state {
            TaskState::Done => Ok(StepOutcome { tokens: vec![], state: TaskState::Done }),
            TaskState::Prefill => {
                // Prefill only the prompt tail the prefix cache did not
                // cover (DESIGN.md §12): attached tokens are already
                // committed in the slot cache. With a chunk cap set the
                // tail advances at most `chunk` tokens per step and the
                // task stays in `Prefill` until done (DESIGN.md §14);
                // rung 3+ of the degradation ladder halves the chunk.
                let rung = self.degrade.load(Ordering::Relaxed);
                let mut chunk = self.prefill_chunk;
                if chunk > 0 && rung >= crate::scheduler::RUNG_CHUNK_HARDER {
                    chunk = (chunk / 2).max(1);
                }
                let remaining = self.prompt_len - self.prefill_pos;
                let need = if chunk == 0 { remaining } else { remaining.min(chunk) };
                if !self.kv_take(need, need)? {
                    anyhow::bail!(
                        "mock KV cannot host a {}-token prompt",
                        self.prompt_len
                    );
                }
                // Admitted: the attached prefix is consumed — count it
                // (once, with the first chunk).
                if !self.reuse_counted {
                    self.reuse_counted = true;
                    if let Some(pc) = &self.prefix {
                        pc.lock().unwrap().record_reuse(self.prefill_skip);
                    }
                }
                self.prefilled.fetch_add(need, Ordering::Relaxed);
                if !self.prefill_cost.is_zero() && need > 0 {
                    std::thread::sleep(self.prefill_cost * need as u32);
                }
                self.prefill_pos += need;
                if self.prefill_pos < self.prompt_len {
                    return Ok(StepOutcome { tokens: vec![], state: TaskState::Prefill });
                }
                self.state = if self.max_new == 0 || self.kv_headroom() == 0 {
                    TaskState::Done
                } else {
                    TaskState::Iterate
                };
                Ok(StepOutcome { tokens: vec![], state: self.state })
            }
            TaskState::Iterate => {
                if let (Some(q), Some(b)) = (self.accept_q, self.round_budget) {
                    // Alloc-model regime (DESIGN.md §15): a grant of `b`
                    // verify rows covers a depth-`b` draft chain, so
                    // the round commits the truncated-geometric
                    // expectation 1 + Σ_{d=1..b} q^d — accumulated
                    // fractionally so emission stays deterministic.
                    let mut expect = 1.0;
                    let mut p = 1.0;
                    for _ in 0..b {
                        p *= q;
                        expect += p;
                    }
                    self.frac += expect;
                    let whole = self.frac.floor();
                    self.frac -= whole;
                    let want = (whole as usize).min(self.max_new - self.produced);
                    let n = if want > 0 && !self.kv_take(want, want)? { 0 } else { want };
                    // Feed the estimator the observed draft acceptances
                    // (the bonus token is free) against the rows offered.
                    self.accept_est.record_round(n.saturating_sub(1), b);
                    let tokens: Vec<u32> =
                        (self.produced..self.produced + n).map(|x| self.token_at(x)).collect();
                    self.produced += n;
                    if self.produced >= self.max_new || self.kv_headroom() == 0 {
                        self.state = TaskState::Done;
                    }
                    return Ok(StepOutcome { tokens, state: self.state });
                }
                // Degradation (DESIGN.md §14): rung 2+ stops drafting
                // for throughput-class sessions (one token per round);
                // rung 1+ stops over-allocating rejected-draft slots.
                let rung = self.degrade.load(Ordering::Relaxed);
                let per = if rung >= crate::scheduler::RUNG_SKIP_DRAFT && !self.latency_class {
                    1
                } else {
                    self.per_step
                };
                let want = per.min(self.max_new - self.produced);
                // Model a draft step: `want` accepted slots plus rejected
                // draft slots that release right back — two at full
                // budget, one under a shrunk verify tree (rung 1+). The
                // over-allocation never drops to zero: exhaustion must
                // keep surfacing as the typed error *before* the last
                // slack slot commits, or a starved session would end
                // `Done` with a silently truncated stream instead of
                // preempting.
                let extra = if rung >= crate::scheduler::RUNG_SHRINK_BUDGET { 1 } else { 2 };
                let n = if self.kv_take(want + extra, want)? {
                    want
                } else {
                    // Session-local capacity exhausted: commit what fits.
                    let fit = want.min(self.kv_headroom());
                    if fit > 0 && !self.kv_take(fit, fit)? {
                        0
                    } else {
                        fit
                    }
                };
                let tokens: Vec<u32> =
                    (self.produced..self.produced + n).map(|x| self.token_at(x)).collect();
                self.produced += n;
                if self.produced >= self.max_new || self.kv_headroom() == 0 || n == 0 {
                    self.state = TaskState::Done;
                }
                Ok(StepOutcome { tokens, state: self.state })
            }
        }
    }
}

impl Drop for MockTask {
    fn drop(&mut self) {
        // Prefix-cache insertion (DESIGN.md §12): donate fully-committed
        // prompt blocks to the trie before the reset would free them.
        // Committed slot j holds token (prompt ++ generated)[j].
        if let (Some(pc), MockKv::Cache { cache, .. }) = (&self.prefix, &mut self.kv) {
            let n = cache.committed_len().min(self.prompt_len + self.produced);
            if n > 0 {
                let tokens: Vec<u32> = (0..n)
                    .map(|j| {
                        if j < self.prompt_len {
                            self.prompt[j]
                        } else {
                            // token_at(j - prompt_len), inlined to keep
                            // the borrow of `cache` field-disjoint.
                            self.seed_tok.wrapping_add((j - 1) as u32)
                        }
                    })
                    .collect();
                pc.lock().unwrap().insert(&tokens, &mut [cache]);
            }
        }
        // "Free the KV caches": return every held slot (and the equal-
        // partition lease; a paged SlotCache returns its own blocks and
        // drops its read-shared prefix references).
        self.gauge.fetch_sub(self.held, Ordering::Relaxed);
        if let MockKv::Cache { cache, lease } = &mut self.kv {
            cache.reset();
            if let Some((part, range)) = lease.take() {
                part.lock().unwrap().release(range);
            }
        }
    }
}

impl DecodeTask for MockTask {
    fn state(&self) -> TaskState {
        self.state
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn step(&mut self) -> crate::Result<StepOutcome> {
        if self.state != TaskState::Done {
            std::thread::sleep(self.delay + self.draft_delay);
        }
        self.advance()
    }

    fn headroom(&self) -> usize {
        self.kv_headroom()
    }

    fn uncached_prompt_len(&self) -> Option<usize> {
        // Shrinks chunk by chunk while a chunked prefill is in flight.
        Some(self.prompt_len - self.prefill_pos)
    }

    fn kv_slots_in_use(&self) -> usize {
        self.held
    }

    fn set_slo_class(&mut self, latency: bool) {
        self.latency_class = latency;
    }

    fn retryable(&self) -> bool {
        // A failed `kv_take` allocates nothing, so a pool-exhausted mock
        // step can simply re-run on a later round — letting the
        // scheduler walk the whole degradation ladder before preempting.
        self.state != TaskState::Done
    }

    fn accept_rate(&self) -> Option<f64> {
        self.accept_q.map(|_| self.accept_est.q())
    }

    fn allocated_budget(&self) -> Option<usize> {
        self.round_budget
    }

    fn finish(self: Box<Self>) -> Generation {
        Generation {
            tokens: (0..self.produced).map(|x| self.token_at(x)).collect(),
            iterations: self.produced.div_ceil(self.per_step),
            seconds: self.delay.as_secs_f64() * self.produced.div_ceil(self.per_step) as f64,
            prefill_seconds: self.delay.as_secs_f64(),
            recorder: Recorder::new(),
        }
    }
}

impl StepEngine for MockStepEngine {
    fn begin(&mut self, prompt: &[u32], max_new: usize) -> crate::Result<Box<dyn DecodeTask>> {
        anyhow::ensure!(!prompt.is_empty(), "empty prompt");
        let mut prefill_skip = 0usize;
        let kv = if let Some(pool) = &self.paged_pool {
            let cache = match &self.prefix {
                Some(pc) => {
                    // Attach the longest cached prefix read-shared and
                    // start the prefill at the first uncached token
                    // (the mock commits every prompt token, so the whole
                    // prompt keys the trie).
                    let mut cache =
                        crate::kvcache::SlotCache::paged_with_prefix(pool.clone(), pc.clone());
                    let hit = pc.lock().unwrap().acquire(prompt);
                    if hit.tokens > 0 {
                        cache.attach_prefix(&hit.blocks[0]);
                        prefill_skip = hit.tokens;
                    }
                    cache
                }
                None => crate::kvcache::SlotCache::paged(pool.clone()),
            };
            MockKv::Cache { cache, lease: None }
        } else if let Some(part) = &self.equal_part {
            let (leased, total) = {
                let mut p = part.lock().unwrap();
                (p.lease(), p.total_capacity())
            };
            match leased {
                Some(range) => MockKv::Cache {
                    cache: crate::kvcache::SlotCache::with_range(
                        range,
                        total,
                        total as u32 - 1,
                    ),
                    lease: Some((part.clone(), range)),
                },
                // Every region taken: zero headroom → admission rejects.
                None => MockKv::Unleased,
            }
        } else {
            MockKv::Counted { capacity: self.capacity, held: 0 }
        };
        Ok(Box::new(MockTask {
            state: TaskState::Prefill,
            prompt_len: prompt.len(),
            produced: 0,
            max_new,
            per_step: self.tokens_per_step,
            delay: self.step_delay,
            draft_delay: self.draft_delay,
            seed_tok: prompt[0],
            prompt: prompt.to_vec(),
            prefill_skip,
            prefill_pos: prefill_skip,
            prefill_chunk: self.prefill_chunk,
            reuse_counted: false,
            latency_class: true,
            degrade: self.degrade.clone(),
            prefill_cost: self.prefill_cost,
            prefilled: self.prefilled_tokens.clone(),
            prefix: self.prefix.clone(),
            held: 0,
            gauge: self.slots_in_use.clone(),
            violations: self.violations.clone(),
            kv,
            accept_q: self
                .alloc
                .map(|_| ((prompt[0] % 100) as f64 / 100.0).clamp(0.01, 0.99)),
            accept_est: crate::objective::AcceptanceEstimator::seeded(0.5),
            frac: 0.0,
            round_budget: None,
        }))
    }

    /// The mock analog of cross-session batching: one simulated *verify*
    /// delay serves the whole round (the §9 packed verify), and the
    /// simulated *draft* stage costs one `draft_delay` per round when
    /// `batch_draft` (the §11 stage-aligned packed draft calls) but one
    /// per live session otherwise — the verify-only regime, where the
    /// drafter still serializes N× under N concurrent clients.
    fn step_batch(
        &mut self,
        tasks: &mut [&mut dyn DecodeTask],
    ) -> Vec<crate::Result<StepOutcome>> {
        let t0 = Instant::now();
        let live = tasks.iter().filter(|t| t.state() != TaskState::Done).count();
        // Round-budget resolution (DESIGN.md §15): one global allocation
        // across the live iterate-stage sessions, priced per granted row.
        let mut alloc_rows = 0usize;
        if let Some(model) = self.alloc {
            let mut idxs: Vec<usize> = Vec::new();
            let mut demands: Vec<crate::scheduler::alloc::SessionDemand> = Vec::new();
            for (i, t) in tasks.iter_mut().enumerate() {
                let Some(m) = t.as_any_mut().downcast_mut::<MockTask>() else {
                    continue;
                };
                if m.state != TaskState::Iterate || m.accept_q.is_none() {
                    continue;
                }
                idxs.push(i);
                demands.push(crate::scheduler::alloc::SessionDemand {
                    q: m.accept_est.q(),
                    envelope: model.base_budget * 2,
                    headroom: m.kv_headroom().max(1),
                    latency_class: m.latency_class,
                });
            }
            if !demands.is_empty() {
                let global = model.base_budget * demands.len();
                let budgets = if model.adaptive {
                    crate::scheduler::alloc::allocate_verify_budget(
                        &demands,
                        global,
                        usize::MAX,
                        None,
                    )
                } else {
                    crate::scheduler::alloc::uniform_verify_budget(&demands, global)
                };
                alloc_rows = budgets.iter().sum();
                for (k, &i) in idxs.iter().enumerate() {
                    if let Some(m) = tasks[i].as_any_mut().downcast_mut::<MockTask>() {
                        m.round_budget = Some(budgets[k]);
                    }
                }
            }
        }
        if live > 0 {
            let tr = self.tracer.as_deref();
            // Stage order mirrors the real round (DESIGN.md §11): the
            // draft stage precedes the packed verify. Spans use uid 0 —
            // they cover the whole batch, not one request.
            if !self.draft_delay.is_zero() {
                let sp = tr.map(|t| t.begin(crate::trace::Name::TreeDraft, 0));
                let rides = if self.batch_draft { 1 } else { live as u32 };
                std::thread::sleep(self.draft_delay * rides);
                if let (Some(t), Some(sp)) = (tr, sp) {
                    t.end(crate::trace::Name::TreeDraft, 0, sp);
                }
            }
            let sp = tr.map(|t| t.begin(crate::trace::Name::Verify, 0));
            std::thread::sleep(self.step_delay);
            if let Some(model) = self.alloc.filter(|_| alloc_rows > 0) {
                std::thread::sleep(model.row_cost * alloc_rows as u32);
            }
            if let (Some(t), Some(sp)) = (tr, sp) {
                t.end(crate::trace::Name::Verify, 0, sp);
            }
        }
        let outs: Vec<crate::Result<StepOutcome>> = tasks
            .iter_mut()
            .map(|t| {
                if let Some(m) = t.as_any_mut().downcast_mut::<MockTask>() {
                    return m.advance();
                }
                t.step()
            })
            .collect();
        self.calls
            .lock()
            .unwrap()
            .push(MockCall { live, seconds: t0.elapsed().as_secs_f64() });
        outs
    }

    fn set_degradation(&mut self, rung: u8) {
        self.degrade.store(rung, Ordering::Relaxed);
        self.rungs_seen.lock().unwrap().push(rung);
    }

    fn set_tracer(&mut self, tracer: Arc<crate::trace::Tracer>) {
        self.tracer = Some(tracer);
    }

    fn cache_occupancy(&self) -> Option<(u64, u64)> {
        self.paged_pool.as_ref().map(|p| {
            let p = p.lock().unwrap();
            (p.blocks_in_use() as u64, p.num_blocks() as u64)
        })
    }

    fn prefix_stats(&self) -> Option<crate::kvcache::PrefixCacheStats> {
        self.prefix.as_ref().map(|pc| pc.lock().unwrap().stats())
    }
}

impl Engine for MockStepEngine {
    fn name(&self) -> String {
        "mock-step".into()
    }

    fn generate_with(
        &mut self,
        prompt: &[u32],
        max_new: usize,
        sink: crate::engine::TokenSink,
    ) -> crate::Result<Generation> {
        let task = self.begin(prompt, max_new)?;
        drive(task, sink)
    }
}

/// Keyed response demux used by tests that multiplex one connection.
pub fn group_events(lines: &[String]) -> BTreeMap<u64, Vec<Json>> {
    let mut out: BTreeMap<u64, Vec<Json>> = BTreeMap::new();
    for l in lines {
        if let Ok(j) = Json::parse(l) {
            let id = j.get("id").and_then(|v| v.as_u64()).unwrap_or(0);
            out.entry(id).or_default().push(j);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts(stream: bool) -> ServeOpts {
        ServeOpts { max_queue: 8, max_sessions: 4, stream, ..ServeOpts::default() }
    }

    #[test]
    fn echo_roundtrip_with_streaming() {
        let srv = Server::spawn("127.0.0.1:0", Box::new(EchoEngine), opts(true)).unwrap();
        let mut c = Client::connect(&srv.addr).unwrap();
        let r = c.generate(1, &[10, 20, 30], 7).unwrap();
        assert_eq!(r.tokens, vec![10, 20, 30, 10, 20, 30, 10]);
        assert!(r.stream_events >= 2, "expected streamed chunks");
        assert!(r.queue_ms >= 0.0);
        assert!(r.ttft_ms >= 0.0);
        assert_eq!(srv.stats.requests.load(std::sync::atomic::Ordering::Relaxed), 1);
    }

    #[test]
    fn multiple_sequential_requests_share_the_engine() {
        let srv = Server::spawn("127.0.0.1:0", Box::new(EchoEngine), opts(false)).unwrap();
        let mut c = Client::connect(&srv.addr).unwrap();
        for i in 0..5 {
            let r = c.generate(i, &[1, 2], 4).unwrap();
            assert_eq!(r.tokens.len(), 4);
            assert_eq!(r.stream_events, 0, "stream disabled");
        }
        assert_eq!(srv.stats.tokens.load(std::sync::atomic::Ordering::Relaxed), 20);
    }

    #[test]
    fn concurrent_clients_all_complete() {
        let srv = Server::spawn("127.0.0.1:0", Box::new(EchoEngine), opts(false)).unwrap();
        let addr = srv.addr;
        let handles: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    let mut c = Client::connect(&addr).unwrap();
                    c.generate(i, &[i as u32 + 1], 3).unwrap().tokens
                })
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            let toks = h.join().unwrap();
            assert_eq!(toks, vec![i as u32 + 1; 3]);
        }
    }

    #[test]
    fn malformed_requests_get_error_events() {
        let srv = Server::spawn("127.0.0.1:0", Box::new(EchoEngine), opts(false)).unwrap();
        let sock = TcpStream::connect(srv.addr).unwrap();
        let mut w = sock.try_clone().unwrap();
        writeln!(w, "this is not json").unwrap();
        let mut r = BufReader::new(sock);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.str("event").unwrap(), "error");
    }

    #[test]
    fn text_requests_are_byte_tokenized() {
        let srv = Server::spawn("127.0.0.1:0", Box::new(EchoEngine), opts(false)).unwrap();
        let sock = TcpStream::connect(srv.addr).unwrap();
        let mut w = sock.try_clone().unwrap();
        writeln!(w, r#"{{"id": 3, "text": "hi", "max_new": 2}}"#).unwrap();
        let mut r = BufReader::new(sock);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.str("event").unwrap(), "done");
        // "hi" = [104, 105] cycled twice
        let toks: Vec<usize> =
            j.arr("tokens").unwrap().iter().map(|t| t.as_usize().unwrap()).collect();
        assert_eq!(toks, vec![104, 105]);
    }

    #[test]
    fn string_ids_beyond_f64_precision_roundtrip() {
        let srv = Server::spawn("127.0.0.1:0", Box::new(EchoEngine), opts(false)).unwrap();
        let sock = TcpStream::connect(srv.addr).unwrap();
        let mut w = sock.try_clone().unwrap();
        let big = u64::MAX - 7;
        writeln!(w, r#"{{"id": "{big}", "prompt": [5], "max_new": 2}}"#).unwrap();
        let mut r = BufReader::new(sock);
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.str("event").unwrap(), "done");
        assert_eq!(j.u64("id").unwrap(), big, "id must survive bit-exact");
    }

    #[test]
    fn stats_request_reports_counters() {
        let srv = Server::spawn("127.0.0.1:0", Box::new(EchoEngine), opts(false)).unwrap();
        let mut c = Client::connect(&srv.addr).unwrap();
        let _ = c.generate(1, &[4, 5], 6).unwrap();
        let s = c.stats().unwrap();
        assert_eq!(s.u64("requests").unwrap(), 1);
        assert_eq!(s.u64("tokens").unwrap(), 6);
        assert_eq!(s.u64("cancelled").unwrap(), 0);
        assert!(s.f64("queue_delay_ms_mean").unwrap() >= 0.0);
    }

    /// Satellite: the `{"metrics": true}` reply's body must parse as
    /// valid Prometheus text exposition (DESIGN.md §17).
    #[test]
    fn metrics_request_returns_valid_prometheus_text() {
        let srv = Server::spawn("127.0.0.1:0", Box::new(EchoEngine), opts(false)).unwrap();
        let mut c = Client::connect(&srv.addr).unwrap();
        let _ = c.generate(1, &[4, 5], 6).unwrap();
        let body = c.metrics().unwrap();
        crate::trace::validate_prometheus(&body).unwrap();
        assert!(body.contains(r#"ygg_requests_total{worker="fleet"} 1"#), "{body}");
        assert!(body.contains(r#"ygg_tokens_total{worker="0"} 6"#));
        assert!(body.contains("# TYPE ygg_queue_delay_seconds histogram"));
    }

    #[test]
    fn client_rejects_malformed_done_tokens_instead_of_zeroing() {
        // A `done` event carrying a non-numeric token must surface as a
        // typed error — the old `as_usize().unwrap_or(0)` silently
        // replaced it with token 0, corrupting the stream.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (sock, _) = listener.accept().unwrap();
            let mut r = BufReader::new(sock.try_clone().unwrap());
            let mut line = String::new();
            r.read_line(&mut line).unwrap(); // consume the request
            let mut w = sock;
            writeln!(
                w,
                r#"{{"id": 1, "event": "done", "tokens": [5, "bogus", 7], "aal": 1.0, "tpot_ms": 1.0, "iterations": 1, "prefill_ms": 0.1}}"#
            )
            .unwrap();
        });
        let mut c = Client::connect(&addr).unwrap();
        let err = c.generate(1, &[1, 2], 4).unwrap_err();
        assert!(
            format!("{err:#}").contains("malformed token"),
            "unexpected error: {err:#}"
        );
        server.join().unwrap();
    }

    /// Bit-exactness gate (DESIGN.md §15): with identical acceptance
    /// profiles the adaptive allocator must early-return to the uniform
    /// water-fill, so the per-round emission schedule — not just the
    /// final streams — matches the uniform baseline exactly.
    #[test]
    fn alloc_mode_is_bit_exact_vs_uniform_for_identical_profiles() {
        let run = |adaptive: bool| -> Vec<Vec<Vec<u32>>> {
            let mut e = MockStepEngine::new(0, 2, 1 << 20).with_alloc_model(4, 0, adaptive);
            let mut tasks: Vec<Box<dyn DecodeTask>> =
                (0..3).map(|_| e.begin(&[50, 1, 2], 40).unwrap()).collect();
            let mut streams = vec![Vec::new(); 3];
            for _ in 0..64 {
                let mut refs: Vec<&mut dyn DecodeTask> =
                    tasks.iter_mut().map(|t| t.as_mut()).collect();
                let outs = e.step_batch(&mut refs);
                for (k, o) in outs.into_iter().enumerate() {
                    streams[k].push(o.unwrap().tokens);
                }
            }
            streams
        };
        assert_eq!(
            run(true),
            run(false),
            "identical profiles must produce identical round schedules"
        );
    }

    /// Adaptive skew (DESIGN.md §15): once the online estimators
    /// diverge, the allocator gives the high-acceptance session deeper
    /// trees and the low-acceptance one shallow probes, within the
    /// shared global budget.
    #[test]
    fn alloc_mode_skews_budgets_toward_high_acceptance_sessions() {
        let mut e = MockStepEngine::new(0, 2, 1 << 20).with_alloc_model(8, 0, true);
        let mut easy = e.begin(&[90; 4], 400).unwrap();
        let mut hard = e.begin(&[10; 4], 400).unwrap();
        for _ in 0..40 {
            let mut refs: Vec<&mut dyn DecodeTask> = vec![easy.as_mut(), hard.as_mut()];
            let _ = e.step_batch(&mut refs);
        }
        let be = easy.allocated_budget().unwrap();
        let bh = hard.allocated_budget().unwrap();
        assert!(be > bh, "easy session got {be} rows vs hard {bh}");
        assert!(be + bh <= 16, "global budget (2 × 8 rows) exceeded");
        assert!(easy.accept_rate().unwrap() > hard.accept_rate().unwrap());
    }

    /// Satellite: a class with zero ITL samples must not emit its keys
    /// at all — the old unconditional emission serialized the NaN
    /// percentile as `null` for every idle class.
    #[test]
    fn stats_json_omits_itl_keys_for_classes_without_samples() {
        let stats = ServerStats::default();
        let j = stats.snapshot().to_json();
        assert!(j.get("itl_ms_p50_latency").is_none(), "no samples → no key");
        assert!(j.get("itl_ms_p95_latency").is_none());
        assert!(j.get("itl_ms_p50_throughput").is_none());
        assert!(j.get("itl_ms_p95_throughput").is_none());
        // Counters and means still emit (means degrade to null, which is
        // meaningful for always-present keys).
        assert_eq!(j.u64("requests").unwrap(), 0);
        assert!(j.get("queue_delay_ms_mean").is_some());
        // One latency-class sample: its keys appear, the idle class stays
        // omitted.
        stats.recorder.lock().unwrap().record("server.itl_s.latency", 0.5);
        let j = stats.snapshot().to_json();
        assert_eq!(j.f64("itl_ms_p50_latency").unwrap(), 500.0);
        assert_eq!(j.f64("itl_ms_p95_latency").unwrap(), 500.0);
        assert!(j.get("itl_ms_p50_throughput").is_none(), "idle class still omitted");
    }

    #[test]
    fn merged_stats_sum_counters_max_rungs_and_concatenate_series() {
        use std::sync::atomic::Ordering::Relaxed;
        let a = ServerStats::default();
        let b = ServerStats::default();
        a.requests.store(2, Relaxed);
        b.requests.store(3, Relaxed);
        a.tokens.store(40, Relaxed);
        b.tokens.store(2, Relaxed);
        a.degrade_rung.store(1, Relaxed);
        b.degrade_rung.store(3, Relaxed);
        a.recorder.lock().unwrap().record("server.ttft_s", 0.5);
        b.recorder.lock().unwrap().record("server.ttft_s", 0.25);
        b.recorder.lock().unwrap().record("server.ttft_s", 0.25);
        let acc = ServerStats::default();
        acc.merge_from(&a);
        acc.merge_from(&b);
        let s = acc.snapshot();
        assert_eq!(s.requests, 5);
        assert_eq!(s.tokens, 42);
        assert_eq!(s.degrade_rung, 3, "fleet rung is the max, not a sum");
        // Percentiles over the *concatenated* samples [0.5, 0.25, 0.25]:
        // the median is 0.25s, not the mean of per-worker medians.
        assert_eq!(s.ttft_ms_p50, 250.0);
    }

    #[test]
    fn parse_request_accepts_numeric_and_string_ids() {
        let Ok(Req::Generate { id, .. }) = parse_request(r#"{"id": 42, "prompt": [1]}"#) else {
            panic!("numeric id rejected")
        };
        assert_eq!(id, 42);
        let Ok(Req::Generate { id, .. }) =
            parse_request(r#"{"id": "18446744073709551615", "prompt": [1]}"#)
        else {
            panic!("string id rejected")
        };
        assert_eq!(id, u64::MAX);
        assert!(parse_request(r#"{"prompt": []}"#).is_err(), "empty prompt");
        // Invalid ids are rejected loudly, not silently mapped to 0.
        assert!(parse_request(r#"{"id": 1.5, "prompt": [1]}"#).is_err());
        assert!(parse_request(r#"{"id": -3, "prompt": [1]}"#).is_err());
    }
}
