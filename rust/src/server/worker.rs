//! The engine-worker boundary (DESIGN.md §16): one [`EngineWorker`] owns
//! one `StepEngine` — and with it that engine's `SharedCachePool`, paged
//! block pool, and radix prefix trie — plus the continuous-serving
//! scheduler loop on a dedicated thread. The server frontend owns *no*
//! engine state; it only talks to workers through their [`JobQueue`]s
//! (command side) and each job's typed [`ServerEvent`](super::ServerEvent)
//! reply channel (event side), mirroring the actor-runtime pattern of
//! `runtime/actor.rs` (spawn → ready handshake → channel-driven loop →
//! close-to-shutdown).
//!
//! ## Why a deque and not a channel
//!
//! The worker's inbox is a [`JobQueue`] — a condvar-signalled deque —
//! instead of the previous `mpsc::sync_channel`, because the router's
//! work-stealing rebalance must be able to *take jobs back* from an
//! overloaded worker's backlog. The queue gives that operation a
//! structural safety guarantee: it only ever holds jobs that no engine
//! has touched (never admitted, never prefilled, no streamed tokens).
//! Preempted jobs — which *have* streamed tokens and must resume on the
//! worker that holds their state — live in the scheduler's private
//! resume deque inside `run_worker`, unreachable from here. Stealing
//! from the back (`steal_back`) while the worker pops from the front
//! also means the jobs most likely to wait longest are the ones that
//! migrate.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Duration;

use crate::engine::StepEngine;
use crate::trace::Tracer;

use super::{sessions, CancelFlag, Job, ServeOpts, ServerStats};

/// Result of a bounded blocking pop from a [`JobQueue`].
pub enum Pop {
    /// A job was dequeued.
    Job(Job),
    /// The timeout elapsed with the queue still empty.
    Timeout,
    /// The queue is closed and drained: the worker should exit.
    Closed,
}

struct QueueState {
    jobs: VecDeque<Job>,
    closed: bool,
}

/// Bounded two-ended job inbox shared between one worker (front) and the
/// router (back). See the module docs for why this replaces a channel.
pub struct JobQueue {
    state: Mutex<QueueState>,
    ready: Condvar,
    cap: usize,
}

impl JobQueue {
    /// An open queue holding at most `cap` pending jobs.
    pub fn new(cap: usize) -> Self {
        Self {
            state: Mutex::new(QueueState { jobs: VecDeque::new(), closed: false }),
            ready: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Enqueues at the back. Returns the job on a full or closed queue so
    /// the caller can spill it to another worker or reject it.
    pub fn try_push(&self, job: Job) -> Result<(), Job> {
        let mut s = self.state.lock().unwrap();
        if s.closed || s.jobs.len() >= self.cap {
            return Err(job);
        }
        s.jobs.push_back(job);
        drop(s);
        self.ready.notify_one();
        Ok(())
    }

    /// Non-blocking pop from the front (the worker's admission path).
    pub fn try_pop(&self) -> Option<Job> {
        self.state.lock().unwrap().jobs.pop_front()
    }

    /// Blocking pop from the front, bounded by `timeout` so the worker's
    /// stop flag stays responsive.
    pub fn pop_timeout(&self, timeout: Duration) -> Pop {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(job) = s.jobs.pop_front() {
                return Pop::Job(job);
            }
            if s.closed {
                return Pop::Closed;
            }
            let (next, res) = self.ready.wait_timeout(s, timeout).unwrap();
            s = next;
            if res.timed_out() {
                return match s.jobs.pop_front() {
                    Some(job) => Pop::Job(job),
                    None if s.closed => Pop::Closed,
                    None => Pop::Timeout,
                };
            }
        }
    }

    /// Pops from the *back* — the router's work-stealing side. Every job
    /// here is still pending by construction; the debug assertion pins
    /// the invariant that a stolen job was never admitted anywhere.
    pub fn steal_back(&self) -> Option<Job> {
        let job = self.state.lock().unwrap().jobs.pop_back()?;
        debug_assert!(
            job.queue_s.is_none() && job.first_token.is_none() && job.resumed.is_empty(),
            "stolen job must be pending: never admitted, prefilled, or streamed"
        );
        Some(job)
    }

    /// Pending jobs (the worker's backlog).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().jobs.len()
    }

    /// True when no jobs are pending.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Closes the queue: future pushes fail, pops drain what remains,
    /// and a blocked worker wakes to exit.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.ready.notify_all();
    }
}

/// One data-parallel serving worker: a `StepEngine` (with its own cache
/// pool and prefix trie), its [`JobQueue`] inbox, its own
/// [`ServerStats`], and the scheduler loop on a named thread.
pub struct EngineWorker {
    /// Fleet-wide worker index (also the uid namespace, DESIGN.md §16).
    pub id: usize,
    /// This worker's serving statistics (aggregated fleet-wide by the
    /// router's [`FleetSnapshot`](super::FleetSnapshot)).
    pub stats: Arc<ServerStats>,
    /// This worker's flight recorder (DESIGN.md §17): the scheduler loop,
    /// the engine's stage spans, and the router's placement/steal events
    /// all record into it; exporters read it from here.
    pub tracer: Arc<Tracer>,
    queue: Arc<JobQueue>,
    stop: CancelFlag,
    thread: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl EngineWorker {
    /// Moves `engine` onto a dedicated scheduler thread (named
    /// `ygg-worker-{id}`) and returns once the thread has signalled
    /// ready, mirroring the actor-runtime spawn handshake.
    pub fn spawn(
        id: usize,
        mut engine: Box<dyn StepEngine + Send>,
        opts: &ServeOpts,
    ) -> crate::Result<Self> {
        let queue = Arc::new(JobQueue::new(opts.max_queue));
        let stats = Arc::new(ServerStats::default());
        let tracer = Arc::new(Tracer::new(id, opts.trace_ring));
        let stop: CancelFlag = Arc::new(AtomicBool::new(false));
        let (ready_tx, ready_rx) = mpsc::channel::<()>();
        engine.set_tracer(tracer.clone());
        let (q, s, tr, st, o) =
            (queue.clone(), stats.clone(), tracer.clone(), stop.clone(), opts.clone());
        let thread = std::thread::Builder::new()
            .name(format!("ygg-worker-{id}"))
            .spawn(move || {
                let _ = ready_tx.send(());
                sessions::run_worker(engine, q, s, tr, st, o);
            })?;
        let _ = ready_rx.recv();
        Ok(Self { id, stats, tracer, queue, stop, thread: Mutex::new(Some(thread)) })
    }

    /// The worker's job inbox (the router pushes and steals here).
    pub fn queue(&self) -> &Arc<JobQueue> {
        &self.queue
    }

    /// Pending (not yet admitted) jobs.
    pub fn backlog(&self) -> usize {
        self.queue.len()
    }

    /// Routing load: pending jobs plus live sessions. The gauge lags one
    /// scheduling round, which is fine for placement — affinity routing
    /// dominates ties and the backlog half updates synchronously.
    pub fn load(&self) -> usize {
        self.queue.len() + self.stats.active_sessions.load(Ordering::Relaxed) as usize
    }

    /// Stops the scheduler loop and joins the thread. Idempotent; live
    /// sessions are aborted and their caches freed (task drop).
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
        self.queue.close();
        if let Some(t) = self.thread.lock().unwrap().take() {
            let _ = t.join();
        }
    }
}

impl Drop for EngineWorker {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::super::SloClass;
    use super::*;
    use std::sync::atomic::AtomicBool;

    fn job(id: u64) -> (Job, mpsc::Receiver<super::super::ServerEvent>) {
        let (tx, rx) = mpsc::channel();
        let cancel = Arc::new(AtomicBool::new(false));
        (Job::new(id, vec![1, 2, 3], 4, SloClass::Latency, tx, false, cancel), rx)
    }

    #[test]
    fn queue_is_fifo_for_the_worker_and_lifo_for_the_thief() {
        let q = JobQueue::new(8);
        let rxs: Vec<_> = (0..4)
            .map(|i| {
                let (j, rx) = job(i);
                q.try_push(j).ok().unwrap();
                rx
            })
            .collect();
        assert_eq!(q.len(), 4);
        // The worker drains oldest-first…
        assert_eq!(q.try_pop().unwrap().id, 0);
        // …the thief takes the youngest (longest expected wait).
        assert_eq!(q.steal_back().unwrap().id, 3);
        assert_eq!(q.steal_back().unwrap().id, 2);
        assert_eq!(q.try_pop().unwrap().id, 1);
        assert!(q.try_pop().is_none());
        assert!(q.steal_back().is_none());
        drop(rxs);
    }

    #[test]
    fn full_and_closed_queues_hand_the_job_back() {
        let q = JobQueue::new(1);
        let (a, _ra) = job(0);
        let (b, _rb) = job(1);
        assert!(q.try_push(a).is_ok());
        let Err(b) = q.try_push(b) else { panic!("full queue must refuse") };
        assert_eq!(b.id, 1);
        q.close();
        assert!(q.try_push(b).is_err(), "closed queue must refuse");
        // A closed queue still drains what it holds…
        assert_eq!(q.try_pop().unwrap().id, 0);
        // …then reports Closed rather than Timeout.
        assert!(matches!(q.pop_timeout(Duration::from_millis(1)), Pop::Closed));
    }

    #[test]
    fn stolen_jobs_are_always_pending() {
        let q = JobQueue::new(4);
        let (j, _rx) = job(7);
        q.try_push(j).ok().unwrap();
        let stolen = q.steal_back().unwrap();
        assert!(stolen.queue_s.is_none(), "never admitted");
        assert!(stolen.first_token.is_none(), "never streamed");
        assert!(stolen.resumed.is_empty(), "never preempted");
    }

    #[test]
    fn pop_timeout_wakes_on_push() {
        let q = Arc::new(JobQueue::new(4));
        let q2 = q.clone();
        let t = std::thread::spawn(move || match q2.pop_timeout(Duration::from_secs(5)) {
            Pop::Job(j) => j.id,
            _ => u64::MAX,
        });
        std::thread::sleep(Duration::from_millis(10));
        let (j, _rx) = job(42);
        q.try_push(j).ok().unwrap();
        assert_eq!(t.join().unwrap(), 42);
    }

    #[test]
    fn worker_serves_jobs_pushed_straight_into_its_queue() {
        let engine = Box::new(super::super::EchoEngine);
        let w = EngineWorker::spawn(3, engine, &ServeOpts::default()).unwrap();
        let (j, rx) = job(9);
        w.queue().try_push(j).ok().unwrap();
        let mut tokens = Vec::new();
        loop {
            match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
                super::super::ServerEvent::Done { id, summary } => {
                    assert_eq!(id, 9);
                    tokens = summary.tokens;
                    break;
                }
                super::super::ServerEvent::Tokens { .. } => {}
                other => panic!("unexpected event {other:?}"),
            }
        }
        assert_eq!(tokens, vec![1, 2, 3, 1]);
        assert_eq!(w.stats.requests.load(Ordering::Relaxed), 1);
        w.shutdown();
    }
}
