//! Continuous multi-session serving: the scheduler that replaced the
//! single-tenant FCFS worker.
//!
//! One worker thread still owns the engine (the device is single-tenant —
//! submission order is execution order), but instead of running each
//! request to completion it keeps up to `max_sessions` resumable
//! [`DecodeTask`]s live and runs **one scheduling round per loop
//! iteration** over all of them. Every live client therefore streams
//! tokens every round — a long generation can no longer block every
//! client behind it — and the serving regime becomes iteration-level
//! interleaving (the SpecInfer/vLLM-style continuous batching
//! discipline, at step rather than batch granularity).
//!
//! In batched mode ([`ServeOpts::batched`], the default) a round is
//! **stage-aligned**: the whole live set enters
//! [`StepEngine::step_batch`] together, whose engine-side phases — draft
//! (packed head call, then one packed drafter call per tree level),
//! CPU build, packed verify — advance every session through the *same*
//! stage before any session moves to the next, so sessions at the same
//! tree level ride one width-padded device call instead of issuing one
//! narrow call each (DESIGN.md §9 + §11). `--round-robin` restores
//! serial time-sliced `step()`s.
//!
//! * **Admission control** — a job leaves the queue only when a session
//!   slot is free, and its freshly opened task must report enough
//!   [`DecodeTask::headroom`] (KV-slot budget, via
//!   `engine::Session::headroom`) to cover the prompt; otherwise the
//!   request is rejected with a typed error before any device work.
//!   Under a paged shared cache (DESIGN.md §10) the headroom counts the
//!   shared block pool, so admission is **token-level**: a request is
//!   admitted whenever the pool covers prompt + tree budget, not when a
//!   worst-case fixed region happens to be free.
//! * **Preemption / resume** — a paged session whose mid-generation
//!   allocation finds the pool dry fails its step with the typed
//!   [`PoolExhausted`] marker. The scheduler *preempts* it: the task is
//!   dropped (every leased block returns to the pool immediately), the
//!   tokens generated so far are appended to the saved prompt, and the
//!   job is requeued for a re-prefill resume once blocks free up.
//!   Resumed jobs have priority over fresh admissions; a resumed job
//!   that can never fit (nothing live holds blocks) or exceeds
//!   `max_resumes` gets a terminal error instead of livelocking.
//! * **Cancellation** — each connection owns a cancel flag, raised when
//!   the client disconnects (reader EOF or a failed write). The scheduler
//!   checks it before every step and simply drops the session: the task
//!   owns its KV caches, so the drop frees them immediately and the slot
//!   admits the next queued request in the same round.
//! * **Metrics** — per-request queueing delay, time-to-first-token,
//!   decode throughput and (for preempted requests) preempt-to-resume
//!   delay are recorded into the shared
//!   [`ServerStats`](super::ServerStats) recorder and echoed on each
//!   `done` event; block-pool occupancy gauges update every round.
//!
//! Worker→connection traffic is the typed [`ServerEvent`] enum; JSON only
//! exists at the connection boundary (`ServerEvent::to_json`). The old
//! per-request pump that sniffed `"event":"done"` substrings is gone
//! entirely: one writer pump per connection forwards every event and
//! request lifetimes are tracked by the scheduler, not the wire format.

use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use crate::engine::{DecodeTask, StepEngine, StepOutcome, TaskState};
use crate::kvcache::PoolExhausted;
use crate::scheduler::DegradationLadder;
use crate::trace::{self, Name, Tracer};
use crate::util::json::Json;
use crate::util::log;

use super::{CancelFlag, FleetSnapshot, ServeOpts, ServerStats, SloClass};

/// Sliding window for the per-request serving series: bounds the stats
/// recorder's memory (and each snapshot's percentile scan) on servers
/// that run indefinitely.
const STATS_WINDOW: usize = 4096;

/// Rounds a parked resumed job waits between re-admission attempts.
/// Each attempt costs an `engine.begin()` (session construction) just to
/// run the footprint check, so retrying every single scheduling round
/// would churn allocations on the serving hot loop for nothing — pool
/// headroom only changes when a session finishes or is preempted.
const RESUME_RETRY_ROUNDS: u32 = 4;

/// Final per-request summary carried by [`ServerEvent::Done`].
#[derive(Debug, Clone)]
pub struct DoneSummary {
    /// Generated tokens (complete sequence, including everything
    /// generated before any preemption).
    pub tokens: Vec<u32>,
    /// Average accepted length (final incarnation).
    pub aal: f64,
    /// Per-token latency (ms, final incarnation).
    pub tpot_ms: f64,
    /// Verification iterations used (final incarnation).
    pub iterations: usize,
    /// Prompt prefill time (ms, final incarnation — resumes re-prefill).
    pub prefill_ms: f64,
    /// Time the request waited in the queue before admission.
    pub queue_ms: f64,
    /// Enqueue → first committed token (NaN when nothing was generated).
    pub ttft_ms: f64,
    /// Decode throughput over the request's admitted lifetime (all
    /// incarnations).
    pub tok_per_s: f64,
    /// Times this request was preempted and resumed (paged serving).
    pub preemptions: usize,
}

/// Typed worker→connection event stream. One connection multiplexes many
/// requests; `id` keys the demux client-side.
#[derive(Debug, Clone)]
pub enum ServerEvent {
    /// Tokens committed by one scheduling step (stream mode only).
    Tokens { id: u64, tokens: Vec<u32> },
    /// Generation finished.
    Done { id: u64, summary: DoneSummary },
    /// Request-level failure. `id` is `None` for lines that never parsed
    /// far enough to have one.
    Error { id: Option<u64>, message: String },
    /// Reply to a `{"stats": true}` request (produced connection-side;
    /// fleet-wide, DESIGN.md §16).
    Stats(FleetSnapshot),
    /// Reply to a `{"metrics": true}` request: the fleet's counters,
    /// gauges, and latency histograms rendered in Prometheus text
    /// exposition format (DESIGN.md §17; produced connection-side).
    Metrics(String),
}

impl ServerEvent {
    /// Wire form (one JSON object per line). Ids serialize via
    /// [`Json::from_u64`], so they survive the full u64 range.
    pub fn to_json(&self) -> Json {
        match self {
            ServerEvent::Tokens { id, tokens } => Json::obj(vec![
                ("id", Json::from_u64(*id)),
                ("event", Json::Str("tokens".into())),
                ("tokens", Json::Arr(tokens.iter().map(|&t| Json::Num(t as f64)).collect())),
            ]),
            ServerEvent::Done { id, summary } => Json::obj(vec![
                ("id", Json::from_u64(*id)),
                ("event", Json::Str("done".into())),
                (
                    "tokens",
                    Json::Arr(summary.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
                ),
                ("aal", Json::Num(summary.aal)),
                ("tpot_ms", Json::Num(summary.tpot_ms)),
                ("iterations", Json::Num(summary.iterations as f64)),
                ("prefill_ms", Json::Num(summary.prefill_ms)),
                ("queue_ms", Json::Num(summary.queue_ms)),
                ("ttft_ms", Json::Num(summary.ttft_ms)),
                ("tok_per_s", Json::Num(summary.tok_per_s)),
                ("preemptions", Json::Num(summary.preemptions as f64)),
            ]),
            ServerEvent::Error { id, message } => {
                let mut fields = Vec::new();
                if let Some(id) = id {
                    fields.push(("id", Json::from_u64(*id)));
                }
                fields.push(("event", Json::Str("error".into())));
                fields.push(("message", Json::Str(message.clone())));
                Json::obj(fields)
            }
            ServerEvent::Stats(s) => s.to_json(),
            ServerEvent::Metrics(body) => Json::obj(vec![
                ("event", Json::Str("metrics".into())),
                ("body", Json::Str(body.clone())),
            ]),
        }
    }
}

/// One queued generation request. The scheduler-maintained fields
/// (`resumed`, `preempts`, …) track preemption/resume state across
/// incarnations; connections initialize them empty via [`Job::new`].
pub struct Job {
    /// Client-chosen request id (demux key).
    pub id: u64,
    /// Fleet-unique internal id, minted by the router at placement time
    /// (worker-scoped namespace: `(worker + 1) << 48 | seq`). Client ids
    /// are only unique per connection — two reconnecting clients may both
    /// send `id: 0` — so every cross-worker ledger keys on `uid`, never
    /// on `id`. Zero until the job passes through a router.
    pub uid: u64,
    /// Tokenized prompt. After a preemption this grows by the generated
    /// prefix, so the resumed incarnation re-prefills exactly the context
    /// it stopped at.
    pub prompt: Vec<u32>,
    /// Generation budget (total across incarnations).
    pub max_new: usize,
    /// SLO class (DESIGN.md §14): latency-class requests get protected
    /// inter-token latency; throughput-class requests absorb degradation
    /// first when the pool runs dry.
    pub class: SloClass,
    /// Event channel back to the owning connection's writer pump.
    pub reply: mpsc::Sender<ServerEvent>,
    /// Emit per-step `tokens` events.
    pub stream: bool,
    /// Connection-level cancel flag (client disconnected).
    pub cancelled: CancelFlag,
    /// When the request entered the queue (queue-delay metric).
    pub enqueued: Instant,
    /// Tokens generated before the latest preemption (already streamed;
    /// prepended to the final summary).
    pub resumed: Vec<u32>,
    /// Times this request has been preempted.
    pub preempts: usize,
    /// When the latest preemption happened (resume-delay metric).
    pub preempted_at: Option<Instant>,
    /// When the first token was committed (survives preemptions).
    pub first_token: Option<Instant>,
    /// When the latest token batch was committed — the anchor for the
    /// per-class inter-token-latency series and SLO-violation counting.
    pub last_token: Option<Instant>,
    /// Admitted seconds accumulated by earlier incarnations.
    pub active_s: f64,
    /// Enqueue → *first* admission, in seconds (set once; re-admissions
    /// after a preemption must not inflate the queueing-delay metric).
    pub queue_s: Option<f64>,
    /// Flight-recorder span id of this request's `request` span
    /// (DESIGN.md §17), opened at first admission and closed at
    /// completion/error/disconnect. Survives preemption so the span
    /// covers the whole admit→done lifetime. Zero until admitted.
    pub trace_span: u32,
}

impl Job {
    /// A fresh (never-preempted) request.
    pub fn new(
        id: u64,
        prompt: Vec<u32>,
        max_new: usize,
        class: SloClass,
        reply: mpsc::Sender<ServerEvent>,
        stream: bool,
        cancelled: CancelFlag,
    ) -> Self {
        Self {
            id,
            uid: 0,
            prompt,
            max_new,
            class,
            reply,
            stream,
            cancelled,
            enqueued: Instant::now(),
            resumed: Vec::new(),
            preempts: 0,
            preempted_at: None,
            first_token: None,
            last_token: None,
            active_s: 0.0,
            queue_s: None,
            trace_span: 0,
        }
    }
}

/// A live, admitted session: one resumable task plus its timing marks.
struct ServeSession {
    job: Job,
    task: Box<dyn DecodeTask>,
    admitted: Instant,
}

/// The continuous-serving scheduler loop (the worker thread body).
///
/// Jobs arrive through a [`JobQueue`](super::worker::JobQueue) rather
/// than a plain channel so the router can *steal from the back* of the
/// pending backlog (work-stealing rebalance, DESIGN.md §16). The
/// structural invariant that makes stealing safe lives here: only
/// never-admitted jobs sit in the queue — preempted (already-prefilled)
/// jobs wait in this function's private `resume` deque, which the router
/// cannot reach.
pub(super) fn run_worker(
    engine: Box<dyn StepEngine + Send>,
    queue: Arc<super::worker::JobQueue>,
    stats: Arc<ServerStats>,
    tracer: Arc<Tracer>,
    stop: CancelFlag,
    opts: ServeOpts,
) {
    let mut engine = engine;
    let max_sessions = opts.max_sessions.max(1);
    let mut live: Vec<ServeSession> = Vec::new();
    // Preempted jobs waiting for pool blocks; strictly ahead of fresh
    // admissions (their clients are already mid-stream).
    let mut resume: VecDeque<Job> = VecDeque::new();
    let mut resume_backoff: u32 = 0;
    // Overload-degradation state (DESIGN.md §14): escalates one rung per
    // pool-exhausted round, relaxes after a clean streak.
    let mut ladder = DegradationLadder::new();
    // Scheduling-round counter: stamps every trace event of a round and
    // wraps each round in exactly one `round` span (DESIGN.md §17).
    let mut round_no: u64 = 0;
    while !stop.load(Ordering::Relaxed) {
        resume_backoff = resume_backoff.saturating_sub(1);
        // Admission: fill free session slots — resumes first, then queue.
        while live.len() < max_sessions {
            let (job, fresh) = if resume.is_empty() {
                match queue.try_pop() {
                    Some(j) => (j, true),
                    None => break,
                }
            } else if resume_backoff == 0 {
                (resume.pop_front().unwrap(), false)
            } else {
                // A parked resume keeps priority over fresh jobs but only
                // re-probes every few rounds (each probe costs a begin()).
                break;
            };
            if let Some(parked) = admit(&mut engine, job, &mut live, &stats, &tracer, fresh) {
                if live.is_empty() {
                    // Nothing live holds pool blocks, so headroom will
                    // never improve: the resumed request is unservable.
                    reject_unadmittable(parked, &stats, &tracer);
                } else {
                    resume.push_front(parked);
                    resume_backoff = RESUME_RETRY_ROUNDS;
                    break;
                }
            }
        }
        stats.peak_sessions.fetch_max(live.len() as u64, Ordering::Relaxed);
        if live.is_empty() {
            stats.active_sessions.store(0, Ordering::Relaxed);
            stats.kv_slots_in_use.store(0, Ordering::Relaxed);
            // Idle: block for work (bounded, so `stop` stays responsive).
            match queue.pop_timeout(Duration::from_millis(20)) {
                super::worker::Pop::Job(job) => {
                    let _ = admit(&mut engine, job, &mut live, &stats, &tracer, true);
                }
                super::worker::Pop::Timeout => {}
                super::worker::Pop::Closed => break,
            }
            continue;
        }
        round_no += 1;
        tracer.set_round(round_no);
        let round_span = tracer.begin(Name::Round, 0);
        round(&mut engine, &mut live, &mut resume, &stats, &tracer, &opts, &mut ladder);
        tracer.end(Name::Round, 0, round_span);
        let kv: usize = live.iter().map(|s| s.task.kv_slots_in_use()).sum();
        stats.active_sessions.store(live.len() as u64, Ordering::Relaxed);
        stats.kv_slots_in_use.store(kv as u64, Ordering::Relaxed);
        if let Some((used, total)) = engine.cache_occupancy() {
            stats.blocks_in_use.store(used, Ordering::Relaxed);
            stats.blocks_total.store(total, Ordering::Relaxed);
        }
        if let Some(ps) = engine.prefix_stats() {
            stats.prefix_lookups.store(ps.lookups, Ordering::Relaxed);
            stats.prefix_hits.store(ps.hits, Ordering::Relaxed);
            stats.prefix_tokens_reused.store(ps.tokens_reused, Ordering::Relaxed);
            stats.prefix_evictions.store(ps.evictions, Ordering::Relaxed);
            stats.prefix_cached_blocks.store(ps.cached_blocks, Ordering::Relaxed);
        }
        // Allocator observability (DESIGN.md §15, §17): mirror each
        // session's online acceptance estimate into the `accept_rate`
        // percentile series, each grant into an `alloc_grant` trace
        // instant, and the round's rollup into the budget gauge. The
        // summary is folded per session — no intermediate Vec — to keep
        // the steady round loop allocation-free.
        let mut grants = crate::scheduler::alloc::GrantSummary::default();
        {
            let mut rec = stats.recorder.lock().unwrap();
            for s in live.iter() {
                if let Some(r) = s.task.accept_rate() {
                    rec.record_windowed("server.accept_rate", r, STATS_WINDOW);
                }
                if let Some(b) = s.task.allocated_budget() {
                    grants.add(b);
                    tracer.instant(Name::AllocGrant, s.job.uid, b as i64);
                }
            }
        }
        if !grants.is_empty() {
            stats.alloc_budget_total.store(grants.total as u64, Ordering::Relaxed);
            stats.alloc_rounds.fetch_add(1, Ordering::Relaxed);
        }
    }
    // Dropping `live` drops every task → all session KV caches freed.
    // Parked resume jobs drop with their reply senders (connections see
    // the server close).
    drop(live);
    drop(resume);
    stats.active_sessions.store(0, Ordering::Relaxed);
    stats.kv_slots_in_use.store(0, Ordering::Relaxed);
}

/// Opens a task for `job` and admits it. Fresh jobs that fail the
/// headroom check are rejected with a typed error; resumed jobs are
/// handed back (`Some`) to wait for blocks instead — their client is
/// already streaming, so rejection is not an option while the pool can
/// still drain. Every *fresh* dequeued job counts as a request, matching
/// the original FCFS accounting.
fn admit(
    engine: &mut Box<dyn StepEngine + Send>,
    job: Job,
    live: &mut Vec<ServeSession>,
    stats: &ServerStats,
    tracer: &Tracer,
    fresh: bool,
) -> Option<Job> {
    if fresh {
        stats.requests.fetch_add(1, Ordering::Relaxed);
    }
    if job.cancelled.load(Ordering::Relaxed) {
        // Client vanished while the job sat in the queue.
        stats.cancelled.fetch_add(1, Ordering::Relaxed);
        tracer.instant(Name::Disconnect, job.uid, 0);
        if job.trace_span != 0 {
            tracer.end(Name::Request, job.uid, job.trace_span);
        }
        return None;
    }
    let remaining = job.max_new.saturating_sub(job.resumed.len());
    match engine.begin(&job.prompt, remaining) {
        Ok(mut task) => {
            task.set_slo_class(job.class.is_latency());
            // Token-level admission counts only *new* blocks: a prompt
            // prefix served by the cross-request prefix cache (DESIGN.md
            // §12) is already resident, so the footprint to budget for is
            // the uncached tail.
            let need = task.uncached_prompt_len().unwrap_or(job.prompt.len());
            // Fresh jobs admit optimistically: pool covers the uncached
            // prompt + tree budget (headroom already subtracts the
            // budget). A *resumed* job re-admits only when the pool
            // covers its whole remaining footprint beyond what live
            // sessions are still projected to claim — optimistic
            // re-admission of mutually-starved sessions would ping-pong
            // through preempt/resume without anyone progressing.
            let fits = if fresh {
                task.headroom() >= need + 1
            } else {
                let outstanding: usize = live.iter().map(projected_demand).sum();
                task.headroom() >= need + remaining + 1 + outstanding
            };
            if !fits {
                if !fresh {
                    return Some(job); // park until blocks free up
                }
                stats.rejected.fetch_add(1, Ordering::Relaxed);
                tracer.instant(Name::Reject, job.uid, task.headroom() as i64);
                let message = format!(
                    "insufficient KV headroom for a {}-token prompt (headroom {})",
                    job.prompt.len(),
                    task.headroom()
                );
                let _ = job.reply.send(ServerEvent::Error { id: Some(job.id), message });
                // `task` drops here: its freshly allocated caches are freed.
            } else {
                let mut job = job;
                if job.queue_s.is_none() {
                    job.queue_s = Some(job.enqueued.elapsed().as_secs_f64());
                }
                let mut rec = stats.recorder.lock().unwrap();
                if fresh {
                    rec.record_windowed(
                        "server.queue_delay_s",
                        job.queue_s.unwrap_or(0.0),
                        STATS_WINDOW,
                    );
                } else {
                    stats.resumes.fetch_add(1, Ordering::Relaxed);
                    if let Some(t) = job.preempted_at {
                        // Preempt → re-admit latency: the re-prefill
                        // resume path's serving-side stage.
                        rec.record_windowed(
                            "server.resume_delay_s",
                            t.elapsed().as_secs_f64(),
                            STATS_WINDOW,
                        );
                    }
                }
                drop(rec);
                if fresh {
                    // The request span covers admit → done across any
                    // preemptions; the prefix-attach instant records the
                    // prompt tokens served from the radix trie.
                    job.trace_span = tracer.begin(Name::Request, job.uid);
                    tracer.instant(Name::Admit, job.uid, job.prompt.len() as i64);
                    let reused = job.prompt.len().saturating_sub(need);
                    if reused > 0 {
                        tracer.instant(Name::PrefixAttach, job.uid, reused as i64);
                    }
                } else {
                    tracer.instant(Name::Resume, job.uid, job.preempts as i64);
                }
                live.push(ServeSession { job, task, admitted: Instant::now() });
            }
        }
        Err(e) => {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            if job.trace_span != 0 {
                tracer.end(Name::Request, job.uid, job.trace_span);
            }
            let _ = job
                .reply
                .send(ServerEvent::Error { id: Some(job.id), message: format!("{e:#}") });
        }
    }
    None
}

/// Worst-case KV slots a live session may still claim from the shared
/// pool: its full projected footprint (prompt + remaining generation)
/// minus what it already holds. A coarse heuristic — good enough to stop
/// resumed jobs from re-admitting into guaranteed starvation.
fn projected_demand(s: &ServeSession) -> usize {
    let remaining = s.job.max_new.saturating_sub(s.job.resumed.len());
    (s.job.prompt.len() + remaining).saturating_sub(s.task.kv_slots_in_use())
}

/// Terminal rejection of a resumed job that can never be re-admitted
/// (empty pool still short of its prompt, or resume budget exceeded).
fn reject_unadmittable(job: Job, stats: &ServerStats, tracer: &Tracer) {
    stats.errors.fetch_add(1, Ordering::Relaxed);
    tracer.instant(Name::Reject, job.uid, 0);
    if job.trace_span != 0 {
        tracer.end(Name::Request, job.uid, job.trace_span);
    }
    let message = format!(
        "preempted request cannot resume: {}-token context exceeds the pool \
         (after {} preemptions)",
        job.prompt.len(),
        job.preempts
    );
    let _ = job.reply.send(ServerEvent::Error { id: Some(job.id), message });
}

/// True when `e` carries the typed [`PoolExhausted`] marker anywhere in
/// its chain — the paged cache's "preempt me" signal, as opposed to a
/// terminal engine failure.
fn is_pool_exhausted(e: &anyhow::Error) -> bool {
    e.chain().any(|c| c.downcast_ref::<PoolExhausted>().is_some())
}

/// Preempts a session under pool exhaustion: drop the task (every leased
/// block returns to the shared pool immediately), fold the generated
/// prefix into the saved prompt, and requeue the job for a re-prefill
/// resume (DESIGN.md §10).
fn preempt(s: ServeSession, resume: &mut VecDeque<Job>, stats: &ServerStats, tracer: &Tracer) {
    let ServeSession { mut job, task, admitted } = s;
    let g = task.finish(); // consumes the task: blocks are freed here
    stats.tokens.fetch_add(g.tokens.len() as u64, Ordering::Relaxed);
    job.active_s += admitted.elapsed().as_secs_f64();
    job.prompt.extend_from_slice(&g.tokens);
    job.resumed.extend_from_slice(&g.tokens);
    job.preempts += 1;
    job.preempted_at = Some(Instant::now());
    stats.preemptions.fetch_add(1, Ordering::Relaxed);
    tracer.instant(Name::Preempt, job.uid, job.preempts as i64);
    dump_recent_window(tracer, "preemption", job.uid);
    resume.push_back(job);
}

/// Post-mortem aid (DESIGN.md §17): on degradation escalation or
/// preemption, render the flight recorder's last-[`trace::DUMP_ROUNDS`]
/// rounds to the log stream at Warn — the decisions leading up to the
/// event survive without reproduction. Allocates; never on the clean
/// round path.
fn dump_recent_window(tracer: &Tracer, why: &str, uid: u64) {
    if !log::enabled(log::Level::Warn) {
        return;
    }
    let w = tracer.window(trace::DUMP_ROUNDS);
    log::log(
        log::Level::Warn,
        Some(tracer.worker()),
        Some(uid),
        &format!(
            "{why}: flight-recorder dump of the last {} rounds ({} events)\n{}",
            trace::DUMP_ROUNDS,
            w.len(),
            trace::format_window(&w)
        ),
    );
}

/// One scheduling round over the live set, removing sessions as they
/// cancel, finish, preempt, or fail.
///
/// The round is *packed* (DESIGN.md §14): every warm (non-`Prefill`)
/// session steps, plus at most **one** cold session doing prompt work —
/// with [`BatchConfig::prefill_chunk`](crate::config::BatchConfig) set,
/// that is one chunk of one cold prompt per round, so a long arrival
/// never stalls the warm sessions behind a monolithic prefill call.
/// Latency-class cold sessions take the slot ahead of throughput-class
/// ones.
///
/// In round-robin mode each stepped task takes one serial `step()` (the
/// time-sliced discipline). In batched mode the packed subset goes
/// through [`StepEngine::step_batch`] *once*, so the engine sees the
/// round together and runs it stage-aligned (DESIGN.md §9 + §11) —
/// outcomes still arrive one per stepped task and are applied
/// identically.
///
/// A pool-exhausted step escalates the [`DegradationLadder`] one rung
/// (per round) and republishes the rung to the engine; tasks that report
/// [`DecodeTask::retryable`] stay live and simply re-step next round
/// under the shed budgets — preemption happens only at
/// [`RUNG_PREEMPT`](crate::scheduler::RUNG_PREEMPT) or for
/// non-retryable tasks. Exhaustion-free rounds relax the ladder.
fn round(
    engine: &mut Box<dyn StepEngine + Send>,
    live: &mut Vec<ServeSession>,
    resume: &mut VecDeque<Job>,
    stats: &ServerStats,
    tracer: &Tracer,
    opts: &ServeOpts,
    ladder: &mut DegradationLadder,
) {
    // Drop cancelled sessions first: frees their KV immediately and
    // keeps them out of this round's batch.
    let mut i = 0;
    while i < live.len() {
        if live[i].job.cancelled.load(Ordering::Relaxed) {
            let s = live.remove(i);
            tracer.instant(Name::Disconnect, s.job.uid, 0);
            if s.job.trace_span != 0 {
                tracer.end(Name::Request, s.job.uid, s.job.trace_span);
            }
            drop(s); // frees the task's KV caches now
            stats.cancelled.fetch_add(1, Ordering::Relaxed);
        } else {
            i += 1;
        }
    }
    if live.is_empty() {
        return;
    }
    // Pack the round: all warm sessions + at most one cold prefill.
    let mut cold: Option<usize> = None;
    let mut stepped: Vec<usize> = Vec::with_capacity(live.len());
    for (i, s) in live.iter().enumerate() {
        if s.task.state() == TaskState::Prefill {
            let better = match cold {
                None => true,
                Some(j) => s.job.class.is_latency() && !live[j].job.class.is_latency(),
            };
            if better {
                cold = Some(i);
            }
        } else {
            stepped.push(i);
        }
    }
    if let Some(c) = cold {
        stepped.push(c);
        stepped.sort_unstable();
    }
    let outcomes: Vec<crate::Result<StepOutcome>> = if opts.batched {
        let mut want = stepped.iter().copied().peekable();
        let mut refs: Vec<&mut dyn DecodeTask> = Vec::with_capacity(stepped.len());
        for (i, s) in live.iter_mut().enumerate() {
            if want.peek() == Some(&i) {
                want.next();
                refs.push(s.task.as_mut());
            }
        }
        engine.step_batch(&mut refs)
    } else {
        stepped.iter().map(|&i| live[i].task.step()).collect()
    };
    // Apply outcomes back-to-front so removals keep earlier indices valid
    // (`stepped` is ascending).
    debug_assert_eq!(outcomes.len(), stepped.len());
    let now = Instant::now();
    let mut exhausted_this_round = false;
    for (k, outcome) in outcomes.into_iter().enumerate().rev() {
        let i = stepped[k];
        match outcome {
            Ok(out) => {
                if cold == Some(i) {
                    // The cold session advanced one unit of prefill work
                    // (a chunk, or the whole prompt when unchunked).
                    stats.prefill_chunks.fetch_add(1, Ordering::Relaxed);
                    let left = live[i].task.uncached_prompt_len().unwrap_or(0);
                    tracer.instant(Name::PrefillChunk, live[i].job.uid, left as i64);
                }
                let done = out.done();
                if !out.tokens.is_empty() {
                    let s = &mut live[i];
                    if s.job.first_token.is_none() {
                        s.job.first_token = Some(now);
                        let ttft = s.job.enqueued.elapsed().as_secs_f64();
                        stats
                            .recorder
                            .lock()
                            .unwrap()
                            .record_windowed("server.ttft_s", ttft, STATS_WINDOW);
                    }
                    if let Some(prev) = s.job.last_token {
                        // Per-class inter-token latency: the metric the
                        // SLO classes and the degradation ladder protect.
                        let gap = now.duration_since(prev).as_secs_f64();
                        let series = if s.job.class.is_latency() {
                            "server.itl_s.latency"
                        } else {
                            "server.itl_s.throughput"
                        };
                        stats
                            .recorder
                            .lock()
                            .unwrap()
                            .record_windowed(series, gap, STATS_WINDOW);
                        if s.job.class.is_latency() && gap * 1e3 > opts.slo_target_ms {
                            stats.slo_violations.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    s.job.last_token = Some(now);
                    if s.job.stream {
                        let ev = ServerEvent::Tokens { id: s.job.id, tokens: out.tokens };
                        if s.job.reply.send(ev).is_err() {
                            // Connection dropped between rounds.
                            let s = live.remove(i);
                            tracer.instant(Name::Disconnect, s.job.uid, 0);
                            if s.job.trace_span != 0 {
                                tracer.end(Name::Request, s.job.uid, s.job.trace_span);
                            }
                            drop(s);
                            stats.cancelled.fetch_add(1, Ordering::Relaxed);
                            continue;
                        }
                    }
                }
                if done {
                    let s = live.remove(i);
                    finish_session(s, stats, tracer);
                }
            }
            Err(e) => {
                // A dry shared pool is a scheduling condition, not a
                // request failure. Walk the degradation ladder before
                // reaching for preemption: escalate one rung (once per
                // round), republish it to the engine, and — if the task
                // can safely re-step — keep it live so the shed budgets
                // (shrunk verify trees, skipped throughput-class drafts,
                // harder chunking) get a chance to drain the pressure.
                if is_pool_exhausted(&e) {
                    if !exhausted_this_round {
                        exhausted_this_round = true;
                        let rung = ladder.escalate();
                        engine.set_degradation(rung);
                        stats.degraded_rounds.fetch_add(1, Ordering::Relaxed);
                        tracer.instant(Name::RungChange, live[i].job.uid, rung as i64);
                        dump_recent_window(tracer, "degradation escalation", live[i].job.uid);
                    }
                    if live[i].task.retryable() && !ladder.at_preempt() {
                        continue;
                    }
                    // Top rung (or a task that cannot re-step): preempt so
                    // its blocks drain to the survivors (or to parked
                    // resumes), unless it is truly alone — nothing live or
                    // parked could ever free a block for it — or out of
                    // resume budget.
                    if (live.len() > 1 || !resume.is_empty())
                        && live[i].job.preempts < opts.max_resumes
                    {
                        let s = live.remove(i);
                        preempt(s, resume, stats, tracer);
                        continue;
                    }
                }
                let s = live.remove(i);
                stats.errors.fetch_add(1, Ordering::Relaxed);
                if s.job.trace_span != 0 {
                    tracer.end(Name::Request, s.job.uid, s.job.trace_span);
                }
                // A request that already survived preemptions dies here
                // because its resume budget (or sole tenancy) ran out —
                // surface that as the typed terminal-resume error instead
                // of a raw engine failure mid-stream.
                let message = if s.job.preempts > 0 {
                    format!(
                        "preempted request cannot resume: {e:#} (after {} preemptions)",
                        s.job.preempts
                    )
                } else {
                    format!("{e:#}")
                };
                let _ = s.job.reply.send(ServerEvent::Error { id: Some(s.job.id), message });
            }
        }
    }
    if !exhausted_this_round && ladder.relax() {
        engine.set_degradation(ladder.rung());
        tracer.instant(Name::RungChange, 0, ladder.rung() as i64);
    }
    stats.degrade_rung.store(ladder.rung() as u64, Ordering::Relaxed);
}

/// Completes a session: final metrics + the typed `done` event. Tokens
/// generated before any preemption are prepended so the summary always
/// carries the full sequence.
fn finish_session(s: ServeSession, stats: &ServerStats, tracer: &Tracer) {
    let ServeSession { job, task, admitted } = s;
    let g = task.finish();
    stats.tokens.fetch_add(g.tokens.len() as u64, Ordering::Relaxed);
    let mut tokens = job.resumed.clone();
    tokens.extend_from_slice(&g.tokens);
    let active_s = job.active_s + admitted.elapsed().as_secs_f64();
    let tok_per_s = if active_s > 0.0 { tokens.len() as f64 / active_s } else { 0.0 };
    // Queueing delay is enqueue → *first* admission: a preempted request's
    // later re-admissions are generation-time churn, not queue time.
    let queue_ms = job
        .queue_s
        .unwrap_or_else(|| admitted.duration_since(job.enqueued).as_secs_f64())
        * 1e3;
    let ttft_ms = job
        .first_token
        .map(|t| t.duration_since(job.enqueued).as_secs_f64() * 1e3)
        .unwrap_or(f64::NAN);
    stats
        .recorder
        .lock()
        .unwrap()
        .record_windowed("server.tok_per_s", tok_per_s, STATS_WINDOW);
    let aal = g.aal();
    let tpot_ms = g.tpot() * 1e3;
    let summary = DoneSummary {
        aal,
        tpot_ms,
        iterations: g.iterations,
        prefill_ms: g.prefill_seconds * 1e3,
        queue_ms,
        ttft_ms,
        tok_per_s,
        preemptions: job.preempts,
        tokens,
    };
    tracer.instant(Name::Done, job.uid, summary.tokens.len() as i64);
    if job.trace_span != 0 {
        tracer.end(Name::Request, job.uid, job.trace_span);
    }
    let _ = job.reply.send(ServerEvent::Done { id: job.id, summary });
}

#[cfg(test)]
mod tests {
    use super::super::MockStepEngine;
    use super::*;
    use std::sync::atomic::AtomicBool;

    fn test_job(
        id: u64,
        prompt: Vec<u32>,
        max_new: usize,
        class: SloClass,
    ) -> (Job, mpsc::Receiver<ServerEvent>) {
        let (tx, rx) = mpsc::channel();
        let cancel = Arc::new(AtomicBool::new(false));
        (Job::new(id, prompt, max_new, class, tx, false, cancel), rx)
    }

    /// Fault injection (DESIGN.md §14): with the shared pool held dry by
    /// two greedy sessions, the scheduler must walk the degradation
    /// ladder in order — shrink budgets, skip drafts, chunk harder — and
    /// preempt only once the top rung is reached, never before.
    #[test]
    fn exhaustion_walks_the_ladder_before_preempting() {
        // block_size 1: every allocation draws on the shared pool, so
        // the two sessions keep it dry round after round.
        let mock = MockStepEngine::with_paged_pool(0, 4, 12, 1).unwrap();
        let rungs = mock.rungs_seen.clone();
        let mut engine: Box<dyn StepEngine + Send> = Box::new(mock);
        let stats = ServerStats::default();
        let opts = ServeOpts::default();
        let mut live: Vec<ServeSession> = Vec::new();
        let mut resume: VecDeque<Job> = VecDeque::new();
        let mut ladder = DegradationLadder::new();
        let tracer = Tracer::new(0, 256);
        let mut rxs = Vec::new();
        for id in 0..2u64 {
            let (job, rx) = test_job(id, vec![100 * (id as u32 + 1); 5], 8, SloClass::Latency);
            rxs.push(rx);
            assert!(admit(&mut engine, job, &mut live, &stats, &tracer, true).is_none());
        }
        assert_eq!(live.len(), 2, "both sessions admitted");
        for _ in 0..24 {
            round(&mut engine, &mut live, &mut resume, &stats, &tracer, &opts, &mut ladder);
            let preempted = stats.preemptions.load(Ordering::Relaxed);
            if !rungs.lock().unwrap().contains(&crate::scheduler::RUNG_PREEMPT) {
                assert_eq!(preempted, 0, "preempted before the ladder's top rung");
            }
            if preempted > 0 {
                break;
            }
        }
        assert_eq!(
            rungs.lock().unwrap().clone(),
            vec![
                crate::scheduler::RUNG_SHRINK_BUDGET,
                crate::scheduler::RUNG_SKIP_DRAFT,
                crate::scheduler::RUNG_CHUNK_HARDER,
                crate::scheduler::RUNG_PREEMPT,
            ],
            "one rung per exhausted round, in ladder order"
        );
        let preempted = stats.preemptions.load(Ordering::Relaxed);
        assert!(preempted > 0, "the top rung finally preempts");
        assert_eq!(resume.len(), preempted as usize, "preempted jobs parked for resume");
        assert!(stats.degraded_rounds.load(Ordering::Relaxed) >= 4);
    }

    /// Round packing (DESIGN.md §14): at most one cold session prefills
    /// per round — a chunk at a time — and a latency-class cold prompt
    /// takes the slot ahead of a throughput-class one.
    #[test]
    fn one_cold_prefill_chunk_per_round_prefers_latency_class() {
        let mock = MockStepEngine::new(0, 2, 1024).with_prefill_chunk(4);
        let mut engine: Box<dyn StepEngine + Send> = Box::new(mock);
        let stats = ServerStats::default();
        let opts = ServeOpts::default();
        let mut live: Vec<ServeSession> = Vec::new();
        let mut resume: VecDeque<Job> = VecDeque::new();
        let mut ladder = DegradationLadder::new();
        let tracer = Tracer::new(0, 256);
        let (tp, _rx0) = test_job(0, vec![10; 9], 4, SloClass::Throughput);
        let (lat, _rx1) = test_job(1, vec![20; 9], 4, SloClass::Latency);
        assert!(admit(&mut engine, tp, &mut live, &stats, &tracer, true).is_none());
        assert!(admit(&mut engine, lat, &mut live, &stats, &tracer, true).is_none());
        round(&mut engine, &mut live, &mut resume, &stats, &tracer, &opts, &mut ladder);
        assert_eq!(stats.prefill_chunks.load(Ordering::Relaxed), 1);
        assert_eq!(
            live[1].task.uncached_prompt_len(),
            Some(5),
            "the latency-class prompt advanced one 4-token chunk"
        );
        assert_eq!(
            live[0].task.uncached_prompt_len(),
            Some(9),
            "the throughput-class prompt waited"
        );
        // 9 tokens at chunk 4 = 3 chunks per prompt, interleaved one per
        // round with the finished session's decode steps.
        for _ in 0..6 {
            round(&mut engine, &mut live, &mut resume, &stats, &tracer, &opts, &mut ladder);
        }
        assert_eq!(stats.prefill_chunks.load(Ordering::Relaxed), 6);
        assert!(live.iter().all(|s| s.task.state() != TaskState::Prefill));
    }

    #[test]
    fn events_serialize_with_ids_and_kind() {
        let ev = ServerEvent::Tokens { id: 7, tokens: vec![1, 2] };
        let j = ev.to_json();
        assert_eq!(j.str("event").unwrap(), "tokens");
        assert_eq!(j.u64("id").unwrap(), 7);
        let err = ServerEvent::Error { id: None, message: "boom".into() };
        assert_eq!(err.to_json().str("event").unwrap(), "error");
        assert!(err.to_json().get("id").is_none());
    }

    #[test]
    fn done_event_carries_serving_metrics() {
        let ev = ServerEvent::Done {
            id: 3,
            summary: DoneSummary {
                tokens: vec![9],
                aal: 2.0,
                tpot_ms: 1.5,
                iterations: 4,
                prefill_ms: 0.3,
                queue_ms: 12.0,
                ttft_ms: 20.0,
                tok_per_s: 800.0,
                preemptions: 2,
            },
        };
        let j = ev.to_json();
        assert_eq!(j.str("event").unwrap(), "done");
        assert!((j.f64("queue_ms").unwrap() - 12.0).abs() < 1e-9);
        assert!((j.f64("ttft_ms").unwrap() - 20.0).abs() < 1e-9);
        assert!((j.f64("tok_per_s").unwrap() - 800.0).abs() < 1e-9);
        assert_eq!(j.usize("preemptions").unwrap(), 2);
    }

    #[test]
    fn huge_ids_survive_the_wire_format() {
        let id = u64::MAX - 1;
        let ev = ServerEvent::Tokens { id, tokens: vec![] };
        let line = ev.to_json().to_string();
        let back = Json::parse(&line).unwrap();
        assert_eq!(back.u64("id").unwrap(), id);
    }

    #[test]
    fn pool_exhausted_is_detected_through_context_chains() {
        let base = anyhow::Error::new(PoolExhausted { what: "test" });
        let wrapped = base.context("mid-iteration failure");
        assert!(is_pool_exhausted(&wrapped));
        assert!(!is_pool_exhausted(&anyhow::anyhow!("ordinary failure")));
    }
}
